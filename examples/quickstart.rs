//! Quickstart: load the trained artifacts, run one AgileNN inference end to
//! end, and print the full latency/energy breakdown.
//!
//!     cargo run --release --example quickstart
//!
//! Requires `make artifacts` to have been run (or AGILENN_ARTIFACTS set).

use agilenn::baselines::{make_runner, SchemeRunner};
use agilenn::config::{default_artifacts_dir, Meta, RunConfig, Scheme};
use agilenn::runtime::Engine;
use agilenn::workload::TestSet;
use anyhow::Result;

fn main() -> Result<()> {
    let cfg = RunConfig::new(default_artifacts_dir(), "svhns", Scheme::Agile);
    let meta = Meta::load(&cfg.dataset_dir())?;
    let testset = TestSet::load(&cfg.dataset_dir().join("test.bin"))?;
    let engine = Engine::cpu()?;
    println!("PJRT platform: {}", engine.platform());
    println!(
        "AgileNN[{}]: {} classes, k={} of {} channels local, alpha={:.3}",
        meta.dataset, meta.num_classes, meta.k, meta.feature[2], meta.alpha
    );

    let mut runner = make_runner(&engine, &cfg, &meta)?;
    let mut correct = 0;
    let n = 16.min(testset.len());
    for i in 0..n {
        let out = runner.process(&testset.image(i)?, testset.labels[i])?;
        correct += out.correct as usize;
        if i == 0 {
            println!("\nfirst request breakdown:");
            println!("  local NN    : {:.2} ms", out.breakdown.local_nn_s * 1e3);
            println!("  compression : {:.2} ms", out.breakdown.compression_s * 1e3);
            println!("  network     : {:.2} ms", out.breakdown.network_s * 1e3);
            println!("  remote NN   : {:.2} ms", out.breakdown.remote_s * 1e3);
            println!("  total       : {:.2} ms", out.breakdown.total_s() * 1e3);
            println!("  tx bytes    : {} (raw would be {})", out.tx_bytes,
                     meta.tx_elements(Scheme::Agile) * 4);
            println!("  energy      : {:.2} mJ", out.energy.total_mj());
        }
    }
    println!("\naccuracy over {n} requests: {:.1}%", 100.0 * correct as f64 / n as f64);
    Ok(())
}
