//! Quickstart: load the trained artifacts, serve a short AgileNN run
//! through the batched pipeline, and print the per-request breakdown of
//! the first streamed outcome plus the aggregate report.
//!
//!     cargo run --release --example quickstart
//!
//! Requires `make artifacts` to have been run (or AGILENN_ARTIFACTS set).

use agilenn::config::Scheme;
use agilenn::serve::ServeBuilder;
use anyhow::Result;

fn main() -> Result<()> {
    // one device, batch of 1: the printed remote time is pure server work,
    // with no batch-deadline queueing mixed in
    let service = ServeBuilder::new("svhns")
        .scheme(Scheme::Agile)
        .devices(1)
        .requests(16)
        .max_batch(1)
        .build()?;
    let meta = service.meta();
    println!(
        "AgileNN[{}]: {} classes, k={} of {} channels local, alpha={:.3}",
        meta.dataset, meta.num_classes, meta.k, meta.feature[2], meta.alpha
    );
    let raw_tx = meta.tx_elements(Scheme::Agile) * 4;

    let mut outcomes = service.stream()?;
    for out in outcomes.by_ref() {
        if out.id == 0 {
            let b = &out.outcome.breakdown;
            println!("\nfirst request breakdown:");
            println!("  local NN    : {:.2} ms", b.local_nn_s * 1e3);
            println!("  compression : {:.2} ms", b.compression_s * 1e3);
            println!("  network     : {:.2} ms", b.network_s * 1e3);
            println!("  remote NN   : {:.2} ms", b.remote_s * 1e3);
            println!("  total       : {:.2} ms", b.total_s() * 1e3);
            println!("  tx bytes    : {} (raw would be {raw_tx})", out.outcome.tx_bytes);
            println!("  energy      : {:.2} mJ", out.outcome.energy.total_mj());
        }
    }
    let report = outcomes.finish()?;
    println!(
        "\naccuracy over {} requests: {:.1}% ({:.1} req/s through the pipeline)",
        report.requests,
        report.accuracy * 100.0,
        report.throughput_rps
    );
    Ok(())
}
