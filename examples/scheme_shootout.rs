//! Scheme shootout: every serving scheme on every built dataset — a compact
//! version of the paper's whole evaluation section in one run. Prints the
//! exact synchronous accounting first, then drives all five schemes
//! through the batched multi-device serving pipeline (the redesign's
//! point: the baselines batch too, not just AgileNN).
//!
//!     cargo run --release --example scheme_shootout [n_per_point]

use agilenn::config::Scheme;
use agilenn::experiments::{eval_scheme, EvalCtx};
use agilenn::report::{mj, ms, pct, Table};
use agilenn::serve::ServeBuilder;
use anyhow::Result;

fn main() -> Result<()> {
    let n: usize = std::env::args().nth(1).and_then(|s| s.parse().ok()).unwrap_or(64);
    let ctx = EvalCtx::from_env()?;
    for ds in ctx.datasets.clone() {
        let mut t = Table::new(
            format!("shootout [{ds}] ({n} requests/scheme)"),
            &["scheme", "total_ms", "local_ms", "net_ms", "tx_bytes", "energy_mJ", "acc", "early_exit"],
        );
        for scheme in Scheme::all() {
            let e = eval_scheme(&ctx, &ctx.run_config(&ds, scheme), n)?;
            t.row(vec![
                scheme.name().into(),
                ms(e.total_latency_s()),
                ms(e.mean.local_nn_s),
                ms(e.mean.network_s),
                format!("{:.0}", e.mean_tx_bytes),
                mj(e.mean_energy.total_j()),
                pct(e.accuracy),
                pct(e.early_exit_rate),
            ]);
        }
        t.print();
        println!();

        let mut t2 = Table::new(
            format!("served [{ds}] (4 devices, {n} requests/scheme, batched)"),
            &["scheme", "throughput_rps", "mean_ms", "p95_ms", "mean_batch", "acc"],
        );
        for scheme in Scheme::all() {
            let rep = ServeBuilder::new(&ds)
                .artifacts_dir(ctx.artifacts_dir.clone())
                .scheme(scheme)
                .devices(4)
                .requests(n)
                .rate_hz(200.0)
                .build()?
                .run()?;
            t2.row(vec![
                scheme.name().into(),
                format!("{:.1}", rep.throughput_rps),
                ms(rep.mean_latency_s),
                ms(rep.p95_latency_s),
                format!("{:.2}", rep.mean_batch_size),
                pct(rep.accuracy),
            ]);
        }
        t2.print();
        println!();
    }
    Ok(())
}
