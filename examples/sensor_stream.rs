//! Sensor-stream serving (the paper's motivating workload, §1/§7.2):
//! several camera-class sensors sample at 30 Hz and stream through the
//! threaded AgileNN pipeline with dynamic remote batching. Real-time means
//! the per-request latency stays under the 33 ms sampling interval.
//!
//! The wall-clock sweeps measure the live pipeline; the final run swaps
//! in the discrete-event sim clock (`ClockKind::Sim`) to play a
//! 100k-request day-in-the-life schedule in seconds of wall time with
//! seed-deterministic latency quantiles.
//!
//!     cargo run --release --example sensor_stream [dataset]

use agilenn::config::Scheme;
use agilenn::serve::{ClockKind, ServeBuilder};
use agilenn::workload::Arrival;
use anyhow::Result;
use std::time::Instant;

fn main() -> Result<()> {
    let dataset = std::env::args().nth(1).unwrap_or_else(|| "svhns".into());

    for devices in [1usize, 4, 8] {
        let rep = ServeBuilder::new(&dataset)
            .scheme(Scheme::Agile)
            .devices(devices)
            .requests(devices * 60)
            .arrival(Arrival::Periodic { hz: 30.0 })
            .max_batch(8)
            .batch_deadline_us(3000)
            .build()?
            .run()?;
        println!(
            "{devices} sensors @30Hz: {:>6.1} req/s, mean {:.2} ms, p95 {:.2} ms, \
             acc {:.1}%, mean batch {:.2} ({} batches){}",
            rep.throughput_rps,
            rep.mean_latency_s * 1e3,
            rep.p95_latency_s * 1e3,
            rep.accuracy * 100.0,
            rep.mean_batch_size,
            rep.batches,
            if rep.mean_latency_s < 1.0 / 30.0 { "  [real-time OK]" } else { "  [MISSES 30Hz]" },
        );
    }

    // virtual time: 100k requests over 8 sensors at 30 Hz is ~7 minutes
    // of arrival pacing on the wall clock; the sim clock plays the same
    // schedule without sleeping, and every quantile is seed-deterministic
    let t = Instant::now();
    let rep = ServeBuilder::new(&dataset)
        .scheme(Scheme::Agile)
        .devices(8)
        .requests(100_000)
        .rate_hz(30.0)
        .arrival_seed(42)
        .clock(ClockKind::Sim)
        .build()?
        .run()?;
    println!(
        "sim clock: {} reqs in {:.1} s wall ({:.1} s virtual), {:.0} req/s virtual, \
         p95 {:.2} ms, acc {:.1}%",
        rep.requests,
        t.elapsed().as_secs_f64(),
        rep.wall_s,
        rep.throughput_rps,
        rep.p95_latency_s * 1e3,
        rep.accuracy * 100.0,
    );

    // fleet scale: the sim runs on the discrete-event engine, so a
    // 500k-request, 5k-sensor sweep across 4 sharded servers is a few
    // seconds of host time — with per-server load in the report
    let t = Instant::now();
    let rep = ServeBuilder::new(&dataset)
        .scheme(Scheme::Agile)
        .devices(5_000)
        .requests(500_000)
        .rate_hz(20.0)
        .arrival_seed(42)
        .clock(ClockKind::Sim)
        .servers(4)
        .placement(agilenn::serve::Placement::LeastLoaded)
        .build()?
        .run()?;
    println!(
        "fleet engine: {} reqs x 5k sensors x 4 servers in {:.1} s wall, \
         p95 {:.2} ms, shard loads {:?}",
        rep.requests,
        t.elapsed().as_secs_f64(),
        rep.p95_latency_s * 1e3,
        rep.shards.iter().map(|s| s.requests).collect::<Vec<_>>(),
    );
    Ok(())
}
