//! Degraded-network scenario (paper §7.6 + §9 "extreme network
//! conditions"): sweep the link from 6 Mbps WiFi down to a 270 kbps
//! BLE-class radio via the serve builder's network profile, then cut the
//! link entirely and fall back to local-only prediction from the top-k
//! important features.
//!
//!     cargo run --release --example degraded_network [dataset]

use agilenn::baselines::AgileRunner;
use agilenn::config::{default_artifacts_dir, Meta, RunConfig, Scheme};
use agilenn::runtime::Engine;
use agilenn::serve::ServeBuilder;
use agilenn::simulator::NetworkProfile;
use agilenn::workload::TestSet;
use anyhow::Result;

fn main() -> Result<()> {
    let dataset = std::env::args().nth(1).unwrap_or_else(|| "svhns".into());
    let n = 64usize;

    println!("link degradation sweep on {dataset} ({n} requests each):");
    for kbps in [6000.0, 1000.0, 270.0] {
        let profile = if kbps <= 300.0 {
            NetworkProfile::ble_270kbps()
        } else {
            NetworkProfile::wifi_6mbps().with_bandwidth(kbps * 1e3)
        };
        // stream the outcomes: the simulated breakdown carries the link
        // model. max_batch 1 keeps the lone device's measured remote time
        // free of batch-deadline queueing, matching the sweep's intent.
        let mut outcomes = ServeBuilder::new(&dataset)
            .scheme(Scheme::Agile)
            .devices(1)
            .requests(n)
            .max_batch(1)
            .network_profile(profile)
            .build()?
            .stream()?;
        let (mut total, mut correct) = (0.0f64, 0usize);
        for out in outcomes.by_ref() {
            total += out.outcome.breakdown.total_s();
            correct += out.outcome.correct as usize;
        }
        let rep = outcomes.finish()?;
        println!(
            "  {:>7.0} kbps: mean latency {:6.2} ms, accuracy {:.1}%",
            kbps,
            total / rep.requests as f64 * 1e3,
            100.0 * correct as f64 / rep.requests as f64
        );
    }

    // link down: local-only fallback (§9) — most important features are local
    let base = RunConfig::new(default_artifacts_dir(), &dataset, Scheme::Agile);
    let meta = Meta::load(&base.dataset_dir())?;
    let testset = TestSet::load(&base.dataset_dir().join("test.bin"))?;
    let engine = Engine::cpu()?;
    let n = n.min(testset.len());
    let mut runner = AgileRunner::new(&engine, &base, &meta)?;
    let (mut total, mut correct) = (0.0f64, 0usize);
    for i in 0..n {
        let out = runner.process_offline(&testset.image(i)?, testset.labels[i])?;
        total += out.breakdown.total_s();
        correct += out.correct as usize;
    }
    println!(
        "  link DOWN    : mean latency {:6.2} ms, accuracy {:.1}% (local top-k only)",
        total / n as f64 * 1e3,
        100.0 * correct as f64 / n as f64
    );
    Ok(())
}
