//! Degraded-network scenarios (paper §7.6 + §9 "extreme network
//! conditions"), end to end through the `agilenn::net` channel subsystem:
//!
//! 1. bandwidth sweep — 6 Mbps WiFi down to a 270 kbps BLE-class radio;
//! 2. loss sweep — Gilbert–Elliott bursty packet loss at 0/10/30/50%,
//!    comparing ARQ (retransmit until complete: latency pays) against the
//!    deadline-bounded anytime transport with importance-ordered vs naive
//!    packets (accuracy pays, gracefully);
//! 3. link down — local-only fallback from the top-k important features.
//!
//!     cargo run --release --example degraded_network [dataset] [backend]
//!
//! `backend` is `pjrt` (default; needs `make artifacts` and a
//! pjrt-enabled build) or `reference` (pure-Rust deterministic model
//! family + synthetic dataset — runs anywhere, no artifacts).

use agilenn::baselines::AgileRunner;
use agilenn::config::{default_artifacts_dir, BackendKind, RunConfig, Scheme};
use agilenn::net::{DeliveryPolicy, GilbertElliott, PacketOrder};
use agilenn::runtime::make_backend;
use agilenn::serve::{ClockKind, ServeBuilder};
use agilenn::simulator::NetworkProfile;
use agilenn::workload::Arrival;
use anyhow::Result;

/// Sweep pacing: 30 Hz keeps the radio uncontended (the sweeps isolate
/// transport behavior, not queueing) and the sim clock makes it free.
const SWEEP_ARRIVAL: Arrival = Arrival::Periodic { hz: 30.0 };

fn main() -> Result<()> {
    let dataset = std::env::args().nth(1).unwrap_or_else(|| "svhns".into());
    let backend: BackendKind = std::env::args().nth(2).as_deref().unwrap_or("pjrt").parse()?;
    let n = 64usize;

    println!("link degradation sweep on {dataset} [{}] ({n} requests each):", backend.name());
    for kbps in [6000.0, 1000.0, 270.0] {
        let profile = if kbps <= 300.0 {
            NetworkProfile::ble_270kbps()
        } else {
            NetworkProfile::wifi_6mbps().with_bandwidth(kbps * 1e3)
        };
        // stream the outcomes: the simulated breakdown carries the link
        // model. max_batch 1 keeps the lone device's measured remote time
        // free of batch-deadline queueing, matching the sweep's intent.
        let mut outcomes = ServeBuilder::new(&dataset)
            .scheme(Scheme::Agile)
            .backend(backend)
            .devices(1)
            .requests(n)
            .max_batch(1)
            .arrival(SWEEP_ARRIVAL)
            .clock(ClockKind::Sim)
            .network_profile(profile)
            .build()?
            .stream()?;
        let (mut total, mut correct) = (0.0f64, 0usize);
        for out in outcomes.by_ref() {
            total += out.outcome.breakdown.total_s();
            correct += out.outcome.correct as usize;
        }
        let rep = outcomes.finish()?;
        println!(
            "  {:>7.0} kbps: mean latency {:6.2} ms, accuracy {:.1}%",
            kbps,
            total / rep.requests as f64 * 1e3,
            100.0 * correct as f64 / rep.requests as f64
        );
    }

    // lossy link: ARQ pays latency, anytime pays (a little) accuracy —
    // least when the most important features ship first. Same seed across
    // configurations: the comparison is paired packet for packet.
    println!("\npacket-loss sweep (bursty, mean burst 4 pkts; anytime deadline 3 ms):");
    for loss in [0.0, 0.1, 0.3, 0.5] {
        for (label, delivery, order) in [
            ("arq        ", DeliveryPolicy::Arq, PacketOrder::Importance),
            (
                "anytime/imp",
                DeliveryPolicy::Anytime { deadline_s: 3e-3 },
                PacketOrder::Importance,
            ),
            ("anytime/idx", DeliveryPolicy::Anytime { deadline_s: 3e-3 }, PacketOrder::Index),
        ] {
            let rep = ServeBuilder::new(&dataset)
                .scheme(Scheme::Agile)
                .backend(backend)
                .devices(1)
                .requests(n)
                .max_batch(1)
                .arrival(SWEEP_ARRIVAL)
                .clock(ClockKind::Sim)
                .loss(GilbertElliott::bursty(loss, 4.0))
                .delivery(delivery)
                .packet_order(order)
                .packet_payload(64)
                .net_seed(42)
                .build()?
                .run()?;
            println!(
                "  loss {:>3.0}% {label}: accuracy {:>5.1}%, link p99 {:>6.2} ms, \
                 features {:>5.1}%, {} retx rounds",
                loss * 100.0,
                rep.accuracy * 100.0,
                rep.p99_net_s * 1e3,
                rep.delivered_feature_rate * 100.0,
                rep.retransmit_rounds
            );
        }
    }

    // link down: local-only fallback (§9) — most important features are local
    let mut base = RunConfig::new(default_artifacts_dir(), &dataset, Scheme::Agile);
    base.backend = backend;
    let (meta, testset) = agilenn::fixtures::load_world(&base)?;
    let backend_impl = make_backend(&base, &meta)?;
    let n = n.min(testset.len());
    let mut runner = AgileRunner::new(backend_impl.as_ref(), &base, &meta)?;
    let (mut total, mut correct) = (0.0f64, 0usize);
    for i in 0..n {
        let out = runner.process_offline(&testset.image(i)?, testset.labels[i])?;
        total += out.breakdown.total_s();
        correct += out.correct as usize;
    }
    println!(
        "\n  link DOWN    : mean latency {:6.2} ms, accuracy {:.1}% (local top-k only)",
        total / n as f64 * 1e3,
        100.0 * correct as f64 / n as f64
    );
    Ok(())
}
