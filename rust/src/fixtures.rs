//! Synthetic fixtures: a fabricated [`Meta`] / [`Manifest`] and an
//! in-memory [`TestSet`] so a [`RunConfig`](crate::config::RunConfig) on
//! the reference backend works with **no artifacts directory at all**.
//!
//! One [`SyntheticSpec`] pins everything the python export would have
//! written — class count, image/feature geometry, the top-k importance
//! split, per-bit-width codebooks — plus the seed of the deterministic
//! sample generator. The generated images and the
//! [`ReferenceBackend`](crate::runtime::ReferenceBackend) model family
//! agree by construction: both derive the per-class Walsh patterns from
//! [`walsh_sign`], so the family's heads recover each sample's class
//! exactly on a clean link, and the loss/imputation paths have a known
//! oracle to degrade from.
//!
//! Samples alternate between a strong ([`EXIT_AMPLITUDE`]) and a weak
//! ([`STAY_AMPLITUDE`]) pattern amplitude; SPINN's exit head crosses its
//! exported 0.9 confidence threshold exactly for the strong half, so the
//! synthetic early-exit rate is a deterministic ~50%.

use crate::config::{
    BackendKind, ImportanceStats, MacCounts, Manifest, Meta, ParamBytes, PyAccuracy, RunConfig,
    SkewQuantiles, SpinnExit, TxElements,
};
use crate::runtime::{walsh_sign, DEEPCOD_CODE_CHANNELS, SPINN_FEATURE_CHANNELS};
use crate::tensor::Tensor;
use crate::workload::TestSet;
use anyhow::{ensure, Result};
use std::collections::HashMap;

/// Dataset name used wherever a synthetic world stands in for a trained
/// artifacts tree.
pub const SYNTHETIC_DATASET: &str = "synthetic";

/// Samples a [`SyntheticSpec::testset`] holds by default (serving indexes
/// requests modulo the set length, so any request count works).
pub const DEFAULT_TEST_SAMPLES: usize = 256;

/// Pattern amplitude of even-indexed samples: strong enough that SPINN's
/// exit confidence clears the exported 0.9 threshold.
pub const EXIT_AMPLITUDE: f32 = 0.36;
/// Pattern amplitude of odd-indexed samples: SPINN stays below threshold
/// and offloads.
pub const STAY_AMPLITUDE: f32 = 0.18;
/// Uniform per-pixel jitter half-width. Block means average ~48 pixels,
/// so the recovered per-cell signal moves by well under the amplitude
/// gap — predictions stay deterministic.
pub const JITTER: f32 = 0.05;

/// Everything the synthetic world is derived from.
#[derive(Debug, Clone)]
pub struct SyntheticSpec {
    pub dataset: String,
    pub num_classes: usize,
    pub image: [usize; 3],
    pub feature: [usize; 3],
    /// top-k important feature channels kept local (AgileNN split)
    pub k: usize,
    /// importance mass carried by the top-k split (meta bookkeeping)
    pub rho: f64,
    /// trained local/remote fusion weight
    pub alpha: f64,
    /// seed of the sample generator (images are a pure function of
    /// `(seed, sample index)`)
    pub seed: u64,
}

impl SyntheticSpec {
    /// The default geometry — mirrors the real 32x32 exports: 10 classes,
    /// 8x8x24 features, top-5 split.
    pub fn new(dataset: impl Into<String>) -> Self {
        Self {
            dataset: dataset.into(),
            num_classes: 10,
            image: [32, 32, 3],
            feature: [8, 8, 24],
            k: 5,
            rho: 0.8,
            alpha: 0.5,
            seed: 0xA61E,
        }
    }

    fn cells(&self) -> usize {
        self.feature[0] * self.feature[1]
    }

    /// Uniform codebook over [0, 1] with `2^bits` levels — the reference
    /// family's feature range, with `index_of(0.0) == 0` so the
    /// imputation reference symbol decodes to a feature's true resting
    /// value.
    fn codebook(bits: u32) -> Vec<f32> {
        let n = 1usize << bits;
        (0..n).map(|i| i as f32 / (n - 1) as f32).collect()
    }

    fn codebooks() -> HashMap<String, Vec<f32>> {
        (1..=6).map(|b| (b.to_string(), Self::codebook(b))).collect()
    }

    /// Fabricate the metadata the python build would have exported.
    /// Accuracy fields carry the family's nominal (clean-link) values;
    /// MAC/param counts are plausible constants that keep every scheme
    /// inside the STM32F746 memory budgets.
    pub fn meta(&self) -> Meta {
        let [h, w, c] = self.image;
        let remote_channels = self.feature[2] - self.k;
        // selected (local) channels carry rho of the importance mass;
        // remote channels share the rest with distinct, scrambled weights
        // so the anytime transport's importance order is a non-trivial
        // permutation
        let per_selected = self.rho / self.k as f64;
        let remote_base = (1.0 - self.rho) / remote_channels as f64;
        let mean_importance: Vec<f64> = (0..self.feature[2])
            .map(|ch| {
                if ch < self.k {
                    per_selected
                } else {
                    let r = ch - self.k;
                    remote_base * (0.5 + (r * 7 % remote_channels) as f64 / remote_channels as f64)
                }
            })
            .collect();
        Meta {
            dataset: self.dataset.clone(),
            num_classes: self.num_classes,
            image: self.image,
            feature: self.feature,
            k: self.k,
            rho: self.rho,
            alpha: self.alpha,
            xai_tool: "reference".into(),
            selected_channels: (0..self.k).collect(),
            codebooks: Self::codebooks(),
            code_entropy_bits: (1..=6u32).map(|b| (b.to_string(), b as f64 * 0.6)).collect(),
            deepcod_codebooks: Self::codebooks(),
            spinn_codebooks: Self::codebooks(),
            macs: MacCounts {
                agile_device: 480_000,
                agile_extractor: 400_000,
                agile_local: 80_000,
                agile_remote: 3_000_000,
                deepcod_device: 620_000,
                spinn_device: 700_000,
                mcunet_local: 1_600_000,
            },
            param_bytes_int8: ParamBytes {
                agile_device: 60_000,
                deepcod_device: 90_000,
                spinn_device: 80_000,
                mcunet_local: 250_000,
            },
            tx_elements: TxElements {
                agile: self.cells() * remote_channels,
                deepcod: self.cells() * DEEPCOD_CODE_CHANNELS,
                spinn: self.cells() * SPINN_FEATURE_CHANNELS,
                edge_raw_bytes: h * w * c,
            },
            accuracy: PyAccuracy {
                agile: 1.0,
                agile_quant4: 1.0,
                agile_local_only: 1.0,
                deepcod: 1.0,
                spinn_final: 1.0,
                mcunet: 1.0,
                edge_only: 1.0,
            },
            spinn_exit: SpinnExit { threshold: 0.9, rate: 0.5, accuracy: 1.0 },
            importance: ImportanceStats {
                natural_skewness_quantiles: SkewQuantiles { p10: 0.62, p50: 0.71, p90: 0.84 },
                achieved_skewness_mean: self.rho,
                disorder_rate: 0.02,
                mean_importance_per_channel: mean_importance,
            },
        }
    }

    /// Fabricate the manifest `make artifacts` would have written.
    pub fn manifest(&self) -> Manifest {
        Manifest { datasets: vec![self.dataset.clone()], quick: false }
    }

    /// Generate `n` deterministic samples. Sample `i` has label
    /// `i % num_classes`; its image paints the label's Walsh pattern at
    /// the alternating strong/weak amplitude, plus seeded per-pixel
    /// jitter. Pure function of `(spec, n)` — bit-identical across runs
    /// and machines.
    pub fn testset(&self, n: usize) -> Result<TestSet> {
        let [h, w, c] = self.image;
        let [fh, fw, _] = self.feature;
        ensure!(n > 0, "need at least one synthetic sample");
        ensure!(
            h % fh == 0 && w % fw == 0,
            "image {h}x{w} not divisible into the {fh}x{fw} feature grid"
        );
        let (bh, bw) = (h / fh, w / fw);
        let mut data = Vec::with_capacity(n * h * w * c);
        let mut labels = Vec::with_capacity(n);
        for i in 0..n {
            let label = i % self.num_classes;
            labels.push(label as i32);
            let amp = if i % 2 == 0 { EXIT_AMPLITUDE } else { STAY_AMPLITUDE };
            let mut rng = self.seed ^ (i as u64 + 1).wrapping_mul(0x9E37_79B9_7F4A_7C15);
            for yy in 0..h {
                for xx in 0..w {
                    let cell = (yy / bh) * fw + xx / bw;
                    let base = 0.5 + amp * walsh_sign(label, cell);
                    for _ch in 0..c {
                        let noise = (unit_f32(splitmix64(&mut rng)) - 0.5) * 2.0 * JITTER;
                        data.push((base + noise).clamp(0.0, 1.0));
                    }
                }
            }
        }
        Ok(TestSet { images: Tensor::new(vec![n, h, w, c], data)?, labels })
    }
}

/// The trained metadata + test set a [`RunConfig`] resolves to: the
/// synthetic world on the reference backend, the artifacts tree on PJRT.
/// The single source of truth for this dispatch — the serve builder, the
/// CLI and the examples all go through it.
pub fn load_world(cfg: &RunConfig) -> Result<(Meta, TestSet)> {
    match cfg.backend {
        BackendKind::Reference => {
            let spec = SyntheticSpec::new(cfg.dataset.as_str());
            Ok((spec.meta(), spec.testset(DEFAULT_TEST_SAMPLES)?))
        }
        BackendKind::Pjrt => Ok((
            Meta::load(&cfg.dataset_dir())?,
            TestSet::load(&cfg.dataset_dir().join("test.bin"))?,
        )),
    }
}

/// splitmix64 step — the standard seeded stream behind the jitter.
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// Top 24 bits -> uniform f32 in [0, 1).
fn unit_f32(bits: u64) -> f32 {
    (bits >> 40) as f32 / (1u64 << 24) as f32
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Scheme;

    #[test]
    fn meta_is_internally_consistent() {
        let spec = SyntheticSpec::new(SYNTHETIC_DATASET);
        let m = spec.meta();
        assert_eq!(m.tx_elements(Scheme::Agile), 8 * 8 * 19);
        assert_eq!(m.tx_elements(Scheme::Deepcod), 8 * 8 * 12);
        assert_eq!(m.tx_elements(Scheme::Spinn), 8 * 8 * 32);
        assert_eq!(m.importance.mean_importance_per_channel.len(), m.feature[2]);
        assert_eq!(m.selected_channels.len(), m.k);
        for bits in 1..=6 {
            let cb = m.codebook(Scheme::Agile, bits).unwrap();
            assert_eq!(cb.len(), 1 << bits);
            assert_eq!(cb[0], 0.0);
            assert_eq!(*cb.last().unwrap(), 1.0);
        }
        // selected channels must rank above every remote channel
        let imp = &m.importance.mean_importance_per_channel;
        let min_selected =
            m.selected_channels.iter().map(|&c| imp[c]).fold(f64::INFINITY, f64::min);
        let max_remote = (m.k..m.feature[2]).map(|c| imp[c]).fold(0.0, f64::max);
        assert!(min_selected > max_remote);
    }

    #[test]
    fn importance_order_is_available_for_the_anytime_transport() {
        let spec = SyntheticSpec::new(SYNTHETIC_DATASET);
        let m = spec.meta();
        let order = crate::net::importance_order(&m, Scheme::Agile).expect("synthetic order");
        assert_eq!(order.len(), m.tx_elements(Scheme::Agile));
        // remote importance weights are scrambled, so the ranked order is
        // not just the identity over channels
        assert!(order.windows(2).any(|w| w[1] < w[0]));
    }

    #[test]
    fn testset_is_deterministic_and_labeled() {
        let spec = SyntheticSpec::new(SYNTHETIC_DATASET);
        let a = spec.testset(16).unwrap();
        let b = spec.testset(16).unwrap();
        assert_eq!(a.images.data(), b.images.data(), "samples must be a pure function of the spec");
        assert_eq!(a.labels, b.labels);
        assert_eq!(a.len(), 16);
        assert_eq!(a.labels[13], 3);
        assert_eq!(a.image(7).unwrap().shape(), &[1, 32, 32, 3]);
        // pixels stay inside the unit range the u8 edge path assumes
        assert!(a.images.data().iter().all(|&v| (0.0..=1.0).contains(&v)));
        // a different seed moves the jitter
        let mut other = spec.clone();
        other.seed ^= 1;
        assert_ne!(a.images.data(), other.testset(16).unwrap().images.data());
    }

    #[test]
    fn manifest_lists_the_synthetic_dataset() {
        let spec = SyntheticSpec::new(SYNTHETIC_DATASET);
        let m = spec.manifest();
        assert_eq!(m.datasets, vec![SYNTHETIC_DATASET.to_string()]);
    }
}
