//! `serve::autoscale` — the virtual-time SLO control plane.
//!
//! The fleet engine (`super::engine`) simulates a fixed server set; this
//! module adds the three pieces a closed scaling loop needs, all of them
//! deterministic functions of the virtual timeline:
//!
//! * [`ServiceModel`] — a per-batch virtual service-time model
//!   (`base + per_sample · batch_size`, divided by a per-server capacity
//!   weight) so scaling curves reflect remote *compute*, not only
//!   queueing. The default model prices every batch at zero seconds,
//!   which leaves the engine's timeline bit-identical to the pre-model
//!   engine — the equivalence contract extends through this module.
//! * [`Controller`] — a deterministic feedback controller that observes
//!   the rolling per-shard queue-wait p95 over a virtual-time window and
//!   decides, on fixed control ticks, whether to grow or shrink the
//!   active server set: scale **out** on sustained SLO pressure, scale
//!   **in** on sustained idle, with a cooldown between actions. The
//!   engine applies the decision (activation, drain-before-retire); the
//!   controller never touches engine state, so its decision sequence is
//!   unit-testable from synthetic observations.
//! * [`ShardLifetime`] — integrated per-shard active-lifetime accounting
//!   (activation → retirement intervals), the corrected basis for the
//!   `server_seconds` fleet-cost objective: an idle-but-provisioned
//!   server is billed, a retired or never-activated one is not.
//!
//! Scale actions surface as [`ScaleEvent`] records and as
//! `obs::EventKind::{ScaleOut, ScaleIn}` trace instants on the server
//! lanes; `PipelineReport` carries the counts plus SLO attainment vs
//! integrated server-seconds. See `docs/serving.md`, "Autoscaling & SLO
//! control".

use std::collections::VecDeque;

/// Virtual cost of one remote batch inference. The engine holds the
/// dispatched batch in service for `batch_service_s` virtual seconds
/// (batches on one shard serialize), so under offered load beyond a
/// shard's capacity the queue wait grows without bound — the signal the
/// [`Controller`] scales on.
#[derive(Debug, Clone, PartialEq, Default)]
pub struct ServiceModel {
    /// fixed per-batch cost, seconds (kernel launch, weights touch)
    pub base_s: f64,
    /// marginal per-sample cost, seconds
    pub per_sample_s: f64,
    /// per-server capacity weights (service time divides by the weight;
    /// weighted placement divides load by it). Empty = every server 1.0.
    pub capacities: Vec<f64>,
}

impl ServiceModel {
    /// True when every batch is free — the pre-model engine timeline.
    pub fn is_zero(&self) -> bool {
        self.base_s == 0.0 && self.per_sample_s == 0.0
    }

    /// Capacity weight of one shard (1.0 where unspecified).
    pub fn capacity(&self, shard: usize) -> f64 {
        self.capacities.get(shard).copied().unwrap_or(1.0)
    }

    /// Virtual service time of a `batch`-sample batch on `shard`.
    pub fn batch_service_s(&self, shard: usize, batch: usize) -> f64 {
        if self.is_zero() {
            return 0.0;
        }
        (self.base_s + self.per_sample_s * batch as f64) / self.capacity(shard)
    }

    /// Reject non-finite or negative parameters with a message.
    pub fn validate(&self) -> Result<(), String> {
        for (name, v) in [("base_s", self.base_s), ("per_sample_s", self.per_sample_s)] {
            if !v.is_finite() || v < 0.0 {
                return Err(format!("service model {name} must be finite and >= 0, got {v}"));
            }
        }
        for (i, c) in self.capacities.iter().enumerate() {
            if !c.is_finite() || *c <= 0.0 {
                return Err(format!("capacity weight for server {i} must be finite and > 0, got {c}"));
            }
        }
        Ok(())
    }
}

/// Controller knobs. Defaults via [`AutoscaleConfig::new`] suit the
/// default 2 ms batch deadline: the low watermark (25% of a 20 ms queue
/// SLO = 5 ms) sits safely above the deadline-bound idle queue wait, so
/// an idle fleet reads as scale-in pressure rather than noise.
#[derive(Debug, Clone, PartialEq)]
pub struct AutoscaleConfig {
    /// never drain below this many accepting servers
    pub min_servers: usize,
    /// shard slots provisioned (and the activation ceiling)
    pub max_servers: usize,
    /// scale-out threshold on the rolling queue-wait p95, seconds
    pub slo_queue_p95_s: f64,
    /// scale-in threshold as a fraction of `slo_queue_p95_s`
    pub low_watermark: f64,
    /// rolling observation window, virtual seconds
    pub window_s: f64,
    /// control tick period, virtual seconds
    pub interval_s: f64,
    /// minimum virtual time between scale actions
    pub cooldown_s: f64,
    /// consecutive over/under ticks required before acting
    pub sustain: u32,
}

impl AutoscaleConfig {
    pub fn new(min_servers: usize, max_servers: usize) -> Self {
        Self {
            min_servers,
            max_servers,
            slo_queue_p95_s: 20e-3,
            low_watermark: 0.25,
            window_s: 2.0,
            interval_s: 0.5,
            cooldown_s: 2.0,
            sustain: 2,
        }
    }

    /// Reject inconsistent bounds/thresholds with a message; `initial`
    /// is the builder's starting server count.
    pub fn validate(&self, initial: usize) -> Result<(), String> {
        if self.min_servers < 1 {
            return Err("autoscale min_servers must be >= 1".into());
        }
        if self.max_servers < self.min_servers {
            return Err(format!(
                "autoscale max_servers {} below min_servers {}",
                self.max_servers, self.min_servers
            ));
        }
        if initial < self.min_servers || initial > self.max_servers {
            return Err(format!(
                "initial server count {initial} outside the autoscale bounds [{}, {}]",
                self.min_servers, self.max_servers
            ));
        }
        for (name, v) in [
            ("slo_queue_p95_s", self.slo_queue_p95_s),
            ("window_s", self.window_s),
            ("interval_s", self.interval_s),
        ] {
            if !v.is_finite() || v <= 0.0 {
                return Err(format!("autoscale {name} must be finite and > 0, got {v}"));
            }
        }
        if !self.cooldown_s.is_finite() || self.cooldown_s < 0.0 {
            return Err(format!("autoscale cooldown_s must be finite and >= 0, got {}", self.cooldown_s));
        }
        if !(0.0..1.0).contains(&self.low_watermark) {
            return Err(format!("autoscale low_watermark must be in [0, 1), got {}", self.low_watermark));
        }
        if self.sustain == 0 {
            return Err("autoscale sustain must be >= 1".into());
        }
        Ok(())
    }
}

/// Which way a [`ScaleEvent`] moved the fleet.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ScaleKind {
    Out,
    In,
}

/// One applied scale action, stamped in virtual time. Scale-outs take
/// effect at the decision tick; scale-ins are stamped when the drained
/// shard actually retires (drain-before-retire), with the pressure the
/// controller saw at the decision.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ScaleEvent {
    /// virtual instant the action took effect
    pub t_s: f64,
    /// the shard activated or retired
    pub shard: usize,
    pub kind: ScaleKind,
    /// accepting server count after the action
    pub active_after: usize,
    /// the controller's pressure (max accepting-shard window p95) at the
    /// decision instant
    pub pressure_s: f64,
}

/// What one control tick asks the engine to do.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ScaleDecision {
    Hold,
    /// activate one more shard (or cancel an in-progress drain)
    Out,
    /// start draining one shard toward retirement
    In,
}

/// Integrated active lifetime of one shard: the sum of its activation →
/// retirement intervals. Open intervals are closed at the run's final
/// virtual time by [`ShardLifetime::total`].
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct ShardLifetime {
    accumulated_s: f64,
    since: Option<f64>,
}

impl ShardLifetime {
    pub fn activate(&mut self, t: f64) {
        if self.since.is_none() {
            self.since = Some(t);
        }
    }

    pub fn retire(&mut self, t: f64) {
        if let Some(s) = self.since.take() {
            self.accumulated_s += t - s;
        }
    }

    pub fn is_active(&self) -> bool {
        self.since.is_some()
    }

    /// Total active seconds with any open interval closed at `t_end`.
    pub fn total(&self, t_end: f64) -> f64 {
        self.accumulated_s + self.since.map(|s| t_end - s).unwrap_or(0.0)
    }
}

/// The deterministic scaling controller. Pure state over virtual-time
/// observations: the engine feeds it per-shard queue waits as batches
/// start service ([`Controller::observe`]) and asks for a decision on
/// each control tick ([`Controller::on_tick`]); it never reads engine
/// state, so identical observation sequences produce bit-identical
/// decision sequences.
#[derive(Debug)]
pub struct Controller {
    pub cfg: AutoscaleConfig,
    /// per-shard rolling (t, queue_wait_s) samples, pruned to `window_s`
    windows: Vec<VecDeque<(f64, f64)>>,
    over_ticks: u32,
    under_ticks: u32,
    last_action_s: f64,
    /// pressure computed by the latest tick (recorded into scale events)
    pub last_pressure_s: f64,
}

impl Controller {
    pub fn new(cfg: AutoscaleConfig) -> Self {
        let shards = cfg.max_servers;
        Self {
            cfg,
            windows: (0..shards).map(|_| VecDeque::new()).collect(),
            over_ticks: 0,
            under_ticks: 0,
            last_action_s: f64::NEG_INFINITY,
            last_pressure_s: 0.0,
        }
    }

    /// Record one queue wait observed on `shard` at virtual time `t`.
    pub fn observe(&mut self, shard: usize, t: f64, wait_s: f64) {
        let w = &mut self.windows[shard];
        w.push_back((t, wait_s));
    }

    fn prune(&mut self, t: f64) {
        let horizon = t - self.cfg.window_s;
        for w in &mut self.windows {
            while w.front().is_some_and(|(ts, _)| *ts < horizon) {
                w.pop_front();
            }
        }
    }

    /// Exact p95 of one shard's rolling window. **A 0-sample window is
    /// 0.0, not NaN** — a shard that scales in before serving anything
    /// must still report defined quantiles (the same convention as
    /// `obs::Histogram` on empty data).
    pub fn window_p95(&self, shard: usize) -> f64 {
        let w = &self.windows[shard];
        if w.is_empty() {
            return 0.0;
        }
        let mut vals: Vec<f64> = w.iter().map(|(_, v)| *v).collect();
        vals.sort_by(|a, b| a.total_cmp(b));
        let idx = ((vals.len() - 1) as f64 * 0.95).round() as usize;
        vals[idx]
    }

    /// Fleet pressure: the worst accepting shard's window p95.
    pub fn pressure(&self, accepting: &[bool]) -> f64 {
        let mut p = 0.0f64;
        for (s, acc) in accepting.iter().enumerate() {
            if *acc {
                p = p.max(self.window_p95(s));
            }
        }
        p
    }

    /// One control tick at virtual time `t`. `accepting[s]` is true for
    /// shards currently taking placements (active and not draining).
    pub fn on_tick(&mut self, t: f64, accepting: &[bool]) -> ScaleDecision {
        self.prune(t);
        let accepting_count = accepting.iter().filter(|a| **a).count();
        let p = self.pressure(accepting);
        self.last_pressure_s = p;
        let cooled = t - self.last_action_s >= self.cfg.cooldown_s;
        if p > self.cfg.slo_queue_p95_s {
            self.over_ticks += 1;
            self.under_ticks = 0;
            if self.over_ticks >= self.cfg.sustain && cooled && accepting_count < self.cfg.max_servers
            {
                self.over_ticks = 0;
                self.last_action_s = t;
                return ScaleDecision::Out;
            }
        } else if p < self.cfg.slo_queue_p95_s * self.cfg.low_watermark {
            self.under_ticks += 1;
            self.over_ticks = 0;
            if self.under_ticks >= self.cfg.sustain
                && cooled
                && accepting_count > self.cfg.min_servers
            {
                self.under_ticks = 0;
                self.last_action_s = t;
                return ScaleDecision::In;
            }
        } else {
            self.over_ticks = 0;
            self.under_ticks = 0;
        }
        ScaleDecision::Hold
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zero_service_model_prices_every_batch_at_zero() {
        let m = ServiceModel::default();
        assert!(m.is_zero());
        assert_eq!(m.batch_service_s(0, 8), 0.0);
        assert_eq!(m.capacity(3), 1.0);
        m.validate().unwrap();
    }

    #[test]
    fn service_time_scales_with_batch_and_divides_by_capacity() {
        let m = ServiceModel {
            base_s: 2e-3,
            per_sample_s: 0.5e-3,
            capacities: vec![1.0, 2.0],
        };
        assert!(!m.is_zero());
        assert!((m.batch_service_s(0, 8) - 6e-3).abs() < 1e-15);
        assert!((m.batch_service_s(1, 8) - 3e-3).abs() < 1e-15, "double capacity halves it");
        // unspecified shards fall back to weight 1.0
        assert!((m.batch_service_s(5, 1) - 2.5e-3).abs() < 1e-15);
        m.validate().unwrap();
        let bad = ServiceModel { base_s: -1.0, ..ServiceModel::default() };
        assert!(bad.validate().unwrap_err().contains("base_s"));
        let bad = ServiceModel { capacities: vec![0.0], ..ServiceModel::default() };
        assert!(bad.validate().unwrap_err().contains("server 0"));
    }

    #[test]
    fn config_validation_rejects_inconsistent_bounds() {
        AutoscaleConfig::new(1, 4).validate(2).unwrap();
        assert!(AutoscaleConfig::new(0, 4).validate(1).is_err());
        assert!(AutoscaleConfig::new(3, 2).validate(3).is_err());
        assert!(AutoscaleConfig::new(2, 4).validate(1).is_err(), "initial below min");
        assert!(AutoscaleConfig::new(1, 4).validate(5).is_err(), "initial above max");
        let mut c = AutoscaleConfig::new(1, 4);
        c.sustain = 0;
        assert!(c.validate(1).is_err());
        let mut c = AutoscaleConfig::new(1, 4);
        c.low_watermark = 1.0;
        assert!(c.validate(1).is_err());
        let mut c = AutoscaleConfig::new(1, 4);
        c.interval_s = 0.0;
        assert!(c.validate(1).is_err());
    }

    #[test]
    fn lifetime_integrates_activation_intervals() {
        let mut l = ShardLifetime::default();
        assert!(!l.is_active());
        assert_eq!(l.total(10.0), 0.0, "never activated -> zero server-seconds");
        l.activate(1.0);
        assert!(l.is_active());
        l.activate(2.0); // re-activation while active is a no-op
        l.retire(4.0);
        assert!(!l.is_active());
        assert!((l.total(100.0) - 3.0).abs() < 1e-12);
        l.retire(5.0); // retire while retired is a no-op
        l.activate(10.0);
        assert!((l.total(12.0) - 5.0).abs() < 1e-12, "open interval closes at t_end");
    }

    #[test]
    fn empty_window_quantile_is_zero_not_nan() {
        // regression (satellite of PR 9): a shard that scales in before
        // serving any request has a 0-sample window; its quantile must be
        // a defined value, never NaN leaking into ordered JSON
        let c = Controller::new(AutoscaleConfig::new(1, 2));
        let p = c.window_p95(1);
        assert_eq!(p, 0.0);
        assert!(!p.is_nan());
        assert_eq!(c.pressure(&[true, true]), 0.0);
    }

    #[test]
    fn window_prunes_and_takes_the_worst_accepting_shard() {
        let mut cfg = AutoscaleConfig::new(1, 3);
        cfg.window_s = 1.0;
        let mut c = Controller::new(cfg);
        c.observe(0, 0.1, 0.5); // will age out of the window by t=2
        c.observe(0, 1.8, 0.001);
        c.observe(1, 1.9, 0.040);
        c.observe(2, 1.9, 0.500); // worst shard, but not accepting
        c.on_tick(2.0, &[true, true, false]);
        assert!((c.last_pressure_s - 0.040).abs() < 1e-12);
        assert_eq!(c.window_p95(0), 0.001, "the 0.5 sample aged out");
    }

    fn tick_n(c: &mut Controller, t0: f64, n: usize, accepting: &[bool], wait: f64) -> Vec<ScaleDecision> {
        (0..n)
            .map(|i| {
                let t = t0 + i as f64 * c.cfg.interval_s;
                for (s, acc) in accepting.iter().enumerate() {
                    if *acc {
                        c.observe(s, t, wait);
                    }
                }
                c.on_tick(t, accepting)
            })
            .collect()
    }

    #[test]
    fn sustained_pressure_scales_out_and_cooldown_spaces_actions() {
        let mut cfg = AutoscaleConfig::new(1, 4);
        cfg.sustain = 2;
        cfg.interval_s = 0.5;
        cfg.cooldown_s = 2.0;
        let mut c = Controller::new(cfg);
        // heavy waits, one accepting shard: first tick arms, second fires
        let d = tick_n(&mut c, 0.0, 2, &[true, false, false, false], 0.100);
        assert_eq!(d, vec![ScaleDecision::Hold, ScaleDecision::Out]);
        // cooldown: the next ticks hold even under sustained pressure
        let d = tick_n(&mut c, 1.0, 2, &[true, true, false, false], 0.100);
        assert_eq!(d, vec![ScaleDecision::Hold, ScaleDecision::Hold]);
        // once cooled (2.5 - 0.5 >= cooldown), it fires again immediately:
        // the over-streak kept accumulating through the cooldown
        let d = tick_n(&mut c, 2.5, 2, &[true, true, false, false], 0.100);
        assert_eq!(d[0], ScaleDecision::Out);
    }

    #[test]
    fn sustained_idle_scales_in_but_never_below_min() {
        let mut cfg = AutoscaleConfig::new(1, 4);
        cfg.sustain = 2;
        cfg.interval_s = 0.5;
        cfg.cooldown_s = 0.0;
        let mut c = Controller::new(cfg);
        // idle waits (deadline-bound 2 ms, below the 5 ms low watermark)
        let d = tick_n(&mut c, 0.0, 4, &[true, true, false, false], 0.002);
        assert!(d.contains(&ScaleDecision::In));
        // at the floor the controller holds forever
        let d = tick_n(&mut c, 10.0, 4, &[true, false, false, false], 0.002);
        assert!(d.iter().all(|d| *d == ScaleDecision::Hold));
    }

    #[test]
    fn mid_band_pressure_resets_the_sustain_counters() {
        let mut cfg = AutoscaleConfig::new(1, 4);
        cfg.sustain = 2;
        cfg.cooldown_s = 0.0;
        cfg.window_s = 0.4; // shorter than the tick interval: each tick
                            // sees only its own observation
        let mut c = Controller::new(cfg.clone());
        // over, then mid-band, then over again: the streak restarts, so
        // the second "over" tick does not fire
        assert_eq!(tick_n(&mut c, 0.0, 1, &[true], 0.100), vec![ScaleDecision::Hold]);
        assert_eq!(tick_n(&mut c, 0.5, 1, &[true], 0.010), vec![ScaleDecision::Hold]);
        assert_eq!(tick_n(&mut c, 1.0, 1, &[true], 0.100), vec![ScaleDecision::Hold]);
        assert_eq!(tick_n(&mut c, 1.5, 1, &[true], 0.100), vec![ScaleDecision::Out]);
    }

    #[test]
    fn identical_observation_sequences_give_identical_decisions() {
        let cfg = AutoscaleConfig::new(1, 3);
        let run = || {
            let mut c = Controller::new(cfg.clone());
            let mut out = Vec::new();
            for i in 0..40 {
                let t = i as f64 * 0.5;
                let wait = if i % 10 < 5 { 0.080 } else { 0.001 };
                c.observe(i % 3, t, wait);
                out.push(c.on_tick(t, &[true, true, i % 2 == 0]));
            }
            out
        };
        assert_eq!(run(), run());
    }
}
