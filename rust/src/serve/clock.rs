//! Serving clocks: real time vs a shared, conservative virtual clock.
//!
//! The pipeline's timeline used to be wall time only: device threads paced
//! arrivals with `thread::sleep`, the batcher keyed deadlines on raw
//! `Instant`s, and `LatencyBreakdown.remote_s` mixed wall-clock queueing
//! into an otherwise simulated latency budget. That makes sustained-load
//! runs (30 Hz × 100k+ requests) take hours of real time and leaves every
//! latency quantile nondeterministic.
//!
//! [`Clock`] abstracts the timeline:
//!
//! * [`ClockKind::Wall`] — the pre-existing behavior: `now()` is seconds
//!   since the pipeline started, `sleep_until` really sleeps, and the
//!   batcher's deadline waits ride on `recv_timeout`.
//! * [`ClockKind::Sim`] — a discrete-event virtual clock shared by every
//!   pipeline thread. Threads *register* as participants; when they block
//!   (arrival pacing, batch-deadline waits, waiting for a remote reply)
//!   they tell the clock what they are waiting for, and once **all**
//!   participants are blocked with no message in flight, virtual time
//!   jumps to the earliest pending wake-up. Nothing ever sleeps, so a
//!   conservative (no-lookahead) schedule of 100k+ requests plays out in
//!   the time the real compute takes — and every timestamp, batch
//!   composition trigger, and queueing delay is a pure function of the
//!   run's seeds.
//!
//! The coordination protocol for channel messages (offloads and replies)
//! avoids lost wake-ups with an epoch counter: a receiver snapshots
//! [`Clock::epoch`] *before* polling its channel, and [`Clock::wait`]
//! returns immediately if the epoch moved in between. Senders bump the
//! in-flight count *before* pushing into the channel ([`Clock::msg_sent`])
//! so virtual time can never advance past an unprocessed message, and
//! notify after ([`Clock::notify`]).

use std::str::FromStr;
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

/// Which timeline drives the serving pipeline.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum ClockKind {
    /// Real time: arrival pacing sleeps, latency quantiles measure the
    /// host pipeline (the pre-virtual-clock behavior, and the default).
    #[default]
    Wall,
    /// Discrete-event virtual time: no sleeps, seed-deterministic
    /// latencies, load sweeps run at CPU speed.
    Sim,
}

impl ClockKind {
    pub fn name(&self) -> &'static str {
        match self {
            ClockKind::Wall => "wall",
            ClockKind::Sim => "sim",
        }
    }
}

impl FromStr for ClockKind {
    type Err = anyhow::Error;

    fn from_str(s: &str) -> anyhow::Result<Self> {
        match s.to_ascii_lowercase().as_str() {
            "wall" | "real" => Ok(ClockKind::Wall),
            "sim" | "virtual" => Ok(ClockKind::Sim),
            other => anyhow::bail!("unknown clock {other:?} (wall|sim)"),
        }
    }
}

/// A handle on the pipeline's timeline; cheap to clone into every thread.
#[derive(Debug, Clone)]
pub struct Clock {
    inner: Inner,
}

#[derive(Debug, Clone)]
enum Inner {
    Wall { t0: Instant },
    Sim(Arc<SimClock>),
}

impl Clock {
    /// Wall clock anchored at creation: `now()` is seconds since then.
    pub fn wall() -> Self {
        Clock { inner: Inner::Wall { t0: Instant::now() } }
    }

    /// Virtual clock starting at 0.0 with `participants` registered
    /// threads. Every participant must eventually take a
    /// [`Clock::participant`] guard; virtual time only advances while all
    /// of them are blocked in a clock wait.
    pub fn sim(participants: usize) -> Self {
        Clock {
            inner: Inner::Sim(Arc::new(SimClock {
                state: Mutex::new(SimState {
                    now: 0.0,
                    participants,
                    blocked: 0,
                    inflight: 0,
                    epoch: 0,
                    wake: Vec::new(),
                }),
                cv: Condvar::new(),
            })),
        }
    }

    pub fn kind(&self) -> ClockKind {
        match self.inner {
            Inner::Wall { .. } => ClockKind::Wall,
            Inner::Sim(_) => ClockKind::Sim,
        }
    }

    pub fn is_sim(&self) -> bool {
        matches!(self.inner, Inner::Sim(_))
    }

    /// Seconds since the pipeline started (virtual seconds in sim mode).
    pub fn now(&self) -> f64 {
        match &self.inner {
            Inner::Wall { t0 } => t0.elapsed().as_secs_f64(),
            Inner::Sim(sim) => sim.state.lock().unwrap().now,
        }
    }

    /// Block until the clock reaches `t` (no-op if already past). Wall:
    /// a real sleep. Sim: a virtual wait that lets time advance.
    pub fn sleep_until(&self, t: f64) {
        match &self.inner {
            Inner::Wall { t0 } => {
                let now = t0.elapsed().as_secs_f64();
                if t > now && (t - now).is_finite() {
                    std::thread::sleep(Duration::from_secs_f64(t - now));
                }
            }
            Inner::Sim(sim) => sim.sleep_until(t),
        }
    }

    /// RAII registration guard for one pipeline thread; dropping it
    /// (normal exit or error unwind) deregisters, so a sim run can never
    /// end up waiting on a thread that is gone.
    pub fn participant(&self) -> ClockParticipant {
        ClockParticipant {
            sim: match &self.inner {
                Inner::Wall { .. } => None,
                Inner::Sim(sim) => Some(sim.clone()),
            },
        }
    }

    /// Event-counter snapshot; take it *before* polling a channel and pass
    /// it to [`Clock::wait`] so a send landing in between is never missed.
    pub fn epoch(&self) -> u64 {
        match &self.inner {
            Inner::Wall { .. } => 0,
            Inner::Sim(sim) => sim.state.lock().unwrap().epoch,
        }
    }

    /// Sim: block until virtual time reaches `deadline` (`None` = only an
    /// event can wake us) or the epoch moves past `epoch0`; returns true
    /// iff the deadline was reached.
    ///
    /// # Panics
    /// On a wall clock: the wall pipeline waits on its channels
    /// (`recv_timeout` / `recv`) and must never call this — failing fast
    /// in every build profile beats silently sleeping to a virtual
    /// timestamp.
    pub fn wait(&self, deadline: Option<f64>, epoch0: u64) -> bool {
        match &self.inner {
            Inner::Wall { .. } => {
                panic!("Clock::wait is a sim-clock primitive; wall pipelines wait on channels")
            }
            Inner::Sim(sim) => sim.wait(deadline, epoch0),
        }
    }

    /// A message is about to enter a channel: virtual time must not
    /// advance until the receiver has taken it ([`Clock::msg_received`]).
    /// No-op on the wall clock.
    pub fn msg_sent(&self) {
        if let Inner::Sim(sim) = &self.inner {
            sim.state.lock().unwrap().inflight += 1;
        }
    }

    /// The send failed (receiver gone): undo [`Clock::msg_sent`].
    pub fn msg_cancelled(&self) {
        if let Inner::Sim(sim) = &self.inner {
            let mut st = sim.state.lock().unwrap();
            st.inflight = st.inflight.saturating_sub(1);
        }
    }

    /// A message was taken off a channel.
    pub fn msg_received(&self) {
        if let Inner::Sim(sim) = &self.inner {
            let mut st = sim.state.lock().unwrap();
            st.inflight = st.inflight.saturating_sub(1);
        }
    }

    /// Wake every clock waiter to re-check its channels (call after a
    /// channel send). No-op on the wall clock.
    pub fn notify(&self) {
        if let Inner::Sim(sim) = &self.inner {
            let mut st = sim.state.lock().unwrap();
            st.epoch = st.epoch.wrapping_add(1);
            sim.cv.notify_all();
        }
    }
}

/// See [`Clock::participant`].
#[derive(Debug)]
pub struct ClockParticipant {
    sim: Option<Arc<SimClock>>,
}

impl Drop for ClockParticipant {
    fn drop(&mut self) {
        if let Some(sim) = &self.sim {
            let mut st = sim.state.lock().unwrap();
            st.participants = st.participants.saturating_sub(1);
            st.epoch = st.epoch.wrapping_add(1);
            sim.advance_if_quiescent(&mut st);
            sim.cv.notify_all();
        }
    }
}

#[derive(Debug)]
struct SimState {
    now: f64,
    /// registered pipeline threads (devices + server)
    participants: usize,
    /// how many of them are currently blocked in a clock wait
    blocked: usize,
    /// messages pushed into a channel but not yet taken by their receiver
    inflight: usize,
    /// bumped on every advance and every notify; lets waiters detect
    /// events without holding channel and clock locks together
    epoch: u64,
    /// wake deadlines of the blocked threads (INFINITY = event-only)
    wake: Vec<f64>,
}

/// The shared conservative virtual clock behind [`ClockKind::Sim`].
#[derive(Debug)]
struct SimClock {
    state: Mutex<SimState>,
    cv: Condvar,
}

impl SimClock {
    fn sleep_until(&self, t: f64) {
        let mut st = self.state.lock().unwrap();
        // non-finite targets are a no-op (matching the wall clock's
        // is_finite guard): an INFINITY wake would otherwise pin the
        // advance forever and silently deadlock the whole pipeline
        if !t.is_finite() || t <= st.now {
            return;
        }
        st.blocked += 1;
        st.wake.push(t);
        self.advance_if_quiescent(&mut st);
        while st.now < t {
            st = self.cv.wait(st).unwrap();
        }
        Self::remove_wake(&mut st, t);
        st.blocked -= 1;
    }

    fn wait(&self, deadline: Option<f64>, epoch0: u64) -> bool {
        let wake_at = deadline.unwrap_or(f64::INFINITY);
        let mut st = self.state.lock().unwrap();
        if st.now >= wake_at {
            return true;
        }
        if st.epoch != epoch0 {
            return false;
        }
        st.blocked += 1;
        st.wake.push(wake_at);
        self.advance_if_quiescent(&mut st);
        let fired = loop {
            if st.now >= wake_at {
                break true;
            }
            if st.epoch != epoch0 {
                break false;
            }
            st = self.cv.wait(st).unwrap();
        };
        Self::remove_wake(&mut st, wake_at);
        st.blocked -= 1;
        fired
    }

    /// The conservative advance: when every participant is blocked and no
    /// message is in flight, jump to the earliest pending wake-up. If all
    /// waits are event-only (INFINITY), stay put — only an external event
    /// (send, thread exit) can unblock the pipeline then.
    fn advance_if_quiescent(&self, st: &mut SimState) {
        if st.participants == 0 || st.blocked < st.participants || st.inflight > 0 {
            return;
        }
        let min = st.wake.iter().copied().fold(f64::INFINITY, f64::min);
        if min.is_finite() && min > st.now {
            st.now = min;
            st.epoch = st.epoch.wrapping_add(1);
            self.cv.notify_all();
        }
    }

    fn remove_wake(st: &mut SimState, t: f64) {
        if let Some(i) = st.wake.iter().position(|&w| w == t) {
            st.wake.swap_remove(i);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::mpsc::{channel, TryRecvError};

    #[test]
    fn kind_parses_and_names() {
        assert_eq!("wall".parse::<ClockKind>().unwrap(), ClockKind::Wall);
        assert_eq!("SIM".parse::<ClockKind>().unwrap(), ClockKind::Sim);
        assert!("lamport".parse::<ClockKind>().is_err());
        assert_eq!(ClockKind::Sim.name(), "sim");
        assert_eq!(ClockKind::default(), ClockKind::Wall);
    }

    #[test]
    fn wall_clock_advances_and_sleeps() {
        let c = Clock::wall();
        assert!(!c.is_sim());
        let a = c.now();
        c.sleep_until(a + 0.005);
        assert!(c.now() >= a + 0.005);
        // already-past deadlines return immediately
        c.sleep_until(0.0);
    }

    #[test]
    fn sim_sleep_advances_virtual_time_without_real_sleeping() {
        let c = Clock::sim(1);
        let _p = c.participant();
        let wall = Instant::now();
        c.sleep_until(3600.0); // one virtual hour
        assert_eq!(c.now(), 3600.0);
        assert!(wall.elapsed() < Duration::from_secs(5), "must not really sleep");
    }

    #[test]
    fn sim_interleaves_two_sleepers_in_timestamp_order() {
        let c = Clock::sim(2);
        let log = Arc::new(Mutex::new(Vec::new()));
        let spawn = |name: &'static str, ts: Vec<f64>| {
            let c = c.clone();
            let log = log.clone();
            std::thread::spawn(move || {
                let _p = c.participant();
                for t in ts {
                    c.sleep_until(t);
                    log.lock().unwrap().push((name, c.now()));
                }
            })
        };
        let a = spawn("a", vec![1.0, 3.0]);
        let b = spawn("b", vec![2.0, 4.0]);
        a.join().unwrap();
        b.join().unwrap();
        assert_eq!(
            *log.lock().unwrap(),
            vec![("a", 1.0), ("b", 2.0), ("a", 3.0), ("b", 4.0)]
        );
    }

    #[test]
    fn sim_message_wakes_event_only_waiter() {
        let c = Clock::sim(2);
        let (tx, rx) = channel::<u32>();
        let consumer = {
            let c = c.clone();
            std::thread::spawn(move || {
                let _p = c.participant();
                loop {
                    let epoch = c.epoch();
                    match rx.try_recv() {
                        Ok(v) => {
                            c.msg_received();
                            return (v, c.now());
                        }
                        Err(TryRecvError::Empty) => {
                            c.wait(None, epoch);
                        }
                        Err(TryRecvError::Disconnected) => panic!("producer gone"),
                    }
                }
            })
        };
        let producer = {
            let c = c.clone();
            std::thread::spawn(move || {
                let _p = c.participant();
                c.sleep_until(5.0);
                c.msg_sent();
                tx.send(7).unwrap();
                c.notify();
            })
        };
        producer.join().unwrap();
        let (v, at) = consumer.join().unwrap();
        assert_eq!(v, 7);
        // the consumer received at the producer's virtual send time: time
        // advanced to 5.0 despite the consumer waiting without a deadline
        assert_eq!(at, 5.0);
    }

    #[test]
    fn sim_deadline_wait_fires_at_the_deadline() {
        let c = Clock::sim(1);
        let _p = c.participant();
        let epoch = c.epoch();
        assert!(c.wait(Some(0.25), epoch), "deadline must fire");
        assert_eq!(c.now(), 0.25);
        // an already-expired deadline returns true immediately
        assert!(c.wait(Some(0.1), c.epoch()));
        assert_eq!(c.now(), 0.25);
    }

    #[test]
    fn sim_deregistration_wakes_waiters_for_shutdown() {
        let c = Clock::sim(2);
        let (tx, rx) = channel::<u32>();
        let consumer = {
            let c = c.clone();
            std::thread::spawn(move || {
                let _p = c.participant();
                loop {
                    let epoch = c.epoch();
                    match rx.try_recv() {
                        Ok(_) => {
                            c.msg_received();
                        }
                        Err(TryRecvError::Empty) => {
                            c.wait(None, epoch);
                        }
                        Err(TryRecvError::Disconnected) => return true,
                    }
                }
            })
        };
        {
            let c = c.clone();
            std::thread::spawn(move || {
                let _p = c.participant();
                c.sleep_until(1.0);
                drop(tx); // exit without ever sending
            })
            .join()
            .unwrap();
        }
        assert!(consumer.join().unwrap(), "consumer must see the disconnect");
    }

    #[test]
    fn sim_inflight_message_blocks_the_advance() {
        // one registered thread sends itself a message, then takes a
        // deadline wait: the deadline must NOT fire while the message is
        // in flight (epoch path returns first after msg_received+notify).
        let c = Clock::sim(1);
        let _p = c.participant();
        let (tx, rx) = channel::<u32>();
        c.msg_sent();
        tx.send(1).unwrap();
        let epoch = c.epoch();
        c.notify();
        // the notify bumped the epoch, so the wait must return `false`
        // (event) rather than advancing to the deadline
        assert!(!c.wait(Some(9.0), epoch));
        assert_eq!(c.now(), 0.0);
        assert_eq!(rx.try_recv().unwrap(), 1);
        c.msg_received();
        // with the message drained, the deadline path works again
        assert!(c.wait(Some(9.0), c.epoch()));
        assert_eq!(c.now(), 9.0);
    }
}
