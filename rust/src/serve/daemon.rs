//! The real-socket serving daemon: `agilenn serve --listen <addr>`.
//!
//! Hosts the server half of a scheme behind a TCP listener speaking the
//! versioned wire envelope ([`crate::net::wire`]). Each accepted
//! connection is one device client ([`super::fabric::TcpTransport`]):
//! after a `Hello`/`HelloAck` handshake pinning dataset, scheme, bit-width
//! and protocol version, the connection carries offload requests in
//! lockstep — uplink body out, logits back — all feeding the *same*
//! deadline-batched [`server_loop`] the in-process pipeline runs.
//!
//! Division of labor (and why loopback runs verify bitwise): the simulated
//! lossy channel, packetization, retransmission accounting and outcome
//! assembly all stay on the device client — the daemon only ever sees what
//! *survived* the simulated link, exactly like the in-process server loop.
//! TCP is carriage, not the channel model; the channel model prices the
//! wire. So a device client run against a loopback daemon reproduces every
//! seed-deterministic report field of an in-process run bit for bit (the
//! contract `docs/daemon.md` spells out and CI enforces).
//!
//! The daemon runs on the wall clock only — virtual time cannot
//! coordinate across processes — and stops when a client sends
//! [`WireMsg::Shutdown`] (`agilenn device --shutdown`, or
//! [`send_shutdown`]).
//!
//! [`server_loop`]: super::service

use crate::config::{Meta, RunConfig};
use crate::net::wire::{Hello, WireError, WireMsg};
use crate::obs::Tracer;
use crate::runtime::make_backend;
use crate::serve::clock::Clock;
use crate::serve::fabric::{OffloadMsg, UplinkBody};
use crate::serve::scheme::{make_server_side, ServerSide};
use crate::serve::service::{server_loop, ServeBuilder, ShardReport};
use anyhow::{anyhow, bail, Context, Result};
use std::io::{BufReader, BufWriter, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Sender};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// What one daemon lifetime did, reported after shutdown: how many
/// connections were accepted, plus the server loop's own batch/queue
/// accounting (the same [`ShardReport`] an in-process run puts in
/// `PipelineReport::shards`).
#[derive(Debug, Clone)]
pub struct DaemonSummary {
    /// accepted connections (device clients, plus the shutdown control
    /// connection that ended the run)
    pub connections: usize,
    pub shard: ShardReport,
}

/// A bound, not-yet-running serving daemon. [`Daemon::bind`] resolves the
/// world and loads the scheme's server half eagerly so configuration
/// errors surface before the first client connects; [`Daemon::run`] then
/// serves until a [`WireMsg::Shutdown`] arrives.
pub struct Daemon {
    listener: TcpListener,
    cfg: RunConfig,
    meta: Meta,
    tracer: Tracer,
    server: Box<dyn ServerSide>,
    max_batch: usize,
    io_timeout: Option<Duration>,
}

impl Daemon {
    /// Bind `addr` and assemble the server half described by `builder`
    /// (scheme, backend, batching knobs). Schemes without a server half
    /// (local-only) have nothing to host and are rejected here.
    pub fn bind(addr: &str, builder: ServeBuilder) -> Result<Self> {
        let (cfg, tracer) = builder.daemon_parts();
        let (meta, _testset) = crate::fixtures::load_world(&cfg)?;
        let backend = make_backend(&cfg, &meta)?;
        let server = make_server_side(backend.as_ref(), &cfg, &meta)?.ok_or_else(|| {
            anyhow!("{} runs entirely on-device; there is no server half to host", cfg.scheme.name())
        })?;
        let max_batch = cfg.batch.max_batch.min(server.max_batch());
        let listener = TcpListener::bind(addr)
            .with_context(|| format!("binding serving daemon listener on {addr}"))?;
        Ok(Self { listener, cfg, meta, tracer, server, max_batch, io_timeout: None })
    }

    /// Per-connection socket read/write timeout (default: none — blocking
    /// reads, the pre-timeout behavior). With a timeout set, a half-open
    /// or stalled client trips [`WireError::TimedOut`] and its handler
    /// disconnects instead of pinning a thread forever and blocking
    /// `Shutdown` drain. The CLI daemon sets 30 s.
    pub fn io_timeout(mut self, timeout: Duration) -> Self {
        self.io_timeout = Some(timeout);
        self
    }

    /// The bound address (resolves `--listen 127.0.0.1:0` to the actual
    /// port, for tests and logs).
    pub fn local_addr(&self) -> Result<SocketAddr> {
        Ok(self.listener.local_addr()?)
    }

    /// Serve until shutdown. Spawns the shared deadline-batched
    /// [`server_loop`] once, then one lightweight handler thread per
    /// accepted connection; handlers funnel decoded offload requests into
    /// the server loop over the same `mpsc` fabric the in-process pipeline
    /// uses, so batching dynamics are identical.
    ///
    /// [`server_loop`]: super::service
    pub fn run(self) -> Result<DaemonSummary> {
        let t0 = Instant::now();
        let io_timeout = self.io_timeout;
        let deadline_s = self.cfg.batch.deadline_s();
        let clock = Clock::wall();
        let depth = Arc::new(AtomicUsize::new(0));
        let (tx, rx) = channel::<OffloadMsg>();
        let server_handle = {
            let clock = clock.clone();
            let tracer = self.tracer.clone();
            let depth = depth.clone();
            let server = self.server;
            let max_batch = self.max_batch;
            std::thread::spawn(move || {
                server_loop(server, rx, max_batch, deadline_s, clock, tracer, depth)
            })
        };

        let stop = Arc::new(AtomicBool::new(false));
        let local = self.listener.local_addr()?;
        let world = Arc::new(WorldKey {
            dataset: self.cfg.dataset.clone(),
            scheme: self.cfg.scheme.name().to_string(),
            bits: self.cfg.bits,
            num_classes: self.meta.num_classes as u32,
        });
        let mut handlers = Vec::new();
        let mut connections = 0usize;
        for stream in self.listener.incoming() {
            if stop.load(Ordering::SeqCst) {
                break;
            }
            let stream = match stream {
                Ok(s) => s,
                Err(e) => {
                    eprintln!("accept failed: {e}");
                    continue;
                }
            };
            connections += 1;
            let tx = tx.clone();
            let stop = stop.clone();
            let world = world.clone();
            handlers.push(std::thread::spawn(move || {
                if let Err(e) = handle_connection(stream, io_timeout, &world, &tx, &stop, local) {
                    eprintln!("connection handler: {e:#}");
                }
            }));
        }
        // master sender gone; the server loop drains once every handler's
        // clone has dropped too
        drop(tx);
        for h in handlers {
            let _ = h.join();
        }
        let agg = server_handle.join().map_err(|_| anyhow!("server loop panicked"))?;
        Ok(DaemonSummary { connections, shard: agg.into_report(0, t0.elapsed().as_secs_f64()) })
    }
}

/// The identity a client must match to be served: handshake validation is
/// exact, so a client built against a different world fails fast with a
/// reason instead of producing silently-wrong logits.
struct WorldKey {
    dataset: String,
    scheme: String,
    bits: u32,
    num_classes: u32,
}

impl WorldKey {
    fn check(&self, hello: &Hello) -> std::result::Result<(), String> {
        if hello.dataset != self.dataset || hello.scheme != self.scheme || hello.bits != self.bits {
            return Err(format!(
                "daemon serves {}/{} at {} bits; client asked for {}/{} at {} bits",
                self.dataset, self.scheme, self.bits, hello.dataset, hello.scheme, hello.bits
            ));
        }
        Ok(())
    }
}

/// One connection: handshake, then offload requests in lockstep until the
/// client disconnects or sends `Shutdown`. Protocol violations get a
/// best-effort `Reject` before the connection closes.
fn handle_connection(
    stream: TcpStream,
    io_timeout: Option<Duration>,
    world: &WorldKey,
    tx: &Sender<OffloadMsg>,
    stop: &AtomicBool,
    local: SocketAddr,
) -> Result<()> {
    stream.set_nodelay(true).ok();
    // None (the default) keeps blocking reads; with a timeout a stalled
    // peer surfaces as WireError::TimedOut below instead of pinning this
    // handler thread forever
    stream.set_read_timeout(io_timeout)?;
    stream.set_write_timeout(io_timeout)?;
    let mut writer = BufWriter::new(stream.try_clone()?);
    let mut reader = BufReader::new(stream);

    // handshake — or an immediate Shutdown from the control client
    match read_or_reject(&mut reader, &mut writer)? {
        Some(WireMsg::Hello(hello)) => match world.check(&hello) {
            Ok(()) => {
                WireMsg::HelloAck { num_classes: world.num_classes }.write_to(&mut writer)?;
                writer.flush()?;
            }
            Err(reason) => {
                WireMsg::Reject { reason: reason.clone() }.write_to(&mut writer)?;
                writer.flush()?;
                bail!("rejected handshake: {reason}");
            }
        },
        Some(WireMsg::Shutdown) => {
            request_stop(stop, local);
            return Ok(());
        }
        Some(other) => {
            let reason = format!("expected Hello, got {other:?}");
            let _ = WireMsg::Reject { reason: reason.clone() }.write_to(&mut writer);
            let _ = writer.flush();
            bail!("{reason}");
        }
        None => return Ok(()), // probe connection: opened and closed
    }

    while let Some(msg) = read_or_reject(&mut reader, &mut writer)? {
        let (id, body) = match msg {
            WireMsg::OffloadFrame { id, frame } => (id, UplinkBody::Whole(frame)),
            WireMsg::OffloadPackets { id, count, bits, packets } => {
                (id, UplinkBody::Packets { packets, count: count as usize, bits })
            }
            WireMsg::Shutdown => {
                request_stop(stop, local);
                return Ok(());
            }
            other => {
                let reason = format!("expected an offload request, got {other:?}");
                let _ = WireMsg::Reject { reason: reason.clone() }.write_to(&mut writer);
                let _ = writer.flush();
                bail!("{reason}");
            }
        };
        let (rtx, rrx) = channel();
        tx.send(OffloadMsg { id, body, reply: rtx })
            .map_err(|_| anyhow!("server loop gone while serving request {id}"))?;
        // forward the depth the server loop stamped when it *sent* this
        // reply — re-reading the shared counter here could advertise the
        // queue state of a different moment (wire v2's stale-depth fix)
        let reply = rrx
            .recv()
            .map_err(|_| anyhow!("server loop dropped the reply for request {id}"))?;
        WireMsg::Reply {
            id,
            queue_depth: reply.queue_depth,
            result: reply.result.map_err(|e| e.0),
        }
        .write_to(&mut writer)?;
        writer.flush()?;
    }
    Ok(())
}

/// Read the next message; on a malformed/foreign byte stream, send a
/// best-effort `Reject` naming the parse error before surfacing it. A
/// socket timeout (stalled or half-open peer) becomes a typed
/// [`WireError::TimedOut`] with *no* Reject attempt — writing to a peer
/// that stopped reading could stall this handler right back.
fn read_or_reject(
    reader: &mut BufReader<TcpStream>,
    writer: &mut BufWriter<TcpStream>,
) -> Result<Option<WireMsg>> {
    match WireMsg::read_from(reader) {
        Ok(m) => Ok(m),
        Err(e) => {
            if let Some(io) = e.downcast_ref::<std::io::Error>() {
                if matches!(
                    io.kind(),
                    std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut
                ) {
                    return Err(
                        WireError::TimedOut { context: "waiting for the next message" }.into()
                    );
                }
            }
            let _ = WireMsg::Reject { reason: format!("{e:#}") }.write_to(writer);
            let _ = writer.flush();
            Err(e)
        }
    }
}

/// Flag the accept loop to stop and wake it with a throwaway connection
/// (accept has no timeout; the self-connection is the wakeup).
fn request_stop(stop: &AtomicBool, local: SocketAddr) {
    stop.store(true, Ordering::SeqCst);
    let _ = TcpStream::connect(local);
}

/// Ask the daemon at `addr` to shut down after finishing in-flight work
/// (what `agilenn device --connect <addr> --shutdown` calls).
pub fn send_shutdown(addr: &str) -> Result<()> {
    let stream = TcpStream::connect(addr)
        .with_context(|| format!("connecting to serving daemon at {addr}"))?;
    let mut writer = BufWriter::new(stream);
    WireMsg::Shutdown.write_to(&mut writer)?;
    writer.flush()?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{BackendKind, Scheme};
    use crate::serve::fabric::TcpTransport;

    fn daemon(dataset: &str) -> Daemon {
        Daemon::bind(
            "127.0.0.1:0",
            ServeBuilder::new(dataset).backend(BackendKind::Reference).scheme(Scheme::Agile),
        )
        .unwrap()
    }

    #[test]
    fn daemon_rejects_a_local_only_scheme() {
        let err = Daemon::bind(
            "127.0.0.1:0",
            ServeBuilder::new("svhns").backend(BackendKind::Reference).scheme(Scheme::Mcunet),
        )
        .unwrap_err();
        assert!(err.to_string().contains("no server half"), "{err:#}");
    }

    #[test]
    fn daemon_acks_a_matching_hello_and_rejects_a_mismatched_one() {
        let d = daemon("svhns");
        let addr = d.local_addr().unwrap().to_string();
        let run = std::thread::spawn(move || d.run().unwrap());

        // matching world: handshake succeeds and reports the class count
        let good = Hello { dataset: "svhns".into(), scheme: "agile".into(), bits: 4 };
        let t = TcpTransport::connect(&addr, &good).unwrap();
        assert_eq!(t.num_classes(), 10);
        drop(t);

        // mismatched bit-width: typed rejection naming both sides
        let bad = Hello { dataset: "svhns".into(), scheme: "agile".into(), bits: 2 };
        let err = TcpTransport::connect(&addr, &bad).unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("4 bits") && msg.contains("2 bits"), "{msg}");

        send_shutdown(&addr).unwrap();
        let summary = run.join().unwrap();
        // the good client, the bad client, and the shutdown connection
        assert_eq!(summary.connections, 3);
        assert_eq!(summary.shard.requests, 0);
    }

    #[test]
    fn stalled_client_times_out_instead_of_blocking_shutdown() {
        // regression (PR 9 satellite): without socket timeouts a half-open
        // client pinned its handler thread in a blocking read forever, and
        // Shutdown drain (which joins every handler) hung with it
        let d = daemon("svhns").io_timeout(Duration::from_millis(100));
        let addr = d.local_addr().unwrap().to_string();
        let run = std::thread::spawn(move || d.run().unwrap());
        // connect and send nothing, keeping the socket open: the handler
        // must trip its read timeout and disconnect on its own
        let stalled = TcpStream::connect(&addr).unwrap();
        send_shutdown(&addr).unwrap();
        // joins the stalled handler too — hangs forever if the timeout
        // path regresses
        let summary = run.join().unwrap();
        assert_eq!(summary.connections, 2);
        drop(stalled);
    }
}
