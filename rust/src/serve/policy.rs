//! `serve::policy` — deterministic per-request adaptive offloading.
//!
//! The trained operating point (quantizer width, delivery policy) is
//! static at runtime: every uplink ships the same number of bits under the
//! same delivery policy no matter what the channel or the server queue is
//! doing. DynO-style adaptation moves that decision to the device half,
//! per request: an EWMA of recent per-device [`NetStats`] (delivered
//! feature rate, goodput, retransmit rounds) plus the server's advertised
//! queue depth drives a ladder of operating points
//!
//! ```text
//!   widths[n-1] ARQ  ←→  …  ←→  widths[0] ARQ  ←→  widths[0] anytime  ←→  local-only
//!   (best accuracy)                                (bounded latency)      (no uplink)
//! ```
//!
//! with hysteresis so decisions don't flap: a *sustain* streak of
//! consecutive bad (good) observations is required before stepping down
//! (up), a *cooldown* freezes the ladder for a number of observations
//! after every step, and the good/bad signal bands are disjoint
//! (`rate_low < rate_high`, `depth_low < depth_high`), so a constant
//! channel converges to one rung and stays there.
//!
//! **Determinism contract.** [`DevicePolicy`] is pure state-machine
//! arithmetic: no clocks, no randomness, no floats read from the
//! environment. The decision sequence is a function of the observation
//! sequence alone, so two runs that feed it the same (seeded) channel
//! outcomes make bit-identical decisions — and policy-off runs never
//! construct one, leaving the static pipeline untouched.
//!
//! While local-only, no uplinks happen, so no observations arrive and the
//! EWMA freezes; recovery is via deterministic *probes*: every
//! `probe_every`-th decision is an uplink at the most conservative rung,
//! whose observation can start a good streak and climb back out.

use crate::net::{DeliveryPolicy, NetStats};

/// Knobs of the per-request adaptation policy (`RunConfig::policy`;
/// `None` = static operating point, the pre-policy pipeline bit for bit).
#[derive(Debug, Clone, PartialEq)]
pub struct PolicyConfig {
    /// candidate quantizer widths, strictly ascending; each must name a
    /// codebook actually exported in the manifest (validated at build
    /// time). The policy starts at the widest (most accurate) candidate.
    pub widths: Vec<u32>,
    /// EWMA smoothing factor in (0, 1]: weight of the newest observation
    pub ewma_alpha: f64,
    /// delivered-feature-rate floor: an EWMA below this reads as a bad
    /// channel (only the anytime path delivers partial frames; under ARQ
    /// the rate is 1 and pressure shows up as retransmit rounds instead)
    pub rate_low: f64,
    /// delivered-feature-rate ceiling required to read as a good channel
    /// (must exceed `rate_low`: the gap is the hysteresis band)
    pub rate_high: f64,
    /// EWMA retransmit rounds per uplink above which the channel reads
    /// as bad; "good" requires at most half of this
    pub rounds_high: f64,
    /// goodput floor, bits/s (0 disables the signal): an EWMA below this
    /// reads as bad, and "good" requires at least twice it
    pub goodput_low_bps: f64,
    /// advertised server queue depth at or above which the signal is bad
    pub depth_high: usize,
    /// advertised depth at or below which the signal can read good
    /// (must be below `depth_high`)
    pub depth_low: usize,
    /// consecutive bad (good) observations required before stepping the
    /// ladder down (up)
    pub sustain: u32,
    /// observations after a step during which the ladder is frozen
    pub cooldown: u32,
    /// deadline handed to [`DeliveryPolicy::Anytime`] when the policy
    /// degrades delivery at the narrowest width; 0 removes the anytime
    /// rung entirely (the ladder is widths-only, then local fallback)
    pub anytime_deadline_s: f64,
    /// allow the bottom rung: answer from the device-local head alone,
    /// skipping the uplink, until probes see a good channel again
    pub local_fallback: bool,
    /// while local-only, every `probe_every`-th decision is an uplink
    /// probe at the most conservative rung
    pub probe_every: u32,
}

impl Default for PolicyConfig {
    fn default() -> Self {
        Self {
            widths: vec![1, 2, 4],
            ewma_alpha: 0.3,
            rate_low: 0.90,
            rate_high: 0.995,
            rounds_high: 1.5,
            goodput_low_bps: 0.0,
            depth_high: 8,
            depth_low: 2,
            sustain: 2,
            cooldown: 8,
            anytime_deadline_s: 0.05,
            local_fallback: false,
            probe_every: 16,
        }
    }
}

impl PolicyConfig {
    /// Structural validation (everything checkable without the manifest;
    /// width-vs-exported-codebook checks happen in `Service::validate`,
    /// which has the `Meta`). Returns the reason on failure.
    pub fn validate(&self) -> Result<(), String> {
        if self.widths.is_empty() {
            return Err("widths must name at least one candidate".into());
        }
        if !self.widths.windows(2).all(|w| w[0] < w[1]) {
            return Err(format!("widths must be strictly ascending, got {:?}", self.widths));
        }
        if self.widths.iter().any(|&w| w == 0 || w > 8) {
            return Err(format!("widths must be in 1..=8, got {:?}", self.widths));
        }
        if !(self.ewma_alpha > 0.0 && self.ewma_alpha <= 1.0) {
            return Err(format!("ewma_alpha must be in (0, 1], got {}", self.ewma_alpha));
        }
        if !(0.0..=1.0).contains(&self.rate_low)
            || !(0.0..=1.0).contains(&self.rate_high)
            || self.rate_low >= self.rate_high
        {
            return Err(format!(
                "need 0 <= rate_low < rate_high <= 1, got {} / {}",
                self.rate_low, self.rate_high
            ));
        }
        if !self.rounds_high.is_finite() || self.rounds_high < 0.0 {
            return Err(format!("rounds_high must be finite and >= 0, got {}", self.rounds_high));
        }
        if !self.goodput_low_bps.is_finite() || self.goodput_low_bps < 0.0 {
            return Err(format!(
                "goodput_low_bps must be finite and >= 0, got {}",
                self.goodput_low_bps
            ));
        }
        if self.depth_low >= self.depth_high {
            return Err(format!(
                "need depth_low < depth_high, got {} / {}",
                self.depth_low, self.depth_high
            ));
        }
        if self.sustain == 0 {
            return Err("sustain must be >= 1".into());
        }
        if !self.anytime_deadline_s.is_finite() || self.anytime_deadline_s < 0.0 {
            return Err(format!(
                "anytime_deadline_s must be finite and >= 0, got {}",
                self.anytime_deadline_s
            ));
        }
        if self.local_fallback && self.probe_every == 0 {
            return Err("probe_every must be >= 1 when local_fallback is on".into());
        }
        Ok(())
    }

    /// The anytime rung exists only when a positive deadline was given.
    pub fn has_anytime_rung(&self) -> bool {
        self.anytime_deadline_s > 0.0
    }
}

/// What the device half does for one request.
#[derive(Debug, Clone, PartialEq)]
pub struct Decision {
    /// quantizer width to encode at (meaningful even for `local_only`:
    /// the width the policy would use if it were uplinking)
    pub bits: u32,
    /// delivery policy for this uplink
    pub delivery: DeliveryPolicy,
    /// answer from the local head alone; skip the uplink
    pub local_only: bool,
    /// this decision differs from the previous one (probe transitions
    /// included) — drives the `PolicySwitch` trace instant
    pub switched: bool,
}

/// Per-request summary of the policy's choice, carried on served
/// outcomes so reporting can histogram widths and count switches.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct PolicyOutcome {
    pub bits: u32,
    pub switched: bool,
    pub local_only: bool,
}

/// Ladder rung, best to worst.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Mode {
    /// uplink under ARQ at `width_idx`
    Arq,
    /// uplink under the anytime deadline at the narrowest width
    Anytime,
    /// no uplink; local head only
    LocalOnly,
}

/// Per-device adaptation state machine. One per device; single-threaded
/// (the event engine owns all of them, the threaded path owns one per
/// device thread).
#[derive(Debug, Clone)]
pub struct DevicePolicy {
    cfg: PolicyConfig,
    mode: Mode,
    /// index into `cfg.widths` (only meaningful in `Mode::Arq`;
    /// the anytime and local rungs pin the narrowest width)
    width_idx: usize,
    ewma_rate: f64,
    ewma_rounds: f64,
    ewma_goodput: f64,
    /// no observation yet: EWMAs seed from the first sample
    seen: bool,
    bad_streak: u32,
    good_streak: u32,
    cooldown_left: u32,
    /// decisions made since the last probe (local-only mode)
    since_probe: u32,
    /// ladder transitions (state changes, not per-request re-decisions)
    steps: u64,
    /// (bits, delivery name, local_only) of the previous decision
    last: Option<(u32, &'static str, bool)>,
}

impl DevicePolicy {
    /// `cfg` must have passed [`PolicyConfig::validate`].
    pub fn new(cfg: PolicyConfig) -> Self {
        let width_idx = cfg.widths.len() - 1;
        Self {
            cfg,
            mode: Mode::Arq,
            width_idx,
            ewma_rate: 1.0,
            ewma_rounds: 0.0,
            ewma_goodput: 0.0,
            seen: false,
            bad_streak: 0,
            good_streak: 0,
            cooldown_left: 0,
            since_probe: 0,
            steps: 0,
            last: None,
        }
    }

    /// Decide what to do with the next request. Pure read of the ladder
    /// state except for the probe counter: while local-only, every
    /// `probe_every`-th call is an uplink probe at the most conservative
    /// rung.
    pub fn decide(&mut self) -> Decision {
        let (bits, delivery, local_only) = match self.mode {
            Mode::Arq => (self.cfg.widths[self.width_idx], DeliveryPolicy::Arq, false),
            Mode::Anytime => (
                self.cfg.widths[0],
                DeliveryPolicy::Anytime { deadline_s: self.cfg.anytime_deadline_s },
                false,
            ),
            Mode::LocalOnly => {
                self.since_probe += 1;
                if self.since_probe >= self.cfg.probe_every {
                    self.since_probe = 0;
                    (self.cfg.widths[0], self.probe_delivery(), false)
                } else {
                    (self.cfg.widths[0], DeliveryPolicy::Arq, true)
                }
            }
        };
        let key = (bits, delivery.name(), local_only);
        let switched = self.last.is_some_and(|prev| prev != key);
        self.last = Some(key);
        Decision { bits, delivery, local_only, switched }
    }

    /// Feed back one uplink's transport accounting plus the queue depth
    /// the server advertised on the reply. Updates the EWMAs, then — past
    /// any cooldown — accumulates the sustain streaks and steps the
    /// ladder. Local-only requests produce no observation (the EWMA
    /// freezes until a probe).
    pub fn observe(&mut self, stats: &NetStats, queue_depth: usize) {
        let rate = if stats.features_total > 0 {
            stats.features_delivered as f64 / stats.features_total as f64
        } else if stats.complete {
            1.0
        } else {
            0.0
        };
        let rounds = stats.retransmit_rounds as f64;
        let goodput = if stats.uplink_s > 0.0 {
            stats.app_bytes_delivered as f64 * 8.0 / stats.uplink_s
        } else {
            0.0
        };
        if self.seen {
            let a = self.cfg.ewma_alpha;
            self.ewma_rate = a * rate + (1.0 - a) * self.ewma_rate;
            self.ewma_rounds = a * rounds + (1.0 - a) * self.ewma_rounds;
            self.ewma_goodput = a * goodput + (1.0 - a) * self.ewma_goodput;
        } else {
            self.ewma_rate = rate;
            self.ewma_rounds = rounds;
            self.ewma_goodput = goodput;
            self.seen = true;
        }
        if self.cooldown_left > 0 {
            self.cooldown_left -= 1;
            return;
        }
        let c = &self.cfg;
        let bad = self.ewma_rate < c.rate_low
            || self.ewma_rounds > c.rounds_high
            || queue_depth >= c.depth_high
            || (c.goodput_low_bps > 0.0 && self.ewma_goodput < c.goodput_low_bps);
        let good = self.ewma_rate >= c.rate_high
            && self.ewma_rounds <= c.rounds_high * 0.5
            && queue_depth <= c.depth_low
            && (c.goodput_low_bps == 0.0 || self.ewma_goodput >= 2.0 * c.goodput_low_bps);
        if bad {
            self.bad_streak += 1;
            self.good_streak = 0;
        } else if good {
            self.good_streak += 1;
            self.bad_streak = 0;
        } else {
            self.bad_streak = 0;
            self.good_streak = 0;
        }
        if self.bad_streak >= self.cfg.sustain {
            self.bad_streak = 0;
            if self.step_down() {
                self.steps += 1;
                self.cooldown_left = self.cfg.cooldown;
            }
        } else if self.good_streak >= self.cfg.sustain {
            self.good_streak = 0;
            if self.step_up() {
                self.steps += 1;
                self.cooldown_left = self.cfg.cooldown;
            }
        }
    }

    /// Ladder transitions so far (state changes, not re-decisions).
    pub fn steps(&self) -> u64 {
        self.steps
    }

    /// Width the next uplink would encode at.
    pub fn current_bits(&self) -> u32 {
        match self.mode {
            Mode::Arq => self.cfg.widths[self.width_idx],
            _ => self.cfg.widths[0],
        }
    }

    fn probe_delivery(&self) -> DeliveryPolicy {
        if self.cfg.has_anytime_rung() {
            DeliveryPolicy::Anytime { deadline_s: self.cfg.anytime_deadline_s }
        } else {
            DeliveryPolicy::Arq
        }
    }

    /// One rung down; false at the bottom of the configured ladder.
    fn step_down(&mut self) -> bool {
        match self.mode {
            Mode::Arq if self.width_idx > 0 => {
                self.width_idx -= 1;
                true
            }
            Mode::Arq if self.cfg.has_anytime_rung() => {
                self.mode = Mode::Anytime;
                true
            }
            Mode::Arq | Mode::Anytime if self.cfg.local_fallback => {
                self.mode = Mode::LocalOnly;
                self.since_probe = 0;
                true
            }
            _ => false,
        }
    }

    /// One rung up; false at the top.
    fn step_up(&mut self) -> bool {
        match self.mode {
            Mode::LocalOnly => {
                self.mode =
                    if self.cfg.has_anytime_rung() { Mode::Anytime } else { Mode::Arq };
                self.width_idx = 0;
                true
            }
            Mode::Anytime => {
                self.mode = Mode::Arq;
                self.width_idx = 0;
                true
            }
            Mode::Arq if self.width_idx + 1 < self.cfg.widths.len() => {
                self.width_idx += 1;
                true
            }
            Mode::Arq => false,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn bad_stats() -> NetStats {
        NetStats {
            features_total: 100,
            features_delivered: 40,
            retransmit_rounds: 4,
            app_bytes_offered: 100,
            app_bytes_delivered: 40,
            uplink_s: 0.1,
            complete: false,
            ..NetStats::default()
        }
    }

    fn good_stats() -> NetStats {
        NetStats {
            features_total: 100,
            features_delivered: 100,
            retransmit_rounds: 0,
            app_bytes_offered: 100,
            app_bytes_delivered: 100,
            uplink_s: 0.01,
            complete: true,
            ..NetStats::default()
        }
    }

    fn quick(cfg: &mut PolicyConfig) {
        cfg.sustain = 2;
        cfg.cooldown = 1;
    }

    #[test]
    fn defaults_validate() {
        assert_eq!(PolicyConfig::default().validate(), Ok(()));
    }

    #[test]
    fn validate_rejects_malformed_configs() {
        let base = PolicyConfig::default;
        let cases: Vec<(&str, PolicyConfig)> = vec![
            ("empty widths", PolicyConfig { widths: vec![], ..base() }),
            ("unsorted widths", PolicyConfig { widths: vec![4, 2], ..base() }),
            ("duplicate widths", PolicyConfig { widths: vec![2, 2], ..base() }),
            ("width 0", PolicyConfig { widths: vec![0, 2], ..base() }),
            ("width 9", PolicyConfig { widths: vec![2, 9], ..base() }),
            ("alpha 0", PolicyConfig { ewma_alpha: 0.0, ..base() }),
            ("alpha > 1", PolicyConfig { ewma_alpha: 1.5, ..base() }),
            ("rate band inverted", PolicyConfig { rate_low: 0.99, rate_high: 0.9, ..base() }),
            ("depth band inverted", PolicyConfig { depth_low: 8, depth_high: 8, ..base() }),
            ("sustain 0", PolicyConfig { sustain: 0, ..base() }),
            ("negative deadline", PolicyConfig { anytime_deadline_s: -1.0, ..base() }),
            (
                "local fallback without probes",
                PolicyConfig { local_fallback: true, probe_every: 0, ..base() },
            ),
        ];
        for (what, cfg) in cases {
            assert!(cfg.validate().is_err(), "{what} should be rejected");
        }
    }

    #[test]
    fn starts_at_the_widest_candidate_under_arq() {
        let mut p = DevicePolicy::new(PolicyConfig::default());
        let d = p.decide();
        assert_eq!(d.bits, 4);
        assert_eq!(d.delivery, DeliveryPolicy::Arq);
        assert!(!d.local_only);
        assert!(!d.switched, "the first decision is never a switch");
    }

    #[test]
    fn sustained_bad_channel_steps_width_down_then_delivery() {
        let mut cfg = PolicyConfig::default();
        quick(&mut cfg);
        let mut p = DevicePolicy::new(cfg);
        let mut widths = vec![p.decide().bits];
        for _ in 0..40 {
            p.observe(&bad_stats(), 0);
            widths.push(p.decide().bits);
        }
        // walked 4 -> 2 -> 1, then degraded delivery to anytime at width 1
        assert!(widths.contains(&2) && widths.ends_with(&[1]));
        let d = p.decide();
        assert_eq!(d.delivery, DeliveryPolicy::Anytime { deadline_s: 0.05 });
        assert!(p.steps() >= 3);
    }

    #[test]
    fn one_bad_observation_does_not_switch() {
        let mut p = DevicePolicy::new(PolicyConfig::default()); // sustain 2
        p.observe(&bad_stats(), 0);
        assert_eq!(p.decide().bits, 4);
        assert_eq!(p.steps(), 0);
    }

    #[test]
    fn cooldown_freezes_the_ladder_after_a_step() {
        let mut cfg = PolicyConfig::default();
        cfg.sustain = 1;
        cfg.cooldown = 5;
        let mut p = DevicePolicy::new(cfg);
        p.observe(&bad_stats(), 0); // step 4 -> 2, cooldown starts
        assert_eq!(p.decide().bits, 2);
        for _ in 0..5 {
            p.observe(&bad_stats(), 0); // absorbed by the cooldown
        }
        assert_eq!(p.decide().bits, 2);
        p.observe(&bad_stats(), 0); // first counted observation
        assert_eq!(p.decide().bits, 1);
    }

    #[test]
    fn good_channel_climbs_back_to_the_widest_candidate() {
        let mut cfg = PolicyConfig::default();
        quick(&mut cfg);
        let mut p = DevicePolicy::new(cfg);
        for _ in 0..30 {
            p.observe(&bad_stats(), 0);
            p.decide();
        }
        assert_eq!(p.decide().bits, 1);
        for _ in 0..60 {
            p.observe(&good_stats(), 0);
            p.decide();
        }
        let d = p.decide();
        assert_eq!((d.bits, d.delivery), (4, DeliveryPolicy::Arq));
    }

    #[test]
    fn queue_pressure_alone_degrades() {
        let mut cfg = PolicyConfig::default();
        quick(&mut cfg);
        let mut p = DevicePolicy::new(cfg);
        for _ in 0..10 {
            p.observe(&good_stats(), 20); // perfect channel, deep queue
        }
        assert!(p.decide().bits < 4);
    }

    #[test]
    fn local_fallback_engages_and_probes_deterministically() {
        let mut cfg = PolicyConfig::default();
        quick(&mut cfg);
        cfg.local_fallback = true;
        cfg.probe_every = 4;
        cfg.ewma_alpha = 1.0; // no smoothing: recovery needs `sustain` good probes exactly
        let mut p = DevicePolicy::new(cfg);
        for _ in 0..60 {
            p.observe(&bad_stats(), 0);
            p.decide();
        }
        // bottom rung reached: local-only with every 4th decision a probe
        let kinds: Vec<bool> = (0..8).map(|_| p.decide().local_only).collect();
        let probes = kinds.iter().filter(|l| !**l).count();
        assert_eq!(probes, 2, "every probe_every-th decision uplinks: {kinds:?}");
        // two good probes climb back out of local-only
        for _ in 0..20 {
            let d = p.decide();
            if !d.local_only {
                p.observe(&good_stats(), 0);
            }
        }
        assert!(!p.decide().local_only);
    }

    #[test]
    fn constant_channel_converges_and_stops_switching() {
        for (stats, depth) in [(bad_stats(), 0usize), (good_stats(), 0), (good_stats(), 50)] {
            let mut cfg = PolicyConfig::default();
            quick(&mut cfg);
            let mut p = DevicePolicy::new(cfg);
            let mut tail = Vec::new();
            for i in 0..400 {
                let d = p.decide();
                if i >= 300 {
                    tail.push(d.clone());
                }
                p.observe(&stats, depth);
            }
            assert!(
                tail.windows(2).all(|w| w[0] == w[1]) && !tail[0].switched,
                "decisions still moving under a constant channel: {:?}",
                tail.first()
            );
            assert!(p.steps() <= 4, "ladder is short; steps must be bounded");
        }
    }

    #[test]
    fn decision_sequences_are_bitwise_deterministic() {
        let mut cfg = PolicyConfig::default();
        cfg.local_fallback = true;
        let run = || {
            let mut p = DevicePolicy::new(cfg.clone());
            let mut out = Vec::new();
            for i in 0..200 {
                out.push(p.decide());
                let s = if i % 3 == 0 { good_stats() } else { bad_stats() };
                p.observe(&s, i % 7);
            }
            out
        };
        assert_eq!(run(), run());
    }

    #[test]
    fn switched_flags_probe_transitions() {
        let mut cfg = PolicyConfig::default();
        quick(&mut cfg);
        cfg.local_fallback = true;
        cfg.probe_every = 3;
        let mut p = DevicePolicy::new(cfg);
        for _ in 0..60 {
            p.observe(&bad_stats(), 0);
            p.decide();
        }
        let mut saw_switch = false;
        for _ in 0..6 {
            let d = p.decide();
            saw_switch |= d.switched;
        }
        assert!(saw_switch, "local->probe->local transitions mark switched");
    }
}
