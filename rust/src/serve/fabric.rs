//! The transport fabric: how a device half reaches a server half.
//!
//! Before this module, device↔server communication was hard-wired into
//! `mpsc` channels inside `service.rs` — correct, but only ever
//! in-process. [`Transport`] extracts the three things the device loop
//! actually needs (send an uplink, block for the remote logits, read the
//! server's advertised queue depth) so the same `device_loop` drives:
//!
//! * [`ChannelTransport`] — the original in-process path, verbatim: an
//!   `mpsc` sender into the shared [`server_loop`] plus the sim clock's
//!   in-flight message accounting. Both clocks, bitwise-identical to the
//!   pre-fabric pipeline.
//! * [`TcpTransport`] — a real socket to an `agilenn serve --listen`
//!   daemon ([`super::daemon`]), speaking the versioned wire envelope
//!   ([`crate::net::wire`]). Wall clock only: virtual time cannot
//!   coordinate across processes.
//!
//! The queue-depth advertisement exists for DynO-style adaptive split
//! policies: the channel transport reads the live shared counter, the TCP
//! transport caches the depth each [`WireMsg::Reply`] carried.
//!
//! [`server_loop`]: super::service

use crate::compression::Frame;
use crate::net::wire::{Hello, WireMsg};
use crate::net::Packet;
use crate::serve::clock::Clock;
use crate::serve::service::RemoteFailure;
use anyhow::{anyhow, bail, Context, Result};
use std::io::{BufReader, BufWriter, Write};
use std::net::TcpStream;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender, TryRecvError};
use std::sync::Arc;

/// What actually crossed the (simulated) wire for one offload. Shared by
/// the threaded pipeline, the event engine ([`super::engine`], which
/// builds the same bodies from the same transmit calls), and the TCP
/// transport (which serializes it through [`crate::net::wire`]).
pub enum UplinkBody {
    /// intact LZW frame (ARQ transport: only decodable when complete)
    Whole(Frame),
    /// whatever packets arrived in time (anytime transport: the server
    /// reconstructs and imputes the rest)
    Packets { packets: Vec<Packet>, count: usize, bits: u32 },
}

/// What a server half sends back per offload: the remote logits (or the
/// remote failure) plus a queue-depth advertisement stamped by the server
/// loop *at the instant it sent this reply* — not re-read later by
/// whatever thread forwards it, which could observe a depth from a
/// different moment entirely (the stale-advertisement bug wire v2 fixes;
/// see `docs/daemon.md`).
pub(crate) struct Reply {
    pub(crate) result: std::result::Result<Vec<f32>, RemoteFailure>,
    /// batch-queue depth when the server sent this reply
    pub(crate) queue_depth: u32,
}

/// One in-flight offload awaiting its remote logits.
pub(crate) struct OffloadMsg {
    pub(crate) id: u64,
    pub(crate) body: UplinkBody,
    pub(crate) reply: Sender<Reply>,
}

/// How a device half reaches its server half: send one uplink body, block
/// until the remote logits (or the remote failure) come back.
///
/// The exchange is synchronous because each simulated device is — its
/// radio is half-duplex and its loop serves one request at a time — so a
/// request/reply pair per call is exactly the concurrency the pipeline
/// has. Fan-out across devices comes from each device owning its own
/// transport instance.
pub trait Transport: Send {
    /// Send request `id`'s uplink and block for the remote logits.
    fn exchange(&mut self, id: u64, body: UplinkBody) -> Result<Vec<f32>>;

    /// The server's most recently advertised batch-queue depth (live for
    /// the in-process transport; as of the last reply for TCP). The hook
    /// DynO-style adaptive split/rate policies key on.
    fn queue_depth(&self) -> usize;
}

/// The in-process transport: an `mpsc` sender into the shared server
/// loop. This is the pre-fabric device→server code path moved verbatim —
/// including the sim clock's msg_sent/notify/in-flight accounting and the
/// exact error wording — so threaded sim runs stay bitwise-equal to the
/// event-engine oracle.
pub(crate) struct ChannelTransport {
    tx: Sender<OffloadMsg>,
    clock: Clock,
    depth: Arc<AtomicUsize>,
}

impl ChannelTransport {
    pub(crate) fn new(tx: Sender<OffloadMsg>, clock: Clock, depth: Arc<AtomicUsize>) -> Self {
        Self { tx, clock, depth }
    }
}

impl Transport for ChannelTransport {
    fn exchange(&mut self, id: u64, body: UplinkBody) -> Result<Vec<f32>> {
        let (reply_tx, reply_rx) = channel();
        self.clock.msg_sent();
        if self.tx.send(OffloadMsg { id, body, reply: reply_tx }).is_err() {
            self.clock.msg_cancelled();
            return Err(anyhow!("server thread gone"));
        }
        self.clock.notify();
        recv_reply(&self.clock, &reply_rx)
            .ok_or_else(|| anyhow!("reply dropped for request {id}"))?
            .result
            .map_err(|e| anyhow!("remote inference failed for request {id}: {}", e.0))
    }

    fn queue_depth(&self) -> usize {
        // in-process the live shared counter is at least as fresh as any
        // per-reply stamp, so the advertisement is read straight from it
        self.depth.load(Ordering::Relaxed)
    }
}

/// Reply to one waiting device, keeping the sim clock's in-flight
/// accounting balanced even if the device is already gone.
pub(crate) fn send_reply(clock: &Clock, tx: &Sender<Reply>, reply: Reply) {
    clock.msg_sent();
    if tx.send(reply).is_err() {
        clock.msg_cancelled();
    }
}

/// Receive the server reply: a plain blocking `recv` under the wall clock,
/// a virtual-time wait (woken by the server's notify) under the sim clock.
pub(crate) fn recv_reply(clock: &Clock, rx: &Receiver<Reply>) -> Option<Reply> {
    if !clock.is_sim() {
        return rx.recv().ok();
    }
    loop {
        let epoch = clock.epoch();
        match rx.try_recv() {
            Ok(r) => {
                clock.msg_received();
                return Some(r);
            }
            Err(TryRecvError::Empty) => {
                clock.wait(None, epoch);
            }
            Err(TryRecvError::Disconnected) => return None,
        }
    }
}

/// The real-socket transport: one TCP connection per simulated device to
/// an `agilenn serve --listen` daemon, request/reply in lockstep over the
/// versioned wire envelope. Wall clock only.
pub struct TcpTransport {
    reader: BufReader<TcpStream>,
    writer: BufWriter<TcpStream>,
    num_classes: usize,
    depth: usize,
}

impl TcpTransport {
    /// Connect and handshake: send [`Hello`] (the world this client was
    /// built against), expect a `HelloAck`. A daemon serving a different
    /// dataset/scheme/bit-width — or speaking a different protocol
    /// version — rejects here, before any request is risked.
    pub fn connect(addr: &str, hello: &Hello) -> Result<Self> {
        let stream = TcpStream::connect(addr)
            .with_context(|| format!("connecting to serving daemon at {addr}"))?;
        stream.set_nodelay(true).ok();
        let mut writer = BufWriter::new(stream.try_clone()?);
        let mut reader = BufReader::new(stream);
        WireMsg::Hello(hello.clone()).write_to(&mut writer)?;
        writer.flush()?;
        match WireMsg::read_from(&mut reader)? {
            Some(WireMsg::HelloAck { num_classes }) => Ok(Self {
                reader,
                writer,
                num_classes: num_classes as usize,
                depth: 0,
            }),
            Some(WireMsg::Reject { reason }) => {
                bail!("daemon at {addr} rejected the handshake: {reason}")
            }
            Some(other) => bail!("unexpected handshake reply from {addr}: {other:?}"),
            None => bail!("daemon at {addr} closed the connection during the handshake"),
        }
    }

    /// The server world's class count, from the handshake.
    pub fn num_classes(&self) -> usize {
        self.num_classes
    }
}

impl Transport for TcpTransport {
    fn exchange(&mut self, id: u64, body: UplinkBody) -> Result<Vec<f32>> {
        let msg = match body {
            UplinkBody::Whole(frame) => WireMsg::OffloadFrame { id, frame },
            UplinkBody::Packets { packets, count, bits } => {
                WireMsg::OffloadPackets { id, count: count as u32, bits, packets }
            }
        };
        msg.write_to(&mut self.writer)?;
        self.writer.flush()?;
        match WireMsg::read_from(&mut self.reader)? {
            Some(WireMsg::Reply { id: rid, queue_depth, result }) => {
                if rid != id {
                    bail!("reply for request {rid} arrived while waiting on request {id}");
                }
                self.depth = queue_depth as usize;
                result.map_err(|e| anyhow!("remote inference failed for request {id}: {e}"))
            }
            Some(WireMsg::Reject { reason }) => bail!("daemon rejected request {id}: {reason}"),
            Some(other) => bail!("unexpected reply to request {id}: {other:?}"),
            None => bail!("server connection closed while awaiting the reply for request {id}"),
        }
    }

    fn queue_depth(&self) -> usize {
        self.depth
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::wire::WireError;
    use std::net::TcpListener;

    #[test]
    fn channel_transport_round_trips_and_reads_the_depth_advertisement() {
        let (tx, rx) = channel::<OffloadMsg>();
        let depth = Arc::new(AtomicUsize::new(0));
        let server = std::thread::spawn(move || {
            while let Ok(m) = rx.recv() {
                let _ = m.reply.send(Reply { result: Ok(vec![m.id as f32]), queue_depth: 5 });
            }
        });
        let mut t = ChannelTransport::new(tx, Clock::wall(), depth.clone());
        let frame = Frame { payload: vec![1, 2], count: 4, bits: 4 };
        let row = t.exchange(7, UplinkBody::Whole(frame)).unwrap();
        assert_eq!(row, vec![7.0]);
        assert_eq!(t.queue_depth(), 0);
        depth.store(3, Ordering::Relaxed); // server_loop publishes through the shared counter
        assert_eq!(t.queue_depth(), 3);
        drop(t); // sender gone -> fake server drains and exits
        server.join().unwrap();
    }

    #[test]
    fn channel_transport_names_a_gone_server() {
        let (tx, rx) = channel::<OffloadMsg>();
        drop(rx);
        let mut t = ChannelTransport::new(tx, Clock::wall(), Arc::new(AtomicUsize::new(0)));
        let frame = Frame { payload: vec![], count: 0, bits: 4 };
        let err = t.exchange(0, UplinkBody::Whole(frame)).unwrap_err();
        assert!(err.to_string().contains("server thread gone"), "{err:#}");
    }

    #[test]
    fn tcp_transport_surfaces_a_handshake_rejection() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let daemon = std::thread::spawn(move || {
            let (mut s, _) = listener.accept().unwrap();
            let hello = WireMsg::read_from(&mut s).unwrap();
            assert!(matches!(hello, Some(WireMsg::Hello(_))));
            WireMsg::Reject { reason: "daemon serves synthetic/agile at 2 bits".into() }
                .write_to(&mut s)
                .unwrap();
        });
        let hello = Hello { dataset: "synthetic".into(), scheme: "agile".into(), bits: 4 };
        let err = TcpTransport::connect(&addr, &hello).unwrap_err();
        assert!(format!("{err:#}").contains("daemon serves synthetic/agile at 2 bits"));
        daemon.join().unwrap();
    }

    #[test]
    fn tcp_transport_rejects_a_foreign_peer_with_a_typed_error() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let daemon = std::thread::spawn(move || {
            let (mut s, _) = listener.accept().unwrap();
            // not the agilenn protocol: 8 bytes that parse as a bad-magic header
            s.write_all(&[0x00, 0x01, 0x02, 0x03, 0x00, 0x00, 0x00, 0x00]).unwrap();
        });
        let hello = Hello { dataset: "synthetic".into(), scheme: "agile".into(), bits: 4 };
        let err = TcpTransport::connect(&addr, &hello).unwrap_err();
        assert_eq!(
            err.downcast_ref::<WireError>(),
            Some(&WireError::BadMagic { found: 0x00 })
        );
        daemon.join().unwrap();
    }
}
