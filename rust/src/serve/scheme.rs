//! The scheme-agnostic halves every serving scheme decomposes into:
//!
//! * [`DeviceSide`] — everything that would run on the MCU: the on-device
//!   NN (if any), feature quantization/compression, and the decision
//!   whether an uplink [`Frame`] is produced at all. Local-only schemes
//!   (MCUNet, SPINN requests resolved at the early exit) return no frame
//!   and never touch the server batcher.
//! * [`ServerSide`] — decode uplink frames back into model inputs and run
//!   the fixed-shape batched remote NN. Shared by the deadline-batched
//!   server loop in [`super::service`] and the synchronous runners.
//! * [`Fuser`] — turn local and (optional) remote logits into the final
//!   class prediction (AgileNN's §3.3 alpha fusion, plain argmax for the
//!   baselines).
//!
//! `make_device_side` / `make_server_side` / `make_fuser` wire a
//! [`RunConfig`] to the right halves, which is the only scheme dispatch the
//! serving pipeline needs.

use crate::baselines::RequestOutcome;
use crate::compression::{lzw, quantizer::Codebook, Frame, TxEncoder};
use crate::config::{Meta, RunConfig, Scheme};
use crate::coordinator::combiner::Combiner;
use crate::coordinator::device_runtime::DeviceRuntime;
use crate::coordinator::server::RemoteServer;
use crate::metrics::{EnergyLedger, LatencyBreakdown};
use crate::net::{LinkOutcome, NetStats, Packet};
use crate::runtime::{Backend, Module};
use crate::simulator::{DeviceSim, DeviceTimings, MemoryReport, NetworkSim};
use crate::tensor::{argmax, max_confidence, Tensor};
use anyhow::{ensure, Result};
use std::collections::HashMap;
use std::sync::Arc;

/// Downlink reply payload: logits (num_classes f32) + small header.
pub fn reply_bytes(num_classes: usize) -> usize {
    num_classes * 4 + 8
}

/// Result of the on-device phase for one request, scheme-agnostic.
/// `Clone` because the fleet engine memoizes encodes per test-set sample
/// (encode is a pure function of the input) and hands out copies.
#[derive(Debug, Clone)]
pub struct LocalResult {
    /// On-device logits (empty when the scheme has no device-side head).
    pub local_logits: Vec<f32>,
    /// Compressed uplink payload; `None` means the request resolved
    /// locally and bypasses the server batcher entirely.
    pub frame: Option<Frame>,
    /// Quantized symbol stream behind `frame`, for the packetized
    /// (anytime) transport; `None` when there is no uplink.
    pub symbols: Option<Vec<u8>>,
    /// Simulated device-side costs.
    pub timings: DeviceTimings,
    /// Resolved at an on-device early exit (SPINN) or offline fallback.
    pub exited_early: bool,
}

impl LocalResult {
    /// Application-layer uplink bytes (0 when nothing is transmitted).
    pub fn tx_bytes(&self) -> usize {
        self.frame.as_ref().map_or(0, |f| f.wire_bytes())
    }
}

/// Device half of a serving scheme.
pub trait DeviceSide: Send {
    fn scheme(&self) -> Scheme;

    /// Run the on-device phase for one sensor sample (unit batch).
    fn encode(&mut self, image: &Tensor) -> Result<LocalResult>;

    /// Switch the quantizer to a different exported bit width — the
    /// adaptive policy's rate actuator ([`crate::serve::policy`]).
    /// Subsequent `encode` calls transmit at `bits`. Pre-validated
    /// candidates only: encoders for every `RunConfig::candidate_widths`
    /// entry are built at construction, so a width the manifest never
    /// exported fails at `build()`, not here. Schemes without a
    /// quantizer reject the call.
    fn set_bits(&mut self, bits: u32) -> Result<()> {
        anyhow::bail!(
            "{} does not support per-request width switching (asked for {bits}-bit)",
            self.scheme().name()
        )
    }

    /// Static on-device memory accounting (Fig 20).
    fn memory_report(&self) -> MemoryReport;
}

/// Server half of a serving scheme: frame decode + batched remote NN.
pub trait ServerSide: Send {
    /// Decode one uplink frame into the remote NN's input tensor.
    fn decode(&self, frame: &Frame) -> Result<Tensor>;

    /// Decode a (possibly partial) packetized frame: reconstruct from
    /// whatever packets arrived, imputing missing features from the stored
    /// reference. `count`/`bits` describe the full symbol stream.
    fn decode_packets(&self, packets: &[Packet], count: usize, bits: u32) -> Result<Tensor>;

    /// Run the remote NN on a group of decoded inputs; one logits row per
    /// request (padding rows are dropped by the implementation).
    fn infer_batch(&mut self, feats: &[Tensor]) -> Result<Vec<Vec<f32>>>;

    /// Largest batch this server can run (some schemes export fewer batch
    /// sizes); the pipeline clamps its dispatch cap to this.
    fn max_batch(&self) -> usize;
}

impl ServerSide for RemoteServer {
    fn decode(&self, frame: &Frame) -> Result<Tensor> {
        RemoteServer::decode(self, frame)
    }

    fn decode_packets(&self, packets: &[Packet], count: usize, bits: u32) -> Result<Tensor> {
        RemoteServer::decode_packets(self, packets, count, bits)
    }

    fn infer_batch(&mut self, feats: &[Tensor]) -> Result<Vec<Vec<f32>>> {
        self.infer(feats)
    }

    fn max_batch(&self) -> usize {
        RemoteServer::max_batch(self)
    }
}

/// Prediction-fusion step: combine the device half's logits with the
/// server half's (when the request was offloaded) into the final class.
pub trait Fuser: Send {
    fn fuse(&self, local: &LocalResult, remote: Option<&[f32]>) -> Result<usize>;
}

/// AgileNN §3.3: alpha-weighted local/remote sum; falls back to the local
/// head alone when the request never reached the server (link down, §9).
pub struct AlphaFuser {
    combiner: Combiner,
}

impl AlphaFuser {
    pub fn new(alpha: f64) -> Result<Self> {
        Ok(Self { combiner: Combiner::new(alpha)? })
    }

    pub fn alpha(&self) -> f64 {
        self.combiner.alpha()
    }
}

impl Fuser for AlphaFuser {
    fn fuse(&self, local: &LocalResult, remote: Option<&[f32]>) -> Result<usize> {
        match remote {
            Some(r) => self.combiner.predict(&local.local_logits, r),
            None => Ok(self.combiner.predict_local_only(&local.local_logits)),
        }
    }
}

/// Offloaded schemes without a fusing head (DeepCOD, EdgeOnly, SPINN): the
/// remote logits decide; early-exited requests use the device logits.
pub struct RemoteArgmaxFuser;

impl Fuser for RemoteArgmaxFuser {
    fn fuse(&self, local: &LocalResult, remote: Option<&[f32]>) -> Result<usize> {
        match remote {
            Some(r) => Ok(argmax(r)),
            None => {
                ensure!(
                    !local.local_logits.is_empty(),
                    "request neither offloaded nor resolved on device"
                );
                Ok(argmax(&local.local_logits))
            }
        }
    }
}

/// Local-only schemes (MCUNet): the device logits are the prediction.
pub struct LocalArgmaxFuser;

impl Fuser for LocalArgmaxFuser {
    fn fuse(&self, local: &LocalResult, _remote: Option<&[f32]>) -> Result<usize> {
        ensure!(!local.local_logits.is_empty(), "local-only scheme produced no logits");
        Ok(argmax(&local.local_logits))
    }
}

/// Fuse and price one request after the (optional) remote phase. Shared by
/// the synchronous runners and the threaded pipeline so the simulated
/// accounting (link model, energy ledger, breakdown fields) never
/// diverges between the two paths. `remote_wall_s` is whatever the caller
/// measured around the server phase (per-request for the sync path, queue
/// + batch for the live pipeline — wall-measured or virtual depending on
/// the serving clock). When the request crossed a simulated lossy channel,
/// `link` carries the measured transport outcome and overrides the
/// closed-form `net` pricing (which remains the ideal-link fallback for
/// the synchronous runners); its `radio_wait_s` — time queued behind the
/// device radio under load — is charged to the network component of the
/// breakdown, but not to the radio energy (an idle wait is not airtime).
#[allow(clippy::too_many_arguments)]
pub(crate) fn assemble_outcome(
    fuser: &dyn Fuser,
    local: &LocalResult,
    remote: Option<&[f32]>,
    label: i32,
    tx_bytes: usize,
    remote_wall_s: f64,
    dev: &DeviceSim,
    net: &NetworkSim,
    link: Option<&LinkOutcome>,
    num_classes: usize,
) -> Result<RequestOutcome> {
    let (network_s, radio_j, net_stats) = match (remote.is_some(), link) {
        (true, Some(l)) => {
            (l.network_s + l.stats.radio_wait_s, dev.radio_energy_j(l.airtime_s), l.stats)
        }
        (true, None) => {
            let reply = reply_bytes(num_classes);
            let stats = NetStats {
                packets_sent: net.packets(tx_bytes),
                app_bytes_offered: tx_bytes,
                app_bytes_delivered: tx_bytes,
                complete: true,
                uplink_s: net.transfer_s(tx_bytes),
                airtime_s: net.airtime_s(tx_bytes),
                ..NetStats::default()
            };
            (
                net.transfer_s(tx_bytes) + net.transfer_s(reply),
                dev.radio_energy_j(net.airtime_s(tx_bytes) + net.airtime_s(reply)),
                stats,
            )
        }
        (false, _) => (0.0, 0.0, NetStats::default()),
    };
    let predicted = fuser.fuse(local, remote)?;
    Ok(RequestOutcome {
        predicted,
        correct: predicted as i32 == label,
        breakdown: LatencyBreakdown {
            local_nn_s: local.timings.nn_compute_s,
            compression_s: local.timings.quantize_s + local.timings.compress_s,
            network_s,
            remote_s: remote_wall_s,
        },
        energy: EnergyLedger { compute_j: dev.compute_energy_j(local.timings.total_s()), radio_j },
        tx_bytes,
        net: net_stats,
        exited_early: local.exited_early,
    })
}

// ---------------------------------------------------------------------------
// Memory accounting (Fig 20), shared by all device halves.
// ---------------------------------------------------------------------------

/// Activation-peak estimate (int8 bytes at 32x32; the device sim's
/// resolution_scale handles the 96x96 translation for SRAM the same way it
/// does for MACs — activations scale with spatial area).
fn activation_peak(scheme: Scheme) -> usize {
    match scheme {
        // conv1: 32*32*3 in + 16*16*16 out; conv2: 4096 + 8*8*24
        Scheme::Agile => 3072 + 4096,
        // encoder conv2: 16*16*32 + 16*16*32
        Scheme::Deepcod => 8192 + 8192,
        // conv1: 3072 + 16*16*24
        Scheme::Spinn => 3072 + 6144,
        // conv1: 3072 + 16*16*16
        Scheme::Mcunet => 3072 + 4096,
        // raw image buffer only
        Scheme::EdgeOnly => 3072,
    }
}

/// LZW dictionary SRAM for schemes that compress on-device.
const LZW_DICT_SRAM: usize = 20 * 1024;

/// Only the anytime transport re-chunks the quantized symbol stream;
/// skipping the capture keeps the per-request copy off the ARQ/bench hot
/// path. An adaptive policy with an anytime rung can switch into the
/// packetized transport mid-run, so it forces the capture too.
fn capture_symbols(cfg: &RunConfig) -> bool {
    matches!(cfg.net.delivery, crate::net::DeliveryPolicy::Anytime { .. })
        || cfg.policy.as_ref().is_some_and(|p| p.has_anytime_rung())
}

/// Pre-built spare [`TxEncoder`]s for every adaptive-policy candidate
/// width other than the active `cfg.bits`, keyed by width. Empty with
/// the policy off — the single-encoder fast path is untouched then.
fn alt_encoders(
    cfg: &RunConfig,
    meta: &Meta,
    scheme: Scheme,
) -> Result<HashMap<u32, TxEncoder>> {
    let mut alts = HashMap::new();
    for w in cfg.candidate_widths() {
        if w != cfg.bits {
            alts.insert(w, TxEncoder::new(Codebook::new(meta.codebook(scheme, w)?)?));
        }
    }
    Ok(alts)
}

/// Swap the active encoder for the `bits`-wide spare (O(1), no
/// allocation: the displaced encoder parks in the spares map under its
/// own width). No-op when already at `bits`.
fn swap_encoder(
    tx: &mut TxEncoder,
    alts: &mut HashMap<u32, TxEncoder>,
    current: &mut u32,
    bits: u32,
) -> Result<()> {
    if bits == *current {
        return Ok(());
    }
    let mut next = alts.remove(&bits).ok_or_else(|| {
        anyhow::anyhow!(
            "no {bits}-bit encoder prepared (policy candidate widths are validated at build time)"
        )
    })?;
    std::mem::swap(tx, &mut next);
    alts.insert(*current, next);
    *current = bits;
    Ok(())
}

fn memory_report_for(cfg: &RunConfig, meta: &Meta, scheme: Scheme) -> MemoryReport {
    let scale = cfg.device.resolution_scale as usize;
    let compresses = !matches!(scheme, Scheme::Mcunet);
    let act = activation_peak(scheme) * scale + if compresses { LZW_DICT_SRAM } else { 0 };
    MemoryReport::new(&cfg.device, act, meta.device_param_bytes(scheme) as usize)
}

// ---------------------------------------------------------------------------
// Device halves
// ---------------------------------------------------------------------------

/// AgileNN device half: fused extractor + local NN + learned tx pipeline.
pub struct AgileDevice {
    inner: DeviceRuntime,
    mem: MemoryReport,
}

impl AgileDevice {
    pub fn new(backend: &dyn Backend, cfg: &RunConfig, meta: &Meta) -> Result<Self> {
        Ok(Self {
            inner: DeviceRuntime::new(backend, cfg, meta)?,
            mem: memory_report_for(cfg, meta, Scheme::Agile),
        })
    }
}

impl DeviceSide for AgileDevice {
    fn scheme(&self) -> Scheme {
        Scheme::Agile
    }

    fn encode(&mut self, image: &Tensor) -> Result<LocalResult> {
        let out = self.inner.process(image)?;
        Ok(LocalResult {
            local_logits: out.local_logits,
            frame: Some(out.frame),
            symbols: out.symbols,
            timings: out.timings,
            exited_early: false,
        })
    }

    fn set_bits(&mut self, bits: u32) -> Result<()> {
        self.inner.set_bits(bits)
    }

    fn memory_report(&self) -> MemoryReport {
        self.mem
    }
}

/// DeepCOD device half: learned encoder, everything classifies remotely.
pub struct DeepcodDevice {
    encoder: Arc<dyn Module>,
    tx: TxEncoder,
    bits: u32,
    alt_tx: HashMap<u32, TxEncoder>,
    sim: DeviceSim,
    nn_macs: u64,
    mem: MemoryReport,
    capture_symbols: bool,
}

impl DeepcodDevice {
    pub fn new(backend: &dyn Backend, cfg: &RunConfig, meta: &Meta) -> Result<Self> {
        ensure!(cfg.scheme == Scheme::Deepcod, "wrong scheme for DeepcodDevice");
        let encoder = backend.load_module(&cfg.dataset_dir(), "deepcod_device_b1")?;
        let codebook = Codebook::new(meta.codebook(Scheme::Deepcod, cfg.bits)?)?;
        Ok(Self {
            encoder,
            tx: TxEncoder::new(codebook),
            bits: cfg.bits,
            alt_tx: alt_encoders(cfg, meta, Scheme::Deepcod)?,
            sim: DeviceSim::new(cfg.device.clone()),
            nn_macs: meta.macs.deepcod_device,
            mem: memory_report_for(cfg, meta, Scheme::Deepcod),
            capture_symbols: capture_symbols(cfg),
        })
    }
}

impl DeviceSide for DeepcodDevice {
    fn scheme(&self) -> Scheme {
        Scheme::Deepcod
    }

    fn encode(&mut self, image: &Tensor) -> Result<LocalResult> {
        let outputs = self.encoder.run(std::slice::from_ref(image))?;
        ensure!(outputs.len() == 1, "deepcod encoder yields (code,)");
        let code = &outputs[0];
        let frame = self.tx.encode(code.data());
        let symbols = self.capture_symbols.then(|| self.tx.symbols().to_vec());
        let timings = DeviceTimings {
            nn_compute_s: self.sim.nn_latency_s(self.nn_macs),
            quantize_s: self.sim.quantize_latency_s(code.len()),
            compress_s: self
                .sim
                .compress_latency_s((code.len() * self.tx.codebook().bits() as usize + 7) / 8),
        };
        Ok(LocalResult {
            local_logits: Vec::new(),
            frame: Some(frame),
            symbols,
            timings,
            exited_early: false,
        })
    }

    fn set_bits(&mut self, bits: u32) -> Result<()> {
        swap_encoder(&mut self.tx, &mut self.alt_tx, &mut self.bits, bits)
    }

    fn memory_report(&self) -> MemoryReport {
        self.mem
    }
}

/// SPINN device half: partitioned NN with an on-device early exit.
pub struct SpinnDevice {
    device_exe: Arc<dyn Module>,
    tx: TxEncoder,
    bits: u32,
    alt_tx: HashMap<u32, TxEncoder>,
    sim: DeviceSim,
    nn_macs: u64,
    exit_threshold: f32,
    mem: MemoryReport,
    capture_symbols: bool,
}

impl SpinnDevice {
    pub fn new(backend: &dyn Backend, cfg: &RunConfig, meta: &Meta) -> Result<Self> {
        ensure!(cfg.scheme == Scheme::Spinn, "wrong scheme for SpinnDevice");
        let device_exe = backend.load_module(&cfg.dataset_dir(), "spinn_device_b1")?;
        let codebook = Codebook::new(meta.codebook(Scheme::Spinn, cfg.bits)?)?;
        Ok(Self {
            device_exe,
            tx: TxEncoder::new(codebook),
            bits: cfg.bits,
            alt_tx: alt_encoders(cfg, meta, Scheme::Spinn)?,
            sim: DeviceSim::new(cfg.device.clone()),
            nn_macs: meta.macs.spinn_device,
            exit_threshold: meta.spinn_exit.threshold as f32,
            mem: memory_report_for(cfg, meta, Scheme::Spinn),
            capture_symbols: capture_symbols(cfg),
        })
    }
}

impl DeviceSide for SpinnDevice {
    fn scheme(&self) -> Scheme {
        Scheme::Spinn
    }

    fn encode(&mut self, image: &Tensor) -> Result<LocalResult> {
        let outputs = self.device_exe.run(std::slice::from_ref(image))?;
        ensure!(outputs.len() == 2, "spinn device yields (feats, exit_logits)");
        let feats = &outputs[0];
        let exit_logits = outputs[1].data().to_vec();
        let nn_s = self.sim.nn_latency_s(self.nn_macs);

        // confident enough -> resolve on device, no transmission
        if max_confidence(&exit_logits) >= self.exit_threshold {
            return Ok(LocalResult {
                local_logits: exit_logits,
                frame: None,
                symbols: None,
                timings: DeviceTimings { nn_compute_s: nn_s, ..Default::default() },
                exited_early: true,
            });
        }

        let frame = self.tx.encode(feats.data());
        let symbols = self.capture_symbols.then(|| self.tx.symbols().to_vec());
        let timings = DeviceTimings {
            nn_compute_s: nn_s,
            quantize_s: self.sim.quantize_latency_s(feats.len()),
            compress_s: self
                .sim
                .compress_latency_s((feats.len() * self.tx.codebook().bits() as usize + 7) / 8),
        };
        Ok(LocalResult {
            local_logits: exit_logits,
            frame: Some(frame),
            symbols,
            timings,
            exited_early: false,
        })
    }

    fn set_bits(&mut self, bits: u32) -> Result<()> {
        swap_encoder(&mut self.tx, &mut self.alt_tx, &mut self.bits, bits)
    }

    fn memory_report(&self) -> MemoryReport {
        self.mem
    }
}

/// MCUNet device half: full local inference, never offloads.
pub struct McunetDevice {
    exe: Arc<dyn Module>,
    sim: DeviceSim,
    nn_macs: u64,
    mem: MemoryReport,
}

impl McunetDevice {
    pub fn new(backend: &dyn Backend, cfg: &RunConfig, meta: &Meta) -> Result<Self> {
        ensure!(cfg.scheme == Scheme::Mcunet, "wrong scheme for McunetDevice");
        Ok(Self {
            exe: backend.load_module(&cfg.dataset_dir(), "mcunet_local_b1")?,
            sim: DeviceSim::new(cfg.device.clone()),
            nn_macs: meta.macs.mcunet_local,
            mem: memory_report_for(cfg, meta, Scheme::Mcunet),
        })
    }
}

impl DeviceSide for McunetDevice {
    fn scheme(&self) -> Scheme {
        Scheme::Mcunet
    }

    fn encode(&mut self, image: &Tensor) -> Result<LocalResult> {
        let outputs = self.exe.run(std::slice::from_ref(image))?;
        ensure!(!outputs.is_empty(), "mcunet artifact yields (logits,)");
        Ok(LocalResult {
            local_logits: outputs[0].data().to_vec(),
            frame: None,
            symbols: None,
            timings: DeviceTimings {
                nn_compute_s: self.sim.nn_latency_s(self.nn_macs),
                ..Default::default()
            },
            exited_early: false,
        })
    }

    fn memory_report(&self) -> MemoryReport {
        self.mem
    }
}

/// Edge-only device half: no NN on device, LZW-compressed raw image uplink.
pub struct EdgeDevice {
    sim: DeviceSim,
    mem: MemoryReport,
}

impl EdgeDevice {
    pub fn new(cfg: &RunConfig, meta: &Meta) -> Self {
        Self {
            sim: DeviceSim::new(cfg.device.clone()),
            mem: memory_report_for(cfg, meta, Scheme::EdgeOnly),
        }
    }
}

impl DeviceSide for EdgeDevice {
    fn scheme(&self) -> Scheme {
        Scheme::EdgeOnly
    }

    fn encode(&mut self, image: &Tensor) -> Result<LocalResult> {
        // quantize f32 [0,1] image to u8 and LZW it; an 8-bit "codebook"
        // frame whose count is the raw byte length
        let raw: Vec<u8> = image.data().iter().map(|&v| (v * 255.0) as u8).collect();
        let payload = lzw::compress(&raw);
        let timings = DeviceTimings {
            compress_s: self.sim.compress_latency_s(raw.len()),
            ..Default::default()
        };
        Ok(LocalResult {
            local_logits: Vec::new(),
            frame: Some(Frame { payload, count: raw.len(), bits: 8 }),
            symbols: Some(raw),
            timings,
            exited_early: false,
        })
    }

    fn memory_report(&self) -> MemoryReport {
        self.mem
    }
}

// ---------------------------------------------------------------------------
// Scheme dispatch
// ---------------------------------------------------------------------------

/// Device half for `cfg.scheme`.
pub fn make_device_side(
    backend: &dyn Backend,
    cfg: &RunConfig,
    meta: &Meta,
) -> Result<Box<dyn DeviceSide>> {
    Ok(match cfg.scheme {
        Scheme::Agile => Box::new(AgileDevice::new(backend, cfg, meta)?),
        Scheme::Deepcod => Box::new(DeepcodDevice::new(backend, cfg, meta)?),
        Scheme::Spinn => Box::new(SpinnDevice::new(backend, cfg, meta)?),
        Scheme::Mcunet => Box::new(McunetDevice::new(backend, cfg, meta)?),
        Scheme::EdgeOnly => Box::new(EdgeDevice::new(cfg, meta)),
    })
}

/// Server half for `cfg.scheme`; `None` for fully-local schemes, which
/// never enter the batcher.
pub fn make_server_side(
    backend: &dyn Backend,
    cfg: &RunConfig,
    meta: &Meta,
) -> Result<Option<Box<dyn ServerSide>>> {
    Ok(match cfg.scheme {
        Scheme::Mcunet => None,
        _ => Some(Box::new(RemoteServer::new(backend, cfg, meta)?)),
    })
}

/// Fusion step for `cfg.scheme` (honours `cfg.alpha_override` for AgileNN).
pub fn make_fuser(cfg: &RunConfig, meta: &Meta) -> Result<Box<dyn Fuser>> {
    Ok(match cfg.scheme {
        Scheme::Agile => Box::new(AlphaFuser::new(cfg.alpha_override.unwrap_or(meta.alpha))?),
        Scheme::Mcunet => Box::new(LocalArgmaxFuser),
        Scheme::Deepcod | Scheme::Spinn | Scheme::EdgeOnly => Box::new(RemoteArgmaxFuser),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn local(logits: Vec<f32>, exited: bool) -> LocalResult {
        LocalResult {
            local_logits: logits,
            frame: None,
            symbols: None,
            timings: DeviceTimings::default(),
            exited_early: exited,
        }
    }

    #[test]
    fn alpha_fuser_matches_combiner() {
        let f = AlphaFuser::new(0.3).unwrap();
        let l = local(vec![10.0, 0.0], false);
        // 0.3*10 + 0.7*0 = 3 vs 0.3*0 + 0.7*10 = 7 -> class 1
        assert_eq!(f.fuse(&l, Some(&[0.0, 10.0])).unwrap(), 1);
        // no remote: local head alone
        assert_eq!(f.fuse(&l, None).unwrap(), 0);
    }

    #[test]
    fn remote_argmax_prefers_remote_then_local() {
        let f = RemoteArgmaxFuser;
        let l = local(vec![0.0, 5.0], true);
        assert_eq!(f.fuse(&l, Some(&[9.0, 0.0, 1.0])).unwrap(), 0);
        assert_eq!(f.fuse(&l, None).unwrap(), 1);
        assert!(f.fuse(&local(Vec::new(), false), None).is_err());
    }

    #[test]
    fn local_argmax_requires_logits() {
        let f = LocalArgmaxFuser;
        assert_eq!(f.fuse(&local(vec![1.0, 3.0, 2.0], false), None).unwrap(), 1);
        assert!(f.fuse(&local(Vec::new(), false), None).is_err());
    }

    #[test]
    fn tx_bytes_zero_without_frame() {
        assert_eq!(local(vec![1.0], false).tx_bytes(), 0);
        let with_frame = LocalResult {
            local_logits: Vec::new(),
            frame: Some(Frame { payload: vec![1, 2, 3], count: 3, bits: 8 }),
            symbols: Some(vec![1, 2, 3]),
            timings: DeviceTimings::default(),
            exited_early: false,
        };
        assert_eq!(with_frame.tx_bytes(), 3 + 4);
    }

    #[test]
    fn reply_bytes_scale_with_classes() {
        assert_eq!(reply_bytes(10), 48);
        assert!(reply_bytes(100) > reply_bytes(10));
    }
}
