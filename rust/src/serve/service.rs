//! `ServeBuilder` → [`Service`] → [`OutcomeStream`]: the scheme-agnostic
//! multi-device serving pipeline.
//!
//! N simulated sensor devices stream requests through a shared remote
//! server with deadline-driven dynamic batching (vLLM-router topology),
//! built on std threads + channels — the build environment vendors no
//! async runtime, and the server loop's recv_timeout + deadline poll is
//! exactly the select it needs. The timeline is pluggable
//! ([`super::clock`]): the wall clock really sleeps and really waits,
//! while the sim clock replays the same event structure in discrete
//! virtual time, making sustained-load runs fast and bit-reproducible.
//!
//! Every scheme runs through the same loop: its [`DeviceSide`] decides per
//! request whether an uplink frame exists (local-only schemes and SPINN
//! early exits bypass the batcher entirely), offloaded frames share the
//! deadline-batched [`ServerSide`] loop, and a [`Fuser`] produces the
//! final prediction. Per-request [`ServedOutcome`]s stream out of the
//! pipeline as they complete, so metrics sinks, CLI progress output, and
//! figure sweeps all consume one source of truth.
//!
//! [`DeviceSide`]: super::scheme::DeviceSide
//! [`ServerSide`]: super::scheme::ServerSide
//! [`Fuser`]: super::scheme::Fuser

use crate::baselines::RequestOutcome;
use crate::config::{default_artifacts_dir, BackendKind, Meta, RunConfig, Scheme};
use crate::coordinator::batcher::{BatchQueue, Pending, REMOTE_BATCH_SIZES};
use crate::metrics::AccuracyCounter;
use crate::net::wire::Hello;
use crate::net::{
    importance_order, transmit_frame_traced, transmit_packets_traced, BandwidthTrace, Channel,
    DeliveryPolicy, GilbertElliott, LinkOutcome, PacketOrder, Packetizer,
};
use crate::obs::{EventKind, Histogram, Lane, MetricsRegistry, TraceSink, Tracer};
use crate::runtime::{make_backend, Backend};
use crate::serve::autoscale::{AutoscaleConfig, ScaleKind, ServiceModel};
use crate::serve::clock::{Clock, ClockKind};
use crate::serve::engine::{self, FleetSpec, Placement, SimEngine};
use crate::serve::fabric::{
    send_reply, ChannelTransport, OffloadMsg, Reply, TcpTransport, Transport, UplinkBody,
};
use crate::serve::policy::{DevicePolicy, PolicyOutcome};
use crate::serve::scheme::{
    assemble_outcome, make_device_side, make_fuser, make_server_side, ServerSide,
};
use crate::simulator::{DeviceProfile, DeviceSim, NetworkProfile, NetworkSim};
use crate::tensor::Tensor;
use crate::workload::{Arrival, TestSet};
use anyhow::{anyhow, ensure, Result};
use std::path::PathBuf;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender, TryRecvError};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// Aggregate report from a pipeline run.
///
/// `accuracy`, the transport counters (`packets_*`, `retransmit_rounds`,
/// `incomplete_frames`, `delivered_feature_rate`) and the sort-based link
/// quantile `p99_net_s` are **seed-deterministic** in both clock modes:
/// two runs with the same `ServeBuilder` configuration and seeds produce
/// bit-identical values. `mean_net_s`, `mean_radio_wait_s` and
/// `goodput_bps` (whose airtime denominator is an f64 sum) are
/// deterministic up to f64 summation order (outcomes are accumulated in
/// stream-arrival order, which thread scheduling can permute on the
/// threaded paths; the sim clock's event engine emits in deterministic
/// event order, so there even these means reproduce bitwise). The
/// remaining fields depend on the clock
/// ([`ServeBuilder::clock`]): under the wall clock (the default) `wall_s`,
/// `throughput_rps`, the latency quantiles, and the batch counters measure
/// the live host pipeline and vary run to run; under the sim clock they
/// are virtual-time quantities and reproduce run to run.
#[derive(Debug, Clone)]
pub struct PipelineReport {
    pub requests: usize,
    /// which clock produced the run (and which fields are deterministic)
    pub clock: ClockKind,
    /// elapsed clock time: host seconds (wall) or virtual seconds (sim)
    pub wall_s: f64,
    pub throughput_rps: f64,
    pub accuracy: f64,
    pub mean_latency_s: f64,
    pub p95_latency_s: f64,
    pub p99_latency_s: f64,
    pub mean_batch_size: f64,
    pub batches: usize,
    /// per-server batch/queue accounting, indexed by server (one entry on
    /// the single-server paths; empty for local-only schemes, which have
    /// no server half). Multi-server topologies exist only on the sim
    /// clock's event engine ([`ServeBuilder::servers`]).
    pub shards: Vec<ShardReport>,
    /// packets pushed into the simulated channel, retransmissions included
    pub packets_sent: u64,
    /// packets the channel dropped
    pub packets_lost: u64,
    /// retransmission rounds beyond each first pass
    pub retransmit_rounds: u64,
    /// offloaded requests whose frame was decoded from a partial packet set
    pub incomplete_frames: usize,
    /// delivered / offered feature elements across packetized uplinks
    /// (1.0 when every frame completed or nothing was packetized)
    pub delivered_feature_rate: f64,
    /// application-layer goodput over the run: delivered uplink bytes * 8 /
    /// simulated link-busy time (0 when nothing was transmitted)
    pub goodput_bps: f64,
    /// mean simulated link time per request, radio queueing included
    /// (deterministic; excludes the server phase)
    pub mean_net_s: f64,
    /// p99 simulated link time per request, radio queueing included
    /// (deterministic)
    pub p99_net_s: f64,
    /// mean time per *uplink* spent queued behind the device radio
    /// (deterministic; 0 when the offered load never contends the link or
    /// nothing offloaded)
    pub mean_radio_wait_s: f64,
    /// integrated provisioned server time, Σ per-shard `active_s`:
    /// activation → retirement intervals under autoscaling, the whole run
    /// for a fixed fleet, 0 for local-only schemes. The corrected
    /// fleet-cost basis for `TuneObjectives::server_seconds` (the old
    /// `shards × wall_s` billed idle and never-activated servers).
    pub server_seconds: f64,
    /// configured end-to-end p99 latency SLO, seconds (0 = unset)
    pub slo_p99_s: f64,
    /// fraction of requests finishing within `slo_p99_s` (1.0 when no
    /// SLO is configured)
    pub slo_attainment: f64,
    /// autoscale shard activations over the run (0 with the controller off)
    pub scale_outs: usize,
    /// autoscale shard retirements over the run (0 with the controller off)
    pub scale_ins: usize,
    /// adaptive-policy accounting (`None` with the policy off — and the
    /// JSON form omits every policy field then, so policy-off reports
    /// stay byte-identical to the pre-policy pipeline)
    pub policy: Option<PolicyReport>,
}

/// Adaptive-policy accounting of one run ([`PipelineReport::policy`]).
#[derive(Debug, Clone)]
pub struct PolicyReport {
    /// per-request decision changes across the run, deterministic probe
    /// transitions included
    pub switches: usize,
    /// requests answered by the device-local head alone (no uplink)
    pub local_only: usize,
    /// mean chosen quantizer width over offloaded requests (0 when
    /// nothing offloaded)
    pub mean_bits: f64,
    /// (width, offloaded requests encoded at that width), ascending
    pub widths: Vec<(u32, usize)>,
}

/// Registry counter names for the per-width histogram, indexed by
/// `width - 1` (the registry requires `&'static str` names).
const POLICY_WIDTH_COUNTERS: [&str; 8] = [
    "policy_width_1_requests",
    "policy_width_2_requests",
    "policy_width_3_requests",
    "policy_width_4_requests",
    "policy_width_5_requests",
    "policy_width_6_requests",
    "policy_width_7_requests",
    "policy_width_8_requests",
];

impl PipelineReport {
    /// Deterministic machine-readable form: insertion-ordered JSON (see
    /// [`crate::report::JsonObj`]), so two runs with identical reports
    /// serialize byte-identically — the property golden snapshots and the
    /// CI perf-gate artifacts key on.
    pub fn to_ordered_json(&self) -> String {
        use crate::report::{json_array, JsonObj};
        let shards = json_array(self.shards.iter().map(|s| {
            JsonObj::new()
                .field_usize("server", s.server)
                .field_usize("requests", s.requests)
                .field_usize("batches", s.batches)
                .field_f64("mean_batch_size", s.mean_batch_size)
                .field_f64("mean_queue_s", s.mean_queue_s)
                .field_f64("p95_queue_s", s.p95_queue_s)
                .field_f64("active_s", s.active_s)
                .finish()
        }));
        let obj = JsonObj::new()
            .field_usize("requests", self.requests)
            .field_str("clock", self.clock.name())
            .field_f64("wall_s", self.wall_s)
            .field_f64("throughput_rps", self.throughput_rps)
            .field_f64("accuracy", self.accuracy)
            .field_f64("mean_latency_s", self.mean_latency_s)
            .field_f64("p95_latency_s", self.p95_latency_s)
            .field_f64("p99_latency_s", self.p99_latency_s)
            .field_f64("mean_batch_size", self.mean_batch_size)
            .field_usize("batches", self.batches)
            .field_raw("shards", &shards)
            .field_u64("packets_sent", self.packets_sent)
            .field_u64("packets_lost", self.packets_lost)
            .field_u64("retransmit_rounds", self.retransmit_rounds)
            .field_usize("incomplete_frames", self.incomplete_frames)
            .field_f64("delivered_feature_rate", self.delivered_feature_rate)
            .field_f64("goodput_bps", self.goodput_bps)
            .field_f64("mean_net_s", self.mean_net_s)
            .field_f64("p99_net_s", self.p99_net_s)
            .field_f64("mean_radio_wait_s", self.mean_radio_wait_s)
            .field_f64("server_seconds", self.server_seconds)
            .field_f64("slo_p99_s", self.slo_p99_s)
            .field_f64("slo_attainment", self.slo_attainment)
            .field_usize("scale_outs", self.scale_outs)
            .field_usize("scale_ins", self.scale_ins);
        // policy fields exist only when the policy ran: policy-off JSON is
        // byte-identical to the pre-policy report (the bit-identity the
        // golden snapshot pins)
        let obj = match &self.policy {
            None => obj,
            Some(p) => {
                let widths = json_array(p.widths.iter().map(|(w, n)| {
                    JsonObj::new()
                        .field_u64("bits", *w as u64)
                        .field_usize("requests", *n)
                        .finish()
                }));
                obj.field_usize("policy_switches", p.switches)
                    .field_usize("policy_local_only", p.local_only)
                    .field_f64("policy_mean_bits", p.mean_bits)
                    .field_raw("policy_widths", &widths)
            }
        };
        obj.finish()
    }

    /// Build the report as a view over the metrics registry: every field
    /// derives from named counters/sums/histograms with the same formulas
    /// the pre-registry accumulation used, so reports computed this way
    /// are field-for-field (bit-for-bit on the deterministic fields)
    /// identical to the pre-refactor implementation — the equivalence the
    /// golden snapshot pins. See `docs/observability.md` for the metric
    /// names.
    pub fn from_registry(
        m: &mut MetricsRegistry,
        clock: ClockKind,
        wall_s: f64,
        shards: Vec<ShardReport>,
    ) -> PipelineReport {
        let requests = m.counter("requests_total") as usize;
        let correct = m.counter("requests_correct");
        let batches = m.counter("batches") as usize;
        let batched = m.counter("batched_requests");
        let uplinks = m.counter("uplinks");
        let features_total = m.counter("features_total");
        let features_delivered = m.counter("features_delivered");
        let bytes_delivered = m.counter("bytes_delivered");
        let airtime_s = m.sum("airtime_s");
        let radio_wait_s = m.sum("radio_wait_s");
        let (mean_latency_s, p95_latency_s, p99_latency_s) = {
            let h = m.hist_mut("latency_s");
            (h.mean_s(), h.p95(), h.p99())
        };
        let (mean_net_s, p99_net_s) = {
            let h = m.hist_mut("net_s");
            (h.mean_s(), h.p99())
        };
        let slo_p99_s = m.sum("slo_p99_s");
        let within_slo = m.counter("requests_within_slo");
        PipelineReport {
            requests,
            clock,
            wall_s,
            throughput_rps: if wall_s > 0.0 { requests as f64 / wall_s } else { 0.0 },
            accuracy: if requests == 0 { 0.0 } else { correct as f64 / requests as f64 },
            mean_latency_s,
            p95_latency_s,
            p99_latency_s,
            mean_batch_size: if batches == 0 { 0.0 } else { batched as f64 / batches as f64 },
            batches,
            shards,
            packets_sent: m.counter("packets_sent"),
            packets_lost: m.counter("packets_lost"),
            retransmit_rounds: m.counter("retransmit_rounds"),
            incomplete_frames: m.counter("incomplete_frames") as usize,
            delivered_feature_rate: if features_total == 0 {
                1.0
            } else {
                features_delivered as f64 / features_total as f64
            },
            goodput_bps: if airtime_s <= 0.0 {
                0.0
            } else {
                bytes_delivered as f64 * 8.0 / airtime_s
            },
            mean_net_s,
            p99_net_s,
            mean_radio_wait_s: if uplinks == 0 {
                0.0
            } else {
                radio_wait_s / uplinks as f64
            },
            server_seconds: m.sum("server_seconds"),
            slo_p99_s,
            slo_attainment: if slo_p99_s <= 0.0 || requests == 0 {
                1.0
            } else {
                within_slo as f64 / requests as f64
            },
            scale_outs: m.counter("scale_outs") as usize,
            scale_ins: m.counter("scale_ins") as usize,
            // reads never create registry entries (`counter` is a plain
            // lookup), so policy-off registries stay untouched here
            policy: if m.counter("policy_enabled") > 0 {
                let uplinks = m.counter("policy_uplinks");
                let widths: Vec<(u32, usize)> = POLICY_WIDTH_COUNTERS
                    .iter()
                    .enumerate()
                    .filter_map(|(i, name)| {
                        let c = m.counter(name);
                        (c > 0).then_some((i as u32 + 1, c as usize))
                    })
                    .collect();
                Some(PolicyReport {
                    switches: m.counter("policy_switches") as usize,
                    local_only: m.counter("policy_local_only") as usize,
                    mean_bits: if uplinks == 0 {
                        0.0
                    } else {
                        m.counter("policy_bits_sum") as f64 / uplinks as f64
                    },
                    widths,
                })
            } else {
                None
            },
        }
    }
}

/// Per-server load/latency accounting of one run.
#[derive(Debug, Clone)]
pub struct ShardReport {
    pub server: usize,
    /// offloaded requests this server batched
    pub requests: usize,
    pub batches: usize,
    pub mean_batch_size: f64,
    /// batch-queue wait (enqueue → dispatch), deterministic in sim mode
    pub mean_queue_s: f64,
    pub p95_queue_s: f64,
    /// integrated seconds this server was provisioned and active:
    /// activation → retirement intervals under autoscaling, the whole run
    /// otherwise. Summed into `PipelineReport::server_seconds`.
    pub active_s: f64,
}

/// Accumulating form of [`ShardReport`], shared by the threaded server
/// loop and the event engine.
#[derive(Debug)]
pub(crate) struct ShardAgg {
    pub batched: usize,
    pub batches: usize,
    pub queue_wait: Histogram,
    /// integrated active seconds; the `-1.0` sentinel means "active for
    /// the whole run" (fixed fleets and the threaded path, which have no
    /// lifetime accounting) and resolves to the run's makespan in
    /// [`ShardAgg::into_report`]
    pub active_s: f64,
}

impl Default for ShardAgg {
    fn default() -> Self {
        Self { batched: 0, batches: 0, queue_wait: Histogram::default(), active_s: -1.0 }
    }
}

impl ShardAgg {
    pub(crate) fn into_report(mut self, server: usize, run_s: f64) -> ShardReport {
        ShardReport {
            server,
            requests: self.batched,
            batches: self.batches,
            mean_batch_size: if self.batches == 0 {
                0.0
            } else {
                self.batched as f64 / self.batches as f64
            },
            mean_queue_s: self.queue_wait.mean_s(),
            p95_queue_s: self.queue_wait.p95(),
            active_s: if self.active_s < 0.0 { run_s } else { self.active_s },
        }
    }
}

/// Request ids and arrival timestamps for one device: round-robin request
/// assignment plus the per-device periodic phase tie-break. One
/// implementation for both execution paths (threads and event engine), so
/// their schedules cannot drift — the phase keeps lockstep periodic
/// sensors off bit-identical virtual instants (see the comment in
/// [`Service::stream`]); Poisson streams are decorrelated by
/// `Arrival::for_device`.
pub(crate) fn device_schedule(
    arrival: &Arrival,
    devices: usize,
    requests: usize,
    d: usize,
) -> (Vec<usize>, Vec<f64>) {
    let ids: Vec<usize> = (d..requests).step_by(devices).collect();
    let mut times = arrival.for_device(d).timestamps(ids.len());
    if let Arrival::Periodic { hz } = *arrival {
        if hz > 0.0 {
            let phase = d as f64 * 1e-6 / hz;
            for t in &mut times {
                *t += phase;
            }
        }
    }
    (ids, times)
}

/// One per-request outcome as it streams out of the live pipeline.
#[derive(Debug, Clone)]
pub struct ServedOutcome {
    /// Request id (global; assigned round-robin across devices).
    pub id: u64,
    /// Index of the simulated device that served it.
    pub device: usize,
    /// Request latency through the threaded pipeline, including batch
    /// queueing — as opposed to `outcome.breakdown`, which carries the
    /// simulated device/network accounting. Under the wall clock: live
    /// host seconds from when the device started processing. Under the
    /// sim clock: virtual (seed-deterministic) sojourn seconds from the
    /// request's *scheduled* arrival, so device backlog under saturation
    /// is included.
    pub wall_s: f64,
    pub outcome: RequestOutcome,
    /// what the adaptive policy chose for this request (`None` with the
    /// policy off)
    pub policy: Option<PolicyOutcome>,
}

/// Server-side failure delivered to the waiting device thread, so its
/// error names the remote cause instead of a bare "reply dropped".
#[derive(Debug, Clone)]
pub struct RemoteFailure(pub String);

/// A rejected serving configuration, detected before anything starts.
///
/// Typed (and downcastable through `anyhow`) so programmatic callers —
/// the autotuner skipping infeasible grid points — can tell a bad
/// configuration from a real pipeline failure, and CLI users get a clear
/// message from the calling thread instead of a panic inside a spawned
/// worker. [`Service::stream`] runs [`Service::validate`] first, so every
/// conflict below surfaces this way.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum ConfigError {
    /// `devices == 0`
    NoDevices,
    /// `requests == 0`
    NoRequests,
    /// the test set resolved to zero examples
    EmptyTestSet,
    /// `servers == 0`
    NoServers,
    /// `max_batch` is not an exported remote batch size
    /// ([`REMOTE_BATCH_SIZES`]) — previously an assert inside the spawned
    /// server thread
    UnsupportedMaxBatch { max_batch: usize },
    /// `servers > 1` off the sim clock's event engine (the threaded paths
    /// have no server sharding)
    MultiServerNeedsEventEngine { servers: usize, clock: ClockKind, engine: SimEngine },
    /// `connect` (a remote serving daemon) off the wall clock — virtual
    /// time cannot coordinate across processes
    RemoteNeedsWallClock { clock: ClockKind },
    /// `connect` with a multi-server topology: the remote daemon *is* the
    /// one server this client can reach
    RemoteConflictsWithServers { servers: usize },
    /// the autoscale controller off the sim clock's event engine — the
    /// control plane runs on virtual time inside the fleet engine
    AutoscaleNeedsEventEngine { clock: ClockKind, engine: SimEngine },
    /// inconsistent autoscale bounds or thresholds
    /// ([`AutoscaleConfig::validate`]), or a bad SLO knob
    InvalidAutoscale { reason: String },
    /// bad service-model parameters ([`ServiceModel::validate`]), or a
    /// non-zero model off the event engine (batch pricing exists only
    /// there)
    InvalidServiceModel { reason: String },
    /// `bits` (or an adaptive-policy candidate width) has no codebook
    /// exported in the manifest for this scheme — previously an anyhow
    /// error from deep inside a spawned device thread
    UnsupportedBits { bits: u32, scheme: Scheme, available: Vec<u32> },
    /// malformed or unusable adaptive-policy configuration
    /// ([`crate::serve::policy::PolicyConfig::validate`])
    InvalidPolicy { reason: String },
}

impl std::fmt::Display for ConfigError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ConfigError::NoDevices => write!(f, "need at least one device"),
            ConfigError::NoRequests => write!(f, "need at least one request"),
            ConfigError::EmptyTestSet => write!(f, "empty test set"),
            ConfigError::NoServers => write!(f, "need at least one server"),
            ConfigError::UnsupportedMaxBatch { max_batch } => write!(
                f,
                "max batch {max_batch} is not an exported remote batch size \
                 {REMOTE_BATCH_SIZES:?}"
            ),
            ConfigError::MultiServerNeedsEventEngine { servers, clock, engine } => write!(
                f,
                "{servers} servers require the sim clock's event engine \
                 (clock sim + sim-engine event), not {} clock / {} engine",
                clock.name(),
                engine.name()
            ),
            ConfigError::RemoteNeedsWallClock { clock } => write!(
                f,
                "connecting to a remote serving daemon requires the wall clock \
                 (virtual time cannot coordinate across processes), not the {} clock",
                clock.name()
            ),
            ConfigError::RemoteConflictsWithServers { servers } => write!(
                f,
                "{servers} servers conflict with a remote daemon connection \
                 (the daemon is the one server this client can reach)"
            ),
            ConfigError::AutoscaleNeedsEventEngine { clock, engine } => write!(
                f,
                "the autoscale controller requires the sim clock's event engine \
                 (clock sim + sim-engine event), not {} clock / {} engine",
                clock.name(),
                engine.name()
            ),
            ConfigError::InvalidAutoscale { reason } => write!(f, "{reason}"),
            ConfigError::InvalidServiceModel { reason } => write!(f, "{reason}"),
            ConfigError::UnsupportedBits { bits, scheme, available } => write!(
                f,
                "no {bits}-bit codebook exported for {} (the manifest has {available:?})",
                scheme.name()
            ),
            ConfigError::InvalidPolicy { reason } => write!(f, "adaptive policy: {reason}"),
        }
    }
}

impl std::error::Error for ConfigError {}

/// What the batcher queues per offloaded request: the decoded features and
/// the waiting device's reply channel.
type BatchItem = (Tensor, Sender<Reply>);

/// Fleet topology and control-plane knobs, grouped (the PR-10
/// typed-config redesign; [`ServeBuilder::fleet`]). These are
/// builder-level knobs: they describe the simulated fleet around one
/// [`RunConfig`], not the per-request pipeline itself.
#[derive(Debug, Clone)]
pub struct FleetConfig {
    /// concurrent simulated sensor devices
    pub devices: usize,
    /// total requests, assigned round-robin across devices
    pub requests: usize,
    /// remote servers, each with its own batch queue (`> 1` requires the
    /// sim clock's event engine)
    pub servers: usize,
    /// device→server placement policy for multi-server topologies
    pub placement: Placement,
    /// per-batch virtual service-time pricing + per-server capacity
    /// weights (sim event engine only; the zero default is unpriced)
    pub service: ServiceModel,
    /// the autoscale SLO control plane (`None` = fixed fleet)
    pub autoscale: Option<AutoscaleConfig>,
    /// end-to-end p99 latency SLO target, seconds (0 = unset)
    pub slo_p99_s: f64,
}

impl Default for FleetConfig {
    fn default() -> Self {
        Self {
            devices: 1,
            requests: 64,
            servers: 1,
            placement: Placement::default(),
            service: ServiceModel::default(),
            autoscale: None,
            slo_p99_s: 0.0,
        }
    }
}

/// Builder for a scheme-agnostic serving [`Service`].
///
/// Replaces the pre-redesign pattern of hand-mutating [`RunConfig`] fields
/// and calling `run_pipeline(cfg, meta, testset, n_devices, n_requests,
/// arrival)`: every knob is a builder method, and `build()` loads the
/// trained metadata and test set from the artifacts tree.
///
/// Knobs are grouped into typed sub-configs edited in place —
/// [`ServeBuilder::fleet`] ([`FleetConfig`]), [`ServeBuilder::batch`]
/// ([`BatchConfig`]), [`ServeBuilder::net`] ([`crate::net::NetConfig`])
/// and [`ServeBuilder::policy`]
/// ([`crate::serve::policy::PolicyConfig`]) — replacing the old flat
/// setter soup (`devices`, `max_batch`, `loss_rate`, …), which remains
/// as deprecated delegating shims. [`ServeBuilder::from_config`] ⇄
/// [`ServeBuilder::to_config`] round-trip losslessly over the
/// [`RunConfig`]-representable subset (property-tested).
#[derive(Debug, Clone)]
pub struct ServeBuilder {
    artifacts_dir: PathBuf,
    dataset: String,
    scheme: Scheme,
    backend: BackendKind,
    fleet: FleetConfig,
    batch: crate::config::BatchConfig,
    arrival: Arrival,
    bits: u32,
    alpha: Option<f64>,
    device_profile: Option<DeviceProfile>,
    network_profile: Option<NetworkProfile>,
    net: crate::net::NetConfig,
    policy: Option<crate::serve::policy::PolicyConfig>,
    clock: ClockKind,
    arrival_seed: Option<u64>,
    sim_engine: SimEngine,
    trace: Tracer,
    connect: Option<String>,
}

impl ServeBuilder {
    pub fn new(dataset: impl Into<String>) -> Self {
        Self {
            artifacts_dir: default_artifacts_dir(),
            dataset: dataset.into(),
            scheme: Scheme::Agile,
            backend: BackendKind::default(),
            fleet: FleetConfig::default(),
            batch: crate::config::BatchConfig::default(),
            arrival: Arrival::Periodic { hz: 1e9 },
            bits: 4,
            alpha: None,
            device_profile: None,
            network_profile: None,
            net: crate::net::NetConfig::default(),
            policy: None,
            clock: ClockKind::Wall,
            arrival_seed: None,
            sim_engine: SimEngine::default(),
            trace: Tracer::off(),
            connect: None,
        }
    }

    /// Edit the fleet topology / control-plane group in place:
    /// `.fleet(|f| { f.devices = 64; f.servers = 4; })`.
    pub fn fleet(mut self, edit: impl FnOnce(&mut FleetConfig)) -> Self {
        edit(&mut self.fleet);
        self
    }

    /// Edit the dynamic-batcher group in place:
    /// `.batch(|b| { b.max_batch = 4; b.deadline_us = 500; })`.
    pub fn batch(mut self, edit: impl FnOnce(&mut crate::config::BatchConfig)) -> Self {
        edit(&mut self.batch);
        self
    }

    /// Edit the channel group in place:
    /// `.net(|n| { n.loss = GilbertElliott::uniform(0.3); n.seed = 7; })`.
    pub fn net(mut self, edit: impl FnOnce(&mut crate::net::NetConfig)) -> Self {
        edit(&mut self.net);
        self
    }

    /// Enable the per-request adaptive split/rate policy
    /// ([`crate::serve::policy`]). The candidate widths are validated
    /// against the manifest's exported codebooks before serving starts.
    pub fn policy(mut self, policy: crate::serve::policy::PolicyConfig) -> Self {
        self.policy = Some(policy);
        self
    }

    /// Artifacts directory (default: `$AGILENN_ARTIFACTS` or `./artifacts`).
    pub fn artifacts_dir(mut self, dir: impl Into<PathBuf>) -> Self {
        self.artifacts_dir = dir.into();
        self
    }

    /// Serving scheme; every scheme runs through the same batched pipeline.
    pub fn scheme(mut self, scheme: Scheme) -> Self {
        self.scheme = scheme;
        self
    }

    /// Inference backend (default: PJRT over the artifacts tree).
    /// [`BackendKind::Reference`] swaps in the pure-Rust deterministic
    /// model family plus a synthetic in-memory dataset
    /// ([`crate::fixtures::SyntheticSpec`]) — no artifacts directory, no
    /// `pjrt` cargo feature, same pipeline.
    pub fn backend(mut self, backend: BackendKind) -> Self {
        self.backend = backend;
        self
    }

    /// Number of concurrent simulated sensor devices.
    #[deprecated(note = "grouped configs: use .fleet(|f| f.devices = n)")]
    pub fn devices(mut self, n: usize) -> Self {
        self.fleet.devices = n;
        self
    }

    /// Total requests, assigned round-robin across devices.
    #[deprecated(note = "grouped configs: use .fleet(|f| f.requests = n)")]
    pub fn requests(mut self, n: usize) -> Self {
        self.fleet.requests = n;
        self
    }

    /// Per-device inter-arrival process.
    pub fn arrival(mut self, arrival: Arrival) -> Self {
        self.arrival = arrival;
        self
    }

    /// Convenience: Poisson arrivals at `hz` per device, or unpaced
    /// (back-to-back) when `hz <= 0`. The base seed (42 unless
    /// [`ServeBuilder::arrival_seed`] overrides it) is decorrelated per
    /// device at stream time via [`Arrival::for_device`].
    pub fn rate_hz(mut self, hz: f64) -> Self {
        self.arrival = if hz > 0.0 {
            Arrival::Poisson { hz, seed: 42 }
        } else {
            Arrival::Periodic { hz: 1e9 }
        };
        self
    }

    /// Base seed for the per-device Poisson arrival streams (overrides the
    /// seed carried by [`ServeBuilder::arrival`] / [`ServeBuilder::rate_hz`];
    /// no-op for periodic arrivals).
    pub fn arrival_seed(mut self, seed: u64) -> Self {
        self.arrival_seed = Some(seed);
        self
    }

    /// Which clock drives the pipeline: [`ClockKind::Wall`] (default,
    /// real sleeps and live latencies) or [`ClockKind::Sim`] (discrete-
    /// event virtual time — no sleeps, seed-deterministic latencies, load
    /// sweeps at CPU speed).
    pub fn clock(mut self, clock: ClockKind) -> Self {
        self.clock = clock;
        self
    }

    /// Number of remote servers, each with its own batch queue (default
    /// 1). `servers > 1` requires the sim clock's event engine — the
    /// threaded paths reject it at `stream()`.
    #[deprecated(note = "grouped configs: use .fleet(|f| f.servers = n)")]
    pub fn servers(mut self, n: usize) -> Self {
        self.fleet.servers = n;
        self
    }

    /// Device→server placement policy for multi-server topologies
    /// (default: [`Placement::Static`], `server = device % servers`).
    #[deprecated(note = "grouped configs: use .fleet(|f| f.placement = p)")]
    pub fn placement(mut self, placement: Placement) -> Self {
        self.fleet.placement = placement;
        self
    }

    /// How [`ClockKind::Sim`] executes (default: the single-threaded
    /// discrete-event [`SimEngine::Event`] fleet engine; the legacy
    /// [`SimEngine::Threads`] fabric is the bitwise-equivalence oracle).
    /// No effect on the wall clock.
    pub fn sim_engine(mut self, engine: SimEngine) -> Self {
        self.sim_engine = engine;
        self
    }

    /// Per-batch virtual service-time pricing for the event engine's
    /// remote phase: each dispatched batch holds its shard for
    /// `(base_s + per_sample_s · batch_size) / capacity` virtual seconds,
    /// and batches on one shard serialize — so offered load beyond a
    /// shard's capacity shows up as unbounded queue wait, the signal the
    /// autoscale controller watches. The default zero model keeps the
    /// engine timeline bit-identical to the unpriced engine. Sim event
    /// engine only; see [`ServiceModel`].
    #[deprecated(note = "grouped configs: use .fleet(|f| { f.service.base_s = ..; f.service.per_sample_s = ..; })")]
    pub fn service_model(mut self, base_s: f64, per_sample_s: f64) -> Self {
        self.fleet.service.base_s = base_s;
        self.fleet.service.per_sample_s = per_sample_s;
        self
    }

    /// Per-server capacity weights: a shard's service time divides by its
    /// weight, and [`Placement::WeightedLeastLoaded`] divides its load by
    /// it. Servers beyond the vector weigh 1.0.
    #[deprecated(note = "grouped configs: use .fleet(|f| f.service.capacities = w)")]
    pub fn capacities(mut self, weights: Vec<f64>) -> Self {
        self.fleet.service.capacities = weights;
        self
    }

    /// Enable the autoscale SLO control plane ([`AutoscaleConfig`]): the
    /// `fleet.servers` count becomes the *initial* active set,
    /// grown/shrunk by the controller within `[min_servers, max_servers]`.
    /// Sim event engine only; see `docs/serving.md`, "Autoscaling & SLO
    /// control".
    #[deprecated(note = "grouped configs: use .fleet(|f| f.autoscale = Some(cfg))")]
    pub fn autoscale(mut self, cfg: AutoscaleConfig) -> Self {
        self.fleet.autoscale = Some(cfg);
        self
    }

    /// End-to-end p99 latency SLO target, seconds, for the report's
    /// SLO-attainment accounting (`slo_attainment` = fraction of requests
    /// finishing within this bound). 0 (the default) disables it.
    #[deprecated(note = "grouped configs: use .fleet(|f| f.slo_p99_s = s)")]
    pub fn slo_p99(mut self, slo_s: f64) -> Self {
        self.fleet.slo_p99_s = slo_s;
        self
    }

    /// Serve against a remote daemon (`agilenn serve --listen <addr>`)
    /// over TCP instead of an in-process server half: every device opens
    /// its own connection and speaks the versioned wire envelope
    /// ([`crate::net::wire`]). Wall clock only — the run is rejected with
    /// a typed [`ConfigError`] otherwise. The report's server-side batch
    /// accounting (`shards`, `batches`) lives in the daemon's summary, not
    /// the client report; every device-side deterministic field is
    /// bit-identical to an in-process run of the same config (see
    /// `docs/daemon.md`).
    pub fn connect(mut self, addr: impl Into<String>) -> Self {
        self.connect = Some(addr.into());
        self
    }

    /// Attach a trace sink receiving the typed request-lifecycle events
    /// (arrival → encode → radio wait → per-packet uplink → server queue
    /// → batch dispatch → remote → downlink → done) plus fleet-level
    /// events, stamped with the run's clock. Default: tracing off — a
    /// single branch per would-be event. See [`crate::obs`].
    pub fn trace_sink(mut self, sink: Arc<dyn TraceSink>) -> Self {
        self.trace = Tracer::new(sink);
        self
    }

    /// Dynamic batcher: max batch (must be an exported remote batch size).
    #[deprecated(note = "grouped configs: use .batch(|b| b.max_batch = n)")]
    pub fn max_batch(mut self, b: usize) -> Self {
        self.batch.max_batch = b;
        self
    }

    /// Dynamic batcher: max queueing delay before dispatch.
    #[deprecated(note = "grouped configs: use .batch(|b| b.deadline_us = us)")]
    pub fn batch_deadline_us(mut self, us: u64) -> Self {
        self.batch.deadline_us = us;
        self
    }

    /// Quantizer bit width for transmitted features.
    pub fn bits(mut self, bits: u32) -> Self {
        self.bits = bits;
        self
    }

    /// Override the trained alpha (AgileNN §3.3 runtime re-weighting).
    pub fn alpha(mut self, alpha: f64) -> Self {
        self.alpha = Some(alpha);
        self
    }

    /// Device cost-model profile (default: STM32F746).
    pub fn device_profile(mut self, profile: DeviceProfile) -> Self {
        self.device_profile = Some(profile);
        self
    }

    /// Wireless-link profile (default: 6 Mbps WiFi).
    pub fn network_profile(mut self, profile: NetworkProfile) -> Self {
        self.network_profile = Some(profile);
        self
    }

    /// Packet-loss process on the uplink channel (default: lossless).
    #[deprecated(note = "grouped configs: use .net(|n| n.loss = loss)")]
    pub fn loss(mut self, loss: GilbertElliott) -> Self {
        self.net.loss = loss;
        self
    }

    /// Convenience: independent (Bernoulli) packet loss at `rate`.
    #[deprecated(note = "grouped configs: use .net(|n| n.loss = GilbertElliott::uniform(rate))")]
    pub fn loss_rate(mut self, rate: f64) -> Self {
        self.net.loss = GilbertElliott::uniform(rate);
        self
    }

    /// Replayable time-varying bandwidth trace (default: constant profile
    /// bandwidth).
    #[deprecated(note = "grouped configs: use .net(|n| n.trace = Some(trace))")]
    pub fn bandwidth_trace(mut self, trace: BandwidthTrace) -> Self {
        self.net.trace = Some(trace);
        self
    }

    /// Uplink delivery policy: ARQ (default) or deadline-bounded anytime.
    #[deprecated(note = "grouped configs: use .net(|n| n.delivery = policy)")]
    pub fn delivery(mut self, policy: DeliveryPolicy) -> Self {
        self.net.delivery = policy;
        self
    }

    /// Packet ordering for the anytime transport (default: importance).
    #[deprecated(note = "grouped configs: use .net(|n| n.order = order)")]
    pub fn packet_order(mut self, order: PacketOrder) -> Self {
        self.net.order = order;
        self
    }

    /// Max application bytes per anytime packet, header included
    /// (default: link MTU).
    #[deprecated(note = "grouped configs: use .net(|n| n.packet_payload = Some(bytes))")]
    pub fn packet_payload(mut self, bytes: usize) -> Self {
        self.net.packet_payload = Some(bytes);
        self
    }

    /// Seed for the channel's loss process; all stochastic link behavior
    /// is deterministic given this seed.
    #[deprecated(note = "grouped configs: use .net(|n| n.seed = seed)")]
    pub fn net_seed(mut self, seed: u64) -> Self {
        self.net.seed = seed;
        self
    }

    /// The [`RunConfig`] this builder resolves to (without touching disk).
    pub fn to_config(&self) -> RunConfig {
        let mut cfg = RunConfig::new(self.artifacts_dir.clone(), &self.dataset, self.scheme);
        cfg.backend = self.backend;
        cfg.bits = self.bits;
        cfg.alpha_override = self.alpha;
        cfg.batch = self.batch.clone();
        cfg.policy = self.policy.clone();
        if let Some(p) = &self.device_profile {
            cfg.device = p.clone();
        }
        if let Some(p) = &self.network_profile {
            cfg.network = p.clone();
        }
        cfg.net = self.net.clone();
        cfg
    }

    /// Rebuild a builder from a [`RunConfig`] — the inverse of
    /// [`ServeBuilder::to_config`]: `from_config(b.to_config()).to_config()
    /// == b.to_config()` for every builder (property-tested). Fleet/arrival
    /// knobs live outside `RunConfig` and come back as defaults.
    pub fn from_config(cfg: RunConfig) -> Self {
        let mut b = Self::new(&cfg.dataset);
        b.artifacts_dir = cfg.artifacts_dir.clone();
        b.scheme = cfg.scheme;
        b.backend = cfg.backend;
        b.bits = cfg.bits;
        b.alpha = cfg.alpha_override;
        b.batch = cfg.batch.clone();
        b.policy = cfg.policy.clone();
        b.device_profile = Some(cfg.device.clone());
        b.network_profile = Some(cfg.network.clone());
        b.net = cfg.net.clone();
        b
    }

    /// Assemble the [`Service`]: load the trained metadata + test set
    /// from the artifacts tree (PJRT), or fabricate the synthetic world
    /// in memory (reference backend — no artifacts directory needed, and
    /// [`ServeBuilder::artifacts_dir`] is ignored).
    pub fn build(self) -> Result<Service> {
        let cfg = self.to_config();
        let (meta, testset) = crate::fixtures::load_world(&cfg)?;
        self.build_with_world(meta, Arc::new(testset))
    }

    /// Assemble the [`Service`] against an already-loaded world. Batch
    /// evaluators (the autotuner) load `Meta` + test set once and reuse
    /// them across hundreds of configurations instead of paying
    /// `load_world` per point.
    pub fn build_with_world(self, meta: Meta, testset: Arc<TestSet>) -> Result<Service> {
        let cfg = self.to_config();
        let arrival = match self.arrival_seed {
            Some(seed) => self.arrival.with_seed(seed),
            None => self.arrival,
        };
        Ok(
            Service::from_parts(cfg, meta, testset, self.fleet.devices, self.fleet.requests, arrival)?
                .with_clock(self.clock)
                .with_servers(self.fleet.servers, self.fleet.placement)
                .with_sim_engine(self.sim_engine)
                .with_service_model(self.fleet.service)
                .with_autoscale(self.fleet.autoscale)
                .with_slo_p99(self.fleet.slo_p99_s)
                .with_tracer(self.trace)
                .with_connect(self.connect),
        )
    }

    /// Resolve the pieces the serving daemon needs: the run configuration
    /// (scheme, backend, bits, batcher knobs) and the trace handle. The
    /// client-only knobs (devices, arrival, channel) are simply unused on
    /// the daemon side.
    pub(crate) fn daemon_parts(self) -> (RunConfig, Tracer) {
        (self.to_config(), self.trace)
    }
}

/// A fully-assembled serving setup, ready to run (or stream).
pub struct Service {
    cfg: RunConfig,
    meta: Meta,
    testset: Arc<TestSet>,
    devices: usize,
    requests: usize,
    arrival: Arrival,
    clock: ClockKind,
    servers: usize,
    placement: Placement,
    sim_engine: SimEngine,
    service_model: ServiceModel,
    autoscale: Option<AutoscaleConfig>,
    slo_p99_s: f64,
    tracer: Tracer,
    connect: Option<String>,
}

impl Service {
    /// Assemble a service from already-loaded parts ([`ServeBuilder::build`]
    /// loads them from the artifacts tree; sweeps that cache `Meta`/test
    /// sets use this directly). Runs on the wall clock unless
    /// [`Service::with_clock`] says otherwise.
    pub fn from_parts(
        cfg: RunConfig,
        meta: Meta,
        testset: Arc<TestSet>,
        devices: usize,
        requests: usize,
        arrival: Arrival,
    ) -> Result<Self> {
        if devices < 1 {
            return Err(ConfigError::NoDevices.into());
        }
        if requests < 1 {
            return Err(ConfigError::NoRequests.into());
        }
        if testset.is_empty() {
            return Err(ConfigError::EmptyTestSet.into());
        }
        Ok(Self {
            cfg,
            meta,
            testset,
            devices,
            requests,
            arrival,
            clock: ClockKind::Wall,
            servers: 1,
            placement: Placement::default(),
            sim_engine: SimEngine::default(),
            service_model: ServiceModel::default(),
            autoscale: None,
            slo_p99_s: 0.0,
            tracer: Tracer::off(),
            connect: None,
        })
    }

    /// Select the clock driving the run (default: wall).
    pub fn with_clock(mut self, clock: ClockKind) -> Self {
        self.clock = clock;
        self
    }

    /// Select the server topology (default: one server, static placement).
    pub fn with_servers(mut self, servers: usize, placement: Placement) -> Self {
        self.servers = servers;
        self.placement = placement;
        self
    }

    /// Select the sim execution engine (default: the event engine).
    pub fn with_sim_engine(mut self, engine: SimEngine) -> Self {
        self.sim_engine = engine;
        self
    }

    /// Set the per-batch virtual service-time model (default: zero); see
    /// [`ServeBuilder::service_model`].
    pub fn with_service_model(mut self, model: ServiceModel) -> Self {
        self.service_model = model;
        self
    }

    /// Enable the autoscale control plane (default: off); see
    /// [`ServeBuilder::autoscale`].
    pub fn with_autoscale(mut self, autoscale: Option<AutoscaleConfig>) -> Self {
        self.autoscale = autoscale;
        self
    }

    /// Set the p99 latency SLO target for attainment accounting
    /// (default: 0 = unset); see [`ServeBuilder::slo_p99`].
    pub fn with_slo_p99(mut self, slo_s: f64) -> Self {
        self.slo_p99_s = slo_s;
        self
    }

    /// Attach a trace handle (default: [`Tracer::off`]); see
    /// [`ServeBuilder::trace_sink`].
    pub fn with_tracer(mut self, tracer: Tracer) -> Self {
        self.tracer = tracer;
        self
    }

    /// Serve against a remote daemon instead of an in-process server half
    /// (default: `None`); see [`ServeBuilder::connect`].
    pub fn with_connect(mut self, connect: Option<String>) -> Self {
        self.connect = connect;
        self
    }

    pub fn config(&self) -> &RunConfig {
        &self.cfg
    }

    pub fn meta(&self) -> &Meta {
        &self.meta
    }

    /// Run to completion and return the aggregate report.
    pub fn run(self) -> Result<PipelineReport> {
        self.stream()?.finish()
    }

    /// Check the configuration for conflicts without starting anything.
    /// [`Service::stream`] calls this first, so every rejection here is a
    /// typed [`ConfigError`] raised from the calling thread — never a
    /// panic inside a spawned worker (the pre-tuner behavior for e.g.
    /// `max_batch(3)`).
    pub fn validate(&self) -> std::result::Result<(), ConfigError> {
        if self.servers < 1 {
            return Err(ConfigError::NoServers);
        }
        if !REMOTE_BATCH_SIZES.contains(&self.cfg.batch.max_batch) {
            return Err(ConfigError::UnsupportedMaxBatch { max_batch: self.cfg.batch.max_batch });
        }
        let on_engine = self.clock == ClockKind::Sim && self.sim_engine == SimEngine::Event;
        if self.servers > 1 && !on_engine {
            return Err(ConfigError::MultiServerNeedsEventEngine {
                servers: self.servers,
                clock: self.clock,
                engine: self.sim_engine,
            });
        }
        if self.connect.is_some() {
            if self.clock != ClockKind::Wall {
                return Err(ConfigError::RemoteNeedsWallClock { clock: self.clock });
            }
            if self.servers > 1 {
                return Err(ConfigError::RemoteConflictsWithServers { servers: self.servers });
            }
        }
        if let Err(reason) = self.service_model.validate() {
            return Err(ConfigError::InvalidServiceModel { reason });
        }
        if !self.service_model.is_zero() && !on_engine {
            return Err(ConfigError::InvalidServiceModel {
                reason: format!(
                    "a non-zero service model requires the sim clock's event engine \
                     (clock sim + sim-engine event), not {} clock / {} engine",
                    self.clock.name(),
                    self.sim_engine.name()
                ),
            });
        }
        if !self.slo_p99_s.is_finite() || self.slo_p99_s < 0.0 {
            return Err(ConfigError::InvalidAutoscale {
                reason: format!("slo_p99 must be finite and >= 0, got {}", self.slo_p99_s),
            });
        }
        if let Some(a) = &self.autoscale {
            if !on_engine {
                return Err(ConfigError::AutoscaleNeedsEventEngine {
                    clock: self.clock,
                    engine: self.sim_engine,
                });
            }
            if let Err(reason) = a.validate(self.servers) {
                return Err(ConfigError::InvalidAutoscale { reason });
            }
        }
        let quantizes = matches!(self.cfg.scheme, Scheme::Agile | Scheme::Deepcod | Scheme::Spinn);
        if let Some(p) = &self.cfg.policy {
            if let Err(reason) = p.validate() {
                return Err(ConfigError::InvalidPolicy { reason });
            }
            if !quantizes {
                return Err(ConfigError::InvalidPolicy {
                    reason: format!(
                        "{} does not quantize features; the adaptive policy has no width actuator",
                        self.cfg.scheme.name()
                    ),
                });
            }
            if self.connect.is_some() {
                return Err(ConfigError::InvalidPolicy {
                    reason: "a remote daemon pins one bit width at the handshake; \
                             run the policy in-process"
                        .into(),
                });
            }
            if p.local_fallback && !matches!(self.cfg.scheme, Scheme::Agile | Scheme::Spinn) {
                return Err(ConfigError::InvalidPolicy {
                    reason: format!(
                        "{} has no on-device classification head, so local_fallback \
                         cannot resolve requests locally",
                        self.cfg.scheme.name()
                    ),
                });
            }
        }
        // every width the run can transmit at — the static `bits` plus the
        // policy's candidate ladder — must have an exported codebook
        if quantizes {
            let available = self.meta.codebook_widths(self.cfg.scheme);
            for w in self.cfg.candidate_widths() {
                if !available.contains(&w) {
                    return Err(ConfigError::UnsupportedBits {
                        bits: w,
                        scheme: self.cfg.scheme,
                        available,
                    });
                }
            }
        }
        Ok(())
    }

    /// Start the pipeline and return a streaming handle over per-request
    /// outcomes. Dropping the stream without `finish()` is safe: device
    /// threads stop producing once the receiver is gone and every worker
    /// winds down.
    ///
    /// Routing: the sim clock runs on the single-threaded discrete-event
    /// fleet engine ([`SimEngine::Event`], bitwise-equivalent to the
    /// threaded fabric) unless [`Service::with_sim_engine`] opts back into
    /// threads; the wall clock always runs the threaded pipeline.
    /// Multi-server topologies (`servers > 1`) exist only on the engine.
    pub fn stream(self) -> Result<OutcomeStream> {
        self.validate()?;
        let use_engine = self.clock == ClockKind::Sim && self.sim_engine == SimEngine::Event;
        if use_engine {
            return self.stream_engine();
        }
        let backend: Arc<dyn Backend> = make_backend(&self.cfg, &self.meta)?;
        if self.connect.is_some() {
            return self.stream_remote(backend);
        }
        let server = make_server_side(backend.as_ref(), &self.cfg, &self.meta)?;
        // some schemes export fewer remote batch sizes (edge-only: max 4)
        let max_batch = match &server {
            Some(s) => self.cfg.batch.max_batch.min(s.max_batch()),
            None => self.cfg.batch.max_batch,
        };
        let deadline_s = self.cfg.batch.deadline_s();
        // the sim clock must know every participant up front — a thread
        // that registers late could otherwise watch time advance past it
        let clock = match self.clock {
            ClockKind::Wall => Clock::wall(),
            ClockKind::Sim => Clock::sim(self.devices + server.is_some() as usize),
        };

        // live batch-queue depth, published by the server loop and read
        // back through Transport::queue_depth
        let depth = Arc::new(AtomicUsize::new(0));
        let (tx_offload, server_handle) = match server {
            Some(server) => {
                let (tx, rx) = channel::<OffloadMsg>();
                let clock = clock.clone();
                let tracer = self.tracer.clone();
                let depth = depth.clone();
                let handle = std::thread::spawn(move || {
                    server_loop(server, rx, max_batch, deadline_s, clock, tracer, depth)
                });
                (Some(tx), Some(handle))
            }
            None => (None, None),
        };

        let (tx_done, rx_done) = channel::<ServedOutcome>();
        let mut device_handles = Vec::new();
        for d in 0..self.devices {
            let cfg = self.cfg.clone();
            let meta = self.meta.clone();
            let backend = backend.clone();
            let testset = self.testset.clone();
            let transport: Option<Box<dyn Transport>> = tx_offload.as_ref().map(|tx| {
                Box::new(ChannelTransport::new(tx.clone(), clock.clone(), depth.clone()))
                    as Box<dyn Transport>
            });
            let tx_done = tx_done.clone();
            let clock = clock.clone();
            let tracer = self.tracer.clone();
            // break exact cross-device event-time ties deterministically:
            // lockstep periodic sensors get a vanishing per-device phase
            // of (device index) ppm of the period, so the server never
            // has to race two offloads sent at the bit-identical virtual
            // instant. Scaling by the period keeps the phase off the
            // arrival grid at every rate (a fixed offset would collide
            // with the unpaced 1e9 Hz grid); Poisson streams are already
            // decorrelated by for_device. One implementation with the
            // event engine (`device_schedule`), so the paths agree bitwise.
            let (ids, times) = device_schedule(&self.arrival, self.devices, self.requests, d);
            device_handles.push(std::thread::spawn(move || {
                device_loop(
                    d,
                    backend.as_ref(),
                    &cfg,
                    &meta,
                    &testset,
                    &ids,
                    &times,
                    transport,
                    tx_done,
                    clock,
                    tracer,
                )
            }));
        }
        drop(tx_offload);
        drop(tx_done);

        Ok(OutcomeStream {
            rx: rx_done,
            handle: RunHandle::Threads { device_handles, server_handle, clock },
            agg: StreamAgg::with_slo(self.slo_p99_s),
        })
    }

    /// The remote path: every device opens its own [`TcpTransport`] to the
    /// daemon named by [`ServeBuilder::connect`] and runs the identical
    /// `device_loop` — same simulated channel, same schedule, same
    /// outcome assembly — so every device-side deterministic report field
    /// is bit-equal to an in-process run of the same config. The server
    /// half (and its batch accounting) lives in the daemon.
    fn stream_remote(self, backend: Arc<dyn Backend>) -> Result<OutcomeStream> {
        let addr = self.connect.clone().expect("stream_remote requires connect");
        let clock = Clock::wall();
        let hello = Hello {
            dataset: self.cfg.dataset.clone(),
            scheme: self.cfg.scheme.name().to_string(),
            bits: self.cfg.bits,
        };
        // connect every device up front so handshake rejections (version,
        // scheme, bit-width mismatches) surface from stream(), typed, not
        // from inside a spawned worker
        let mut transports = Vec::with_capacity(self.devices);
        for _ in 0..self.devices {
            let t = TcpTransport::connect(&addr, &hello)?;
            ensure!(
                t.num_classes() == self.meta.num_classes,
                "daemon at {addr} serves {} classes, this client's world has {}",
                t.num_classes(),
                self.meta.num_classes
            );
            transports.push(t);
        }
        let (tx_done, rx_done) = channel::<ServedOutcome>();
        let mut device_handles = Vec::new();
        for (d, transport) in transports.into_iter().enumerate() {
            let cfg = self.cfg.clone();
            let meta = self.meta.clone();
            let backend = backend.clone();
            let testset = self.testset.clone();
            let tx_done = tx_done.clone();
            let clock = clock.clone();
            let tracer = self.tracer.clone();
            let (ids, times) = device_schedule(&self.arrival, self.devices, self.requests, d);
            device_handles.push(std::thread::spawn(move || {
                device_loop(
                    d,
                    backend.as_ref(),
                    &cfg,
                    &meta,
                    &testset,
                    &ids,
                    &times,
                    Some(Box::new(transport) as Box<dyn Transport>),
                    tx_done,
                    clock,
                    tracer,
                )
            }));
        }
        drop(tx_done);
        Ok(OutcomeStream {
            rx: rx_done,
            handle: RunHandle::Threads { device_handles, server_handle: None, clock },
            agg: StreamAgg::with_slo(self.slo_p99_s),
        })
    }

    /// The event-engine path: one background thread runs the whole fleet
    /// and streams outcomes through the same channel the threaded path
    /// uses, so `OutcomeStream` consumers cannot tell them apart.
    fn stream_engine(self) -> Result<OutcomeStream> {
        // resolve the backend up front so configuration errors surface
        // from stream() rather than at finish()
        let backend: Arc<dyn Backend> = make_backend(&self.cfg, &self.meta)?;
        let (tx_done, rx_done) = channel::<ServedOutcome>();
        let slo_p99_s = self.slo_p99_s;
        let spec = FleetSpec {
            devices: self.devices,
            requests: self.requests,
            arrival: self.arrival,
            servers: self.servers,
            placement: self.placement,
            service: self.service_model.clone(),
            autoscale: self.autoscale.clone(),
        };
        let tracer = self.tracer.clone();
        let handle = std::thread::spawn(move || {
            engine::run_fleet(
                backend.as_ref(),
                &self.cfg,
                &self.meta,
                &self.testset,
                &spec,
                &tx_done,
                &tracer,
            )
        });
        Ok(OutcomeStream {
            rx: rx_done,
            handle: RunHandle::Engine { handle },
            agg: StreamAgg::with_slo(slo_p99_s),
        })
    }
}

/// Aggregated transport counters across a run.
#[derive(Debug, Default)]
struct NetAgg {
    packets_sent: u64,
    packets_lost: u64,
    retransmit_rounds: u64,
    incomplete_frames: usize,
    features_total: u64,
    features_delivered: u64,
    bytes_delivered: u64,
    airtime_s: f64,
    radio_wait_s: f64,
    /// requests that actually produced an uplink (denominator for the
    /// per-uplink radio-wait mean)
    uplinks: usize,
}

impl NetAgg {
    fn record(&mut self, out: &RequestOutcome) {
        let s = &out.net;
        self.uplinks += (out.tx_bytes > 0) as usize;
        self.packets_sent += s.packets_sent as u64;
        self.packets_lost += s.packets_lost as u64;
        self.retransmit_rounds += s.retransmit_rounds as u64;
        self.incomplete_frames += (out.tx_bytes > 0 && !s.complete) as usize;
        self.features_total += s.features_total as u64;
        self.features_delivered += s.features_delivered as u64;
        self.bytes_delivered += s.app_bytes_delivered as u64;
        self.airtime_s += s.airtime_s;
        self.radio_wait_s += s.radio_wait_s;
    }

    fn delivered_feature_rate(&self) -> f64 {
        if self.features_total == 0 {
            1.0
        } else {
            self.features_delivered as f64 / self.features_total as f64
        }
    }

    fn goodput_bps(&self) -> f64 {
        if self.airtime_s <= 0.0 {
            0.0
        } else {
            self.bytes_delivered as f64 * 8.0 / self.airtime_s
        }
    }
}

/// Per-run metric accumulation behind [`OutcomeStream`]: typed fields on
/// the hot path (no name lookups per request), folded into the
/// [`MetricsRegistry`] once at finish. The four `phase_*` histograms are
/// the per-phase latency breakdown surfaced by `serve --metrics-out` and
/// `bench --figure breakdown`.
#[derive(Debug, Default)]
struct StreamAgg {
    acc: AccuracyCounter,
    lat: Histogram,
    net_lat: Histogram,
    phase_local_nn: Histogram,
    phase_compression: Histogram,
    phase_network: Histogram,
    phase_remote: Histogram,
    net: NetAgg,
    /// configured p99 latency SLO (0 = unset); requests at or under it
    /// count into `within_slo`
    slo_p99_s: f64,
    within_slo: u64,
    /// true once any outcome carried a policy decision; gates the policy
    /// registry entries so policy-off registries stay byte-identical
    policy_seen: bool,
    policy_switches: u64,
    policy_local: u64,
    policy_bits_sum: u64,
    policy_uplinks: u64,
    policy_widths: std::collections::BTreeMap<u32, u64>,
}

impl StreamAgg {
    fn with_slo(slo_p99_s: f64) -> Self {
        Self { slo_p99_s, ..Self::default() }
    }

    fn record(&mut self, out: &ServedOutcome) {
        self.acc.record(out.outcome.correct);
        self.lat.record(out.wall_s);
        if self.slo_p99_s > 0.0 && out.wall_s <= self.slo_p99_s {
            self.within_slo += 1;
        }
        if let Some(p) = &out.policy {
            self.policy_seen = true;
            self.policy_switches += p.switched as u64;
            if p.local_only {
                self.policy_local += 1;
            } else {
                self.policy_bits_sum += p.bits as u64;
                self.policy_uplinks += 1;
                *self.policy_widths.entry(p.bits).or_insert(0) += 1;
            }
        }
        let b = &out.outcome.breakdown;
        self.net_lat.record(b.network_s);
        self.phase_local_nn.record(b.local_nn_s);
        self.phase_compression.record(b.compression_s);
        self.phase_network.record(b.network_s);
        self.phase_remote.record(b.remote_s);
        self.net.record(&out.outcome);
    }

    /// Fold the typed accumulators into named registry entries (see
    /// `docs/observability.md` for the vocabulary).
    fn into_registry(self, batches: usize, batched: usize) -> MetricsRegistry {
        let mut m = MetricsRegistry::new();
        m.counter_add("requests_total", self.acc.total as u64);
        m.counter_add("requests_correct", self.acc.correct as u64);
        m.counter_add("uplinks", self.net.uplinks as u64);
        m.counter_add("incomplete_frames", self.net.incomplete_frames as u64);
        m.counter_add("packets_sent", self.net.packets_sent);
        m.counter_add("packets_lost", self.net.packets_lost);
        m.counter_add("retransmit_rounds", self.net.retransmit_rounds);
        m.counter_add("features_total", self.net.features_total);
        m.counter_add("features_delivered", self.net.features_delivered);
        m.counter_add("bytes_delivered", self.net.bytes_delivered);
        m.counter_add("batches", batches as u64);
        m.counter_add("batched_requests", batched as u64);
        m.counter_add("requests_within_slo", self.within_slo);
        m.sum_add("airtime_s", self.net.airtime_s);
        m.sum_add("radio_wait_s", self.net.radio_wait_s);
        m.sum_add("slo_p99_s", self.slo_p99_s);
        m.insert_hist("latency_s", self.lat);
        m.insert_hist("net_s", self.net_lat);
        m.insert_hist("phase_local_nn_s", self.phase_local_nn);
        m.insert_hist("phase_compression_s", self.phase_compression);
        m.insert_hist("phase_network_s", self.phase_network);
        m.insert_hist("phase_remote_s", self.phase_remote);
        // policy entries exist only when the policy ran, so a policy-off
        // registry (and everything derived from it) is byte-identical to
        // the pre-policy pipeline's
        if self.policy_seen {
            m.counter_add("policy_enabled", 1);
            m.counter_add("policy_switches", self.policy_switches);
            m.counter_add("policy_local_only", self.policy_local);
            m.counter_add("policy_bits_sum", self.policy_bits_sum);
            m.counter_add("policy_uplinks", self.policy_uplinks);
            for (w, n) in self.policy_widths {
                m.counter_add(POLICY_WIDTH_COUNTERS[(w - 1) as usize], n);
            }
        }
        m
    }
}

/// Streaming handle over a running [`Service`]: iterate per-request
/// outcomes as devices finish them, then call [`OutcomeStream::finish`]
/// for the aggregate [`PipelineReport`] (or
/// [`OutcomeStream::finish_full`] for the report plus the full
/// [`MetricsRegistry`]).
pub struct OutcomeStream {
    rx: Receiver<ServedOutcome>,
    handle: RunHandle,
    agg: StreamAgg,
}

/// The worker fabric behind an [`OutcomeStream`]: the threaded pipeline
/// (wall clock or legacy sim fabric) or the event engine's run thread.
enum RunHandle {
    Threads {
        device_handles: Vec<JoinHandle<Result<()>>>,
        server_handle: Option<JoinHandle<ShardAgg>>,
        clock: Clock,
    },
    Engine {
        handle: JoinHandle<Result<engine::EngineRun>>,
    },
}

impl Iterator for OutcomeStream {
    type Item = ServedOutcome;

    fn next(&mut self) -> Option<ServedOutcome> {
        match self.rx.recv() {
            Ok(out) => {
                self.agg.record(&out);
                Some(out)
            }
            Err(_) => None,
        }
    }
}

impl OutcomeStream {
    /// Drain any remaining outcomes, join the worker threads (or the
    /// engine thread), and return the aggregate report. Worker errors
    /// surface here.
    pub fn finish(self) -> Result<PipelineReport> {
        Ok(self.finish_full()?.0)
    }

    /// Like [`OutcomeStream::finish`], additionally returning the full
    /// [`MetricsRegistry`] the report is a view over — including the
    /// per-phase breakdown histograms (`phase_*_s`) that have no report
    /// field. This is what `serve --metrics-out` writes.
    pub fn finish_full(mut self) -> Result<(PipelineReport, MetricsRegistry)> {
        while self.next().is_some() {}
        let (clock_kind, wall, shard_aggs, scale_events) = match self.handle {
            RunHandle::Threads { device_handles, server_handle, clock } => {
                for h in device_handles {
                    h.join().map_err(|_| anyhow!("device thread panicked"))??;
                }
                let aggs = match server_handle {
                    Some(h) => {
                        vec![h.join().map_err(|_| anyhow!("server thread panicked"))?]
                    }
                    None => Vec::new(),
                };
                // host seconds on the wall clock; final virtual time on
                // the sim clock (all participants have deregistered by
                // now, so this is the timestamp of the last simulated
                // event)
                (clock.kind(), clock.now(), aggs, Vec::new())
            }
            RunHandle::Engine { handle } => {
                let run = handle.join().map_err(|_| anyhow!("engine thread panicked"))??;
                (ClockKind::Sim, run.wall_s, run.shards, run.scale_events)
            }
        };
        let total_batched: usize = shard_aggs.iter().map(|a| a.batched).sum();
        let batches: usize = shard_aggs.iter().map(|a| a.batches).sum();
        let shards: Vec<ShardReport> =
            shard_aggs.into_iter().enumerate().map(|(i, a)| a.into_report(i, wall)).collect();
        // integrated fleet cost: Σ per-shard active seconds (the fixed
        // fleets' sentinel already resolved to the makespan above) — the
        // corrected basis for TuneObjectives::server_seconds
        let server_seconds: f64 = shards.iter().map(|s| s.active_s).sum();
        let scale_outs = scale_events.iter().filter(|e| e.kind == ScaleKind::Out).count();
        let scale_ins = scale_events.len() - scale_outs;
        let mut registry = self.agg.into_registry(batches, total_batched);
        registry.sum_add("server_seconds", server_seconds);
        registry.counter_add("scale_outs", scale_outs as u64);
        registry.counter_add("scale_ins", scale_ins as u64);
        let report = PipelineReport::from_registry(&mut registry, clock_kind, wall, shards);
        Ok((report, registry))
    }
}

/// Decode one uplink and enqueue it for batching (timestamped with the
/// serving clock); decode failures reply to the device immediately.
fn decode_and_enqueue(
    m: OffloadMsg,
    server: &mut dyn ServerSide,
    queue: &mut BatchQueue<BatchItem>,
    clock: &Clock,
) -> Option<Vec<Pending<BatchItem>>> {
    let decoded = match &m.body {
        UplinkBody::Whole(frame) => server.decode(frame),
        UplinkBody::Packets { packets, count, bits } => {
            server.decode_packets(packets, *count, *bits)
        }
    };
    match decoded {
        Ok(feats) => queue.push(m.id, (feats, m.reply), clock.now()),
        Err(e) => {
            send_reply(
                clock,
                &m.reply,
                Reply {
                    result: Err(RemoteFailure(format!("decoding request {}: {e:#}", m.id))),
                    queue_depth: queue.len() as u32,
                },
            );
            clock.notify();
            None
        }
    }
}

/// The shared deadline-batched server loop. Decode failures and batch
/// failures are propagated to the waiting device threads as explicit
/// [`RemoteFailure`] replies, never silently dropped.
///
/// Batch deadlines key on [`Clock::now`] timestamps: on the wall clock the
/// loop blocks in `recv_timeout` exactly as before; on the sim clock it
/// registers its next deadline with the virtual clock, which advances to
/// it once every device is likewise blocked.
///
/// `depth` is the fabric's queue-depth advertisement: the loop publishes
/// the live batch-queue length after every enqueue/dispatch so transports
/// ([`Transport::queue_depth`]) can expose it to split policies.
pub(crate) fn server_loop(
    mut server: Box<dyn ServerSide>,
    rx: Receiver<OffloadMsg>,
    max_batch: usize,
    deadline_s: f64,
    clock: Clock,
    tracer: Tracer,
    depth: Arc<AtomicUsize>,
) -> ShardAgg {
    let _participant = clock.participant();
    let lane = Lane::Server(0);
    let mut queue: BatchQueue<BatchItem> = BatchQueue::new(max_batch, deadline_s);
    let mut agg = ShardAgg::default();
    // `qlen` is the batch queue's length after this batch popped — stamped
    // onto every reply as the freshest possible depth advertisement
    let mut run_batch = |batch: Vec<Pending<BatchItem>>, server: &mut dyn ServerSide, qlen: usize| {
        let feats: Vec<_> = batch.iter().map(|p| p.payload.0.clone()).collect();
        // dispatch instant, taken before the batch executes: queue wait is
        // enqueue → dispatch on both clocks (under the sim clock virtual
        // time is frozen during inference anyway; under the wall clock a
        // post-inference read would fold remote execution into the wait)
        let dispatched = clock.now();
        match server.infer_batch(&feats) {
            Ok(rows) => {
                agg.batched += batch.len();
                agg.batches += 1;
                for p in &batch {
                    agg.queue_wait.record(dispatched - p.enqueued);
                    tracer.span(lane, EventKind::ServerQueue, p.id, p.enqueued, dispatched, 0.0);
                }
                let seq = agg.batches as u64;
                tracer.instant(lane, EventKind::BatchDispatch, seq, dispatched, feats.len() as f64);
                for (p, row) in batch.into_iter().zip(rows) {
                    send_reply(
                        &clock,
                        &p.payload.1,
                        Reply { result: Ok(row), queue_depth: qlen as u32 },
                    );
                }
            }
            Err(e) => {
                let msg = format!("remote batch of {} failed: {e:#}", batch.len());
                eprintln!("{msg}");
                for p in batch {
                    send_reply(
                        &clock,
                        &p.payload.1,
                        Reply {
                            result: Err(RemoteFailure(msg.clone())),
                            queue_depth: qlen as u32,
                        },
                    );
                }
            }
        }
        clock.notify();
    };
    if clock.is_sim() {
        loop {
            // snapshot the event counter *before* polling the channel so a
            // send landing in between cannot be slept through
            let epoch = clock.epoch();
            match rx.try_recv() {
                Ok(m) => {
                    clock.msg_received();
                    if let Some(batch) = decode_and_enqueue(m, server.as_mut(), &mut queue, &clock)
                    {
                        let qlen = queue.len();
                        run_batch(batch, server.as_mut(), qlen);
                    }
                    depth.store(queue.len(), Ordering::Relaxed);
                }
                Err(TryRecvError::Empty) => {
                    if let Some(batch) = queue.poll_deadline(clock.now()) {
                        let qlen = queue.len();
                        run_batch(batch, server.as_mut(), qlen);
                        depth.store(queue.len(), Ordering::Relaxed);
                        continue;
                    }
                    clock.wait(queue.next_deadline_at(), epoch);
                }
                Err(TryRecvError::Disconnected) => break,
            }
        }
    } else {
        loop {
            let wait = queue
                .next_deadline_in(clock.now())
                .map(Duration::from_secs_f64)
                .unwrap_or(Duration::from_secs(3600));
            match rx.recv_timeout(wait) {
                Ok(m) => {
                    if let Some(batch) = decode_and_enqueue(m, server.as_mut(), &mut queue, &clock)
                    {
                        let qlen = queue.len();
                        run_batch(batch, server.as_mut(), qlen);
                    }
                    depth.store(queue.len(), Ordering::Relaxed);
                }
                Err(RecvTimeoutError::Timeout) => {
                    if let Some(batch) = queue.poll_deadline(clock.now()) {
                        let qlen = queue.len();
                        run_batch(batch, server.as_mut(), qlen);
                        depth.store(queue.len(), Ordering::Relaxed);
                    }
                }
                Err(RecvTimeoutError::Disconnected) => break,
            }
        }
    }
    let tail = queue.flush();
    if !tail.is_empty() {
        run_batch(tail, server.as_mut(), 0);
    }
    depth.store(0, Ordering::Relaxed);
    agg
}

/// One simulated device: build the scheme's device half + fuser, pace
/// requests to the arrival process, push uplink frames through the
/// simulated channel under the configured delivery policy, and stream
/// each fused outcome.
///
/// The simulated timeline is identical under both clocks: the uplink
/// starts when the device compute is done *and* the half-duplex radio has
/// finished the previous request's exchange (`radio_free`), so one
/// device's transmissions never overlap on the air and queueing shows up
/// as `NetStats::radio_wait_s`. Under the sim clock the thread
/// additionally waits in virtual time, so the server sees each offload at
/// its simulated arrival and batch queueing becomes deterministic.
#[allow(clippy::too_many_arguments)]
fn device_loop(
    device_index: usize,
    backend: &dyn Backend,
    cfg: &RunConfig,
    meta: &Meta,
    testset: &TestSet,
    ids: &[usize],
    times: &[f64],
    transport: Option<Box<dyn Transport>>,
    done_tx: Sender<ServedOutcome>,
    clock: Clock,
    tracer: Tracer,
) -> Result<()> {
    let _participant = clock.participant();
    // Rebind the server-facing ends as locals *after* the participant
    // guard: locals drop in reverse declaration order (and parameters only
    // after all locals), so on any exit path the transport's sender and
    // the outcome sender disconnect BEFORE the guard deregisters. The
    // deregistration's epoch bump is the only thing that wakes a sim
    // server blocked in a clock wait — if the guard dropped first, the
    // server could re-block in the tiny window while the sender was still
    // live and then sleep forever.
    let mut transport = transport;
    let tx_done = done_tx;
    let mut device = make_device_side(backend, cfg, meta)?;
    let fuser = make_fuser(cfg, meta)?;
    let dev_sim = DeviceSim::new(cfg.device.clone());
    let net = NetworkSim::new(cfg.network.clone());
    let mut chan = Channel::new(
        &cfg.network,
        cfg.net.loss.clone(),
        cfg.net.trace.clone(),
        cfg.net.device_seed(device_index),
    );
    let order = match cfg.net.order {
        PacketOrder::Importance => importance_order(meta, cfg.scheme),
        PacketOrder::Index => None,
    };
    let packetizer = Packetizer::new(cfg.net.payload_cap(cfg.network.mtu), order);
    // per-device adaptive split/rate policy; `None` keeps every branch
    // below on the pre-policy code path (the bit-identity contract)
    let mut policy = cfg.policy.clone().map(DevicePolicy::new);
    // wall mode paces against a per-device anchor taken *after* model
    // loading (the pre-clock behavior: a slow init must not turn the
    // first arrivals into a past-due burst); sim mode waits in virtual
    // time on the shared clock
    let t0 = Instant::now();
    // simulated time this device's radio frees up after the previous
    // request's uplink + downlink exchange
    let mut radio_free = 0.0f64;
    for (j, &i) in ids.iter().enumerate() {
        // pace to the arrival process (real sleep or virtual wait)
        if clock.is_sim() {
            clock.sleep_until(times[j]);
        } else {
            let due = Duration::from_secs_f64(times[j]);
            if let Some(sleep_for) = due.checked_sub(t0.elapsed()) {
                std::thread::sleep(sleep_for);
            }
        }
        let req_start = Instant::now();
        let t_start = clock.now();
        let lane = Lane::Device(device_index as u32);
        let rid = i as u64;
        tracer.instant(lane, EventKind::Arrival, rid, times[j], 0.0);
        // consult the adaptive policy *before* encoding: the decision
        // picks the quantizer width for this request's uplink (or drops
        // the uplink entirely under the local-only fallback)
        let decision = policy.as_mut().map(|p| p.decide());
        if let Some(d) = &decision {
            if d.switched {
                let arg = if d.local_only { 0.0 } else { d.bits as f64 };
                tracer.instant(lane, EventKind::PolicySwitch, rid, times[j], arg);
            }
            if !d.local_only {
                device.set_bits(d.bits)?;
            }
        }
        let idx = i % testset.len();
        let img = testset.image(idx)?;
        let mut local = device.encode(&img)?;
        if decision.as_ref().is_some_and(|d| d.local_only) {
            // resolve on device: drop the uplink and its pricing — a
            // request the policy keeps local never quantizes/compresses
            local.frame = None;
            local.symbols = None;
            local.timings.quantize_s = 0.0;
            local.timings.compress_s = 0.0;
        }

        let mut remote: Option<Vec<f32>> = None;
        let mut remote_s = 0.0f64;
        let mut link: Option<LinkOutcome> = None;
        let mut tx_bytes = local.tx_bytes();
        // virtual completion time: arrival + device compute, extended by
        // the remote exchange below when the request offloads
        let mut t_done = t_start + local.timings.total_s();
        if let Some(frame) = local.frame.take() {
            let transport = transport.as_mut().ok_or_else(|| {
                anyhow!("{} produced an uplink frame but has no server half", cfg.scheme.name())
            })?;
            // the uplink starts when the device phase is done AND the
            // radio has finished the previous exchange — under high rates
            // requests queue for the radio instead of overlapping on air
            let compute_done = times[j] + local.timings.total_s();
            tracer.span(lane, EventKind::Encode, rid, times[j], compute_done, 0.0);
            let tx_start = compute_done.max(radio_free);
            if tx_start > compute_done {
                tracer.span(lane, EventKind::RadioWait, rid, compute_done, tx_start, 0.0);
            }
            // the adaptive policy overrides the configured delivery for
            // this request; without a policy this is `&cfg.net.delivery`
            // and the match below behaves exactly as before
            let delivery = match &decision {
                Some(d) => &d.delivery,
                None => &cfg.net.delivery,
            };
            let (body, mut stats) = match (delivery, local.symbols.take()) {
                (DeliveryPolicy::Anytime { .. }, Some(symbols)) => {
                    let bits = frame.bits;
                    let pkts = packetizer.packetize(i as u64, &symbols, bits)?;
                    let (arrived, stats) = transmit_packets_traced(
                        &mut chan,
                        delivery,
                        &pkts,
                        tx_start,
                        &tracer,
                        lane,
                        rid,
                    );
                    (UplinkBody::Packets { packets: arrived, count: symbols.len(), bits }, stats)
                }
                _ => {
                    let stats = transmit_frame_traced(
                        &mut chan,
                        frame.wire_bytes(),
                        tx_start,
                        &tracer,
                        lane,
                        rid,
                    );
                    (UplinkBody::Whole(frame), stats)
                }
            };
            stats.radio_wait_s = tx_start - compute_done;
            tx_bytes = stats.app_bytes_offered;
            // downlink reply (assumed reliable: server radios are not the
            // constrained end) priced on the same channel timing
            let reply = crate::serve::scheme::reply_bytes(meta.num_classes);
            let t_reply = tx_start + stats.uplink_s;
            let downlink_s = chan.transfer_s(t_reply, reply);
            tracer.span(lane, EventKind::Uplink, rid, tx_start, t_reply, tx_bytes as f64);
            // the radio frees up on the *priced* timeline (downlink at
            // t_reply, server queueing excluded) — the same convention
            // assemble_outcome uses for network_s, and the only anchoring
            // both clocks can compute identically, which keeps every
            // channel timestamp (and so every deterministic report field)
            // bit-equal between wall and sim runs
            radio_free = t_reply + downlink_s;
            link = Some(LinkOutcome {
                network_s: stats.uplink_s + downlink_s,
                airtime_s: stats.airtime_s + chan.airtime_s(t_reply, reply),
                stats,
            });
            // sim clock only: hold the offload until its simulated arrival
            // at the server, so batching dynamics play out in virtual time
            // (the wall pipeline sends immediately, as it always has)
            if clock.is_sim() {
                clock.sleep_until(t_reply);
            }
            let t_remote_wall = Instant::now();
            let t_remote = clock.now();
            let row = transport.exchange(rid, body)?;
            remote_s = if clock.is_sim() {
                clock.now() - t_remote
            } else {
                t_remote_wall.elapsed().as_secs_f64()
            };
            remote = Some(row);
            if let Some(p) = policy.as_mut() {
                // feed the EWMAs: this exchange's link stats plus the
                // fresh queue-depth advertisement stamped on the reply
                p.observe(&stats, transport.queue_depth());
            }
            tracer.span(lane, EventKind::Remote, rid, t_remote, t_remote + remote_s, 0.0);
            t_done = clock.now() + downlink_s;
            tracer.span(lane, EventKind::Downlink, rid, t_done - downlink_s, t_done, 0.0);
        } else {
            // no uplink: the whole request is the device-side encode
            tracer.span(lane, EventKind::Encode, rid, t_start, t_done, 0.0);
        }
        // sim only: the device stays busy (MCU compute + radio exchange)
        // until t_done, serializing its virtual timeline so a saturated
        // device accumulates visible backlog — mirroring the wall loop,
        // which also finishes each request before starting the next. The
        // channel timestamps above are schedule-anchored, so this wait
        // never moves a deterministic field.
        if clock.is_sim() {
            clock.sleep_until(t_done);
        }
        let outcome = assemble_outcome(
            fuser.as_ref(),
            &local,
            remote.as_deref(),
            testset.labels[idx],
            tx_bytes,
            remote_s,
            &dev_sim,
            &net,
            link.as_ref(),
            meta.num_classes,
        )?;
        tracer.instant(lane, EventKind::Done, rid, t_done, outcome.correct as u64 as f64);
        let served = ServedOutcome {
            id: i as u64,
            device: device_index,
            // sim latency is the sojourn time from the *scheduled* arrival,
            // so a backlogged device's accumulated delay shows up in the
            // quantiles instead of silently vanishing when the priced
            // timeline falls behind the execution clock
            wall_s: if clock.is_sim() {
                t_done - times[j]
            } else {
                req_start.elapsed().as_secs_f64()
            },
            outcome,
            policy: decision.as_ref().map(|d| PolicyOutcome {
                bits: d.bits,
                switched: d.switched,
                local_only: d.local_only,
            }),
        };
        if tx_done.send(served).is_err() {
            break; // stream consumer gone; stop producing
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builder_maps_every_knob_onto_run_config() {
        let cfg = ServeBuilder::new("svhns")
            .artifacts_dir("/tmp/arts")
            .scheme(Scheme::Deepcod)
            .backend(BackendKind::Reference)
            .fleet(|f| {
                f.devices = 4;
                f.requests = 128;
            })
            .batch(|b| {
                b.max_batch = 4;
                b.deadline_us = 500;
            })
            .bits(2)
            .alpha(0.7)
            .network_profile(NetworkProfile::ble_270kbps())
            .device_profile(DeviceProfile::stm32h743())
            .to_config();
        assert_eq!(cfg.dataset, "svhns");
        assert_eq!(cfg.scheme, Scheme::Deepcod);
        assert_eq!(cfg.backend, BackendKind::Reference);
        assert_eq!(cfg.batch.max_batch, 4);
        assert_eq!(cfg.batch.deadline_us, 500);
        assert_eq!(cfg.bits, 2);
        assert_eq!(cfg.alpha_override, Some(0.7));
        assert_eq!(cfg.network.name, "BLE-270kbps");
        assert_eq!(cfg.device.name, "STM32H743");
        assert!(cfg.dataset_dir().ends_with("arts/svhns"));
    }

    #[test]
    fn builder_defaults_match_run_config_defaults() {
        let cfg = ServeBuilder::new("x").to_config();
        let base = RunConfig::new(cfg.artifacts_dir.clone(), "x", Scheme::Agile);
        assert_eq!(cfg.backend, base.backend);
        assert_eq!(cfg.bits, base.bits);
        assert_eq!(cfg.batch, base.batch);
        assert_eq!(cfg.policy, base.policy);
        assert_eq!(cfg.alpha_override, None);
    }

    #[test]
    fn builder_maps_net_knobs_onto_run_config() {
        let cfg = ServeBuilder::new("svhns")
            .net(|n| {
                n.loss = GilbertElliott::bursty(0.3, 4.0);
                n.delivery = DeliveryPolicy::Anytime { deadline_s: 0.05 };
                n.order = PacketOrder::Index;
                n.packet_payload = Some(64);
                n.seed = 7;
                n.trace = Some(BandwidthTrace::constant(1e6));
            })
            .to_config();
        assert!(!cfg.net.is_ideal());
        assert!((cfg.net.loss.expected_loss_rate() - 0.3).abs() < 1e-9);
        assert_eq!(cfg.net.delivery, DeliveryPolicy::Anytime { deadline_s: 0.05 });
        assert_eq!(cfg.net.order, PacketOrder::Index);
        assert_eq!(cfg.net.packet_payload, Some(64));
        assert_eq!(cfg.net.seed, 7);
        assert!(cfg.net.trace.is_some());
        // defaults stay on the ideal pre-channel link
        assert!(ServeBuilder::new("x").to_config().net.is_ideal());
    }

    #[test]
    fn from_config_to_config_round_trips() {
        let cfg = ServeBuilder::new("svhns")
            .scheme(Scheme::Spinn)
            .backend(BackendKind::Reference)
            .bits(2)
            .alpha(0.6)
            .batch(|b| {
                b.max_batch = 4;
                b.deadline_us = 750;
            })
            .net(|n| {
                n.seed = 5;
                n.delivery = DeliveryPolicy::Anytime { deadline_s: 0.02 };
            })
            .policy(crate::serve::policy::PolicyConfig::default())
            .device_profile(DeviceProfile::stm32h743())
            .network_profile(NetworkProfile::ble_270kbps())
            .to_config();
        assert_eq!(ServeBuilder::from_config(cfg.clone()).to_config(), cfg);
    }

    #[test]
    #[allow(deprecated)]
    fn deprecated_flat_setters_delegate_to_grouped_configs() {
        let via_shims = ServeBuilder::new("x")
            .max_batch(4)
            .batch_deadline_us(500)
            .loss_rate(0.25)
            .net_seed(3)
            .to_config();
        let via_groups = ServeBuilder::new("x")
            .batch(|b| {
                b.max_batch = 4;
                b.deadline_us = 500;
            })
            .net(|n| {
                n.loss = GilbertElliott::uniform(0.25);
                n.seed = 3;
            })
            .to_config();
        assert_eq!(via_shims, via_groups);
    }

    #[test]
    fn rate_hz_selects_arrival_process() {
        let b = ServeBuilder::new("x").rate_hz(30.0);
        assert!(matches!(b.arrival, Arrival::Poisson { hz, .. } if hz == 30.0));
        let b = ServeBuilder::new("x").rate_hz(0.0);
        assert!(matches!(b.arrival, Arrival::Periodic { .. }));
    }

    #[test]
    fn builder_clock_and_arrival_seed_knobs() {
        let b = ServeBuilder::new("x").clock(ClockKind::Sim).rate_hz(30.0).arrival_seed(7);
        assert_eq!(b.clock, ClockKind::Sim);
        let seeded = b.arrival.with_seed(b.arrival_seed.unwrap());
        assert!(matches!(seeded, Arrival::Poisson { seed: 7, .. }));
        // defaults: wall clock, no arrival-seed override
        let d = ServeBuilder::new("x");
        assert_eq!(d.clock, ClockKind::Wall);
        assert!(d.arrival_seed.is_none());
    }
}
