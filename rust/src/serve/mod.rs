//! Scheme-agnostic serving API (the redesign of the ad-hoc
//! `run_pipeline` entry point).
//!
//! The paper's evaluation (§7) is a *comparison* across five serving
//! schemes, so the serving surface must not privilege one of them. Here
//! every scheme decomposes into a device half + optional server half + a
//! fuser ([`scheme`]), and one threaded, deadline-batched pipeline
//! ([`service`]) serves any of them:
//!
//! ```no_run
//! use agilenn::config::Scheme;
//! use agilenn::serve::ServeBuilder;
//!
//! let report = ServeBuilder::new("svhns")
//!     .scheme(Scheme::Deepcod)   // any of the five schemes
//!     .fleet(|f| { f.devices = 4; f.requests = 256; })
//!     .rate_hz(30.0)
//!     .build().unwrap()
//!     .run().unwrap();
//! println!("{:.1} req/s", report.throughput_rps);
//! ```
//!
//! For per-request observability, [`Service::stream`] returns an
//! [`OutcomeStream`] — an iterator over [`ServedOutcome`]s as devices
//! finish them — and `finish()` yields the same [`PipelineReport`].
//!
//! Uplink frames cross a simulated lossy channel ([`crate::net`]):
//! `ServeBuilder::loss` / `bandwidth_trace` / `delivery` / `packet_order`
//! select the loss process, a replayable bandwidth trace, and ARQ vs.
//! deadline-bounded anytime transport (importance-ordered packets, server
//! decodes whatever arrived). The defaults reproduce the ideal link.
//!
//! The pipeline's timeline is pluggable ([`clock`]):
//! `ServeBuilder::clock(ClockKind::Sim)` swaps the wall clock for
//! discrete-event virtual time — arrival pacing, batch deadlines and
//! reply waits play out without ever sleeping, so sustained-load sweeps
//! run at CPU speed and every latency quantile in the [`PipelineReport`]
//! becomes seed-deterministic.
//!
//! Sim runs execute on the single-threaded fleet [`engine`] (bitwise-
//! equivalent to the legacy thread-per-device fabric, which remains
//! selectable via `ServeBuilder::sim_engine`), which scales to millions
//! of requests across tens of thousands of devices and adds the
//! multi-server axis: `ServeBuilder::{servers,placement}` shards the
//! batch queue across N servers under a static / round-robin /
//! least-loaded / capacity-weighted device→server [`Placement`] policy,
//! with per-shard load/latency in [`PipelineReport::shards`].
//!
//! Autoscaling ([`autoscale`]): engine runs can model per-batch service
//! time (`ServeBuilder::service_model`, [`ServiceModel`]) and hand fleet
//! sizing to a deterministic SLO controller
//! (`ServeBuilder::autoscale`, [`AutoscaleConfig`]) that watches rolling
//! per-shard queue-wait p95 over a virtual-time window and grows or
//! drains the active server set mid-run — every [`ScaleEvent`] lands in
//! the trace, and the report gains integrated `server_seconds` plus SLO
//! attainment against `ServeBuilder::slo_p99`. See `docs/serving.md`.
//!
//! Real sockets ([`fabric`], [`daemon`]): device↔server communication
//! flows through the [`Transport`] trait, so the same `device_loop` that
//! drives the in-process `mpsc` path can instead speak a versioned wire
//! protocol ([`crate::net::wire`]) over TCP to an `agilenn serve --listen`
//! daemon ([`Daemon`]), with `ServeBuilder::connect` selecting the remote
//! path on the client. The simulated channel stays device-side, so a
//! loopback daemon run reproduces every seed-deterministic report field
//! of an in-process run bit for bit (see `docs/daemon.md`).
//!
//! Adaptive offloading ([`policy`]): `ServeBuilder::policy` arms a
//! deterministic per-request policy on each device half that picks the
//! quantizer bit-width, degrades ARQ to deadline-bounded anytime
//! delivery, or falls back to the device-local head entirely, driven by
//! an EWMA of recent link stats plus the server's queue-depth
//! advertisements, with hysteresis and cooldown. Policy-off runs are
//! bit-identical to the static pipeline. See `docs/policy.md`.
//!
//! Observability ([`crate::obs`]): `ServeBuilder::trace_sink` attaches a
//! [`TraceSink`](crate::obs::TraceSink) that receives every
//! request-lifecycle span (arrival → encode → radio wait → per-packet
//! uplink → server queue → batch dispatch → remote NN → downlink → done)
//! stamped in the run's clock domain — exported to Chrome/Perfetto JSON
//! via [`crate::obs::chrome_trace_json`], bitwise-reproducible under the
//! sim clock. [`OutcomeStream::finish_full`] additionally returns the
//! [`MetricsRegistry`](crate::obs::MetricsRegistry) the
//! [`PipelineReport`] is derived from. See `docs/observability.md`.

pub mod autoscale;
pub mod clock;
pub mod daemon;
pub mod engine;
pub mod fabric;
pub mod policy;
pub mod scheme;
pub mod service;

pub use autoscale::{AutoscaleConfig, ScaleEvent, ScaleKind, ServiceModel};
pub use clock::{Clock, ClockKind};
pub use daemon::{send_shutdown, Daemon, DaemonSummary};
pub use engine::{Placement, SimEngine};
pub use fabric::{TcpTransport, Transport, UplinkBody};
pub use policy::{Decision, DevicePolicy, PolicyConfig, PolicyOutcome};
pub use scheme::{
    make_device_side, make_fuser, make_server_side, reply_bytes, AgileDevice, AlphaFuser,
    DeepcodDevice, DeviceSide, EdgeDevice, Fuser, LocalArgmaxFuser, LocalResult, McunetDevice,
    RemoteArgmaxFuser, ServerSide, SpinnDevice,
};
pub use service::{
    ConfigError, FleetConfig, OutcomeStream, PipelineReport, PolicyReport, RemoteFailure,
    ServeBuilder, ServedOutcome, Service, ShardReport,
};
