//! Scheme-agnostic serving API (the redesign of the ad-hoc
//! `run_pipeline` entry point).
//!
//! The paper's evaluation (§7) is a *comparison* across five serving
//! schemes, so the serving surface must not privilege one of them. Here
//! every scheme decomposes into a device half + optional server half + a
//! fuser ([`scheme`]), and one threaded, deadline-batched pipeline
//! ([`service`]) serves any of them:
//!
//! ```no_run
//! use agilenn::config::Scheme;
//! use agilenn::serve::ServeBuilder;
//!
//! let report = ServeBuilder::new("svhns")
//!     .scheme(Scheme::Deepcod)   // any of the five schemes
//!     .devices(4)
//!     .requests(256)
//!     .rate_hz(30.0)
//!     .build().unwrap()
//!     .run().unwrap();
//! println!("{:.1} req/s", report.throughput_rps);
//! ```
//!
//! For per-request observability, [`Service::stream`] returns an
//! [`OutcomeStream`] — an iterator over [`ServedOutcome`]s as devices
//! finish them — and `finish()` yields the same [`PipelineReport`].
//!
//! Uplink frames cross a simulated lossy channel ([`crate::net`]):
//! `ServeBuilder::loss` / `bandwidth_trace` / `delivery` / `packet_order`
//! select the loss process, a replayable bandwidth trace, and ARQ vs.
//! deadline-bounded anytime transport (importance-ordered packets, server
//! decodes whatever arrived). The defaults reproduce the ideal link.
//!
//! The pipeline's timeline is pluggable ([`clock`]):
//! `ServeBuilder::clock(ClockKind::Sim)` swaps the wall clock for a
//! shared discrete-event virtual clock — arrival pacing, batch deadlines
//! and reply waits play out in virtual time without ever sleeping, so
//! 100k+-request load sweeps run at CPU speed and every latency quantile
//! in the [`PipelineReport`] becomes seed-deterministic.

pub mod clock;
pub mod scheme;
pub mod service;

pub use clock::{Clock, ClockKind};
pub use scheme::{
    make_device_side, make_fuser, make_server_side, reply_bytes, AgileDevice, AlphaFuser,
    DeepcodDevice, DeviceSide, EdgeDevice, Fuser, LocalArgmaxFuser, LocalResult, McunetDevice,
    RemoteArgmaxFuser, ServerSide, SpinnDevice,
};
pub use service::{
    OutcomeStream, PipelineReport, RemoteFailure, ServeBuilder, ServedOutcome, Service,
};
