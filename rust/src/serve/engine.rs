//! `serve::engine` — the single-threaded discrete-event fleet engine.
//!
//! The threaded sim pipeline (`super::service` + `super::clock`) runs one
//! OS thread per device and coordinates them through a shared conservative
//! virtual clock. That is faithful but caps out around 10k requests × 8
//! devices: every virtual-time step is a cross-thread rendezvous. This
//! module executes the *same* device/server logic as event-driven state
//! machines on one thread: arrival → local NN → packetized uplink through
//! [`Channel`] → batch queue → remote NN → fusion, with a binary heap of
//! `(time, seq)` events replacing the clock's quiescence protocol. A
//! million-request, ten-thousand-device sweep plays out in seconds.
//!
//! ## Equivalence contract
//!
//! On every **tie-free** configuration both paths accept (one server),
//! the engine is **bitwise equivalent** to the threaded sim clock:
//! identical `PipelineReport` deterministic fields, identical virtual
//! makespan, identical batch compositions. This holds because the
//! simulated timeline was already schedule-anchored (PR 3): every channel
//! timestamp is a pure function of the arrival schedule, the per-device
//! seeds, and the batch dispatch times — and the engine reproduces each
//! arithmetic expression of the threaded device/server loops verbatim:
//!
//! * a device's uplink starts at `max(arrival + compute, radio_free)`;
//! * the offload reaches its server at `max(device cursor, t_reply)`;
//! * batches dispatch on the size trigger at the push timestamp, or on
//!   the deadline at exactly `BatchQueue::next_deadline_at`;
//! * the reply frees the device at `dispatch + downlink`.
//!
//! Events at *distinct* virtual times are totally ordered. Exact ties are
//! broken FIFO by schedule order, deterministically — whereas the
//! threaded fabric resolves them by OS scheduling. Ties are not
//! hypothetical: in a **saturated** fleet, devices whose offloads ride
//! the same batch resume at the identical virtual instant (dispatch time
//! plus the constant downlink), and if they are all backlogged their next
//! offloads are sent at bit-equal times, so the threaded fabric's batch
//! composition there depends on thread wake order. Non-saturating
//! configurations (device latency below the inter-arrival gap, as in the
//! equivalence suite) are tie-free by construction: every send is
//! anchored on `arrival + compute + uplink`, and the per-device periodic
//! phases / decorrelated Poisson streams keep those sums distinct. The
//! engine turns the remaining saturated-tie races into one deterministic
//! schedule instead of inheriting them.
//!
//! The engine additionally memoizes the device encode and the whole-frame
//! server decode per test-set sample: both are pure functions of the
//! sample (the same request indexes the same image), so a 1M-request run
//! pays the NN/LZW cost once per distinct sample instead of once per
//! request. This is an optimization, not a semantic change.
//!
//! ## Multi-server sharding
//!
//! The engine generalizes the server side to N shards, each with its own
//! [`ServerSide`] instance and [`BatchQueue`], fed through a pluggable
//! device→server [`Placement`] policy (static shard, round-robin,
//! least-loaded). Surfaced as `ServeBuilder::{servers,placement}` and
//! `serve --servers N --placement p`; per-shard load/latency lands in
//! `PipelineReport::shards`. Multi-server topologies exist only here —
//! the wall clock and the threaded sim reject `servers > 1`.

use crate::config::{Meta, RunConfig};
use crate::coordinator::batcher::BatchQueue;
use crate::net::{
    importance_order, transmit_frame_traced, transmit_packets_traced, Channel, DeliveryPolicy,
    LinkOutcome, PacketOrder, Packetizer,
};
use crate::obs::{self, Lane, Tracer};
use crate::runtime::Backend;
use crate::serve::autoscale::{
    AutoscaleConfig, Controller, ScaleDecision, ScaleEvent, ScaleKind, ServiceModel, ShardLifetime,
};
use crate::serve::scheme::{
    assemble_outcome, make_device_side, make_fuser, make_server_side, reply_bytes, DeviceSide,
    Fuser, LocalResult, ServerSide,
};
use crate::serve::fabric::UplinkBody;
use crate::serve::policy::{DevicePolicy, PolicyOutcome};
use crate::serve::service::{device_schedule, ServedOutcome, ShardAgg};
use crate::simulator::{DeviceSim, NetworkSim};
use crate::tensor::Tensor;
use crate::workload::{Arrival, TestSet};
use anyhow::{anyhow, ensure, Context, Result};
use std::cmp::Ordering;
use std::collections::{BinaryHeap, HashMap};
use std::str::FromStr;
use std::sync::mpsc::Sender;

/// How `ClockKind::Sim` executes: the discrete-event fleet engine (the
/// default) or the legacy thread-per-device fabric it replaced. The
/// threaded fabric is kept as the equivalence oracle — the two must agree
/// bitwise on every overlapping configuration — and as a debugging escape
/// hatch (`serve --sim-engine threads`).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum SimEngine {
    /// Single-threaded event loop; supports multi-server topologies.
    #[default]
    Event,
    /// One OS thread per device + the shared conservative clock (PR 3).
    Threads,
}

impl SimEngine {
    pub fn name(&self) -> &'static str {
        match self {
            SimEngine::Event => "event",
            SimEngine::Threads => "threads",
        }
    }
}

impl FromStr for SimEngine {
    type Err = anyhow::Error;

    fn from_str(s: &str) -> Result<Self> {
        match s.to_ascii_lowercase().as_str() {
            "event" | "engine" => Ok(SimEngine::Event),
            "threads" | "threaded" => Ok(SimEngine::Threads),
            other => anyhow::bail!("unknown sim engine {other:?} (event|threads)"),
        }
    }
}

/// Device→server placement policy for multi-server topologies.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum Placement {
    /// `server = device % servers`: a pure function of the device index,
    /// so the assignment survives run-to-run and device renumbering
    /// renumbers shards predictably. The default.
    #[default]
    Static,
    /// Offloads cycle through the servers in virtual-time order,
    /// regardless of which device sent them.
    RoundRobin,
    /// Each offload goes to the server with the fewest queued requests at
    /// its arrival instant. Ties rotate round-robin rather than picking
    /// the lowest index: serving-fleet queues drain to empty between
    /// bursts, and a lowest-index tie-break would pile every
    /// empty-queue decision onto server 0 (measured: worse totals than
    /// static placement); with rotation the policy degenerates to
    /// round-robin when depths are flat and water-fills when they are
    /// not.
    LeastLoaded,
    /// Least-loaded normalized by per-server capacity weight
    /// ([`ServiceModel::capacities`]): each offload goes to the server
    /// minimizing `queued / capacity`, so a 2× server absorbs 2× the
    /// depth before losing a placement. With uniform weights this is
    /// exactly [`Placement::LeastLoaded`]. Ties rotate for the same
    /// reason least-loaded's do.
    ///
    /// [`ServiceModel::capacities`]: super::autoscale::ServiceModel
    WeightedLeastLoaded,
}

impl Placement {
    pub fn name(&self) -> &'static str {
        match self {
            Placement::Static => "static",
            Placement::RoundRobin => "rr",
            Placement::LeastLoaded => "least",
            Placement::WeightedLeastLoaded => "weighted",
        }
    }
}

impl FromStr for Placement {
    type Err = anyhow::Error;

    fn from_str(s: &str) -> Result<Self> {
        match s.to_ascii_lowercase().as_str() {
            "static" | "shard" => Ok(Placement::Static),
            "rr" | "round-robin" | "roundrobin" => Ok(Placement::RoundRobin),
            "least" | "least-loaded" | "leastloaded" => Ok(Placement::LeastLoaded),
            "weighted" | "wleast" | "weighted-least-loaded" => Ok(Placement::WeightedLeastLoaded),
            other => anyhow::bail!("unknown placement {other:?} (static|rr|least|weighted)"),
        }
    }
}

/// The placement decision procedure, separated from the engine so the
/// policy is unit-testable without a pipeline.
#[derive(Debug)]
pub(crate) struct Placer {
    policy: Placement,
    servers: usize,
    rr_next: usize,
}

impl Placer {
    pub(crate) fn new(policy: Placement, servers: usize) -> Self {
        Self { policy, servers, rr_next: 0 }
    }

    /// Shard for one offload from `device`. `accepting` marks shards
    /// currently taking placements (a draining or inactive autoscale
    /// shard is skipped; fixed fleets pass `|_| true`, on which every
    /// policy reduces to its pre-autoscale behavior), `load` reports a
    /// shard's outstanding requests, and
    /// `capacity` its weight for [`Placement::WeightedLeastLoaded`]. At
    /// least one shard must be accepting.
    pub(crate) fn pick(
        &mut self,
        device: usize,
        accepting: impl Fn(usize) -> bool,
        load: impl Fn(usize) -> usize,
        capacity: impl Fn(usize) -> f64,
    ) -> usize {
        match self.policy {
            Placement::Static => {
                // `device % accepting_count`, mapped onto the accepting
                // list — identical to `device % servers` when all accept
                let n = (0..self.servers).filter(|&s| accepting(s)).count();
                assert!(n > 0, "no accepting shard for placement");
                let k = device % n;
                (0..self.servers)
                    .filter(|&s| accepting(s))
                    .nth(k)
                    .expect("k-th accepting shard exists")
            }
            Placement::RoundRobin => loop {
                let s = self.rr_next;
                self.rr_next = (s + 1) % self.servers;
                if accepting(s) {
                    break s;
                }
            },
            Placement::LeastLoaded => {
                // strict minimum scanned from the rotation cursor: flat
                // depths degenerate to round-robin instead of piling every
                // tie onto server 0
                let mut best: Option<(usize, usize)> = None;
                for k in 0..self.servers {
                    let s = (self.rr_next + k) % self.servers;
                    if !accepting(s) {
                        continue;
                    }
                    let l = load(s);
                    match best {
                        Some((_, bl)) if l >= bl => {}
                        _ => best = Some((s, l)),
                    }
                }
                let (best, _) = best.expect("no accepting shard for placement");
                self.rr_next = (best + 1) % self.servers;
                best
            }
            Placement::WeightedLeastLoaded => {
                let mut best: Option<(usize, f64)> = None;
                for k in 0..self.servers {
                    let s = (self.rr_next + k) % self.servers;
                    if !accepting(s) {
                        continue;
                    }
                    let l = load(s) as f64 / capacity(s);
                    match best {
                        Some((_, bl)) if l >= bl => {}
                        _ => best = Some((s, l)),
                    }
                }
                let (best, _) = best.expect("no accepting shard for placement");
                self.rr_next = (best + 1) % self.servers;
                best
            }
        }
    }
}

/// What one engine run hands back to `OutcomeStream::finish`.
#[derive(Debug)]
pub(crate) struct EngineRun {
    /// final virtual time: the completion timestamp of the last request
    pub wall_s: f64,
    /// per-server batch/queue accounting, indexed by server
    pub shards: Vec<ShardAgg>,
    /// applied autoscale actions in virtual-time order (empty when the
    /// controller is off)
    pub scale_events: Vec<ScaleEvent>,
}

/// Everything that parameterizes one fleet run (identical to what the
/// threaded `Service::stream` consumes, plus the server topology and the
/// autoscale control plane).
pub(crate) struct FleetSpec {
    pub devices: usize,
    pub requests: usize,
    pub arrival: Arrival,
    /// initial active server count (the full fleet when `autoscale` is
    /// off; the starting set, growable to `max_servers`, when on)
    pub servers: usize,
    pub placement: Placement,
    /// per-batch remote service-time pricing; the zero default leaves
    /// the timeline bit-identical to the pre-model engine
    pub service: ServiceModel,
    /// the SLO control plane; `None` = fixed fleet, the pre-autoscale
    /// engine code path
    pub autoscale: Option<AutoscaleConfig>,
}

// ---------------------------------------------------------------------------
// events
// ---------------------------------------------------------------------------

#[derive(Debug, Clone, Copy)]
enum EventKind {
    /// the device starts (or resumes after completing) its next request
    Device { device: usize },
    /// a computed offload reaches the server side
    Offload { device: usize },
    /// batch-deadline wake-up for one shard; stale wake-ups are no-ops,
    /// exactly like the threaded clock's deadline waits
    Deadline { shard: usize },
    /// a dispatched batch finishes its virtual service time on one shard
    /// (only scheduled when the service model prices batches above zero)
    BatchDone { shard: usize },
    /// autoscale control tick (only scheduled when the controller is on)
    ControlTick,
}

#[derive(Debug, Clone, Copy)]
struct Ev {
    t: f64,
    /// schedule order, the deterministic FIFO tie-break at equal times
    seq: u64,
    kind: EventKind,
}

impl PartialEq for Ev {
    fn eq(&self, other: &Self) -> bool {
        self.cmp(other) == Ordering::Equal
    }
}

impl Eq for Ev {}

impl PartialOrd for Ev {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

impl Ord for Ev {
    fn cmp(&self, other: &Self) -> Ordering {
        // BinaryHeap is a max-heap: reverse so the earliest (then
        // first-scheduled) event pops first. Event times are never NaN.
        other.t.total_cmp(&self.t).then_with(|| other.seq.cmp(&self.seq))
    }
}

// ---------------------------------------------------------------------------
// state machines
// ---------------------------------------------------------------------------

/// One in-flight offload: everything the device needs back when its reply
/// arrives from the batch dispatch.
struct Awaiting {
    /// schedule index (into `ids`/`times`) of the offloaded request
    j: usize,
    /// global request id
    id: usize,
    body: Option<UplinkBody>,
    local: LocalResult,
    link: LinkOutcome,
    tx_bytes: usize,
    downlink_s: f64,
    /// virtual instant the offload left the device (= the threaded
    /// pipeline's channel-send time)
    t_send: f64,
    /// what the adaptive policy chose for this request (`None` when off)
    policy: Option<PolicyOutcome>,
}

struct DeviceState {
    ids: Vec<usize>,
    times: Vec<f64>,
    /// index of the next request in `ids`/`times`
    next: usize,
    /// simulated time this device's radio frees up after the previous
    /// request's uplink + downlink exchange (priced timeline)
    radio_free: f64,
    chan: Channel,
    awaiting: Option<Awaiting>,
}

/// One batch held in virtual service: inference already ran (results are
/// time-independent), the devices resume when the service time elapses.
struct InService {
    batch: Vec<crate::coordinator::batcher::Pending<(usize, Tensor)>>,
    rows: Vec<Vec<f32>>,
    t_finish: f64,
    /// queue depth at the dispatch instant — the advertisement the
    /// threaded server stamps on every reply, fed to device policies
    advert_depth: usize,
}

struct ServerState {
    side: Box<dyn ServerSide>,
    queue: BatchQueue<(usize, Tensor)>,
    agg: ShardAgg,
    /// virtual instant this shard's in-service batches all complete;
    /// batches on one shard serialize (service starts at
    /// `max(dispatch, busy_until)`)
    busy_until: f64,
    /// FIFO of batches currently paying their virtual service time
    in_service: std::collections::VecDeque<InService>,
    /// provisioned and taking placements
    active: bool,
    /// scale-in decided: no new placements, retires once drained
    draining: bool,
    /// controller pressure at the drain decision (recorded into the
    /// retirement's [`ScaleEvent`])
    drain_pressure: f64,
    /// integrated activation → retirement intervals
    lifetime: ShardLifetime,
}

impl ServerState {
    fn accepting(&self) -> bool {
        self.active && !self.draining
    }

    /// Outstanding work: queued plus in-service requests. The load signal
    /// placement policies scan — equal to `queue.len()` whenever the
    /// service model is zero (nothing ever sits in service).
    fn outstanding(&self) -> usize {
        self.queue.len() + self.in_service.iter().map(|b| b.batch.len()).sum::<usize>()
    }
}

/// The assembled fleet: every state machine plus the event heap.
struct Fleet<'a> {
    cfg: &'a RunConfig,
    testset: &'a TestSet,
    tx_done: &'a Sender<ServedOutcome>,
    devices: Vec<DeviceState>,
    servers: Vec<ServerState>,
    placer: Placer,
    heap: BinaryHeap<Ev>,
    seq: u64,
    device_side: Box<dyn DeviceSide>,
    fuser: Box<dyn Fuser>,
    dev_sim: DeviceSim,
    net_sim: NetworkSim,
    packetizer: Packetizer,
    /// downlink reply payload, bytes
    reply: usize,
    num_classes: usize,
    /// per-sample memoized device encodes (index = sample index) — sound
    /// because `DeviceSide::encode` is a pure function of the sample: the
    /// same request index always reproduces the same frame, symbols, and
    /// priced timings
    encoded: Vec<Option<LocalResult>>,
    /// per-sample memoized whole-frame decodes (ARQ path only; a partial
    /// packet set depends on the channel state and is never cached)
    decoded: Vec<Option<Tensor>>,
    /// adaptive-policy state machines, one per device; empty with the
    /// policy off, which keeps every branch below on the pre-policy
    /// code path (the bit-identity contract)
    policies: Vec<DevicePolicy>,
    /// last width each device's encoder was set to (policy on only):
    /// local-only requests encode at the previous uplink's width, exactly
    /// like the threaded device whose encoder keeps its last `set_bits`
    cur_bits: Vec<u32>,
    /// encode memo keyed by (sample, width) — the policy-on counterpart
    /// of `encoded`: the encode is pure per (sample, width)
    encoded_multi: HashMap<(usize, u32), LocalResult>,
    /// whole-frame decode memo keyed by (sample, width), policy on only
    decoded_multi: HashMap<(usize, u32), Tensor>,
    /// completion timestamp of the latest finished request — the virtual
    /// makespan, matching the threaded sim clock's final `now()`
    t_end: f64,
    /// the stream consumer is gone; stop producing, like device threads do
    stopped: bool,
    /// per-batch virtual service-time pricing (zero by default)
    service: ServiceModel,
    /// the SLO control plane; `None` runs the fixed-fleet code path
    controller: Option<Controller>,
    /// applied scale actions, in virtual-time order
    scale_events: Vec<ScaleEvent>,
    /// requests not yet emitted — control ticks stop rescheduling when
    /// this reaches zero so the event heap can drain
    remaining: usize,
    /// request-lifecycle trace sink; emissions mirror the threaded
    /// `device_loop`/`server_loop` expression for expression, so sim
    /// traces agree between the two paths on tie-free configurations
    tracer: Tracer,
}

/// Run the fleet to completion, streaming outcomes into `tx_done`.
pub(crate) fn run_fleet(
    backend: &dyn Backend,
    cfg: &RunConfig,
    meta: &Meta,
    testset: &TestSet,
    spec: &FleetSpec,
    tx_done: &Sender<ServedOutcome>,
    tracer: &Tracer,
) -> Result<EngineRun> {
    ensure!(spec.servers >= 1, "need at least one server");
    let device_side = make_device_side(backend, cfg, meta)?;
    let fuser = make_fuser(cfg, meta)?;
    // with the controller on, every shard slot up to max_servers is
    // provisioned (model instantiated) but only the first `spec.servers`
    // start active; a fixed fleet provisions exactly `spec.servers`
    let slots = spec.autoscale.as_ref().map(|a| a.max_servers).unwrap_or(spec.servers);
    let mut servers = Vec::new();
    for i in 0..slots {
        match make_server_side(backend, cfg, meta)? {
            Some(side) => {
                let max_batch = cfg.batch.max_batch.min(side.max_batch());
                let deadline_s = cfg.batch.deadline_s();
                let active = i < spec.servers;
                let mut lifetime = ShardLifetime::default();
                if active {
                    lifetime.activate(0.0);
                }
                servers.push(ServerState {
                    side,
                    queue: BatchQueue::new(max_batch, deadline_s),
                    agg: ShardAgg::default(),
                    busy_until: 0.0,
                    in_service: std::collections::VecDeque::new(),
                    active,
                    draining: false,
                    drain_pressure: 0.0,
                    lifetime,
                });
            }
            // local-only schemes have no server half; the topology is moot
            None => break,
        }
    }
    let order = match cfg.net.order {
        PacketOrder::Importance => importance_order(meta, cfg.scheme),
        PacketOrder::Index => None,
    };
    let placer_slots = servers.len().max(1);
    let mut fleet = Fleet {
        cfg,
        testset,
        tx_done,
        devices: Vec::with_capacity(spec.devices),
        servers,
        placer: Placer::new(spec.placement, placer_slots),
        heap: BinaryHeap::with_capacity(spec.devices + 1),
        seq: 0,
        device_side,
        fuser,
        dev_sim: DeviceSim::new(cfg.device.clone()),
        net_sim: NetworkSim::new(cfg.network.clone()),
        packetizer: Packetizer::new(cfg.net.payload_cap(cfg.network.mtu), order),
        reply: reply_bytes(meta.num_classes),
        num_classes: meta.num_classes,
        encoded: (0..testset.len()).map(|_| None).collect(),
        decoded: (0..testset.len()).map(|_| None).collect(),
        policies: match &cfg.policy {
            Some(p) => (0..spec.devices).map(|_| DevicePolicy::new(p.clone())).collect(),
            None => Vec::new(),
        },
        cur_bits: vec![cfg.bits; spec.devices],
        encoded_multi: HashMap::new(),
        decoded_multi: HashMap::new(),
        t_end: 0.0,
        stopped: false,
        service: spec.service.clone(),
        controller: spec.autoscale.clone().map(Controller::new),
        scale_events: Vec::new(),
        remaining: 0,
        tracer: tracer.clone(),
    };
    for d in 0..spec.devices {
        let (ids, times) = device_schedule(&spec.arrival, spec.devices, spec.requests, d);
        let first = times.first().copied();
        fleet.remaining += ids.len();
        fleet.devices.push(DeviceState {
            ids,
            times,
            next: 0,
            radio_free: 0.0,
            chan: Channel::new(
                &cfg.network,
                cfg.net.loss.clone(),
                cfg.net.trace.clone(),
                cfg.net.device_seed(d),
            ),
            awaiting: None,
        });
        if let Some(t0) = first {
            fleet.schedule(t0, EventKind::Device { device: d });
        }
    }
    if let Some(ctl) = &fleet.controller {
        let t0 = ctl.cfg.interval_s;
        fleet.schedule(t0, EventKind::ControlTick);
    }
    fleet.run()
}

impl Fleet<'_> {
    fn schedule(&mut self, t: f64, kind: EventKind) {
        let seq = self.seq;
        self.seq += 1;
        self.heap.push(Ev { t, seq, kind });
    }

    fn run(&mut self) -> Result<EngineRun> {
        while let Some(ev) = self.heap.pop() {
            if self.stopped {
                break;
            }
            match ev.kind {
                EventKind::Device { device } => self.handle_device(ev.t, device)?,
                EventKind::Offload { device } => self.handle_offload(ev.t, device)?,
                EventKind::Deadline { shard } => self.handle_deadline(ev.t, shard)?,
                EventKind::BatchDone { shard } => self.handle_batch_done(ev.t, shard)?,
                EventKind::ControlTick => self.handle_control_tick(ev.t)?,
            }
        }
        let autoscaled = self.controller.is_some();
        let t_end = self.t_end;
        Ok(EngineRun {
            wall_s: t_end,
            shards: self
                .servers
                .drain(..)
                .map(|s| {
                    let mut agg = s.agg;
                    // integrated active lifetime, open intervals closed at
                    // the makespan; fixed fleets keep the sentinel, which
                    // `finish_full` resolves to the whole run
                    if autoscaled {
                        agg.active_s = s.lifetime.total(t_end);
                    }
                    agg
                })
                .collect(),
            scale_events: std::mem::take(&mut self.scale_events),
        })
    }

    /// Memoized device encode for one test-set sample.
    fn encode(&mut self, idx: usize) -> Result<LocalResult> {
        if self.encoded[idx].is_none() {
            let img = self.testset.image(idx)?;
            self.encoded[idx] = Some(self.device_side.encode(&img)?);
        }
        Ok(self.encoded[idx].as_ref().expect("just memoized").clone())
    }

    /// Memoized device encode at one candidate width (adaptive policy on).
    /// The encode is pure per (sample, width), so each pair pays the
    /// NN/quantize/LZW cost once; the shared device half is re-keyed only
    /// on a memo miss.
    fn encode_at(&mut self, idx: usize, bits: u32) -> Result<LocalResult> {
        let key = (idx, bits);
        if !self.encoded_multi.contains_key(&key) {
            self.device_side.set_bits(bits)?;
            let img = self.testset.image(idx)?;
            let local = self.device_side.encode(&img)?;
            self.encoded_multi.insert(key, local);
        }
        Ok(self.encoded_multi.get(&key).expect("just memoized").clone())
    }

    /// The device phase of one request: the arithmetic of the threaded
    /// `device_loop`, expression for expression. The event fires at
    /// `max(t_free, times[j])` — the device's virtual cursor after arrival
    /// pacing — which is `t` here by construction.
    fn handle_device(&mut self, t: f64, d: usize) -> Result<()> {
        let (j, id, t_arrival) = {
            let st = &self.devices[d];
            (st.next, st.ids[st.next], st.times[st.next])
        };
        let lane = Lane::Device(d as u32);
        let rid = id as u64;
        self.tracer.instant(lane, obs::EventKind::Arrival, rid, t_arrival, 0.0);
        // consult the adaptive policy before encoding — the threaded
        // `device_loop` expression for expression: the encoder width only
        // moves on non-local decisions, local-only requests reuse the
        // previous uplink's width
        let decision = if self.policies.is_empty() {
            None
        } else {
            let dec = self.policies[d].decide();
            if dec.switched {
                let arg = if dec.local_only { 0.0 } else { dec.bits as f64 };
                self.tracer.instant(lane, obs::EventKind::PolicySwitch, rid, t_arrival, arg);
            }
            if !dec.local_only {
                self.cur_bits[d] = dec.bits;
            }
            Some(dec)
        };
        let idx = id % self.testset.len();
        let mut local = match &decision {
            None => self.encode(idx)?,
            Some(_) => self.encode_at(idx, self.cur_bits[d])?,
        };
        if decision.as_ref().is_some_and(|dec| dec.local_only) {
            // resolve on device: drop the uplink and its pricing — a
            // request the policy keeps local never quantizes/compresses
            local.frame = None;
            local.symbols = None;
            local.timings.quantize_s = 0.0;
            local.timings.compress_s = 0.0;
        }
        let policy_out = decision.as_ref().map(|dec| PolicyOutcome {
            bits: dec.bits,
            switched: dec.switched,
            local_only: dec.local_only,
        });
        let timings_total = local.timings.total_s();
        match local.frame.take() {
            Some(frame) => {
                ensure!(
                    !self.servers.is_empty(),
                    "{} produced an uplink frame but has no server half",
                    self.cfg.scheme.name()
                );
                let symbols = local.symbols.take();
                let st = &mut self.devices[d];
                // the uplink starts when the device phase is done AND the
                // radio has finished the previous exchange (schedule-
                // anchored, identical to the threaded pipeline)
                let compute_done = t_arrival + timings_total;
                self.tracer.span(lane, obs::EventKind::Encode, rid, t_arrival, compute_done, 0.0);
                let tx_start = compute_done.max(st.radio_free);
                if tx_start > compute_done {
                    self.tracer
                        .span(lane, obs::EventKind::RadioWait, rid, compute_done, tx_start, 0.0);
                }
                // the adaptive policy overrides the configured delivery
                // for this request; without a policy this is
                // `&cfg.net.delivery` and the match behaves as before
                let delivery = match &decision {
                    Some(dec) => &dec.delivery,
                    None => &self.cfg.net.delivery,
                };
                let (body, mut stats) = match (delivery, symbols) {
                    (DeliveryPolicy::Anytime { .. }, Some(symbols)) => {
                        let bits = frame.bits;
                        let pkts = self.packetizer.packetize(id as u64, &symbols, bits)?;
                        let (arrived, stats) = transmit_packets_traced(
                            &mut st.chan,
                            delivery,
                            &pkts,
                            tx_start,
                            &self.tracer,
                            lane,
                            rid,
                        );
                        let count = symbols.len();
                        (UplinkBody::Packets { packets: arrived, count, bits }, stats)
                    }
                    _ => {
                        let stats = transmit_frame_traced(
                            &mut st.chan,
                            frame.wire_bytes(),
                            tx_start,
                            &self.tracer,
                            lane,
                            rid,
                        );
                        (UplinkBody::Whole(frame), stats)
                    }
                };
                stats.radio_wait_s = tx_start - compute_done;
                let tx_bytes = stats.app_bytes_offered;
                let t_reply = tx_start + stats.uplink_s;
                let downlink_s = st.chan.transfer_s(t_reply, self.reply);
                self.tracer
                    .span(lane, obs::EventKind::Uplink, rid, tx_start, t_reply, tx_bytes as f64);
                st.radio_free = t_reply + downlink_s;
                let link = LinkOutcome {
                    network_s: stats.uplink_s + downlink_s,
                    airtime_s: stats.airtime_s + st.chan.airtime_s(t_reply, self.reply),
                    stats,
                };
                // the offload reaches the server once the device's own
                // timeline catches up with the simulated link arrival
                let t_send = t.max(t_reply);
                st.awaiting = Some(Awaiting {
                    j,
                    id,
                    body: Some(body),
                    local,
                    link,
                    tx_bytes,
                    downlink_s,
                    t_send,
                    policy: policy_out,
                });
                self.schedule(t_send, EventKind::Offload { device: d });
            }
            None => {
                // resolved on device: the local timeline alone
                let t_done = t + timings_total;
                self.tracer.span(lane, obs::EventKind::Encode, rid, t, t_done, 0.0);
                self.emit(d, j, id, &local, None, 0, 0.0, None, policy_out, t_done)?;
            }
        }
        Ok(())
    }

    /// One offload arrives at the server side: place it on a shard,
    /// decode, and run the batch policy — the threaded `server_loop`'s
    /// message branch.
    fn handle_offload(&mut self, t: f64, d: usize) -> Result<()> {
        let (id, body) = {
            let aw = self.devices[d]
                .awaiting
                .as_mut()
                .ok_or_else(|| anyhow!("offload event for device {d} with nothing in flight"))?;
            (aw.id, aw.body.take().ok_or_else(|| anyhow!("offload body already consumed"))?)
        };
        let shard = self.placer.pick(
            d,
            |s| self.servers[s].accepting(),
            |s| self.servers[s].outstanding(),
            |s| self.service.capacity(s),
        );
        // fleet-level placement decision: which shard got this offload
        let placed = Lane::Server(shard as u32);
        self.tracer.instant(placed, obs::EventKind::Placement, id as u64, t, d as f64);
        let idx = id % self.testset.len();
        let feats = match &body {
            UplinkBody::Whole(frame) => {
                if self.policies.is_empty() {
                    if self.decoded[idx].is_none() {
                        let feats = self.servers[shard]
                            .side
                            .decode(frame)
                            .with_context(|| format!("decoding request {id}"))?;
                        self.decoded[idx] = Some(feats);
                    }
                    self.decoded[idx].as_ref().expect("just decoded").clone()
                } else {
                    // with the policy on, the decode depends on the frame's
                    // width too — memo keyed by (sample, width)
                    let key = (idx, frame.bits);
                    if !self.decoded_multi.contains_key(&key) {
                        let feats = self.servers[shard]
                            .side
                            .decode(frame)
                            .with_context(|| format!("decoding request {id}"))?;
                        self.decoded_multi.insert(key, feats);
                    }
                    self.decoded_multi.get(&key).expect("just memoized").clone()
                }
            }
            UplinkBody::Packets { packets, count, bits } => self.servers[shard]
                .side
                .decode_packets(packets, *count, *bits)
                .with_context(|| format!("decoding request {id}"))?,
        };
        if let Some(batch) = self.servers[shard].queue.push(id as u64, (d, feats), t) {
            return self.dispatch(shard, batch, t);
        }
        if self.servers[shard].queue.len() == 1 {
            let at = self.servers[shard].queue.next_deadline_at().expect("just pushed");
            self.schedule(at, EventKind::Deadline { shard });
        }
        // mirror the threaded loop's post-message poll: an already-expired
        // deadline dispatches at the arrival instant
        if let Some(batch) = self.servers[shard].queue.poll_deadline(t) {
            return self.dispatch(shard, batch, t);
        }
        Ok(())
    }

    fn handle_deadline(&mut self, t: f64, shard: usize) -> Result<()> {
        if let Some(batch) = self.servers[shard].queue.poll_deadline(t) {
            self.dispatch(shard, batch, t)?;
        }
        self.maybe_retire(shard, t);
        Ok(())
    }

    /// A batch's virtual service time elapsed: resume its devices.
    /// Completions are FIFO per shard (service starts serialize on
    /// `busy_until`), so pop every front batch whose finish time has
    /// arrived; later wake-ups for the same shard are no-ops.
    fn handle_batch_done(&mut self, t: f64, shard: usize) -> Result<()> {
        while let Some(front) = self.servers[shard].in_service.front() {
            if front.t_finish > t {
                break;
            }
            let b = self.servers[shard].in_service.pop_front().expect("front exists");
            self.complete(b.batch, b.rows, b.t_finish, b.advert_depth)?;
        }
        self.maybe_retire(shard, t);
        Ok(())
    }

    /// Run one batch through the shard's remote NN and start its virtual
    /// service — the threaded `run_batch` + reply delivery. With the zero
    /// service model on an idle shard the batch completes inline at `t`,
    /// the pre-autoscale code path expression for expression; otherwise
    /// the completion is deferred to a [`EventKind::BatchDone`] event at
    /// `max(t, busy_until) + service_s`.
    fn dispatch(
        &mut self,
        shard: usize,
        batch: Vec<crate::coordinator::batcher::Pending<(usize, Tensor)>>,
        t: f64,
    ) -> Result<()> {
        let feats: Vec<Tensor> = batch.iter().map(|p| p.payload.1.clone()).collect();
        let rows = self.servers[shard]
            .side
            .infer_batch(&feats)
            .with_context(|| format!("remote batch of {} failed on server {shard}", batch.len()))?;
        // queue depth after this batch was pulled, at the dispatch instant
        // — exactly what the threaded server stamps on every reply of the
        // batch, the advertisement device policies observe
        let advert_depth = self.servers[shard].queue.len();
        let start = t.max(self.servers[shard].busy_until);
        let service_s = self.service.batch_service_s(shard, batch.len());
        let t_finish = start + service_s;
        let agg = &mut self.servers[shard].agg;
        agg.batched += batch.len();
        agg.batches += 1;
        let lane = Lane::Server(shard as u32);
        for p in &batch {
            // queue wait runs until service *starts*: on a busy shard the
            // backlog is visible here, which is exactly the congestion
            // signal the autoscale controller watches
            let wait = start - p.enqueued;
            agg.queue_wait.record(wait);
            self.tracer.span(lane, obs::EventKind::ServerQueue, p.id, p.enqueued, start, 0.0);
            if let Some(ctl) = self.controller.as_mut() {
                ctl.observe(shard, t, wait);
            }
        }
        let seq = agg.batches as u64;
        self.tracer.instant(lane, obs::EventKind::BatchDispatch, seq, t, batch.len() as f64);
        if t_finish <= t {
            self.complete(batch, rows, t, advert_depth)
        } else {
            self.servers[shard].busy_until = t_finish;
            self.servers[shard]
                .in_service
                .push_back(InService { batch, rows, t_finish, advert_depth });
            self.schedule(t_finish, EventKind::BatchDone { shard });
            Ok(())
        }
    }

    /// Resume every device whose request rode one serviced batch.
    fn complete(
        &mut self,
        batch: Vec<crate::coordinator::batcher::Pending<(usize, Tensor)>>,
        rows: Vec<Vec<f32>>,
        t_finish: f64,
        advert_depth: usize,
    ) -> Result<()> {
        for (p, row) in batch.into_iter().zip(rows) {
            let d = p.payload.0;
            let aw = self.devices[d]
                .awaiting
                .take()
                .ok_or_else(|| anyhow!("reply for device {d} with nothing in flight"))?;
            let remote_s = t_finish - aw.t_send;
            let t_done = t_finish + aw.downlink_s;
            let dlane = Lane::Device(d as u32);
            let rid = aw.id as u64;
            self.tracer.span(dlane, obs::EventKind::Remote, rid, aw.t_send, t_finish, 0.0);
            self.tracer.span(dlane, obs::EventKind::Downlink, rid, t_finish, t_done, 0.0);
            // feed the EWMAs: this exchange's link stats plus the queue
            // depth the dispatching shard advertised on the reply
            if let Some(pol) = self.policies.get_mut(d) {
                pol.observe(&aw.link.stats, advert_depth);
            }
            self.emit(
                d,
                aw.j,
                aw.id,
                &aw.local,
                Some(&row),
                aw.tx_bytes,
                remote_s,
                Some(&aw.link),
                aw.policy,
                t_done,
            )?;
        }
        Ok(())
    }

    /// One autoscale control tick: feed the accepting mask to the
    /// controller and apply its decision. Ticks stop rescheduling once
    /// every request has been emitted, letting the event heap drain.
    fn handle_control_tick(&mut self, t: f64) -> Result<()> {
        if self.stopped || self.remaining == 0 {
            return Ok(());
        }
        let accepting: Vec<bool> = self.servers.iter().map(|s| s.accepting()).collect();
        let ctl = self.controller.as_mut().expect("control tick without a controller");
        let decision = ctl.on_tick(t, &accepting);
        let pressure = ctl.last_pressure_s;
        let interval = ctl.cfg.interval_s;
        match decision {
            ScaleDecision::Hold => {}
            ScaleDecision::Out => {
                // prefer cancelling the most recent drain (the shard is
                // still provisioned and billing); otherwise activate the
                // lowest-index inactive slot
                let target = (0..self.servers.len())
                    .rev()
                    .find(|&s| self.servers[s].draining)
                    .or_else(|| (0..self.servers.len()).find(|&s| !self.servers[s].active));
                if let Some(s) = target {
                    let st = &mut self.servers[s];
                    st.draining = false;
                    st.active = true;
                    st.lifetime.activate(t);
                    let after = self.servers.iter().filter(|s| s.accepting()).count();
                    self.record_scale(ScaleKind::Out, s, t, after, pressure);
                }
            }
            ScaleDecision::In => {
                // drain the highest-index accepting shard: no new
                // placements from this instant, retirement once its queue
                // and in-service batches empty (drain-before-retire — no
                // request is ever dropped)
                if let Some(s) = (0..self.servers.len()).rev().find(|&s| self.servers[s].accepting())
                {
                    self.servers[s].draining = true;
                    self.servers[s].drain_pressure = pressure;
                    self.maybe_retire(s, t);
                }
            }
        }
        self.schedule(t + interval, EventKind::ControlTick);
        Ok(())
    }

    /// Retire a fully drained shard: close its lifetime interval and
    /// record the scale-in. No-op unless the shard is draining and empty.
    fn maybe_retire(&mut self, shard: usize, t: f64) {
        let st = &mut self.servers[shard];
        if !st.draining || st.queue.len() != 0 || !st.in_service.is_empty() {
            return;
        }
        st.draining = false;
        st.active = false;
        st.lifetime.retire(t);
        let pressure = st.drain_pressure;
        let after = self.servers.iter().filter(|s| s.accepting()).count();
        self.record_scale(ScaleKind::In, shard, t, after, pressure);
    }

    /// Append one applied scale action and its trace instant.
    fn record_scale(&mut self, kind: ScaleKind, shard: usize, t: f64, after: usize, pressure: f64) {
        let ev_kind = match kind {
            ScaleKind::Out => obs::EventKind::ScaleOut,
            ScaleKind::In => obs::EventKind::ScaleIn,
        };
        self.tracer.instant(Lane::Server(shard as u32), ev_kind, shard as u64, t, after as f64);
        self.scale_events.push(ScaleEvent { t_s: t, shard, kind, active_after: after, pressure_s: pressure });
    }

    /// Assemble and stream one finished request, then advance the device
    /// to its next arrival.
    #[allow(clippy::too_many_arguments)]
    fn emit(
        &mut self,
        d: usize,
        j: usize,
        id: usize,
        local: &LocalResult,
        remote: Option<&[f32]>,
        tx_bytes: usize,
        remote_s: f64,
        link: Option<&LinkOutcome>,
        policy: Option<PolicyOutcome>,
        t_done: f64,
    ) -> Result<()> {
        let idx = id % self.testset.len();
        let outcome = assemble_outcome(
            self.fuser.as_ref(),
            local,
            remote,
            self.testset.labels[idx],
            tx_bytes,
            remote_s,
            &self.dev_sim,
            &self.net_sim,
            link,
            self.num_classes,
        )?;
        let lane = Lane::Device(d as u32);
        let correct = outcome.correct as u64 as f64;
        self.tracer.instant(lane, obs::EventKind::Done, id as u64, t_done, correct);
        let served = ServedOutcome {
            id: id as u64,
            device: d,
            // sojourn from the scheduled arrival, the sim-clock convention
            wall_s: t_done - self.devices[d].times[j],
            outcome,
            policy,
        };
        self.t_end = self.t_end.max(t_done);
        self.remaining = self.remaining.saturating_sub(1);
        if self.tx_done.send(served).is_err() {
            self.stopped = true;
        }
        let st = &mut self.devices[d];
        st.next = j + 1;
        if st.next < st.ids.len() && !self.stopped {
            let t_next = st.times[st.next].max(t_done);
            self.schedule(t_next, EventKind::Device { device: d });
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sim_engine_and_placement_parse() {
        assert_eq!("event".parse::<SimEngine>().unwrap(), SimEngine::Event);
        assert_eq!("THREADS".parse::<SimEngine>().unwrap(), SimEngine::Threads);
        assert!("fibers".parse::<SimEngine>().is_err());
        assert_eq!(SimEngine::default(), SimEngine::Event);
        assert_eq!(SimEngine::Event.name(), "event");

        assert_eq!("static".parse::<Placement>().unwrap(), Placement::Static);
        assert_eq!("rr".parse::<Placement>().unwrap(), Placement::RoundRobin);
        assert_eq!("round-robin".parse::<Placement>().unwrap(), Placement::RoundRobin);
        assert_eq!("least".parse::<Placement>().unwrap(), Placement::LeastLoaded);
        assert_eq!("weighted".parse::<Placement>().unwrap(), Placement::WeightedLeastLoaded);
        assert!("hash".parse::<Placement>().is_err());
        assert_eq!(Placement::default(), Placement::Static);
        for p in [
            Placement::Static,
            Placement::RoundRobin,
            Placement::LeastLoaded,
            Placement::WeightedLeastLoaded,
        ] {
            assert_eq!(p.name().parse::<Placement>().unwrap(), p);
        }
    }

    #[test]
    fn static_placement_is_a_pure_function_of_the_device_index() {
        let mut p = Placer::new(Placement::Static, 4);
        // load and call history are irrelevant; renumbering a device
        // renumbers its shard the same way every time
        for round in 0..3 {
            for d in 0..16 {
                let shard = p.pick(d, |_| true, |s| (s * 31 + round) % 7, |_| 1.0);
                assert_eq!(shard, d % 4, "device {d} round {round}");
            }
        }
    }

    #[test]
    fn static_placement_maps_onto_the_accepting_set() {
        // with shard 1 draining, `device % 3` walks the remaining shards
        // {0, 2, 3} — deterministic and never lands on the drained one
        let mut p = Placer::new(Placement::Static, 4);
        let accepting = |s: usize| s != 1;
        let picks: Vec<usize> = (0..6).map(|d| p.pick(d, accepting, |_| 0, |_| 1.0)).collect();
        assert_eq!(picks, vec![0, 2, 3, 0, 2, 3]);
    }

    #[test]
    fn round_robin_cycles_regardless_of_device() {
        let mut p = Placer::new(Placement::RoundRobin, 3);
        let picks: Vec<usize> =
            [7usize, 7, 7, 0, 1, 2, 9].iter().map(|&d| p.pick(d, |_| true, |_| 0, |_| 1.0)).collect();
        assert_eq!(picks, vec![0, 1, 2, 0, 1, 2, 0]);
        // a non-accepting shard is skipped without stalling the cycle
        let picks: Vec<usize> = (0..4).map(|d| p.pick(d, |s| s != 1, |_| 0, |_| 1.0)).collect();
        assert_eq!(picks, vec![2, 0, 2, 0]);
    }

    #[test]
    fn least_loaded_picks_the_minimum_and_rotates_ties() {
        let mut p = Placer::new(Placement::LeastLoaded, 4);
        // cursor at 0: the strict minimum (two servers tie at 1) is taken
        // in rotation order -> server 1; cursor moves past it
        let loads = [3usize, 1, 4, 1];
        assert_eq!(p.pick(0, |_| true, |s| loads[s], |_| 1.0), 1, "first minimum in rotation order");
        // flat depths degenerate to round-robin from the cursor (now 2)
        let uniform = [2usize, 2, 2, 2];
        assert_eq!(p.pick(5, |_| true, |s| uniform[s], |_| 1.0), 2);
        assert_eq!(p.pick(5, |_| true, |s| uniform[s], |_| 1.0), 3);
        assert_eq!(p.pick(5, |_| true, |s| uniform[s], |_| 1.0), 0);
        // a strictly emptier server still wins over the rotation
        let empty_last = [5usize, 4, 3, 0];
        assert_eq!(p.pick(1, |_| true, |s| empty_last[s], |_| 1.0), 3);
    }

    #[test]
    fn weighted_least_loaded_normalizes_by_capacity() {
        let mut p = Placer::new(Placement::WeightedLeastLoaded, 3);
        // loads 4/2/3 over capacities 4/1/1: normalized 1.0 / 2.0 / 3.0 —
        // the big server wins despite holding the deepest raw queue
        let loads = [4usize, 2, 3];
        let caps = [4.0, 1.0, 1.0];
        assert_eq!(p.pick(0, |_| true, |s| loads[s], |s| caps[s]), 0);
        // with uniform capacity it is exactly least-loaded (min at 1)
        assert_eq!(p.pick(0, |_| true, |s| loads[s], |_| 1.0), 1);
        // non-accepting shards are excluded even when normalized-best
        assert_eq!(p.pick(0, |s| s != 0, |s| loads[s], |s| caps[s]), 1);
    }

    #[test]
    fn least_loaded_on_empty_queues_is_round_robin() {
        // the serving fleet's common case: queues drained between bursts.
        // A lowest-index tie-break would return 0 forever and overload one
        // shard; the rotation spreads the burst evenly.
        let mut p = Placer::new(Placement::LeastLoaded, 3);
        let picks: Vec<usize> = (0..7).map(|d| p.pick(d, |_| true, |_| 0, |_| 1.0)).collect();
        assert_eq!(picks, vec![0, 1, 2, 0, 1, 2, 0]);
    }

    #[test]
    fn event_heap_orders_by_time_then_schedule_order() {
        let mut heap = BinaryHeap::new();
        let ev = |t: f64, seq: u64| Ev { t, seq, kind: EventKind::Deadline { shard: 0 } };
        heap.push(ev(2.0, 0));
        heap.push(ev(1.0, 3));
        heap.push(ev(1.0, 1));
        heap.push(ev(0.5, 4));
        let order: Vec<(f64, u64)> =
            std::iter::from_fn(|| heap.pop()).map(|e| (e.t, e.seq)).collect();
        assert_eq!(order, vec![(0.5, 4), (1.0, 1), (1.0, 3), (2.0, 0)]);
    }
}
