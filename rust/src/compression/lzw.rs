//! LZW codec — the paper's §6 transmitter ("we first adopt learning-based
//! quantization and then apply standard LZW compression").
//!
//! Classic dictionary LZW over bytes with 12-bit codes (dictionary reset at
//! 4096 entries), output bit-packed MSB-first. The zero-heavy quantized
//! feature streams AgileNN produces compress extremely well here, which is
//! the mechanism behind Table 2's transmitted-byte reductions.

use anyhow::{bail, Result};

const MAX_CODE: usize = 1 << 12; // 12-bit codes
const RESET_SENTINEL: u16 = 256; // emitted when the dictionary resets
const FIRST_FREE: u16 = 257;

/// Bit writer, MSB-first.
struct BitWriter {
    out: Vec<u8>,
    acc: u32,
    nbits: u32,
}

impl BitWriter {
    fn new() -> Self {
        Self { out: Vec::new(), acc: 0, nbits: 0 }
    }

    fn push(&mut self, code: u16, width: u32) {
        self.acc = (self.acc << width) | u32::from(code);
        self.nbits += width;
        while self.nbits >= 8 {
            self.nbits -= 8;
            self.out.push((self.acc >> self.nbits) as u8);
        }
    }

    fn finish(mut self) -> Vec<u8> {
        if self.nbits > 0 {
            self.out.push((self.acc << (8 - self.nbits)) as u8);
        }
        self.out
    }
}

/// Bit reader, MSB-first.
struct BitReader<'a> {
    input: &'a [u8],
    pos: usize,
    acc: u32,
    nbits: u32,
}

impl<'a> BitReader<'a> {
    fn new(input: &'a [u8]) -> Self {
        Self { input, pos: 0, acc: 0, nbits: 0 }
    }

    fn pull(&mut self, width: u32) -> Option<u16> {
        while self.nbits < width {
            if self.pos >= self.input.len() {
                return None;
            }
            self.acc = (self.acc << 8) | u32::from(self.input[self.pos]);
            self.pos += 1;
            self.nbits += 8;
        }
        self.nbits -= width;
        Some(((self.acc >> self.nbits) & ((1 << width) - 1)) as u16)
    }
}

fn code_width(next_code: usize) -> u32 {
    // enough bits for the largest code currently assignable
    let mut w = 9;
    while (1usize << w) < next_code + 1 {
        w += 1;
    }
    w
}

/// Open-addressed (prefix-code, byte) -> code dictionary.
///
/// Perf: the std HashMap's SipHash dominated the encoder profile
/// (EXPERIMENTS.md §Perf); LZW needs at most 4096 live entries with u32
/// keys, so a fixed 8192-slot linear-probe table with a multiplicative hash
/// is both allocation-free after construction and ~3x faster. Generation
/// tagging makes `clear()` O(1) for the dictionary-reset path.
struct Dict {
    keys: Vec<u32>,
    vals: Vec<u16>,
    gens: Vec<u32>,
    gen: u32,
}

const DICT_SLOTS: usize = 8192; // 2x MAX_CODE keeps load factor <= 0.5

impl Dict {
    fn new() -> Self {
        Self {
            keys: vec![0; DICT_SLOTS],
            vals: vec![0; DICT_SLOTS],
            gens: vec![0; DICT_SLOTS],
            gen: 1,
        }
    }

    #[inline]
    fn slot(key: u32) -> usize {
        // Fibonacci hashing; table size is a power of two
        ((key.wrapping_mul(0x9E37_79B9)) >> (32 - 13)) as usize
    }

    #[inline]
    fn get(&self, key: u32) -> Option<u16> {
        let mut i = Self::slot(key);
        loop {
            if self.gens[i] != self.gen {
                return None;
            }
            if self.keys[i] == key {
                return Some(self.vals[i]);
            }
            i = (i + 1) & (DICT_SLOTS - 1);
        }
    }

    #[inline]
    fn insert(&mut self, key: u32, val: u16) {
        let mut i = Self::slot(key);
        while self.gens[i] == self.gen {
            i = (i + 1) & (DICT_SLOTS - 1);
        }
        self.keys[i] = key;
        self.vals[i] = val;
        self.gens[i] = self.gen;
    }

    #[inline]
    fn clear(&mut self) {
        self.gen += 1;
    }
}

/// LZW-compress a byte stream.
pub fn compress(input: &[u8]) -> Vec<u8> {
    if input.is_empty() {
        return Vec::new();
    }
    let mut dict = Dict::new();
    let mut next: u16 = FIRST_FREE;
    let mut w = BitWriter::new();
    let mut cur: u16 = u16::from(input[0]);
    for &byte in &input[1..] {
        let key = (u32::from(cur) << 8) | u32::from(byte);
        match dict.get(key) {
            Some(code) => cur = code,
            None => {
                w.push(cur, code_width(next as usize));
                if (next as usize) < MAX_CODE {
                    dict.insert(key, next);
                    next += 1;
                } else {
                    w.push(RESET_SENTINEL, code_width(next as usize));
                    dict.clear();
                    next = FIRST_FREE;
                }
                cur = u16::from(byte);
            }
        }
    }
    w.push(cur, code_width(next as usize));
    w.finish()
}

/// Inverse of [`compress`].
///
/// Perf: entries are (prefix-code, byte) parent pointers expanded in place —
/// no per-entry `Vec` allocation (EXPERIMENTS.md §Perf). `prev`/`entry` are
/// tracked as (start, len) ranges into `out`, so emitting an entry is a
/// within-vector copy.
pub fn decompress(input: &[u8]) -> Result<Vec<u8>> {
    if input.is_empty() {
        return Ok(Vec::new());
    }
    // parent[c] = (prefix code, appended byte); codes < 256 are literals
    let mut parent: Vec<(u16, u8)> = Vec::with_capacity(MAX_CODE);
    let reset_table = |parent: &mut Vec<(u16, u8)>| {
        parent.clear();
        for b in 0..=255u16 {
            parent.push((u16::MAX, b as u8));
        }
        parent.push((u16::MAX, 0)); // 256 reset sentinel placeholder
    };
    reset_table(&mut parent);

    let mut r = BitReader::new(input);
    let mut out: Vec<u8> = Vec::with_capacity(input.len() * 3);
    let mut scratch: Vec<u8> = Vec::with_capacity(64);

    // append the expansion of `code` to out; returns (start, len) of it
    let emit = |code: u16, parent: &[(u16, u8)], out: &mut Vec<u8>, scratch: &mut Vec<u8>| {
        let start = out.len();
        scratch.clear();
        let mut c = code;
        while c != u16::MAX {
            let (p, b) = parent[c as usize];
            scratch.push(b);
            c = p;
        }
        out.extend(scratch.iter().rev());
        (start, out.len() - start)
    };

    let first = match r.pull(code_width(parent.len() + 1)) {
        Some(c) => c,
        None => return Ok(out),
    };
    if first as usize >= parent.len() || first == RESET_SENTINEL {
        bail!("corrupt LZW stream: bad first code {first}");
    }
    let mut prev_code = first;
    let (mut prev_start, mut prev_len) = emit(first, &parent, &mut out, &mut scratch);
    loop {
        // width accounts for the entry we are about to add
        let width = code_width(parent.len() + 1);
        let code = match r.pull(width) {
            Some(c) => c,
            None => break,
        };
        if code == RESET_SENTINEL {
            reset_table(&mut parent);
            let width = code_width(parent.len() + 1);
            let c2 = match r.pull(width) {
                Some(c) => c,
                None => break,
            };
            if c2 as usize >= parent.len() || c2 == RESET_SENTINEL {
                bail!("corrupt LZW stream after reset: code {c2}");
            }
            prev_code = c2;
            (prev_start, prev_len) = emit(c2, &parent, &mut out, &mut scratch);
            continue;
        }
        let (entry_start, entry_len);
        if (code as usize) < parent.len() {
            (entry_start, entry_len) = emit(code, &parent, &mut out, &mut scratch);
        } else if code as usize == parent.len() {
            // KwKwK special case: entry = prev + prev[0]
            entry_start = out.len();
            let first_byte = out[prev_start];
            out.extend_from_within(prev_start..prev_start + prev_len);
            out.push(first_byte);
            entry_len = prev_len + 1;
        } else {
            bail!("corrupt LZW stream: code {code} beyond table {}", parent.len());
        }
        if parent.len() < MAX_CODE {
            parent.push((prev_code, out[entry_start]));
        }
        prev_code = code;
        (prev_start, prev_len) = (entry_start, entry_len);
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn roundtrip(data: &[u8]) {
        let c = compress(data);
        let d = decompress(&c).unwrap();
        assert_eq!(d, data, "roundtrip failed for len {}", data.len());
    }

    #[test]
    fn empty_and_single() {
        roundtrip(&[]);
        roundtrip(&[42]);
    }

    #[test]
    fn repetitive_compresses_well() {
        let data = vec![0u8; 4096];
        let c = compress(&data);
        assert!(c.len() < data.len() / 10, "got {} bytes", c.len());
        roundtrip(&data);
    }

    #[test]
    fn kwkwk_pattern() {
        // the classic aba ababa... case exercising code == table.len()
        roundtrip(b"abababababababababab");
    }

    #[test]
    fn incompressible_random_roundtrips() {
        let mut state = 0x12345678u32;
        let data: Vec<u8> = (0..10_000)
            .map(|_| {
                state = state.wrapping_mul(1664525).wrapping_add(1013904223);
                (state >> 24) as u8
            })
            .collect();
        roundtrip(&data);
    }

    #[test]
    fn dictionary_reset_path() {
        // enough distinct bigrams to overflow 4096 dictionary entries
        let mut data = Vec::new();
        for i in 0..60_000u32 {
            data.push((i % 251) as u8);
            data.push((i * 7 % 253) as u8);
        }
        roundtrip(&data);
    }

    #[test]
    fn zero_skewed_stream_high_ratio() {
        // quantized post-ReLU features: ~85% zeros — paper's sparsity case
        let mut state = 7u32;
        let data: Vec<u8> = (0..8192)
            .map(|_| {
                state = state.wrapping_mul(48271) % 0x7fffffff;
                if state % 100 < 85 {
                    0
                } else {
                    (state % 16) as u8
                }
            })
            .collect();
        let c = compress(&data);
        assert!(c.len() * 2 < data.len(), "ratio only {}/{}", c.len(), data.len());
        roundtrip(&data);
    }
}
