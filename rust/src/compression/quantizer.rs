//! Learned-codebook scalar quantizer (paper §6, [4]): the codebook is fitted
//! offline in python (k-means over the transmitted-feature distribution,
//! exported per bit-width in meta.json); the runtime only does a nearest-
//! codeword lookup — O(log n) binary search over midpoints.

use anyhow::{ensure, Result};

/// Scalar quantizer defined by a sorted codebook.
#[derive(Debug, Clone)]
pub struct Codebook {
    levels: Vec<f32>,
    /// decision boundaries: midpoint between adjacent codewords
    midpoints: Vec<f32>,
}

impl Codebook {
    pub fn new(mut levels: Vec<f32>) -> Result<Self> {
        ensure!(!levels.is_empty(), "empty codebook");
        ensure!(levels.len() <= 256, "codebook larger than u8 index space");
        levels.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let midpoints = levels.windows(2).map(|w| (w[0] + w[1]) / 2.0).collect();
        Ok(Self { levels, midpoints })
    }

    pub fn len(&self) -> usize {
        self.levels.len()
    }

    pub fn is_empty(&self) -> bool {
        self.levels.is_empty()
    }

    /// Bits per symbol this codebook implies.
    pub fn bits(&self) -> u32 {
        (usize::BITS - (self.levels.len() - 1).leading_zeros()).max(1)
    }

    pub fn levels(&self) -> &[f32] {
        &self.levels
    }

    /// Nearest-codeword index.
    #[inline]
    pub fn index_of(&self, v: f32) -> u8 {
        self.midpoints.partition_point(|&m| m < v) as u8
    }

    pub fn quantize(&self, values: &[f32], out: &mut Vec<u8>) {
        out.clear();
        out.reserve(values.len());
        out.extend(values.iter().map(|&v| self.index_of(v)));
    }

    pub fn dequantize(&self, indices: &[u8], out: &mut Vec<f32>) {
        out.clear();
        out.reserve(indices.len());
        out.extend(indices.iter().map(|&i| self.levels[(i as usize).min(self.levels.len() - 1)]));
    }
}

/// Pack `bits`-wide indices into a dense byte stream (MSB-first).
pub fn bitpack(indices: &[u8], bits: u32) -> Vec<u8> {
    debug_assert!(bits >= 1 && bits <= 8);
    let mut out = Vec::with_capacity((indices.len() * bits as usize + 7) / 8);
    let mut acc: u32 = 0;
    let mut n: u32 = 0;
    for &i in indices {
        acc = (acc << bits) | u32::from(i);
        n += bits;
        while n >= 8 {
            n -= 8;
            out.push((acc >> n) as u8);
        }
    }
    if n > 0 {
        out.push((acc << (8 - n)) as u8);
    }
    out
}

/// Inverse of [`bitpack`]; `count` symbols are recovered.
pub fn bitunpack(bytes: &[u8], bits: u32, count: usize) -> Vec<u8> {
    debug_assert!(bits >= 1 && bits <= 8);
    let mut out = Vec::with_capacity(count);
    let mut acc: u32 = 0;
    let mut n: u32 = 0;
    let mask: u32 = (1 << bits) - 1;
    let mut it = bytes.iter();
    while out.len() < count {
        while n < bits {
            match it.next() {
                Some(&b) => {
                    acc = (acc << 8) | u32::from(b);
                    n += 8;
                }
                None => return out, // truncated stream: best-effort
            }
        }
        n -= bits;
        out.push(((acc >> n) & mask) as u8);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cb4() -> Codebook {
        Codebook::new(vec![0.0, 0.5, 1.0, 2.0]).unwrap()
    }

    #[test]
    fn nearest_codeword() {
        let cb = cb4();
        assert_eq!(cb.index_of(-1.0), 0);
        assert_eq!(cb.index_of(0.2), 0);
        assert_eq!(cb.index_of(0.3), 1);
        assert_eq!(cb.index_of(0.8), 2);
        assert_eq!(cb.index_of(5.0), 3);
    }

    #[test]
    fn bits_computation() {
        assert_eq!(Codebook::new(vec![0.0, 1.0]).unwrap().bits(), 1);
        assert_eq!(cb4().bits(), 2);
        assert_eq!(Codebook::new((0..64).map(|i| i as f32).collect()).unwrap().bits(), 6);
    }

    #[test]
    fn quantize_dequantize_is_nearest() {
        let cb = cb4();
        let vals = [0.1f32, 0.6, 1.4, 3.0];
        let (mut idx, mut deq) = (Vec::new(), Vec::new());
        cb.quantize(&vals, &mut idx);
        cb.dequantize(&idx, &mut deq);
        assert_eq!(deq, vec![0.0, 0.5, 1.0, 2.0]); // 1.4 -> 1.0 (midpoint 1.5)
    }

    #[test]
    fn empty_and_oversize_codebooks_rejected() {
        assert!(Codebook::new(vec![]).is_err());
        assert!(Codebook::new(vec![0.0; 257]).is_err());
    }

    #[test]
    fn bitpack_roundtrip_all_widths() {
        for bits in 1..=8u32 {
            let n = 101;
            let idx: Vec<u8> = (0..n).map(|i| (i % (1 << bits)) as u8).collect();
            let packed = bitpack(&idx, bits);
            assert_eq!(packed.len(), (n * bits as usize + 7) / 8);
            assert_eq!(bitunpack(&packed, bits, n), idx);
        }
    }

    #[test]
    fn bitunpack_truncated_is_best_effort() {
        let idx = vec![3u8; 16];
        let packed = bitpack(&idx, 4);
        let got = bitunpack(&packed[..4], 4, 16);
        assert_eq!(got, vec![3u8; 8]);
    }
}
