//! JPEG-style 8x8 DCT + quantization codec for the raw-data-compression
//! baselines (paper Fig 2: "standard JPEG" compressing the NN input before
//! transmission; higher quality factor = lower compression rate).
//!
//! This is deliberately the minimal transform-coding pipeline — blockwise
//! DCT-II, quality-scaled quantization table, zig-zag + LZW entropy stage —
//! enough to reproduce Fig 2's accuracy-vs-rate tradeoff shape.

use super::lzw;
use anyhow::{ensure, Result};

const N: usize = 8;

/// Luminance quantization table (ITU-T T.81 Annex K).
#[rustfmt::skip]
const QTABLE: [f32; 64] = [
    16., 11., 10., 16., 24., 40., 51., 61.,
    12., 12., 14., 19., 26., 58., 60., 55.,
    14., 13., 16., 24., 40., 57., 69., 56.,
    14., 17., 22., 29., 51., 87., 80., 62.,
    18., 22., 37., 56., 68., 109., 103., 77.,
    24., 35., 55., 64., 81., 104., 113., 92.,
    49., 64., 78., 87., 103., 121., 120., 101.,
    72., 92., 95., 98., 112., 100., 103., 99.,
];

fn quality_scale(quality: f32) -> f32 {
    // libjpeg quality mapping
    let q = quality.clamp(1.0, 100.0);
    if q < 50.0 {
        50.0 / q
    } else {
        2.0 - q / 50.0
    }
}

fn dct_1d(input: &[f32; N], out: &mut [f32; N]) {
    for (k, o) in out.iter_mut().enumerate() {
        let mut s = 0.0;
        for (n, &v) in input.iter().enumerate() {
            s += v * ((std::f32::consts::PI / N as f32) * (n as f32 + 0.5) * k as f32).cos();
        }
        let scale = if k == 0 { (1.0 / N as f32).sqrt() } else { (2.0 / N as f32).sqrt() };
        *o = s * scale;
    }
}

fn idct_1d(input: &[f32; N], out: &mut [f32; N]) {
    for (n, o) in out.iter_mut().enumerate() {
        let mut s = input[0] * (1.0 / N as f32).sqrt();
        for (k, &v) in input.iter().enumerate().skip(1) {
            s += v
                * (2.0 / N as f32).sqrt()
                * ((std::f32::consts::PI / N as f32) * (n as f32 + 0.5) * k as f32).cos();
        }
        *o = s;
    }
}

fn block_transform(block: &mut [f32; 64], inverse: bool) {
    let mut tmp = [0.0f32; 64];
    let (mut row_in, mut row_out) = ([0.0f32; N], [0.0f32; N]);
    // rows
    for r in 0..N {
        row_in.copy_from_slice(&block[r * N..(r + 1) * N]);
        if inverse {
            idct_1d(&row_in, &mut row_out);
        } else {
            dct_1d(&row_in, &mut row_out);
        }
        tmp[r * N..(r + 1) * N].copy_from_slice(&row_out);
    }
    // columns
    for c in 0..N {
        for r in 0..N {
            row_in[r] = tmp[r * N + c];
        }
        if inverse {
            idct_1d(&row_in, &mut row_out);
        } else {
            dct_1d(&row_in, &mut row_out);
        }
        for r in 0..N {
            block[r * N + c] = row_out[r];
        }
    }
}

/// Zig-zag scan order for an 8x8 block.
fn zigzag_order() -> [usize; 64] {
    let mut order = [0usize; 64];
    let (mut r, mut c, mut up) = (0i32, 0i32, true);
    for o in order.iter_mut() {
        *o = (r * 8 + c) as usize;
        if up {
            if c == 7 {
                r += 1;
                up = false;
            } else if r == 0 {
                c += 1;
                up = false;
            } else {
                r -= 1;
                c += 1;
            }
        } else if r == 7 {
            c += 1;
            up = true;
        } else if c == 0 {
            r += 1;
            up = true;
        } else {
            r += 1;
            c -= 1;
        }
    }
    order
}

/// Encoded image: quantized DCT coefficients, LZW-entropy-coded.
pub struct DctEncoded {
    pub payload: Vec<u8>,
    pub h: usize,
    pub w: usize,
    pub c: usize,
    pub quality: f32,
}

/// Encode an HWC f32 image in [0,1]. Dimensions must be multiples of 8.
pub fn encode(img: &[f32], h: usize, w: usize, c: usize, quality: f32) -> Result<DctEncoded> {
    ensure!(img.len() == h * w * c, "image size mismatch");
    ensure!(h % N == 0 && w % N == 0, "dims must be multiples of 8");
    let scale = quality_scale(quality);
    let zz = zigzag_order();
    // i16 coefficients, serialized as zig-zagged bytes (i8 saturating) + LZW
    let mut symbols: Vec<u8> = Vec::with_capacity(img.len());
    let mut block = [0.0f32; 64];
    for ch in 0..c {
        for by in (0..h).step_by(N) {
            for bx in (0..w).step_by(N) {
                for r in 0..N {
                    for cc in 0..N {
                        block[r * N + cc] = img[((by + r) * w + bx + cc) * c + ch] * 255.0 - 128.0;
                    }
                }
                block_transform(&mut block, false);
                for &zi in zz.iter() {
                    let q = (QTABLE[zi] * scale).max(1.0);
                    let v = (block[zi] / q).round().clamp(-127.0, 127.0) as i8;
                    symbols.push(v as u8);
                }
            }
        }
    }
    Ok(DctEncoded { payload: lzw::compress(&symbols), h, w, c, quality })
}

/// Decode back to an HWC f32 image in [0,1].
pub fn decode(enc: &DctEncoded) -> Result<Vec<f32>> {
    let symbols = lzw::decompress(&enc.payload)?;
    ensure!(symbols.len() == enc.h * enc.w * enc.c, "corrupt DCT payload");
    let scale = quality_scale(enc.quality);
    let zz = zigzag_order();
    let mut img = vec![0.0f32; enc.h * enc.w * enc.c];
    let mut block = [0.0f32; 64];
    let mut si = 0;
    for ch in 0..enc.c {
        for by in (0..enc.h).step_by(N) {
            for bx in (0..enc.w).step_by(N) {
                for &zi in zz.iter() {
                    let q = (QTABLE[zi] * scale).max(1.0);
                    block[zi] = (symbols[si] as i8) as f32 * q;
                    si += 1;
                }
                block_transform(&mut block, true);
                for r in 0..N {
                    for cc in 0..N {
                        img[((by + r) * enc.w + bx + cc) * enc.c + ch] =
                            ((block[r * N + cc] + 128.0) / 255.0).clamp(0.0, 1.0);
                    }
                }
            }
        }
    }
    Ok(img)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn test_image() -> Vec<f32> {
        (0..32 * 32 * 3)
            .map(|i| (((i % 37) as f32 / 37.0) + ((i / 96) as f32 / 40.0)).fract())
            .collect()
    }

    #[test]
    fn roundtrip_error_shrinks_with_quality() {
        let img = test_image();
        let mut errs = Vec::new();
        for q in [10.0, 50.0, 95.0] {
            let enc = encode(&img, 32, 32, 3, q).unwrap();
            let dec = decode(&enc).unwrap();
            let mse: f32 =
                img.iter().zip(&dec).map(|(a, b)| (a - b) * (a - b)).sum::<f32>() / img.len() as f32;
            errs.push(mse);
        }
        assert!(errs[0] > errs[1] && errs[1] > errs[2], "{errs:?}");
    }

    #[test]
    fn lower_quality_smaller_payload() {
        let img = test_image();
        let hi = encode(&img, 32, 32, 3, 90.0).unwrap().payload.len();
        let lo = encode(&img, 32, 32, 3, 10.0).unwrap().payload.len();
        assert!(lo < hi, "lo={lo} hi={hi}");
    }

    #[test]
    fn smooth_image_compresses_hard() {
        let img = vec![0.5f32; 32 * 32 * 3];
        let enc = encode(&img, 32, 32, 3, 50.0).unwrap();
        assert!(enc.payload.len() < 32 * 32 * 3 / 10);
    }

    #[test]
    fn rejects_bad_dims() {
        assert!(encode(&[0.0; 10 * 10 * 3], 10, 10, 3, 50.0).is_err());
        assert!(encode(&[0.0; 100], 32, 32, 3, 50.0).is_err());
    }

    #[test]
    fn zigzag_is_permutation() {
        let mut seen = [false; 64];
        for i in zigzag_order() {
            assert!(!seen[i]);
            seen[i] = true;
        }
    }
}
