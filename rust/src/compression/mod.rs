//! Compression substrate: the device-side transmit pipeline
//! (learned quantization -> bit-packing -> LZW, paper §6) plus the
//! JPEG-style DCT codec used by the raw-compression baselines (Fig 2).

pub mod dct;
pub mod lzw;
pub mod quantizer;

use anyhow::Result;
use quantizer::Codebook;

/// Frame header as serialized by the wire protocol
/// (`crate::net::wire::encode_frame`): magic + version + bits + reserved +
/// count (u32) = 8 bytes. [`Frame::wire_bytes`] prices these same bytes on
/// the simulated link, so the simulator and the TCP transport agree on
/// what a frame costs.
pub const FRAME_HEADER_BYTES: usize = 8;

/// One compressed feature frame as it would go on the wire.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Frame {
    /// LZW-compressed bit-packed code indices.
    pub payload: Vec<u8>,
    /// number of feature elements encoded
    pub count: usize,
    /// bits per symbol before entropy coding
    pub bits: u32,
}

impl Frame {
    /// On-wire size in bytes (payload + [`FRAME_HEADER_BYTES`]-byte header
    /// carrying magic/version/bits/count).
    pub fn wire_bytes(&self) -> usize {
        self.payload.len() + FRAME_HEADER_BYTES
    }
}

/// Device-side transmit path: quantize -> bitpack -> LZW.
/// Scratch buffers are caller-provided so the hot loop does not allocate.
pub struct TxEncoder {
    codebook: Codebook,
    idx_scratch: Vec<u8>,
}

impl TxEncoder {
    pub fn new(codebook: Codebook) -> Self {
        Self { codebook, idx_scratch: Vec::new() }
    }

    pub fn codebook(&self) -> &Codebook {
        &self.codebook
    }

    pub fn encode(&mut self, values: &[f32]) -> Frame {
        let bits = self.codebook.bits();
        self.codebook.quantize(values, &mut self.idx_scratch);
        let packed = quantizer::bitpack(&self.idx_scratch, bits);
        Frame { payload: lzw::compress(&packed), count: values.len(), bits }
    }

    /// Quantized symbol indices of the last [`TxEncoder::encode`] call —
    /// the per-packet transport (`crate::net`) re-chunks these so each
    /// packet decodes independently.
    pub fn symbols(&self) -> &[u8] {
        &self.idx_scratch
    }
}

/// Server-side receive path: LZW -> bitunpack -> dequantize.
pub struct RxDecoder {
    codebook: Codebook,
}

impl RxDecoder {
    pub fn new(codebook: Codebook) -> Self {
        Self { codebook }
    }

    pub fn codebook(&self) -> &Codebook {
        &self.codebook
    }

    /// Dequantize an already-reassembled symbol stream (the partial-frame
    /// receive path, where unpacking happened per packet).
    pub fn dequantize_symbols(&self, symbols: &[u8]) -> Vec<f32> {
        let mut out = Vec::new();
        self.codebook.dequantize(symbols, &mut out);
        out
    }

    pub fn decode(&self, frame: &Frame) -> Result<Vec<f32>> {
        let packed = lzw::decompress(&frame.payload)?;
        let idx = quantizer::bitunpack(&packed, frame.bits, frame.count);
        let mut out = Vec::new();
        self.codebook.dequantize(&idx, &mut out);
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn skewed_features(n: usize) -> Vec<f32> {
        // post-ReLU-like: mostly zeros, a few positive values
        (0..n)
            .map(|i| if i % 7 == 0 { (i % 13) as f32 * 0.17 } else { 0.0 })
            .collect()
    }

    #[test]
    fn tx_rx_roundtrip_values_snap_to_codebook() {
        let cb = Codebook::new(vec![0.0, 0.25, 0.5, 1.0, 1.5, 2.0, 2.5, 3.0]).unwrap();
        let mut tx = TxEncoder::new(cb.clone());
        let rx = RxDecoder::new(cb.clone());
        let vals = skewed_features(1216);
        let frame = tx.encode(&vals);
        let back = rx.decode(&frame).unwrap();
        assert_eq!(back.len(), vals.len());
        for (orig, got) in vals.iter().zip(&back) {
            // got must be the nearest codeword of orig
            let nearest = cb.levels()[cb.index_of(*orig) as usize];
            assert_eq!(*got, nearest);
        }
    }

    #[test]
    fn skewed_stream_beats_raw_f32_by_a_lot() {
        let cb = Codebook::new((0..16).map(|i| i as f32 * 0.2).collect()).unwrap();
        let mut tx = TxEncoder::new(cb);
        let vals = skewed_features(1216); // AgileNN tx size: 8*8*19
        let frame = tx.encode(&vals);
        let raw = vals.len() * 4;
        assert!(
            frame.wire_bytes() * 8 < raw,
            "compressed {} vs raw {}",
            frame.wire_bytes(),
            raw
        );
    }

    #[test]
    fn wire_bytes_includes_header() {
        let cb = Codebook::new(vec![0.0, 1.0]).unwrap();
        let mut tx = TxEncoder::new(cb);
        let frame = tx.encode(&[0.0, 1.0, 0.0]);
        assert_eq!(frame.wire_bytes(), frame.payload.len() + FRAME_HEADER_BYTES);
    }
}
