//! Wireless-link model (substitutes the paper's ESP-WROOM WiFi module).
//!
//! Transfer time = packetized serialization delay + one-way latency.
//! Packetization matters: small payloads on a 244-byte-MTU BLE link pay a
//! much larger relative overhead than on WiFi, which is exactly the regime
//! Fig 23 sweeps.

use super::profiles::NetworkProfile;

#[derive(Debug, Clone)]
pub struct NetworkSim {
    pub profile: NetworkProfile,
}

impl NetworkSim {
    pub fn new(profile: NetworkProfile) -> Self {
        Self { profile }
    }

    /// Number of packets for `bytes` of application payload.
    pub fn packets(&self, bytes: usize) -> usize {
        if bytes == 0 {
            0
        } else {
            bytes.div_ceil(self.profile.mtu)
        }
    }

    /// On-air bytes including per-packet overhead.
    pub fn wire_bytes(&self, bytes: usize) -> usize {
        bytes + self.packets(bytes) * self.profile.per_packet_overhead
    }

    /// One-way transfer time for `bytes` of application payload, seconds.
    pub fn transfer_s(&self, bytes: usize) -> f64 {
        if bytes == 0 {
            return 0.0;
        }
        self.wire_bytes(bytes) as f64 * 8.0 / self.profile.bandwidth_bps
            + self.profile.one_way_latency_s
    }

    /// Radio-active airtime (serialization only, for the energy model).
    pub fn airtime_s(&self, bytes: usize) -> f64 {
        self.wire_bytes(bytes) as f64 * 8.0 / self.profile.bandwidth_bps
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::simulator::profiles::NetworkProfile;

    #[test]
    fn zero_bytes_zero_time() {
        let net = NetworkSim::new(NetworkProfile::wifi_6mbps());
        assert_eq!(net.transfer_s(0), 0.0);
        assert_eq!(net.packets(0), 0);
    }

    #[test]
    fn transfer_monotone_in_bytes() {
        let net = NetworkSim::new(NetworkProfile::wifi_6mbps());
        assert!(net.transfer_s(2000) > net.transfer_s(200));
    }

    #[test]
    fn packetization() {
        let net = NetworkSim::new(NetworkProfile::ble_270kbps());
        assert_eq!(net.packets(244), 1);
        assert_eq!(net.packets(245), 2);
        assert_eq!(net.wire_bytes(244), 244 + 10);
    }

    #[test]
    fn slow_link_slower() {
        let wifi = NetworkSim::new(NetworkProfile::wifi_6mbps());
        let ble = NetworkSim::new(NetworkProfile::ble_270kbps());
        assert!(ble.transfer_s(1000) > 10.0 * wifi.transfer_s(1000));
    }

    #[test]
    fn bandwidth_scaling() {
        let base = NetworkProfile::wifi_6mbps();
        let half = NetworkSim::new(base.with_bandwidth(3e6));
        let full = NetworkSim::new(base);
        let b = 10_000;
        assert!(half.airtime_s(b) / full.airtime_s(b) > 1.99);
    }
}
