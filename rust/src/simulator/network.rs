//! Wireless-link model (substitutes the paper's ESP-WROOM WiFi module).
//!
//! Transfer time = packetized serialization delay + one-way latency.
//! Packetization matters: small payloads on a 244-byte-MTU BLE link pay a
//! much larger relative overhead than on WiFi, which is exactly the regime
//! Fig 23 sweeps.
//!
//! Since the `net` channel subsystem landed, this type is a thin façade
//! over [`Channel::ideal`] — the zero-loss, constant-bandwidth fast path —
//! so the closed-form timing used by the synchronous benches and the lossy
//! channel used by serving share one implementation and cannot drift.

use super::profiles::NetworkProfile;
use crate::net::Channel;

#[derive(Debug, Clone)]
pub struct NetworkSim {
    pub profile: NetworkProfile,
    chan: Channel,
}

impl NetworkSim {
    pub fn new(profile: NetworkProfile) -> Self {
        let chan = Channel::ideal(&profile);
        Self { profile, chan }
    }

    /// Number of packets for `bytes` of application payload.
    pub fn packets(&self, bytes: usize) -> usize {
        self.chan.packets(bytes)
    }

    /// On-air bytes including per-packet overhead.
    pub fn wire_bytes(&self, bytes: usize) -> usize {
        self.chan.wire_bytes(bytes)
    }

    /// One-way transfer time for `bytes` of application payload, seconds.
    pub fn transfer_s(&self, bytes: usize) -> f64 {
        self.chan.transfer_s(0.0, bytes)
    }

    /// Radio-active airtime (serialization only, for the energy model).
    pub fn airtime_s(&self, bytes: usize) -> f64 {
        self.chan.airtime_s(0.0, bytes)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::simulator::profiles::NetworkProfile;

    #[test]
    fn zero_bytes_zero_time() {
        let net = NetworkSim::new(NetworkProfile::wifi_6mbps());
        assert_eq!(net.transfer_s(0), 0.0);
        assert_eq!(net.packets(0), 0);
    }

    #[test]
    fn transfer_monotone_in_bytes() {
        let net = NetworkSim::new(NetworkProfile::wifi_6mbps());
        assert!(net.transfer_s(2000) > net.transfer_s(200));
    }

    #[test]
    fn packetization() {
        let net = NetworkSim::new(NetworkProfile::ble_270kbps());
        assert_eq!(net.packets(244), 1);
        assert_eq!(net.packets(245), 2);
        assert_eq!(net.wire_bytes(244), 244 + 10);
    }

    #[test]
    fn slow_link_slower() {
        let wifi = NetworkSim::new(NetworkProfile::wifi_6mbps());
        let ble = NetworkSim::new(NetworkProfile::ble_270kbps());
        assert!(ble.transfer_s(1000) > 10.0 * wifi.transfer_s(1000));
    }

    #[test]
    fn bandwidth_scaling() {
        let base = NetworkProfile::wifi_6mbps();
        let half = NetworkSim::new(base.with_bandwidth(3e6));
        let full = NetworkSim::new(base);
        let b = 10_000;
        assert!(half.airtime_s(b) / full.airtime_s(b) > 1.99);
    }

    #[test]
    fn matches_the_pre_channel_closed_form() {
        // the formula NetworkSim shipped with before the net subsystem:
        // wire_bytes * 8 / bandwidth + one_way_latency
        for p in [NetworkProfile::wifi_6mbps(), NetworkProfile::ble_270kbps()] {
            let net = NetworkSim::new(p.clone());
            for bytes in [1usize, 100, 244, 1400, 1401, 9999] {
                let wire = bytes + bytes.div_ceil(p.mtu) * p.per_packet_overhead;
                let expect = wire as f64 * 8.0 / p.bandwidth_bps + p.one_way_latency_s;
                assert!((net.transfer_s(bytes) - expect).abs() < 1e-12, "{bytes} on {}", p.name);
            }
        }
    }
}
