//! Device and network profiles.
//!
//! Calibration (see DESIGN.md §3): the paper measures on a physical
//! STM32F746 board; we price device compute from MAC counts with CMSIS-NN
//! int8 throughput, and scale MACs by `resolution_scale` = (96/32)^2 = 9 so
//! latencies correspond to the paper's 96x96 input resolution while the
//! functional models run at 32x32.


/// Embedded-device cost model parameters.
#[derive(Debug, Clone, PartialEq)]
pub struct DeviceProfile {
    pub name: String,
    /// CPU frequency in Hz (STM32F746: 216 MHz, scalable; §7.5)
    pub freq_hz: f64,
    /// effective int8 MACs per cycle with CMSIS-NN on Cortex-M7
    pub macs_per_cycle: f64,
    /// SRAM budget in bytes (STM32F746: 320 KB)
    pub sram_bytes: usize,
    /// flash budget in bytes (STM32F746: 1 MB)
    pub flash_bytes: usize,
    /// active-compute power draw in watts (core + SRAM at full speed)
    pub active_power_w: f64,
    /// radio power draw while transmitting, watts (ESP-WROOM-02D class)
    pub radio_power_w: f64,
    /// cycles per byte for LZW compression on-device
    pub lzw_cycles_per_byte: f64,
    /// cycles per element for codebook quantization (binary search)
    pub quant_cycles_per_elem: f64,
    /// MAC-count multiplier translating 32x32 models to the paper's 96x96
    pub resolution_scale: f64,
}

impl DeviceProfile {
    /// STM32F746NG discovery board — the paper's device (§6).
    pub fn stm32f746() -> Self {
        Self {
            name: "STM32F746".into(),
            freq_hz: 216e6,
            macs_per_cycle: 0.5,
            sram_bytes: 320 * 1024,
            flash_bytes: 1024 * 1024,
            active_power_w: 0.33, // ~100 mA @ 3.3 V at 216 MHz
            radio_power_w: 0.56,  // ESP WiFi tx ~170 mA @ 3.3 V
            lzw_cycles_per_byte: 30.0,
            quant_cycles_per_elem: 12.0,
            resolution_scale: 9.0,
        }
    }

    /// STM32H743 — faster sibling (§7.5 mentions 480 MHz dual-core M7).
    pub fn stm32h743() -> Self {
        Self { name: "STM32H743".into(), freq_hz: 480e6, ..Self::stm32f746() }
    }

    /// Arduino-Nano-class ATmega328 (16 MHz, tiny memories) — §7.5's low end.
    pub fn arduino_nano() -> Self {
        Self {
            name: "ArduinoNano".into(),
            freq_hz: 16e6,
            macs_per_cycle: 0.1, // no DSP extensions
            sram_bytes: 2 * 1024,
            flash_bytes: 32 * 1024,
            active_power_w: 0.05,
            ..Self::stm32f746()
        }
    }

    /// Same device with the CPU clock scaled (paper §7.5 frequency sweep).
    pub fn with_freq(&self, freq_hz: f64) -> Self {
        Self { name: format!("{}@{:.0}MHz", self.name, freq_hz / 1e6), freq_hz, ..self.clone() }
    }
}

/// Wireless link model.
#[derive(Debug, Clone, PartialEq)]
pub struct NetworkProfile {
    pub name: String,
    /// application-layer goodput, bits per second
    pub bandwidth_bps: f64,
    /// one-way propagation + stack latency, seconds
    pub one_way_latency_s: f64,
    /// per-packet header overhead (UDP/IP), bytes
    pub per_packet_overhead: usize,
    /// maximum payload per packet, bytes
    pub mtu: usize,
}

impl NetworkProfile {
    /// ESP-WROOM-02D WiFi capped at 6 Mbps UDP (paper §6).
    pub fn wifi_6mbps() -> Self {
        Self {
            name: "WiFi-6Mbps".into(),
            bandwidth_bps: 6e6,
            one_way_latency_s: 2e-3,
            per_packet_overhead: 42,
            mtu: 1400,
        }
    }

    /// Narrowband low-energy radio, 270 kbps (paper §7.6's BLE-class link).
    pub fn ble_270kbps() -> Self {
        Self {
            name: "BLE-270kbps".into(),
            bandwidth_bps: 270e3,
            one_way_latency_s: 8e-3,
            per_packet_overhead: 10,
            mtu: 244,
        }
    }

    /// Same link with scaled bandwidth (paper §7.6 sweep).
    pub fn with_bandwidth(&self, bps: f64) -> Self {
        Self { name: format!("{}@{:.0}kbps", self.name, bps / 1e3), bandwidth_bps: bps, ..self.clone() }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stm32_profile_matches_datasheet() {
        let p = DeviceProfile::stm32f746();
        assert_eq!(p.freq_hz, 216e6);
        assert_eq!(p.sram_bytes, 320 * 1024);
        assert_eq!(p.flash_bytes, 1024 * 1024);
    }

    #[test]
    fn with_freq_scales_only_frequency() {
        let p = DeviceProfile::stm32f746().with_freq(64e6);
        assert_eq!(p.freq_hz, 64e6);
        assert_eq!(p.sram_bytes, DeviceProfile::stm32f746().sram_bytes);
    }

    #[test]
    fn network_profiles_ordered() {
        assert!(NetworkProfile::wifi_6mbps().bandwidth_bps > NetworkProfile::ble_270kbps().bandwidth_bps);
    }
}
