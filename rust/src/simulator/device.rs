//! MCU cost/energy/memory model (substitutes the paper's physical
//! STM32F746 + power-meter testbed; DESIGN.md §3).
//!
//! Latency   t = MACs * resolution_scale / (freq * macs_per_cycle)
//! Energy    E = P_active * t_compute + P_radio * t_tx   (Fig 19's two terms)
//! Memory    SRAM = tensor arena (activations) + runtime overhead;
//!           flash = int8 weights + runtime code           (Fig 20)

use super::profiles::DeviceProfile;

/// Simulated device-side timings for one inference (seconds).
#[derive(Debug, Clone, Copy, Default)]
pub struct DeviceTimings {
    pub nn_compute_s: f64,
    pub quantize_s: f64,
    pub compress_s: f64,
}

impl DeviceTimings {
    pub fn total_s(&self) -> f64 {
        self.nn_compute_s + self.quantize_s + self.compress_s
    }
}

/// Device simulator: prices compute, compression and radio activity.
#[derive(Debug, Clone)]
pub struct DeviceSim {
    pub profile: DeviceProfile,
}

impl DeviceSim {
    pub fn new(profile: DeviceProfile) -> Self {
        Self { profile }
    }

    /// Latency of running `macs` multiply-accumulates of int8 NN compute.
    pub fn nn_latency_s(&self, macs: u64) -> f64 {
        macs as f64 * self.profile.resolution_scale
            / (self.profile.freq_hz * self.profile.macs_per_cycle)
    }

    /// Latency of quantizing `elems` feature values through the codebook.
    pub fn quantize_latency_s(&self, elems: usize) -> f64 {
        elems as f64 * self.profile.resolution_scale * self.profile.quant_cycles_per_elem
            / self.profile.freq_hz
    }

    /// Latency of LZW-compressing `bytes` input bytes on-device.
    pub fn compress_latency_s(&self, bytes: usize) -> f64 {
        bytes as f64 * self.profile.resolution_scale * self.profile.lzw_cycles_per_byte
            / self.profile.freq_hz
    }

    /// Energy for a compute phase of duration `t` seconds (joules).
    pub fn compute_energy_j(&self, t: f64) -> f64 {
        self.profile.active_power_w * t
    }

    /// Energy for a radio-active phase of duration `t` seconds (joules).
    pub fn radio_energy_j(&self, t: f64) -> f64 {
        self.profile.radio_power_w * t
    }
}

/// Static memory accounting for a deployed scheme (Fig 20).
#[derive(Debug, Clone, Copy)]
pub struct MemoryReport {
    /// peak tensor-arena bytes (largest layer input+output, int8)
    pub sram_used: usize,
    /// int8 model weights + runtime code
    pub flash_used: usize,
    pub sram_budget: usize,
    pub flash_budget: usize,
}

/// TF-Micro-class runtime overheads (interpreter + op resolver + stack).
pub const RUNTIME_SRAM_OVERHEAD: usize = 24 * 1024;
pub const RUNTIME_FLASH_OVERHEAD: usize = 96 * 1024;

impl MemoryReport {
    /// `activation_peak` = max concurrent activation bytes (int8, at the
    /// paper's 96x96 resolution, i.e. x9 vs our 32x32 models);
    /// `weight_bytes` = int8 parameter bytes.
    pub fn new(profile: &DeviceProfile, activation_peak: usize, weight_bytes: usize) -> Self {
        Self {
            sram_used: activation_peak + RUNTIME_SRAM_OVERHEAD,
            flash_used: weight_bytes + RUNTIME_FLASH_OVERHEAD,
            sram_budget: profile.sram_bytes,
            flash_budget: profile.flash_bytes,
        }
    }

    pub fn sram_frac(&self) -> f64 {
        self.sram_used as f64 / self.sram_budget as f64
    }

    pub fn flash_frac(&self) -> f64 {
        self.flash_used as f64 / self.flash_budget as f64
    }

    pub fn fits(&self) -> bool {
        self.sram_used <= self.sram_budget && self.flash_used <= self.flash_budget
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::simulator::profiles::DeviceProfile;

    #[test]
    fn latency_scales_inverse_with_frequency() {
        let fast = DeviceSim::new(DeviceProfile::stm32f746());
        let slow = DeviceSim::new(DeviceProfile::stm32f746().with_freq(108e6));
        let t_fast = fast.nn_latency_s(1_000_000);
        let t_slow = slow.nn_latency_s(1_000_000);
        assert!((t_slow / t_fast - 2.0).abs() < 1e-9);
    }

    #[test]
    fn latency_linear_in_macs() {
        let sim = DeviceSim::new(DeviceProfile::stm32f746());
        assert!((sim.nn_latency_s(2_000_000) / sim.nn_latency_s(1_000_000) - 2.0).abs() < 1e-9);
    }

    #[test]
    fn mcunet_scale_latency_in_paper_band() {
        // ~1.6M MACs at 32x32 (x9 for 96x96) on the F746 should land in the
        // paper's MCUNet band of 100-500 ms.
        let sim = DeviceSim::new(DeviceProfile::stm32f746());
        let t = sim.nn_latency_s(1_600_000);
        assert!(t > 0.05 && t < 0.5, "t={t}");
    }

    #[test]
    fn energy_proportional_to_power_and_time() {
        let sim = DeviceSim::new(DeviceProfile::stm32f746());
        let e = sim.compute_energy_j(0.1);
        assert!((e - 0.033).abs() < 1e-9);
        assert!(sim.radio_energy_j(0.1) > e); // radio draws more than core
    }

    #[test]
    fn memory_report_fractions() {
        let p = DeviceProfile::stm32f746();
        let r = MemoryReport::new(&p, 40 * 1024, 100 * 1024);
        assert!(r.fits());
        assert!(r.sram_frac() > 0.0 && r.sram_frac() < 1.0);
        let too_big = MemoryReport::new(&p, 512 * 1024, 100 * 1024);
        assert!(!too_big.fits());
    }

    #[test]
    fn timings_total() {
        let t = DeviceTimings { nn_compute_s: 0.01, quantize_s: 0.002, compress_s: 0.003 };
        assert!((t.total_s() - 0.015).abs() < 1e-12);
    }
}
