//! Hardware simulators standing in for the paper's physical testbed:
//! an STM32-class device cost/energy/memory model and a wireless-link model.
//! See DESIGN.md §3 for the substitution rationale and calibration.

pub mod device;
pub mod network;
pub mod profiles;

pub use device::{DeviceSim, DeviceTimings, MemoryReport};
pub use network::NetworkSim;
pub use profiles::{DeviceProfile, NetworkProfile};
