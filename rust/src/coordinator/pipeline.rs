//! Deprecated shims over [`crate::serve`], kept so pre-redesign call sites
//! keep compiling. The multi-device pipeline itself lives in
//! `serve::service`; it now serves **every** scheme (not just AgileNN)
//! with deadline-driven dynamic batching and streaming per-request
//! outcomes. New code should use [`crate::serve::ServeBuilder`].

use crate::baselines::{RequestOutcome, SchemeRunner};
use crate::config::{Meta, RunConfig};
use crate::serve::Service;
use crate::workload::{Arrival, TestSet};
use anyhow::Result;
use std::sync::Arc;

pub use crate::serve::PipelineReport;

/// Run the multi-device serving pipeline over the test set.
///
/// `n_devices` concurrent device threads share one batched remote server;
/// requests are assigned round-robin and paced by `arrival` per device.
#[deprecated(note = "use agilenn::serve::ServeBuilder (or Service::from_parts) instead")]
pub fn run_pipeline(
    cfg: &RunConfig,
    meta: &Meta,
    testset: Arc<TestSet>,
    n_devices: usize,
    n_requests: usize,
    arrival: Arrival,
) -> Result<PipelineReport> {
    Service::from_parts(cfg.clone(), meta.clone(), testset, n_devices, n_requests, arrival)?.run()
}

/// Synchronous single-request convenience.
#[deprecated(note = "use agilenn::baselines::make_runner instead")]
pub fn run_single(
    cfg: &RunConfig,
    meta: &Meta,
    testset: &TestSet,
    index: usize,
) -> Result<RequestOutcome> {
    let backend = crate::runtime::make_backend(cfg, meta)?;
    let mut runner = crate::baselines::make_runner(backend.as_ref(), cfg, meta)?;
    let idx = index % testset.len();
    runner.process(&testset.image(idx)?, testset.labels[idx])
}
