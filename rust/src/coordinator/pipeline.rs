//! Multi-device serving pipeline: N simulated sensor devices stream
//! requests through a shared remote server with deadline-driven dynamic
//! batching (vLLM-router topology), built on std threads + channels — the
//! build environment vendors no async runtime, and the server loop's
//! recv_timeout + deadline poll is exactly the select it needs.
//!
//! This is the "serve" showcase proving the layers compose concurrently;
//! the per-figure benches use the synchronous `SchemeRunner` path where the
//! simulated-time accounting is exact.

use crate::baselines::AgileRunner;
use crate::compression::Frame;
use crate::config::{Meta, RunConfig, Scheme};
use crate::coordinator::batcher::BatchQueue;
use crate::coordinator::combiner::Combiner;
use crate::coordinator::device_runtime::DeviceRuntime;
use crate::coordinator::server::RemoteServer;
use crate::metrics::{AccuracyCounter, LatencyStats};
use crate::runtime::Engine;
use crate::tensor::Tensor;
use crate::workload::{Arrival, TestSet};
use anyhow::{anyhow, ensure, Result};
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// One in-flight offload awaiting its remote logits.
struct OffloadMsg {
    id: u64,
    frame: Frame,
    reply: Sender<Vec<f32>>,
}

/// Aggregate report from a pipeline run.
#[derive(Debug)]
pub struct PipelineReport {
    pub requests: usize,
    pub wall_s: f64,
    pub throughput_rps: f64,
    pub accuracy: f64,
    pub mean_latency_s: f64,
    pub p95_latency_s: f64,
    pub mean_batch_size: f64,
    pub batches: usize,
}

fn server_loop(
    mut server: RemoteServer,
    rx: Receiver<OffloadMsg>,
    max_batch: usize,
    deadline: Duration,
) -> (usize, usize) {
    let mut queue: BatchQueue<(Tensor, Sender<Vec<f32>>)> = BatchQueue::new(max_batch, deadline);
    let mut total_batched = 0usize;
    let mut batches = 0usize;
    let mut run_batch =
        |batch: Vec<crate::coordinator::batcher::Pending<(Tensor, Sender<Vec<f32>>)>>,
         server: &mut RemoteServer| {
            let feats: Vec<_> = batch.iter().map(|p| p.payload.0.clone()).collect();
            match server.infer(&feats) {
                Ok(rows) => {
                    total_batched += batch.len();
                    batches += 1;
                    for (p, row) in batch.into_iter().zip(rows) {
                        let _ = p.payload.1.send(row);
                    }
                }
                Err(e) => eprintln!("remote batch failed: {e:#}"),
            }
        };
    loop {
        let wait = queue.next_deadline_in(Instant::now()).unwrap_or(Duration::from_secs(3600));
        match rx.recv_timeout(wait) {
            Ok(m) => {
                let feats = match server.decode(&m.frame) {
                    Ok(f) => f,
                    Err(e) => {
                        eprintln!("decode {} failed: {e:#}", m.id);
                        continue;
                    }
                };
                if let Some(batch) = queue.push(m.id, (feats, m.reply), Instant::now()) {
                    run_batch(batch, &mut server);
                }
            }
            Err(RecvTimeoutError::Timeout) => {
                if let Some(batch) = queue.poll_deadline(Instant::now()) {
                    run_batch(batch, &mut server);
                }
            }
            Err(RecvTimeoutError::Disconnected) => break,
        }
    }
    let tail = queue.flush();
    if !tail.is_empty() {
        run_batch(tail, &mut server);
    }
    (total_batched, batches)
}

/// Run the multi-device AgileNN pipeline over the test set.
///
/// `n_devices` concurrent device threads share one batched remote server;
/// requests are assigned round-robin and paced by `arrival` per device.
pub fn run_pipeline(
    cfg: &RunConfig,
    meta: &Meta,
    testset: Arc<TestSet>,
    n_devices: usize,
    n_requests: usize,
    arrival: Arrival,
) -> Result<PipelineReport> {
    ensure!(cfg.scheme == Scheme::Agile, "the pipeline showcases the AgileNN scheme");
    ensure!(n_devices >= 1, "need at least one device");
    let engine = Arc::new(Engine::cpu()?);

    let server = RemoteServer::new(&engine, cfg, meta)?;
    let (tx_offload, rx_offload) = channel::<OffloadMsg>();
    let max_batch = cfg.max_batch;
    let deadline = Duration::from_micros(cfg.batch_deadline_us);
    let server_handle = std::thread::spawn(move || server_loop(server, rx_offload, max_batch, deadline));

    let (tx_done, rx_done) = channel::<(bool, f64)>();
    let t_start = Instant::now();
    let mut device_handles = Vec::new();
    for d in 0..n_devices {
        let cfg = cfg.clone();
        let meta = meta.clone();
        let engine = engine.clone();
        let testset = testset.clone();
        let tx_offload = tx_offload.clone();
        let tx_done = tx_done.clone();
        let ids: Vec<usize> = (0..n_requests).filter(|i| i % n_devices == d).collect();
        let times = arrival.timestamps(ids.len());
        device_handles.push(std::thread::spawn(move || -> Result<()> {
            let mut device = DeviceRuntime::new(&engine, &cfg, &meta)?;
            let combiner = Combiner::new(cfg.alpha_override.unwrap_or(meta.alpha))?;
            let t0 = Instant::now();
            for (j, &i) in ids.iter().enumerate() {
                // pace to the arrival process
                let due = Duration::from_secs_f64(times[j]);
                if let Some(sleep_for) = due.checked_sub(t0.elapsed()) {
                    std::thread::sleep(sleep_for);
                }
                let req_start = Instant::now();
                let idx = i % testset.len();
                let img = testset.image(idx)?;
                let out = device.process(&img)?;
                let (reply_tx, reply_rx) = channel();
                tx_offload
                    .send(OffloadMsg { id: i as u64, frame: out.frame, reply: reply_tx })
                    .map_err(|_| anyhow!("server gone"))?;
                let remote_logits =
                    reply_rx.recv().map_err(|_| anyhow!("reply dropped"))?;
                let pred = combiner.predict(&out.local_logits, &remote_logits)?;
                let correct = pred as i32 == testset.labels[idx];
                let _ = tx_done.send((correct, req_start.elapsed().as_secs_f64()));
            }
            Ok(())
        }));
    }
    drop(tx_offload);
    drop(tx_done);

    // collect results as they stream in
    let mut acc = AccuracyCounter::default();
    let mut lat = LatencyStats::new();
    while let Ok((correct, seconds)) = rx_done.recv() {
        acc.record(correct);
        lat.record(seconds);
    }
    for h in device_handles {
        h.join().map_err(|_| anyhow!("device thread panicked"))??;
    }
    let (total_batched, batches) =
        server_handle.join().map_err(|_| anyhow!("server thread panicked"))?;
    let wall = t_start.elapsed().as_secs_f64();

    Ok(PipelineReport {
        requests: acc.total,
        wall_s: wall,
        throughput_rps: acc.total as f64 / wall,
        accuracy: acc.accuracy(),
        mean_latency_s: lat.mean_s(),
        p95_latency_s: lat.p95(),
        mean_batch_size: if batches == 0 { 0.0 } else { total_batched as f64 / batches as f64 },
        batches,
    })
}

/// Synchronous single-request convenience used by examples and the CLI.
pub fn run_single(
    cfg: &RunConfig,
    meta: &Meta,
    testset: &TestSet,
    index: usize,
) -> Result<crate::baselines::RequestOutcome> {
    let engine = Engine::cpu()?;
    let mut runner = AgileRunner::new(&engine, cfg, meta)?;
    let idx = index % testset.len();
    crate::baselines::SchemeRunner::process(&mut runner, &testset.image(idx)?, testset.labels[idx])
}
