//! Device-side runtime (what would run on the MCU): one backend call for
//! the fused extractor+local-NN module (PJRT artifact or reference model),
//! positional feature split (already done inside the module), learned
//! quantization + LZW of the transmitted features, and cost-model pricing
//! of every step.

use crate::compression::{quantizer::Codebook, Frame, TxEncoder};
use crate::config::{Meta, RunConfig, Scheme};
use crate::net::DeliveryPolicy;
use crate::runtime::{Backend, Module};
use crate::simulator::{DeviceSim, DeviceTimings};
use crate::tensor::Tensor;
use anyhow::{anyhow, ensure, Result};
use std::collections::HashMap;
use std::sync::Arc;

/// Result of the on-device phase for one request.
#[derive(Debug)]
pub struct DeviceOutput {
    /// Local NN logits over the top-k important features.
    pub local_logits: Vec<f32>,
    /// Compressed less-important features, ready for the uplink.
    pub frame: Frame,
    /// Quantized symbol stream behind `frame` (the packetized transport
    /// re-chunks these so each packet decodes independently); captured
    /// only when the delivery policy needs it — the copy stays off the
    /// ARQ/bench hot path.
    pub symbols: Option<Vec<u8>>,
    /// Raw remote-feature tensor shape (needed server-side to rebuild).
    pub remote_shape: Vec<usize>,
    /// Simulated device timings.
    pub timings: DeviceTimings,
}

pub struct DeviceRuntime {
    device_exe: Arc<dyn Module>,
    tx: TxEncoder,
    /// active quantizer width; [`DeviceRuntime::set_bits`] swaps it
    bits: u32,
    /// spare encoders for the adaptive policy's other candidate widths
    alt_tx: HashMap<u32, TxEncoder>,
    sim: DeviceSim,
    nn_macs: u64,
    num_classes: usize,
    /// anytime transport re-chunks the symbol stream; ARQ never reads it
    capture_symbols: bool,
}

impl DeviceRuntime {
    pub fn new(backend: &dyn Backend, cfg: &RunConfig, meta: &Meta) -> Result<Self> {
        ensure!(cfg.scheme == Scheme::Agile, "DeviceRuntime is the AgileNN device path");
        let device_exe = backend.load_module(&cfg.dataset_dir(), "agile_device_b1")?;
        let codebook = Codebook::new(meta.codebook(Scheme::Agile, cfg.bits)?)?;
        let mut alt_tx = HashMap::new();
        for w in cfg.candidate_widths() {
            if w != cfg.bits {
                alt_tx.insert(w, TxEncoder::new(Codebook::new(meta.codebook(Scheme::Agile, w)?)?));
            }
        }
        Ok(Self {
            device_exe,
            tx: TxEncoder::new(codebook),
            bits: cfg.bits,
            alt_tx,
            sim: DeviceSim::new(cfg.device.clone()),
            nn_macs: meta.macs.agile_device,
            num_classes: meta.num_classes,
            // an adaptive policy with an anytime rung can switch into the
            // packetized transport mid-run, so it forces the capture too
            capture_symbols: matches!(cfg.net.delivery, DeliveryPolicy::Anytime { .. })
                || cfg.policy.as_ref().is_some_and(|p| p.has_anytime_rung()),
        })
    }

    /// Switch the quantizer to another pre-built candidate width (the
    /// adaptive policy's rate actuator). O(1): the displaced encoder
    /// parks in the spares map under its own width.
    pub fn set_bits(&mut self, bits: u32) -> Result<()> {
        if bits == self.bits {
            return Ok(());
        }
        let mut next = self.alt_tx.remove(&bits).ok_or_else(|| {
            anyhow!(
                "no {bits}-bit encoder prepared (policy candidate widths are validated at build time)"
            )
        })?;
        std::mem::swap(&mut self.tx, &mut next);
        self.alt_tx.insert(self.bits, next);
        self.bits = bits;
        Ok(())
    }

    /// Run the device phase on one image (unit batch).
    pub fn process(&mut self, image: &Tensor) -> Result<DeviceOutput> {
        ensure!(image.batch() == 1, "device path takes unit-batch images");
        let outputs = self.device_exe.run(std::slice::from_ref(image))?;
        ensure!(outputs.len() == 2, "device artifact must yield (logits, remote_feats)");
        let local_logits = outputs[0].data().to_vec();
        ensure!(local_logits.len() == self.num_classes, "unexpected logit width");
        let remote_feats = &outputs[1];

        let frame = self.tx.encode(remote_feats.data());
        let symbols = self.capture_symbols.then(|| self.tx.symbols().to_vec());
        let timings = DeviceTimings {
            nn_compute_s: self.sim.nn_latency_s(self.nn_macs),
            quantize_s: self.sim.quantize_latency_s(remote_feats.len()),
            compress_s: self
                .sim
                .compress_latency_s((remote_feats.len() * self.tx.codebook().bits() as usize + 7) / 8),
        };
        Ok(DeviceOutput {
            local_logits,
            frame,
            symbols,
            remote_shape: remote_feats.shape().to_vec(),
            timings,
        })
    }

    pub fn sim(&self) -> &DeviceSim {
        &self.sim
    }
}
