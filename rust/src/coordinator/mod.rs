//! The AgileNN serving coordinator (the paper's system contribution, L3):
//!
//! * [`device_runtime`] — AgileNN on-device phase: fused extractor+local-NN
//!   PJRT call, positional feature split, learned quantization + LZW.
//! * [`server`] — server phase for every offloading scheme: decode,
//!   fixed-shape batched remote NN.
//! * [`batcher`] — deadline-driven dynamic batching policy.
//! * [`combiner`] — alpha-weighted local/remote prediction fusion (§3.3).
//! * [`pipeline`] — deprecated shims over [`crate::serve`], the
//!   scheme-agnostic threaded multi-device serving loop.

pub mod batcher;
pub mod combiner;
pub mod device_runtime;
pub mod pipeline;
pub mod server;

pub use batcher::{BatchQueue, EDGE_BATCH_SIZES, REMOTE_BATCH_SIZES};
pub use combiner::Combiner;
pub use device_runtime::{DeviceOutput, DeviceRuntime};
#[allow(deprecated)]
pub use pipeline::{run_pipeline, run_single};
pub use pipeline::PipelineReport;
pub use server::RemoteServer;
