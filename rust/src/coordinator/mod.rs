//! The AgileNN serving coordinator (the paper's system contribution, L3):
//!
//! * [`device_runtime`] — AgileNN on-device phase: fused extractor+local-NN
//!   PJRT call, positional feature split, learned quantization + LZW.
//! * [`server`] — server phase for every offloading scheme: decode,
//!   fixed-shape batched remote NN.
//! * [`batcher`] — deadline-driven dynamic batching policy.
//! * [`combiner`] — alpha-weighted local/remote prediction fusion (§3.3).
//!
//! The multi-device serving loop itself lives in [`crate::serve`]
//! (`ServeBuilder`); the pre-redesign `run_pipeline`/`run_single` shims
//! that used to live here are gone.

pub mod batcher;
pub mod combiner;
pub mod device_runtime;
pub mod server;

pub use batcher::{BatchQueue, EDGE_BATCH_SIZES, REMOTE_BATCH_SIZES};
pub use combiner::Combiner;
pub use device_runtime::{DeviceOutput, DeviceRuntime};
pub use server::RemoteServer;
