//! Dynamic batcher for the remote NN (vLLM-router-style deadline batching).
//!
//! Remote HLO executables are compiled for fixed batch sizes {1,2,4,8};
//! the batcher accumulates decoded feature tensors until either the largest
//! batch fills or the oldest request's deadline expires, then dispatches and
//! pads to the smallest exported batch size that fits.
//!
//! Timestamps are **clock seconds** (`serve::clock::Clock::now`), not raw
//! `Instant`s, so the same policy runs unchanged on the wall clock and on
//! the discrete-event sim clock.

/// Exported remote batch sizes (must match compile/aot.py REMOTE_BATCHES).
pub const REMOTE_BATCH_SIZES: [usize; 4] = [1, 2, 4, 8];

/// Batch sizes the edge-only remote artifact exports — compile/aot.py
/// compiles the raw-image server model for a reduced set. Shared by the
/// PJRT server half and the reference backend's stem validation so the
/// two cannot drift.
pub const EDGE_BATCH_SIZES: [usize; 2] = [1, 4];

/// Smallest exported batch size >= n.
pub fn pad_batch_size(n: usize) -> usize {
    for &b in REMOTE_BATCH_SIZES.iter() {
        if b >= n {
            return b;
        }
    }
    *REMOTE_BATCH_SIZES.last().unwrap()
}

/// A queued request awaiting batching.
#[derive(Debug)]
pub struct Pending<T> {
    pub id: u64,
    pub payload: T,
    /// clock timestamp (seconds) when the request entered the queue
    pub enqueued: f64,
}

/// Deadline-driven batch queue. Pure data structure (no async) so the policy
/// is unit-testable; the serve loop drives it from the server thread.
#[derive(Debug)]
pub struct BatchQueue<T> {
    pending: Vec<Pending<T>>,
    max_batch: usize,
    deadline_s: f64,
}

impl<T> BatchQueue<T> {
    pub fn new(max_batch: usize, deadline_s: f64) -> Self {
        assert!(REMOTE_BATCH_SIZES.contains(&max_batch), "max_batch must be exported");
        assert!(
            deadline_s.is_finite() && deadline_s >= 0.0,
            "deadline must be finite and non-negative"
        );
        Self { pending: Vec::new(), max_batch, deadline_s }
    }

    pub fn len(&self) -> usize {
        self.pending.len()
    }

    pub fn is_empty(&self) -> bool {
        self.pending.is_empty()
    }

    /// Enqueue; returns a full batch if the size trigger fired.
    pub fn push(&mut self, id: u64, payload: T, now_s: f64) -> Option<Vec<Pending<T>>> {
        self.pending.push(Pending { id, payload, enqueued: now_s });
        if self.pending.len() >= self.max_batch {
            return Some(std::mem::take(&mut self.pending));
        }
        None
    }

    /// Absolute clock time the oldest queued request must dispatch by
    /// (None if the queue is empty). The deadline poll uses the *same*
    /// arithmetic, so a sim clock advanced exactly to this timestamp is
    /// guaranteed to fire it.
    pub fn next_deadline_at(&self) -> Option<f64> {
        self.pending.first().map(|oldest| oldest.enqueued + self.deadline_s)
    }

    /// Dispatch if the oldest request's deadline has expired.
    pub fn poll_deadline(&mut self, now_s: f64) -> Option<Vec<Pending<T>>> {
        match self.next_deadline_at() {
            Some(at) if now_s >= at => Some(std::mem::take(&mut self.pending)),
            _ => None,
        }
    }

    /// Seconds until the current deadline fires (None if queue empty,
    /// clamped at zero once expired).
    pub fn next_deadline_in(&self, now_s: f64) -> Option<f64> {
        self.next_deadline_at().map(|at| (at - now_s).max(0.0))
    }

    /// Drain whatever is queued (shutdown path).
    pub fn flush(&mut self) -> Vec<Pending<T>> {
        std::mem::take(&mut self.pending)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pad_batch_size_snaps_up() {
        assert_eq!(pad_batch_size(1), 1);
        assert_eq!(pad_batch_size(3), 4);
        assert_eq!(pad_batch_size(5), 8);
        assert_eq!(pad_batch_size(8), 8);
        assert_eq!(pad_batch_size(20), 8); // clamped to max exported
    }

    #[test]
    fn size_trigger_dispatches_full_batch() {
        let mut q = BatchQueue::new(2, 0.010);
        assert!(q.push(1, "a", 0.0).is_none());
        let batch = q.push(2, "b", 0.0).expect("size trigger");
        assert_eq!(batch.len(), 2);
        assert!(q.is_empty());
    }

    #[test]
    fn deadline_trigger() {
        let mut q = BatchQueue::new(8, 0.005);
        q.push(1, "a", 0.0);
        assert!(q.poll_deadline(0.0).is_none());
        let batch = q.poll_deadline(0.006).expect("deadline trigger");
        assert_eq!(batch.len(), 1);
        assert_eq!(batch[0].id, 1);
    }

    #[test]
    fn deadline_fires_at_exactly_the_advertised_timestamp() {
        // the sim clock advances to next_deadline_at() bit for bit; the
        // poll must fire there even when fp rounding makes
        // (enqueued + d) - enqueued < d
        let mut q = BatchQueue::new(8, 2e-3);
        let enq = 0.300000000000000044;
        q.push(1, "a", enq);
        let at = q.next_deadline_at().unwrap();
        assert!(q.poll_deadline(at).is_some());
    }

    #[test]
    fn next_deadline_counts_down() {
        let mut q = BatchQueue::new(8, 0.010);
        assert!(q.next_deadline_in(0.0).is_none());
        q.push(1, "a", 0.0);
        let d = q.next_deadline_in(0.004).unwrap();
        assert!((d - 0.006).abs() < 1e-12);
    }

    #[test]
    fn flush_drains() {
        let mut q = BatchQueue::new(8, 0.010);
        q.push(1, "a", 0.0);
        q.push(2, "b", 0.0);
        assert_eq!(q.flush().len(), 2);
        assert!(q.is_empty());
    }

    #[test]
    fn deadline_fires_partial_batch_with_everything_pending() {
        // only the oldest request is past the deadline, but the whole
        // partial batch rides along (dispatching it costs one padded exec)
        let mut q = BatchQueue::new(8, 0.005);
        q.push(1, "a", 0.0);
        q.push(2, "b", 0.004);
        q.push(3, "c", 0.004);
        let batch = q.poll_deadline(0.006).expect("deadline");
        assert_eq!(batch.len(), 3);
        assert_eq!(batch[0].id, 1);
        assert!(q.is_empty());
        // a fresh push restarts the deadline clock from its own enqueue time
        q.push(4, "d", 0.007);
        assert!(q.poll_deadline(0.0119).is_none());
        assert!(q.poll_deadline(0.012).is_some());
    }

    #[test]
    fn size_trigger_leaves_overflow_for_the_next_batch() {
        let mut q = BatchQueue::new(2, 0.050);
        assert!(q.push(1, "a", 0.0).is_none());
        assert!(q.push(2, "b", 0.0).is_some());
        // the queue is empty again; a lone tail request sits until flush
        assert!(q.push(3, "c", 0.0).is_none());
        assert_eq!(q.len(), 1);
        let tail = q.flush();
        assert_eq!(tail.len(), 1);
        assert_eq!(tail[0].id, 3);
    }

    #[test]
    fn flush_on_empty_queue_is_empty() {
        let mut q = BatchQueue::<&str>::new(4, 0.001);
        assert!(q.flush().is_empty());
        // flush never fabricates deadlines either
        assert!(q.next_deadline_in(0.0).is_none());
        assert!(q.next_deadline_at().is_none());
    }

    #[test]
    fn expired_deadline_reports_zero_wait() {
        let mut q = BatchQueue::new(8, 0.002);
        q.push(1, "a", 0.0);
        assert_eq!(q.next_deadline_in(0.010), Some(0.0));
    }

    #[test]
    #[should_panic]
    fn non_exported_max_batch_panics() {
        let _ = BatchQueue::<u8>::new(3, 0.001);
    }

    #[test]
    #[should_panic]
    fn non_finite_deadline_panics() {
        let _ = BatchQueue::<u8>::new(4, f64::NAN);
    }
}
