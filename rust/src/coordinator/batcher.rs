//! Dynamic batcher for the remote NN (vLLM-router-style deadline batching).
//!
//! Remote HLO executables are compiled for fixed batch sizes {1,2,4,8};
//! the batcher accumulates decoded feature tensors until either the largest
//! batch fills or the oldest request's deadline expires, then dispatches and
//! pads to the smallest exported batch size that fits.

use std::time::{Duration, Instant};

/// Exported remote batch sizes (must match compile/aot.py REMOTE_BATCHES).
pub const REMOTE_BATCH_SIZES: [usize; 4] = [1, 2, 4, 8];

/// Smallest exported batch size >= n.
pub fn pad_batch_size(n: usize) -> usize {
    for &b in REMOTE_BATCH_SIZES.iter() {
        if b >= n {
            return b;
        }
    }
    *REMOTE_BATCH_SIZES.last().unwrap()
}

/// A queued request awaiting batching.
#[derive(Debug)]
pub struct Pending<T> {
    pub id: u64,
    pub payload: T,
    pub enqueued: Instant,
}

/// Deadline-driven batch queue. Pure data structure (no async) so the policy
/// is unit-testable; `pipeline.rs` drives it from the pipeline thread.
#[derive(Debug)]
pub struct BatchQueue<T> {
    pending: Vec<Pending<T>>,
    max_batch: usize,
    deadline: Duration,
}

impl<T> BatchQueue<T> {
    pub fn new(max_batch: usize, deadline: Duration) -> Self {
        assert!(REMOTE_BATCH_SIZES.contains(&max_batch), "max_batch must be exported");
        Self { pending: Vec::new(), max_batch, deadline }
    }

    pub fn len(&self) -> usize {
        self.pending.len()
    }

    pub fn is_empty(&self) -> bool {
        self.pending.is_empty()
    }

    /// Enqueue; returns a full batch if the size trigger fired.
    pub fn push(&mut self, id: u64, payload: T, now: Instant) -> Option<Vec<Pending<T>>> {
        self.pending.push(Pending { id, payload, enqueued: now });
        if self.pending.len() >= self.max_batch {
            return Some(std::mem::take(&mut self.pending));
        }
        None
    }

    /// Dispatch if the oldest request has waited past the deadline.
    pub fn poll_deadline(&mut self, now: Instant) -> Option<Vec<Pending<T>>> {
        match self.pending.first() {
            Some(oldest) if now.duration_since(oldest.enqueued) >= self.deadline => {
                Some(std::mem::take(&mut self.pending))
            }
            _ => None,
        }
    }

    /// Time until the current deadline fires (None if queue empty).
    pub fn next_deadline_in(&self, now: Instant) -> Option<Duration> {
        self.pending.first().map(|oldest| {
            self.deadline
                .checked_sub(now.duration_since(oldest.enqueued))
                .unwrap_or(Duration::ZERO)
        })
    }

    /// Drain whatever is queued (shutdown path).
    pub fn flush(&mut self) -> Vec<Pending<T>> {
        std::mem::take(&mut self.pending)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pad_batch_size_snaps_up() {
        assert_eq!(pad_batch_size(1), 1);
        assert_eq!(pad_batch_size(3), 4);
        assert_eq!(pad_batch_size(5), 8);
        assert_eq!(pad_batch_size(8), 8);
        assert_eq!(pad_batch_size(20), 8); // clamped to max exported
    }

    #[test]
    fn size_trigger_dispatches_full_batch() {
        let mut q = BatchQueue::new(2, Duration::from_millis(10));
        let t = Instant::now();
        assert!(q.push(1, "a", t).is_none());
        let batch = q.push(2, "b", t).expect("size trigger");
        assert_eq!(batch.len(), 2);
        assert!(q.is_empty());
    }

    #[test]
    fn deadline_trigger() {
        let mut q = BatchQueue::new(8, Duration::from_millis(5));
        let t0 = Instant::now();
        q.push(1, "a", t0);
        assert!(q.poll_deadline(t0).is_none());
        let later = t0 + Duration::from_millis(6);
        let batch = q.poll_deadline(later).expect("deadline trigger");
        assert_eq!(batch.len(), 1);
        assert_eq!(batch[0].id, 1);
    }

    #[test]
    fn next_deadline_counts_down() {
        let mut q = BatchQueue::new(8, Duration::from_millis(10));
        let t0 = Instant::now();
        assert!(q.next_deadline_in(t0).is_none());
        q.push(1, "a", t0);
        let d = q.next_deadline_in(t0 + Duration::from_millis(4)).unwrap();
        assert!(d <= Duration::from_millis(6));
    }

    #[test]
    fn flush_drains() {
        let mut q = BatchQueue::new(8, Duration::from_millis(10));
        q.push(1, "a", Instant::now());
        q.push(2, "b", Instant::now());
        assert_eq!(q.flush().len(), 2);
        assert!(q.is_empty());
    }

    #[test]
    fn deadline_fires_partial_batch_with_everything_pending() {
        // only the oldest request is past the deadline, but the whole
        // partial batch rides along (dispatching it costs one padded exec)
        let mut q = BatchQueue::new(8, Duration::from_millis(5));
        let t0 = Instant::now();
        q.push(1, "a", t0);
        q.push(2, "b", t0 + Duration::from_millis(4));
        q.push(3, "c", t0 + Duration::from_millis(4));
        let batch = q.poll_deadline(t0 + Duration::from_millis(6)).expect("deadline");
        assert_eq!(batch.len(), 3);
        assert_eq!(batch[0].id, 1);
        assert!(q.is_empty());
        // a fresh push restarts the deadline clock from its own enqueue time
        let t1 = t0 + Duration::from_millis(7);
        q.push(4, "d", t1);
        assert!(q.poll_deadline(t1 + Duration::from_millis(4)).is_none());
        assert!(q.poll_deadline(t1 + Duration::from_millis(5)).is_some());
    }

    #[test]
    fn size_trigger_leaves_overflow_for_the_next_batch() {
        let mut q = BatchQueue::new(2, Duration::from_millis(50));
        let t = Instant::now();
        assert!(q.push(1, "a", t).is_none());
        assert!(q.push(2, "b", t).is_some());
        // the queue is empty again; a lone tail request sits until flush
        assert!(q.push(3, "c", t).is_none());
        assert_eq!(q.len(), 1);
        let tail = q.flush();
        assert_eq!(tail.len(), 1);
        assert_eq!(tail[0].id, 3);
    }

    #[test]
    fn flush_on_empty_queue_is_empty() {
        let mut q = BatchQueue::<&str>::new(4, Duration::from_millis(1));
        assert!(q.flush().is_empty());
        // flush never fabricates deadlines either
        assert!(q.next_deadline_in(Instant::now()).is_none());
    }

    #[test]
    fn expired_deadline_reports_zero_wait() {
        let mut q = BatchQueue::new(8, Duration::from_millis(2));
        let t0 = Instant::now();
        q.push(1, "a", t0);
        let d = q.next_deadline_in(t0 + Duration::from_millis(10)).unwrap();
        assert_eq!(d, Duration::ZERO);
    }

    #[test]
    #[should_panic]
    fn non_exported_max_batch_panics() {
        let _ = BatchQueue::<u8>::new(3, Duration::from_millis(1));
    }
}
