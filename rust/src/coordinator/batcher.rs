//! Dynamic batcher for the remote NN (vLLM-router-style deadline batching).
//!
//! Remote HLO executables are compiled for fixed batch sizes {1,2,4,8};
//! the batcher accumulates decoded feature tensors until either the largest
//! batch fills or the oldest request's deadline expires, then dispatches and
//! pads to the smallest exported batch size that fits.

use std::time::{Duration, Instant};

/// Exported remote batch sizes (must match compile/aot.py REMOTE_BATCHES).
pub const REMOTE_BATCH_SIZES: [usize; 4] = [1, 2, 4, 8];

/// Smallest exported batch size >= n.
pub fn pad_batch_size(n: usize) -> usize {
    for &b in REMOTE_BATCH_SIZES.iter() {
        if b >= n {
            return b;
        }
    }
    *REMOTE_BATCH_SIZES.last().unwrap()
}

/// A queued request awaiting batching.
#[derive(Debug)]
pub struct Pending<T> {
    pub id: u64,
    pub payload: T,
    pub enqueued: Instant,
}

/// Deadline-driven batch queue. Pure data structure (no async) so the policy
/// is unit-testable; `pipeline.rs` drives it from the pipeline thread.
#[derive(Debug)]
pub struct BatchQueue<T> {
    pending: Vec<Pending<T>>,
    max_batch: usize,
    deadline: Duration,
}

impl<T> BatchQueue<T> {
    pub fn new(max_batch: usize, deadline: Duration) -> Self {
        assert!(REMOTE_BATCH_SIZES.contains(&max_batch), "max_batch must be exported");
        Self { pending: Vec::new(), max_batch, deadline }
    }

    pub fn len(&self) -> usize {
        self.pending.len()
    }

    pub fn is_empty(&self) -> bool {
        self.pending.is_empty()
    }

    /// Enqueue; returns a full batch if the size trigger fired.
    pub fn push(&mut self, id: u64, payload: T, now: Instant) -> Option<Vec<Pending<T>>> {
        self.pending.push(Pending { id, payload, enqueued: now });
        if self.pending.len() >= self.max_batch {
            return Some(std::mem::take(&mut self.pending));
        }
        None
    }

    /// Dispatch if the oldest request has waited past the deadline.
    pub fn poll_deadline(&mut self, now: Instant) -> Option<Vec<Pending<T>>> {
        match self.pending.first() {
            Some(oldest) if now.duration_since(oldest.enqueued) >= self.deadline => {
                Some(std::mem::take(&mut self.pending))
            }
            _ => None,
        }
    }

    /// Time until the current deadline fires (None if queue empty).
    pub fn next_deadline_in(&self, now: Instant) -> Option<Duration> {
        self.pending.first().map(|oldest| {
            self.deadline
                .checked_sub(now.duration_since(oldest.enqueued))
                .unwrap_or(Duration::ZERO)
        })
    }

    /// Drain whatever is queued (shutdown path).
    pub fn flush(&mut self) -> Vec<Pending<T>> {
        std::mem::take(&mut self.pending)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pad_batch_size_snaps_up() {
        assert_eq!(pad_batch_size(1), 1);
        assert_eq!(pad_batch_size(3), 4);
        assert_eq!(pad_batch_size(5), 8);
        assert_eq!(pad_batch_size(8), 8);
        assert_eq!(pad_batch_size(20), 8); // clamped to max exported
    }

    #[test]
    fn size_trigger_dispatches_full_batch() {
        let mut q = BatchQueue::new(2, Duration::from_millis(10));
        let t = Instant::now();
        assert!(q.push(1, "a", t).is_none());
        let batch = q.push(2, "b", t).expect("size trigger");
        assert_eq!(batch.len(), 2);
        assert!(q.is_empty());
    }

    #[test]
    fn deadline_trigger() {
        let mut q = BatchQueue::new(8, Duration::from_millis(5));
        let t0 = Instant::now();
        q.push(1, "a", t0);
        assert!(q.poll_deadline(t0).is_none());
        let later = t0 + Duration::from_millis(6);
        let batch = q.poll_deadline(later).expect("deadline trigger");
        assert_eq!(batch.len(), 1);
        assert_eq!(batch[0].id, 1);
    }

    #[test]
    fn next_deadline_counts_down() {
        let mut q = BatchQueue::new(8, Duration::from_millis(10));
        let t0 = Instant::now();
        assert!(q.next_deadline_in(t0).is_none());
        q.push(1, "a", t0);
        let d = q.next_deadline_in(t0 + Duration::from_millis(4)).unwrap();
        assert!(d <= Duration::from_millis(6));
    }

    #[test]
    fn flush_drains() {
        let mut q = BatchQueue::new(8, Duration::from_millis(10));
        q.push(1, "a", Instant::now());
        q.push(2, "b", Instant::now());
        assert_eq!(q.flush().len(), 2);
        assert!(q.is_empty());
    }

    #[test]
    #[should_panic]
    fn non_exported_max_batch_panics() {
        let _ = BatchQueue::<u8>::new(3, Duration::from_millis(1));
    }
}
