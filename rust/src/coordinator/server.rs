//! Server-side runtime: decompress received frames, rebuild feature
//! tensors, run the remote NN through the exported fixed-batch executables
//! (padding up via the batcher policy), return per-request logits.

use crate::compression::{quantizer::Codebook, Frame, RxDecoder};
use crate::config::{Meta, RunConfig, Scheme};
use crate::coordinator::batcher::pad_batch_size;
use crate::runtime::{Engine, Executable};
use crate::tensor::Tensor;
use anyhow::{ensure, Result};
use std::collections::HashMap;
use std::sync::Arc;
use std::time::{Duration, Instant};

pub struct RemoteServer {
    exes: HashMap<usize, Arc<Executable>>,
    rx: RxDecoder,
    feature_shape: Vec<usize>, // (1, h, w, c_remote)
    num_classes: usize,
    /// wall-clock spent in remote NN execution (for perf accounting)
    pub exec_time: Duration,
    pub batches_run: usize,
}

impl RemoteServer {
    pub fn new(engine: &Engine, cfg: &RunConfig, meta: &Meta) -> Result<Self> {
        let (stem, ch) = match cfg.scheme {
            Scheme::Agile => ("agile_remote", meta.feature[2] - meta.k),
            Scheme::Deepcod => ("deepcod_remote", 12),
            Scheme::Spinn => ("spinn_remote", 32),
            _ => anyhow::bail!("{} has no feature-receiving server", cfg.scheme.name()),
        };
        let mut exes = HashMap::new();
        for b in super::batcher::REMOTE_BATCH_SIZES {
            exes.insert(b, engine.load_artifact(&cfg.dataset_dir(), &format!("{stem}_b{b}"))?);
        }
        let codebook = Codebook::new(meta.codebook(cfg.scheme, cfg.bits)?)?;
        Ok(Self {
            exes,
            rx: RxDecoder::new(codebook),
            feature_shape: vec![1, meta.feature[0], meta.feature[1], ch],
            num_classes: meta.num_classes,
            exec_time: Duration::ZERO,
            batches_run: 0,
        })
    }

    /// Decode one frame back into a unit-batch feature tensor.
    pub fn decode(&self, frame: &Frame) -> Result<Tensor> {
        let values = self.rx.decode(frame)?;
        ensure!(
            values.len() == self.feature_shape.iter().product::<usize>(),
            "frame decodes to {} values, expected shape {:?}",
            values.len(),
            self.feature_shape
        );
        Tensor::new(self.feature_shape.clone(), values)
    }

    /// Run the remote NN on a group of decoded feature tensors.
    /// Returns per-request logits (padding rows are dropped).
    pub fn infer(&mut self, feats: &[Tensor]) -> Result<Vec<Vec<f32>>> {
        ensure!(!feats.is_empty(), "empty batch");
        let padded = pad_batch_size(feats.len());
        ensure!(padded <= 8, "batch exceeds exported sizes");
        let batch = Tensor::stack_padded(feats, padded)?;
        let exe = self.exes.get(&padded).expect("exported batch size");
        let t0 = Instant::now();
        let out = exe.run(std::slice::from_ref(&batch))?;
        self.exec_time += t0.elapsed();
        self.batches_run += 1;
        ensure!(out.len() == 1, "remote artifact must yield (logits,)");
        let logits = &out[0];
        ensure!(logits.shape() == [padded, self.num_classes], "bad remote logits shape");
        (0..feats.len()).map(|i| Ok(logits.row(i)?.to_vec())).collect()
    }

    /// End-to-end server phase for one frame (decode + batch-1 inference).
    pub fn process_frame(&mut self, frame: &Frame) -> Result<Vec<f32>> {
        let feats = self.decode(frame)?;
        Ok(self.infer(std::slice::from_ref(&feats))?.remove(0))
    }
}
