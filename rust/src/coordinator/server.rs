//! Server-side runtime: decode received frames, rebuild model inputs, run
//! the remote NN through the exported fixed-batch executables (padding up
//! via the batcher policy), return per-request logits.
//!
//! Covers every offloading scheme: learned-codebook feature streams
//! (AgileNN, DeepCOD, SPINN) and the edge-only raw-image path (LZW'd u8
//! pixels, rebuilt to f32 server-side). MCUNet resolves on-device and has
//! no server half.

use crate::compression::{lzw, quantizer::Codebook, Frame, RxDecoder};
use crate::config::{Meta, RunConfig, Scheme};
use crate::coordinator::batcher::{EDGE_BATCH_SIZES, REMOTE_BATCH_SIZES};
use crate::net::{importance_order, reassemble_symbols, Packet, PacketOrder};
use crate::runtime::{Backend, Module};
use crate::tensor::Tensor;
use anyhow::{ensure, Result};
use std::collections::HashMap;
use std::sync::Arc;
use std::time::{Duration, Instant};

/// How uplink frames decode back into model inputs.
enum FrameDecoder {
    /// learned-codebook feature stream (AgileNN / DeepCOD / SPINN)
    Features(RxDecoder),
    /// LZW-compressed raw u8 image (edge-only)
    RawImage,
}

pub struct RemoteServer {
    exes: HashMap<usize, Arc<dyn Module>>,
    /// exported batch sizes for this scheme's remote artifact, ascending
    sizes: Vec<usize>,
    decoder: FrameDecoder,
    /// spare feature decoders for the adaptive policy's other candidate
    /// widths, keyed by width (empty with the policy off and for the
    /// raw-image path)
    alt_rx: HashMap<u32, RxDecoder>,
    input_shape: Vec<usize>, // (1, h, w, c)
    num_classes: usize,
    /// shared transmit-order permutation for packetized frames (must match
    /// the device's packetizer) — `None` = index order
    tx_order: Option<Vec<u32>>,
    /// imputation symbol for features missing from a partial frame: the
    /// codeword nearest the stored reference activation (0.0 post-ReLU),
    /// or black pixels for the raw-image path
    fill_symbol: u8,
    /// wall-clock spent in remote NN execution (for perf accounting)
    pub exec_time: Duration,
    pub batches_run: usize,
}

impl RemoteServer {
    pub fn new(backend: &dyn Backend, cfg: &RunConfig, meta: &Meta) -> Result<Self> {
        let stem = match cfg.scheme {
            Scheme::Agile => "agile_remote",
            Scheme::Deepcod => "deepcod_remote",
            Scheme::Spinn => "spinn_remote",
            Scheme::EdgeOnly => "edge_remote",
            Scheme::Mcunet => {
                anyhow::bail!("{} resolves on-device; it has no server half", cfg.scheme.name())
            }
        };
        let (input_shape, decoder) = match cfg.scheme {
            Scheme::EdgeOnly => (
                vec![1, meta.image[0], meta.image[1], meta.image[2]],
                FrameDecoder::RawImage,
            ),
            _ => {
                let ch = match cfg.scheme {
                    Scheme::Agile => meta.feature[2] - meta.k,
                    Scheme::Deepcod => 12,
                    _ => 32, // Spinn
                };
                (
                    vec![1, meta.feature[0], meta.feature[1], ch],
                    FrameDecoder::Features(RxDecoder::new(Codebook::new(
                        meta.codebook(cfg.scheme, cfg.bits)?,
                    )?)),
                )
            }
        };
        // edge-only exports a reduced batch set (compile/aot.py)
        let sizes: Vec<usize> = match cfg.scheme {
            Scheme::EdgeOnly => EDGE_BATCH_SIZES.to_vec(),
            _ => REMOTE_BATCH_SIZES.to_vec(),
        };
        let mut exes: HashMap<usize, Arc<dyn Module>> = HashMap::new();
        for &b in &sizes {
            exes.insert(b, backend.load_module(&cfg.dataset_dir(), &format!("{stem}_b{b}"))?);
        }
        let tx_order = match cfg.net.order {
            PacketOrder::Importance => importance_order(meta, cfg.scheme),
            PacketOrder::Index => None,
        };
        let fill_symbol = match &decoder {
            FrameDecoder::Features(rx) => rx.codebook().index_of(0.0),
            FrameDecoder::RawImage => 0,
        };
        let mut alt_rx = HashMap::new();
        if matches!(decoder, FrameDecoder::Features(_)) {
            for w in cfg.candidate_widths() {
                if w != cfg.bits {
                    alt_rx.insert(w, RxDecoder::new(Codebook::new(meta.codebook(cfg.scheme, w)?)?));
                }
            }
        }
        Ok(Self {
            exes,
            sizes,
            decoder,
            alt_rx,
            input_shape,
            num_classes: meta.num_classes,
            tx_order,
            fill_symbol,
            exec_time: Duration::ZERO,
            batches_run: 0,
        })
    }

    /// Largest exported remote batch size for this scheme (the batcher's
    /// dispatch cap must not exceed it).
    pub fn max_batch(&self) -> usize {
        *self.sizes.last().expect("at least one exported batch size")
    }

    /// Feature decoder for a given frame width: the default-width decoder,
    /// or the pre-built spare for an adaptive-policy candidate width.
    fn rx_for<'a>(&'a self, default: &'a RxDecoder, bits: u32) -> Result<&'a RxDecoder> {
        if bits == default.codebook().bits() {
            return Ok(default);
        }
        self.alt_rx.get(&bits).ok_or_else(|| {
            anyhow::anyhow!(
                "no {bits}-bit decoder prepared (policy candidate widths are validated at build time)"
            )
        })
    }

    /// Decode one frame back into a unit-batch input tensor.
    pub fn decode(&self, frame: &Frame) -> Result<Tensor> {
        let values = match &self.decoder {
            FrameDecoder::Features(rx) => self.rx_for(rx, frame.bits)?.decode(frame)?,
            FrameDecoder::RawImage => {
                let bytes = lzw::decompress(&frame.payload)?;
                ensure!(
                    bytes.len() == frame.count,
                    "raw image frame decodes to {} bytes, expected {}",
                    bytes.len(),
                    frame.count
                );
                bytes.iter().map(|&b| b as f32 / 255.0).collect()
            }
        };
        ensure!(
            values.len() == self.input_shape.iter().product::<usize>(),
            "frame decodes to {} values, expected shape {:?}",
            values.len(),
            self.input_shape
        );
        Tensor::new(self.input_shape.clone(), values)
    }

    /// Decode a (possibly partial) packetized frame into a unit-batch
    /// input tensor: delivered packets are unpacked into place through the
    /// shared transmit-order permutation, everything missing is imputed
    /// with the stored reference symbol.
    pub fn decode_packets(&self, packets: &[Packet], count: usize, bits: u32) -> Result<Tensor> {
        // the imputation symbol is codebook-specific (the codeword nearest
        // 0.0 sits at a different index per width), so resolve the decoder
        // for *this frame's* width before reassembly
        let (rx, fill) = match &self.decoder {
            FrameDecoder::Features(default) => {
                let rx = self.rx_for(default, bits)?;
                (Some(rx), rx.codebook().index_of(0.0))
            }
            FrameDecoder::RawImage => (None, self.fill_symbol),
        };
        let (symbols, _delivered) =
            reassemble_symbols(packets, count, bits, fill, self.tx_order.as_deref())?;
        let values: Vec<f32> = match rx {
            Some(rx) => rx.dequantize_symbols(&symbols),
            None => symbols.iter().map(|&b| b as f32 / 255.0).collect(),
        };
        ensure!(
            values.len() == self.input_shape.iter().product::<usize>(),
            "packetized frame decodes to {} values, expected shape {:?}",
            values.len(),
            self.input_shape
        );
        Tensor::new(self.input_shape.clone(), values)
    }

    /// Run the remote NN on a group of decoded input tensors, padding up
    /// to the smallest exported batch size that fits.
    /// Returns per-request logits (padding rows are dropped).
    pub fn infer(&mut self, feats: &[Tensor]) -> Result<Vec<Vec<f32>>> {
        ensure!(!feats.is_empty(), "empty batch");
        let padded = *self
            .sizes
            .iter()
            .find(|&&b| b >= feats.len())
            .ok_or_else(|| {
                anyhow::anyhow!(
                    "batch of {} exceeds the largest exported size {}",
                    feats.len(),
                    self.max_batch()
                )
            })?;
        let batch = Tensor::stack_padded(feats, padded)?;
        let exe = self.exes.get(&padded).expect("exported batch size");
        let t0 = Instant::now();
        let out = exe.run(std::slice::from_ref(&batch))?;
        self.exec_time += t0.elapsed();
        self.batches_run += 1;
        ensure!(out.len() == 1, "remote artifact must yield (logits,)");
        let logits = &out[0];
        ensure!(logits.shape() == [padded, self.num_classes], "bad remote logits shape");
        (0..feats.len()).map(|i| Ok(logits.row(i)?.to_vec())).collect()
    }

    /// End-to-end server phase for one frame (decode + batch-1 inference).
    pub fn process_frame(&mut self, frame: &Frame) -> Result<Vec<f32>> {
        let feats = self.decode(frame)?;
        Ok(self.infer(std::slice::from_ref(&feats))?.remove(0))
    }
}
