//! Prediction combiner (paper §3.3): final logits are the point-to-point
//! weighted sum alpha*local + (1-alpha)*remote. alpha is trained offline
//! (sigmoid(w/T)) and can be overridden at runtime to re-balance the split
//! when XAI mis-evaluated some features (§3.3's runtime fine-tuning knob).

use crate::tensor::argmax;
use anyhow::{ensure, Result};

#[derive(Debug, Clone, Copy)]
pub struct Combiner {
    alpha: f64,
}

impl Combiner {
    pub fn new(alpha: f64) -> Result<Self> {
        ensure!((0.0..=1.0).contains(&alpha), "alpha must be in [0,1], got {alpha}");
        Ok(Self { alpha })
    }

    pub fn alpha(&self) -> f64 {
        self.alpha
    }

    /// Runtime re-weighting (paper §3.3 / Fig 18).
    pub fn with_alpha(&self, alpha: f64) -> Result<Self> {
        Self::new(alpha)
    }

    /// Combined logits (allocating variant).
    pub fn combine(&self, local: &[f32], remote: &[f32]) -> Result<Vec<f32>> {
        ensure!(
            local.len() == remote.len(),
            "logit length mismatch: {} vs {}",
            local.len(),
            remote.len()
        );
        let a = self.alpha as f32;
        Ok(local.iter().zip(remote).map(|(l, r)| a * l + (1.0 - a) * r).collect())
    }

    /// Final class prediction.
    pub fn predict(&self, local: &[f32], remote: &[f32]) -> Result<usize> {
        Ok(argmax(&self.combine(local, remote)?))
    }

    /// Local-only fallback (paper §9 "extreme network conditions": when the
    /// link is down the device still predicts from the top-k features).
    pub fn predict_local_only(&self, local: &[f32]) -> usize {
        argmax(local)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rejects_out_of_range_alpha() {
        assert!(Combiner::new(-0.1).is_err());
        assert!(Combiner::new(1.1).is_err());
        assert!(Combiner::new(0.5).is_ok());
    }

    #[test]
    fn endpoints_select_one_side() {
        let local = [10.0, 0.0];
        let remote = [0.0, 10.0];
        assert_eq!(Combiner::new(1.0).unwrap().predict(&local, &remote).unwrap(), 0);
        assert_eq!(Combiner::new(0.0).unwrap().predict(&local, &remote).unwrap(), 1);
    }

    #[test]
    fn weighted_sum_is_pointwise() {
        let c = Combiner::new(0.3).unwrap();
        let out = c.combine(&[1.0, 2.0], &[3.0, 4.0]).unwrap();
        assert!((out[0] - (0.3 * 1.0 + 0.7 * 3.0)).abs() < 1e-6);
        assert!((out[1] - (0.3 * 2.0 + 0.7 * 4.0)).abs() < 1e-6);
    }

    #[test]
    fn mismatched_lengths_error() {
        let c = Combiner::new(0.5).unwrap();
        assert!(c.combine(&[1.0], &[1.0, 2.0]).is_err());
    }

    #[test]
    fn local_fallback() {
        let c = Combiner::new(0.5).unwrap();
        assert_eq!(c.predict_local_only(&[0.0, 5.0, 1.0]), 1);
    }
}
