//! Concrete scheme runners. Shared conventions:
//!  * functional outputs come from the AOT PJRT artifacts (real numerics);
//!  * device-side latency/energy are priced by the MCU cost model;
//!  * server-side NN latency is measured wall-clock on the PJRT CPU client;
//!  * network time comes from the link model over the real payload sizes.

use super::{RequestOutcome, SchemeRunner};
use crate::compression::{lzw, quantizer::Codebook, TxEncoder};
use crate::config::{Meta, RunConfig, Scheme};
use crate::coordinator::combiner::Combiner;
use crate::coordinator::device_runtime::DeviceRuntime;
use crate::coordinator::server::RemoteServer;
use crate::metrics::{EnergyLedger, LatencyBreakdown};
use crate::runtime::{Engine, Executable};
use crate::simulator::{DeviceSim, MemoryReport, NetworkSim};
use crate::tensor::{argmax, max_confidence, Tensor};
use anyhow::{ensure, Result};
use std::sync::Arc;
use std::time::Instant;

/// Downlink reply: logits (num_classes f32) + small header.
fn reply_bytes(num_classes: usize) -> usize {
    num_classes * 4 + 8
}

/// Activation-peak estimate (int8 bytes at 32x32; the device sim's
/// resolution_scale handles the 96x96 translation for SRAM the same way it
/// does for MACs — activations scale with spatial area).
fn activation_peak(scheme: Scheme) -> usize {
    match scheme {
        // conv1: 32*32*3 in + 16*16*16 out; conv2: 4096 + 8*8*24
        Scheme::Agile => 3072 + 4096,
        // encoder conv2: 16*16*32 + 16*16*32
        Scheme::Deepcod => 8192 + 8192,
        // conv1: 3072 + 16*16*24
        Scheme::Spinn => 3072 + 6144,
        // conv1: 3072 + 16*16*16
        Scheme::Mcunet => 3072 + 4096,
        // raw image buffer only
        Scheme::EdgeOnly => 3072,
    }
}

/// LZW dictionary SRAM for schemes that compress on-device.
const LZW_DICT_SRAM: usize = 20 * 1024;

fn memory_report_for(cfg: &RunConfig, meta: &Meta, scheme: Scheme) -> MemoryReport {
    let scale = cfg.device.resolution_scale as usize;
    let compresses = !matches!(scheme, Scheme::Mcunet);
    let act = activation_peak(scheme) * scale + if compresses { LZW_DICT_SRAM } else { 0 };
    MemoryReport::new(&cfg.device, act, meta.device_param_bytes(scheme) as usize)
}

// ---------------------------------------------------------------------------
// AgileNN
// ---------------------------------------------------------------------------

pub struct AgileRunner {
    device: DeviceRuntime,
    server: RemoteServer,
    combiner: Combiner,
    net: NetworkSim,
    meta_mem: MemoryReport,
    num_classes: usize,
}

impl AgileRunner {
    pub fn new(engine: &Engine, cfg: &RunConfig, meta: &Meta) -> Result<Self> {
        ensure!(cfg.scheme == Scheme::Agile, "wrong scheme for AgileRunner");
        let alpha = cfg.alpha_override.unwrap_or(meta.alpha);
        Ok(Self {
            device: DeviceRuntime::new(engine, cfg, meta)?,
            server: RemoteServer::new(engine, cfg, meta)?,
            combiner: Combiner::new(alpha)?,
            net: NetworkSim::new(cfg.network.clone()),

            meta_mem: memory_report_for(cfg, meta, Scheme::Agile),
            num_classes: meta.num_classes,
        })
    }

    pub fn set_alpha(&mut self, alpha: f64) -> Result<()> {
        self.combiner = self.combiner.with_alpha(alpha)?;
        Ok(())
    }

    /// Local-only operation for link-down conditions (paper §9).
    pub fn process_offline(&mut self, image: &Tensor, label: i32) -> Result<RequestOutcome> {
        let out = self.device.process(image)?;
        let predicted = self.combiner.predict_local_only(&out.local_logits);
        let sim = self.device.sim().clone();
        Ok(RequestOutcome {
            predicted,
            correct: predicted as i32 == label,
            breakdown: LatencyBreakdown {
                local_nn_s: out.timings.nn_compute_s,
                ..Default::default()
            },
            energy: EnergyLedger {
                compute_j: sim.compute_energy_j(out.timings.nn_compute_s),
                radio_j: 0.0,
            },
            tx_bytes: 0,
            exited_early: true,
        })
    }
}

impl SchemeRunner for AgileRunner {
    fn scheme(&self) -> Scheme {
        Scheme::Agile
    }

    fn process(&mut self, image: &Tensor, label: i32) -> Result<RequestOutcome> {
        let out = self.device.process(image)?;
        let tx_bytes = out.frame.wire_bytes();

        let t0 = Instant::now();
        let remote_logits = self.server.process_frame(&out.frame)?;
        let remote_wall = t0.elapsed().as_secs_f64();

        let predicted = self.combiner.predict(&out.local_logits, &remote_logits)?;

        let uplink = self.net.transfer_s(tx_bytes);
        let downlink = self.net.transfer_s(reply_bytes(self.num_classes));
        let sim = self.device.sim();
        let breakdown = LatencyBreakdown {
            local_nn_s: out.timings.nn_compute_s,
            compression_s: out.timings.quantize_s + out.timings.compress_s,
            network_s: uplink + downlink,
            remote_s: remote_wall,
        };
        let energy = EnergyLedger {
            compute_j: sim.compute_energy_j(out.timings.total_s()),
            radio_j: sim
                .radio_energy_j(self.net.airtime_s(tx_bytes) + self.net.airtime_s(reply_bytes(self.num_classes))),
        };
        Ok(RequestOutcome {
            predicted,
            correct: predicted as i32 == label,
            breakdown,
            energy,
            tx_bytes,
            exited_early: false,
        })
    }

    fn memory_report(&self) -> MemoryReport {
        self.meta_mem
    }
}

// ---------------------------------------------------------------------------
// DeepCOD [65]
// ---------------------------------------------------------------------------

pub struct DeepcodRunner {
    encoder: Arc<Executable>,
    server: RemoteServer,
    tx: TxEncoder,
    dev: DeviceSim,
    net: NetworkSim,
    device_macs: u64,
    num_classes: usize,
    mem: MemoryReport,
}

impl DeepcodRunner {
    pub fn new(engine: &Engine, cfg: &RunConfig, meta: &Meta) -> Result<Self> {
        ensure!(cfg.scheme == Scheme::Deepcod, "wrong scheme for DeepcodRunner");
        let encoder = engine.load_artifact(&cfg.dataset_dir(), "deepcod_device_b1")?;
        let codebook = Codebook::new(meta.codebook(Scheme::Deepcod, cfg.bits)?)?;
        Ok(Self {
            encoder,
            server: RemoteServer::new(engine, cfg, meta)?,
            tx: TxEncoder::new(codebook),
            dev: DeviceSim::new(cfg.device.clone()),
            net: NetworkSim::new(cfg.network.clone()),
            device_macs: meta.macs.deepcod_device,
            num_classes: meta.num_classes,
            mem: memory_report_for(cfg, meta, Scheme::Deepcod),
        })
    }
}

impl SchemeRunner for DeepcodRunner {
    fn scheme(&self) -> Scheme {
        Scheme::Deepcod
    }

    fn process(&mut self, image: &Tensor, label: i32) -> Result<RequestOutcome> {
        let outputs = self.encoder.run(std::slice::from_ref(image))?;
        ensure!(outputs.len() == 1, "deepcod encoder yields (code,)");
        let code = &outputs[0];
        let frame = self.tx.encode(code.data());
        let tx_bytes = frame.wire_bytes();

        let t0 = Instant::now();
        let logits = self.server.process_frame(&frame)?;
        let remote_wall = t0.elapsed().as_secs_f64();
        let predicted = argmax(&logits);

        let nn_s = self.dev.nn_latency_s(self.device_macs);
        let quant_s = self.dev.quantize_latency_s(code.len());
        let lzw_s = self
            .dev
            .compress_latency_s((code.len() * self.tx.codebook().bits() as usize + 7) / 8);
        let breakdown = LatencyBreakdown {
            local_nn_s: nn_s,
            compression_s: quant_s + lzw_s,
            network_s: self.net.transfer_s(tx_bytes) + self.net.transfer_s(reply_bytes(self.num_classes)),
            remote_s: remote_wall,
        };
        let energy = EnergyLedger {
            compute_j: self.dev.compute_energy_j(nn_s + quant_s + lzw_s),
            radio_j: self.dev.radio_energy_j(
                self.net.airtime_s(tx_bytes) + self.net.airtime_s(reply_bytes(self.num_classes)),
            ),
        };
        Ok(RequestOutcome {
            predicted,
            correct: predicted as i32 == label,
            breakdown,
            energy,
            tx_bytes,
            exited_early: false,
        })
    }

    fn memory_report(&self) -> MemoryReport {
        self.mem
    }
}

// ---------------------------------------------------------------------------
// SPINN [39]
// ---------------------------------------------------------------------------

pub struct SpinnRunner {
    device_exe: Arc<Executable>,
    server: RemoteServer,
    tx: TxEncoder,
    dev: DeviceSim,
    net: NetworkSim,
    device_macs: u64,
    exit_threshold: f32,
    num_classes: usize,
    mem: MemoryReport,
}

impl SpinnRunner {
    pub fn new(engine: &Engine, cfg: &RunConfig, meta: &Meta) -> Result<Self> {
        ensure!(cfg.scheme == Scheme::Spinn, "wrong scheme for SpinnRunner");
        let device_exe = engine.load_artifact(&cfg.dataset_dir(), "spinn_device_b1")?;
        let codebook = Codebook::new(meta.codebook(Scheme::Spinn, cfg.bits)?)?;
        Ok(Self {
            device_exe,
            server: RemoteServer::new(engine, cfg, meta)?,
            tx: TxEncoder::new(codebook),
            dev: DeviceSim::new(cfg.device.clone()),
            net: NetworkSim::new(cfg.network.clone()),
            device_macs: meta.macs.spinn_device,
            exit_threshold: meta.spinn_exit.threshold as f32,
            num_classes: meta.num_classes,
            mem: memory_report_for(cfg, meta, Scheme::Spinn),
        })
    }
}

impl SchemeRunner for SpinnRunner {
    fn scheme(&self) -> Scheme {
        Scheme::Spinn
    }

    fn process(&mut self, image: &Tensor, label: i32) -> Result<RequestOutcome> {
        let outputs = self.device_exe.run(std::slice::from_ref(image))?;
        ensure!(outputs.len() == 2, "spinn device yields (feats, exit_logits)");
        let feats = &outputs[0];
        let exit_logits = outputs[1].data();
        let nn_s = self.dev.nn_latency_s(self.device_macs);

        // early exit: confident enough -> resolve on device, no transmission
        if max_confidence(exit_logits) >= self.exit_threshold {
            let predicted = argmax(exit_logits);
            return Ok(RequestOutcome {
                predicted,
                correct: predicted as i32 == label,
                breakdown: LatencyBreakdown { local_nn_s: nn_s, ..Default::default() },
                energy: EnergyLedger { compute_j: self.dev.compute_energy_j(nn_s), radio_j: 0.0 },
                tx_bytes: 0,
                exited_early: true,
            });
        }

        let frame = self.tx.encode(feats.data());
        let tx_bytes = frame.wire_bytes();
        let t0 = Instant::now();
        let logits = self.server.process_frame(&frame)?;
        let remote_wall = t0.elapsed().as_secs_f64();
        let predicted = argmax(&logits);

        let quant_s = self.dev.quantize_latency_s(feats.len());
        let lzw_s = self
            .dev
            .compress_latency_s((feats.len() * self.tx.codebook().bits() as usize + 7) / 8);
        let breakdown = LatencyBreakdown {
            local_nn_s: nn_s,
            compression_s: quant_s + lzw_s,
            network_s: self.net.transfer_s(tx_bytes) + self.net.transfer_s(reply_bytes(self.num_classes)),
            remote_s: remote_wall,
        };
        let energy = EnergyLedger {
            compute_j: self.dev.compute_energy_j(nn_s + quant_s + lzw_s),
            radio_j: self.dev.radio_energy_j(
                self.net.airtime_s(tx_bytes) + self.net.airtime_s(reply_bytes(self.num_classes)),
            ),
        };
        Ok(RequestOutcome {
            predicted,
            correct: predicted as i32 == label,
            breakdown,
            energy,
            tx_bytes,
            exited_early: false,
        })
    }

    fn memory_report(&self) -> MemoryReport {
        self.mem
    }
}

// ---------------------------------------------------------------------------
// MCUNet [44] — full local inference
// ---------------------------------------------------------------------------

pub struct McunetRunner {
    exe: Arc<Executable>,
    dev: DeviceSim,
    device_macs: u64,
    mem: MemoryReport,
}

impl McunetRunner {
    pub fn new(engine: &Engine, cfg: &RunConfig, meta: &Meta) -> Result<Self> {
        ensure!(cfg.scheme == Scheme::Mcunet, "wrong scheme for McunetRunner");
        Ok(Self {
            exe: engine.load_artifact(&cfg.dataset_dir(), "mcunet_local_b1")?,
            dev: DeviceSim::new(cfg.device.clone()),
            device_macs: meta.macs.mcunet_local,
            mem: memory_report_for(cfg, meta, Scheme::Mcunet),
        })
    }
}

impl SchemeRunner for McunetRunner {
    fn scheme(&self) -> Scheme {
        Scheme::Mcunet
    }

    fn process(&mut self, image: &Tensor, label: i32) -> Result<RequestOutcome> {
        let outputs = self.exe.run(std::slice::from_ref(image))?;
        let predicted = argmax(outputs[0].data());
        let nn_s = self.dev.nn_latency_s(self.device_macs);
        Ok(RequestOutcome {
            predicted,
            correct: predicted as i32 == label,
            breakdown: LatencyBreakdown { local_nn_s: nn_s, ..Default::default() },
            energy: EnergyLedger { compute_j: self.dev.compute_energy_j(nn_s), radio_j: 0.0 },
            tx_bytes: 0,
            exited_early: false,
        })
    }

    fn memory_report(&self) -> MemoryReport {
        self.mem
    }
}

// ---------------------------------------------------------------------------
// Edge-only: LZW-compressed raw image to the server
// ---------------------------------------------------------------------------

pub struct EdgeOnlyRunner {
    exe: Arc<Executable>,
    dev: DeviceSim,
    net: NetworkSim,
    num_classes: usize,
    mem: MemoryReport,
}

impl EdgeOnlyRunner {
    pub fn new(engine: &Engine, cfg: &RunConfig, meta: &Meta) -> Result<Self> {
        ensure!(cfg.scheme == Scheme::EdgeOnly, "wrong scheme for EdgeOnlyRunner");
        Ok(Self {
            exe: engine.load_artifact(&cfg.dataset_dir(), "edge_remote_b1")?,
            dev: DeviceSim::new(cfg.device.clone()),
            net: NetworkSim::new(cfg.network.clone()),
            num_classes: meta.num_classes,
            mem: memory_report_for(cfg, meta, Scheme::EdgeOnly),
        })
    }
}

impl SchemeRunner for EdgeOnlyRunner {
    fn scheme(&self) -> Scheme {
        Scheme::EdgeOnly
    }

    fn process(&mut self, image: &Tensor, label: i32) -> Result<RequestOutcome> {
        // device: quantize f32 [0,1] image to u8 and LZW it (no NN on device)
        let raw: Vec<u8> = image.data().iter().map(|&v| (v * 255.0) as u8).collect();
        let compressed = lzw::compress(&raw);
        let tx_bytes = compressed.len() + 4;

        // server: decompress, rebuild the image, full NN
        let t0 = Instant::now();
        let decompressed = lzw::decompress(&compressed)?;
        let img: Vec<f32> = decompressed.iter().map(|&b| b as f32 / 255.0).collect();
        let tensor = Tensor::new(image.shape().to_vec(), img)?;
        let outputs = self.exe.run(std::slice::from_ref(&tensor))?;
        let remote_wall = t0.elapsed().as_secs_f64();
        let predicted = argmax(outputs[0].data());

        let lzw_s = self.dev.compress_latency_s(raw.len());
        let breakdown = LatencyBreakdown {
            local_nn_s: 0.0,
            compression_s: lzw_s,
            network_s: self.net.transfer_s(tx_bytes) + self.net.transfer_s(reply_bytes(self.num_classes)),
            remote_s: remote_wall,
        };
        let energy = EnergyLedger {
            compute_j: self.dev.compute_energy_j(lzw_s),
            radio_j: self.dev.radio_energy_j(
                self.net.airtime_s(tx_bytes) + self.net.airtime_s(reply_bytes(self.num_classes)),
            ),
        };
        Ok(RequestOutcome {
            predicted,
            correct: predicted as i32 == label,
            breakdown,
            energy,
            tx_bytes,
            exited_early: false,
        })
    }

    fn memory_report(&self) -> MemoryReport {
        self.mem
    }
}
