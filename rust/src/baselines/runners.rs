//! Synchronous scheme runners, composed from the serve module's halves
//! (`DeviceSide` -> optional `ServerSide` -> `Fuser`). Shared conventions:
//!  * functional outputs come from the selected inference backend (AOT
//!    PJRT artifacts for real numerics, or the deterministic pure-Rust
//!    reference family);
//!  * device-side latency/energy are priced by the MCU cost model;
//!  * server-side NN latency is measured wall-clock on the PJRT CPU client;
//!  * network time comes from the link model over the real payload sizes.
//!
//! The per-figure benches use this path because its simulated-time
//! accounting is exact; the threaded serving pipeline in `crate::serve`
//! drives the very same halves concurrently.

use super::{RequestOutcome, SchemeRunner};
use crate::config::{Meta, RunConfig, Scheme};
use crate::runtime::Backend;
use crate::serve::scheme::assemble_outcome;
use crate::serve::{
    make_device_side, make_fuser, make_server_side, AlphaFuser, DeviceSide, Fuser, ServerSide,
};
use crate::simulator::{DeviceSim, MemoryReport, NetworkSim};
use crate::tensor::Tensor;
use anyhow::{anyhow, ensure, Result};
use std::time::Instant;

/// Any serving scheme, synchronously: device half -> (optional) server
/// half -> fuser, one request at a time.
pub struct ComposedRunner {
    scheme: Scheme,
    device: Box<dyn DeviceSide>,
    server: Option<Box<dyn ServerSide>>,
    fuser: Box<dyn Fuser>,
    dev: DeviceSim,
    net: NetworkSim,
    num_classes: usize,
}

impl ComposedRunner {
    pub fn new(backend: &dyn Backend, cfg: &RunConfig, meta: &Meta) -> Result<Self> {
        Ok(Self {
            scheme: cfg.scheme,
            device: make_device_side(backend, cfg, meta)?,
            server: make_server_side(backend, cfg, meta)?,
            fuser: make_fuser(cfg, meta)?,
            dev: DeviceSim::new(cfg.device.clone()),
            net: NetworkSim::new(cfg.network.clone()),
            num_classes: meta.num_classes,
        })
    }

    /// `offload = false` models a downed link (paper §9): the device skips
    /// the tx pipeline and the fuser falls back to the local head.
    fn process_inner(&mut self, image: &Tensor, label: i32, offload: bool) -> Result<RequestOutcome> {
        let mut local = self.device.encode(image)?;
        if !offload {
            local.frame = None;
            local.symbols = None;
            local.timings.quantize_s = 0.0;
            local.timings.compress_s = 0.0;
            local.exited_early = true;
        }
        let tx_bytes = local.tx_bytes();

        let mut remote: Option<Vec<f32>> = None;
        let mut remote_wall = 0.0f64;
        if let Some(frame) = local.frame.take() {
            let server = self.server.as_mut().ok_or_else(|| {
                anyhow!("{} produced an uplink frame but has no server half", self.scheme.name())
            })?;
            let t0 = Instant::now();
            let feats = server.decode(&frame)?;
            let rows = server.infer_batch(std::slice::from_ref(&feats))?;
            remote_wall = t0.elapsed().as_secs_f64();
            let row = rows.into_iter().next().ok_or_else(|| anyhow!("server returned no logits"))?;
            remote = Some(row);
        }

        assemble_outcome(
            self.fuser.as_ref(),
            &local,
            remote.as_deref(),
            label,
            tx_bytes,
            remote_wall,
            &self.dev,
            &self.net,
            None, // synchronous benches stay on the exact ideal-link pricing
            self.num_classes,
        )
    }
}

impl SchemeRunner for ComposedRunner {
    fn scheme(&self) -> Scheme {
        self.scheme
    }

    fn process(&mut self, image: &Tensor, label: i32) -> Result<RequestOutcome> {
        self.process_inner(image, label, true)
    }

    fn memory_report(&self) -> MemoryReport {
        self.device.memory_report()
    }
}

/// AgileNN's runner, adding the paper's runtime knobs (§3.3 alpha
/// re-weighting, §9 offline fallback) on top of [`ComposedRunner`].
pub struct AgileRunner {
    inner: ComposedRunner,
}

impl AgileRunner {
    pub fn new(backend: &dyn Backend, cfg: &RunConfig, meta: &Meta) -> Result<Self> {
        ensure!(cfg.scheme == Scheme::Agile, "wrong scheme for AgileRunner");
        Ok(Self { inner: ComposedRunner::new(backend, cfg, meta)? })
    }

    /// Runtime re-weighting (paper §3.3 / Fig 18).
    pub fn set_alpha(&mut self, alpha: f64) -> Result<()> {
        self.inner.fuser = Box::new(AlphaFuser::new(alpha)?);
        Ok(())
    }

    /// Local-only operation for link-down conditions (paper §9).
    pub fn process_offline(&mut self, image: &Tensor, label: i32) -> Result<RequestOutcome> {
        self.inner.process_inner(image, label, false)
    }
}

impl SchemeRunner for AgileRunner {
    fn scheme(&self) -> Scheme {
        Scheme::Agile
    }

    fn process(&mut self, image: &Tensor, label: i32) -> Result<RequestOutcome> {
        self.inner.process_inner(image, label, true)
    }

    fn memory_report(&self) -> MemoryReport {
        self.inner.memory_report()
    }
}
