//! All five serving schemes behind one trait, so every bench/figure sweeps
//! them uniformly (paper §7's comparison set: AgileNN, DeepCOD, SPINN,
//! MCUNet, edge-only). Runners are thin synchronous compositions of the
//! device/server halves in [`crate::serve`].
//!
//! Each runner produces, per request: the prediction, a latency breakdown
//! priced by the device/network simulators (plus measured wall-clock for the
//! server-side NN), the device energy ledger, and the transmitted bytes.

mod runners;

pub use runners::{AgileRunner, ComposedRunner};

use crate::config::{Meta, RunConfig, Scheme};
use crate::metrics::{EnergyLedger, LatencyBreakdown};
use crate::net::NetStats;
use crate::runtime::Backend;
use crate::simulator::MemoryReport;
use crate::tensor::Tensor;
use anyhow::Result;

/// Outcome of one request under some scheme.
#[derive(Debug, Clone)]
pub struct RequestOutcome {
    pub predicted: usize,
    pub correct: bool,
    pub breakdown: LatencyBreakdown,
    pub energy: EnergyLedger,
    /// application-layer uplink payload bytes (0 for local-only schemes)
    pub tx_bytes: usize,
    /// transport accounting over the simulated channel (zeroed for
    /// local-only requests; `complete` on the ideal synchronous path)
    pub net: NetStats,
    /// SPINN: request resolved at the on-device early exit
    pub exited_early: bool,
}

/// A serving scheme, end to end.
pub trait SchemeRunner {
    fn scheme(&self) -> Scheme;

    /// Process one sensor sample; `label` is used only for accuracy scoring.
    fn process(&mut self, image: &Tensor, label: i32) -> Result<RequestOutcome>;

    /// Static on-device memory accounting (Fig 20).
    fn memory_report(&self) -> MemoryReport;
}

/// Instantiate a runner for any scheme.
pub fn make_runner(
    backend: &dyn Backend,
    cfg: &RunConfig,
    meta: &Meta,
) -> Result<Box<dyn SchemeRunner>> {
    Ok(match cfg.scheme {
        Scheme::Agile => Box::new(AgileRunner::new(backend, cfg, meta)?),
        _ => Box::new(ComposedRunner::new(backend, cfg, meta)?),
    })
}
