//! Typed configuration: trained-artifact metadata (meta.json, written by
//! `python -m compile.aot`) and the runtime configuration assembled from
//! CLI flags.

use crate::json::Value;
use crate::net::NetConfig;
use crate::serve::policy::PolicyConfig;
use crate::simulator::{DeviceProfile, NetworkProfile};
use anyhow::{anyhow, bail, Context, Result};
use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::str::FromStr;

/// Which serving scheme to run (paper §7's comparison set).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Scheme {
    /// AgileNN: XAI-partitioned offloading (the paper's system)
    Agile,
    /// DeepCOD [65]: learned encoder on-device, decoder remote
    Deepcod,
    /// SPINN [39]: partitioned NN with on-device early exit
    Spinn,
    /// MCUNet [44]: full local inference
    Mcunet,
    /// Edge-only: LZW-compressed raw data to the server
    EdgeOnly,
}

impl Scheme {
    pub fn all() -> [Scheme; 5] {
        [Scheme::Agile, Scheme::Deepcod, Scheme::Spinn, Scheme::Mcunet, Scheme::EdgeOnly]
    }

    pub fn name(&self) -> &'static str {
        match self {
            Scheme::Agile => "AgileNN",
            Scheme::Deepcod => "DeepCOD",
            Scheme::Spinn => "SPINN",
            Scheme::Mcunet => "MCUNet",
            Scheme::EdgeOnly => "EdgeOnly",
        }
    }
}

impl FromStr for Scheme {
    type Err = anyhow::Error;

    fn from_str(s: &str) -> Result<Self> {
        match s.to_ascii_lowercase().as_str() {
            "agile" | "agilenn" => Ok(Scheme::Agile),
            "deepcod" => Ok(Scheme::Deepcod),
            "spinn" => Ok(Scheme::Spinn),
            "mcunet" => Ok(Scheme::Mcunet),
            "edge" | "edgeonly" | "edge-only" => Ok(Scheme::EdgeOnly),
            other => bail!("unknown scheme {other:?} (agile|deepcod|spinn|mcunet|edge)"),
        }
    }
}

/// Which inference backend executes the exported model components
/// (`crate::runtime::Backend`).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, Default)]
pub enum BackendKind {
    /// AOT-compiled HLO artifacts on the PJRT CPU client (cargo feature
    /// `pjrt`; needs `make artifacts`). The default: real numerics.
    #[default]
    Pjrt,
    /// Pure-Rust deterministic reference model family
    /// (`crate::runtime::ReferenceBackend`): no artifacts, no native
    /// deps, synthetic fixtures (`crate::fixtures`) stand in for the
    /// trained metadata and test set.
    Reference,
}

impl BackendKind {
    pub fn all() -> [BackendKind; 2] {
        [BackendKind::Pjrt, BackendKind::Reference]
    }

    pub fn name(&self) -> &'static str {
        match self {
            BackendKind::Pjrt => "pjrt",
            BackendKind::Reference => "reference",
        }
    }
}

impl FromStr for BackendKind {
    type Err = anyhow::Error;

    fn from_str(s: &str) -> Result<Self> {
        match s.to_ascii_lowercase().as_str() {
            "pjrt" | "xla" => Ok(BackendKind::Pjrt),
            "reference" | "ref" => Ok(BackendKind::Reference),
            other => bail!("unknown backend {other:?} (pjrt|reference)"),
        }
    }
}

/// MAC counts per component (exported by python, 32x32 models).
#[derive(Debug, Clone)]
pub struct MacCounts {
    pub agile_device: u64,
    pub agile_extractor: u64,
    pub agile_local: u64,
    pub agile_remote: u64,
    pub deepcod_device: u64,
    pub spinn_device: u64,
    pub mcunet_local: u64,
}

#[derive(Debug, Clone)]
pub struct ParamBytes {
    pub agile_device: u64,
    pub deepcod_device: u64,
    pub spinn_device: u64,
    pub mcunet_local: u64,
}

#[derive(Debug, Clone)]
pub struct TxElements {
    pub agile: usize,
    pub deepcod: usize,
    pub spinn: usize,
    pub edge_raw_bytes: usize,
}

#[derive(Debug, Clone)]
pub struct PyAccuracy {
    pub agile: f64,
    pub agile_quant4: f64,
    pub agile_local_only: f64,
    pub deepcod: f64,
    pub spinn_final: f64,
    pub mcunet: f64,
    pub edge_only: f64,
}

#[derive(Debug, Clone)]
pub struct SpinnExit {
    pub threshold: f64,
    pub rate: f64,
    pub accuracy: f64,
}

#[derive(Debug, Clone)]
pub struct SkewQuantiles {
    pub p10: f64,
    pub p50: f64,
    pub p90: f64,
}

#[derive(Debug, Clone)]
pub struct ImportanceStats {
    pub natural_skewness_quantiles: SkewQuantiles,
    pub achieved_skewness_mean: f64,
    pub disorder_rate: f64,
    pub mean_importance_per_channel: Vec<f64>,
}

/// Everything the python build exported about one trained dataset.
#[derive(Debug, Clone)]
pub struct Meta {
    pub dataset: String,
    pub num_classes: usize,
    pub image: [usize; 3],
    pub feature: [usize; 3],
    pub k: usize,
    pub rho: f64,
    pub alpha: f64,
    pub xai_tool: String,
    pub selected_channels: Vec<usize>,
    /// codebooks keyed by bit width ("1".."6")
    pub codebooks: HashMap<String, Vec<f32>>,
    pub code_entropy_bits: HashMap<String, f64>,
    pub deepcod_codebooks: HashMap<String, Vec<f32>>,
    pub spinn_codebooks: HashMap<String, Vec<f32>>,
    pub macs: MacCounts,
    pub param_bytes_int8: ParamBytes,
    pub tx_elements: TxElements,
    pub accuracy: PyAccuracy,
    pub spinn_exit: SpinnExit,
    pub importance: ImportanceStats,
}

fn dims3(v: &Value, key: &str) -> Result<[usize; 3]> {
    let xs = v.usize_vec_at(key)?;
    if xs.len() != 3 {
        bail!("{key} must have 3 dims");
    }
    Ok([xs[0], xs[1], xs[2]])
}

fn codebook_map(v: &Value, key: &str) -> Result<HashMap<String, Vec<f32>>> {
    let mut out = HashMap::new();
    for (k, val) in v.get(key)?.as_obj()? {
        let levels: Vec<f32> =
            val.as_arr()?.iter().map(|x| Ok(x.as_f64()? as f32)).collect::<Result<_>>()?;
        out.insert(k.clone(), levels);
    }
    Ok(out)
}

impl Meta {
    pub fn from_json(v: &Value) -> Result<Self> {
        let macs = v.get("macs")?;
        let pb = v.get("param_bytes_int8")?;
        let tx = v.get("tx_elements")?;
        let acc = v.get("accuracy")?;
        let se = v.get("spinn_exit")?;
        let imp = v.get("importance")?;
        let nsq = imp.get("natural_skewness_quantiles")?;
        let mut entropy = HashMap::new();
        for (k, val) in v.get("code_entropy_bits")?.as_obj()? {
            entropy.insert(k.clone(), val.as_f64()?);
        }
        Ok(Meta {
            dataset: v.str_at("dataset")?,
            num_classes: v.usize_at("num_classes")?,
            image: dims3(v, "image")?,
            feature: dims3(v, "feature")?,
            k: v.usize_at("k")?,
            rho: v.f64_at("rho")?,
            alpha: v.f64_at("alpha")?,
            xai_tool: v.str_at("xai_tool")?,
            selected_channels: v.usize_vec_at("selected_channels")?,
            codebooks: codebook_map(v, "codebooks")?,
            code_entropy_bits: entropy,
            deepcod_codebooks: codebook_map(v, "deepcod_codebooks")?,
            spinn_codebooks: codebook_map(v, "spinn_codebooks")?,
            macs: MacCounts {
                agile_device: macs.u64_at("agile_device")?,
                agile_extractor: macs.u64_at("agile_extractor")?,
                agile_local: macs.u64_at("agile_local")?,
                agile_remote: macs.u64_at("agile_remote")?,
                deepcod_device: macs.u64_at("deepcod_device")?,
                spinn_device: macs.u64_at("spinn_device")?,
                mcunet_local: macs.u64_at("mcunet_local")?,
            },
            param_bytes_int8: ParamBytes {
                agile_device: pb.u64_at("agile_device")?,
                deepcod_device: pb.u64_at("deepcod_device")?,
                spinn_device: pb.u64_at("spinn_device")?,
                mcunet_local: pb.u64_at("mcunet_local")?,
            },
            tx_elements: TxElements {
                agile: tx.usize_at("agile")?,
                deepcod: tx.usize_at("deepcod")?,
                spinn: tx.usize_at("spinn")?,
                edge_raw_bytes: tx.usize_at("edge_raw_bytes")?,
            },
            accuracy: PyAccuracy {
                agile: acc.f64_at("agile")?,
                agile_quant4: acc.f64_at("agile_quant4")?,
                agile_local_only: acc.f64_at("agile_local_only")?,
                deepcod: acc.f64_at("deepcod")?,
                spinn_final: acc.f64_at("spinn_final")?,
                mcunet: acc.f64_at("mcunet")?,
                edge_only: acc.f64_at("edge_only")?,
            },
            spinn_exit: SpinnExit {
                threshold: se.f64_at("threshold")?,
                rate: se.f64_at("rate")?,
                accuracy: se.f64_at("accuracy")?,
            },
            importance: ImportanceStats {
                natural_skewness_quantiles: SkewQuantiles {
                    p10: nsq.f64_at("p10")?,
                    p50: nsq.f64_at("p50")?,
                    p90: nsq.f64_at("p90")?,
                },
                achieved_skewness_mean: imp.f64_at("achieved_skewness_mean")?,
                disorder_rate: imp.f64_at("disorder_rate")?,
                mean_importance_per_channel: imp.f64_vec_at("mean_importance_per_channel")?,
            },
        })
    }

    pub fn load(dir: &Path) -> Result<Self> {
        let path = dir.join("meta.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {}", path.display()))?;
        let v = Value::parse(&text).with_context(|| format!("parsing {}", path.display()))?;
        Self::from_json(&v)
    }

    /// Codebook for a bit width, for a given scheme's transmitted stream.
    pub fn codebook(&self, scheme: Scheme, bits: u32) -> Result<Vec<f32>> {
        let table = match scheme {
            Scheme::Agile => &self.codebooks,
            Scheme::Deepcod => &self.deepcod_codebooks,
            Scheme::Spinn => &self.spinn_codebooks,
            _ => return Err(anyhow!("{} does not quantize features", scheme.name())),
        };
        table
            .get(&bits.to_string())
            .cloned()
            .ok_or_else(|| anyhow!("no {}-bit codebook for {}", bits, scheme.name()))
    }

    /// Quantizer widths with an exported codebook for a scheme, ascending
    /// (empty for schemes that do not quantize features).
    pub fn codebook_widths(&self, scheme: Scheme) -> Vec<u32> {
        let table = match scheme {
            Scheme::Agile => &self.codebooks,
            Scheme::Deepcod => &self.deepcod_codebooks,
            Scheme::Spinn => &self.spinn_codebooks,
            _ => return Vec::new(),
        };
        let mut widths: Vec<u32> = table.keys().filter_map(|k| k.parse().ok()).collect();
        widths.sort_unstable();
        widths
    }

    /// Transmitted feature-element count for a scheme (0 = no feature tx).
    pub fn tx_elements(&self, scheme: Scheme) -> usize {
        match scheme {
            Scheme::Agile => self.tx_elements.agile,
            Scheme::Deepcod => self.tx_elements.deepcod,
            Scheme::Spinn => self.tx_elements.spinn,
            _ => 0,
        }
    }

    /// Device-side NN MACs for a scheme.
    pub fn device_macs(&self, scheme: Scheme) -> u64 {
        match scheme {
            Scheme::Agile => self.macs.agile_device,
            Scheme::Deepcod => self.macs.deepcod_device,
            Scheme::Spinn => self.macs.spinn_device,
            Scheme::Mcunet => self.macs.mcunet_local,
            Scheme::EdgeOnly => 0,
        }
    }

    /// Device-side int8 weight bytes for a scheme.
    pub fn device_param_bytes(&self, scheme: Scheme) -> u64 {
        match scheme {
            Scheme::Agile => self.param_bytes_int8.agile_device,
            Scheme::Deepcod => self.param_bytes_int8.deepcod_device,
            Scheme::Spinn => self.param_bytes_int8.spinn_device,
            Scheme::Mcunet => self.param_bytes_int8.mcunet_local,
            Scheme::EdgeOnly => 0,
        }
    }
}

/// Artifact-tree manifest (which datasets were built).
#[derive(Debug, Clone)]
pub struct Manifest {
    pub datasets: Vec<String>,
    pub quick: bool,
}

impl Manifest {
    pub fn load(artifacts_dir: &Path) -> Result<Self> {
        let path = artifacts_dir.join("manifest.json");
        let text = std::fs::read_to_string(&path).with_context(|| {
            format!("reading {} — run `make artifacts` first", path.display())
        })?;
        let v = Value::parse(&text)?;
        Ok(Manifest {
            datasets: v
                .get("datasets")?
                .as_arr()?
                .iter()
                .map(|d| Ok(d.as_str()?.to_string()))
                .collect::<Result<_>>()?,
            quick: v.opt("quick").map(|q| q.as_bool().unwrap_or(false)).unwrap_or(false),
        })
    }
}

/// Dynamic-batcher knobs, grouped (the PR-10 typed-config redesign
/// collapsed the flat `max_batch`/`batch_deadline_us` pair into this).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct BatchConfig {
    /// max batch per dispatch (must be an exported remote batch size)
    pub max_batch: usize,
    /// max queueing delay before dispatch, microseconds
    pub deadline_us: u64,
}

impl Default for BatchConfig {
    fn default() -> Self {
        Self { max_batch: 8, deadline_us: 2000 }
    }
}

impl BatchConfig {
    /// Deadline in seconds, the unit the server loops work in.
    pub fn deadline_s(&self) -> f64 {
        self.deadline_us as f64 * 1e-6
    }
}

/// Fully-resolved runtime configuration for one serving setup.
#[derive(Debug, Clone, PartialEq)]
pub struct RunConfig {
    pub artifacts_dir: PathBuf,
    pub dataset: String,
    pub scheme: Scheme,
    /// which inference backend executes the model components (default:
    /// PJRT over the artifacts tree; `Reference` needs neither artifacts
    /// nor the `pjrt` cargo feature)
    pub backend: BackendKind,
    pub device: DeviceProfile,
    pub network: NetworkProfile,
    /// channel-facing knobs: loss model, bandwidth trace, delivery policy,
    /// packet ordering, seed (defaults = the ideal pre-channel link)
    pub net: NetConfig,
    /// quantizer bit width for transmitted features
    pub bits: u32,
    /// override the trained alpha (paper §3.3 runtime re-weighting)
    pub alpha_override: Option<f64>,
    /// dynamic batcher knobs
    pub batch: BatchConfig,
    /// per-request adaptive split/rate policy (`serve::policy`);
    /// `None` = static operating point, the pre-policy pipeline
    pub policy: Option<PolicyConfig>,
}

impl RunConfig {
    pub fn new(artifacts_dir: impl Into<PathBuf>, dataset: &str, scheme: Scheme) -> Self {
        Self {
            artifacts_dir: artifacts_dir.into(),
            dataset: dataset.to_string(),
            scheme,
            backend: BackendKind::default(),
            device: DeviceProfile::stm32f746(),
            network: NetworkProfile::wifi_6mbps(),
            net: NetConfig::default(),
            bits: 4,
            alpha_override: None,
            batch: BatchConfig::default(),
            policy: None,
        }
    }

    pub fn dataset_dir(&self) -> PathBuf {
        self.artifacts_dir.join(&self.dataset)
    }

    /// Every quantizer width this run may encode at: the static `bits`
    /// plus the policy's candidate set. Each must name an exported
    /// codebook (validated against the manifest before serving starts).
    pub fn candidate_widths(&self) -> Vec<u32> {
        let mut widths = vec![self.bits];
        if let Some(p) = &self.policy {
            widths.extend(p.widths.iter().copied());
        }
        widths.sort_unstable();
        widths.dedup();
        widths
    }
}

/// Default artifacts directory: $AGILENN_ARTIFACTS or ./artifacts.
pub fn default_artifacts_dir() -> PathBuf {
    std::env::var_os("AGILENN_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|| PathBuf::from("artifacts"))
}

#[cfg(test)]
pub(crate) mod tests {
    use super::*;

    #[test]
    fn scheme_names_unique_and_parseable() {
        let names: std::collections::HashSet<_> = Scheme::all().iter().map(|s| s.name()).collect();
        assert_eq!(names.len(), 5);
        assert_eq!("agile".parse::<Scheme>().unwrap(), Scheme::Agile);
        assert_eq!("EDGE".parse::<Scheme>().unwrap(), Scheme::EdgeOnly);
        assert!("bogus".parse::<Scheme>().is_err());
    }

    #[test]
    fn run_config_defaults() {
        let c = RunConfig::new("artifacts", "svhns", Scheme::Agile);
        assert_eq!(c.bits, 4);
        assert_eq!(c.batch, BatchConfig::default());
        assert_eq!(c.batch.max_batch, 8);
        assert_eq!(c.batch.deadline_us, 2000);
        assert_eq!(c.backend, BackendKind::Pjrt);
        assert!(c.policy.is_none());
        assert_eq!(c.candidate_widths(), vec![4]);
        assert!(c.dataset_dir().ends_with("artifacts/svhns"));
    }

    #[test]
    fn candidate_widths_merge_static_bits_with_the_policy_set() {
        let mut c = RunConfig::new("artifacts", "svhns", Scheme::Agile);
        c.bits = 2;
        c.policy = Some(PolicyConfig { widths: vec![1, 2, 4], ..PolicyConfig::default() });
        assert_eq!(c.candidate_widths(), vec![1, 2, 4]);
        c.bits = 6;
        assert_eq!(c.candidate_widths(), vec![1, 2, 4, 6]);
    }

    #[test]
    fn backend_kind_names_parse_back() {
        for kind in BackendKind::all() {
            assert_eq!(kind.name().parse::<BackendKind>().unwrap(), kind);
        }
        assert_eq!("ref".parse::<BackendKind>().unwrap(), BackendKind::Reference);
        assert_eq!("XLA".parse::<BackendKind>().unwrap(), BackendKind::Pjrt);
        assert!("tpu".parse::<BackendKind>().is_err());
        assert_eq!(BackendKind::default(), BackendKind::Pjrt);
    }

    pub(crate) const MINIMAL_META: &str = r#"{
        "dataset":"t","num_classes":10,"image":[32,32,3],"feature":[8,8,24],
        "k":5,"rho":0.8,"alpha":0.5,"xai_tool":"ig",
        "selected_channels":[1,2,3,4,5],
        "codebooks":{"4":[0.0,1.0]},"code_entropy_bits":{"4":1.0},
        "deepcod_codebooks":{"4":[0.0,1.0]},"spinn_codebooks":{"4":[0.0,1.0]},
        "macs":{"agile_device":1,"agile_extractor":1,"agile_local":1,
                "agile_remote":1,"deepcod_device":1,"spinn_device":1,"mcunet_local":1},
        "param_bytes_int8":{"agile_device":1,"deepcod_device":1,"spinn_device":1,"mcunet_local":1},
        "tx_elements":{"agile":1216,"deepcod":768,"spinn":2048,"edge_raw_bytes":3072},
        "accuracy":{"agile":0.9,"agile_quant4":0.9,"agile_local_only":0.2,
                    "deepcod":0.9,"spinn_final":0.9,"mcunet":0.9,"edge_only":0.9},
        "spinn_exit":{"threshold":0.9,"rate":0.5,"accuracy":0.9},
        "importance":{"natural_skewness_quantiles":{"p10":0.3,"p50":0.5,"p90":0.7},
                      "achieved_skewness_mean":0.8,"disorder_rate":0.02,
                      "mean_importance_per_channel":[0.1,0.9]}
    }"#;

    #[test]
    fn meta_parses_minimal_json() {
        let v = Value::parse(MINIMAL_META).unwrap();
        let m = Meta::from_json(&v).unwrap();
        assert_eq!(m.k, 5);
        assert_eq!(m.tx_elements(Scheme::Agile), 1216);
        assert_eq!(m.device_macs(Scheme::EdgeOnly), 0);
        assert!(m.codebook(Scheme::Agile, 4).is_ok());
        assert!(m.codebook(Scheme::Agile, 7).is_err());
        assert!(m.codebook(Scheme::Mcunet, 4).is_err());
    }

    #[test]
    fn manifest_parses() {
        let dir = std::env::temp_dir().join("agilenn_manifest_test");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("manifest.json"), r#"{"datasets":["a","b"],"quick":true}"#)
            .unwrap();
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.datasets, vec!["a", "b"]);
        assert!(m.quick);
    }
}
