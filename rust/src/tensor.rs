//! Minimal dense f32 tensor substrate for the coordinator hot path.
//!
//! The request path needs only a handful of operations (channel slicing,
//! batch stacking/padding, argmax/softmax over logits), so we carry a tiny
//! purpose-built NHWC tensor instead of pulling in an ndarray dependency.

use anyhow::{ensure, Result};

/// Dense row-major f32 tensor.
#[derive(Debug, Clone, PartialEq)]
pub struct Tensor {
    shape: Vec<usize>,
    data: Vec<f32>,
}

impl Tensor {
    pub fn new(shape: Vec<usize>, data: Vec<f32>) -> Result<Self> {
        let n: usize = shape.iter().product();
        ensure!(
            n == data.len(),
            "shape {:?} wants {} elements, got {}",
            shape,
            n,
            data.len()
        );
        Ok(Self { shape, data })
    }

    pub fn zeros(shape: Vec<usize>) -> Self {
        let n = shape.iter().product();
        Self { shape, data: vec![0.0; n] }
    }

    pub fn shape(&self) -> &[usize] {
        &self.shape
    }

    pub fn data(&self) -> &[f32] {
        &self.data
    }

    pub fn data_mut(&mut self) -> &mut [f32] {
        &mut self.data
    }

    pub fn into_data(self) -> Vec<f32> {
        self.data
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    /// Leading-dimension (batch) size; 1 for rank-0.
    pub fn batch(&self) -> usize {
        self.shape.first().copied().unwrap_or(1)
    }

    /// Reinterpret the shape without moving data.
    pub fn reshape(mut self, shape: Vec<usize>) -> Result<Self> {
        let n: usize = shape.iter().product();
        ensure!(n == self.data.len(), "reshape {:?} incompatible with {} elems", shape, n);
        self.shape = shape;
        Ok(self)
    }

    /// Row `i` of a rank-2 tensor.
    pub fn row(&self, i: usize) -> Result<&[f32]> {
        ensure!(self.shape.len() == 2, "row() needs rank-2, got {:?}", self.shape);
        let w = self.shape[1];
        ensure!(i < self.shape[0], "row {} out of bounds {:?}", i, self.shape);
        Ok(&self.data[i * w..(i + 1) * w])
    }

    /// Extract sample `i` along the batch dimension (keeps a unit batch dim).
    pub fn select_batch(&self, i: usize) -> Result<Tensor> {
        ensure!(!self.shape.is_empty() && i < self.shape[0], "batch index {i} out of bounds");
        let per: usize = self.shape[1..].iter().product();
        let mut shape = self.shape.clone();
        shape[0] = 1;
        Tensor::new(shape, self.data[i * per..(i + 1) * per].to_vec())
    }

    /// Stack unit-batch tensors into one batch, padding with repeats of the
    /// last element up to `pad_to` (dynamic batcher feeding fixed-shape HLO).
    pub fn stack_padded(items: &[Tensor], pad_to: usize) -> Result<Tensor> {
        ensure!(!items.is_empty(), "stack_padded on empty slice");
        ensure!(items.len() <= pad_to, "{} items exceed pad_to={}", items.len(), pad_to);
        let inner = &items[0].shape[1..];
        for t in items {
            ensure!(t.shape[0] == 1, "stack_padded wants unit-batch tensors");
            ensure!(&t.shape[1..] == inner, "inhomogeneous shapes in stack");
        }
        let per: usize = inner.iter().product();
        let mut data = Vec::with_capacity(pad_to * per);
        for t in items {
            data.extend_from_slice(&t.data);
        }
        let last = &items[items.len() - 1].data;
        for _ in items.len()..pad_to {
            data.extend_from_slice(last);
        }
        let mut shape = vec![pad_to];
        shape.extend_from_slice(inner);
        Tensor::new(shape, data)
    }
}

/// Index of the maximum element (ties -> first).
pub fn argmax(xs: &[f32]) -> usize {
    let mut best = 0;
    for (i, &v) in xs.iter().enumerate() {
        if v > xs[best] {
            best = i;
        }
    }
    best
}

/// Numerically stable softmax.
pub fn softmax(xs: &[f32]) -> Vec<f32> {
    let m = xs.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
    let exps: Vec<f32> = xs.iter().map(|&v| (v - m).exp()).collect();
    let s: f32 = exps.iter().sum();
    exps.iter().map(|&e| e / s).collect()
}

/// Max softmax probability — SPINN's early-exit confidence measure.
pub fn max_confidence(logits: &[f32]) -> f32 {
    softmax(logits).into_iter().fold(0.0, f32::max)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn new_checks_element_count() {
        assert!(Tensor::new(vec![2, 3], vec![0.0; 6]).is_ok());
        assert!(Tensor::new(vec![2, 3], vec![0.0; 5]).is_err());
    }

    #[test]
    fn select_batch_slices_correctly() {
        let t = Tensor::new(vec![2, 3], vec![1., 2., 3., 4., 5., 6.]).unwrap();
        let s = t.select_batch(1).unwrap();
        assert_eq!(s.shape(), &[1, 3]);
        assert_eq!(s.data(), &[4., 5., 6.]);
        assert!(t.select_batch(2).is_err());
    }

    #[test]
    fn stack_padded_pads_with_last() {
        let a = Tensor::new(vec![1, 2], vec![1., 2.]).unwrap();
        let b = Tensor::new(vec![1, 2], vec![3., 4.]).unwrap();
        let s = Tensor::stack_padded(&[a, b], 4).unwrap();
        assert_eq!(s.shape(), &[4, 2]);
        assert_eq!(s.data(), &[1., 2., 3., 4., 3., 4., 3., 4.]);
    }

    #[test]
    fn stack_padded_rejects_overflow_and_mismatch() {
        let a = Tensor::new(vec![1, 2], vec![1., 2.]).unwrap();
        let b = Tensor::new(vec![1, 3], vec![3., 4., 5.]).unwrap();
        assert!(Tensor::stack_padded(&[a.clone(), b], 4).is_err());
        assert!(Tensor::stack_padded(&[a.clone(), a.clone(), a], 2).is_err());
    }

    #[test]
    fn argmax_and_softmax() {
        assert_eq!(argmax(&[0.1, 0.9, 0.3]), 1);
        assert_eq!(argmax(&[1.0, 1.0]), 0);
        let p = softmax(&[0.0, 0.0]);
        assert!((p[0] - 0.5).abs() < 1e-6);
        let p = softmax(&[100.0, -100.0]);
        assert!(p[0] > 0.999 && p.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn confidence_in_unit_interval() {
        let c = max_confidence(&[2.0, 1.0, 0.5]);
        assert!(c > 1.0 / 3.0 && c < 1.0);
    }

    #[test]
    fn row_access() {
        let t = Tensor::new(vec![2, 2], vec![1., 2., 3., 4.]).unwrap();
        assert_eq!(t.row(1).unwrap(), &[3., 4.]);
        assert!(t.row(2).is_err());
    }
}
