//! `agilenn::tune` — a resumable serving autotuner with the fleet engine
//! as its evaluator.
//!
//! AgileNN's core bet is moving cost from online inference to offline
//! work. The event-driven fleet engine makes a full serving sweep cost
//! seconds, which turns "pick good serving knobs" into an offline search
//! problem: a [`SearchSpace`](space::SearchSpace) spans the serving knobs
//! (batch deadline, packet payload, quantizer width, delivery policy,
//! placement, server count), a [`strategies`] module decides which points
//! to visit (exhaustive grid or seeded genetic), and every evaluation is
//! one deterministic fleet-engine run — sim clock, event engine,
//! reference backend by default — scored on four objectives at once
//! ([`ranking::Objectives`]). The result is the Pareto front over
//! {accuracy, p99_latency_s, goodput_bps, server_seconds}, emitted as an
//! insertion-ordered JSON artifact that diffs cleanly in CI.
//!
//! Everything is deterministic end to end: the evaluator is
//! seed-deterministic, the strategies draw from a config-seeded
//! xorshift64* stream, and [`state`] logs every completed evaluation to
//! an append-only JSONL file. Interrupting a search and re-invoking with
//! the same `--state` path replays the strategy against the log —
//! completed points are answered from cache — and produces a front
//! byte-identical to an uninterrupted run's.
//!
//! Points the serving layer rejects (a typed
//! [`ConfigError`](crate::serve::ConfigError), e.g. `servers > 1` on the
//! threaded sim fabric) are recorded as infeasible and skipped, not
//! fatal: the search space may legitimately cover corners the current
//! execution mode cannot run.

pub mod ranking;
pub mod space;
pub mod state;
pub mod strategies;

pub use ranking::Objectives;
pub use space::{SearchSpace, TunePoint};
pub use state::{EvalOutcome, TuneState};
pub use strategies::StrategyKind;

use crate::config::{BackendKind, Scheme};
use crate::net::GilbertElliott;
use crate::obs::{EventKind, Lane, Tracer};
use crate::report::{json_array, JsonObj};
use crate::serve::{ClockKind, ConfigError, ServeBuilder, SimEngine};
use anyhow::{Context, Result};
use std::collections::HashSet;
use std::path::PathBuf;
use std::sync::Arc;

/// Everything about an evaluation that is *not* searched: the workload,
/// the backend, and the execution mode every grid point shares.
#[derive(Debug, Clone, PartialEq)]
pub struct EvalSpec {
    /// artifacts tree override (`None`: builder default; ignored by the
    /// reference backend)
    pub artifacts_dir: Option<PathBuf>,
    pub dataset: String,
    pub backend: BackendKind,
    pub scheme: Scheme,
    pub devices: usize,
    pub requests: usize,
    /// per-device Poisson arrival rate (Hz); `<= 0` = unpaced
    pub rate_hz: f64,
    pub arrival_seed: u64,
    pub net_seed: u64,
    /// expected packet-loss rate (0 = ideal link)
    pub loss: f64,
    /// mean loss-burst length; `> 1` selects the bursty Gilbert-Elliott
    /// process, otherwise uniform loss
    pub burst: f64,
    /// dynamic batcher cap (not searched; must be an exported size)
    pub max_batch: usize,
    /// execution clock (default sim — wall-clock evaluations are neither
    /// fast nor deterministic, but the axis stays overridable)
    pub clock: ClockKind,
    /// sim execution engine (default the event engine; `threads` makes
    /// every multi-server point infeasible, exercising graceful skips)
    pub sim_engine: SimEngine,
}

impl Default for EvalSpec {
    fn default() -> Self {
        Self {
            artifacts_dir: None,
            dataset: crate::fixtures::SYNTHETIC_DATASET.to_string(),
            backend: BackendKind::Reference,
            scheme: Scheme::Agile,
            devices: 16,
            requests: 4000,
            rate_hz: 50.0,
            arrival_seed: 11,
            net_seed: 42,
            loss: 0.0,
            burst: 1.0,
            max_batch: 8,
            clock: ClockKind::Sim,
            sim_engine: SimEngine::Event,
        }
    }
}

impl EvalSpec {
    /// The shared builder every grid point starts from.
    pub fn base_builder(&self) -> ServeBuilder {
        let mut b = ServeBuilder::new(self.dataset.as_str())
            .backend(self.backend)
            .scheme(self.scheme)
            .fleet(|f| {
                f.devices = self.devices;
                f.requests = self.requests;
            })
            .rate_hz(self.rate_hz)
            .arrival_seed(self.arrival_seed)
            .net(|n| n.seed = self.net_seed)
            .batch(|c| c.max_batch = self.max_batch)
            .clock(self.clock)
            .sim_engine(self.sim_engine);
        if let Some(dir) = &self.artifacts_dir {
            b = b.artifacts_dir(dir);
        }
        if self.loss > 0.0 {
            let loss = if self.burst > 1.0 {
                GilbertElliott::bursty(self.loss, self.burst)
            } else {
                GilbertElliott::uniform(self.loss)
            };
            b = b.net(|n| n.loss = loss);
        }
        b
    }

    /// Materialize one grid point onto the shared builder.
    pub fn builder(&self, point: &TunePoint) -> ServeBuilder {
        point.apply(self.base_builder())
    }

    /// Deterministic JSON form — part of the saved-state fingerprint and
    /// the front artifact.
    pub fn to_ordered_json(&self) -> String {
        JsonObj::new()
            .field_str("dataset", &self.dataset)
            .field_str("backend", self.backend.name())
            .field_str("scheme", self.scheme.name())
            .field_usize("devices", self.devices)
            .field_usize("requests", self.requests)
            .field_f64("rate_hz", self.rate_hz)
            .field_u64("arrival_seed", self.arrival_seed)
            .field_u64("net_seed", self.net_seed)
            .field_f64("loss", self.loss)
            .field_f64("burst", self.burst)
            .field_usize("max_batch", self.max_batch)
            .field_str("clock", self.clock.name())
            .field_str("sim_engine", self.sim_engine.name())
            .finish()
    }
}

/// One tuner invocation: what to search, how to evaluate, where to keep
/// resumable state.
#[derive(Debug, Clone)]
pub struct TuneConfig {
    pub space: SearchSpace,
    pub eval: EvalSpec,
    pub strategy: StrategyKind,
    /// saved-state path; `None` runs in memory (no resume)
    pub state: Option<PathBuf>,
    /// write the front artifact here when set
    pub out: Option<PathBuf>,
    /// stop this invocation after N *new* evaluations (the search resumes
    /// from the log next time); `None` runs to completion
    pub stop_after: Option<usize>,
    /// per-evaluation progress trace on the tuner lane: a `TuneEval`
    /// span per fresh evaluation, a `TuneCached` instant per resume hit,
    /// a `TuneInfeasible` instant per rejected point. Virtual time is
    /// the visit index (the tuner has no serving clock). Off by default;
    /// deliberately excluded from [`TuneConfig::fingerprint`] — tracing
    /// is observational and must not invalidate saved state.
    pub trace: Tracer,
}

impl TuneConfig {
    /// The saved-state fingerprint: everything that shapes the search.
    /// `stop_after` is deliberately excluded — it partitions one search
    /// across invocations rather than defining a different one.
    pub fn fingerprint(&self) -> String {
        let mut obj = JsonObj::new()
            .field_str("schema", "agilenn-tune-state-v1")
            .field_str("strategy", self.strategy.name());
        if let StrategyKind::Genetic { seed, population, budget } = self.strategy {
            obj = obj
                .field_u64("seed", seed)
                .field_usize("population", population)
                .field_usize("budget", budget);
        }
        obj.field_raw("space", &self.space.to_ordered_json())
            .field_raw("eval", &self.eval.to_ordered_json())
            .finish()
    }
}

/// What one tuner invocation produced.
#[derive(Debug, Clone)]
pub struct TuneOutcome {
    /// the strategy ran to completion (false: `--stop-after` interrupted
    /// it; re-invoke with the same `--state` to continue)
    pub completed: bool,
    /// fleet evaluations actually executed by this invocation
    pub evaluated: usize,
    /// distinct points answered from the execution log (resume hits)
    pub cached: usize,
    /// distinct points rejected as infeasible configurations
    pub infeasible: usize,
    /// the Pareto front over every feasible evaluated point, in the
    /// deterministic presentation order
    pub front: Vec<(TunePoint, Objectives)>,
    /// the full ordered-JSON front artifact
    pub front_json: String,
}

/// Run one tuner invocation. `progress` receives one human-readable line
/// per evaluation (fresh, cached, or skipped-infeasible).
pub fn run(cfg: &TuneConfig, mut progress: impl FnMut(&str)) -> Result<TuneOutcome> {
    cfg.space.validate()?;
    // load the world once; every evaluation shares it
    let (meta, testset) = crate::fixtures::load_world(&cfg.eval.base_builder().to_config())?;
    let testset = Arc::new(testset);
    let fingerprint = cfg.fingerprint();
    let mut st = match &cfg.state {
        Some(path) => TuneState::open(path, &fingerprint)?,
        None => TuneState::in_memory(),
    };

    // visit bookkeeping: artifact entries in strategy-visit order, plus
    // counters distinguishing resume hits from this invocation's work
    let mut visited: Vec<(TunePoint, EvalOutcome)> = Vec::new();
    let mut visited_keys: HashSet<String> = HashSet::new();
    let mut fresh_keys: HashSet<String> = HashSet::new();
    let mut evaluated = 0usize;
    let mut cached = 0usize;
    // tuner-lane virtual time: the visit index, counting resume hits and
    // fresh evaluations alike, so a resumed search's trace lines up with
    // an uninterrupted run's visit order
    let mut visit_seq = 0u64;

    let completed = {
        let mut eval = |point: &TunePoint| -> Result<Option<EvalOutcome>> {
            let key = point.key();
            if let Some(hit) = st.lookup(&key).cloned() {
                if visited_keys.insert(key.clone()) {
                    if !fresh_keys.contains(&key) {
                        cached += 1;
                        progress(&format!("cached {key}"));
                        let t = visit_seq as f64;
                        cfg.trace.instant(Lane::Tuner, EventKind::TuneCached, visit_seq, t, 0.0);
                        visit_seq += 1;
                    }
                    visited.push((point.clone(), hit.clone()));
                }
                return Ok(Some(hit));
            }
            if let Some(stop) = cfg.stop_after {
                if evaluated >= stop {
                    return Ok(None);
                }
            }
            let run = cfg
                .eval
                .builder(point)
                .build_with_world(meta.clone(), testset.clone())
                .and_then(|svc| svc.run());
            let outcome = match run {
                Ok(rep) => {
                    let obj = Objectives::from_report(&rep);
                    if obj.is_finite() {
                        progress(&format!(
                            "eval {key}: accuracy {:.3}, p99 {:.4}s, goodput {:.0} bps, \
                             server-seconds {:.2}",
                            obj.accuracy, obj.p99_latency_s, obj.goodput_bps, obj.server_seconds
                        ));
                        let t = visit_seq as f64;
                        let k = EventKind::TuneEval;
                        cfg.trace.span(Lane::Tuner, k, visit_seq, t, t + 1.0, obj.accuracy);
                        let o = EvalOutcome::Done(obj);
                        st.record(point, &o, Some(&rep.to_ordered_json()))?;
                        o
                    } else {
                        progress(&format!("skip {key}: non-finite objectives"));
                        let t = visit_seq as f64;
                        let k = EventKind::TuneInfeasible;
                        cfg.trace.instant(Lane::Tuner, k, visit_seq, t, 0.0);
                        let o = EvalOutcome::Infeasible("non-finite objectives".to_string());
                        st.record(point, &o, Some(&rep.to_ordered_json()))?;
                        o
                    }
                }
                Err(e) => match e.downcast_ref::<ConfigError>() {
                    Some(ce) => {
                        progress(&format!("skip {key}: {ce}"));
                        let t = visit_seq as f64;
                        let k = EventKind::TuneInfeasible;
                        cfg.trace.instant(Lane::Tuner, k, visit_seq, t, 0.0);
                        let o = EvalOutcome::Infeasible(ce.to_string());
                        st.record(point, &o, None)?;
                        o
                    }
                    None => return Err(e.context(format!("evaluating {key}"))),
                },
            };
            evaluated += 1;
            visit_seq += 1;
            fresh_keys.insert(key.clone());
            if visited_keys.insert(key) {
                visited.push((point.clone(), outcome.clone()));
            }
            Ok(Some(outcome))
        };
        match cfg.strategy {
            StrategyKind::Exhaustive => strategies::exhaustive::run(&cfg.space, &mut eval)?,
            StrategyKind::Genetic { seed, population, budget } => {
                strategies::genetic::run(&cfg.space, seed, population, budget, &mut eval)?
            }
        }
    };

    // the front over every feasible visited point, ordered by the
    // deterministic objective order with point-key tie-breaks — the same
    // bytes regardless of which invocation evaluated which point
    let entries: Vec<(TunePoint, Objectives)> = visited
        .iter()
        .filter_map(|(p, o)| match o {
            EvalOutcome::Done(obj) => Some((p.clone(), *obj)),
            EvalOutcome::Infeasible(_) => None,
        })
        .collect();
    let objs: Vec<Objectives> = entries.iter().map(|e| e.1).collect();
    let mut front: Vec<(TunePoint, Objectives)> =
        ranking::pareto_front(&objs).into_iter().map(|i| entries[i].clone()).collect();
    front.sort_by(|a, b| ranking::compare(&a.1, &b.1).then_with(|| a.0.key().cmp(&b.0.key())));
    let infeasible = visited.len() - entries.len();

    let front_items = json_array(front.iter().map(|(p, o)| {
        JsonObj::new()
            .field_raw("point", &p.to_ordered_json())
            .field_raw("objectives", &o.to_ordered_json())
            .finish()
    }));
    let mut art = JsonObj::new()
        .field_str("schema", "agilenn-tune-v1")
        .field_str("strategy", cfg.strategy.name());
    if let StrategyKind::Genetic { seed, population, budget } = cfg.strategy {
        art = art
            .field_u64("seed", seed)
            .field_usize("population", population)
            .field_usize("budget", budget);
    }
    let front_json = art
        .field_raw("space", &cfg.space.to_ordered_json())
        .field_raw("eval", &cfg.eval.to_ordered_json())
        .field_usize("evaluations", visited.len())
        .field_usize("infeasible", infeasible)
        .field_bool("completed", completed)
        .field_raw("front", &front_items)
        .finish();
    if let Some(out) = &cfg.out {
        std::fs::write(out, format!("{front_json}\n"))
            .with_context(|| format!("writing front artifact {}", out.display()))?;
    }

    Ok(TuneOutcome { completed, evaluated, cached, infeasible, front, front_json })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_cfg() -> TuneConfig {
        TuneConfig {
            space: SearchSpace {
                batch_deadline_us: vec![500, 2000],
                packet_payload: vec![None],
                bits: vec![2, 4],
                delivery: vec![crate::net::DeliveryPolicy::Arq],
                placement: vec![crate::serve::Placement::Static],
                servers: vec![1],
                autoscale: vec![false],
                policy: vec![false],
            },
            eval: EvalSpec { devices: 2, requests: 32, rate_hz: 200.0, ..EvalSpec::default() },
            strategy: StrategyKind::Exhaustive,
            state: None,
            out: None,
            stop_after: None,
            trace: Tracer::off(),
        }
    }

    #[test]
    fn exhaustive_in_memory_run_covers_the_grid() {
        let cfg = tiny_cfg();
        let out = run(&cfg, |_| {}).unwrap();
        assert!(out.completed);
        assert_eq!(out.evaluated, 4);
        assert_eq!(out.cached, 0);
        assert_eq!(out.infeasible, 0);
        assert!(!out.front.is_empty(), "a full grid always yields a non-empty front");
        let v = crate::json::Value::parse(&out.front_json).unwrap();
        assert_eq!(v.str_at("schema").unwrap(), "agilenn-tune-v1");
        assert_eq!(v.usize_at("evaluations").unwrap(), 4);
        assert!(!v.get("front").unwrap().as_arr().unwrap().is_empty());
    }

    #[test]
    fn same_config_reproduces_the_artifact_bitwise() {
        let cfg = tiny_cfg();
        let a = run(&cfg, |_| {}).unwrap();
        let b = run(&cfg, |_| {}).unwrap();
        assert_eq!(a.front_json, b.front_json);
    }

    #[test]
    fn fingerprint_pins_strategy_space_and_eval() {
        let cfg = tiny_cfg();
        let fp = cfg.fingerprint();
        assert_eq!(fp, cfg.fingerprint());
        let mut genetic = cfg.clone();
        genetic.strategy = StrategyKind::Genetic { seed: 3, population: 4, budget: 6 };
        assert_ne!(fp, genetic.fingerprint());
        let mut wider = cfg.clone();
        wider.space.bits.push(6);
        assert_ne!(fp, wider.fingerprint());
        let mut busier = cfg;
        busier.eval.requests += 1;
        assert_ne!(fp, busier.fingerprint());
        // stop_after does NOT change the fingerprint (same search, split
        // across invocations)
        let mut split = tiny_cfg();
        split.stop_after = Some(2);
        assert_eq!(fp, split.fingerprint());
    }

    #[test]
    fn infeasible_spec_points_are_skipped_not_fatal() {
        let mut cfg = tiny_cfg();
        cfg.space.servers = vec![1, 2];
        cfg.eval.sim_engine = SimEngine::Threads; // multi-server points now conflict
        let out = run(&cfg, |_| {}).unwrap();
        assert!(out.completed);
        assert_eq!(out.evaluated, 8);
        assert_eq!(out.infeasible, 4, "every servers=2 point is rejected, not fatal");
        assert!(out.front.iter().all(|(p, _)| p.servers == 1));
        assert!(!out.front.is_empty());
    }
}
