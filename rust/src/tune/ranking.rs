//! Pareto ranking over serving objectives.
//!
//! A tuned configuration is judged on four axes at once — accuracy and
//! goodput (higher is better), p99 sojourn latency and server-seconds
//! spent (lower is better) — and no scalarization is neutral between
//! them, so the tuner reports the full Pareto front: every evaluated
//! point that no other evaluated point beats on all four axes.
//!
//! Everything here is deterministic: [`pareto_front`] returns its members
//! in a total order ([`compare`], ties broken by the caller-supplied point
//! key), so the serialized front artifact is byte-stable across runs and
//! independent of evaluation order.

use crate::report::JsonObj;
use crate::serve::PipelineReport;
use anyhow::Result;
use std::cmp::Ordering;

/// The four gated objectives of one evaluated configuration.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Objectives {
    /// top-1 accuracy over the run (maximize)
    pub accuracy: f64,
    /// p99 request sojourn, virtual seconds (minimize)
    pub p99_latency_s: f64,
    /// application-layer goodput, bits/s (maximize)
    pub goodput_bps: f64,
    /// fleet cost: integrated per-shard active seconds (minimize; 0 for
    /// local-only schemes, which keep no server half). Under autoscaling
    /// a shard is only charged for its activation→retirement lifetime —
    /// the old `shards × makespan` formula double-billed retired shards
    /// and made every same-fleet point cost-identical.
    pub server_seconds: f64,
}

impl Objectives {
    /// Extract the objective vector from a finished fleet run.
    pub fn from_report(rep: &PipelineReport) -> Self {
        Self {
            accuracy: rep.accuracy,
            p99_latency_s: rep.p99_latency_s,
            goodput_bps: rep.goodput_bps,
            server_seconds: rep.server_seconds,
        }
    }

    /// All four objectives are finite (JSON cannot carry non-finite
    /// values, and dominance over NaN is meaningless).
    pub fn is_finite(&self) -> bool {
        self.accuracy.is_finite()
            && self.p99_latency_s.is_finite()
            && self.goodput_bps.is_finite()
            && self.server_seconds.is_finite()
    }

    /// Deterministic JSON form; parsing it back yields bit-identical
    /// floats (`report::json_f64` is shortest-roundtrip).
    pub fn to_ordered_json(&self) -> String {
        JsonObj::new()
            .field_f64("accuracy", self.accuracy)
            .field_f64("p99_latency_s", self.p99_latency_s)
            .field_f64("goodput_bps", self.goodput_bps)
            .field_f64("server_seconds", self.server_seconds)
            .finish()
    }

    /// Parse the form [`Objectives::to_ordered_json`] writes (the
    /// execution log stores evaluations this way).
    pub fn parse(v: &crate::json::Value) -> Result<Self> {
        Ok(Self {
            accuracy: v.f64_at("accuracy")?,
            p99_latency_s: v.f64_at("p99_latency_s")?,
            goodput_bps: v.f64_at("goodput_bps")?,
            server_seconds: v.f64_at("server_seconds")?,
        })
    }
}

/// Strict Pareto dominance: `a` is at least as good as `b` on every
/// objective and strictly better on at least one. Irreflexive and
/// transitive, so every dominated point is dominated by some front
/// member.
pub fn dominates(a: &Objectives, b: &Objectives) -> bool {
    let ge = a.accuracy >= b.accuracy
        && a.p99_latency_s <= b.p99_latency_s
        && a.goodput_bps >= b.goodput_bps
        && a.server_seconds <= b.server_seconds;
    let gt = a.accuracy > b.accuracy
        || a.p99_latency_s < b.p99_latency_s
        || a.goodput_bps > b.goodput_bps
        || a.server_seconds < b.server_seconds;
    ge && gt
}

/// How many of `objs` strictly dominate `objs[i]` — the genetic
/// strategy's rank (0 = on the front of its population).
pub fn domination_count(objs: &[Objectives], i: usize) -> usize {
    objs.iter().enumerate().filter(|&(j, o)| j != i && dominates(o, &objs[i])).count()
}

/// Deterministic total order over objective vectors: accuracy descending,
/// then p99 ascending, then goodput descending, then server-seconds
/// ascending. Used to present the front and to break fitness ties; it is
/// a refinement of dominance (a dominating point always sorts first).
pub fn compare(a: &Objectives, b: &Objectives) -> Ordering {
    b.accuracy
        .total_cmp(&a.accuracy)
        .then(a.p99_latency_s.total_cmp(&b.p99_latency_s))
        .then(b.goodput_bps.total_cmp(&a.goodput_bps))
        .then(a.server_seconds.total_cmp(&b.server_seconds))
}

/// Indices of the non-dominated members of `objs`, sorted by
/// [`compare`] with exact ties kept in input order. Callers that need
/// permutation-independent ordering (the front artifact) additionally
/// tie-break by point key, which is unique per configuration.
pub fn pareto_front(objs: &[Objectives]) -> Vec<usize> {
    let mut front: Vec<usize> = (0..objs.len())
        .filter(|&i| !objs.iter().enumerate().any(|(j, o)| j != i && dominates(o, &objs[i])))
        .collect();
    front.sort_by(|&a, &b| compare(&objs[a], &objs[b]));
    front
}

#[cfg(test)]
mod tests {
    use super::*;

    fn obj(acc: f64, p99: f64, gp: f64, ss: f64) -> Objectives {
        Objectives { accuracy: acc, p99_latency_s: p99, goodput_bps: gp, server_seconds: ss }
    }

    #[test]
    fn dominance_is_strict_and_irreflexive() {
        let a = obj(0.9, 0.01, 1e6, 10.0);
        let better = obj(0.95, 0.01, 1e6, 10.0);
        assert!(dominates(&better, &a));
        assert!(!dominates(&a, &better));
        assert!(!dominates(&a, &a), "equal points never dominate each other");
        // a trade-off (better accuracy, worse latency) dominates neither way
        let trade = obj(0.95, 0.02, 1e6, 10.0);
        assert!(!dominates(&trade, &a));
        assert!(!dominates(&a, &trade));
    }

    #[test]
    fn front_keeps_trade_offs_and_drops_dominated_points() {
        let objs = [
            obj(0.90, 0.010, 1e6, 10.0), // dominated by [2]
            obj(0.80, 0.005, 1e6, 10.0), // front: best latency
            obj(0.95, 0.010, 1e6, 10.0), // front: best accuracy
            obj(0.95, 0.010, 1e6, 20.0), // dominated by [2] on cost
        ];
        let front = pareto_front(&objs);
        assert_eq!(front, vec![2, 1], "sorted accuracy-first");
        assert_eq!(domination_count(&objs, 0), 1);
        assert_eq!(domination_count(&objs, 2), 0);
    }

    #[test]
    fn duplicate_points_all_stay_on_the_front() {
        let objs = [obj(0.9, 0.01, 1e6, 10.0), obj(0.9, 0.01, 1e6, 10.0)];
        assert_eq!(pareto_front(&objs), vec![0, 1]);
    }

    #[test]
    fn objectives_json_roundtrips_bit_exactly() {
        let o = obj(0.1 + 0.2, 1.0 / 3.0, 123456.789, 0.0);
        let text = o.to_ordered_json();
        let back = Objectives::parse(&crate::json::Value::parse(&text).unwrap()).unwrap();
        assert_eq!(back.accuracy.to_bits(), o.accuracy.to_bits());
        assert_eq!(back.p99_latency_s.to_bits(), o.p99_latency_s.to_bits());
        assert_eq!(back.goodput_bps.to_bits(), o.goodput_bps.to_bits());
        assert_eq!(back.to_ordered_json(), text, "parse -> serialize is the identity");
    }

    #[test]
    fn finiteness_check_rejects_any_nan_axis() {
        assert!(obj(0.9, 0.01, 1e6, 10.0).is_finite());
        assert!(!obj(f64::NAN, 0.0, 0.0, 0.0).is_finite());
        assert!(!obj(0.9, f64::INFINITY, 0.0, 0.0).is_finite());
    }

    #[test]
    fn compare_refines_dominance() {
        let worse = obj(0.9, 0.02, 1e6, 10.0);
        let better = obj(0.9, 0.01, 1e6, 10.0);
        assert!(dominates(&better, &worse));
        assert_eq!(compare(&better, &worse), Ordering::Less, "dominating point sorts first");
    }
}
