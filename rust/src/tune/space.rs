//! The typed search space: which serving knobs the tuner may move, and
//! how an abstract point materializes into a [`ServeBuilder`].
//!
//! A [`SearchSpace`] is one `Vec` of candidate values per knob; the full
//! grid is their cross product, addressed by a mixed-radix index (axis 0
//! is the least-significant digit). The genetic strategy manipulates the
//! digit vectors directly — a genome is a `Vec<usize>` of per-axis
//! indices — so crossover and mutation always land on valid points.
//!
//! A [`TunePoint`]'s identity is its insertion-ordered JSON serialization
//! ([`TunePoint::key`]): stable field order plus shortest-roundtrip
//! floats make the key byte-stable, so the execution log can match
//! completed evaluations across interrupted runs.

use crate::net::DeliveryPolicy;
use crate::report::{json_array, JsonObj};
use crate::serve::{AutoscaleConfig, Placement, PolicyConfig, ServeBuilder};
use anyhow::{bail, ensure, Result};

/// Candidate values per serving knob; the search grid is the cross
/// product of all eight axes.
#[derive(Debug, Clone, PartialEq)]
pub struct SearchSpace {
    /// dynamic-batcher deadline, microseconds
    pub batch_deadline_us: Vec<u64>,
    /// anytime packet payload cap, bytes (`None` = link MTU)
    pub packet_payload: Vec<Option<usize>>,
    /// quantizer bit width for transmitted features
    pub bits: Vec<u32>,
    /// uplink delivery policy (ARQ / deadline-bounded anytime)
    pub delivery: Vec<DeliveryPolicy>,
    /// device→server placement policy
    pub placement: Vec<Placement>,
    /// remote server count
    pub servers: Vec<usize>,
    /// whether the SLO autoscaler runs (`true` starts one shard and lets
    /// the controller grow toward the servers-axis value as a ceiling;
    /// engine clock only)
    pub autoscale: Vec<bool>,
    /// whether the per-request adaptive split/rate policy runs (`true`
    /// arms [`PolicyConfig::default`] on every device half; the searched
    /// `bits` axis then sets the starting/static width while the policy
    /// adapts around it)
    pub policy: Vec<bool>,
}

impl Default for SearchSpace {
    /// A small default grid (8 points): batch deadline × quantizer width
    /// × server count, everything else pinned to the serving defaults.
    fn default() -> Self {
        Self {
            batch_deadline_us: vec![500, 2000],
            packet_payload: vec![None],
            bits: vec![2, 4],
            delivery: vec![DeliveryPolicy::Arq],
            placement: vec![Placement::Static],
            servers: vec![1, 2],
            autoscale: vec![false],
            policy: vec![false],
        }
    }
}

impl SearchSpace {
    /// Per-axis lengths, least-significant axis first.
    fn radices(&self) -> [usize; 8] {
        [
            self.batch_deadline_us.len(),
            self.packet_payload.len(),
            self.bits.len(),
            self.delivery.len(),
            self.placement.len(),
            self.servers.len(),
            self.autoscale.len(),
            self.policy.len(),
        ]
    }

    /// Every axis must offer at least one value.
    pub fn validate(&self) -> Result<()> {
        let names = [
            "deadlines-us",
            "payloads",
            "bits",
            "delivery",
            "placements",
            "servers",
            "autoscale",
            "policy",
        ];
        for (n, name) in self.radices().iter().zip(names) {
            ensure!(*n > 0, "search axis --{name} is empty");
        }
        Ok(())
    }

    /// Total number of grid points.
    pub fn len(&self) -> usize {
        self.radices().iter().product()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Decompose a grid index into per-axis digits (a genome).
    pub fn genome(&self, index: usize) -> Vec<usize> {
        debug_assert!(index < self.len());
        let mut rest = index;
        self.radices()
            .iter()
            .map(|&r| {
                let d = rest % r;
                rest /= r;
                d
            })
            .collect()
    }

    /// Recompose per-axis digits into the grid index ([`SearchSpace::genome`]
    /// inverted).
    pub fn index_of(&self, genome: &[usize]) -> usize {
        let radices = self.radices();
        debug_assert_eq!(genome.len(), radices.len());
        let mut index = 0usize;
        let mut stride = 1usize;
        for (d, r) in genome.iter().zip(radices) {
            debug_assert!(*d < r);
            index += d * stride;
            stride *= r;
        }
        index
    }

    /// Materialize the point a genome addresses.
    pub fn point_of(&self, genome: &[usize]) -> TunePoint {
        TunePoint {
            batch_deadline_us: self.batch_deadline_us[genome[0]],
            packet_payload: self.packet_payload[genome[1]],
            bits: self.bits[genome[2]],
            delivery: self.delivery[genome[3]].clone(),
            placement: self.placement[genome[4]],
            servers: self.servers[genome[5]],
            autoscale: self.autoscale[genome[6]],
            policy: self.policy[genome[7]],
        }
    }

    /// Materialize grid point `index`.
    pub fn point(&self, index: usize) -> TunePoint {
        self.point_of(&self.genome(index))
    }

    /// Number of axes (genome length).
    pub fn axes(&self) -> usize {
        self.radices().len()
    }

    /// Length of axis `a` (genome digit bound).
    pub fn radix(&self, a: usize) -> usize {
        self.radices()[a]
    }

    /// Deterministic JSON form — part of the saved-state fingerprint, so
    /// a resumed run provably searches the same grid.
    pub fn to_ordered_json(&self) -> String {
        JsonObj::new()
            .field_raw(
                "batch_deadline_us",
                &json_array(self.batch_deadline_us.iter().map(|v| v.to_string())),
            )
            .field_raw(
                "packet_payload",
                &json_array(self.packet_payload.iter().map(|v| match v {
                    Some(n) => n.to_string(),
                    None => "\"mtu\"".to_string(),
                })),
            )
            .field_raw("bits", &json_array(self.bits.iter().map(|v| v.to_string())))
            .field_raw(
                "delivery",
                &json_array(self.delivery.iter().map(delivery_json)),
            )
            .field_raw(
                "placement",
                &json_array(
                    self.placement.iter().map(|p| crate::report::json_str(p.name())),
                ),
            )
            .field_raw("servers", &json_array(self.servers.iter().map(|v| v.to_string())))
            .field_raw("autoscale", &json_array(self.autoscale.iter().map(|v| v.to_string())))
            .field_raw("policy", &json_array(self.policy.iter().map(|v| v.to_string())))
            .finish()
    }
}

/// One configuration under evaluation: a single value per searched knob.
#[derive(Debug, Clone, PartialEq)]
pub struct TunePoint {
    pub batch_deadline_us: u64,
    pub packet_payload: Option<usize>,
    pub bits: u32,
    pub delivery: DeliveryPolicy,
    pub placement: Placement,
    pub servers: usize,
    pub autoscale: bool,
    pub policy: bool,
}

impl TunePoint {
    /// Apply this point's knobs on top of an eval-spec builder.
    pub fn apply(&self, mut b: ServeBuilder) -> ServeBuilder {
        b = b
            .batch(|c| c.deadline_us = self.batch_deadline_us)
            .bits(self.bits)
            .net(|n| n.delivery = self.delivery.clone())
            .fleet(|f| {
                f.placement = self.placement;
                f.servers = self.servers;
            });
        if let Some(bytes) = self.packet_payload {
            b = b.net(|n| n.packet_payload = Some(bytes));
        }
        if self.autoscale {
            // the servers axis becomes the controller's ceiling: start
            // from one shard and let SLO pressure grow the fleet
            b = b.fleet(|f| {
                f.servers = 1;
                f.autoscale = Some(AutoscaleConfig::new(1, self.servers));
            });
        }
        if self.policy {
            b = b.policy(PolicyConfig::default());
        }
        b
    }

    /// Deterministic JSON form; doubles as the point's identity in the
    /// execution log and the front artifact.
    pub fn to_ordered_json(&self) -> String {
        let mut obj = JsonObj::new().field_u64("batch_deadline_us", self.batch_deadline_us);
        obj = match self.packet_payload {
            Some(bytes) => obj.field_usize("packet_payload", bytes),
            None => obj.field_str("packet_payload", "mtu"),
        };
        obj = obj.field_u64("bits", self.bits as u64);
        obj = obj.field_str("delivery", self.delivery.name());
        if let DeliveryPolicy::Anytime { deadline_s } = self.delivery {
            obj = obj.field_f64("net_deadline_s", deadline_s);
        }
        obj.field_str("placement", self.placement.name())
            .field_usize("servers", self.servers)
            .field_bool("autoscale", self.autoscale)
            .field_bool("policy", self.policy)
            .finish()
    }

    /// The point's identity string (== its serialization).
    pub fn key(&self) -> String {
        self.to_ordered_json()
    }

    /// Parse the form [`TunePoint::to_ordered_json`] writes. The anytime
    /// deadline roundtrips bit-exactly (shortest-roundtrip floats), so
    /// `parse(p.key()).key() == p.key()` byte for byte.
    pub fn parse(v: &crate::json::Value) -> Result<TunePoint> {
        let delivery = match v.str_at("delivery")?.as_str() {
            "arq" => DeliveryPolicy::Arq,
            "anytime" => DeliveryPolicy::Anytime { deadline_s: v.f64_at("net_deadline_s")? },
            other => bail!("unknown delivery {other:?} in logged point"),
        };
        let packet_payload = match v.get("packet_payload")? {
            crate::json::Value::Str(s) if s == "mtu" => None,
            other => Some(other.as_usize()?),
        };
        Ok(TunePoint {
            batch_deadline_us: v.u64_at("batch_deadline_us")?,
            packet_payload,
            bits: v.u64_at("bits")? as u32,
            delivery,
            placement: v.str_at("placement")?.parse()?,
            servers: v.usize_at("servers")?,
            autoscale: v.get("autoscale")?.as_bool()?,
            policy: v.get("policy")?.as_bool()?,
        })
    }
}

/// A delivery policy as a JSON value (string for ARQ, object carrying the
/// deadline for anytime) — used by the space fingerprint.
fn delivery_json(d: &DeliveryPolicy) -> String {
    match d {
        DeliveryPolicy::Arq => crate::report::json_str("arq"),
        DeliveryPolicy::Anytime { deadline_s } => JsonObj::new()
            .field_str("policy", "anytime")
            .field_f64("deadline_s", *deadline_s)
            .finish(),
    }
}

// ---------------------------------------------------------------------------
// comma-list flag parsers (CLI surface of the six axes)
// ---------------------------------------------------------------------------

/// Split a `--flag a,b,c` value; rejects empty segments.
fn segments(s: &str) -> Result<Vec<&str>> {
    let parts: Vec<&str> = s.split(',').map(str::trim).collect();
    ensure!(
        !parts.is_empty() && parts.iter().all(|p| !p.is_empty()),
        "empty entry in list {s:?}"
    );
    Ok(parts)
}

/// `"500,2000"` → `[500, 2000]` (any `FromStr` integer/float axis).
pub fn parse_list<T: std::str::FromStr>(s: &str) -> Result<Vec<T>>
where
    T::Err: std::fmt::Display,
{
    segments(s)?
        .into_iter()
        .map(|p| p.parse().map_err(|e| anyhow::anyhow!("bad list entry {p:?}: {e}")))
        .collect()
}

/// `"mtu,64"` → `[None, Some(64)]`.
pub fn parse_payloads(s: &str) -> Result<Vec<Option<usize>>> {
    segments(s)?
        .into_iter()
        .map(|p| {
            if p.eq_ignore_ascii_case("mtu") {
                Ok(None)
            } else {
                Ok(Some(p.parse().map_err(|e| anyhow::anyhow!("bad payload {p:?}: {e}"))?))
            }
        })
        .collect()
}

/// `"arq,anytime"` → the two policies, anytime carrying `net_deadline_s`.
pub fn parse_deliveries(s: &str, net_deadline_s: f64) -> Result<Vec<DeliveryPolicy>> {
    segments(s)?
        .into_iter()
        .map(|p| match p.to_ascii_lowercase().as_str() {
            "arq" => Ok(DeliveryPolicy::Arq),
            "anytime" => Ok(DeliveryPolicy::Anytime { deadline_s: net_deadline_s }),
            other => bail!("unknown delivery {other:?} (arq|anytime)"),
        })
        .collect()
}

/// `"static,least"` → placement policies (same spellings as `serve
/// --placement`).
pub fn parse_placements(s: &str) -> Result<Vec<Placement>> {
    segments(s)?.into_iter().map(|p| p.parse()).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn space() -> SearchSpace {
        SearchSpace {
            batch_deadline_us: vec![500, 2000],
            packet_payload: vec![None, Some(64)],
            bits: vec![2, 4],
            delivery: vec![DeliveryPolicy::Arq, DeliveryPolicy::Anytime { deadline_s: 0.005 }],
            placement: vec![Placement::Static, Placement::LeastLoaded],
            servers: vec![1, 2],
            autoscale: vec![false, true],
            policy: vec![false, true],
        }
    }

    #[test]
    fn mixed_radix_indexing_is_a_bijection() {
        let s = space();
        assert_eq!(s.len(), 256);
        let mut keys = std::collections::HashSet::new();
        for i in 0..s.len() {
            let g = s.genome(i);
            assert_eq!(s.index_of(&g), i, "genome/index roundtrip at {i}");
            assert!(keys.insert(s.point(i).key()), "duplicate point at index {i}");
        }
    }

    #[test]
    fn default_space_is_small_and_valid() {
        let s = SearchSpace::default();
        s.validate().unwrap();
        assert_eq!(s.len(), 8);
    }

    #[test]
    fn empty_axis_is_rejected() {
        let mut s = space();
        s.bits.clear();
        assert!(s.validate().is_err());
        assert_eq!(s.len(), 0);
        assert!(s.is_empty());
    }

    #[test]
    fn point_key_roundtrips_through_the_parser() {
        let s = space();
        for i in [0, 13, 37, 63, 101, 127, 201, 255] {
            let p = s.point(i);
            let v = crate::json::Value::parse(&p.key()).unwrap();
            let back = TunePoint::parse(&v).unwrap();
            assert_eq!(back, p);
            assert_eq!(back.key(), p.key(), "key must be byte-stable through parse");
        }
    }

    #[test]
    fn apply_sets_every_searched_knob() {
        let p = TunePoint {
            batch_deadline_us: 750,
            packet_payload: Some(96),
            bits: 2,
            delivery: DeliveryPolicy::Anytime { deadline_s: 0.004 },
            placement: Placement::RoundRobin,
            servers: 3,
            autoscale: false,
            policy: false,
        };
        let cfg = p.apply(ServeBuilder::new("x")).to_config();
        assert_eq!(cfg.batch.deadline_us, 750);
        assert_eq!(cfg.net.packet_payload, Some(96));
        assert_eq!(cfg.bits, 2);
        assert_eq!(cfg.net.delivery, DeliveryPolicy::Anytime { deadline_s: 0.004 });
        assert!(cfg.policy.is_none());
    }

    #[test]
    fn policy_point_arms_the_adaptive_policy() {
        let p = TunePoint {
            batch_deadline_us: 500,
            packet_payload: None,
            bits: 4,
            delivery: DeliveryPolicy::Arq,
            placement: Placement::Static,
            servers: 1,
            autoscale: false,
            policy: true,
        };
        let cfg = p.apply(ServeBuilder::new("x")).to_config();
        assert_eq!(cfg.policy, Some(PolicyConfig::default()));
        // the policy digit is part of the point's identity, so the
        // execution log never conflates static and adaptive variants
        let mut off = p.clone();
        off.policy = false;
        assert_ne!(off.key(), p.key());
    }

    #[test]
    fn autoscale_point_turns_the_servers_axis_into_a_ceiling() {
        let s = space();
        // flip only the autoscale digit on a 2-server point
        let mut g = vec![0; s.axes()];
        g[5] = 1; // servers = 2
        let p = s.point_of(&g);
        assert!(!p.autoscale);
        g[6] = 1;
        let p = s.point_of(&g);
        assert!(p.autoscale && p.servers == 2);
        // keys differ only in the autoscale field, so the execution log
        // never conflates the fixed and autoscaled variants
        assert_ne!(s.point_of(&{
            let mut g2 = g.clone();
            g2[6] = 0;
            g2
        })
        .key(), p.key());
    }

    #[test]
    fn list_parsers() {
        assert_eq!(parse_list::<u64>("500, 2000").unwrap(), vec![500, 2000]);
        assert!(parse_list::<u64>("500,,2000").is_err());
        assert_eq!(parse_payloads("mtu,64").unwrap(), vec![None, Some(64)]);
        assert_eq!(
            parse_deliveries("arq,anytime", 0.005).unwrap(),
            vec![DeliveryPolicy::Arq, DeliveryPolicy::Anytime { deadline_s: 0.005 }]
        );
        assert!(parse_deliveries("udp", 0.005).is_err());
        assert_eq!(
            parse_placements("static,rr,least").unwrap(),
            vec![Placement::Static, Placement::RoundRobin, Placement::LeastLoaded]
        );
    }

    #[test]
    fn space_fingerprint_is_deterministic_json() {
        let s = space();
        let a = s.to_ordered_json();
        assert_eq!(a, s.to_ordered_json());
        // parses as JSON and names every axis
        let v = crate::json::Value::parse(&a).unwrap();
        assert_eq!(v.get("servers").unwrap().as_arr().unwrap().len(), 2);
        assert_eq!(v.get("packet_payload").unwrap().as_arr().unwrap()[0].as_str().unwrap(), "mtu");
    }
}
