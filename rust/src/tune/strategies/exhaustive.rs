//! Exhaustive grid sweep: every point, in mixed-radix index order.
//!
//! The order is part of the contract — resumed runs replay the same
//! sequence and skip the logged prefix via the driver's cache.

use crate::tune::space::{SearchSpace, TunePoint};
use crate::tune::state::EvalOutcome;
use anyhow::Result;

/// Visit all `space.len()` points in index order. Returns `Ok(true)` when
/// the grid was fully evaluated, `Ok(false)` when the evaluator declined
/// (this invocation's budget is spent; resume later).
pub fn run(
    space: &SearchSpace,
    eval: &mut dyn FnMut(&TunePoint) -> Result<Option<EvalOutcome>>,
) -> Result<bool> {
    for index in 0..space.len() {
        if eval(&space.point(index))?.is_none() {
            return Ok(false);
        }
    }
    Ok(true)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tune::ranking::Objectives;

    fn outcome() -> EvalOutcome {
        EvalOutcome::Done(Objectives {
            accuracy: 0.9,
            p99_latency_s: 0.01,
            goodput_bps: 1e6,
            server_seconds: 1.0,
        })
    }

    #[test]
    fn visits_every_point_in_index_order() {
        let space = SearchSpace::default();
        let mut seen = Vec::new();
        let done = run(&space, &mut |p| {
            seen.push(p.key());
            Ok(Some(outcome()))
        })
        .unwrap();
        assert!(done);
        assert_eq!(seen.len(), space.len());
        let expect: Vec<String> = (0..space.len()).map(|i| space.point(i).key()).collect();
        assert_eq!(seen, expect);
    }

    #[test]
    fn stops_cleanly_when_the_evaluator_declines() {
        let space = SearchSpace::default();
        let mut calls = 0usize;
        let done = run(&space, &mut |_| {
            calls += 1;
            Ok(if calls <= 3 { Some(outcome()) } else { None })
        })
        .unwrap();
        assert!(!done, "an exhausted budget reports the search incomplete");
        assert_eq!(calls, 4, "the declined call ends the sweep immediately");
    }
}
