//! Search strategies: who decides which grid point to evaluate next.
//!
//! A strategy is a deterministic function of (configuration, seed,
//! evaluation results). It never touches the clock, the filesystem, or
//! ambient entropy — all randomness comes from a seeded xorshift64*
//! stream — so re-running a strategy against cached evaluation results
//! replays the exact decision sequence. That property is what makes the
//! execution log a resume mechanism rather than just a record.
//!
//! Strategies see evaluations through one narrow oracle:
//!
//! ```text
//! FnMut(&TunePoint) -> Result<Option<EvalOutcome>>
//! ```
//!
//! `Ok(Some(_))` is a completed evaluation (possibly answered from the
//! resume cache); `Ok(None)` means this invocation's `--stop-after`
//! budget is spent — the strategy unwinds immediately and reports the
//! search as incomplete; `Err` is a real failure and aborts.

pub mod exhaustive;
pub mod genetic;

/// Which strategy drives the search, with its knobs.
#[derive(Debug, Clone, PartialEq)]
pub enum StrategyKind {
    /// visit every grid point in index order
    Exhaustive,
    /// seeded genetic search: tournament selection over Pareto rank,
    /// uniform crossover, per-axis mutation; stops after `budget`
    /// evaluations
    Genetic { seed: u64, population: usize, budget: usize },
}

impl StrategyKind {
    /// Stable name for fingerprints, artifacts, and `--strategy`.
    pub fn name(&self) -> &'static str {
        match self {
            StrategyKind::Exhaustive => "exhaustive",
            StrategyKind::Genetic { .. } => "genetic",
        }
    }
}

impl std::str::FromStr for StrategyKind {
    type Err = anyhow::Error;

    /// Parse a bare `--strategy` name with that strategy's default knobs
    /// (the CLI overrides seed/population/budget separately).
    fn from_str(s: &str) -> anyhow::Result<Self> {
        match s.to_ascii_lowercase().as_str() {
            "exhaustive" | "grid" => Ok(StrategyKind::Exhaustive),
            "genetic" | "ga" => {
                Ok(StrategyKind::Genetic { seed: 1, population: 8, budget: 64 })
            }
            other => anyhow::bail!("unknown strategy {other:?} (exhaustive|genetic)"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn strategy_names_parse_back() {
        assert_eq!("exhaustive".parse::<StrategyKind>().unwrap(), StrategyKind::Exhaustive);
        assert_eq!("grid".parse::<StrategyKind>().unwrap(), StrategyKind::Exhaustive);
        let g: StrategyKind = "genetic".parse().unwrap();
        assert_eq!(g.name(), "genetic");
        assert!(matches!(g, StrategyKind::Genetic { .. }));
        assert!("simulated-annealing".parse::<StrategyKind>().is_err());
    }
}
