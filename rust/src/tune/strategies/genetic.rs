//! Seeded genetic search over the typed space.
//!
//! Genomes are per-axis digit vectors ([`SearchSpace::genome`]), so
//! crossover and mutation always produce valid grid points. Selection is
//! a binary tournament on Pareto rank within the current population
//! (fewer dominators wins), with deterministic tie-breaks: the
//! [`ranking::compare`] total order, then lexicographic genome order.
//! Infeasible members (rejected configurations) always lose to feasible
//! ones, so the search drifts away from invalid corners of the space
//! without hard-coding which combinations are legal.
//!
//! All randomness comes from one xorshift64* stream seeded via config.
//! Given the same (space, seed, population, budget) and the same
//! evaluation results, the strategy visits the same points in the same
//! order — which is exactly what resume-by-replay requires. Re-proposing
//! an already-seen point is allowed and costs nothing: the driver answers
//! it from the evaluation cache.

use crate::tune::ranking;
use crate::tune::space::{SearchSpace, TunePoint};
use crate::tune::state::EvalOutcome;
use anyhow::Result;
use std::cmp::Ordering;

/// xorshift64* — same generator the simulator fabric uses; never zero.
struct Rng(u64);

impl Rng {
    fn new(seed: u64) -> Self {
        Self(seed.max(1))
    }

    fn next(&mut self) -> u64 {
        let mut x = self.0;
        x ^= x >> 12;
        x ^= x << 25;
        x ^= x >> 27;
        self.0 = x;
        x.wrapping_mul(0x2545_F491_4F6C_DD1D)
    }

    fn usize(&mut self, bound: usize) -> usize {
        debug_assert!(bound > 0);
        (self.next() % bound as u64) as usize
    }
}

#[derive(Clone)]
struct Member {
    genome: Vec<usize>,
    outcome: EvalOutcome,
}

/// How many feasible members of `pop` strictly dominate member `i`;
/// `None` if `i` itself is infeasible (rank: worse than any feasible).
fn dom_count(pop: &[Member], i: usize) -> Option<usize> {
    let oi = match &pop[i].outcome {
        EvalOutcome::Done(o) => o,
        EvalOutcome::Infeasible(_) => return None,
    };
    Some(
        pop.iter()
            .enumerate()
            .filter(|&(j, m)| {
                j != i && matches!(&m.outcome, EvalOutcome::Done(oj) if ranking::dominates(oj, oi))
            })
            .count(),
    )
}

/// Total fitness order (best first): lower domination count, then the
/// deterministic objective order, then lexicographic genome.
fn fitness_cmp(pop: &[Member], counts: &[Option<usize>], i: usize, j: usize) -> Ordering {
    match (counts[i], counts[j]) {
        (Some(ci), Some(cj)) => ci
            .cmp(&cj)
            .then_with(|| match (&pop[i].outcome, &pop[j].outcome) {
                (EvalOutcome::Done(oi), EvalOutcome::Done(oj)) => ranking::compare(oi, oj),
                _ => Ordering::Equal,
            })
            .then_with(|| pop[i].genome.cmp(&pop[j].genome)),
        (Some(_), None) => Ordering::Less,
        (None, Some(_)) => Ordering::Greater,
        (None, None) => pop[i].genome.cmp(&pop[j].genome),
    }
}

/// Binary tournament: draw two members, clone the fitter genome.
fn tournament(pop: &[Member], counts: &[Option<usize>], rng: &mut Rng) -> Vec<usize> {
    let a = rng.usize(pop.len());
    let b = rng.usize(pop.len());
    let w = if fitness_cmp(pop, counts, a, b) == Ordering::Greater { b } else { a };
    pop[w].genome.clone()
}

/// Keep the `keep` fittest members, in fitness order (best first). The
/// resulting order is deterministic, so subsequent tournament draws are
/// too.
fn select_survivors(pop: &mut Vec<Member>, keep: usize) {
    let counts: Vec<Option<usize>> = (0..pop.len()).map(|i| dom_count(pop, i)).collect();
    let mut order: Vec<usize> = (0..pop.len()).collect();
    order.sort_by(|&i, &j| fitness_cmp(pop, &counts, i, j));
    order.truncate(keep);
    *pop = order.into_iter().map(|i| pop[i].clone()).collect();
}

/// Run the genetic search: seed `population` distinct random points, then
/// evolve until `budget` evaluations are spent. Returns `Ok(true)` when
/// the budget was fully consumed, `Ok(false)` when the evaluator declined
/// mid-search (`--stop-after`; resume later).
pub fn run(
    space: &SearchSpace,
    seed: u64,
    population: usize,
    budget: usize,
    eval: &mut dyn FnMut(&TunePoint) -> Result<Option<EvalOutcome>>,
) -> Result<bool> {
    let population = population.max(2).min(space.len().max(1));
    let mut rng = Rng::new(seed);
    let mut pop: Vec<Member> = Vec::new();
    let mut seeded = std::collections::HashSet::new();
    let mut evals = 0usize;

    // seed generation: distinct random grid points
    while pop.len() < population && seeded.len() < space.len() {
        if evals >= budget {
            return Ok(true);
        }
        let index = rng.usize(space.len());
        if !seeded.insert(index) {
            continue;
        }
        match eval(&space.point(index))? {
            None => return Ok(false),
            Some(outcome) => {
                evals += 1;
                pop.push(Member { genome: space.genome(index), outcome });
            }
        }
    }

    // evolve: tournament parents -> uniform crossover -> mutation
    while evals < budget {
        let counts: Vec<Option<usize>> = (0..pop.len()).map(|i| dom_count(&pop, i)).collect();
        let pa = tournament(&pop, &counts, &mut rng);
        let pb = tournament(&pop, &counts, &mut rng);
        let axes = space.axes();
        let mut child: Vec<usize> = (0..axes)
            .map(|a| if rng.usize(2) == 0 { pa[a] } else { pb[a] })
            .collect();
        for a in 0..axes {
            // expected one mutated axis per child
            if rng.usize(axes) == 0 {
                child[a] = rng.usize(space.radix(a));
            }
        }
        match eval(&space.point_of(&child))? {
            None => return Ok(false),
            Some(outcome) => {
                evals += 1;
                pop.push(Member { genome: child, outcome });
                select_survivors(&mut pop, population);
            }
        }
    }
    Ok(true)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::DeliveryPolicy;
    use crate::serve::Placement;
    use crate::tune::ranking::Objectives;

    fn space() -> SearchSpace {
        SearchSpace {
            batch_deadline_us: vec![500, 1000, 2000],
            packet_payload: vec![None, Some(64)],
            bits: vec![1, 2, 4],
            delivery: vec![DeliveryPolicy::Arq],
            placement: vec![Placement::Static, Placement::LeastLoaded],
            servers: vec![1, 2],
            autoscale: vec![false],
            policy: vec![false],
        }
    }

    /// A deterministic synthetic objective: better accuracy with more
    /// bits, better latency with shorter deadlines — a real trade-off
    /// surface, no fleet run needed.
    fn synthetic(p: &TunePoint) -> EvalOutcome {
        EvalOutcome::Done(Objectives {
            accuracy: 0.5 + 0.1 * p.bits as f64,
            p99_latency_s: p.batch_deadline_us as f64 * 1e-6 + 0.001 * p.servers as f64,
            goodput_bps: 1e6 / p.bits as f64,
            server_seconds: p.servers as f64,
        })
    }

    #[test]
    fn same_seed_visits_the_same_points_in_the_same_order() {
        let s = space();
        let mut runs = Vec::new();
        for _ in 0..2 {
            let mut seen = Vec::new();
            let done = run(&s, 42, 6, 20, &mut |p| {
                seen.push(p.key());
                Ok(Some(synthetic(p)))
            })
            .unwrap();
            assert!(done);
            assert_eq!(seen.len(), 20, "budget counts every evaluation");
            runs.push(seen);
        }
        assert_eq!(runs[0], runs[1], "seeded search must be replayable");
    }

    #[test]
    fn different_seeds_explore_differently() {
        let s = space();
        let mut fronts = Vec::new();
        for seed in [1u64, 2] {
            let mut seen = Vec::new();
            run(&s, seed, 6, 20, &mut |p| {
                seen.push(p.key());
                Ok(Some(synthetic(p)))
            })
            .unwrap();
            fronts.push(seen);
        }
        assert_ne!(fronts[0], fronts[1]);
    }

    #[test]
    fn declining_evaluator_stops_the_search_incomplete() {
        let s = space();
        let mut calls = 0usize;
        let done = run(&s, 7, 4, 50, &mut |p| {
            calls += 1;
            Ok(if calls <= 5 { Some(synthetic(p)) } else { None })
        })
        .unwrap();
        assert!(!done);
        assert_eq!(calls, 6);
    }

    #[test]
    fn infeasible_members_lose_tournaments_to_feasible_ones() {
        let s = space();
        // everything with 2 servers is "rejected"
        let mut feasible_evals = 0usize;
        let done = run(&s, 3, 6, 30, &mut |p| {
            Ok(Some(if p.servers > 1 {
                EvalOutcome::Infeasible("no".into())
            } else {
                feasible_evals += 1;
                synthetic(p)
            }))
        })
        .unwrap();
        assert!(done);
        assert!(feasible_evals > 0, "the search still finds the feasible half");
    }

    #[test]
    fn survivor_selection_keeps_the_non_dominated_members() {
        let g = |i: usize| vec![i, 0, 0, 0, 0, 0];
        let o = |acc: f64, p99: f64| {
            EvalOutcome::Done(Objectives {
                accuracy: acc,
                p99_latency_s: p99,
                goodput_bps: 1e6,
                server_seconds: 1.0,
            })
        };
        let mut pop = vec![
            Member { genome: g(0), outcome: o(0.9, 0.02) }, // dominated by 2
            Member { genome: g(1), outcome: o(0.8, 0.005) }, // front (fast)
            Member { genome: g(2), outcome: o(0.95, 0.02) }, // front (accurate)
            Member { genome: g(3), outcome: EvalOutcome::Infeasible("x".into()) },
        ];
        select_survivors(&mut pop, 2);
        let genomes: Vec<&Vec<usize>> = pop.iter().map(|m| &m.genome).collect();
        assert_eq!(genomes, vec![&g(2), &g(1)], "front members survive, best-first");
    }
}
