//! Resumable tuner state: a fingerprint file plus an append-only JSONL
//! execution log.
//!
//! Long searches must survive interruption. The state file at `--state
//! <path>` pins a **fingerprint** of everything that shapes the search —
//! space, eval spec, strategy, seed — so a resumed invocation provably
//! continues the *same* search (a mismatch is a hard error, not a silent
//! restart). Next to it, `<path>.log.jsonl` records one line per
//! completed evaluation, flushed as it happens:
//!
//! ```text
//! {"point":{...},"objectives":{...},"report":{...}}
//! {"point":{...},"infeasible":true,"error":"..."}
//! ```
//!
//! Resume is **replay**: strategies are deterministic functions of
//! (config, seed, evaluation results), so a resumed run re-walks the
//! decision sequence from scratch and the driver answers each already-
//! logged point from this cache instead of re-running the fleet. Because
//! objectives are stored with shortest-roundtrip floats, a cached answer
//! is bit-identical to the original measurement — the resumed front
//! serializes byte-for-byte equal to an uninterrupted run's.
//!
//! A process killed mid-write can leave a truncated final line; the
//! loader drops exactly that (the evaluation is simply redone). A
//! malformed line anywhere else means real corruption and errors out.

use super::ranking::Objectives;
use super::space::TunePoint;
use crate::json::Value;
use crate::report::JsonObj;
use anyhow::{bail, Context, Result};
use std::collections::HashMap;
use std::io::Write;
use std::path::{Path, PathBuf};

/// What one logged evaluation resolved to.
#[derive(Debug, Clone, PartialEq)]
pub enum EvalOutcome {
    /// the fleet run finished; its objective vector
    Done(Objectives),
    /// the point is a rejected configuration (`serve::ConfigError`); the
    /// message explains why. Skipped on resume like any completed point.
    Infeasible(String),
}

/// The execution log beside a state file.
pub fn log_path(state_path: &Path) -> PathBuf {
    PathBuf::from(format!("{}.log.jsonl", state_path.display()))
}

/// Completed-evaluation cache, optionally backed by a state file + log.
#[derive(Debug)]
pub struct TuneState {
    log: Option<std::fs::File>,
    cache: HashMap<String, EvalOutcome>,
}

impl TuneState {
    /// Ephemeral state: no files, nothing survives the process (used by
    /// `perfgate` and tests that don't exercise resume).
    pub fn in_memory() -> Self {
        Self { log: None, cache: HashMap::new() }
    }

    /// Open (or create) persistent state. `fingerprint` is the
    /// deterministic JSON of the search configuration; an existing state
    /// file must match it byte for byte.
    pub fn open(state_path: &Path, fingerprint: &str) -> Result<Self> {
        if let Some(dir) = state_path.parent() {
            if !dir.as_os_str().is_empty() {
                std::fs::create_dir_all(dir)
                    .with_context(|| format!("creating state dir {}", dir.display()))?;
            }
        }
        match std::fs::read_to_string(state_path) {
            Ok(existing) => {
                if existing.trim_end() != fingerprint {
                    bail!(
                        "state file {} belongs to a different search \
                         (space/eval/strategy/seed changed); pick a fresh --state path\n\
                         saved:   {}\ncurrent: {fingerprint}",
                        state_path.display(),
                        existing.trim_end(),
                    );
                }
            }
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => {
                std::fs::write(state_path, format!("{fingerprint}\n"))
                    .with_context(|| format!("writing state file {}", state_path.display()))?;
            }
            Err(e) => {
                return Err(e).with_context(|| format!("reading {}", state_path.display()))
            }
        }
        let lp = log_path(state_path);
        let cache = match std::fs::read_to_string(&lp) {
            Ok(text) => parse_log(&text).with_context(|| format!("parsing {}", lp.display()))?,
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => HashMap::new(),
            Err(e) => return Err(e).with_context(|| format!("reading {}", lp.display())),
        };
        let log = std::fs::OpenOptions::new()
            .create(true)
            .append(true)
            .open(&lp)
            .with_context(|| format!("opening {} for append", lp.display()))?;
        Ok(Self { log: Some(log), cache })
    }

    /// Cached outcome for a point key, if this point was already
    /// evaluated (possibly by an earlier, interrupted invocation).
    pub fn lookup(&self, key: &str) -> Option<&EvalOutcome> {
        self.cache.get(key)
    }

    /// Number of evaluations this state knows about.
    pub fn completed(&self) -> usize {
        self.cache.len()
    }

    /// Record one finished evaluation: append a log line (flushed
    /// immediately — an interrupt after this call never loses the
    /// evaluation) and cache it. `report_json` carries the full
    /// `PipelineReport` for human inspection; only the objectives are
    /// read back.
    pub fn record(
        &mut self,
        point: &TunePoint,
        outcome: &EvalOutcome,
        report_json: Option<&str>,
    ) -> Result<()> {
        let mut obj = JsonObj::new().field_raw("point", &point.to_ordered_json());
        match outcome {
            EvalOutcome::Done(o) => {
                obj = obj.field_raw("objectives", &o.to_ordered_json());
                if let Some(rep) = report_json {
                    obj = obj.field_raw("report", rep);
                }
            }
            EvalOutcome::Infeasible(msg) => {
                obj = obj.field_bool("infeasible", true).field_str("error", msg);
            }
        }
        let line = obj.finish();
        if let Some(f) = &mut self.log {
            writeln!(f, "{line}").context("appending to the execution log")?;
            f.flush().context("flushing the execution log")?;
        }
        self.cache.insert(point.key(), outcome.clone());
        Ok(())
    }
}

/// Parse the whole log text into the evaluation cache. A truncated
/// **final** line (interrupted mid-write) is dropped; malformed lines
/// anywhere else are corruption and error out.
fn parse_log(text: &str) -> Result<HashMap<String, EvalOutcome>> {
    let lines: Vec<&str> = text.lines().filter(|l| !l.trim().is_empty()).collect();
    let mut cache = HashMap::new();
    for (i, line) in lines.iter().enumerate() {
        let parsed = Value::parse(line).and_then(|v| parse_entry(&v));
        match parsed {
            Ok((key, outcome)) => {
                cache.insert(key, outcome);
            }
            Err(e) => {
                if i + 1 == lines.len() {
                    // interrupted mid-write; the evaluation reruns
                    continue;
                }
                return Err(e.context(format!("execution log line {}", i + 1)));
            }
        }
    }
    Ok(cache)
}

fn parse_entry(v: &Value) -> Result<(String, EvalOutcome)> {
    let point = TunePoint::parse(v.get("point")?)?;
    let outcome = match v.opt("infeasible") {
        Some(flag) if flag.as_bool()? => EvalOutcome::Infeasible(v.str_at("error")?),
        _ => EvalOutcome::Done(Objectives::parse(v.get("objectives")?)?),
    };
    Ok((point.key(), outcome))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::DeliveryPolicy;
    use crate::serve::Placement;

    fn point(servers: usize) -> TunePoint {
        TunePoint {
            batch_deadline_us: 2000,
            packet_payload: None,
            bits: 4,
            delivery: DeliveryPolicy::Anytime { deadline_s: 1.0 / 3.0 },
            placement: Placement::Static,
            servers,
            autoscale: false,
            policy: false,
        }
    }

    fn objectives() -> Objectives {
        Objectives {
            accuracy: 0.1 + 0.2,
            p99_latency_s: 1.0 / 7.0,
            goodput_bps: 123456.789,
            server_seconds: 2.5,
        }
    }

    fn tmp(name: &str) -> PathBuf {
        let dir = std::env::temp_dir().join("agilenn_tune_state");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join(name);
        let _ = std::fs::remove_file(&p);
        let _ = std::fs::remove_file(log_path(&p));
        p
    }

    #[test]
    fn log_roundtrips_outcomes_bit_exactly() {
        let path = tmp("roundtrip.state");
        let fp = r#"{"schema":"test","seed":1}"#;
        {
            let mut st = TuneState::open(&path, fp).unwrap();
            st.record(&point(1), &EvalOutcome::Done(objectives()), Some("{\"requests\":8}"))
                .unwrap();
            st.record(&point(2), &EvalOutcome::Infeasible("nope".into()), None).unwrap();
            assert_eq!(st.completed(), 2);
        }
        let st = TuneState::open(&path, fp).unwrap();
        assert_eq!(st.completed(), 2);
        match st.lookup(&point(1).key()).unwrap() {
            EvalOutcome::Done(o) => {
                let want = objectives();
                assert_eq!(o.accuracy.to_bits(), want.accuracy.to_bits());
                assert_eq!(o.p99_latency_s.to_bits(), want.p99_latency_s.to_bits());
                assert_eq!(o.goodput_bps.to_bits(), want.goodput_bps.to_bits());
                assert_eq!(o.server_seconds.to_bits(), want.server_seconds.to_bits());
            }
            other => panic!("expected Done, got {other:?}"),
        }
        assert_eq!(
            st.lookup(&point(2).key()),
            Some(&EvalOutcome::Infeasible("nope".into()))
        );
        assert!(st.lookup(&point(3).key()).is_none());
    }

    #[test]
    fn fingerprint_mismatch_is_a_hard_error() {
        let path = tmp("mismatch.state");
        TuneState::open(&path, r#"{"seed":1}"#).unwrap();
        let err = TuneState::open(&path, r#"{"seed":2}"#).unwrap_err();
        assert!(err.to_string().contains("different search"), "{err:#}");
    }

    #[test]
    fn truncated_final_line_is_dropped_but_earlier_corruption_errors() {
        let path = tmp("truncated.state");
        let fp = "{}";
        {
            let mut st = TuneState::open(&path, fp).unwrap();
            st.record(&point(1), &EvalOutcome::Done(objectives()), None).unwrap();
        }
        // simulate a kill mid-write: a half-written final line
        {
            use std::io::Write;
            let mut f =
                std::fs::OpenOptions::new().append(true).open(log_path(&path)).unwrap();
            write!(f, "{{\"point\":{{\"batch_dead").unwrap();
        }
        let st = TuneState::open(&path, fp).unwrap();
        assert_eq!(st.completed(), 1, "the truncated line is simply redone");
        // corruption before the end is a real error
        std::fs::write(log_path(&path), "garbage\n{\"also\":\"broken\"}\n").unwrap();
        assert!(TuneState::open(&path, fp).is_err());
    }

    #[test]
    fn in_memory_state_caches_without_files() {
        let mut st = TuneState::in_memory();
        st.record(&point(1), &EvalOutcome::Done(objectives()), None).unwrap();
        assert!(st.lookup(&point(1).key()).is_some());
        assert_eq!(st.completed(), 1);
    }
}
