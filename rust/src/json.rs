//! Minimal JSON parser — substrate for reading meta.json / manifest.json.
//!
//! The build environment vendors only the `xla` dependency tree, so instead
//! of serde_json we carry a small recursive-descent parser covering the JSON
//! the python exporter writes (objects, arrays, strings, f64 numbers, bools,
//! null; UTF-8 passthrough with \uXXXX escapes).

use anyhow::{anyhow, bail, Context, Result};
use std::collections::HashMap;

#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Value>),
    Obj(HashMap<String, Value>),
}

impl Value {
    pub fn parse(text: &str) -> Result<Value> {
        let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            bail!("trailing garbage at byte {}", p.pos);
        }
        Ok(v)
    }

    // ---- typed accessors ----

    pub fn get(&self, key: &str) -> Result<&Value> {
        match self {
            Value::Obj(m) => m.get(key).ok_or_else(|| anyhow!("missing key {key:?}")),
            _ => bail!("not an object (looking up {key:?})"),
        }
    }

    pub fn opt(&self, key: &str) -> Option<&Value> {
        match self {
            Value::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Value::Num(n) => Ok(*n),
            _ => bail!("not a number: {self:?}"),
        }
    }

    pub fn as_usize(&self) -> Result<usize> {
        let n = self.as_f64()?;
        if n < 0.0 || n.fract() != 0.0 {
            bail!("not a non-negative integer: {n}");
        }
        Ok(n as usize)
    }

    pub fn as_u64(&self) -> Result<u64> {
        Ok(self.as_usize()? as u64)
    }

    pub fn as_bool(&self) -> Result<bool> {
        match self {
            Value::Bool(b) => Ok(*b),
            _ => bail!("not a bool: {self:?}"),
        }
    }

    pub fn as_str(&self) -> Result<&str> {
        match self {
            Value::Str(s) => Ok(s),
            _ => bail!("not a string: {self:?}"),
        }
    }

    pub fn as_arr(&self) -> Result<&[Value]> {
        match self {
            Value::Arr(v) => Ok(v),
            _ => bail!("not an array: {self:?}"),
        }
    }

    pub fn as_obj(&self) -> Result<&HashMap<String, Value>> {
        match self {
            Value::Obj(m) => Ok(m),
            _ => bail!("not an object: {self:?}"),
        }
    }

    /// `get` chained with context for nicer error messages.
    pub fn f64_at(&self, key: &str) -> Result<f64> {
        self.get(key)?.as_f64().with_context(|| format!("key {key:?}"))
    }

    pub fn usize_at(&self, key: &str) -> Result<usize> {
        self.get(key)?.as_usize().with_context(|| format!("key {key:?}"))
    }

    pub fn u64_at(&self, key: &str) -> Result<u64> {
        self.get(key)?.as_u64().with_context(|| format!("key {key:?}"))
    }

    pub fn str_at(&self, key: &str) -> Result<String> {
        Ok(self.get(key)?.as_str().with_context(|| format!("key {key:?}"))?.to_string())
    }

    pub fn f32_vec_at(&self, key: &str) -> Result<Vec<f32>> {
        self.get(key)?
            .as_arr()?
            .iter()
            .map(|v| Ok(v.as_f64()? as f32))
            .collect::<Result<_>>()
            .with_context(|| format!("key {key:?}"))
    }

    pub fn f64_vec_at(&self, key: &str) -> Result<Vec<f64>> {
        self.get(key)?.as_arr()?.iter().map(|v| v.as_f64()).collect()
    }

    pub fn usize_vec_at(&self, key: &str) -> Result<Vec<usize>> {
        self.get(key)?.as_arr()?.iter().map(|v| v.as_usize()).collect()
    }
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.pos < self.bytes.len()
            && matches!(self.bytes[self.pos], b' ' | b'\t' | b'\n' | b'\r')
        {
            self.pos += 1;
        }
    }

    fn peek(&self) -> Result<u8> {
        self.bytes.get(self.pos).copied().ok_or_else(|| anyhow!("unexpected end of input"))
    }

    fn expect(&mut self, b: u8) -> Result<()> {
        if self.peek()? != b {
            bail!("expected {:?} at byte {}, found {:?}", b as char, self.pos, self.peek()? as char);
        }
        self.pos += 1;
        Ok(())
    }

    fn literal(&mut self, lit: &str, v: Value) -> Result<Value> {
        if self.bytes[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(v)
        } else {
            bail!("bad literal at byte {}", self.pos)
        }
    }

    fn value(&mut self) -> Result<Value> {
        self.skip_ws();
        match self.peek()? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Value::Str(self.string()?)),
            b't' => self.literal("true", Value::Bool(true)),
            b'f' => self.literal("false", Value::Bool(false)),
            b'n' => self.literal("null", Value::Null),
            b'-' | b'0'..=b'9' => self.number(),
            c => bail!("unexpected byte {:?} at {}", c as char, self.pos),
        }
    }

    fn object(&mut self) -> Result<Value> {
        self.expect(b'{')?;
        let mut map = HashMap::new();
        self.skip_ws();
        if self.peek()? == b'}' {
            self.pos += 1;
            return Ok(Value::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek()? {
                b',' => self.pos += 1,
                b'}' => {
                    self.pos += 1;
                    return Ok(Value::Obj(map));
                }
                c => bail!("expected ',' or '}}', found {:?}", c as char),
            }
        }
    }

    fn array(&mut self) -> Result<Value> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek()? == b']' {
            self.pos += 1;
            return Ok(Value::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.peek()? {
                b',' => self.pos += 1,
                b']' => {
                    self.pos += 1;
                    return Ok(Value::Arr(items));
                }
                c => bail!("expected ',' or ']', found {:?}", c as char),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            let b = self.peek()?;
            self.pos += 1;
            match b {
                b'"' => return Ok(out),
                b'\\' => {
                    let e = self.peek()?;
                    self.pos += 1;
                    match e {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'n' => out.push('\n'),
                        b'r' => out.push('\r'),
                        b't' => out.push('\t'),
                        b'u' => {
                            if self.pos + 4 > self.bytes.len() {
                                bail!("truncated \\u escape");
                            }
                            let hex = std::str::from_utf8(&self.bytes[self.pos..self.pos + 4])?;
                            let code = u32::from_str_radix(hex, 16)?;
                            self.pos += 4;
                            out.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                        }
                        c => bail!("bad escape \\{}", c as char),
                    }
                }
                _ => {
                    // copy raw UTF-8 bytes through
                    let start = self.pos - 1;
                    while self.pos < self.bytes.len()
                        && self.bytes[self.pos] != b'"'
                        && self.bytes[self.pos] != b'\\'
                    {
                        self.pos += 1;
                    }
                    out.push_str(std::str::from_utf8(&self.bytes[start..self.pos])?);
                }
            }
        }
    }

    fn number(&mut self) -> Result<Value> {
        let start = self.pos;
        while self.pos < self.bytes.len()
            && matches!(self.bytes[self.pos], b'-' | b'+' | b'.' | b'e' | b'E' | b'0'..=b'9')
        {
            self.pos += 1;
        }
        let s = std::str::from_utf8(&self.bytes[start..self.pos])?;
        Ok(Value::Num(s.parse().with_context(|| format!("bad number {s:?}"))?))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Value::parse("42").unwrap().as_f64().unwrap(), 42.0);
        assert_eq!(Value::parse("-1.5e2").unwrap().as_f64().unwrap(), -150.0);
        assert!(Value::parse("true").unwrap().as_bool().unwrap());
        assert_eq!(Value::parse("null").unwrap(), Value::Null);
        assert_eq!(Value::parse(r#""hi""#).unwrap().as_str().unwrap(), "hi");
    }

    #[test]
    fn parses_nested() {
        let v = Value::parse(r#"{"a": [1, 2.5, {"b": "x"}], "c": {"d": false}}"#).unwrap();
        let arr = v.get("a").unwrap().as_arr().unwrap();
        assert_eq!(arr.len(), 3);
        assert_eq!(arr[2].get("b").unwrap().as_str().unwrap(), "x");
        assert!(!v.get("c").unwrap().get("d").unwrap().as_bool().unwrap());
    }

    #[test]
    fn escapes() {
        let v = Value::parse(r#""a\nb\t\"q\" A""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "a\nb\t\"q\" A");
    }

    #[test]
    fn typed_accessors() {
        let v = Value::parse(r#"{"n": 3, "xs": [1.0, 2.0], "is": [1,2,3]}"#).unwrap();
        assert_eq!(v.usize_at("n").unwrap(), 3);
        assert_eq!(v.f32_vec_at("xs").unwrap(), vec![1.0f32, 2.0]);
        assert_eq!(v.usize_vec_at("is").unwrap(), vec![1, 2, 3]);
        assert!(v.get("missing").is_err());
        assert!(v.get("n").unwrap().as_str().is_err());
    }

    #[test]
    fn rejects_garbage() {
        assert!(Value::parse("{").is_err());
        assert!(Value::parse("[1,]").is_err());
        assert!(Value::parse("1 2").is_err());
        assert!(Value::parse("{'a': 1}").is_err());
    }

    #[test]
    fn empty_containers() {
        assert_eq!(Value::parse("[]").unwrap().as_arr().unwrap().len(), 0);
        assert_eq!(Value::parse("{}").unwrap().as_obj().unwrap().len(), 0);
    }

    #[test]
    fn fractional_usize_rejected() {
        assert!(Value::parse("1.5").unwrap().as_usize().is_err());
        assert!(Value::parse("-2").unwrap().as_usize().is_err());
    }
}
