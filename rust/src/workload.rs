//! Workloads: the exported test set (test.bin, written by compile/aot.py)
//! and sensor-style arrival traces driving the serving pipeline.

use crate::tensor::Tensor;
use anyhow::{ensure, Result, Context};
use std::io::Read;
use std::path::Path;

const MAGIC: u32 = 0x4147_4C45; // "AGLE"

/// Test set: images (N,H,W,C) f32 + labels, exported from python.
#[derive(Debug, Clone)]
pub struct TestSet {
    pub images: Tensor,
    pub labels: Vec<i32>,
}

impl TestSet {
    pub fn load(path: &Path) -> Result<Self> {
        let mut f = std::fs::File::open(path)
            .with_context(|| format!("opening {} — run `make artifacts`", path.display()))?;
        let mut hdr = [0u8; 20];
        f.read_exact(&mut hdr)?;
        let rd = |i: usize| u32::from_le_bytes(hdr[i * 4..i * 4 + 4].try_into().unwrap());
        ensure!(rd(0) == MAGIC, "bad magic in {}", path.display());
        let (n, h, w, c) = (rd(1) as usize, rd(2) as usize, rd(3) as usize, rd(4) as usize);
        ensure!(n > 0 && n < 1_000_000, "implausible test set size {n}");
        let mut img_bytes = vec![0u8; n * h * w * c * 4];
        f.read_exact(&mut img_bytes)?;
        let images: Vec<f32> = img_bytes
            .chunks_exact(4)
            .map(|b| f32::from_le_bytes(b.try_into().unwrap()))
            .collect();
        let mut lab_bytes = vec![0u8; n * 4];
        f.read_exact(&mut lab_bytes)?;
        let labels: Vec<i32> = lab_bytes
            .chunks_exact(4)
            .map(|b| i32::from_le_bytes(b.try_into().unwrap()))
            .collect();
        Ok(Self { images: Tensor::new(vec![n, h, w, c], images)?, labels })
    }

    pub fn len(&self) -> usize {
        self.labels.len()
    }

    pub fn is_empty(&self) -> bool {
        self.labels.is_empty()
    }

    /// Image `i` as a unit-batch tensor.
    pub fn image(&self, i: usize) -> Result<Tensor> {
        self.images.select_batch(i)
    }
}

/// Golden-ratio device-seed derivation shared by every per-device
/// stochastic stream (arrival processes here, channel loss streams via
/// `NetConfig::device_seed`): decorrelates devices while keeping the whole
/// run reproducible from one base seed.
pub fn derive_device_seed(base: u64, device_index: usize) -> u64 {
    base ^ (device_index as u64 + 1).wrapping_mul(0x9E37_79B9_7F4A_7C15)
}

/// Inter-arrival process for sensor-driven requests (paper §7.2: real-time
/// means keeping up with the sensor sampling interval).
#[derive(Debug, Clone, Copy)]
pub enum Arrival {
    /// fixed-rate sampling, e.g. a 30 Hz camera
    Periodic { hz: f64 },
    /// Poisson arrivals with the given mean rate
    Poisson { hz: f64, seed: u64 },
    /// Nonhomogeneous Poisson with a raised-cosine diurnal rate:
    /// `rate(t) = base_hz + (peak_hz - base_hz) * (1 - cos(2πt/period_s)) / 2`
    /// — troughs at `t = 0, period_s, …`, a peak at `period_s / 2`.
    /// Sampled by deterministic thinning against `peak_hz`, so the
    /// whole trace is a pure function of the seed.
    Diurnal { period_s: f64, base_hz: f64, peak_hz: f64, seed: u64 },
}

impl Arrival {
    /// Per-device variant of this process: Poisson streams get a
    /// [`derive_device_seed`]-derived seed (the same derivation
    /// `NetConfig::device_seed` uses for channel loss) so concurrent
    /// devices do not produce lockstep-identical timestamps, while the
    /// whole run stays reproducible from one base seed. Periodic
    /// processes are untouched — a fixed-rate sensor is deterministic by
    /// definition.
    pub fn for_device(&self, device_index: usize) -> Arrival {
        match *self {
            Arrival::Periodic { hz } => Arrival::Periodic { hz },
            Arrival::Poisson { hz, seed } => {
                Arrival::Poisson { hz, seed: derive_device_seed(seed, device_index) }
            }
            Arrival::Diurnal { period_s, base_hz, peak_hz, seed } => Arrival::Diurnal {
                period_s,
                base_hz,
                peak_hz,
                seed: derive_device_seed(seed, device_index),
            },
        }
    }

    /// Replace the base seed of a seeded process (no-op for Periodic).
    pub fn with_seed(&self, seed: u64) -> Arrival {
        match *self {
            Arrival::Periodic { hz } => Arrival::Periodic { hz },
            Arrival::Poisson { hz, .. } => Arrival::Poisson { hz, seed },
            Arrival::Diurnal { period_s, base_hz, peak_hz, .. } => {
                Arrival::Diurnal { period_s, base_hz, peak_hz, seed }
            }
        }
    }

    /// Generate `n` arrival timestamps (seconds from epoch 0).
    pub fn timestamps(&self, n: usize) -> Vec<f64> {
        match *self {
            Arrival::Periodic { hz } => (0..n).map(|i| i as f64 / hz).collect(),
            Arrival::Poisson { hz, seed } => {
                let mut state = seed.wrapping_mul(0x9E37_79B9_7F4A_7C15).max(1);
                let mut t = 0.0;
                (0..n)
                    .map(|_| {
                        // xorshift64* -> uniform(0,1) -> exponential
                        state ^= state >> 12;
                        state ^= state << 25;
                        state ^= state >> 27;
                        let u = (state.wrapping_mul(0x2545_F491_4F6C_DD1D) >> 11) as f64
                            / (1u64 << 53) as f64;
                        t += -(1.0 - u).ln() / hz;
                        t
                    })
                    .collect()
            }
            Arrival::Diurnal { period_s, base_hz, peak_hz, seed } => {
                // Lewis–Shedler thinning: draw a homogeneous Poisson
                // stream at peak_hz, keep each candidate arrival with
                // probability rate(t)/peak_hz. Both draws come from one
                // xorshift64* stream, so the trace is seed-deterministic.
                let mut state = seed.wrapping_mul(0x9E37_79B9_7F4A_7C15).max(1);
                let mut draw = move || {
                    state ^= state >> 12;
                    state ^= state << 25;
                    state ^= state >> 27;
                    (state.wrapping_mul(0x2545_F491_4F6C_DD1D) >> 11) as f64 / (1u64 << 53) as f64
                };
                let rate = |t: f64| {
                    let phase = (1.0 - (std::f64::consts::TAU * t / period_s).cos()) * 0.5;
                    base_hz + (peak_hz - base_hz) * phase
                };
                let mut t = 0.0;
                let mut out = Vec::with_capacity(n);
                while out.len() < n {
                    t += -(1.0 - draw()).ln() / peak_hz;
                    if draw() * peak_hz < rate(t) {
                        out.push(t);
                    }
                }
                out
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Write;

    fn write_testbin(path: &Path, n: usize) {
        let (h, w, c) = (4, 4, 3);
        let mut f = std::fs::File::create(path).unwrap();
        for v in [MAGIC, n as u32, h as u32, w as u32, c as u32] {
            f.write_all(&v.to_le_bytes()).unwrap();
        }
        for i in 0..n * h * w * c {
            f.write_all(&(i as f32 * 0.01).to_le_bytes()).unwrap();
        }
        for i in 0..n {
            f.write_all(&(i as i32 % 10).to_le_bytes()).unwrap();
        }
    }

    #[test]
    fn loads_testbin_roundtrip() {
        let dir = std::env::temp_dir().join("agilenn_testbin");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("test.bin");
        write_testbin(&path, 6);
        let ts = TestSet::load(&path).unwrap();
        assert_eq!(ts.len(), 6);
        assert_eq!(ts.images.shape(), &[6, 4, 4, 3]);
        assert_eq!(ts.labels[5], 5);
        let img = ts.image(2).unwrap();
        assert_eq!(img.shape(), &[1, 4, 4, 3]);
        assert!((img.data()[0] - 0.96).abs() < 1e-6);
    }

    #[test]
    fn rejects_bad_magic() {
        let dir = std::env::temp_dir().join("agilenn_testbin2");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("bad.bin");
        std::fs::write(&path, [0u8; 40]).unwrap();
        assert!(TestSet::load(&path).is_err());
    }

    #[test]
    fn periodic_arrivals_evenly_spaced() {
        let ts = Arrival::Periodic { hz: 30.0 }.timestamps(4);
        assert!((ts[1] - ts[0] - 1.0 / 30.0).abs() < 1e-12);
        assert!((ts[3] - 0.1).abs() < 1e-9);
    }

    #[test]
    fn per_device_poisson_streams_are_decorrelated_but_stable() {
        // regression: every device thread used to draw the same Arrival,
        // so all devices hit the batcher in perfectly synchronized bursts
        let base = Arrival::Poisson { hz: 30.0, seed: 42 };
        let t0 = base.for_device(0).timestamps(256);
        let t1 = base.for_device(1).timestamps(256);
        assert_ne!(t0, t1, "device streams must differ");
        assert_eq!(t0, base.for_device(0).timestamps(256), "but stay reproducible");
        // same mean rate on every derived stream
        for ts in [&t0, &t1] {
            let mean_gap = ts.last().unwrap() / 256.0;
            assert!((mean_gap - 1.0 / 30.0).abs() < 0.01, "mean gap {mean_gap}");
        }
        // periodic sensors are untouched by device derivation
        let p = Arrival::Periodic { hz: 30.0 };
        assert_eq!(p.for_device(0).timestamps(8), p.for_device(3).timestamps(8));
    }

    #[test]
    fn with_seed_overrides_only_seeded_processes() {
        let a = Arrival::Poisson { hz: 10.0, seed: 1 }.with_seed(9);
        assert!(matches!(a, Arrival::Poisson { seed: 9, .. }));
        assert!(matches!(
            Arrival::Periodic { hz: 10.0 }.with_seed(9),
            Arrival::Periodic { .. }
        ));
    }

    #[test]
    fn diurnal_arrivals_are_monotone_reproducible_and_rate_modulated() {
        let a = Arrival::Diurnal { period_s: 10.0, base_hz: 5.0, peak_hz: 100.0, seed: 3 };
        let ts = a.timestamps(600);
        assert!(ts.windows(2).all(|w| w[1] > w[0]));
        assert_eq!(ts, a.timestamps(600), "seeded trace must be reproducible");
        // arrivals cluster near the peak (t ≈ period/2 mod period): the
        // middle half of each cycle must hold well over half the mass
        let peakish = ts
            .iter()
            .filter(|t| {
                let phase = *t % 10.0;
                (2.5..7.5).contains(&phase)
            })
            .count();
        assert!(peakish > ts.len() * 6 / 10, "only {peakish}/{} near the peak", ts.len());
        // per-device derivation decorrelates but stays stable
        let d0 = a.for_device(0).timestamps(64);
        let d1 = a.for_device(1).timestamps(64);
        assert_ne!(d0, d1);
        assert_eq!(d0, a.for_device(0).timestamps(64));
        // with_seed replaces the stream
        assert!(matches!(a.with_seed(9), Arrival::Diurnal { seed: 9, .. }));
    }

    #[test]
    fn poisson_arrivals_monotone_with_roughly_right_rate() {
        let ts = Arrival::Poisson { hz: 100.0, seed: 7 }.timestamps(2000);
        assert!(ts.windows(2).all(|w| w[1] > w[0]));
        let mean_gap = ts.last().unwrap() / 2000.0;
        assert!((mean_gap - 0.01).abs() < 0.002, "mean gap {mean_gap}");
    }
}
