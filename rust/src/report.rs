//! Plain-text table rendering for figure/table regeneration — each bench
//! prints the same rows/series the paper reports.

/// A simple aligned table.
#[derive(Debug, Clone)]
pub struct Table {
    pub title: String,
    pub headers: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: impl Into<String>, headers: &[&str]) -> Self {
        Self {
            title: title.into(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) -> &mut Self {
        assert_eq!(cells.len(), self.headers.len(), "row width mismatch");
        self.rows.push(cells);
        self
    }

    pub fn render(&self) -> String {
        let ncol = self.headers.len();
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        out.push_str(&format!("== {} ==\n", self.title));
        let fmt_row = |cells: &[String]| {
            let mut line = String::new();
            for i in 0..ncol {
                line.push_str(&format!("{:<width$}  ", cells[i], width = widths[i]));
            }
            line.trim_end().to_string()
        };
        out.push_str(&fmt_row(&self.headers));
        out.push('\n');
        out.push_str(&"-".repeat(widths.iter().sum::<usize>() + 2 * (ncol - 1)));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row));
            out.push('\n');
        }
        out
    }

    pub fn print(&self) {
        print!("{}", self.render());
    }
}

pub fn ms(seconds: f64) -> String {
    format!("{:.2}", seconds * 1e3)
}

pub fn pct(frac: f64) -> String {
    format!("{:.1}%", frac * 100.0)
}

pub fn mj(joules: f64) -> String {
    format!("{:.2}", joules * 1e3)
}

pub fn kb(bytes: usize) -> String {
    format!("{:.1}", bytes as f64 / 1024.0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new("T", &["a", "bbbb"]);
        t.row(vec!["xx".into(), "y".into()]);
        let s = t.render();
        assert!(s.contains("== T =="));
        assert!(s.contains("a   bbbb"));
        assert!(s.lines().count() == 4);
    }

    #[test]
    #[should_panic]
    fn row_width_checked() {
        let mut t = Table::new("T", &["a"]);
        t.row(vec!["x".into(), "y".into()]);
    }

    #[test]
    fn formatters() {
        assert_eq!(ms(0.0213), "21.30");
        assert_eq!(pct(0.912), "91.2%");
        assert_eq!(mj(0.0042), "4.20");
        assert_eq!(kb(2048), "2.0");
    }
}
