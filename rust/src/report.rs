//! Plain-text table rendering for figure/table regeneration — each bench
//! prints the same rows/series the paper reports — plus the deterministic
//! JSON writer behind golden snapshots and the CI bench artifacts.
//!
//! JSON emission here is **insertion-ordered** ([`JsonObj`] keeps fields
//! in the order they are written, never a `HashMap` iteration): emitting
//! through a hash map made `tests/golden/` diffs and `BENCH_*.json`
//! artifacts reshuffle fields run to run, so every re-bless produced a
//! full-file diff and byte-comparison of reports was impossible. Floats
//! use Rust's shortest-roundtrip formatting, so string equality of two
//! serialized reports is bit equality of their fields.

/// A simple aligned table.
#[derive(Debug, Clone)]
pub struct Table {
    pub title: String,
    pub headers: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: impl Into<String>, headers: &[&str]) -> Self {
        Self {
            title: title.into(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) -> &mut Self {
        assert_eq!(cells.len(), self.headers.len(), "row width mismatch");
        self.rows.push(cells);
        self
    }

    pub fn render(&self) -> String {
        let ncol = self.headers.len();
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        out.push_str(&format!("== {} ==\n", self.title));
        let fmt_row = |cells: &[String]| {
            let mut line = String::new();
            for i in 0..ncol {
                line.push_str(&format!("{:<width$}  ", cells[i], width = widths[i]));
            }
            line.trim_end().to_string()
        };
        out.push_str(&fmt_row(&self.headers));
        out.push('\n');
        out.push_str(&"-".repeat(widths.iter().sum::<usize>() + 2 * (ncol - 1)));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row));
            out.push('\n');
        }
        out
    }

    pub fn print(&self) {
        print!("{}", self.render());
    }
}

// ---------------------------------------------------------------------------
// Deterministic (insertion-ordered) JSON emission
// ---------------------------------------------------------------------------

/// Escape a string for JSON output.
pub fn json_str(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
    out
}

/// A float as JSON: shortest-roundtrip decimal (`{:?}`), so parsing it
/// back yields the bit-identical f64; non-finite values (which JSON
/// cannot carry) become `null`.
pub fn json_f64(v: f64) -> String {
    if v.is_finite() {
        format!("{v:?}")
    } else {
        "null".to_string()
    }
}

/// A JSON array from already-serialized element strings.
pub fn json_array(items: impl IntoIterator<Item = String>) -> String {
    let mut out = String::from("[");
    for (i, item) in items.into_iter().enumerate() {
        if i > 0 {
            out.push(',');
        }
        out.push_str(&item);
    }
    out.push(']');
    out
}

/// Insertion-ordered JSON object writer: fields serialize in exactly the
/// order they are added, every time. This is the substrate for golden
/// snapshots and `BENCH_*.json` — any map-ordered emission would reshuffle
/// keys across runs and make byte comparison meaningless.
#[derive(Debug, Clone)]
pub struct JsonObj {
    body: String,
}

impl Default for JsonObj {
    fn default() -> Self {
        Self::new()
    }
}

impl JsonObj {
    pub fn new() -> Self {
        Self { body: String::from("{") }
    }

    fn push_key(&mut self, key: &str) {
        if self.body.len() > 1 {
            self.body.push(',');
        }
        self.body.push_str(&json_str(key));
        self.body.push(':');
    }

    pub fn field_str(mut self, key: &str, v: &str) -> Self {
        self.push_key(key);
        self.body.push_str(&json_str(v));
        self
    }

    pub fn field_f64(mut self, key: &str, v: f64) -> Self {
        self.push_key(key);
        self.body.push_str(&json_f64(v));
        self
    }

    pub fn field_u64(mut self, key: &str, v: u64) -> Self {
        self.push_key(key);
        self.body.push_str(&v.to_string());
        self
    }

    pub fn field_usize(self, key: &str, v: usize) -> Self {
        self.field_u64(key, v as u64)
    }

    pub fn field_bool(mut self, key: &str, v: bool) -> Self {
        self.push_key(key);
        self.body.push_str(if v { "true" } else { "false" });
        self
    }

    /// Insert an already-serialized JSON value (nested object or array).
    pub fn field_raw(mut self, key: &str, raw: &str) -> Self {
        self.push_key(key);
        self.body.push_str(raw);
        self
    }

    pub fn finish(mut self) -> String {
        self.body.push('}');
        self.body
    }
}

pub fn ms(seconds: f64) -> String {
    format!("{:.2}", seconds * 1e3)
}

pub fn pct(frac: f64) -> String {
    format!("{:.1}%", frac * 100.0)
}

pub fn mj(joules: f64) -> String {
    format!("{:.2}", joules * 1e3)
}

pub fn kb(bytes: usize) -> String {
    format!("{:.1}", bytes as f64 / 1024.0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new("T", &["a", "bbbb"]);
        t.row(vec!["xx".into(), "y".into()]);
        let s = t.render();
        assert!(s.contains("== T =="));
        assert!(s.contains("a   bbbb"));
        assert!(s.lines().count() == 4);
    }

    #[test]
    #[should_panic]
    fn row_width_checked() {
        let mut t = Table::new("T", &["a"]);
        t.row(vec!["x".into(), "y".into()]);
    }

    #[test]
    fn formatters() {
        assert_eq!(ms(0.0213), "21.30");
        assert_eq!(pct(0.912), "91.2%");
        assert_eq!(mj(0.0042), "4.20");
        assert_eq!(kb(2048), "2.0");
    }

    #[test]
    fn json_obj_preserves_insertion_order_byte_for_byte() {
        let build = || {
            JsonObj::new()
                .field_str("name", "fleet")
                .field_f64("throughput", 1234.5)
                .field_u64("requests", 42)
                .field_bool("ok", true)
                .finish()
        };
        let a = build();
        assert_eq!(a, build(), "same fields must serialize identically");
        assert_eq!(a, r#"{"name":"fleet","throughput":1234.5,"requests":42,"ok":true}"#);
    }

    #[test]
    fn json_floats_roundtrip_bit_exactly() {
        for v in [0.1 + 0.2, 1.0 / 3.0, 1e-7, 123456789.123456789, 0.0] {
            let s = json_f64(v);
            let back: f64 = s.parse().unwrap();
            assert_eq!(back.to_bits(), v.to_bits(), "{s}");
        }
        assert_eq!(json_f64(f64::NAN), "null");
        assert_eq!(json_f64(f64::INFINITY), "null");
    }

    #[test]
    fn json_output_parses_with_the_crate_parser() {
        let nested = json_array(vec![
            JsonObj::new().field_usize("server", 0).field_f64("q", 0.25).finish(),
            JsonObj::new().field_usize("server", 1).field_f64("q", 0.5).finish(),
        ]);
        let text = JsonObj::new()
            .field_str("esc", "a\"b\\c\nd\u{1}")
            .field_raw("shards", &nested)
            .finish();
        let v = crate::json::Value::parse(&text).unwrap();
        assert_eq!(v.str_at("esc").unwrap(), "a\"b\\c\nd\u{1}");
        let shards = v.get("shards").unwrap().as_arr().unwrap();
        assert_eq!(shards.len(), 2);
        assert_eq!(shards[1].usize_at("server").unwrap(), 1);
        assert_eq!(shards[1].f64_at("q").unwrap(), 0.5);
    }

    #[test]
    fn empty_json_obj_is_valid() {
        assert_eq!(JsonObj::new().finish(), "{}");
        assert_eq!(json_array(Vec::<String>::new()), "[]");
    }
}
