//! Micro-bench harness (criterion stand-in — the build environment vendors
//! no criterion). Benches are `harness = false` binaries calling
//! [`Bench::run`]; output is one line per benchmark with median / p10 / p90
//! nanoseconds per iteration, plus a machine-greppable `BENCH\t` prefix.

use std::time::Instant;

pub struct Bench {
    /// minimum sampling time per benchmark
    budget: std::time::Duration,
    /// samples to collect
    samples: usize,
}

impl Default for Bench {
    fn default() -> Self {
        Self::new()
    }
}

impl Bench {
    pub fn new() -> Self {
        let ms = std::env::var("AGILENN_BENCH_MS").ok().and_then(|s| s.parse().ok()).unwrap_or(300);
        Self { budget: std::time::Duration::from_millis(ms), samples: 30 }
    }

    pub fn with_budget_ms(mut self, ms: u64) -> Self {
        self.budget = std::time::Duration::from_millis(ms);
        self
    }

    /// Measure `f`, printing a stats line. Returns median ns/iter.
    pub fn run<T>(&self, name: &str, mut f: impl FnMut() -> T) -> f64 {
        // warmup + calibrate iterations per sample
        let t0 = Instant::now();
        let mut iters_per_sample = 1usize;
        loop {
            std::hint::black_box(f());
            if t0.elapsed() > self.budget / 10 {
                break;
            }
            iters_per_sample += 1;
        }
        iters_per_sample = iters_per_sample.max(1);

        let mut samples_ns: Vec<f64> = Vec::with_capacity(self.samples);
        let deadline = Instant::now() + self.budget;
        for _ in 0..self.samples {
            let s0 = Instant::now();
            for _ in 0..iters_per_sample {
                std::hint::black_box(f());
            }
            samples_ns.push(s0.elapsed().as_nanos() as f64 / iters_per_sample as f64);
            if Instant::now() > deadline {
                break;
            }
        }
        samples_ns.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let q = |p: f64| samples_ns[((samples_ns.len() - 1) as f64 * p).round() as usize];
        let (p10, med, p90) = (q(0.1), q(0.5), q(0.9));
        println!(
            "BENCH\t{name}\tmedian {}\tp10 {}\tp90 {}\t({} samples x {} iters)",
            fmt_ns(med),
            fmt_ns(p10),
            fmt_ns(p90),
            samples_ns.len(),
            iters_per_sample
        );
        med
    }
}

fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.0} ns")
    } else if ns < 1e6 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.3} s", ns / 1e9)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something_positive() {
        let b = Bench::new().with_budget_ms(20);
        let med = b.run("noop_loop", || {
            let mut s = 0u64;
            for i in 0..100u64 {
                s = s.wrapping_add(i * i);
            }
            s
        });
        assert!(med > 0.0);
    }

    #[test]
    fn fmt_ns_units() {
        assert!(fmt_ns(500.0).ends_with("ns"));
        assert!(fmt_ns(5_000.0).ends_with("µs"));
        assert!(fmt_ns(5_000_000.0).ends_with("ms"));
        assert!(fmt_ns(5e9).ends_with("s"));
    }
}
