//! CI perf-regression gate over the fleet engine and the serving hot
//! paths (`agilenn perfgate`).
//!
//! The gate measures a small timed-harness suite on the reference backend
//! (no artifacts, no PJRT — the numbers isolate the serving stack), emits
//! the results as deterministic insertion-ordered JSON (`BENCH_6.json`,
//! uploaded as a CI artifact, with a self-describing repo-root pointer
//! from [`pointer_json`]), and fails — nonzero exit — when any gated
//! throughput falls more than `tolerance` below a baseline JSON:
//!
//! * the **committed floors** in `rust/bench/baseline.json` guard against
//!   catastrophic regressions on any machine (they are deliberately far
//!   below healthy throughput, so cross-machine variance cannot flake CI);
//! * CI additionally re-runs the gate with `AGILENN_PERF_HANDICAP=1.5`
//!   against the *fresh* same-machine measurement, proving end to end
//!   that an injected slowdown actually trips the gate.
//!
//! `AGILENN_PERF_HANDICAP=<factor>` stretches every timed section by
//! busy-waiting `(factor - 1) × elapsed` inside the measurement — real
//! wall time, not arithmetic on the result — so the handicapped run is a
//! genuine slowdown as the gate sees it.

use crate::config::{BackendKind, Scheme};
use crate::fixtures::{SyntheticSpec, SYNTHETIC_DATASET};
use crate::json::Value;
use crate::net::{transmit_frame, Channel, GilbertElliott};
use crate::obs::{NoopSink, RecordingSink};
use crate::report::{json_array, json_str, JsonObj};
use crate::runtime::ReferenceBackend;
use crate::serve::{make_device_side, AutoscaleConfig, ClockKind, Placement, ServeBuilder};
use crate::workload::Arrival;
use anyhow::{ensure, Context, Result};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Schema tag carried by every emitted report, so a future format change
/// cannot be silently compared against an old baseline.
pub const SCHEMA: &str = "agilenn-bench-v1";

/// Default regression tolerance: fail when a gated throughput drops more
/// than 20% below its baseline.
pub const DEFAULT_TOLERANCE: f64 = 0.20;

/// One measured benchmark.
#[derive(Debug, Clone, PartialEq)]
pub struct PerfEntry {
    pub name: String,
    /// the gated metric: work units per second (higher is better)
    pub throughput: f64,
    /// wall seconds of the measured section (informational)
    pub wall_s: f64,
    /// informational extras (deterministic virtual-time quantities etc.),
    /// sorted by key for stable serialization; never gated
    pub info: Vec<(String, f64)>,
}

/// A bench suite result: what `BENCH_6.json` holds.
#[derive(Debug, Clone, Default)]
pub struct PerfReport {
    pub entries: Vec<PerfEntry>,
}

impl PerfReport {
    /// Deterministic JSON form (insertion-ordered; see `report::JsonObj`).
    pub fn to_json(&self) -> String {
        let entries = json_array(self.entries.iter().map(|e| {
            let mut info = e.info.clone();
            info.sort_by(|a, b| a.0.cmp(&b.0));
            let mut obj = JsonObj::new()
                .field_str("name", &e.name)
                .field_f64("throughput", e.throughput)
                .field_f64("wall_s", e.wall_s);
            let mut inner = JsonObj::new();
            for (k, v) in &info {
                inner = inner.field_f64(k, *v);
            }
            obj = obj.field_raw("info", &inner.finish());
            obj.finish()
        }));
        JsonObj::new()
            .field_str("schema", SCHEMA)
            .field_raw("entries", &entries)
            .finish()
    }

    pub fn parse(text: &str) -> Result<PerfReport> {
        let v = Value::parse(text).context("parsing bench JSON")?;
        ensure!(
            v.str_at("schema")? == SCHEMA,
            "bench JSON schema {:?} is not {SCHEMA:?}",
            v.str_at("schema")?
        );
        let mut entries = Vec::new();
        for e in v.get("entries")?.as_arr()? {
            let mut info: Vec<(String, f64)> = match e.opt("info") {
                Some(obj) => obj
                    .as_obj()?
                    .iter()
                    .map(|(k, val)| Ok((k.clone(), val.as_f64()?)))
                    .collect::<Result<_>>()?,
                None => Vec::new(),
            };
            info.sort_by(|a, b| a.0.cmp(&b.0));
            entries.push(PerfEntry {
                name: e.str_at("name")?,
                throughput: e.f64_at("throughput")?,
                wall_s: e.opt("wall_s").map(|w| w.as_f64()).transpose()?.unwrap_or(0.0),
                info,
            });
        }
        Ok(PerfReport { entries })
    }

    pub fn load(path: &std::path::Path) -> Result<PerfReport> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading bench baseline {}", path.display()))?;
        Self::parse(&text).with_context(|| format!("parsing {}", path.display()))
    }
}

/// Compare `current` against `baseline`: one failure line per gated
/// metric that regressed beyond `tolerance` (or went missing). An empty
/// result means the gate passes; extra entries in `current` are fine.
pub fn check(current: &PerfReport, baseline: &PerfReport, tolerance: f64) -> Vec<String> {
    let mut failures = Vec::new();
    for base in &baseline.entries {
        match current.entries.iter().find(|e| e.name == base.name) {
            None => failures.push(format!("bench {:?} missing from the current run", base.name)),
            Some(cur) => {
                let floor = base.throughput * (1.0 - tolerance);
                if cur.throughput < floor {
                    failures.push(format!(
                        "{}: {:.1}/s is a {:.1}% regression vs baseline {:.1}/s \
                         (tolerance {:.0}%)",
                        base.name,
                        cur.throughput,
                        (1.0 - cur.throughput / base.throughput) * 100.0,
                        base.throughput,
                        tolerance * 100.0
                    ));
                }
            }
        }
    }
    failures
}

/// What the measurement suite runs.
#[derive(Debug, Clone)]
pub struct GateConfig {
    /// fleet-engine sweep size (the headline 1M-request scenario)
    pub requests: usize,
    pub devices: usize,
    pub servers: usize,
}

impl Default for GateConfig {
    fn default() -> Self {
        Self { requests: 1_000_000, devices: 10_000, servers: 4 }
    }
}

/// Injected-slowdown factor from `AGILENN_PERF_HANDICAP` (>= 1.0; 1.0 =
/// no handicap).
pub fn handicap_factor() -> f64 {
    std::env::var("AGILENN_PERF_HANDICAP")
        .ok()
        .and_then(|s| s.parse::<f64>().ok())
        .map(|f| f.max(1.0))
        .unwrap_or(1.0)
}

/// Busy-wait for `d` (std::thread::sleep is too coarse for sub-ms spans
/// and a sleep would not register as CPU work anyway).
fn spin_for(d: Duration) {
    let t0 = Instant::now();
    while t0.elapsed() < d {
        std::hint::spin_loop();
    }
}

/// Time one measured section, stretching it by the handicap factor.
fn timed<T>(handicap: f64, f: impl FnOnce() -> Result<T>) -> Result<(T, f64)> {
    let t0 = Instant::now();
    let out = f()?;
    let measured = t0.elapsed();
    if handicap > 1.0 {
        spin_for(measured.mul_f64(handicap - 1.0));
    }
    Ok((out, t0.elapsed().as_secs_f64()))
}

/// Run the suite and return the report. `progress` gets one line per
/// finished bench (the CLI passes a printer; tests pass a no-op).
pub fn measure(cfg: &GateConfig, mut progress: impl FnMut(&PerfEntry)) -> Result<PerfReport> {
    let handicap = handicap_factor();
    let mut entries = Vec::new();

    // 1) the fleet engine: the 1M-request × 10k-device reference sweep.
    //    Gated on served requests per host second; the sim quantiles ride
    //    along as (deterministic) info fields. A NoopSink is attached on
    //    purpose: the run exercises the full trace-emission path with a
    //    discarding sink and must stay inside the same fleet_engine floor,
    //    proving disabled tracing costs nothing measurable.
    let (rep, wall) = timed(handicap, || {
        ServeBuilder::new(SYNTHETIC_DATASET)
            .backend(BackendKind::Reference)
            .scheme(Scheme::Agile)
            .clock(ClockKind::Sim)
            .fleet(|f| {
                f.devices = cfg.devices;
                f.requests = cfg.requests;
                f.servers = cfg.servers;
                f.placement = Placement::LeastLoaded;
            })
            .rate_hz(20.0)
            .arrival_seed(11)
            .trace_sink(Arc::new(NoopSink))
            .build()?
            .run()
    })?;
    ensure!(rep.requests == cfg.requests, "fleet sweep served {} requests", rep.requests);
    let entry = PerfEntry {
        name: "fleet_engine".into(),
        throughput: cfg.requests as f64 / wall,
        wall_s: wall,
        info: vec![
            ("sim_p99_latency_ms".into(), rep.p99_latency_s * 1e3),
            ("sim_p95_latency_ms".into(), rep.p95_latency_s * 1e3),
            ("sim_wall_s".into(), rep.wall_s),
            ("batches".into(), rep.batches as f64),
            ("servers".into(), rep.shards.len() as f64),
        ],
    };
    progress(&entry);
    entries.push(entry);

    // 2) the device hot path: un-memoized reference encode (NN + quantize
    //    + LZW) — what every request pays on the threaded/wall pipeline.
    let spec = SyntheticSpec::new(SYNTHETIC_DATASET);
    let meta = spec.meta();
    let backend = ReferenceBackend::from_meta(&meta);
    let mut run_cfg =
        crate::config::RunConfig::new("/nonexistent", SYNTHETIC_DATASET, Scheme::Agile);
    run_cfg.backend = BackendKind::Reference;
    let mut device = make_device_side(&backend, &run_cfg, &meta)?;
    let testset = spec.testset(64)?;
    let images: Vec<_> = (0..16).map(|i| testset.image(i).unwrap()).collect();
    let (iters, wall) = timed(handicap, || {
        let mut iters = 0u64;
        let t0 = Instant::now();
        while t0.elapsed() < Duration::from_millis(250) {
            for img in &images {
                std::hint::black_box(device.encode(img)?);
                iters += 1;
            }
        }
        Ok(iters)
    })?;
    let entry = PerfEntry {
        name: "device_encode".into(),
        throughput: iters as f64 / wall,
        wall_s: wall,
        info: Vec::new(),
    };
    progress(&entry);
    entries.push(entry);

    // 3) the transport hot path: whole-frame ARQ over a bursty channel.
    let profile = crate::simulator::NetworkProfile::wifi_6mbps();
    let mut chan = Channel::new(&profile, GilbertElliott::bursty(0.2, 4.0), None, 7);
    let (iters, wall) = timed(handicap, || {
        let mut iters = 0u64;
        let mut t = 0.0f64;
        let t0 = Instant::now();
        while t0.elapsed() < Duration::from_millis(250) {
            for _ in 0..256 {
                let stats = transmit_frame(&mut chan, 420, t);
                t += stats.uplink_s;
                iters += 1;
            }
        }
        Ok(iters)
    })?;
    let entry = PerfEntry {
        name: "arq_transport".into(),
        throughput: iters as f64 / wall,
        wall_s: wall,
        info: Vec::new(),
    };
    progress(&entry);
    entries.push(entry);

    // 4) the wire codec: envelope encode + decode of both uplink bodies
    //    (whole LZW frame / importance-ordered packet subset) — what every
    //    request pays twice on the real-socket path (device client encodes,
    //    daemon decodes, and the reply rides the same envelope).
    let symbols: Vec<u8> = (0..1216).map(|i| (i % 13) as u8 & 0x0F).collect();
    let pkts = crate::net::Packetizer::new(128, None).packetize(9, &symbols, 4)?;
    let frame = crate::compression::Frame { payload: vec![0xA5; 300], count: 1216, bits: 4 };
    let (iters, wall) = timed(handicap, || {
        let mut iters = 0u64;
        let t0 = Instant::now();
        while t0.elapsed() < Duration::from_millis(250) {
            for _ in 0..64 {
                let msg = crate::net::WireMsg::OffloadPackets {
                    id: iters,
                    count: symbols.len() as u32,
                    bits: 4,
                    packets: pkts.clone(),
                };
                std::hint::black_box(crate::net::WireMsg::decode(&msg.encode())?);
                let msg = crate::net::WireMsg::OffloadFrame { id: iters, frame: frame.clone() };
                std::hint::black_box(crate::net::WireMsg::decode(&msg.encode())?);
                iters += 2;
            }
        }
        Ok(iters)
    })?;
    let entry = PerfEntry {
        name: "wire_codec".into(),
        throughput: iters as f64 / wall,
        wall_s: wall,
        info: vec![("packets_per_msg".into(), pkts.len() as f64)],
    };
    progress(&entry);
    entries.push(entry);

    // 5) the autotuner evaluator: an exhaustive tune over the default
    //    8-point grid, every point a fresh fleet-engine run. Gated on
    //    config evaluations per host second.
    let tune_cfg = crate::tune::TuneConfig {
        space: crate::tune::SearchSpace::default(),
        eval: crate::tune::EvalSpec {
            requests: 2000,
            ..crate::tune::EvalSpec::default()
        },
        strategy: crate::tune::StrategyKind::Exhaustive,
        state: None,
        out: None,
        stop_after: None,
        trace: crate::obs::Tracer::off(),
    };
    let grid = tune_cfg.space.len();
    let (outcome, wall) = timed(handicap, || crate::tune::run(&tune_cfg, |_| {}))?;
    ensure!(
        outcome.completed && outcome.evaluated == grid,
        "tune sweep evaluated {}/{} points",
        outcome.evaluated,
        grid
    );
    let entry = PerfEntry {
        name: "tune_eval".into(),
        throughput: outcome.evaluated as f64 / wall,
        wall_s: wall,
        info: vec![
            ("grid_points".into(), grid as f64),
            ("front_size".into(), outcome.front.len() as f64),
        ],
    };
    progress(&entry);
    entries.push(entry);

    // 6) the fleet engine with a *recording* sink: the same headline
    //    sweep as (1) but every request-lifecycle event is materialized
    //    in memory — the worst-case tracing overhead, gated separately so
    //    a regression in the emission path cannot hide inside the
    //    fleet_engine tolerance.
    let sink = Arc::new(RecordingSink::new());
    let (rep, wall) = timed(handicap, || {
        ServeBuilder::new(SYNTHETIC_DATASET)
            .backend(BackendKind::Reference)
            .scheme(Scheme::Agile)
            .clock(ClockKind::Sim)
            .fleet(|f| {
                f.devices = cfg.devices;
                f.requests = cfg.requests;
                f.servers = cfg.servers;
                f.placement = Placement::LeastLoaded;
            })
            .rate_hz(20.0)
            .arrival_seed(11)
            .trace_sink(sink.clone())
            .build()?
            .run()
    })?;
    ensure!(rep.requests == cfg.requests, "traced sweep served {} requests", rep.requests);
    ensure!(!sink.is_empty(), "traced sweep recorded no events");
    let entry = PerfEntry {
        name: "fleet_engine_traced".into(),
        throughput: cfg.requests as f64 / wall,
        wall_s: wall,
        info: vec![
            ("events".into(), sink.len() as f64),
            ("sim_wall_s".into(), rep.wall_s),
        ],
    };
    progress(&entry);
    entries.push(entry);

    // 7) the autoscaled fleet: the same headline scale but diurnal
    //    arrivals, a virtual service-time model, and the SLO controller
    //    resizing the fleet mid-run. The control plane rides the dispatch
    //    hot path (per-batch window append + periodic p95 over the rolling
    //    window), so it is gated separately from fleet_engine.
    let (rep, wall) = timed(handicap, || {
        ServeBuilder::new(SYNTHETIC_DATASET)
            .backend(BackendKind::Reference)
            .scheme(Scheme::Agile)
            .clock(ClockKind::Sim)
            .fleet(|f| {
                f.devices = cfg.devices;
                f.requests = cfg.requests;
                f.servers = 2;
                f.placement = Placement::WeightedLeastLoaded;
                f.service.base_s = 0.5e-3;
                f.service.per_sample_s = 0.1e-3;
                f.autoscale = Some(AutoscaleConfig::new(1, 8));
                f.slo_p99_s = 50e-3;
            })
            .arrival(Arrival::Diurnal { period_s: 20.0, base_hz: 0.4, peak_hz: 4.0, seed: 16 })
            .arrival_seed(11)
            .build()?
            .run()
    })?;
    ensure!(rep.requests == cfg.requests, "autoscaled sweep served {} requests", rep.requests);
    let entry = PerfEntry {
        name: "autoscaled_fleet".into(),
        throughput: cfg.requests as f64 / wall,
        wall_s: wall,
        info: vec![
            ("sim_wall_s".into(), rep.wall_s),
            ("server_seconds".into(), rep.server_seconds),
            ("scale_outs".into(), rep.scale_outs as f64),
            ("scale_ins".into(), rep.scale_ins as f64),
            ("slo_attainment".into(), rep.slo_attainment),
        ],
    };
    progress(&entry);
    entries.push(entry);

    // 8) the adaptive policy: the headline sweep over a bursty lossy
    //    channel with the per-request policy armed — every arrival pays a
    //    policy decision, every completion an EWMA observation, and
    //    multi-width encode/decode memoization replaces the single-width
    //    Vec memos. Gated separately so the policy hot path cannot hide
    //    inside the fleet_engine tolerance.
    let (rep, wall) = timed(handicap, || {
        ServeBuilder::new(SYNTHETIC_DATASET)
            .backend(BackendKind::Reference)
            .scheme(Scheme::Agile)
            .clock(ClockKind::Sim)
            .fleet(|f| {
                f.devices = cfg.devices;
                f.requests = cfg.requests;
                f.servers = cfg.servers;
                f.placement = Placement::LeastLoaded;
            })
            .rate_hz(20.0)
            .arrival_seed(11)
            .net(|n| {
                n.loss = GilbertElliott::bursty(0.3, 4.0);
                n.packet_payload = Some(64);
                n.seed = 42;
            })
            .policy(crate::serve::PolicyConfig::default())
            .build()?
            .run()
    })?;
    ensure!(rep.requests == cfg.requests, "adaptive sweep served {} requests", rep.requests);
    let pol = rep.policy.as_ref().map(|p| (p.switches, p.mean_bits)).unwrap_or((0, 0.0));
    let entry = PerfEntry {
        name: "adaptive_policy".into(),
        throughput: cfg.requests as f64 / wall,
        wall_s: wall,
        info: vec![
            ("sim_wall_s".into(), rep.wall_s),
            ("policy_switches".into(), pol.0 as f64),
            ("policy_mean_bits".into(), pol.1),
        ],
    };
    progress(&entry);
    entries.push(entry);

    Ok(PerfReport { entries })
}

/// Current commit id: `GITHUB_SHA` in CI, `git rev-parse HEAD` locally,
/// `"unknown"` outside a work tree.
pub fn git_sha() -> String {
    if let Ok(sha) = std::env::var("GITHUB_SHA") {
        if !sha.is_empty() {
            return sha;
        }
    }
    std::process::Command::new("git")
        .args(["rev-parse", "HEAD"])
        .output()
        .ok()
        .filter(|out| out.status.success())
        .and_then(|out| String::from_utf8(out.stdout).ok())
        .map(|s| s.trim().to_string())
        .filter(|s| !s.is_empty())
        .unwrap_or_else(|| "unknown".to_string())
}

/// Self-describing repo-root pointer for a bench artifact: which file
/// holds the measurements, which commit produced them, and which entries
/// were actually measured. Replaces the hand-written placeholder notes.
pub fn pointer_json(report: &PerfReport, artifact: &str) -> String {
    let names = json_array(report.entries.iter().map(|e| json_str(&e.name)));
    JsonObj::new()
        .field_str("schema", "agilenn-bench-pointer-v1")
        .field_str("artifact", artifact)
        .field_str("git_sha", &git_sha())
        .field_raw("entries", &names)
        .field_str(
            "note",
            "regenerated by `agilenn perfgate --pointer`; CI uploads the artifact named here",
        )
        .finish()
        + "\n"
}

#[cfg(test)]
mod tests {
    use super::*;

    fn entry(name: &str, throughput: f64) -> PerfEntry {
        PerfEntry { name: name.into(), throughput, wall_s: 1.0, info: Vec::new() }
    }

    fn report(entries: Vec<PerfEntry>) -> PerfReport {
        PerfReport { entries }
    }

    #[test]
    fn gate_fails_on_a_25_percent_slowdown_and_passes_within_tolerance() {
        let baseline = report(vec![entry("fleet_engine", 100_000.0)]);
        // 25% slower than baseline: must trip the 20% gate
        let slowed = report(vec![entry("fleet_engine", 75_000.0)]);
        let failures = check(&slowed, &baseline, DEFAULT_TOLERANCE);
        assert_eq!(failures.len(), 1, "{failures:?}");
        assert!(failures[0].contains("fleet_engine"), "{}", failures[0]);
        // 10% slower: within tolerance
        let ok = report(vec![entry("fleet_engine", 90_000.0)]);
        assert!(check(&ok, &baseline, DEFAULT_TOLERANCE).is_empty());
        // faster never fails
        let faster = report(vec![entry("fleet_engine", 150_000.0)]);
        assert!(check(&faster, &baseline, DEFAULT_TOLERANCE).is_empty());
    }

    #[test]
    fn gate_fails_on_a_missing_bench() {
        let baseline = report(vec![entry("fleet_engine", 1.0), entry("device_encode", 1.0)]);
        let current = report(vec![entry("fleet_engine", 1.0)]);
        let failures = check(&current, &baseline, DEFAULT_TOLERANCE);
        assert_eq!(failures.len(), 1);
        assert!(failures[0].contains("device_encode"));
        // extra entries in current are not an error
        let extra = report(vec![
            entry("fleet_engine", 1.0),
            entry("device_encode", 1.0),
            entry("brand_new", 9.0),
        ]);
        assert!(check(&extra, &baseline, DEFAULT_TOLERANCE).is_empty());
    }

    #[test]
    fn report_json_roundtrips_and_is_byte_stable() {
        let rep = report(vec![
            PerfEntry {
                name: "fleet_engine".into(),
                throughput: 123456.789,
                wall_s: 8.1,
                info: vec![("sim_p99_latency_ms".into(), 4.25), ("batches".into(), 125000.0)],
            },
            entry("arq_transport", 1e6),
        ]);
        let a = rep.to_json();
        assert_eq!(a, rep.to_json(), "serialization must be deterministic");
        let back = PerfReport::parse(&a).unwrap();
        assert_eq!(back.entries.len(), 2);
        assert_eq!(back.entries[0].name, "fleet_engine");
        assert_eq!(back.entries[0].throughput.to_bits(), 123456.789f64.to_bits());
        // info parses back sorted by key regardless of map order
        assert_eq!(back.entries[0].info[0].0, "batches");
        assert_eq!(back.to_json(), a, "parse -> serialize is the identity");
    }

    #[test]
    fn parse_rejects_other_schemas() {
        assert!(PerfReport::parse(r#"{"schema":"v0","entries":[]}"#).is_err());
        assert!(PerfReport::parse("{}").is_err());
    }

    #[test]
    fn pointer_json_names_the_artifact_and_every_entry() {
        let rep = report(vec![entry("fleet_engine", 1.0), entry("tune_eval", 2.0)]);
        let ptr = pointer_json(&rep, "BENCH_6.json");
        assert!(ptr.ends_with('\n'));
        let v = crate::json::Value::parse(&ptr).unwrap();
        assert_eq!(v.str_at("schema").unwrap(), "agilenn-bench-pointer-v1");
        assert_eq!(v.str_at("artifact").unwrap(), "BENCH_6.json");
        assert!(!v.str_at("git_sha").unwrap().is_empty());
        let names: Vec<_> =
            v.get("entries").unwrap().as_arr().unwrap().iter().map(|e| e.as_str().unwrap()).collect();
        assert_eq!(names, ["fleet_engine", "tune_eval"]);
    }

    #[test]
    fn handicap_defaults_to_unity_and_clamps() {
        // (env-var reads in tests are race-prone; exercise the clamp math
        // through the public surface instead)
        assert!(handicap_factor() >= 1.0);
    }
}
