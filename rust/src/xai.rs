//! Runtime-side XAI bookkeeping.
//!
//! At serving time the XAI tool itself is *unavailable* (the paper's whole
//! point): the device just splits features by position, because training
//! pinned the top-k important channels to the front. This module carries the
//! importance statistics exported from training and recomputes the skewness
//! metrics used by the Fig 4 / Fig 21 reports.

/// Normalise an importance vector to unit L1 mass.
pub fn normalize(imp: &[f64]) -> Vec<f64> {
    let s: f64 = imp.iter().map(|v| v.abs()).sum();
    if s <= 0.0 {
        return vec![0.0; imp.len()];
    }
    imp.iter().map(|v| v.abs() / s).collect()
}

/// Position-agnostic skewness: total mass of the k largest entries
/// (paper Fig 4's "normalized importance of the top 20% features").
pub fn natural_skewness(imp: &[f64], k: usize) -> f64 {
    let norm = normalize(imp);
    let mut sorted = norm;
    sorted.sort_by(|a, b| b.partial_cmp(a).unwrap());
    sorted.iter().take(k).sum()
}

/// Position-aware skewness: mass of the *first* k channels — what the
/// runtime split actually gets (paper Fig 21a/d).
pub fn achieved_skewness(imp: &[f64], k: usize) -> f64 {
    let norm = normalize(imp);
    norm.iter().take(k).sum()
}

/// True iff some channel >= k outranks a channel < k (a disorder case).
pub fn is_disordered(imp: &[f64], k: usize) -> bool {
    if k == 0 || k >= imp.len() {
        return false;
    }
    let norm = normalize(imp);
    let min_front = norm[..k].iter().cloned().fold(f64::INFINITY, f64::min);
    let max_back = norm[k..].iter().cloned().fold(f64::NEG_INFINITY, f64::max);
    max_back > min_front
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn normalize_unit_mass() {
        let n = normalize(&[1.0, 3.0]);
        assert!((n.iter().sum::<f64>() - 1.0).abs() < 1e-12);
        assert!((n[1] - 0.75).abs() < 1e-12);
    }

    #[test]
    fn normalize_zero_vector() {
        assert_eq!(normalize(&[0.0, 0.0]), vec![0.0, 0.0]);
    }

    #[test]
    fn skewness_metrics_disagree_when_misordered() {
        let imp = [0.05, 0.05, 0.5, 0.3, 0.1];
        assert!((natural_skewness(&imp, 2) - 0.8).abs() < 1e-9);
        assert!((achieved_skewness(&imp, 2) - 0.1).abs() < 1e-9);
        assert!(is_disordered(&imp, 2));
    }

    #[test]
    fn ordered_vector_not_disordered() {
        let imp = [0.5, 0.3, 0.1, 0.07, 0.03];
        assert!(!is_disordered(&imp, 2));
        assert!((achieved_skewness(&imp, 2) - natural_skewness(&imp, 2)).abs() < 1e-9);
    }

    #[test]
    fn disorder_edge_cases() {
        assert!(!is_disordered(&[1.0, 2.0], 0));
        assert!(!is_disordered(&[1.0, 2.0], 2));
    }
}
