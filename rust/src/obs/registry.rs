//! The unified metrics registry: named counters, float sums, and
//! log-bucketed histograms (the crate's single [`Histogram`] type).
//!
//! The serving stack accumulates into typed per-run aggregates on the hot
//! path (no map lookups per request) and folds them into a registry at
//! stream finish; [`crate::serve::PipelineReport::from_registry`] then
//! derives every report field from registry entries — the report is a
//! view over the registry, field-for-field compatible with the
//! pre-registry implementation.

use std::collections::BTreeMap;

use super::hist::Histogram;
use crate::report::JsonObj;

pub const METRICS_SCHEMA: &str = "agilenn-metrics-v1";

/// Named counters + sums + histograms. Keys are `&'static str` by design:
/// metric names are a fixed vocabulary, not runtime data.
#[derive(Debug, Clone, Default)]
pub struct MetricsRegistry {
    counters: BTreeMap<&'static str, u64>,
    sums: BTreeMap<&'static str, f64>,
    hists: BTreeMap<&'static str, Histogram>,
}

impl MetricsRegistry {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn counter_add(&mut self, name: &'static str, v: u64) {
        *self.counters.entry(name).or_insert(0) += v;
    }

    /// Counter value; 0 when never written.
    pub fn counter(&self, name: &str) -> u64 {
        self.counters.get(name).copied().unwrap_or(0)
    }

    pub fn sum_add(&mut self, name: &'static str, v: f64) {
        *self.sums.entry(name).or_insert(0.0) += v;
    }

    /// Sum value; 0.0 when never written.
    pub fn sum(&self, name: &str) -> f64 {
        self.sums.get(name).copied().unwrap_or(0.0)
    }

    /// The named histogram, created empty on first access.
    pub fn hist_mut(&mut self, name: &'static str) -> &mut Histogram {
        self.hists.entry(name).or_default()
    }

    /// Move an externally accumulated histogram into the registry.
    pub fn insert_hist(&mut self, name: &'static str, h: Histogram) {
        self.hists.insert(name, h);
    }

    pub fn hist(&self, name: &str) -> Option<&Histogram> {
        self.hists.get(name)
    }

    pub fn hist_names(&self) -> impl Iterator<Item = &'static str> + '_ {
        self.hists.keys().copied()
    }

    /// Deterministic JSON: schema tag, then counters / sums / histogram
    /// summaries each as a key-sorted object (BTreeMap order) with
    /// shortest-roundtrip floats. `&mut` because quantiles sort lazily.
    pub fn to_ordered_json(&mut self) -> String {
        let mut counters = JsonObj::new();
        for (k, v) in &self.counters {
            counters = counters.field_u64(k, *v);
        }
        let mut sums = JsonObj::new();
        for (k, v) in &self.sums {
            sums = sums.field_f64(k, *v);
        }
        let mut hists = JsonObj::new();
        for (k, h) in &mut self.hists {
            let summary = JsonObj::new()
                .field_usize("count", h.count())
                .field_usize("non_finite", h.non_finite())
                .field_f64("mean_s", h.mean_s())
                .field_f64("p50_s", h.p50())
                .field_f64("p95_s", h.p95())
                .field_f64("p99_s", h.p99())
                .field_f64("min_s", h.min_s())
                .field_f64("max_s", h.max_s())
                .finish();
            hists = hists.field_raw(k, &summary);
        }
        JsonObj::new()
            .field_str("schema", METRICS_SCHEMA)
            .field_raw("counters", &counters.finish())
            .field_raw("sums", &sums.finish())
            .field_raw("histograms", &hists.finish())
            .finish()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::json::Value;

    #[test]
    fn registry_accumulates_and_reads_back() {
        let mut m = MetricsRegistry::new();
        m.counter_add("requests_total", 2);
        m.counter_add("requests_total", 3);
        m.sum_add("airtime_s", 0.5);
        m.sum_add("airtime_s", 0.25);
        m.hist_mut("latency_s").record(0.010);
        m.hist_mut("latency_s").record(0.030);
        assert_eq!(m.counter("requests_total"), 5);
        assert_eq!(m.counter("never_written"), 0);
        assert!((m.sum("airtime_s") - 0.75).abs() < 1e-12);
        assert_eq!(m.hist("latency_s").unwrap().count(), 2);
        assert!(m.hist("missing").is_none());
    }

    #[test]
    fn json_is_deterministic_and_parseable() {
        let build = || {
            let mut m = MetricsRegistry::new();
            m.counter_add("b_counter", 7);
            m.counter_add("a_counter", 1);
            m.sum_add("radio_wait_s", 0.125);
            let h = m.hist_mut("phase_network_s");
            for i in 1..=10 {
                h.record(i as f64 * 1e-3);
            }
            m.to_ordered_json()
        };
        let a = build();
        assert_eq!(a, build());
        let v = Value::parse(&a).unwrap();
        assert_eq!(v.str_at("schema").unwrap(), METRICS_SCHEMA);
        assert_eq!(v.get("counters").unwrap().usize_at("a_counter").unwrap(), 1);
        let h = v.get("histograms").unwrap().get("phase_network_s").unwrap();
        assert_eq!(h.usize_at("count").unwrap(), 10);
        assert!((h.f64_at("max_s").unwrap() - 0.010).abs() < 1e-12);
    }
}
