//! The crate's single quantile estimator: a streaming histogram with
//! bounded memory, moved here from `metrics.rs` so the serving report,
//! the metrics registry, and every experiment share one implementation
//! (and one set of NaN/total_cmp guarantees). There is exactly one
//! histogram type in the crate; the old `metrics::LatencyStats` alias is
//! gone.

/// Exact-sample cap: below this, quantiles are exact (sorted samples);
/// beyond it the stats spill into fixed log-scale buckets so million-
/// request runs hold a few KB instead of every sample.
const EXACT_MAX_SAMPLES: usize = 4096;

/// Log-scale bucket layout: bucket 0 starts at 1 µs, each bucket is 5%
/// wider than the last, covering up to ~10^6 s. Relative quantile error is
/// bounded by the bucket ratio (±2.5%).
const BUCKET_MIN_S: f64 = 1e-6;
const BUCKET_RATIO: f64 = 1.05;
const N_BUCKETS: usize = 568;

fn bucket_index(seconds: f64) -> usize {
    if seconds <= BUCKET_MIN_S {
        return 0;
    }
    let idx = (seconds / BUCKET_MIN_S).ln() / BUCKET_RATIO.ln();
    (idx as usize).min(N_BUCKETS - 1)
}

/// Streaming statistics with bounded memory: exact quantiles for small
/// runs (the benches), fixed log-scale buckets once the sample count
/// spills past [`EXACT_MAX_SAMPLES`] (million-request serving runs).
///
/// Non-finite samples (NaN, ±inf) are never folded into the quantiles:
/// they are counted separately ([`Histogram::non_finite`]) so a single
/// poisoned measurement can neither panic the sort nor skew the stats.
#[derive(Debug, Clone, Default)]
pub struct Histogram {
    samples_s: Vec<f64>,
    sorted: bool,
    /// engaged lazily on spill; `N_BUCKETS` counters, log-scale
    buckets: Option<Vec<u64>>,
    count: usize,
    non_finite: usize,
    sum_s: f64,
    min_s: f64,
    max_s: f64,
}

impl Histogram {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn record(&mut self, seconds: f64) {
        if !seconds.is_finite() {
            self.non_finite += 1;
            return;
        }
        if self.count == 0 {
            self.min_s = seconds;
            self.max_s = seconds;
        } else {
            self.min_s = self.min_s.min(seconds);
            self.max_s = self.max_s.max(seconds);
        }
        self.count += 1;
        self.sum_s += seconds;
        match &mut self.buckets {
            Some(buckets) => buckets[bucket_index(seconds)] += 1,
            None => {
                self.samples_s.push(seconds);
                self.sorted = false;
                if self.samples_s.len() > EXACT_MAX_SAMPLES {
                    let mut buckets = vec![0u64; N_BUCKETS];
                    for &s in &self.samples_s {
                        buckets[bucket_index(s)] += 1;
                    }
                    self.buckets = Some(buckets);
                    self.samples_s = Vec::new();
                }
            }
        }
    }

    pub fn count(&self) -> usize {
        self.count
    }

    /// Samples rejected by [`Histogram::record`] for being NaN or
    /// infinite (0 in a healthy run).
    pub fn non_finite(&self) -> usize {
        self.non_finite
    }

    pub fn mean_s(&self) -> f64 {
        if self.count == 0 {
            return 0.0;
        }
        self.sum_s / self.count as f64
    }

    /// Smallest recorded finite sample (0 when empty).
    pub fn min_s(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.min_s
        }
    }

    /// Largest recorded finite sample (0 when empty).
    pub fn max_s(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.max_s
        }
    }

    fn ensure_sorted(&mut self) {
        if !self.sorted {
            // total_cmp: NaN-safe total order (record filters non-finite
            // samples already; this can never panic regardless)
            self.samples_s.sort_by(f64::total_cmp);
            self.sorted = true;
        }
    }

    pub fn quantile(&mut self, q: f64) -> f64 {
        if self.count == 0 {
            return 0.0;
        }
        let target = ((self.count as f64 - 1.0) * q.clamp(0.0, 1.0)).round() as usize;
        match &self.buckets {
            None => {
                self.ensure_sorted();
                self.samples_s[target]
            }
            Some(buckets) => {
                let mut cum = 0usize;
                for (b, &n) in buckets.iter().enumerate() {
                    cum += n as usize;
                    if cum > target {
                        // geometric bucket midpoint, clamped to observed range
                        let mid = BUCKET_MIN_S * BUCKET_RATIO.powf(b as f64 + 0.5);
                        return mid.clamp(self.min_s, self.max_s);
                    }
                }
                self.max_s
            }
        }
    }

    pub fn p50(&mut self) -> f64 {
        self.quantile(0.50)
    }

    pub fn p95(&mut self) -> f64 {
        self.quantile(0.95)
    }

    pub fn p99(&mut self) -> f64 {
        self.quantile(0.99)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn latency_quantiles() {
        let mut s = Histogram::new();
        for i in 1..=100 {
            s.record(i as f64);
        }
        assert_eq!(s.count(), 100);
        assert!((s.mean_s() - 50.5).abs() < 1e-9);
        assert!((s.p50() - 50.0).abs() <= 1.0);
        assert!((s.p99() - 99.0).abs() <= 1.0);
        assert_eq!(s.min_s(), 1.0);
        assert_eq!(s.max_s(), 100.0);
    }

    #[test]
    fn empty_stats_are_zero() {
        let mut s = Histogram::new();
        assert_eq!(s.mean_s(), 0.0);
        assert_eq!(s.p95(), 0.0);
        assert_eq!(s.min_s(), 0.0);
        assert_eq!(s.max_s(), 0.0);
    }

    #[test]
    fn non_finite_samples_are_flagged_not_fatal() {
        let mut s = Histogram::new();
        s.record(f64::NAN);
        s.record(1.0);
        s.record(f64::INFINITY);
        s.record(f64::NEG_INFINITY);
        s.record(3.0);
        assert_eq!(s.count(), 2);
        assert_eq!(s.non_finite(), 3);
        assert!((s.mean_s() - 2.0).abs() < 1e-12);
        // the sort that used to panic on partial_cmp(NaN) is now safe
        assert_eq!(s.quantile(1.0), 3.0);
        assert_eq!(s.quantile(0.0), 1.0);
    }

    #[test]
    fn spills_to_bounded_buckets_with_accurate_quantiles() {
        let mut s = Histogram::new();
        let n = 200_000usize;
        for i in 0..n {
            // latencies spread over 1 ms .. 201 ms
            s.record(1e-3 + (i as f64 / n as f64) * 0.2);
        }
        assert_eq!(s.count(), n);
        // memory is bounded: the exact sample vec was dropped on spill
        assert!(s.samples_s.is_empty());
        assert_eq!(s.buckets.as_ref().map(Vec::len), Some(N_BUCKETS));
        assert!((s.mean_s() - 0.101).abs() < 1e-4);
        // bucketed quantiles land within the bucket ratio of the truth
        let p50 = s.p50();
        assert!((p50 - 0.101).abs() / 0.101 < 0.06, "p50 {p50}");
        let p99 = s.p99();
        assert!((p99 - 0.199).abs() / 0.199 < 0.06, "p99 {p99}");
    }

    #[test]
    fn bucketed_quantiles_respect_observed_range() {
        let mut s = Histogram::new();
        for _ in 0..(EXACT_MAX_SAMPLES + 10) {
            s.record(0.005);
        }
        // every sample identical: all quantiles collapse to it exactly
        // (bucket midpoint is clamped to [min, max])
        assert_eq!(s.p50(), 0.005);
        assert_eq!(s.p99(), 0.005);
        assert_eq!(s.count(), EXACT_MAX_SAMPLES + 10);
    }

    #[test]
    fn exact_path_unchanged_below_the_spill_threshold() {
        let mut s = Histogram::new();
        for i in (1..=1000).rev() {
            s.record(i as f64 * 1e-3);
        }
        assert!(s.buckets.is_none());
        assert!((s.p50() - 0.5).abs() <= 2e-3);
        assert!((s.quantile(1.0) - 1.0).abs() < 1e-12);
        assert!((s.quantile(0.0) - 1e-3).abs() < 1e-12);
    }
}
