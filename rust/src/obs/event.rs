//! Typed trace events: the request-lifecycle and fleet-level vocabulary
//! every sink receives. One `Copy` struct, no strings on the hot path.

/// Where an event happened: one Perfetto lane per device, server shard,
/// or the tuner's search loop.
///
/// The derived `Ord` (devices < servers < tuner, then index) is the lane
/// grouping used by the deterministic export sort.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum Lane {
    /// A device by fleet index.
    Device(u32),
    /// A server shard by index (0 for the single-server threaded path).
    Server(u32),
    /// The autotuner's evaluation loop (virtual time = evaluation index).
    Tuner,
}

impl Lane {
    /// Chrome-trace process id: devices, servers, and the tuner render as
    /// three processes so Perfetto groups their lanes.
    pub fn pid(&self) -> u64 {
        match self {
            Lane::Device(_) => 1,
            Lane::Server(_) => 2,
            Lane::Tuner => 3,
        }
    }

    /// Chrome-trace thread id within the process (the lane index).
    pub fn tid(&self) -> u64 {
        match self {
            Lane::Device(i) | Lane::Server(i) => *i as u64,
            Lane::Tuner => 0,
        }
    }

    /// Process label for trace metadata.
    pub fn group_name(&self) -> &'static str {
        match self {
            Lane::Device(_) => "devices",
            Lane::Server(_) => "servers",
            Lane::Tuner => "tuner",
        }
    }

    /// Thread label for trace metadata.
    pub fn label(&self) -> String {
        match self {
            Lane::Device(i) => format!("device {i}"),
            Lane::Server(i) => format!("server {i}"),
            Lane::Tuner => "search".to_string(),
        }
    }
}

/// The event vocabulary. Span kinds carry a duration; instant kinds mark
/// a point in time. The derived `Ord` is only used as a deterministic
/// tie-break in the export sort.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum EventKind {
    /// Instant: a request entered the device's schedule (priced arrival).
    Arrival,
    /// Span: device-side feature extractor + local NN + quantize/compress.
    Encode,
    /// Span: the encoded frame waiting for the device radio to free up.
    RadioWait,
    /// Span: one packet's airtime on the channel (value = payload bytes).
    Packet,
    /// Instant: a packet observed lost at its would-be arrival time.
    PacketLost,
    /// Instant: an ARQ retransmission round began (value = round number).
    RetransmitRound,
    /// Span: the whole uplink transfer (value = app bytes offered).
    Uplink,
    /// Instant, server lane: the placer routed a request to this shard
    /// (value = device index). Emitted by the event engine, where
    /// placement decisions exist.
    Placement,
    /// Span, server lane: a request sitting in the batch queue.
    ServerQueue,
    /// Instant, server lane: a batch fired (id = batch sequence number,
    /// value = batch size).
    BatchDispatch,
    /// Span: uplink-complete → batch-dispatch as seen by the device
    /// (queue wait + remote NN; `LatencyBreakdown::remote_s`).
    Remote,
    /// Span: the reply's downlink transfer back to the device.
    Downlink,
    /// Instant: the request finished on-device — fuse/impute done and the
    /// prediction emitted (value = 1 if the prediction was correct).
    Done,
    /// Instant, server lane: the autoscale controller activated this
    /// shard (value = active server count after the event).
    ScaleOut,
    /// Instant, server lane: the autoscale controller retired this shard
    /// after drain (value = active server count after the event).
    ScaleIn,
    /// Instant, device lane: the adaptive policy stepped its ladder —
    /// the request where the new operating point first applies (value =
    /// new quantizer width in bits, 0 for the local-only fallback).
    PolicySwitch,
    /// Span, tuner lane: one fresh configuration evaluation.
    TuneEval,
    /// Instant, tuner lane: an evaluation answered from the resume log.
    TuneCached,
    /// Instant, tuner lane: a configuration rejected as infeasible.
    TuneInfeasible,
}

impl EventKind {
    pub fn name(&self) -> &'static str {
        match self {
            EventKind::Arrival => "arrival",
            EventKind::Encode => "encode",
            EventKind::RadioWait => "radio_wait",
            EventKind::Packet => "packet",
            EventKind::PacketLost => "packet_lost",
            EventKind::RetransmitRound => "retransmit_round",
            EventKind::Uplink => "uplink",
            EventKind::Placement => "placement",
            EventKind::ServerQueue => "server_queue",
            EventKind::BatchDispatch => "batch_dispatch",
            EventKind::Remote => "remote",
            EventKind::Downlink => "downlink",
            EventKind::Done => "done",
            EventKind::ScaleOut => "scale_out",
            EventKind::ScaleIn => "scale_in",
            EventKind::PolicySwitch => "policy_switch",
            EventKind::TuneEval => "tune_eval",
            EventKind::TuneCached => "tune_cached",
            EventKind::TuneInfeasible => "tune_infeasible",
        }
    }

    /// True for kinds that carry a duration (Chrome "X" events); instants
    /// export as "i".
    pub fn is_span(&self) -> bool {
        matches!(
            self,
            EventKind::Encode
                | EventKind::RadioWait
                | EventKind::Packet
                | EventKind::Uplink
                | EventKind::ServerQueue
                | EventKind::Remote
                | EventKind::Downlink
                | EventKind::TuneEval
        )
    }
}

/// One trace event. Timestamps are the run's clock — virtual seconds
/// under `--clock sim` (bit-reproducible), host seconds since run start
/// under the wall clock (best effort).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct TraceEvent {
    pub lane: Lane,
    pub kind: EventKind,
    /// What the event is about: request id on device/server lanes, batch
    /// sequence for [`EventKind::BatchDispatch`], evaluation index on the
    /// tuner lane.
    pub id: u64,
    /// Start time, seconds on the run's clock.
    pub t_s: f64,
    /// Duration in seconds; 0 for instant kinds.
    pub dur_s: f64,
    /// Kind-specific payload (bytes, batch size, 0/1 correctness, …).
    pub value: f64,
}

impl TraceEvent {
    pub fn span(lane: Lane, kind: EventKind, id: u64, t0_s: f64, t1_s: f64, value: f64) -> Self {
        Self { lane, kind, id, t_s: t0_s, dur_s: t1_s - t0_s, value }
    }

    pub fn instant(lane: Lane, kind: EventKind, id: u64, t_s: f64, value: f64) -> Self {
        Self { lane, kind, id, t_s, dur_s: 0.0, value }
    }

    pub fn end_s(&self) -> f64 {
        self.t_s + self.dur_s
    }
}

/// The total, deterministic event order used by the exporter: time, then
/// lane, then kind, id, duration, value as tie-breaks. Two event sets
/// with the same members always serialize identically regardless of
/// recording order.
pub fn sort_events(events: &mut [TraceEvent]) {
    events.sort_by(|a, b| {
        a.t_s
            .total_cmp(&b.t_s)
            .then_with(|| a.lane.cmp(&b.lane))
            .then_with(|| a.kind.cmp(&b.kind))
            .then_with(|| a.id.cmp(&b.id))
            .then_with(|| a.dur_s.total_cmp(&b.dur_s))
            .then_with(|| a.value.total_cmp(&b.value))
    });
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn span_duration_and_end() {
        let e = TraceEvent::span(Lane::Device(3), EventKind::Uplink, 7, 1.0, 1.5, 128.0);
        assert_eq!(e.dur_s, 0.5);
        assert_eq!(e.end_s(), 1.5);
        assert!(e.kind.is_span());
        let i = TraceEvent::instant(Lane::Server(0), EventKind::BatchDispatch, 1, 2.0, 4.0);
        assert_eq!(i.dur_s, 0.0);
        assert!(!i.kind.is_span());
    }

    #[test]
    fn lanes_map_to_stable_pids() {
        assert_eq!(Lane::Device(9).pid(), 1);
        assert_eq!(Lane::Device(9).tid(), 9);
        assert_eq!(Lane::Server(2).pid(), 2);
        assert_eq!(Lane::Tuner.pid(), 3);
        assert!(Lane::Device(u32::MAX) < Lane::Server(0));
        assert!(Lane::Server(u32::MAX) < Lane::Tuner);
    }

    #[test]
    fn sort_is_total_and_deterministic() {
        let mk = |t, lane, id| TraceEvent::instant(lane, EventKind::Done, id, t, 0.0);
        let mut a = vec![
            mk(2.0, Lane::Device(1), 4),
            mk(1.0, Lane::Server(0), 2),
            mk(1.0, Lane::Device(0), 1),
            mk(1.0, Lane::Device(0), 0),
        ];
        let mut b = a.clone();
        b.reverse();
        sort_events(&mut a);
        sort_events(&mut b);
        assert_eq!(a, b);
        assert_eq!(a[0].id, 0);
        assert_eq!(a[1].id, 1);
        assert_eq!(a[2].lane, Lane::Server(0));
        assert_eq!(a[3].t_s, 2.0);
    }
}
