//! The sink contract: where trace events go. The serving stack emits
//! through a [`Tracer`] handle whose disabled default is a single branch
//! per would-be event — tracing off costs nothing measurable (guarded by
//! the `fleet_engine` perf gate, which runs with [`NoopSink`] attached).

use std::fmt;
use std::sync::{Arc, Mutex};

use super::event::TraceEvent;

/// Receives every emitted event. Implementations must be cheap and
/// non-blocking: the engine hot path calls this inline.
pub trait TraceSink: Send + Sync {
    fn record(&self, ev: TraceEvent);
}

/// Discards everything — the explicit "tracing off" sink. Attaching it
/// exercises the full emission path (event construction + one virtual
/// call per event) and is what the perf gate measures.
#[derive(Debug, Clone, Copy, Default)]
pub struct NoopSink;

impl TraceSink for NoopSink {
    #[inline]
    fn record(&self, _ev: TraceEvent) {}
}

/// Buffers every event in memory for later export. ~48 bytes per event:
/// a 1M-request fleet run records ~10 events per request, ≈500 MB.
#[derive(Debug, Default)]
pub struct RecordingSink {
    events: Mutex<Vec<TraceEvent>>,
}

impl RecordingSink {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn len(&self) -> usize {
        self.events.lock().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// A copy of the recorded events, in recording order.
    pub fn snapshot(&self) -> Vec<TraceEvent> {
        self.events.lock().unwrap().clone()
    }

    /// Drains the recorded events, leaving the sink empty.
    pub fn take(&self) -> Vec<TraceEvent> {
        std::mem::take(&mut *self.events.lock().unwrap())
    }
}

impl TraceSink for RecordingSink {
    fn record(&self, ev: TraceEvent) {
        self.events.lock().unwrap().push(ev);
    }
}

/// The handle the serving stack emits through. Cloned freely into device
/// and server loops; `Tracer::off()` (the default) holds no sink and
/// short-circuits every emission to one branch.
#[derive(Clone, Default)]
pub struct Tracer(Option<Arc<dyn TraceSink>>);

impl Tracer {
    /// The disabled tracer: no sink, emissions are a single branch.
    pub fn off() -> Self {
        Self(None)
    }

    pub fn new(sink: Arc<dyn TraceSink>) -> Self {
        Self(Some(sink))
    }

    pub fn enabled(&self) -> bool {
        self.0.is_some()
    }

    #[inline]
    pub fn emit(&self, ev: TraceEvent) {
        if let Some(sink) = &self.0 {
            sink.record(ev);
        }
    }

    /// Emit a span `[t0_s, t1_s]`; no-op when disabled.
    #[inline]
    pub fn span(
        &self,
        lane: super::Lane,
        kind: super::EventKind,
        id: u64,
        t0_s: f64,
        t1_s: f64,
        value: f64,
    ) {
        if self.0.is_some() {
            self.emit(TraceEvent::span(lane, kind, id, t0_s, t1_s, value));
        }
    }

    /// Emit an instant at `t_s`; no-op when disabled.
    #[inline]
    pub fn instant(
        &self,
        lane: super::Lane,
        kind: super::EventKind,
        id: u64,
        t_s: f64,
        value: f64,
    ) {
        if self.0.is_some() {
            self.emit(TraceEvent::instant(lane, kind, id, t_s, value));
        }
    }
}

impl fmt::Debug for Tracer {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(if self.enabled() { "Tracer(on)" } else { "Tracer(off)" })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::{EventKind, Lane};

    #[test]
    fn recording_sink_keeps_order_and_drains() {
        let sink = RecordingSink::new();
        let tracer = Tracer::new(Arc::new(NoopSink));
        assert!(tracer.enabled());
        tracer.instant(Lane::Tuner, EventKind::TuneCached, 0, 0.0, 0.0);

        let sink = Arc::new(sink);
        let t = Tracer::new(sink.clone());
        t.span(Lane::Device(0), EventKind::Encode, 1, 0.0, 1.0, 0.0);
        t.instant(Lane::Device(0), EventKind::Done, 1, 1.0, 1.0);
        assert_eq!(sink.len(), 2);
        let evs = sink.snapshot();
        assert_eq!(evs[0].kind, EventKind::Encode);
        assert_eq!(evs[1].kind, EventKind::Done);
        assert_eq!(sink.take().len(), 2);
        assert!(sink.is_empty());
    }

    #[test]
    fn disabled_tracer_emits_nothing() {
        let t = Tracer::off();
        assert!(!t.enabled());
        // no sink to observe — this just must not panic
        t.span(Lane::Server(0), EventKind::ServerQueue, 0, 0.0, 1.0, 0.0);
        assert_eq!(format!("{t:?}"), "Tracer(off)");
    }
}
