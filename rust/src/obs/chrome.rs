//! Chrome-trace-event (Perfetto-ready) JSON export.
//!
//! Output is the JSON-array flavor of the Chrome trace format: metadata
//! ("M") events naming one process per lane group (devices / servers /
//! tuner) and one thread per lane, then "X" complete events for spans and
//! "i" instants, timestamps in microseconds. Load the file directly in
//! `ui.perfetto.dev` or `chrome://tracing`.
//!
//! Emission goes through the insertion-ordered [`JsonObj`] writer with
//! shortest-roundtrip floats, and events are first put into the total
//! order of [`sort_events`] — so under `--clock sim` the exported bytes
//! are a pure function of the run configuration: bitwise-reproducible
//! across invocations and invariant to recording order.

use std::collections::{BTreeMap, BTreeSet};

use super::event::{sort_events, Lane, TraceEvent};
use crate::report::{json_array, JsonObj};

/// Seconds → Chrome-trace microseconds.
fn us(seconds: f64) -> f64 {
    seconds * 1e6
}

fn metadata(name: &str, pid: u64, tid: u64, label: &str) -> String {
    JsonObj::new()
        .field_str("name", name)
        .field_str("ph", "M")
        .field_f64("ts", 0.0)
        .field_u64("pid", pid)
        .field_u64("tid", tid)
        .field_raw("args", &JsonObj::new().field_str("name", label).finish())
        .finish()
}

/// Serialize events as a Chrome trace JSON array (one line, no trailing
/// newline). The input slice is not required to be ordered.
pub fn chrome_trace_json(events: &[TraceEvent]) -> String {
    let mut evs = events.to_vec();
    sort_events(&mut evs);

    let lanes: BTreeSet<Lane> = evs.iter().map(|e| e.lane).collect();
    let mut pids: BTreeMap<u64, &'static str> = BTreeMap::new();
    for lane in &lanes {
        pids.insert(lane.pid(), lane.group_name());
    }

    let mut items = Vec::with_capacity(evs.len() + lanes.len() + pids.len());
    for (pid, group) in &pids {
        items.push(metadata("process_name", *pid, 0, group));
    }
    for lane in &lanes {
        items.push(metadata("thread_name", lane.pid(), lane.tid(), &lane.label()));
    }
    for e in &evs {
        let args = JsonObj::new().field_u64("id", e.id).field_f64("value", e.value).finish();
        let mut obj = JsonObj::new()
            .field_str("name", e.kind.name())
            .field_str("ph", if e.kind.is_span() { "X" } else { "i" })
            .field_f64("ts", us(e.t_s));
        if e.kind.is_span() {
            obj = obj.field_f64("dur", us(e.dur_s));
        } else {
            // instant scope: thread
            obj = obj.field_str("s", "t");
        }
        items.push(
            obj.field_u64("pid", e.lane.pid())
                .field_u64("tid", e.lane.tid())
                .field_raw("args", &args)
                .finish(),
        );
    }
    json_array(items)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::json::Value;
    use crate::obs::EventKind;

    fn sample_events() -> Vec<TraceEvent> {
        vec![
            TraceEvent::instant(Lane::Device(0), EventKind::Arrival, 0, 0.0, 0.0),
            TraceEvent::span(Lane::Device(0), EventKind::Encode, 0, 0.0, 0.25e-3, 0.0),
            TraceEvent::span(Lane::Server(1), EventKind::ServerQueue, 0, 0.5e-3, 1.5e-3, 0.0),
            TraceEvent::instant(Lane::Server(1), EventKind::BatchDispatch, 1, 1.5e-3, 4.0),
            TraceEvent::instant(Lane::Device(0), EventKind::Done, 0, 2.0e-3, 1.0),
        ]
    }

    #[test]
    fn export_shape_is_chrome_trace() {
        let text = chrome_trace_json(&sample_events());
        let v = Value::parse(&text).unwrap();
        let arr = v.as_arr().unwrap();
        // 2 process_name + 2 thread_name + 5 events
        assert_eq!(arr.len(), 9);
        for item in arr {
            assert!(item.get("ph").is_ok());
            assert!(item.get("ts").is_ok());
            assert!(item.get("pid").is_ok());
            assert!(item.get("tid").is_ok());
        }
        assert_eq!(arr[0].str_at("ph").unwrap(), "M");
        assert_eq!(arr[0].str_at("name").unwrap(), "process_name");
        // the encode span exports in microseconds
        let encode = arr
            .iter()
            .find(|i| i.str_at("name").map(|n| n == "encode").unwrap_or(false))
            .unwrap();
        assert_eq!(encode.str_at("ph").unwrap(), "X");
        assert!((encode.f64_at("dur").unwrap() - 250.0).abs() < 1e-9);
        let done =
            arr.iter().find(|i| i.str_at("name").map(|n| n == "done").unwrap_or(false)).unwrap();
        assert_eq!(done.str_at("ph").unwrap(), "i");
        assert_eq!(done.str_at("s").unwrap(), "t");
    }

    #[test]
    fn export_is_invariant_to_recording_order() {
        let evs = sample_events();
        let mut rev = evs.clone();
        rev.reverse();
        assert_eq!(chrome_trace_json(&evs), chrome_trace_json(&rev));
    }

    #[test]
    fn empty_input_is_an_empty_array() {
        assert_eq!(chrome_trace_json(&[]), "[]");
    }
}
