//! `agilenn::obs` — structured observability for the serving stack.
//!
//! Three pieces, threaded through `serve::engine`, `serve::service`,
//! `net`, and `tune`:
//!
//! 1. **Event tracing** ([`TraceSink`] / [`Tracer`]): typed
//!    request-lifecycle spans (arrival → encode → radio wait → per-packet
//!    uplink → server queue → batch dispatch → remote NN → downlink →
//!    done) plus fleet-level events (placement decisions, retransmission
//!    rounds, tuner evaluations), stamped with the run's clock. The
//!    disabled default ([`Tracer::off`]) costs one branch per would-be
//!    event; [`RecordingSink`] buffers everything for export.
//! 2. **Chrome/Perfetto export** ([`chrome_trace_json`]): device, server,
//!    and tuner lanes in virtual time, bitwise-reproducible under
//!    `--clock sim` (`serve --trace-out`, `tune --trace-out`).
//! 3. **Metrics** ([`MetricsRegistry`] over the unified [`Histogram`]):
//!    named counters + log-bucketed histograms; `PipelineReport` is a
//!    field-for-field-compatible view over the registry, and per-phase
//!    latency breakdowns surface via `serve --metrics-out` and
//!    `bench --figure breakdown`.
//!
//! See `docs/observability.md` for the event taxonomy, schemas, and the
//! Perfetto how-to.

pub mod chrome;
pub mod event;
pub mod hist;
pub mod registry;
pub mod sink;

pub use chrome::chrome_trace_json;
pub use event::{sort_events, EventKind, Lane, TraceEvent};
pub use hist::Histogram;
pub use registry::{MetricsRegistry, METRICS_SCHEMA};
pub use sink::{NoopSink, RecordingSink, TraceSink, Tracer};
