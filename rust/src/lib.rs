//! # AgileNN reproduction — serving library
//!
//! Reproduction of *"Real-time Neural Network Inference on Extremely Weak
//! Devices: Agile Offloading with Explainable AI"* (Huang & Gao, MobiCom '22)
//! as a three-layer Rust + JAX + Pallas stack:
//!
//! * **L1 (Pallas, build time)** — the feature-extractor conv and the
//!   Integrated-Gradients accumulation kernels (`python/compile/kernels/`).
//! * **L2 (JAX, build time)** — model graphs + XAI-driven joint training
//!   with skewness manipulation (`python/compile/`), AOT-lowered to HLO
//!   text.
//! * **L3 (this crate, run time)** — the serving coordinator: device
//!   runtime simulator, learned quantization + LZW transmit path, a lossy
//!   trace-driven channel with importance-ordered anytime transport
//!   ([`net`]), dynamic remote batching, alpha-weighted prediction fusion,
//!   baseline schemes, a pluggable serving clock ([`serve::clock`]: wall
//!   time or seed-deterministic discrete-event virtual time), a
//!   single-threaded discrete-event fleet engine ([`serve::engine`]:
//!   million-request multi-server sweeps with pluggable device→server
//!   placement), a resumable serving autotuner ([`tune`]: exhaustive or
//!   seeded-genetic search over the serving knobs, Pareto-ranked with the
//!   fleet engine as its evaluator), a structured observability layer
//!   ([`obs`]: request-lifecycle tracing with Perfetto export and a
//!   unified metrics registry), a CI perf-regression gate
//!   ([`perfgate`]), and the bench harness regenerating every
//!   figure/table in the paper's evaluation.
//!   Python is never on the request path.
//!
//! Inference is pluggable ([`runtime::Backend`]): the PJRT backend (cargo
//! feature `pjrt`) executes the real AOT artifacts, while the pure-Rust
//! [`runtime::ReferenceBackend`] plus the synthetic world in [`fixtures`]
//! run the identical serving stack with no artifacts and no native
//! dependencies — `ServeBuilder::backend(BackendKind::Reference)` or
//! `agilenn serve --backend reference`. See `docs/backends.md`.
//!
//! ## Quick start
//!
//! The serving surface is [`serve::ServeBuilder`]: pick a dataset, any of
//! the five schemes (AgileNN, DeepCOD, SPINN, MCUNet, edge-only), a device
//! count and an arrival process, and run the deadline-batched multi-device
//! pipeline. Per-request outcomes stream out as they complete:
//!
//! ```no_run
//! use agilenn::config::Scheme;
//! use agilenn::serve::ServeBuilder;
//!
//! let service = ServeBuilder::new("svhns")
//!     .scheme(Scheme::Agile)   // or Deepcod / Spinn / Mcunet / EdgeOnly
//!     .fleet(|f| { f.devices = 4; f.requests = 256; })
//!     .rate_hz(30.0)           // Poisson arrivals per device
//!     .build()
//!     .unwrap();
//!
//! let mut outcomes = service.stream().unwrap();
//! for out in outcomes.by_ref() {
//!     println!("request {} -> class {} in {} ms (device {})",
//!              out.id, out.outcome.predicted, out.wall_s * 1e3, out.device);
//! }
//! let report = outcomes.finish().unwrap();
//! println!("{:.1} req/s at {:.1}% accuracy, mean batch {:.2}",
//!          report.throughput_rps, report.accuracy * 100.0, report.mean_batch_size);
//! ```
//!
//! For synchronous single-request evaluation with exact simulated-time
//! accounting (the per-figure benches), use [`baselines::make_runner`],
//! which composes the same device/server halves without the thread fabric.

pub mod baselines;
pub mod bench;
pub mod compression;
pub mod config;
pub mod coordinator;
pub mod experiments;
pub mod fixtures;
pub mod json;
pub mod metrics;
pub mod net;
pub mod obs;
pub mod perfgate;
pub mod report;
pub mod runtime;
pub mod serve;
pub mod simulator;
pub mod tensor;
pub mod tune;
pub mod workload;
pub mod xai;
