//! # AgileNN reproduction — serving library
//!
//! Reproduction of *"Real-time Neural Network Inference on Extremely Weak
//! Devices: Agile Offloading with Explainable AI"* (Huang & Gao, MobiCom '22)
//! as a three-layer Rust + JAX + Pallas stack:
//!
//! * **L1 (Pallas, build time)** — the feature-extractor conv and the
//!   Integrated-Gradients accumulation kernels (`python/compile/kernels/`).
//! * **L2 (JAX, build time)** — model graphs + XAI-driven joint training
//!   with skewness manipulation (`python/compile/`), AOT-lowered to HLO
//!   text.
//! * **L3 (this crate, run time)** — the serving coordinator: device
//!   runtime simulator, learned quantization + LZW transmit path, dynamic
//!   remote batching, alpha-weighted prediction fusion, baseline schemes,
//!   and the bench harness regenerating every figure/table in the paper's
//!   evaluation. Python is never on the request path.
//!
//! ## Quick start
//!
//! ```no_run
//! use agilenn::config::{RunConfig, Scheme, default_artifacts_dir, Meta};
//! use agilenn::runtime::Engine;
//! use agilenn::baselines::{make_runner, SchemeRunner};
//! use agilenn::workload::TestSet;
//!
//! let cfg = RunConfig::new(default_artifacts_dir(), "svhns", Scheme::Agile);
//! let meta = Meta::load(&cfg.dataset_dir()).unwrap();
//! let testset = TestSet::load(&cfg.dataset_dir().join("test.bin")).unwrap();
//! let engine = Engine::cpu().unwrap();
//! let mut runner = make_runner(&engine, &cfg, &meta).unwrap();
//! let out = runner.process(&testset.image(0).unwrap(), testset.labels[0]).unwrap();
//! println!("pred={} correct={} latency={:.2}ms",
//!          out.predicted, out.correct, out.breakdown.total_s() * 1e3);
//! ```

pub mod baselines;
pub mod bench;
pub mod compression;
pub mod config;
pub mod coordinator;
pub mod experiments;
pub mod json;
pub mod metrics;
pub mod report;
pub mod runtime;
pub mod simulator;
pub mod tensor;
pub mod workload;
pub mod xai;
