//! Inference runtime: pluggable [`Backend`]s executing the exported model
//! components on [`crate::tensor::Tensor`] batches.
//!
//! * [`Backend`] / [`Module`] — the abstraction every serving layer codes
//!   against: load a component by artifact stem, run it. See
//!   `docs/backends.md` for the contract.
//! * [`ReferenceBackend`] — pure-Rust, seeded, deterministic model family
//!   honoring the full export contract. No artifacts, no native deps:
//!   the entire serving pipeline is testable anywhere.
//! * [`PjrtBackend`] / [`Engine`] (cargo feature `pjrt`) — loads
//!   AOT-compiled HLO-text artifacts and executes them on the CPU PJRT
//!   client; the only place the `xla` crate is touched. Pattern (from
//!   /opt/xla-example/load_hlo): HLO text ->
//!   `HloModuleProto::from_text_file` -> `XlaComputation::from_proto` ->
//!   `client.compile` -> `execute`. Text is the interchange format
//!   because xla_extension 0.5.1 rejects jax>=0.5's 64-bit-id serialized
//!   protos.
//!
//! [`make_backend`] maps a [`crate::config::RunConfig`]'s
//! [`BackendKind`](crate::config::BackendKind) onto an instance.

mod backend;
#[cfg(feature = "pjrt")]
mod engine;
pub mod once_map;
mod reference;

pub use backend::{make_backend, pjrt_backend, Backend, Module};
#[cfg(feature = "pjrt")]
pub use engine::{Engine, Executable, PjrtBackend};
pub use once_map::OnceMap;
pub use reference::{
    channel_sign, walsh_sign, ReferenceBackend, DEEPCOD_CODE_CHANNELS, FEATURE_GAIN, LOGIT_GAIN,
    SPINN_EXIT_LOGIT_GAIN, SPINN_FEATURE_CHANNELS,
};
