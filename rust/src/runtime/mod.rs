//! PJRT runtime: loads AOT-compiled HLO-text artifacts and executes them on
//! the CPU PJRT client. This is the only place the `xla` crate is touched.
//!
//! Pattern (from /opt/xla-example/load_hlo): HLO text ->
//! `HloModuleProto::from_text_file` -> `XlaComputation::from_proto` ->
//! `client.compile` -> `execute`. Text is the interchange format because
//! xla_extension 0.5.1 rejects jax>=0.5's 64-bit-id serialized protos.

mod engine;

pub use engine::{Engine, Executable};
