//! Single-flight keyed cache: at most one caller runs the initializer for
//! any key; concurrent callers for the *same* key block on that one
//! computation, callers for *other* keys proceed independently.
//!
//! This is the executable-cache substrate for [`super::Engine`]
//! (feature `pjrt`): the old double-checked `Mutex<HashMap>` pattern let
//! two threads that both missed the cache each compile the same HLO
//! artifact — wasted work and, for large modules, seconds of duplicated
//! XLA compilation at startup. Here a per-key slot mutex is held across
//! the initializer, so compilation happens exactly once per key while
//! different artifacts still compile concurrently.

use std::collections::HashMap;
use std::sync::{Arc, Mutex};

type Slot<V> = Arc<Mutex<Option<V>>>;

/// Single-flight map from string keys to clonable values.
#[derive(Debug, Default)]
pub struct OnceMap<V> {
    slots: Mutex<HashMap<String, Slot<V>>>,
}

impl<V: Clone> OnceMap<V> {
    pub fn new() -> Self {
        Self { slots: Mutex::new(HashMap::new()) }
    }

    /// Get the cached value for `key`, or run `init` to produce it.
    ///
    /// Exactly one caller runs `init` per key; others block until it
    /// finishes and then clone the result. If `init` fails the slot stays
    /// empty and the error is returned — the next caller retries.
    pub fn get_or_try_init<E>(
        &self,
        key: &str,
        init: impl FnOnce() -> Result<V, E>,
    ) -> Result<V, E> {
        // take (or create) this key's slot under the map lock, then drop
        // the map lock before initializing: other keys stay unblocked
        let slot: Slot<V> = {
            let mut slots = self.slots.lock().unwrap();
            slots.entry(key.to_string()).or_default().clone()
        };
        // recover from poisoning: a panicking initializer leaves the slot
        // at None (the value is only written after init succeeds), so the
        // next caller must retry, not inherit the panic
        let mut guard = slot.lock().unwrap_or_else(std::sync::PoisonError::into_inner);
        if let Some(v) = guard.as_ref() {
            return Ok(v.clone());
        }
        // the slot lock is held across init: single flight per key
        let v = init()?;
        *guard = Some(v.clone());
        Ok(v)
    }

    /// Number of keys whose value has been successfully initialized.
    /// Keys whose initializer is still in flight (or failed) don't count.
    pub fn filled(&self) -> usize {
        self.slots
            .lock()
            .unwrap()
            .values()
            .filter(|s| match s.try_lock() {
                Ok(g) => g.is_some(),
                Err(std::sync::TryLockError::Poisoned(p)) => p.into_inner().is_some(),
                Err(std::sync::TryLockError::WouldBlock) => false,
            })
            .count()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn caches_and_returns_the_first_value() {
        let m: OnceMap<u32> = OnceMap::new();
        let v = m.get_or_try_init("a", || Ok::<_, ()>(7)).unwrap();
        assert_eq!(v, 7);
        // the second initializer never runs
        let v = m.get_or_try_init("a", || Ok::<_, ()>(99)).unwrap();
        assert_eq!(v, 7);
        assert_eq!(m.filled(), 1);
    }

    #[test]
    fn failed_init_leaves_the_slot_retryable() {
        let m: OnceMap<u32> = OnceMap::new();
        assert!(m.get_or_try_init("a", || Err::<u32, &str>("boom")).is_err());
        assert_eq!(m.filled(), 0);
        let v = m.get_or_try_init("a", || Ok::<_, &str>(3)).unwrap();
        assert_eq!(v, 3);
        assert_eq!(m.filled(), 1);
    }

    #[test]
    fn panicking_init_leaves_the_slot_retryable() {
        // the pre-OnceMap cache compiled outside any lock, so a panicking
        // first load left it clean; a poisoned slot must not regress that
        let m = Arc::new(OnceMap::<u32>::new());
        let mc = m.clone();
        let panicked = std::panic::catch_unwind(std::panic::AssertUnwindSafe(move || {
            mc.get_or_try_init("a", || -> Result<u32, ()> { panic!("init blew up") })
        }));
        assert!(panicked.is_err());
        assert_eq!(m.filled(), 0);
        let v = m.get_or_try_init("a", || Ok::<_, ()>(5)).unwrap();
        assert_eq!(v, 5);
        assert_eq!(m.filled(), 1);
    }

    #[test]
    fn concurrent_same_key_initializes_exactly_once() {
        // regression for the Engine::load duplicate-compilation race: N
        // threads race the same key; the initializer must run once
        let m = Arc::new(OnceMap::<u32>::new());
        let calls = Arc::new(AtomicUsize::new(0));
        let barrier = Arc::new(std::sync::Barrier::new(8));
        let handles: Vec<_> = (0..8)
            .map(|_| {
                let (m, calls, barrier) = (m.clone(), calls.clone(), barrier.clone());
                std::thread::spawn(move || {
                    barrier.wait();
                    m.get_or_try_init("shared", || {
                        calls.fetch_add(1, Ordering::SeqCst);
                        // widen the race window the old code lost in
                        std::thread::sleep(std::time::Duration::from_millis(10));
                        Ok::<_, ()>(42)
                    })
                    .unwrap()
                })
            })
            .collect();
        for h in handles {
            assert_eq!(h.join().unwrap(), 42);
        }
        assert_eq!(calls.load(Ordering::SeqCst), 1, "initializer must run once per key");
        assert_eq!(m.filled(), 1);
    }

    #[test]
    fn distinct_keys_do_not_serialize() {
        // a slow init on one key must not block another key: thread B
        // finishes while thread A's initializer is still sleeping
        let m = Arc::new(OnceMap::<u32>::new());
        let entered = Arc::new(std::sync::Barrier::new(2));
        let ma = m.clone();
        let ea = entered.clone();
        let a = std::thread::spawn(move || {
            ma.get_or_try_init("slow", || {
                ea.wait(); // b is about to start
                std::thread::sleep(std::time::Duration::from_millis(50));
                Ok::<_, ()>(1)
            })
            .unwrap()
        });
        entered.wait();
        let t0 = std::time::Instant::now();
        let v = m.get_or_try_init("fast", || Ok::<_, ()>(2)).unwrap();
        assert_eq!(v, 2);
        assert!(t0.elapsed() < std::time::Duration::from_millis(40), "fast key blocked on slow key");
        assert_eq!(a.join().unwrap(), 1);
    }
}
