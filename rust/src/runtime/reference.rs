//! `ReferenceBackend` — a pure-Rust, deterministic model family honoring
//! the full AOT export contract, so the entire serving stack (device
//! halves, quantize/LZW transmit path, lossy channel, batched server,
//! fusion) runs and is testable with **no artifacts and no PJRT**.
//!
//! ## The reference family
//!
//! Inputs come from [`crate::fixtures`]: a synthetic image of class `y` is
//! a block-constant brightness pattern `0.5 + amp * P_y[cell]` (plus small
//! per-sample jitter), where `P_y ∈ {±1}^{fh·fw}` is the class's Walsh
//! pattern ([`walsh_sign`]). Distinct classes have exactly orthogonal
//! patterns over a power-of-two cell grid, so every head below recovers
//! the class with a wide, deterministic margin — and degrades gracefully
//! (never catastrophically) as transmitted features are quantized, lost,
//! or imputed.
//!
//! Every module first recovers the per-cell signal `d[cell] =
//! block_mean - 0.5 ≈ amp * P_y[cell]`, then:
//!
//! * **classifier heads** (`agile_device` logits, `mcunet_local`,
//!   `edge_remote`, SPINN's exit head) score class `c` as
//!   `gain · ⟨P_c, d⟩ / cells` — maximal at `c = y`;
//! * **feature extractors** (`agile_device` remote features,
//!   `deepcod_device` code, `spinn_device` features) emit the post-ReLU
//!   map `F[cell, j] = relu(d[cell] · s_j) · FEATURE_GAIN` with
//!   alternating channel signs `s_j` — mirroring the paper's skew: about
//!   half the transmitted values are exactly zero (maximally
//!   LZW-compressible), and the imputation reference symbol (codeword
//!   nearest 0.0) *is* the true resting value of a missing feature;
//! * **remote heads** (`agile_remote_b*`, `deepcod_remote_b*`,
//!   `spinn_remote_b*`) invert the extractor per row —
//!   `w[cell] = Σ_j s_j · F[cell, j]` has the sign of `d[cell]` — and
//!   score classes from `w`. Rows are computed independently, so padded
//!   batches are bitwise consistent with batch-1 execution at every
//!   exported size.
//!
//! SPINN's early exit: fixture samples alternate between a strong
//! (`EXIT_AMPLITUDE`) and a weak (`STAY_AMPLITUDE`) pattern amplitude;
//! the exit head's confidence crosses the exported 0.9 threshold exactly
//! for the strong half, giving a deterministic ~50% exit rate.
//!
//! The family accepts exactly the stems the python export writes
//! (`{agile,deepcod,spinn}_device_b1`, `mcunet_local_b1`,
//! `{agile,deepcod,spinn}_remote_b{1,2,4,8}`, `edge_remote_b{1,4}`) and
//! rejects everything else, so backend wiring bugs surface as errors, not
//! silently-wrong numerics.

use super::backend::{Backend, Module};
use crate::config::Meta;
use crate::coordinator::batcher::{EDGE_BATCH_SIZES, REMOTE_BATCH_SIZES};
use crate::tensor::Tensor;
use anyhow::{anyhow, ensure, Result};
use std::path::Path;
use std::sync::Arc;

/// DeepCOD's learned-code channel count (matches the export contract the
/// server half assumes).
pub const DEEPCOD_CODE_CHANNELS: usize = 12;
/// SPINN's split-point feature channel count (ditto).
pub const SPINN_FEATURE_CHANNELS: usize = 32;

/// Scale of active (post-ReLU) feature values. With fixture amplitudes in
/// [0.18, 0.36], active features land in ~[0.3, 0.8]: well inside the
/// [0, 1] codebooks, distinguishable from the 0.0 resting level even at
/// 1-bit quantization of strong samples.
pub const FEATURE_GAIN: f32 = 2.0;
/// Classifier logit scale: true-class logits of `gain * amp` (≈ 1.4–2.9)
/// against near-zero off-class logits — confident but not saturating.
pub const LOGIT_GAIN: f32 = 8.0;
/// SPINN exit-head logit scale, tuned so max softmax confidence clears
/// 0.9 at `EXIT_AMPLITUDE` (logit 7.2 → conf ≈ 0.99) and stays below it
/// at `STAY_AMPLITUDE` (logit 3.6 → conf ≈ 0.80).
pub const SPINN_EXIT_LOGIT_GAIN: f32 = 20.0;

/// Class pattern bit: the Walsh function with mask `class + 1` evaluated
/// at `cell`. Over a power-of-two number of cells, distinct classes give
/// exactly orthogonal ±1 patterns. Shared with [`crate::fixtures`], which
/// paints these patterns into the synthetic images.
pub fn walsh_sign(class: usize, cell: usize) -> f32 {
    if ((cell as u64) & (class as u64 + 1)).count_ones() % 2 == 0 {
        1.0
    } else {
        -1.0
    }
}

/// Alternating per-channel sign of the reference feature extractors.
pub fn channel_sign(j: usize) -> f32 {
    if j % 2 == 0 {
        1.0
    } else {
        -1.0
    }
}

/// Geometry shared by every module of one reference model instance.
#[derive(Debug, Clone)]
pub(crate) struct ReferenceModel {
    num_classes: usize,
    image: [usize; 3],
    feature: [usize; 3],
    k: usize,
}

impl ReferenceModel {
    /// Per-cell signal `d[cell] = block_mean - 0.5` of one `[1,h,w,c]`
    /// image, block-averaged down to the `fh × fw` feature grid.
    fn block_signal(&self, img: &[f32]) -> Result<Vec<f32>> {
        let [h, w, c] = self.image;
        let [fh, fw, _] = self.feature;
        ensure!(
            h % fh == 0 && w % fw == 0,
            "image {h}x{w} not divisible into the {fh}x{fw} feature grid"
        );
        let (bh, bw) = (h / fh, w / fw);
        let mut sums = vec![0.0f64; fh * fw];
        for yy in 0..h {
            for xx in 0..w {
                let cell = (yy / bh) * fw + xx / bw;
                for ch in 0..c {
                    sums[cell] += img[(yy * w + xx) * c + ch] as f64;
                }
            }
        }
        let per = (bh * bw * c) as f64;
        Ok(sums.iter().map(|s| (s / per - 0.5) as f32).collect())
    }

    /// Score every class against the recovered signal: `gain · ⟨P_c, d⟩ /
    /// cells`.
    fn class_scores(&self, d: &[f32], gain: f32) -> Vec<f32> {
        let cells = d.len() as f32;
        (0..self.num_classes)
            .map(|cl| {
                let mut s = 0.0f32;
                for (cell, &dv) in d.iter().enumerate() {
                    s += walsh_sign(cl, cell) * dv;
                }
                gain * s / cells
            })
            .collect()
    }

    /// Post-ReLU feature map `F[cell, j] = relu(d[cell]·s_j) ·
    /// FEATURE_GAIN`, laid out `(h, w, channels)` row-major like the real
    /// artifacts.
    fn feature_map(&self, d: &[f32], channels: usize) -> Vec<f32> {
        let mut out = Vec::with_capacity(d.len() * channels);
        for &dv in d {
            for j in 0..channels {
                out.push((dv * channel_sign(j)).max(0.0) * FEATURE_GAIN);
            }
        }
        out
    }

    /// Invert [`ReferenceModel::feature_map`] per cell: `w[cell] = Σ_j
    /// s_j · F[cell, j]` carries the sign (and scale) of `d[cell]`.
    fn recovered_signal(feats: &[f32], channels: usize) -> Vec<f32> {
        feats
            .chunks_exact(channels)
            .map(|cell| {
                let mut s = 0.0f32;
                for (j, &f) in cell.iter().enumerate() {
                    s += channel_sign(j) * f;
                }
                s
            })
            .collect()
    }

    fn remote_channels(&self) -> Result<usize> {
        ensure!(
            self.k < self.feature[2],
            "top-k split k={} must leave remote channels of {} total",
            self.k,
            self.feature[2]
        );
        Ok(self.feature[2] - self.k)
    }
}

/// Which exported component a stem names.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Family {
    AgileDevice,
    AgileRemote,
    DeepcodDevice,
    DeepcodRemote,
    SpinnDevice,
    SpinnRemote,
    McunetLocal,
    EdgeRemote,
}

impl Family {
    fn parse(name: &str) -> Option<Family> {
        Some(match name {
            "agile_device" => Family::AgileDevice,
            "agile_remote" => Family::AgileRemote,
            "deepcod_device" => Family::DeepcodDevice,
            "deepcod_remote" => Family::DeepcodRemote,
            "spinn_device" => Family::SpinnDevice,
            "spinn_remote" => Family::SpinnRemote,
            "mcunet_local" => Family::McunetLocal,
            "edge_remote" => Family::EdgeRemote,
            _ => return None,
        })
    }

    /// Batch sizes the python export compiles for this component.
    fn exported_batches(&self) -> &'static [usize] {
        match self {
            Family::AgileDevice
            | Family::DeepcodDevice
            | Family::SpinnDevice
            | Family::McunetLocal => &[1],
            Family::EdgeRemote => &EDGE_BATCH_SIZES,
            Family::AgileRemote | Family::DeepcodRemote | Family::SpinnRemote => {
                &REMOTE_BATCH_SIZES
            }
        }
    }
}

/// `<family>_b<batch>` — the artifact stem grammar.
fn parse_stem(stem: &str) -> Option<(Family, usize)> {
    let (name, b) = stem.rsplit_once("_b")?;
    let batch: usize = b.parse().ok()?;
    Some((Family::parse(name)?, batch))
}

/// The pure-Rust reference backend. Cheap to construct; modules share the
/// geometry through an [`Arc`], so cloning across device threads is free.
pub struct ReferenceBackend {
    model: Arc<ReferenceModel>,
}

impl ReferenceBackend {
    /// Parameterize the family from trained (or synthetic) metadata: only
    /// the geometry — class count, image/feature dims, top-k split — is
    /// read, so any [`Meta`] works, artifacts or not.
    pub fn from_meta(meta: &Meta) -> Self {
        Self {
            model: Arc::new(ReferenceModel {
                num_classes: meta.num_classes,
                image: meta.image,
                feature: meta.feature,
                k: meta.k,
            }),
        }
    }
}

impl Backend for ReferenceBackend {
    fn name(&self) -> &'static str {
        "reference"
    }

    fn load_module(&self, _dir: &Path, stem: &str) -> Result<Arc<dyn Module>> {
        let (family, batch) = parse_stem(stem).ok_or_else(|| {
            anyhow!("reference backend has no model family for artifact stem {stem:?}")
        })?;
        ensure!(
            family.exported_batches().contains(&batch),
            "{stem:?}: batch size {batch} is not exported for this component \
             (exported: {:?})",
            family.exported_batches()
        );
        Ok(Arc::new(ReferenceModule {
            model: self.model.clone(),
            family,
            batch,
            stem: stem.to_string(),
        }) as Arc<dyn Module>)
    }
}

/// One loaded reference component.
struct ReferenceModule {
    model: Arc<ReferenceModel>,
    family: Family,
    batch: usize,
    stem: String,
}

impl ReferenceModule {
    fn check_input<'a>(&self, inputs: &'a [Tensor], shape: &[usize]) -> Result<&'a Tensor> {
        ensure!(
            inputs.len() == 1,
            "{}: expected 1 input tensor, got {}",
            self.stem,
            inputs.len()
        );
        ensure!(
            inputs[0].shape() == shape,
            "{}: input shape {:?} does not match compiled shape {:?}",
            self.stem,
            inputs[0].shape(),
            shape
        );
        Ok(&inputs[0])
    }

    /// Run a per-row remote head: features `[b, fh, fw, ch]` → logits
    /// `[b, num_classes]`.
    fn remote_head(&self, inputs: &[Tensor], channels: usize) -> Result<Vec<Tensor>> {
        let m = &self.model;
        let [fh, fw, _] = m.feature;
        let input = self.check_input(inputs, &[self.batch, fh, fw, channels])?;
        let per_row = fh * fw * channels;
        let mut logits = Vec::with_capacity(self.batch * m.num_classes);
        for row in input.data().chunks_exact(per_row) {
            let w = ReferenceModel::recovered_signal(row, channels);
            logits.extend(m.class_scores(&w, 1.0));
        }
        Ok(vec![Tensor::new(vec![self.batch, m.num_classes], logits)?])
    }
}

impl Module for ReferenceModule {
    fn name(&self) -> &str {
        &self.stem
    }

    fn run(&self, inputs: &[Tensor]) -> Result<Vec<Tensor>> {
        let m = &self.model;
        let [h, w, c] = m.image;
        let [fh, fw, _] = m.feature;
        match self.family {
            Family::AgileDevice => {
                let img = self.check_input(inputs, &[1, h, w, c])?;
                let d = m.block_signal(img.data())?;
                let rem = m.remote_channels()?;
                let logits = Tensor::new(vec![1, m.num_classes], m.class_scores(&d, LOGIT_GAIN))?;
                let feats = Tensor::new(vec![1, fh, fw, rem], m.feature_map(&d, rem))?;
                Ok(vec![logits, feats])
            }
            Family::DeepcodDevice => {
                let img = self.check_input(inputs, &[1, h, w, c])?;
                let d = m.block_signal(img.data())?;
                let code = m.feature_map(&d, DEEPCOD_CODE_CHANNELS);
                Ok(vec![Tensor::new(vec![1, fh, fw, DEEPCOD_CODE_CHANNELS], code)?])
            }
            Family::SpinnDevice => {
                let img = self.check_input(inputs, &[1, h, w, c])?;
                let d = m.block_signal(img.data())?;
                let feats = Tensor::new(
                    vec![1, fh, fw, SPINN_FEATURE_CHANNELS],
                    m.feature_map(&d, SPINN_FEATURE_CHANNELS),
                )?;
                let exit = Tensor::new(
                    vec![1, m.num_classes],
                    m.class_scores(&d, SPINN_EXIT_LOGIT_GAIN),
                )?;
                Ok(vec![feats, exit])
            }
            Family::McunetLocal => {
                let img = self.check_input(inputs, &[1, h, w, c])?;
                let d = m.block_signal(img.data())?;
                Ok(vec![Tensor::new(vec![1, m.num_classes], m.class_scores(&d, LOGIT_GAIN))?])
            }
            Family::EdgeRemote => {
                let input = self.check_input(inputs, &[self.batch, h, w, c])?;
                let mut logits = Vec::with_capacity(self.batch * m.num_classes);
                for row in input.data().chunks_exact(h * w * c) {
                    let d = m.block_signal(row)?;
                    logits.extend(m.class_scores(&d, LOGIT_GAIN));
                }
                Ok(vec![Tensor::new(vec![self.batch, m.num_classes], logits)?])
            }
            Family::AgileRemote => self.remote_head(inputs, m.remote_channels()?),
            Family::DeepcodRemote => self.remote_head(inputs, DEEPCOD_CODE_CHANNELS),
            Family::SpinnRemote => self.remote_head(inputs, SPINN_FEATURE_CHANNELS),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::json::Value;
    use std::path::PathBuf;

    fn backend() -> ReferenceBackend {
        let meta =
            Meta::from_json(&Value::parse(crate::config::tests::MINIMAL_META).unwrap()).unwrap();
        ReferenceBackend::from_meta(&meta)
    }

    fn pattern_image(class: usize, amp: f32) -> Tensor {
        // block-constant 32x32x3 image of the class pattern on an 8x8 grid
        let (h, w, c, fw, bh, bw) = (32, 32, 3, 8, 4, 4);
        let mut data = Vec::with_capacity(h * w * c);
        for yy in 0..h {
            for xx in 0..w {
                let cell = (yy / bh) * fw + xx / bw;
                for _ in 0..c {
                    data.push(0.5 + amp * walsh_sign(class, cell));
                }
            }
        }
        Tensor::new(vec![1, h, w, c], data).unwrap()
    }

    #[test]
    fn walsh_patterns_are_orthogonal_and_distinct() {
        let cells = 64;
        for a in 0..10 {
            for b in 0..10 {
                let dot: f32 = (0..cells).map(|uv| walsh_sign(a, uv) * walsh_sign(b, uv)).sum();
                if a == b {
                    assert_eq!(dot, cells as f32);
                } else {
                    assert_eq!(dot, 0.0, "classes {a},{b} not orthogonal");
                }
            }
        }
    }

    #[test]
    fn stem_grammar_accepts_the_export_contract_only() {
        let b = backend();
        let dir = PathBuf::from("/nonexistent");
        for stem in [
            "agile_device_b1",
            "deepcod_device_b1",
            "spinn_device_b1",
            "mcunet_local_b1",
            "agile_remote_b1",
            "agile_remote_b2",
            "agile_remote_b4",
            "agile_remote_b8",
            "deepcod_remote_b8",
            "spinn_remote_b4",
            "edge_remote_b1",
            "edge_remote_b4",
        ] {
            assert!(b.load_module(&dir, stem).is_ok(), "{stem} must load");
        }
        for stem in [
            "agile_device_b2",  // device halves export batch 1 only
            "edge_remote_b8",   // edge exports {1,4} only
            "agile_remote_b3",  // not an exported batch size
            "agile_remote",     // no batch suffix
            "unknown_thing_b1", // unknown family
        ] {
            assert!(b.load_module(&dir, stem).is_err(), "{stem} must be rejected");
        }
    }

    #[test]
    fn device_head_recovers_the_class_with_margin() {
        let b = backend();
        let module = b.load_module(&PathBuf::from("/x"), "agile_device_b1").unwrap();
        for class in 0..10 {
            let out = module.run(&[pattern_image(class, 0.3)]).unwrap();
            assert_eq!(out.len(), 2);
            assert_eq!(out[0].shape(), &[1, 10]);
            assert_eq!(out[1].shape(), &[1, 8, 8, 19]);
            assert_eq!(crate::tensor::argmax(out[0].data()), class);
            // orthogonal patterns: off-class logits vanish (up to f32
            // accumulation error)
            for (cl, &v) in out[0].data().iter().enumerate() {
                if cl != class {
                    assert!(v.abs() < 1e-4, "off-class logit {v} for class {cl}");
                }
            }
        }
    }

    #[test]
    fn remote_head_inverts_the_extractor() {
        let b = backend();
        let dev = b.load_module(&PathBuf::from("/x"), "agile_device_b1").unwrap();
        let rem = b.load_module(&PathBuf::from("/x"), "agile_remote_b1").unwrap();
        let class = 7;
        let feats = dev.run(&[pattern_image(class, 0.3)]).unwrap().remove(1);
        let logits = rem.run(&[feats]).unwrap().remove(0);
        assert_eq!(logits.shape(), &[1, 10]);
        assert_eq!(crate::tensor::argmax(logits.data()), class);
    }

    #[test]
    fn batched_rows_match_batch1_bitwise() {
        let b = backend();
        let dev = b.load_module(&PathBuf::from("/x"), "agile_device_b1").unwrap();
        let r1 = b.load_module(&PathBuf::from("/x"), "agile_remote_b1").unwrap();
        let r4 = b.load_module(&PathBuf::from("/x"), "agile_remote_b4").unwrap();
        let feats: Vec<Tensor> = (0..3)
            .map(|cl| dev.run(&[pattern_image(cl, 0.3)]).unwrap().remove(1))
            .collect();
        let singles: Vec<Vec<f32>> =
            feats.iter().map(|f| r1.run(std::slice::from_ref(f)).unwrap()[0].data().to_vec()).collect();
        let batch = Tensor::stack_padded(&feats, 4).unwrap();
        let batched = r4.run(&[batch]).unwrap().remove(0);
        for (i, single) in singles.iter().enumerate() {
            assert_eq!(batched.row(i).unwrap(), single.as_slice(), "row {i} diverged");
        }
    }

    #[test]
    fn spinn_exit_confidence_splits_on_amplitude() {
        let b = backend();
        let spinn = b.load_module(&PathBuf::from("/x"), "spinn_device_b1").unwrap();
        let strong = spinn.run(&[pattern_image(3, 0.36)]).unwrap();
        let weak = spinn.run(&[pattern_image(3, 0.18)]).unwrap();
        assert_eq!(strong[0].shape(), &[1, 8, 8, 32]);
        let conf_strong = crate::tensor::max_confidence(strong[1].data());
        let conf_weak = crate::tensor::max_confidence(weak[1].data());
        assert!(conf_strong >= 0.9, "strong sample must exit: conf {conf_strong}");
        assert!(conf_weak < 0.9, "weak sample must offload: conf {conf_weak}");
        assert_eq!(crate::tensor::argmax(weak[1].data()), 3);
    }

    #[test]
    fn features_are_skewed_toward_zero() {
        // the paper's skew manipulation: roughly half the transmitted
        // feature values sit exactly at the 0.0 reference level
        let b = backend();
        let dev = b.load_module(&PathBuf::from("/x"), "agile_device_b1").unwrap();
        let feats = dev.run(&[pattern_image(2, 0.3)]).unwrap().remove(1);
        let zeros = feats.data().iter().filter(|&&v| v == 0.0).count();
        let frac = zeros as f64 / feats.len() as f64;
        assert!(frac > 0.3 && frac < 0.7, "zero fraction {frac}");
    }

    #[test]
    fn wrong_shape_is_rejected() {
        let b = backend();
        let dev = b.load_module(&PathBuf::from("/x"), "agile_device_b1").unwrap();
        let bad = Tensor::zeros(vec![1, 16, 16, 3]);
        assert!(dev.run(&[bad]).is_err());
    }
}
