//! The pluggable inference-backend abstraction.
//!
//! Everything the serving stack needs from "a compiled NN" is captured by
//! two object-safe traits:
//!
//! * [`Module`] — one loaded model component (a fixed-shape compiled
//!   function): run it on f32 [`Tensor`] inputs, get the decomposed output
//!   tuple back. Mirrors the AOT export contract exactly (unit-batch
//!   device modules, fixed-batch remote heads).
//! * [`Backend`] — a factory of modules, addressed the way the artifact
//!   tree is: a dataset directory plus a file stem like `agile_device_b1`
//!   or `deepcod_remote_b4`.
//!
//! Two implementations exist:
//!
//! * [`PjrtBackend`](super::PjrtBackend) (feature `pjrt`) wraps the PJRT
//!   [`Engine`](super::Engine): real AOT-compiled HLO artifacts, real
//!   numerics, needs `make artifacts` and the vendored xla toolchain.
//! * [`ReferenceBackend`](super::ReferenceBackend) — a pure-Rust, seeded,
//!   deterministic model family honoring the same export contract
//!   (stems, shapes, batch sizes, skewed feature split, SPINN early-exit
//!   logits). No artifacts, no native deps: the whole serving pipeline is
//!   testable on any machine. See `docs/backends.md`.
//!
//! [`make_backend`] is the only dispatch point: it maps
//! [`RunConfig::backend`] to a shared backend instance.

use crate::config::{BackendKind, Meta, RunConfig};
use crate::tensor::Tensor;
use anyhow::Result;
use std::path::Path;
use std::sync::Arc;

/// One loaded model component (the unit the serving stack executes).
pub trait Module: Send + Sync {
    /// Identity for error messages: the artifact stem (PJRT) or the
    /// reference family name.
    fn name(&self) -> &str;

    /// Execute on f32 tensors; returns the decomposed output tuple.
    fn run(&self, inputs: &[Tensor]) -> Result<Vec<Tensor>>;
}

/// A factory of [`Module`]s, addressed like the artifact tree.
pub trait Backend: Send + Sync {
    fn name(&self) -> &'static str;

    /// Load the model component exported as `<stem>` for the dataset
    /// rooted at `dir`. Backends may cache internally; loads of the same
    /// `(dir, stem)` must return behaviorally identical modules.
    fn load_module(&self, dir: &Path, stem: &str) -> Result<Arc<dyn Module>>;
}

/// Instantiate the backend a [`RunConfig`] asks for. `meta` parameterizes
/// the reference model family (class count, feature geometry, top-k
/// split); the PJRT backend ignores it.
pub fn make_backend(cfg: &RunConfig, meta: &Meta) -> Result<Arc<dyn Backend>> {
    match cfg.backend {
        BackendKind::Reference => {
            Ok(Arc::new(super::ReferenceBackend::from_meta(meta)) as Arc<dyn Backend>)
        }
        BackendKind::Pjrt => pjrt_backend(),
    }
}

/// The PJRT backend on the CPU client (or a clear error when this build
/// carries no PJRT support).
#[cfg(feature = "pjrt")]
pub fn pjrt_backend() -> Result<Arc<dyn Backend>> {
    Ok(Arc::new(super::PjrtBackend::cpu()?) as Arc<dyn Backend>)
}

/// The PJRT backend on the CPU client (or a clear error when this build
/// carries no PJRT support).
#[cfg(not(feature = "pjrt"))]
pub fn pjrt_backend() -> Result<Arc<dyn Backend>> {
    anyhow::bail!(
        "PJRT backend unavailable: this binary was built without the `pjrt` cargo \
         feature (which needs the vendored xla toolchain). Use the reference \
         backend instead (`--backend reference` / `BackendKind::Reference`), or \
         rebuild with `cargo build --features pjrt`"
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Scheme;
    use crate::json::Value;

    fn minimal_meta() -> Meta {
        Meta::from_json(&Value::parse(crate::config::tests::MINIMAL_META).unwrap()).unwrap()
    }

    #[test]
    fn reference_kind_resolves_without_artifacts_or_pjrt() {
        let mut cfg = RunConfig::new("/nonexistent", "t", Scheme::Agile);
        cfg.backend = BackendKind::Reference;
        let b = make_backend(&cfg, &minimal_meta()).unwrap();
        assert_eq!(b.name(), "reference");
    }

    #[cfg(not(feature = "pjrt"))]
    #[test]
    fn pjrt_kind_errors_clearly_without_the_feature() {
        let cfg = RunConfig::new("/nonexistent", "t", Scheme::Agile);
        assert_eq!(cfg.backend, BackendKind::Pjrt);
        let err = make_backend(&cfg, &minimal_meta()).unwrap_err();
        assert!(err.to_string().contains("pjrt"), "{err}");
    }
}
