//! PJRT engine: executable cache + tensor <-> literal marshalling.
//! Compiled only with the `pjrt` cargo feature (the vendored xla tree).

use super::backend::{Backend, Module};
use super::once_map::OnceMap;
use crate::tensor::Tensor;
use anyhow::{anyhow, ensure, Context, Result};
use std::path::Path;
use std::sync::Arc;

/// A compiled HLO module ready to execute.
pub struct Executable {
    exe: xla::PjRtLoadedExecutable,
    name: String,
}

impl Executable {
    /// Execute with f32 tensors; returns the decomposed output tuple.
    ///
    /// All our AOT exports lower with `return_tuple=True`, so the single
    /// result literal is always a tuple (possibly of one element).
    pub fn run(&self, inputs: &[Tensor]) -> Result<Vec<Tensor>> {
        let literals: Vec<xla::Literal> = inputs
            .iter()
            .map(tensor_to_literal)
            .collect::<Result<_>>()
            .with_context(|| format!("marshalling inputs for {}", self.name))?;
        let result = self
            .exe
            .execute::<xla::Literal>(&literals)
            .with_context(|| format!("executing {}", self.name))?;
        ensure!(
            !result.is_empty() && !result[0].is_empty(),
            "empty result from {}",
            self.name
        );
        let lit = result[0][0]
            .to_literal_sync()
            .with_context(|| format!("fetching result of {}", self.name))?;
        let parts = lit.to_tuple().with_context(|| format!("untupling result of {}", self.name))?;
        parts.iter().map(literal_to_tensor).collect()
    }
}

impl Module for Executable {
    fn name(&self) -> &str {
        &self.name
    }

    fn run(&self, inputs: &[Tensor]) -> Result<Vec<Tensor>> {
        Executable::run(self, inputs)
    }
}

fn tensor_to_literal(t: &Tensor) -> Result<xla::Literal> {
    let dims: Vec<usize> = t.shape().to_vec();
    let bytes: &[u8] = unsafe {
        std::slice::from_raw_parts(t.data().as_ptr() as *const u8, t.data().len() * 4)
    };
    xla::Literal::create_from_shape_and_untyped_data(xla::ElementType::F32, &dims, bytes)
        .map_err(|e| anyhow!("literal creation failed: {e}"))
}

fn literal_to_tensor(lit: &xla::Literal) -> Result<Tensor> {
    let shape = lit.array_shape().map_err(|e| anyhow!("result shape: {e}"))?;
    let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
    let data: Vec<f32> = lit.to_vec().map_err(|e| anyhow!("result data: {e}"))?;
    Tensor::new(dims, data)
}

/// PJRT client + compiled-executable cache, shared across the coordinator.
///
/// Compilation happens once per artifact at startup/first use (AOT spirit:
/// the request path only executes). The cache is keyed by file stem and is
/// single-flight ([`OnceMap`]): two threads that miss on the same key no
/// longer both compile it — one compiles while the other waits, and
/// different keys still compile concurrently.
pub struct Engine {
    client: xla::PjRtClient,
    cache: OnceMap<Arc<Executable>>,
}

impl Engine {
    pub fn cpu() -> Result<Self> {
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("PJRT CPU client: {e}"))?;
        Ok(Self { client, cache: OnceMap::new() })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load + compile an HLO-text artifact (cached by `key`; compiled at
    /// most once per key even under concurrent first loads).
    pub fn load(&self, key: &str, path: &Path) -> Result<Arc<Executable>> {
        self.cache.get_or_try_init(key, || {
            let proto = xla::HloModuleProto::from_text_file(path)
                .map_err(|e| anyhow!("parsing HLO text {}: {e}", path.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self
                .client
                .compile(&comp)
                .map_err(|e| anyhow!("compiling {}: {e}", path.display()))?;
            Ok(Arc::new(Executable { exe, name: key.to_string() }))
        })
    }

    /// Convenience: load `<dir>/<stem>.hlo.txt`, keyed by the full path so
    /// identically-named artifacts from different datasets never collide in
    /// the cache.
    pub fn load_artifact(&self, dir: &Path, stem: &str) -> Result<Arc<Executable>> {
        let path = dir.join(format!("{stem}.hlo.txt"));
        let key = path.to_string_lossy().into_owned();
        self.load(&key, &path)
    }

    pub fn cached_count(&self) -> usize {
        self.cache.filled()
    }
}

/// The PJRT [`Backend`]: real AOT artifacts on the CPU PJRT client.
pub struct PjrtBackend {
    engine: Engine,
}

impl PjrtBackend {
    pub fn cpu() -> Result<Self> {
        Ok(Self { engine: Engine::cpu()? })
    }

    /// The wrapped engine (platform queries, cache introspection).
    pub fn engine(&self) -> &Engine {
        &self.engine
    }
}

impl Backend for PjrtBackend {
    fn name(&self) -> &'static str {
        "pjrt"
    }

    fn load_module(&self, dir: &Path, stem: &str) -> Result<Arc<dyn Module>> {
        let exe: Arc<dyn Module> = self.engine.load_artifact(dir, stem)?;
        Ok(exe)
    }
}

// PJRT CPU client and loaded executables are thread-safe to invoke.
unsafe impl Send for Engine {}
unsafe impl Sync for Engine {}
unsafe impl Send for Executable {}
unsafe impl Sync for Executable {}
