//! Serving metrics: latency breakdown, energy ledger, accuracy counter.
//!
//! The streaming quantile estimator formerly defined here lives in
//! [`crate::obs::hist`] as the crate-wide [`crate::obs::Histogram`] — the
//! single histogram implementation shared by the serving report, the
//! metrics registry, and the experiments.

/// Per-request latency breakdown (paper §7.2's four components).
#[derive(Debug, Clone, Copy, Default)]
pub struct LatencyBreakdown {
    /// device NN compute (feature extractor + local NN), seconds
    pub local_nn_s: f64,
    /// device-side quantize + LZW compress
    pub compression_s: f64,
    /// uplink + downlink transfer (+ simulated radio queueing under load)
    pub network_s: f64,
    /// server decompress + remote NN (+ batch queueing). Wall-measured
    /// under the wall clock; pure virtual queueing time — and therefore
    /// seed-deterministic — under the sim clock.
    pub remote_s: f64,
}

impl LatencyBreakdown {
    pub fn total_s(&self) -> f64 {
        self.local_nn_s + self.compression_s + self.network_s + self.remote_s
    }
}

/// Energy ledger for the device (Fig 19: compute + radio terms).
#[derive(Debug, Clone, Copy, Default)]
pub struct EnergyLedger {
    pub compute_j: f64,
    pub radio_j: f64,
}

impl EnergyLedger {
    pub fn total_j(&self) -> f64 {
        self.compute_j + self.radio_j
    }

    pub fn total_mj(&self) -> f64 {
        self.total_j() * 1e3
    }

    pub fn add(&mut self, other: &EnergyLedger) {
        self.compute_j += other.compute_j;
        self.radio_j += other.radio_j;
    }
}

/// Aggregate accuracy counter.
#[derive(Debug, Clone, Copy, Default)]
pub struct AccuracyCounter {
    pub correct: usize,
    pub total: usize,
}

impl AccuracyCounter {
    pub fn record(&mut self, correct: bool) {
        self.total += 1;
        if correct {
            self.correct += 1;
        }
    }

    pub fn accuracy(&self) -> f64 {
        if self.total == 0 {
            0.0
        } else {
            self.correct as f64 / self.total as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn breakdown_total() {
        let b = LatencyBreakdown {
            local_nn_s: 0.01,
            compression_s: 0.002,
            network_s: 0.005,
            remote_s: 0.003,
        };
        assert!((b.total_s() - 0.02).abs() < 1e-12);
    }

    #[test]
    fn energy_ledger_accumulates() {
        let mut e = EnergyLedger::default();
        e.add(&EnergyLedger { compute_j: 0.001, radio_j: 0.002 });
        e.add(&EnergyLedger { compute_j: 0.001, radio_j: 0.0 });
        assert!((e.total_mj() - 4.0).abs() < 1e-9);
    }

    #[test]
    fn accuracy_counter() {
        let mut a = AccuracyCounter::default();
        a.record(true);
        a.record(false);
        a.record(true);
        assert!((a.accuracy() - 2.0 / 3.0).abs() < 1e-12);
    }
}
