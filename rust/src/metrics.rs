//! Serving metrics: latency histograms, energy ledger, throughput counters.


/// Streaming latency statistics with exact quantiles (stores samples;
/// request counts here are small enough that this is the simplest correct
/// thing — benches run thousands, not billions, of requests).
#[derive(Debug, Clone, Default)]
pub struct LatencyStats {
    samples_s: Vec<f64>,
    sorted: bool,
}

impl LatencyStats {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn record(&mut self, seconds: f64) {
        self.samples_s.push(seconds);
        self.sorted = false;
    }

    pub fn count(&self) -> usize {
        self.samples_s.len()
    }

    pub fn mean_s(&self) -> f64 {
        if self.samples_s.is_empty() {
            return 0.0;
        }
        self.samples_s.iter().sum::<f64>() / self.samples_s.len() as f64
    }

    fn ensure_sorted(&mut self) {
        if !self.sorted {
            self.samples_s.sort_by(|a, b| a.partial_cmp(b).unwrap());
            self.sorted = true;
        }
    }

    pub fn quantile(&mut self, q: f64) -> f64 {
        if self.samples_s.is_empty() {
            return 0.0;
        }
        self.ensure_sorted();
        let idx = ((self.samples_s.len() as f64 - 1.0) * q.clamp(0.0, 1.0)).round() as usize;
        self.samples_s[idx]
    }

    pub fn p50(&mut self) -> f64 {
        self.quantile(0.50)
    }

    pub fn p95(&mut self) -> f64 {
        self.quantile(0.95)
    }

    pub fn p99(&mut self) -> f64 {
        self.quantile(0.99)
    }
}

/// Per-request latency breakdown (paper §7.2's four components).
#[derive(Debug, Clone, Copy, Default)]
pub struct LatencyBreakdown {
    /// device NN compute (feature extractor + local NN), seconds
    pub local_nn_s: f64,
    /// device-side quantize + LZW compress
    pub compression_s: f64,
    /// uplink + downlink transfer
    pub network_s: f64,
    /// server decompress + remote NN (+ batch queueing)
    pub remote_s: f64,
}

impl LatencyBreakdown {
    pub fn total_s(&self) -> f64 {
        self.local_nn_s + self.compression_s + self.network_s + self.remote_s
    }
}

/// Energy ledger for the device (Fig 19: compute + radio terms).
#[derive(Debug, Clone, Copy, Default)]
pub struct EnergyLedger {
    pub compute_j: f64,
    pub radio_j: f64,
}

impl EnergyLedger {
    pub fn total_j(&self) -> f64 {
        self.compute_j + self.radio_j
    }

    pub fn total_mj(&self) -> f64 {
        self.total_j() * 1e3
    }

    pub fn add(&mut self, other: &EnergyLedger) {
        self.compute_j += other.compute_j;
        self.radio_j += other.radio_j;
    }
}

/// Aggregate accuracy counter.
#[derive(Debug, Clone, Copy, Default)]
pub struct AccuracyCounter {
    pub correct: usize,
    pub total: usize,
}

impl AccuracyCounter {
    pub fn record(&mut self, correct: bool) {
        self.total += 1;
        if correct {
            self.correct += 1;
        }
    }

    pub fn accuracy(&self) -> f64 {
        if self.total == 0 {
            0.0
        } else {
            self.correct as f64 / self.total as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn latency_quantiles() {
        let mut s = LatencyStats::new();
        for i in 1..=100 {
            s.record(i as f64);
        }
        assert_eq!(s.count(), 100);
        assert!((s.mean_s() - 50.5).abs() < 1e-9);
        assert!((s.p50() - 50.0).abs() <= 1.0);
        assert!((s.p99() - 99.0).abs() <= 1.0);
    }

    #[test]
    fn empty_stats_are_zero() {
        let mut s = LatencyStats::new();
        assert_eq!(s.mean_s(), 0.0);
        assert_eq!(s.p95(), 0.0);
    }

    #[test]
    fn breakdown_total() {
        let b = LatencyBreakdown {
            local_nn_s: 0.01,
            compression_s: 0.002,
            network_s: 0.005,
            remote_s: 0.003,
        };
        assert!((b.total_s() - 0.02).abs() < 1e-12);
    }

    #[test]
    fn energy_ledger_accumulates() {
        let mut e = EnergyLedger::default();
        e.add(&EnergyLedger { compute_j: 0.001, radio_j: 0.002 });
        e.add(&EnergyLedger { compute_j: 0.001, radio_j: 0.0 });
        assert!((e.total_mj() - 4.0).abs() < 1e-9);
    }

    #[test]
    fn accuracy_counter() {
        let mut a = AccuracyCounter::default();
        a.record(true);
        a.record(false);
        a.record(true);
        assert!((a.accuracy() - 2.0 / 3.0).abs() < 1e-12);
    }
}
