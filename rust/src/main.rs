//! AgileNN CLI: serve (multi-device batched pipeline, any scheme; with
//! `--listen`, a real TCP serving daemon), device (a device client for a
//! remote daemon), infer (single request, verbose), bench (regenerate a
//! paper figure/table), tune (Pareto autotuner over the serving knobs),
//! report (summary).
//!
//! Argument parsing is hand-rolled (`Args` below) — the build environment
//! vendors only the xla dependency tree.

use agilenn::baselines::SchemeRunner;
use agilenn::config::{default_artifacts_dir, BackendKind, Manifest, Meta, RunConfig, Scheme};
use agilenn::experiments::{all_ids, run_figure, EvalCtx};
use agilenn::net::{BandwidthTrace, DeliveryPolicy, GilbertElliott, PacketOrder};
use agilenn::obs::{chrome_trace_json, RecordingSink, Tracer};
use agilenn::perfgate;
use agilenn::report::{ms, pct};
use agilenn::runtime::make_backend;
use agilenn::serve::{
    send_shutdown, AutoscaleConfig, ClockKind, Daemon, Placement, PolicyConfig, ServeBuilder,
    SimEngine,
};
use agilenn::tune::{self, EvalSpec, SearchSpace, StrategyKind, TuneConfig};
use agilenn::workload::Arrival;
use anyhow::{bail, Result};
use std::path::PathBuf;
use std::sync::Arc;

/// Tiny `--flag [value]` parser. A flag followed by another `--flag` (or by
/// nothing) is valueless and stores `"true"`, so boolean switches like
/// `--quiet` compose with later flags instead of swallowing them.
struct Args {
    cmd: String,
    flags: std::collections::HashMap<String, String>,
}

impl Args {
    fn parse() -> Result<Self> {
        Self::from_iter(std::env::args().skip(1))
    }

    fn from_iter(args: impl IntoIterator<Item = String>) -> Result<Self> {
        let mut it = args.into_iter().peekable();
        let cmd = it.next().unwrap_or_else(|| "help".into());
        let mut flags = std::collections::HashMap::new();
        while let Some(a) = it.next() {
            let key = a
                .strip_prefix("--")
                .ok_or_else(|| anyhow::anyhow!("expected --flag, got {a:?}"))?
                .to_string();
            let val = match it.peek() {
                Some(v) if !v.starts_with("--") => it.next().unwrap(),
                _ => "true".into(),
            };
            flags.insert(key, val);
        }
        Ok(Self { cmd, flags })
    }

    fn get<T: std::str::FromStr>(&self, key: &str, default: T) -> Result<T>
    where
        T::Err: std::fmt::Display,
    {
        match self.flags.get(key) {
            Some(v) => v.parse().map_err(|e| anyhow::anyhow!("--{key} {v:?}: {e}")),
            None => Ok(default),
        }
    }

    fn get_str(&self, key: &str, default: &str) -> String {
        self.flags.get(key).cloned().unwrap_or_else(|| default.to_string())
    }

    fn get_opt_f64(&self, key: &str) -> Result<Option<f64>> {
        match self.flags.get(key) {
            Some(v) => Ok(Some(v.parse()?)),
            None => Ok(None),
        }
    }
}

const HELP: &str = "\
agilenn — AgileNN (MobiCom '22) serving coordinator

USAGE: agilenn <command> [--flag value ...]

COMMANDS:
  serve    run the multi-device batched serving pipeline (any scheme)
             --dataset svhns --scheme agile|deepcod|spinn|mcunet|edge
             --backend pjrt|reference
                                 (pjrt: AOT artifacts, needs `make
                                 artifacts` and a pjrt-enabled build;
                                 reference: pure-Rust deterministic model
                                 family + synthetic dataset — no
                                 artifacts needed at all)
             --devices 4 --requests 256 --rate-hz 30
             --clock wall|sim    (sim: discrete-event virtual time — no
                                 sleeps, seed-deterministic latencies;
                                 runs on the single-threaded fleet
                                 engine, so 1M+-request sweeps take
                                 seconds)
             --servers 1         remote servers, each with its own batch
                                 queue (needs --clock sim)
             --placement static|rr|least|weighted
                                 device->server placement policy
                                 (weighted: least normalized load, i.e.
                                 outstanding/capacity)
             --sim-engine event|threads
                                 sim execution engine (threads: the
                                 legacy fabric, bitwise-equivalent)
             --arrival-seed 42   base seed for per-device Poisson arrivals
             --diurnal P,BASE,PEAK
                                 diurnal arrivals: raised-cosine rate from
                                 BASE to PEAK Hz per device over a P-second
                                 period (e.g. --diurnal 60,0.4,4)
           virtual service time + SLO autoscaling (needs --clock sim on
           the event engine):
             --service-base-us 500   per-batch service-time floor
             --service-per-sample-us 100  added service time per sample
             --capacities 4,1,1  per-server speed weights (scale service
                                 time down; pad/truncate to the fleet)
             --autoscale MIN,MAX hand fleet sizing to the SLO controller
                                 (active servers stay in [MIN,MAX];
                                 --servers is the initial size)
             --slo-queue-ms 20   queue-wait p95 the controller defends
             --scale-window-s 2 --scale-interval-s 0.5
             --scale-cooldown-s 2 --scale-sustain 2
                                 controller observation window, decision
                                 cadence, post-action cooldown, and how
                                 many consecutive breaching ticks arm an
                                 action
             --slo-p99-ms 50     end-to-end p99 target; the report gains
                                 slo_attainment against it
             --max-batch 8 --deadline-us 2000 --bits 4 [--alpha 0.3]
           per-request adaptive split/rate policy (quantizing schemes;
           see docs/policy.md — policy-off runs stay bit-identical):
             --policy            arm the adaptive policy: each device
                                 picks quantizer width / delivery /
                                 local-only per request from an EWMA of
                                 its link stats + the server's advertised
                                 queue depth
             --policy-widths 1,2,4   candidate quantizer widths (each
                                 must have an exported codebook)
             --policy-sustain 2  consecutive bad/good observations that
                                 arm a ladder step
             --policy-cooldown 8 decisions to hold after a switch
             --policy-local-fallback
                                 allow the local-only rung (skip the
                                 uplink entirely; agile/spinn only)
             --quiet   (suppress streaming per-request progress)
             --json    (print the report as deterministic JSON)
             --trace-out FILE    write a Chrome/Perfetto trace of every
                                 request lifecycle (open in ui.perfetto.dev;
                                 bitwise-reproducible under --clock sim)
             --metrics-out FILE  write the unified metrics registry
                                 (counters + per-phase latency histograms)
                                 as deterministic JSON
           channel (default: ideal link; all stochastic behavior is
           deterministic in --net-seed):
             --loss 0.3          packet-loss rate
             --burst 4           mean loss-burst length (Gilbert-Elliott)
             --delivery arq|anytime   uplink transport policy
             --net-deadline-ms 5 anytime decode deadline
             --order importance|index anytime packet ordering
             --packet-payload N  anytime packet payload cap, bytes
             --trace FILE        bandwidth trace (lines: duration_s bps)
             --net-seed 42       channel loss-process seed
           real sockets:
             --listen ADDR       host the server half behind a TCP
                                 listener instead of running a pipeline
                                 (e.g. --listen 127.0.0.1:7431); serves
                                 `device --connect` clients until one
                                 sends --shutdown. The scheme/backend/
                                 batching flags configure the hosted
                                 server; dataset/scheme/bits are pinned
                                 at the client handshake.
             --io-timeout-s 30   per-connection socket read/write timeout;
                                 a stalled client disconnects with a typed
                                 TimedOut instead of pinning its handler
                                 (0 = blocking reads, never time out)
  device   run the device half against a remote serving daemon; same
           flags as serve (devices, requests, rate, channel, reporting),
           plus:
             --connect ADDR      the daemon's --listen address (required)
             --shutdown          just ask the daemon to shut down
           the simulated lossy channel stays on the device side, so a
           loopback daemon run reproduces every seed-deterministic report
           field of `serve --clock sim` bit for bit (docs/daemon.md)
  infer    process one request, print the full breakdown
             --dataset svhns --scheme agile|deepcod|spinn|mcunet|edge
             --backend pjrt|reference --index 0 --bits 4 [--alpha 0.3]
  bench    regenerate a paper figure/table (or a fleet-scale sweep)
             --figure 2|16|t2|17|18|19|20|21|22|23|24|fleet|tune|autoscale|adaptive|breakdown|all
             --backend pjrt|reference  (reference: artifact-free sweeps
                                 on the synthetic model family)
  tune     search the serving-knob space with the fleet engine as the
           evaluator; prints (and optionally writes) the Pareto front
           over {accuracy, p99_latency_s, goodput_bps, server_seconds}
           search axes (comma lists; the cross product is the grid):
             --deadlines-us 500,2000  batch deadlines, microseconds
             --payloads mtu          anytime payload caps (mtu = link MTU)
             --bits 2,4              quantizer widths
             --delivery arq          uplink transports (arq,anytime)
             --net-deadline-ms 5     anytime decode deadline
             --placements static     device->server policies
                                     (static,rr,least,weighted)
             --servers 1,2           server counts
             --autoscale false       false,true — true evaluates the point
                                     under the SLO autoscaler (one initial
                                     server, servers axis as the ceiling)
             --policy false          false,true — true arms the default
                                     adaptive split/rate policy at the
                                     point's bit width
           evaluation (shared by every point; defaults are the fast
           deterministic path — reference backend on the sim clock's
           event engine):
             --dataset synthetic --scheme agile --backend reference
             --devices 16 --requests 4000 --rate-hz 50
             --arrival-seed 11 --net-seed 42 --loss 0 --burst 1
             --max-batch 8 --clock sim --sim-engine event
           strategy:
             --strategy exhaustive|genetic
             --seed 1 --pop 8 --budget 64   (genetic knobs)
           state / output:
             --state PATH    resumable saved state (+ PATH.log.jsonl);
                             re-running with the same PATH skips already-
                             completed evaluations and yields a front
                             byte-identical to an uninterrupted run
             --stop-after K  pause this invocation after K new evaluations
             --out FILE      write the ordered-JSON front artifact
             --trace-out FILE  write a Chrome/Perfetto trace of the search
                             (a span per fresh evaluation, an instant per
                             resume hit / infeasible point)
             --quiet         suppress per-evaluation progress
  perfgate run the CI perf-regression suite (fleet engine + serving hot
           paths + autotuner evaluator, reference backend), write
           deterministic JSON, and fail on a throughput regression vs a
           baseline
             --out BENCH_6.json  where to write the measurements
             --pointer FILE      also write a self-describing repo-root
                                 pointer (git SHA + measured entry names)
             --baseline FILE     compare against this JSON (committed
                                 floors live in rust/bench/baseline.json)
             --tolerance 0.20    allowed fractional regression
             --requests 1000000 --devices 10000 --servers 4
           AGILENN_PERF_HANDICAP=1.5 injects a real 1.5x slowdown into
           every timed section (CI uses it to prove the gate trips)
  report   print what was trained/exported per dataset
  help     this text

GLOBAL:
  --artifacts DIR   artifacts directory (default ./artifacts or
                    $AGILENN_ARTIFACTS)

The serve pipeline is built with agilenn::serve::ServeBuilder; library
users get the same API plus a streaming per-request outcome iterator.
";

fn main() -> Result<()> {
    let args = Args::parse()?;
    let artifacts: PathBuf = args
        .flags
        .get("artifacts")
        .map(PathBuf::from)
        .unwrap_or_else(default_artifacts_dir);
    match args.cmd.as_str() {
        "serve" => {
            let cli = ServeCli::from_args(&args, artifacts)?;
            match args.flags.get("listen").cloned() {
                Some(addr) => cli.run_daemon(&addr)?,
                None => cli.run_client()?,
            }
        }
        "device" => {
            let addr = args.get_str("connect", "");
            if addr.is_empty() {
                bail!("device needs --connect <addr> (the daemon's --listen address)");
            }
            if args.get("shutdown", false)? {
                send_shutdown(&addr)?;
                println!("sent shutdown to {addr}");
            } else {
                let mut cli = ServeCli::from_args(&args, artifacts)?;
                cli.builder = cli.builder.connect(&addr);
                cli.run_client()?;
            }
        }
        "infer" => {
            let dataset = args.get_str("dataset", "svhns");
            let scheme: Scheme = args.get_str("scheme", "agile").parse()?;
            let index: usize = args.get("index", 0)?;
            let mut cfg = RunConfig::new(artifacts, &dataset, scheme);
            cfg.backend = args.get("backend", BackendKind::Pjrt)?;
            cfg.bits = args.get("bits", 4)?;
            cfg.alpha_override = args.get_opt_f64("alpha")?;
            let (meta, testset) = agilenn::fixtures::load_world(&cfg)?;
            let backend = make_backend(&cfg, &meta)?;
            let mut runner = agilenn::baselines::make_runner(backend.as_ref(), &cfg, &meta)?;
            let idx = index % testset.len();
            let out = runner.process(&testset.image(idx)?, testset.labels[idx])?;
            println!("{} on {dataset}[{index}]:", scheme.name());
            println!("  predicted      : {} (label {})", out.predicted, testset.labels[idx]);
            println!("  correct        : {}", out.correct);
            println!("  local NN       : {} ms", ms(out.breakdown.local_nn_s));
            println!("  compression    : {} ms", ms(out.breakdown.compression_s));
            println!("  network        : {} ms", ms(out.breakdown.network_s));
            println!("  remote         : {} ms", ms(out.breakdown.remote_s));
            println!("  total          : {} ms", ms(out.breakdown.total_s()));
            println!("  tx bytes       : {}", out.tx_bytes);
            println!("  energy         : {:.2} mJ", out.energy.total_mj());
            if out.exited_early {
                println!("  (resolved at the on-device early exit)");
            }
        }
        "bench" => {
            let figure = args.get_str("figure", "16");
            let ctx = EvalCtx::with_backend(artifacts, args.get("backend", BackendKind::Pjrt)?)?;
            let ids: Vec<&str> =
                if figure == "all" { all_ids().to_vec() } else { vec![figure.as_str()] };
            for id in ids {
                for table in run_figure(&ctx, id)? {
                    table.print();
                    println!();
                }
            }
        }
        "tune" => {
            let quiet: bool = args.get("quiet", false)?;
            let net_deadline_ms: f64 = args.get("net-deadline-ms", 5.0)?;
            let space = SearchSpace {
                batch_deadline_us: tune::space::parse_list(
                    &args.get_str("deadlines-us", "500,2000"),
                )?,
                packet_payload: tune::space::parse_payloads(&args.get_str("payloads", "mtu"))?,
                bits: tune::space::parse_list(&args.get_str("bits", "2,4"))?,
                delivery: tune::space::parse_deliveries(
                    &args.get_str("delivery", "arq"),
                    net_deadline_ms * 1e-3,
                )?,
                placement: tune::space::parse_placements(&args.get_str("placements", "static"))?,
                servers: tune::space::parse_list(&args.get_str("servers", "1,2"))?,
                autoscale: tune::space::parse_list(&args.get_str("autoscale", "false"))?,
                policy: tune::space::parse_list(&args.get_str("policy", "false"))?,
            };
            let eval = EvalSpec {
                artifacts_dir: Some(artifacts),
                dataset: args.get_str("dataset", agilenn::fixtures::SYNTHETIC_DATASET),
                backend: args.get("backend", BackendKind::Reference)?,
                scheme: args.get_str("scheme", "agile").parse()?,
                devices: args.get("devices", 16)?,
                requests: args.get("requests", 4000)?,
                rate_hz: args.get("rate-hz", 50.0)?,
                arrival_seed: args.get("arrival-seed", 11u64)?,
                net_seed: args.get("net-seed", 42u64)?,
                loss: args.get("loss", 0.0)?,
                burst: args.get("burst", 1.0)?,
                max_batch: args.get("max-batch", 8)?,
                clock: args.get("clock", ClockKind::Sim)?,
                sim_engine: args.get("sim-engine", SimEngine::Event)?,
            };
            let strategy = match args.get_str("strategy", "exhaustive").parse::<StrategyKind>()? {
                StrategyKind::Exhaustive => StrategyKind::Exhaustive,
                StrategyKind::Genetic { .. } => StrategyKind::Genetic {
                    seed: args.get("seed", 1u64)?,
                    population: args.get("pop", 8)?,
                    budget: args.get("budget", 64)?,
                },
            };
            let stop_after = match args.flags.get("stop-after") {
                Some(v) => Some(v.parse()?),
                None => None,
            };
            let trace_out = args.flags.get("trace-out").cloned();
            let sink = trace_out.as_ref().map(|_| Arc::new(RecordingSink::new()));
            let cfg = TuneConfig {
                space,
                eval,
                strategy,
                state: args.flags.get("state").map(PathBuf::from),
                out: args.flags.get("out").map(PathBuf::from),
                stop_after,
                trace: match &sink {
                    Some(s) => Tracer::new(s.clone()),
                    None => Tracer::off(),
                },
            };
            println!(
                "tune: {} strategy over a {}-point grid ({} backend, {} clock, {} engine)",
                cfg.strategy.name(),
                cfg.space.len(),
                cfg.eval.backend.name(),
                cfg.eval.clock.name(),
                cfg.eval.sim_engine.name()
            );
            let outcome = tune::run(&cfg, |line| {
                if !quiet {
                    println!("  {line}");
                }
            })?;
            println!(
                "{}: {} evaluated, {} cached, {} infeasible, front size {}",
                if outcome.completed {
                    "search complete"
                } else {
                    "search interrupted (re-run with the same --state to resume)"
                },
                outcome.evaluated,
                outcome.cached,
                outcome.infeasible,
                outcome.front.len()
            );
            for (p, o) in &outcome.front {
                println!(
                    "  front: acc {}  p99 {} ms  goodput {:.1} kbps  server-s {:.2}  <- {}",
                    pct(o.accuracy),
                    ms(o.p99_latency_s),
                    o.goodput_bps / 1e3,
                    o.server_seconds,
                    p.key()
                );
            }
            if let Some(path) = &cfg.out {
                println!("wrote {}", path.display());
            }
            if let (Some(path), Some(s)) = (&trace_out, &sink) {
                std::fs::write(path, chrome_trace_json(&s.take()) + "\n")?;
                println!("wrote {path}");
            }
        }
        "perfgate" => {
            let out = args.get_str("out", "BENCH_6.json");
            let tolerance: f64 = args.get("tolerance", perfgate::DEFAULT_TOLERANCE)?;
            let gcfg = perfgate::GateConfig {
                requests: args.get("requests", 1_000_000)?,
                devices: args.get("devices", 10_000)?,
                servers: args.get("servers", 4)?,
            };
            let handicap = perfgate::handicap_factor();
            if handicap > 1.0 {
                println!("injected slowdown active: {handicap}x (AGILENN_PERF_HANDICAP)");
            }
            println!(
                "perfgate: fleet {} requests x {} devices x {} servers (reference backend)",
                gcfg.requests, gcfg.devices, gcfg.servers
            );
            let report = perfgate::measure(&gcfg, |e| {
                println!("  {:<14} {:>12.1}/s  ({:.2} s)", e.name, e.throughput, e.wall_s);
            })?;
            std::fs::write(&out, report.to_json())?;
            println!("wrote {out}");
            if let Some(ptr) = args.flags.get("pointer") {
                std::fs::write(ptr, perfgate::pointer_json(&report, &out))?;
                println!("wrote {ptr}");
            }
            if let Some(baseline_path) = args.flags.get("baseline") {
                let baseline = perfgate::PerfReport::load(std::path::Path::new(baseline_path))?;
                let failures = perfgate::check(&report, &baseline, tolerance);
                if !failures.is_empty() {
                    for f in &failures {
                        eprintln!("PERF REGRESSION: {f}");
                    }
                    bail!(
                        "perf gate failed: {} regression(s) vs {baseline_path}",
                        failures.len()
                    );
                }
                println!(
                    "perf gate OK vs {baseline_path} (tolerance {:.0}%)",
                    tolerance * 100.0
                );
            }
        }
        "report" => {
            let manifest = Manifest::load(&artifacts)?;
            println!("artifacts: {} (quick={})", artifacts.display(), manifest.quick);
            for ds in &manifest.datasets {
                let meta = Meta::load(&artifacts.join(ds))?;
                println!(
                    "  {ds}: {} classes, k={}, rho={:.2}, alpha={:.3}, xai={}, \
                     py-acc agile={:.3} deepcod={:.3} spinn={:.3} mcunet={:.3} edge={:.3}",
                    meta.num_classes,
                    meta.k,
                    meta.rho,
                    meta.alpha,
                    meta.xai_tool,
                    meta.accuracy.agile,
                    meta.accuracy.deepcod,
                    meta.accuracy.spinn_final,
                    meta.accuracy.mcunet,
                    meta.accuracy.edge_only,
                );
            }
        }
        "help" | "--help" | "-h" => print!("{HELP}"),
        other => bail!("unknown command {other:?}\n{HELP}"),
    }
    Ok(())
}

/// The parsed serving configuration shared by the three socket roles of
/// the `serve`/`device` commands: in-process run (`serve`), daemon host
/// (`serve --listen`), and remote device client (`device --connect`). One
/// parser means one set of defaults, so a client and a daemon started
/// with the same flags always agree on the world they serve.
struct ServeCli {
    builder: ServeBuilder,
    scheme: Scheme,
    devices: usize,
    requests: usize,
    json_out: bool,
    quiet: bool,
    trace_out: Option<String>,
    metrics_out: Option<String>,
    sink: Option<Arc<RecordingSink>>,
    /// Daemon-mode socket read/write timeout, seconds (0 disables).
    io_timeout_s: f64,
}

impl ServeCli {
    fn from_args(args: &Args, artifacts: PathBuf) -> Result<Self> {
        let dataset = args.get_str("dataset", "svhns");
        let scheme: Scheme = args.get_str("scheme", "agile").parse()?;
        let devices: usize = args.get("devices", 4)?;
        let requests: usize = args.get("requests", 256)?;
        let json_out: bool = args.get("json", false)?;
        // --json owns stdout: progress lines would corrupt the
        // machine-readable output, so it implies --quiet
        let quiet: bool = args.get("quiet", false)? || json_out;
        let servers: usize = args.get("servers", 1)?;
        let placement: Placement = args.get("placement", Placement::Static)?;
        let max_batch: usize = args.get("max-batch", 8)?;
        let deadline_us: u64 = args.get("deadline-us", 2000)?;
        let mut builder = ServeBuilder::new(&dataset)
            .artifacts_dir(artifacts)
            .scheme(scheme)
            .backend(args.get("backend", BackendKind::Pjrt)?)
            .fleet(|f| {
                f.devices = devices;
                f.requests = requests;
                f.servers = servers;
                f.placement = placement;
            })
            .rate_hz(args.get("rate-hz", 30.0)?)
            .clock(args.get("clock", ClockKind::Wall)?)
            .sim_engine(args.get("sim-engine", SimEngine::Event)?)
            .batch(|b| {
                b.max_batch = max_batch;
                b.deadline_us = deadline_us;
            })
            .bits(args.get("bits", 4)?);
        if args.get("policy", false)? {
            let mut policy = PolicyConfig::default();
            if let Some(widths) = args.flags.get("policy-widths") {
                policy.widths = tune::space::parse_list(widths)?;
            }
            policy.sustain = args.get("policy-sustain", policy.sustain)?;
            policy.cooldown = args.get("policy-cooldown", policy.cooldown)?;
            policy.local_fallback = args.get("policy-local-fallback", policy.local_fallback)?;
            builder = builder.policy(policy);
        }
        if let Some(alpha) = args.get_opt_f64("alpha")? {
            builder = builder.alpha(alpha);
        }
        if let Some(spec) = args.flags.get("diurnal") {
            let parts = tune::space::parse_list::<f64>(spec)?;
            let [period_s, base_hz, peak_hz] = parts[..] else {
                bail!("--diurnal wants PERIOD_S,BASE_HZ,PEAK_HZ (got {spec:?})");
            };
            builder = builder.arrival(Arrival::Diurnal { period_s, base_hz, peak_hz, seed: 42 });
        }
        if args.flags.contains_key("arrival-seed") {
            builder = builder.arrival_seed(args.get("arrival-seed", 42u64)?);
        }
        let base_us: f64 = args.get("service-base-us", 0.0)?;
        let per_sample_us: f64 = args.get("service-per-sample-us", 0.0)?;
        if base_us != 0.0 || per_sample_us != 0.0 {
            builder = builder.fleet(|f| {
                f.service.base_s = base_us * 1e-6;
                f.service.per_sample_s = per_sample_us * 1e-6;
            });
        }
        if let Some(caps) = args.flags.get("capacities") {
            let weights: Vec<f64> = tune::space::parse_list(caps)?;
            builder = builder.fleet(|f| f.service.capacities = weights);
        }
        if let Some(range) = args.flags.get("autoscale") {
            let parts = tune::space::parse_list::<usize>(range)?;
            let [min, max] = parts[..] else {
                bail!("--autoscale wants MIN,MAX (got {range:?})");
            };
            let mut scale = AutoscaleConfig::new(min, max);
            scale.slo_queue_p95_s = args.get("slo-queue-ms", scale.slo_queue_p95_s * 1e3)? * 1e-3;
            scale.window_s = args.get("scale-window-s", scale.window_s)?;
            scale.interval_s = args.get("scale-interval-s", scale.interval_s)?;
            scale.cooldown_s = args.get("scale-cooldown-s", scale.cooldown_s)?;
            scale.sustain = args.get("scale-sustain", scale.sustain)?;
            builder = builder.fleet(|f| f.autoscale = Some(scale));
        }
        if let Some(slo_ms) = args.get_opt_f64("slo-p99-ms")? {
            builder = builder.fleet(|f| f.slo_p99_s = slo_ms * 1e-3);
        }
        if let Some(loss) = args.get_opt_f64("loss")? {
            let burst: f64 = args.get("burst", 1.0)?;
            let process = if burst > 1.0 {
                GilbertElliott::bursty(loss, burst)
            } else {
                GilbertElliott::uniform(loss)
            };
            builder = builder.net(|n| n.loss = process);
        }
        let delivery = match args.get_str("delivery", "arq").as_str() {
            "arq" => DeliveryPolicy::Arq,
            "anytime" => {
                let deadline_ms: f64 = args.get("net-deadline-ms", 5.0)?;
                DeliveryPolicy::Anytime { deadline_s: deadline_ms * 1e-3 }
            }
            other => bail!("unknown --delivery {other:?} (arq|anytime)"),
        };
        let order: PacketOrder = args.get("order", PacketOrder::Importance)?;
        let net_seed: u64 = args.get("net-seed", 42u64)?;
        builder = builder.net(|n| {
            n.delivery = delivery;
            n.order = order;
            n.seed = net_seed;
        });
        if let Some(payload) = args.flags.get("packet-payload") {
            let bytes: usize = payload.parse()?;
            builder = builder.net(|n| n.packet_payload = Some(bytes));
        }
        if let Some(path) = args.flags.get("trace") {
            let trace = BandwidthTrace::from_file(std::path::Path::new(path))?;
            builder = builder.net(|n| n.trace = Some(trace));
        }
        let trace_out = args.flags.get("trace-out").cloned();
        let metrics_out = args.flags.get("metrics-out").cloned();
        let sink = trace_out.as_ref().map(|_| Arc::new(RecordingSink::new()));
        if let Some(s) = &sink {
            builder = builder.trace_sink(s.clone());
        }
        Ok(Self {
            builder,
            scheme,
            devices,
            requests,
            json_out,
            quiet,
            trace_out,
            metrics_out,
            sink,
            io_timeout_s: args.get("io-timeout-s", 30.0)?,
        })
    }

    /// Host the server half behind a TCP listener until a client sends
    /// shutdown (`agilenn device --connect <addr> --shutdown`).
    fn run_daemon(self, addr: &str) -> Result<()> {
        let mut daemon = Daemon::bind(addr, self.builder)?;
        if self.io_timeout_s > 0.0 {
            daemon = daemon.io_timeout(std::time::Duration::from_secs_f64(self.io_timeout_s));
        }
        let local = daemon.local_addr()?;
        println!("{}: serving daemon listening on {local}", self.scheme.name());
        let summary = daemon.run()?;
        if let (Some(path), Some(s)) = (&self.trace_out, &self.sink) {
            std::fs::write(path, chrome_trace_json(&s.take()) + "\n")?;
            println!("wrote {path}");
        }
        println!(
            "daemon done: {} connections; {} requests in {} batches (mean size {:.2}), \
             queue mean {} ms / p95 {} ms",
            summary.connections,
            summary.shard.requests,
            summary.shard.batches,
            summary.shard.mean_batch_size,
            ms(summary.shard.mean_queue_s),
            ms(summary.shard.p95_queue_s)
        );
        Ok(())
    }

    /// Run the serving pipeline (in-process, or against a remote daemon
    /// when the builder has a connect address) and print the report.
    fn run_client(self) -> Result<()> {
        let (requests, quiet, json_out) = (self.requests, self.quiet, self.json_out);
        let mut stream = self.builder.build()?.stream()?;
        let mut served = 0usize;
        for out in stream.by_ref() {
            served += 1;
            if !quiet && (served % 32 == 0 || served == requests) {
                println!(
                    "  .. {served}/{requests} served (request {} on device {}: {} ms)",
                    out.id,
                    out.device,
                    ms(out.wall_s),
                );
            }
        }
        let (rep, mut registry) = stream.finish_full()?;
        if let Some(path) = &self.metrics_out {
            std::fs::write(path, registry.to_ordered_json() + "\n")?;
            if !json_out {
                println!("wrote {path}");
            }
        }
        if let (Some(path), Some(s)) = (&self.trace_out, &self.sink) {
            std::fs::write(path, chrome_trace_json(&s.take()) + "\n")?;
            if !json_out {
                println!("wrote {path}");
            }
        }
        if json_out {
            println!("{}", rep.to_ordered_json());
            return Ok(());
        }
        println!(
            "{}: {} requests over {} devices ({} clock)",
            self.scheme.name(),
            rep.requests,
            self.devices,
            rep.clock.name()
        );
        let elapsed_label = if rep.clock == ClockKind::Sim { "virtual time" } else { "wall time" };
        println!("  {elapsed_label:<15}: {:.2} s", rep.wall_s);
        println!("  throughput     : {:.1} req/s", rep.throughput_rps);
        println!("  accuracy       : {}", pct(rep.accuracy));
        println!("  latency mean   : {} ms", ms(rep.mean_latency_s));
        println!("  latency p95    : {} ms", ms(rep.p95_latency_s));
        println!("  batches        : {} (mean size {:.2})", rep.batches, rep.mean_batch_size);
        println!(
            "  link           : {} pkts sent, {} lost, {} retx rounds",
            rep.packets_sent, rep.packets_lost, rep.retransmit_rounds
        );
        println!(
            "  link           : p99 {} ms, goodput {:.1} kbps, \
             features delivered {:.1}%, {} partial frames",
            ms(rep.p99_net_s),
            rep.goodput_bps / 1e3,
            rep.delivered_feature_rate * 100.0,
            rep.incomplete_frames
        );
        println!("  radio queueing : mean {} ms", ms(rep.mean_radio_wait_s));
        println!("  fleet cost     : {:.2} server-seconds", rep.server_seconds);
        if rep.slo_p99_s > 0.0 {
            println!(
                "  SLO            : {} of requests within p99 target {} ms",
                pct(rep.slo_attainment),
                ms(rep.slo_p99_s)
            );
        }
        if let Some(p) = &rep.policy {
            let widths: Vec<String> =
                p.widths.iter().map(|(w, n)| format!("{w}b x{n}")).collect();
            println!(
                "  policy         : {} switches, {} local-only, mean {:.2} bits ({})",
                p.switches,
                p.local_only,
                p.mean_bits,
                widths.join(", ")
            );
        }
        if rep.scale_outs + rep.scale_ins > 0 {
            println!(
                "  autoscaler     : {} scale-outs, {} scale-ins",
                rep.scale_outs, rep.scale_ins
            );
        }
        if rep.shards.len() > 1 {
            for s in &rep.shards {
                println!(
                    "  server {:<2}      : {} reqs in {} batches (mean {:.2}), \
                     queue mean {} ms / p95 {} ms, active {:.2} s",
                    s.server,
                    s.requests,
                    s.batches,
                    s.mean_batch_size,
                    ms(s.mean_queue_s),
                    ms(s.p95_queue_s),
                    s.active_s
                );
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::Args;

    fn parse(words: &[&str]) -> Args {
        Args::from_iter(words.iter().map(|s| s.to_string())).unwrap()
    }

    #[test]
    fn flag_value_pairs() {
        let a = parse(&["serve", "--dataset", "svhns", "--devices", "4"]);
        assert_eq!(a.cmd, "serve");
        assert_eq!(a.get_str("dataset", "x"), "svhns");
        assert_eq!(a.get::<usize>("devices", 0).unwrap(), 4);
    }

    #[test]
    fn valueless_flag_does_not_swallow_the_next_flag() {
        // regression: `--quiet --artifacts X` used to store quiet="--artifacts"
        let a = parse(&["bench", "--figure", "16", "--quiet", "--artifacts", "X"]);
        assert_eq!(a.get_str("figure", ""), "16");
        assert!(a.get::<bool>("quiet", false).unwrap());
        assert_eq!(a.get_str("artifacts", ""), "X");
    }

    #[test]
    fn trailing_valueless_flag_is_true() {
        let a = parse(&["serve", "--quiet"]);
        assert!(a.get::<bool>("quiet", false).unwrap());
    }

    #[test]
    fn negative_numbers_are_values_not_flags() {
        let a = parse(&["serve", "--alpha", "-0.5"]);
        assert_eq!(a.get_opt_f64("alpha").unwrap(), Some(-0.5));
    }

    #[test]
    fn clock_flag_parses_through_args() {
        use agilenn::serve::ClockKind;
        let a = parse(&["serve", "--clock", "sim"]);
        assert_eq!(a.get("clock", ClockKind::Wall).unwrap(), ClockKind::Sim);
        let a = parse(&["serve"]);
        assert_eq!(a.get("clock", ClockKind::Wall).unwrap(), ClockKind::Wall);
        let a = parse(&["serve", "--clock", "sundial"]);
        assert!(a.get("clock", ClockKind::Wall).is_err());
    }

    #[test]
    fn backend_flag_parses_through_args() {
        use agilenn::config::BackendKind;
        let a = parse(&["serve", "--backend", "reference"]);
        assert_eq!(a.get("backend", BackendKind::Pjrt).unwrap(), BackendKind::Reference);
        let a = parse(&["serve"]);
        assert_eq!(a.get("backend", BackendKind::Pjrt).unwrap(), BackendKind::Pjrt);
        let a = parse(&["serve", "--backend", "gpu"]);
        assert!(a.get("backend", BackendKind::Pjrt).is_err());
    }

    #[test]
    fn device_and_listen_flags_parse_through_args() {
        let a = parse(&["device", "--connect", "127.0.0.1:7431", "--requests", "1500"]);
        assert_eq!(a.cmd, "device");
        assert_eq!(a.get_str("connect", ""), "127.0.0.1:7431");
        assert_eq!(a.get::<usize>("requests", 0).unwrap(), 1500);
        assert!(!a.get::<bool>("shutdown", false).unwrap());
        let s = parse(&["device", "--connect", "127.0.0.1:7431", "--shutdown"]);
        assert!(s.get::<bool>("shutdown", false).unwrap());
        // --listen takes an address value; a following --flag stays a flag
        let d = parse(&["serve", "--listen", "127.0.0.1:0", "--quiet"]);
        assert_eq!(d.get_str("listen", ""), "127.0.0.1:0");
        assert!(d.get::<bool>("quiet", false).unwrap());
    }

    #[test]
    fn non_flag_token_errors() {
        assert!(Args::from_iter(["serve".into(), "oops".into()]).is_err());
    }

    #[test]
    fn tune_flags_parse_through_args() {
        use agilenn::net::DeliveryPolicy;
        use agilenn::tune::{space, StrategyKind};
        let a = parse(&[
            "tune",
            "--deadlines-us",
            "500,2000",
            "--bits",
            "2,4",
            "--delivery",
            "arq,anytime",
            "--servers",
            "1,2",
            "--strategy",
            "genetic",
            "--budget",
            "16",
            "--stop-after",
            "3",
        ]);
        assert_eq!(
            space::parse_list::<u64>(&a.get_str("deadlines-us", "")).unwrap(),
            vec![500, 2000]
        );
        assert_eq!(space::parse_list::<u32>(&a.get_str("bits", "")).unwrap(), vec![2, 4]);
        assert_eq!(
            space::parse_deliveries(&a.get_str("delivery", ""), 0.005).unwrap(),
            vec![DeliveryPolicy::Arq, DeliveryPolicy::Anytime { deadline_s: 0.005 }]
        );
        let s: StrategyKind = a.get_str("strategy", "exhaustive").parse().unwrap();
        assert_eq!(s.name(), "genetic");
        assert_eq!(a.get::<usize>("budget", 64).unwrap(), 16);
        assert_eq!(a.get::<usize>("stop-after", 0).unwrap(), 3);
        // the defaults reproduce the default search space
        let d = parse(&["tune"]);
        assert_eq!(
            space::parse_payloads(&d.get_str("payloads", "mtu")).unwrap(),
            vec![None]
        );
        assert_eq!(
            d.get_str("strategy", "exhaustive").parse::<StrategyKind>().unwrap(),
            StrategyKind::Exhaustive
        );
    }

    #[test]
    fn fleet_flags_parse_through_args() {
        use agilenn::serve::{Placement, SimEngine};
        let a = parse(&[
            "serve",
            "--servers",
            "4",
            "--placement",
            "least",
            "--sim-engine",
            "threads",
        ]);
        assert_eq!(a.get::<usize>("servers", 1).unwrap(), 4);
        assert_eq!(a.get("placement", Placement::Static).unwrap(), Placement::LeastLoaded);
        assert_eq!(a.get("sim-engine", SimEngine::Event).unwrap(), SimEngine::Threads);
        let d = parse(&["serve"]);
        assert_eq!(d.get::<usize>("servers", 1).unwrap(), 1);
        assert_eq!(d.get("placement", Placement::Static).unwrap(), Placement::Static);
        assert_eq!(d.get("sim-engine", SimEngine::Event).unwrap(), SimEngine::Event);
        assert!(parse(&["serve", "--placement", "hash"])
            .get("placement", Placement::Static)
            .is_err());
    }
}
