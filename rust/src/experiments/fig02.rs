//! Fig 2: data compressibility in NN offloading (motivation).
//! (a) raw-data compression (JPEG-style DCT codec) — accuracy loss grows
//!     quickly with compression rate;
//! (b) feature-space compression (partitioned DeepCOD encoder + learned
//!     quantizer) — similar rates with far smaller accuracy loss, but at a
//!     much larger on-device model cost.

use super::common::{eval_n, eval_scheme, EvalCtx};
use crate::compression::dct;
use crate::config::Scheme;
use crate::metrics::AccuracyCounter;
use crate::report::{pct, Table};
use crate::tensor::{argmax, Tensor};
use anyhow::Result;

pub const QUALITY_SWEEP: [f32; 5] = [90.0, 50.0, 20.0, 8.0, 2.0];

pub fn run(ctx: &EvalCtx) -> Result<Vec<Table>> {
    let ds = ctx
        .datasets
        .iter()
        .find(|d| d.contains("cifar10s"))
        .or_else(|| ctx.datasets.first())
        .ok_or_else(|| anyhow::anyhow!("no datasets built"))?
        .clone();
    let testset = ctx.testset(&ds)?;
    let cfg = ctx.run_config(&ds, Scheme::EdgeOnly);
    let exe = ctx.backend.load_module(&cfg.dataset_dir(), "edge_remote_b1")?;
    let n = eval_n().min(testset.len());
    let [h, w, c] = [32usize, 32, 3];

    // (a) raw-data DCT compression sweep
    let mut ta = Table::new(
        format!("Fig 2(a) [{ds}]: raw-data compression vs accuracy"),
        &["quality", "rate", "accuracy", "acc_loss"],
    );
    // baseline: uncompressed accuracy
    let mut base_acc = AccuracyCounter::default();
    for i in 0..n {
        let img = testset.image(i)?;
        let out = exe.run(std::slice::from_ref(&img))?;
        base_acc.record(argmax(out[0].data()) as i32 == testset.labels[i]);
    }
    for q in QUALITY_SWEEP {
        let mut acc = AccuracyCounter::default();
        let mut bytes_total = 0usize;
        for i in 0..n {
            let img = testset.image(i)?;
            let enc = dct::encode(img.data(), h, w, c, q)?;
            bytes_total += enc.payload.len();
            let dec = dct::decode(&enc)?;
            let t = Tensor::new(vec![1, h, w, c], dec)?;
            let out = exe.run(std::slice::from_ref(&t))?;
            acc.record(argmax(out[0].data()) as i32 == testset.labels[i]);
        }
        let raw = (h * w * c) as f64; // u8 raw image bytes
        let rate = raw / (bytes_total as f64 / n as f64);
        ta.row(vec![
            format!("{q:.0}"),
            format!("{rate:.1}x"),
            pct(acc.accuracy()),
            pct((base_acc.accuracy() - acc.accuracy()).max(0.0)),
        ]);
    }

    // (b) feature-space compression (DeepCOD-style partitioning)
    let mut tb = Table::new(
        format!("Fig 2(b) [{ds}]: feature compression (DeepCOD encoder)"),
        &["bits", "rate_vs_raw_image", "accuracy", "device_model_KB"],
    );
    let meta = ctx.meta(&ds)?;
    for bits in [6u32, 4, 2, 1] {
        let mut cfg_d = ctx.run_config(&ds, Scheme::Deepcod);
        cfg_d.bits = bits;
        let e = eval_scheme(ctx, &cfg_d, n)?;
        let raw = (h * w * c) as f64;
        tb.row(vec![
            bits.to_string(),
            format!("{:.1}x", raw / e.mean_tx_bytes),
            pct(e.accuracy),
            format!("{:.1}", meta.param_bytes_int8.deepcod_device as f64 / 1024.0),
        ]);
    }
    Ok(vec![ta, tb])
}
