//! `bench --figure breakdown`: per-phase serving-latency quantiles from
//! the unified metrics registry.
//!
//! Fig 16 reports the *mean* of each lifecycle phase; this table shows the
//! tails — p50/p95/p99 of local NN, compression, network, and remote time
//! plus the end-to-end sojourn — per scheme, served under load through the
//! batched multi-device pipeline on the sim clock. The numbers are read
//! from the same [`MetricsRegistry`](crate::obs::MetricsRegistry) that
//! backs `PipelineReport` ([`finish_full`](crate::serve::OutcomeStream::finish_full)),
//! so the table is a direct view of what `serve --metrics-out` writes.

use super::common::{eval_n, EvalCtx};
use crate::config::Scheme;
use crate::report::{ms, Table};
use crate::serve::{ClockKind, Service};
use crate::workload::Arrival;
use anyhow::Result;

/// Registry histogram name -> table label, in presentation order.
const PHASES: &[(&str, &str)] = &[
    ("phase_local_nn_s", "local_nn"),
    ("phase_compression_s", "compress"),
    ("phase_network_s", "network"),
    ("phase_remote_s", "remote"),
    ("latency_s", "total"),
];

pub fn run(ctx: &EvalCtx) -> Result<Vec<Table>> {
    let mut tables = Vec::new();
    for ds in &ctx.datasets {
        for scheme in Scheme::all() {
            let cfg = ctx.run_config(ds, scheme);
            let meta = ctx.meta(ds)?;
            let testset = ctx.testset(ds)?;
            let mut stream = Service::from_parts(
                cfg,
                meta,
                testset,
                4,
                eval_n(),
                Arrival::Poisson { hz: 100.0, seed: 16 },
            )?
            .with_clock(ClockKind::Sim)
            .stream()?;
            for _ in stream.by_ref() {}
            let (_, mut registry) = stream.finish_full()?;
            let mut t = Table::new(
                format!(
                    "Breakdown [{ds}/{}]: per-phase latency quantiles \
                     (4 devices, batched, sim clock)",
                    scheme.name()
                ),
                &["phase", "count", "p50_ms", "p95_ms", "p99_ms", "mean_ms"],
            );
            for (name, label) in PHASES {
                let h = registry.hist_mut(name);
                t.row(vec![
                    (*label).into(),
                    h.count().to_string(),
                    ms(h.p50()),
                    ms(h.p95()),
                    ms(h.p99()),
                    ms(h.mean_s()),
                ]);
            }
            tables.push(t);
        }
    }
    Ok(tables)
}
