//! Fig 24: choice of XAI technique (Integrated Gradients vs Gradient
//! Saliency). The GS-variant training happens python-side
//! (`python -m compile.experiments.fig24_xai`, writing
//! artifacts/figures/fig24.json); here we render the comparison, falling
//! back to the IG-trained point alone if the GS variant is absent.

use super::common::{eval_n, eval_scheme, EvalCtx};
use crate::config::Scheme;
use crate::report::{pct, Table};
use anyhow::Result;

#[derive(Debug)]
struct Fig24Point {
    dataset: String,
    tool: String,
    accuracy: f64,
    achieved_skewness: f64,
    grad_computations_per_eval: usize,
}

pub fn run(ctx: &EvalCtx) -> Result<Vec<Table>> {
    let mut t = Table::new(
        "Fig 24: XAI technique comparison (IG vs GS)",
        &["dataset", "tool", "accuracy", "achieved_skew", "grads/eval"],
    );
    let path = ctx.artifacts_dir.join("figures").join("fig24.json");
    if path.exists() {
        let parsed = crate::json::Value::parse(&std::fs::read_to_string(&path)?)?;
        for v in parsed.as_arr()? {
            let p = Fig24Point {
                dataset: v.str_at("dataset")?,
                tool: v.str_at("tool")?,
                accuracy: v.f64_at("accuracy")?,
                achieved_skewness: v.f64_at("achieved_skewness")?,
                grad_computations_per_eval: v.usize_at("grad_computations_per_eval")?,
            };
            t.row(vec![
                p.dataset,
                p.tool.to_uppercase(),
                pct(p.accuracy),
                pct(p.achieved_skewness),
                p.grad_computations_per_eval.to_string(),
            ]);
        }
    } else {
        for ds in &ctx.datasets {
            let meta = ctx.meta(ds)?;
            let e = eval_scheme(ctx, &ctx.run_config(ds, Scheme::Agile), eval_n())?;
            t.row(vec![
                ds.clone(),
                meta.xai_tool.to_uppercase(),
                pct(e.accuracy),
                pct(meta.importance.achieved_skewness_mean),
                "4".into(), // training-time IG steps
            ]);
        }
        t.title.push_str("  [run `make figures` for the GS-trained variant]");
    }
    Ok(vec![t])
}
