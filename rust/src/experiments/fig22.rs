//! Fig 22: end-to-end latency vs device CPU frequency (216 -> 64 MHz).
//! AgileNN's tiny device NN keeps the curve flat; the baselines blow up.

use super::common::{eval_n, eval_scheme, EvalCtx};
use crate::config::Scheme;
use crate::report::{ms, Table};
use anyhow::Result;

pub const FREQ_SWEEP_MHZ: [f64; 4] = [216.0, 160.0, 108.0, 64.0];

pub fn run(ctx: &EvalCtx) -> Result<Vec<Table>> {
    let mut tables = Vec::new();
    for ds in ctx.datasets.iter().filter(|d| d.contains("cifar100") || d.contains("svhn")) {
        let mut t = Table::new(
            format!("Fig 22 [{ds}]: total latency (ms) vs CPU frequency"),
            &["scheme", "216MHz", "160MHz", "108MHz", "64MHz", "degradation"],
        );
        for scheme in [Scheme::Agile, Scheme::Deepcod, Scheme::Spinn, Scheme::Mcunet] {
            let mut cells = vec![scheme.name().to_string()];
            let mut first = 0.0;
            let mut last = 0.0;
            for (i, mhz) in FREQ_SWEEP_MHZ.iter().enumerate() {
                let mut cfg = ctx.run_config(ds, scheme);
                cfg.device = cfg.device.with_freq(mhz * 1e6);
                let e = eval_scheme(ctx, &cfg, eval_n())?;
                let total = e.total_latency_s();
                if i == 0 {
                    first = total;
                }
                last = total;
                cells.push(ms(total));
            }
            cells.push(format!("+{:.0}%", (last / first - 1.0) * 100.0));
            t.row(cells);
        }
        tables.push(t);
    }
    Ok(tables)
}
