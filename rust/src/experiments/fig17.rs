//! Fig 17: accuracy vs feature-compression rate, AgileNN vs DeepCOD.
//! The rate knob is the quantizer bit width (6..1 bits/value + LZW); the
//! compression rate is computed against the raw f32 feature payload.

use super::common::{eval_n, eval_scheme, EvalCtx};
use crate::config::Scheme;
use crate::report::{pct, Table};
use anyhow::Result;

pub const BIT_SWEEP: [u32; 5] = [6, 4, 3, 2, 1];

pub fn run(ctx: &EvalCtx) -> Result<Vec<Table>> {
    let mut tables = Vec::new();
    for ds in ctx.datasets.iter().filter(|d| d.contains("cifar100") || d.contains("svhn")) {
        let meta = ctx.meta(ds)?;
        let mut t = Table::new(
            format!("Fig 17 [{ds}]: accuracy vs compression rate"),
            &["bits", "agile_rate", "agile_acc", "deepcod_rate", "deepcod_acc"],
        );
        for bits in BIT_SWEEP {
            let mut cfg_a = ctx.run_config(ds, Scheme::Agile);
            cfg_a.bits = bits;
            let a = eval_scheme(ctx, &cfg_a, eval_n())?;
            let mut cfg_d = ctx.run_config(ds, Scheme::Deepcod);
            cfg_d.bits = bits;
            let d = eval_scheme(ctx, &cfg_d, eval_n())?;
            let raw_a = (meta.tx_elements(Scheme::Agile) * 4) as f64;
            let raw_d = (meta.tx_elements(Scheme::Deepcod) * 4) as f64;
            t.row(vec![
                bits.to_string(),
                format!("{:.1}x", raw_a / a.mean_tx_bytes),
                pct(a.accuracy),
                format!("{:.1}x", raw_d / d.mean_tx_bytes),
                pct(d.accuracy),
            ]);
        }
        tables.push(t);
    }
    Ok(tables)
}
