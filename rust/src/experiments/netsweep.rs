//! Loss sweep (channel subsystem): AgileNN accuracy, p99 link latency and
//! delivered-feature rate vs packet-loss rate, comparing the anytime
//! transport with importance-ordered vs naive (index-ordered) packets and
//! the ARQ whole-frame baseline.
//!
//! The anytime deadline is set *below* the one-pass serialization time, so
//! the least-prioritized tail of every frame never ships: importance
//! ordering then degrades gracefully (the dropped features are the ones
//! XAI ranked least important, whose reference imputation is cheapest)
//! while naive ordering drops an arbitrary index range. ARQ retransmits
//! until complete — accuracy holds, latency pays. All three share the same
//! channel seed, so the comparison is paired packet for packet.

use super::common::{eval_n, serve_scheme, EvalCtx};
use crate::config::Scheme;
use crate::net::{DeliveryPolicy, GilbertElliott, PacketOrder, PACKET_HEADER_BYTES};
use crate::report::{ms, pct, Table};
use crate::serve::{ClockKind, PipelineReport};
use crate::workload::Arrival;
use anyhow::Result;

/// Per-device arrival rate for the sweep: slow enough that the radio is
/// never contended (the table isolates *transport* latency, not queueing)
/// — and free under the sim clock, which never sleeps through the pacing.
const SWEEP_RATE_HZ: f64 = 30.0;

pub const LOSS_SWEEP: [f64; 4] = [0.0, 0.1, 0.3, 0.5];

/// Anytime packet payload cap (app bytes, header included): small enough
/// that an AgileNN frame spans ~a dozen packets, so ordering matters.
const PAYLOAD_CAP: usize = 64;

/// Fraction of the clean one-pass serialization time the anytime deadline
/// allows: < 1.0 forces the transport to choose what ships.
const DEADLINE_FRACTION: f64 = 0.75;

struct TransportRow {
    label: &'static str,
    delivery: fn(deadline_s: f64) -> DeliveryPolicy,
    order: PacketOrder,
}

fn anytime(deadline_s: f64) -> DeliveryPolicy {
    DeliveryPolicy::Anytime { deadline_s }
}

fn arq(_deadline_s: f64) -> DeliveryPolicy {
    DeliveryPolicy::Arq
}

const ROWS: [TransportRow; 3] = [
    TransportRow { label: "anytime/importance", delivery: anytime, order: PacketOrder::Importance },
    TransportRow { label: "anytime/naive", delivery: anytime, order: PacketOrder::Index },
    TransportRow { label: "arq/whole-frame", delivery: arq, order: PacketOrder::Importance },
];

/// One-pass serialization time (+ one-way latency) for a packetized
/// AgileNN uplink on `cfg`'s link: the anytime deadline anchors to this.
fn packetized_uplink_s(cfg: &crate::config::RunConfig, tx_elements: usize) -> f64 {
    let bits = cfg.bits.clamp(1, 8) as usize;
    let syms_per_packet = ((PAYLOAD_CAP - PACKET_HEADER_BYTES) * 8 / bits).max(1);
    let packets = tx_elements.div_ceil(syms_per_packet).max(1);
    let payload_bytes = (tx_elements * bits).div_ceil(8) + packets * PACKET_HEADER_BYTES;
    let wire_bytes = payload_bytes + packets * cfg.network.per_packet_overhead;
    wire_bytes as f64 * 8.0 / cfg.network.bandwidth_bps + cfg.network.one_way_latency_s
}

fn run_point(
    ctx: &EvalCtx,
    ds: &str,
    row: &TransportRow,
    loss_rate: f64,
    n: usize,
) -> Result<PipelineReport> {
    let meta = ctx.meta(ds)?;
    let mut cfg = ctx.run_config(ds, Scheme::Agile);
    cfg.batch.max_batch = 1; // b1 executable everywhere: bitwise-stable logits
    let deadline = DEADLINE_FRACTION * packetized_uplink_s(&cfg, meta.tx_elements(Scheme::Agile));
    cfg.net.loss = if loss_rate > 0.0 {
        GilbertElliott::bursty(loss_rate, 4.0)
    } else {
        GilbertElliott::lossless()
    };
    cfg.net.delivery = (row.delivery)(deadline);
    cfg.net.order = row.order;
    cfg.net.packet_payload = Some(PAYLOAD_CAP);
    cfg.net.seed = 42; // shared across rows: paired loss patterns
    serve_scheme(ctx, &cfg, 1, n, Arrival::Periodic { hz: SWEEP_RATE_HZ }, ClockKind::Sim)
}

pub fn run(ctx: &EvalCtx) -> Result<Vec<Table>> {
    let mut tables = Vec::new();
    let Some(ds) = ctx.datasets.first() else {
        return Ok(tables);
    };
    let n = eval_n();
    let headers = ["transport", "0%", "10%", "30%", "50%"];
    let mut acc = Table::new(
        format!("Loss sweep [{ds}]: AgileNN accuracy vs packet loss ({n} reqs)"),
        &headers,
    );
    let mut lat = Table::new(
        format!("Loss sweep [{ds}]: p99 simulated link latency (ms)"),
        &headers,
    );
    let mut feat = Table::new(
        format!("Loss sweep [{ds}]: delivered-feature rate"),
        &headers,
    );
    for row in &ROWS {
        let mut acc_cells = vec![row.label.to_string()];
        let mut lat_cells = vec![row.label.to_string()];
        let mut feat_cells = vec![row.label.to_string()];
        for loss_rate in LOSS_SWEEP {
            let rep = run_point(ctx, ds, row, loss_rate, n)?;
            acc_cells.push(pct(rep.accuracy));
            lat_cells.push(ms(rep.p99_net_s));
            feat_cells.push(format!("{:.3}", rep.delivered_feature_rate));
        }
        acc.row(acc_cells);
        lat.row(lat_cells);
        feat.row(feat_cells);
    }
    tables.push(acc);
    tables.push(lat);
    tables.push(feat);
    Ok(tables)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::RunConfig;

    #[test]
    fn deadline_anchor_is_below_the_whole_frame_arq_time_scale() {
        let cfg = RunConfig::new("artifacts", "svhns", Scheme::Agile);
        let t = packetized_uplink_s(&cfg, 1216);
        // 1216 4-bit symbols in 64-byte packets on 6 Mbps WiFi: ~2-4 ms
        assert!(t > 1e-3 && t < 1e-2, "uplink anchor {t}");
    }
}
