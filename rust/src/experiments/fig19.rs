//! Fig 19: local energy consumption per inference run (compute + radio),
//! all datasets x all schemes.

use super::common::{eval_n, eval_scheme, EvalCtx};
use crate::config::Scheme;
use crate::report::{mj, Table};
use anyhow::Result;

pub fn run(ctx: &EvalCtx) -> Result<Vec<Table>> {
    let mut t = Table::new(
        "Fig 19: device energy per inference (mJ)",
        &["dataset", "scheme", "compute_mJ", "radio_mJ", "total_mJ"],
    );
    for ds in &ctx.datasets {
        for scheme in Scheme::all() {
            let e = eval_scheme(ctx, &ctx.run_config(ds, scheme), eval_n())?;
            t.row(vec![
                ds.clone(),
                scheme.name().into(),
                mj(e.mean_energy.compute_j),
                mj(e.mean_energy.radio_j),
                mj(e.mean_energy.total_j()),
            ]);
        }
    }
    Ok(vec![t])
}
