//! Shared evaluation context and the per-scheme evaluation loop.

use crate::baselines::{make_runner, SchemeRunner};
use crate::config::{BackendKind, Manifest, Meta, RunConfig, Scheme};
use crate::fixtures::{SyntheticSpec, SYNTHETIC_DATASET};
use crate::metrics::{AccuracyCounter, EnergyLedger, LatencyBreakdown};
use crate::runtime::{pjrt_backend, Backend, ReferenceBackend};
use crate::serve::{ClockKind, PipelineReport, Service};
use crate::workload::{Arrival, TestSet};
use anyhow::Result;
use std::collections::HashMap;
use std::path::PathBuf;
use std::sync::{Arc, Mutex};

/// Number of test samples per evaluation sweep point (env-overridable:
/// AGILENN_EVAL_N). Figures sweep many points; 128 keeps a full `cargo
/// bench` run in minutes while staying statistically stable on a 512-sample
/// test set.
pub fn eval_n() -> usize {
    std::env::var("AGILENN_EVAL_N")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(128)
}

/// Shared state for figure regeneration: the inference backend + cached
/// metas/testsets. On [`BackendKind::Pjrt`] (the default) everything is
/// loaded from the artifacts tree; on [`BackendKind::Reference`] the
/// synthetic world ([`SyntheticSpec`]) stands in, so every figure sweep
/// runs with no artifacts and no XLA compile cost.
pub struct EvalCtx {
    pub backend: Arc<dyn Backend>,
    pub backend_kind: BackendKind,
    pub artifacts_dir: PathBuf,
    pub datasets: Vec<String>,
    metas: Mutex<HashMap<String, Meta>>,
    testsets: Mutex<HashMap<String, Arc<TestSet>>>,
}

impl EvalCtx {
    pub fn new(artifacts_dir: PathBuf) -> Result<Self> {
        Self::with_backend(artifacts_dir, BackendKind::Pjrt)
    }

    pub fn with_backend(artifacts_dir: PathBuf, kind: BackendKind) -> Result<Self> {
        let (datasets, backend): (Vec<String>, Arc<dyn Backend>) = match kind {
            BackendKind::Pjrt => (Manifest::load(&artifacts_dir)?.datasets, pjrt_backend()?),
            BackendKind::Reference => {
                let spec = SyntheticSpec::new(SYNTHETIC_DATASET);
                let backend: Arc<dyn Backend> =
                    Arc::new(ReferenceBackend::from_meta(&spec.meta()));
                (spec.manifest().datasets, backend)
            }
        };
        Ok(Self {
            backend,
            backend_kind: kind,
            artifacts_dir,
            datasets,
            metas: Mutex::new(HashMap::new()),
            testsets: Mutex::new(HashMap::new()),
        })
    }

    pub fn from_env() -> Result<Self> {
        Self::new(crate::config::default_artifacts_dir())
    }

    pub fn meta(&self, dataset: &str) -> Result<Meta> {
        let mut metas = self.metas.lock().unwrap();
        if let Some(m) = metas.get(dataset) {
            return Ok(m.clone());
        }
        let m = match self.backend_kind {
            BackendKind::Pjrt => Meta::load(&self.artifacts_dir.join(dataset))?,
            BackendKind::Reference => SyntheticSpec::new(dataset).meta(),
        };
        metas.insert(dataset.to_string(), m.clone());
        Ok(m)
    }

    pub fn testset(&self, dataset: &str) -> Result<Arc<TestSet>> {
        let mut sets = self.testsets.lock().unwrap();
        if let Some(t) = sets.get(dataset) {
            return Ok(t.clone());
        }
        let t = Arc::new(match self.backend_kind {
            BackendKind::Pjrt => {
                TestSet::load(&self.artifacts_dir.join(dataset).join("test.bin"))?
            }
            BackendKind::Reference => {
                SyntheticSpec::new(dataset).testset(crate::fixtures::DEFAULT_TEST_SAMPLES)?
            }
        });
        sets.insert(dataset.to_string(), t.clone());
        Ok(t)
    }

    pub fn run_config(&self, dataset: &str, scheme: Scheme) -> RunConfig {
        let mut cfg = RunConfig::new(self.artifacts_dir.clone(), dataset, scheme);
        cfg.backend = self.backend_kind;
        cfg
    }
}

/// Aggregated evaluation of one scheme over n test samples.
#[derive(Debug, Clone)]
pub struct SchemeEval {
    pub scheme: Scheme,
    pub dataset: String,
    pub n: usize,
    pub accuracy: f64,
    /// mean per-request latency breakdown (simulated device/network +
    /// measured server wall-clock)
    pub mean: LatencyBreakdown,
    pub mean_energy: EnergyLedger,
    pub mean_tx_bytes: f64,
    pub early_exit_rate: f64,
    pub memory: crate::simulator::MemoryReport,
}

impl SchemeEval {
    pub fn total_latency_s(&self) -> f64 {
        self.mean.total_s()
    }
}

/// Serve a scheme through the batched multi-device pipeline — the serving
/// counterpart of [`eval_scheme`]'s synchronous accounting. Reuses the
/// context's cached meta/test set. The figure sweeps run on
/// [`ClockKind::Sim`] so `cargo run -- bench` never sleeps through
/// arrival pacing and the reported quantiles are seed-deterministic.
pub fn serve_scheme(
    ctx: &EvalCtx,
    cfg: &RunConfig,
    devices: usize,
    n: usize,
    arrival: Arrival,
    clock: ClockKind,
) -> Result<PipelineReport> {
    let meta = ctx.meta(&cfg.dataset)?;
    let testset = ctx.testset(&cfg.dataset)?;
    Service::from_parts(cfg.clone(), meta, testset, devices, n, arrival)?
        .with_clock(clock)
        .run()
}

/// Evaluate a scheme under `cfg` over the first `n` test samples.
pub fn eval_scheme(ctx: &EvalCtx, cfg: &RunConfig, n: usize) -> Result<SchemeEval> {
    let meta = ctx.meta(&cfg.dataset)?;
    let testset = ctx.testset(&cfg.dataset)?;
    let mut runner = make_runner(ctx.backend.as_ref(), cfg, &meta)?;
    eval_with_runner(runner.as_mut(), &testset, &cfg.dataset, n)
}

/// Evaluation loop over an already-built runner (alpha sweeps etc. reuse the
/// runner to avoid recompiling executables).
pub fn eval_with_runner(
    runner: &mut dyn SchemeRunner,
    testset: &TestSet,
    dataset: &str,
    n: usize,
) -> Result<SchemeEval> {
    let n = n.min(testset.len());
    let mut acc = AccuracyCounter::default();
    let mut mean = LatencyBreakdown::default();
    let mut energy = EnergyLedger::default();
    let mut tx_total = 0usize;
    let mut exits = 0usize;
    for i in 0..n {
        let img = testset.image(i)?;
        let out = runner.process(&img, testset.labels[i])?;
        acc.record(out.correct);
        mean.local_nn_s += out.breakdown.local_nn_s;
        mean.compression_s += out.breakdown.compression_s;
        mean.network_s += out.breakdown.network_s;
        mean.remote_s += out.breakdown.remote_s;
        energy.add(&out.energy);
        tx_total += out.tx_bytes;
        exits += out.exited_early as usize;
    }
    let nf = n as f64;
    mean.local_nn_s /= nf;
    mean.compression_s /= nf;
    mean.network_s /= nf;
    mean.remote_s /= nf;
    energy.compute_j /= nf;
    energy.radio_j /= nf;
    Ok(SchemeEval {
        scheme: runner.scheme(),
        dataset: dataset.to_string(),
        n,
        accuracy: acc.accuracy(),
        mean,
        mean_energy: energy,
        mean_tx_bytes: tx_total as f64 / nf,
        early_exit_rate: exits as f64 / nf,
        memory: runner.memory_report(),
    })
}
