//! Table 2: reduction of transmitted data size vs DeepCOD, per dataset.

use super::common::{eval_n, eval_scheme, EvalCtx};
use crate::config::Scheme;
use crate::report::{pct, Table};
use anyhow::Result;

pub fn run(ctx: &EvalCtx) -> Result<Vec<Table>> {
    let mut t = Table::new(
        "Table 2: transmitted-bytes reduction vs DeepCOD",
        &["dataset", "agile_bytes", "deepcod_bytes", "reduction"],
    );
    for ds in &ctx.datasets {
        let agile = eval_scheme(ctx, &ctx.run_config(ds, Scheme::Agile), eval_n())?;
        let deepcod = eval_scheme(ctx, &ctx.run_config(ds, Scheme::Deepcod), eval_n())?;
        let reduction = 1.0 - agile.mean_tx_bytes / deepcod.mean_tx_bytes;
        t.row(vec![
            ds.clone(),
            format!("{:.0}", agile.mean_tx_bytes),
            format!("{:.0}", deepcod.mean_tx_bytes),
            pct(reduction),
        ]);
    }
    Ok(vec![t])
}
