//! Fig 23: end-to-end latency vs wireless bandwidth (6 Mbps WiFi down to a
//! 270 kbps BLE-class link). AgileNN's high feature sparsity keeps latency
//! bounded; DeepCOD/SPINN track the link rate.

use super::common::{eval_n, eval_scheme, EvalCtx};
use crate::config::Scheme;
use crate::report::{ms, Table};
use crate::simulator::NetworkProfile;
use anyhow::Result;

pub const BW_SWEEP_KBPS: [f64; 5] = [6000.0, 2000.0, 1000.0, 500.0, 270.0];

pub fn run(ctx: &EvalCtx) -> Result<Vec<Table>> {
    let mut tables = Vec::new();
    for ds in ctx.datasets.iter().filter(|d| d.contains("cifar100") || d.contains("svhn")) {
        let mut t = Table::new(
            format!("Fig 23 [{ds}]: total latency (ms) vs bandwidth"),
            &["scheme", "6Mbps", "2Mbps", "1Mbps", "500kbps", "270kbps"],
        );
        for scheme in [Scheme::Agile, Scheme::Deepcod, Scheme::Spinn, Scheme::EdgeOnly] {
            let mut cells = vec![scheme.name().to_string()];
            for kbps in BW_SWEEP_KBPS {
                let mut cfg = ctx.run_config(ds, scheme);
                cfg.network = if kbps <= 300.0 {
                    NetworkProfile::ble_270kbps()
                } else {
                    NetworkProfile::wifi_6mbps().with_bandwidth(kbps * 1e3)
                };
                let e = eval_scheme(ctx, &cfg, eval_n())?;
                cells.push(ms(e.total_latency_s()));
            }
            t.row(cells);
        }
        tables.push(t);
    }
    Ok(tables)
}
