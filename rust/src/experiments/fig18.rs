//! Fig 18: accuracy under different prediction re-weightings alpha (paper
//! §3.3's runtime knob). Sweeps alpha in [0,1]; reuses one runner to avoid
//! recompiling the PJRT executables per point.

use super::common::{eval_n, eval_with_runner, EvalCtx};
use crate::baselines::AgileRunner;
use crate::config::Scheme;
use crate::report::{pct, Table};
use anyhow::Result;

pub const ALPHA_SWEEP: [f64; 11] = [0.0, 0.1, 0.2, 0.3, 0.4, 0.5, 0.6, 0.7, 0.8, 0.9, 1.0];

pub fn run(ctx: &EvalCtx) -> Result<Vec<Table>> {
    let mut tables = Vec::new();
    for ds in ctx.datasets.iter().filter(|d| d.contains("cifar100") || d.contains("svhn")) {
        let meta = ctx.meta(ds)?;
        let testset = ctx.testset(ds)?;
        let cfg = ctx.run_config(ds, Scheme::Agile);
        let mut runner = AgileRunner::new(ctx.backend.as_ref(), &cfg, &meta)?;
        let mut t = Table::new(
            format!("Fig 18 [{ds}]: accuracy vs alpha (trained alpha={:.2})", meta.alpha),
            &["alpha", "accuracy"],
        );
        for alpha in ALPHA_SWEEP {
            runner.set_alpha(alpha)?;
            let e = eval_with_runner(&mut runner, &testset, ds, eval_n())?;
            t.row(vec![format!("{alpha:.1}"), pct(e.accuracy)]);
        }
        tables.push(t);
    }
    Ok(tables)
}
