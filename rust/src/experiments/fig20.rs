//! Fig 20: on-device memory (SRAM) and storage (flash) usage per scheme.
//! Static accounting — no inference needed.

use super::common::EvalCtx;
use crate::baselines::make_runner;
use crate::config::Scheme;
use crate::report::{kb, pct, Table};
use anyhow::Result;

pub fn run(ctx: &EvalCtx) -> Result<Vec<Table>> {
    let mut t = Table::new(
        "Fig 20: device memory/storage usage",
        &["dataset", "scheme", "sram_KB", "sram_%", "flash_KB", "flash_%", "fits"],
    );
    for ds in &ctx.datasets {
        let meta = ctx.meta(ds)?;
        for scheme in Scheme::all() {
            let cfg = ctx.run_config(ds, scheme);
            let runner = make_runner(ctx.backend.as_ref(), &cfg, &meta)?;
            let m = runner.memory_report();
            t.row(vec![
                ds.clone(),
                scheme.name().into(),
                kb(m.sram_used),
                pct(m.sram_frac()),
                kb(m.flash_used),
                pct(m.flash_frac()),
                if m.fits() { "yes".into() } else { "NO".into() },
            ]);
        }
    }
    Ok(vec![t])
}
