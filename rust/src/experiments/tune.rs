//! Autotuner demonstration (`bench --figure tune`): search the serving
//! config space with the fleet engine as the evaluator and print the
//! Pareto fronts both strategies find.
//!
//! Two tables:
//!
//! 1. exhaustive sweep of the default 8-point grid (2 batch deadlines ×
//!    2 quantizer widths × 2 server counts) — every point evaluated, the
//!    non-dominated subset shown;
//! 2. a seeded genetic search over a wider 64-point space under a small
//!    evaluation budget — what a long search's front looks like when
//!    exhaustion is off the table.
//!
//! Both searches are in-memory here (no `--state`); the durable-resume
//! path is exercised by the integration suite and the CI smoke leg.

use super::common::EvalCtx;
use crate::net::DeliveryPolicy;
use crate::report::{ms, pct, Table};
use crate::serve::Placement;
use crate::tune::{self, EvalSpec, Objectives, SearchSpace, StrategyKind, TuneConfig, TunePoint};
use anyhow::Result;

fn eval_spec(ctx: &EvalCtx, dataset: &str) -> EvalSpec {
    EvalSpec {
        artifacts_dir: Some(ctx.artifacts_dir.clone()),
        dataset: dataset.to_string(),
        backend: ctx.backend_kind,
        devices: 16,
        requests: 4000,
        rate_hz: 50.0,
        ..EvalSpec::default()
    }
}

fn front_table(title: String, front: &[(TunePoint, Objectives)]) -> Table {
    let mut t = Table::new(
        title,
        &[
            "deadline_us",
            "bits",
            "delivery",
            "placement",
            "servers",
            "accuracy",
            "p99_ms",
            "goodput_kbps",
            "server_s",
        ],
    );
    for (p, o) in front {
        t.row(vec![
            p.batch_deadline_us.to_string(),
            p.bits.to_string(),
            p.delivery.name().into(),
            p.placement.name().into(),
            p.servers.to_string(),
            pct(o.accuracy),
            ms(o.p99_latency_s),
            format!("{:.1}", o.goodput_bps / 1e3),
            format!("{:.2}", o.server_seconds),
        ]);
    }
    t
}

pub fn run(ctx: &EvalCtx) -> Result<Vec<Table>> {
    let ds = ctx.datasets.first().cloned().unwrap_or_else(|| "synthetic".into());
    let mut tables = Vec::new();

    // 1) exhaustive over the default grid
    let cfg = TuneConfig {
        space: SearchSpace::default(),
        eval: eval_spec(ctx, &ds),
        strategy: StrategyKind::Exhaustive,
        state: None,
        out: None,
        stop_after: None,
        trace: crate::obs::Tracer::off(),
    };
    let grid = cfg.space.len();
    let out = tune::run(&cfg, |_| {})?;
    tables.push(front_table(
        format!(
            "Tune [{ds}]: exhaustive front — {} of {grid} grid points non-dominated \
             ({} infeasible)",
            out.front.len(),
            out.infeasible
        ),
        &out.front,
    ));

    // 2) seeded genetic over a wider space, budget-bounded
    let cfg = TuneConfig {
        space: SearchSpace {
            batch_deadline_us: vec![250, 500, 1000, 2000],
            packet_payload: vec![None],
            bits: vec![1, 2, 4, 8],
            delivery: vec![DeliveryPolicy::Arq, DeliveryPolicy::Anytime { deadline_s: 0.005 }],
            placement: vec![Placement::Static],
            servers: vec![1, 2],
            autoscale: vec![false],
            policy: vec![false],
        },
        eval: eval_spec(ctx, &ds),
        strategy: StrategyKind::Genetic { seed: 7, population: 8, budget: 24 },
        state: None,
        out: None,
        stop_after: None,
        trace: crate::obs::Tracer::off(),
    };
    let wide = cfg.space.len();
    let out = tune::run(&cfg, |_| {})?;
    tables.push(front_table(
        format!(
            "Tune [{ds}]: genetic front (seed 7, budget 24 of {wide} points) — \
             {} evaluated, {} non-dominated",
            out.evaluated,
            out.front.len()
        ),
        &out.front,
    ));
    Ok(tables)
}
