//! Closed-loop autoscaling under diurnal fleet load (`bench --figure
//! autoscale`): the SLO control plane's headline experiment.
//!
//! One million requests from ten thousand devices whose per-device rate
//! follows a raised-cosine day/night cycle (0.4 → 4 Hz), served under a
//! virtual per-batch service-time model. Two tables:
//!
//! 1. provisioning comparison — a fleet fixed at the diurnal peak, a
//!    fleet fixed at the trough-sized initial fleet, and the autoscaled
//!    fleet (SLO controller, 1..8 servers). The autoscaled run should
//!    hold p99 near the peak-fixed fleet while spending measurably fewer
//!    integrated server-seconds (a retired shard stops billing);
//! 2. the autoscaled fleet's per-shard breakdown, whose `active_s`
//!    column shows which shards the controller ever woke and for how
//!    long.
//!
//! Scale knobs: `AGILENN_FLEET_N` / `AGILENN_FLEET_DEVICES` override the
//! request/device counts (the CI smoke runs a reduced trace); the PJRT
//! backend defaults two orders of magnitude smaller.

use super::common::EvalCtx;
use crate::config::{BackendKind, Scheme};
use crate::report::{ms, pct, Table};
use crate::serve::{
    AutoscaleConfig, ClockKind, Placement, PipelineReport, Service, ServiceModel,
};
use crate::workload::Arrival;
use anyhow::Result;
use std::time::Instant;

fn env_usize(key: &str, default: usize) -> usize {
    std::env::var(key).ok().and_then(|s| s.parse().ok()).unwrap_or(default)
}

/// (requests, devices) for the diurnal sweep.
fn scale(ctx: &EvalCtx) -> (usize, usize) {
    let (n, d) = match ctx.backend_kind {
        BackendKind::Reference => (1_000_000, 10_000),
        BackendKind::Pjrt => (2_000, 16),
    };
    (env_usize("AGILENN_FLEET_N", n), env_usize("AGILENN_FLEET_DEVICES", d))
}

/// Day/night cycle: per-device rate swings 0.4 → 4 Hz over 20 virtual
/// seconds, so the ~45 s run crosses two peaks and two troughs.
const DIURNAL: Arrival =
    Arrival::Diurnal { period_s: 20.0, base_hz: 0.4, peak_hz: 4.0, seed: 16 };
/// Virtual batch cost: 0.5 ms + 0.1 ms/sample (~6 150 req/s per server
/// at the default batch size of 8).
const SERVICE: (f64, f64) = (0.5e-3, 0.1e-3);
const SLO_P99_S: f64 = 50e-3;
const MAX_SERVERS: usize = 8;
const INITIAL_SERVERS: usize = 2;

struct FleetRun {
    rep: PipelineReport,
    host_s: f64,
}

fn run_fleet(
    ctx: &EvalCtx,
    dataset: &str,
    requests: usize,
    devices: usize,
    servers: usize,
    autoscale: Option<AutoscaleConfig>,
) -> Result<FleetRun> {
    let cfg = ctx.run_config(dataset, Scheme::Agile);
    let meta = ctx.meta(dataset)?;
    let testset = ctx.testset(dataset)?;
    let t0 = Instant::now();
    let rep = Service::from_parts(cfg, meta, testset, devices, requests, DIURNAL)?
        .with_clock(ClockKind::Sim)
        .with_servers(servers, Placement::WeightedLeastLoaded)
        .with_service_model(ServiceModel {
            base_s: SERVICE.0,
            per_sample_s: SERVICE.1,
            capacities: Vec::new(),
        })
        .with_autoscale(autoscale)
        .with_slo_p99(SLO_P99_S)
        .run()?;
    Ok(FleetRun { rep, host_s: t0.elapsed().as_secs_f64() })
}

pub fn run(ctx: &EvalCtx) -> Result<Vec<Table>> {
    let mut tables = Vec::new();
    let (requests, devices) = scale(ctx);
    let ds = ctx.datasets.first().cloned().unwrap_or_else(|| "synthetic".into());

    let configs: [(&str, usize, Option<AutoscaleConfig>); 3] = [
        ("fixed@peak", MAX_SERVERS, None),
        ("fixed@initial", INITIAL_SERVERS, None),
        ("autoscaled", INITIAL_SERVERS, Some(AutoscaleConfig::new(1, MAX_SERVERS))),
    ];
    let mut t = Table::new(
        format!(
            "Autoscale [{ds}]: diurnal load, {requests} requests x {devices} devices \
             (0.4-4 Hz/device over 20 s virtual, weighted placement, \
             p99 SLO {} ms)",
            ms(SLO_P99_S)
        ),
        &[
            "config",
            "p99_ms",
            "slo_attained",
            "server_seconds",
            "scale_outs",
            "scale_ins",
            "host_s",
        ],
    );
    let mut autoscaled: Option<FleetRun> = None;
    for (name, servers, scale_cfg) in configs {
        let run = run_fleet(ctx, &ds, requests, devices, servers, scale_cfg.clone())?;
        t.row(vec![
            name.into(),
            ms(run.rep.p99_latency_s),
            pct(run.rep.slo_attainment),
            format!("{:.1}", run.rep.server_seconds),
            run.rep.scale_outs.to_string(),
            run.rep.scale_ins.to_string(),
            format!("{:.1}", run.host_s),
        ]);
        if scale_cfg.is_some() {
            autoscaled = Some(run);
        }
    }
    tables.push(t);

    // 2) where the controller actually spent the fleet: per-shard
    //    lifetimes of the autoscaled run
    let auto = autoscaled.expect("the autoscaled config ran");
    let mut t2 = Table::new(
        format!(
            "Autoscale [{ds}]: autoscaled per-shard breakdown — {} scale-outs, \
             {} scale-ins over {:.1} s virtual",
            auto.rep.scale_outs, auto.rep.scale_ins, auto.rep.wall_s
        ),
        &["server", "requests", "batches", "queue_p95_ms", "active_s"],
    );
    for s in &auto.rep.shards {
        t2.row(vec![
            s.server.to_string(),
            s.requests.to_string(),
            s.batches.to_string(),
            ms(s.p95_queue_s),
            format!("{:.2}", s.active_s),
        ]);
    }
    tables.push(t2);
    Ok(tables)
}
