//! Fig 16: end-to-end inference latency breakdown + accuracy, all datasets x
//! all schemes (the paper's headline comparison) — plus the same scheme set
//! served under load through the batched multi-device pipeline, so the
//! comparison also covers throughput/latency with concurrent devices.

use super::common::{eval_n, eval_scheme, serve_scheme, EvalCtx};
use crate::config::Scheme;
use crate::report::{ms, pct, Table};
use crate::serve::ClockKind;
use crate::workload::Arrival;
use anyhow::Result;

pub fn run(ctx: &EvalCtx) -> Result<Vec<Table>> {
    let mut tables = Vec::new();
    for ds in &ctx.datasets {
        let mut t = Table::new(
            format!("Fig 16 [{ds}]: latency breakdown (ms) + accuracy"),
            &["scheme", "local_nn", "compress", "network", "remote", "total", "accuracy"],
        );
        for scheme in Scheme::all() {
            let cfg = ctx.run_config(ds, scheme);
            let e = eval_scheme(ctx, &cfg, eval_n())?;
            t.row(vec![
                scheme.name().into(),
                ms(e.mean.local_nn_s),
                ms(e.mean.compression_s),
                ms(e.mean.network_s),
                ms(e.mean.remote_s),
                ms(e.total_latency_s()),
                pct(e.accuracy),
            ]);
        }
        tables.push(t);

        // the under-load table runs on the sim clock: arrival pacing and
        // batch deadlines play out in virtual time, so the sweep is fast
        // (no sleeps) and its quantiles are seed-deterministic
        let mut t2 = Table::new(
            format!("Fig 16 [{ds}]: served under load (4 devices, batched, sim clock)"),
            &["scheme", "throughput_rps", "p95_ms", "mean_batch", "accuracy"],
        );
        for scheme in Scheme::all() {
            let cfg = ctx.run_config(ds, scheme);
            let rep = serve_scheme(
                ctx,
                &cfg,
                4,
                eval_n(),
                Arrival::Poisson { hz: 100.0, seed: 16 },
                ClockKind::Sim,
            )?;
            t2.row(vec![
                scheme.name().into(),
                format!("{:.1}", rep.throughput_rps),
                ms(rep.p95_latency_s),
                format!("{:.2}", rep.mean_batch_size),
                pct(rep.accuracy),
            ]);
        }
        tables.push(t2);
    }
    Ok(tables)
}
