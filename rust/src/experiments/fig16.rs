//! Fig 16: end-to-end inference latency breakdown + accuracy, all datasets x
//! all schemes (the paper's headline comparison).

use super::common::{eval_n, eval_scheme, EvalCtx};
use crate::config::Scheme;
use crate::report::{ms, pct, Table};
use anyhow::Result;

pub fn run(ctx: &EvalCtx) -> Result<Vec<Table>> {
    let mut tables = Vec::new();
    for ds in &ctx.datasets {
        let mut t = Table::new(
            format!("Fig 16 [{ds}]: latency breakdown (ms) + accuracy"),
            &["scheme", "local_nn", "compress", "network", "remote", "total", "accuracy"],
        );
        for scheme in Scheme::all() {
            let cfg = ctx.run_config(ds, scheme);
            let e = eval_scheme(ctx, &cfg, eval_n())?;
            t.row(vec![
                scheme.name().into(),
                ms(e.mean.local_nn_s),
                ms(e.mean.compression_s),
                ms(e.mean.network_s),
                ms(e.mean.remote_s),
                ms(e.total_latency_s()),
                pct(e.accuracy),
            ]);
        }
        tables.push(t);
    }
    Ok(tables)
}
