//! Fleet-scale serving sweeps on the discrete-event engine (`bench
//! --figure fleet`): the ROADMAP's "heavy traffic from millions of users"
//! regime, far beyond what the paper's 8-device tables exercise.
//!
//! Three tables:
//!
//! 1. the headline sweep — 1M requests × 10k devices through a 4-server
//!    least-loaded topology, with per-shard load/latency (seconds of host
//!    time on the reference backend; the CI rust job runs it under a
//!    5-minute timeout);
//! 2. placement-policy comparison (static / round-robin / least-loaded)
//!    at a reduced scale, including the shard imbalance each policy
//!    leaves behind;
//! 3. server scaling: how p95 sojourn and batch-queue wait move as the
//!    same offered load spreads over 1 → 8 servers.
//!
//! Scale knobs: `AGILENN_FLEET_N` / `AGILENN_FLEET_DEVICES` override the
//! request/device counts; the PJRT backend defaults two orders of
//! magnitude smaller (real NN execution per request — the fleet regime is
//! the reference backend's job).

use super::common::EvalCtx;
use crate::config::{BackendKind, Scheme};
use crate::report::{ms, pct, Table};
use crate::serve::{ClockKind, Placement, PipelineReport, Service};
use crate::workload::Arrival;
use anyhow::Result;
use std::time::Instant;

fn env_usize(key: &str, default: usize) -> usize {
    std::env::var(key).ok().and_then(|s| s.parse().ok()).unwrap_or(default)
}

/// (requests, devices) for the headline sweep.
fn scale(ctx: &EvalCtx) -> (usize, usize) {
    let (n, d) = match ctx.backend_kind {
        BackendKind::Reference => (1_000_000, 10_000),
        // PJRT executes a real NN per request; keep the smoke honest but
        // small
        BackendKind::Pjrt => (2_000, 16),
    };
    (env_usize("AGILENN_FLEET_N", n), env_usize("AGILENN_FLEET_DEVICES", d))
}

struct FleetRun {
    rep: PipelineReport,
    host_s: f64,
}

fn run_fleet(
    ctx: &EvalCtx,
    dataset: &str,
    requests: usize,
    devices: usize,
    servers: usize,
    placement: Placement,
) -> Result<FleetRun> {
    let cfg = ctx.run_config(dataset, Scheme::Agile);
    let meta = ctx.meta(dataset)?;
    let testset = ctx.testset(dataset)?;
    let t0 = Instant::now();
    let rep = Service::from_parts(
        cfg,
        meta,
        testset,
        devices,
        requests,
        Arrival::Poisson { hz: 20.0, seed: 16 },
    )?
    .with_clock(ClockKind::Sim)
    .with_servers(servers, placement)
    .run()?;
    Ok(FleetRun { rep, host_s: t0.elapsed().as_secs_f64() })
}

/// max/min offloads across shards (1.0 = perfectly balanced).
fn imbalance(rep: &PipelineReport) -> f64 {
    let max = rep.shards.iter().map(|s| s.requests).max().unwrap_or(0);
    let min = rep.shards.iter().map(|s| s.requests).min().unwrap_or(0);
    if min == 0 {
        f64::INFINITY
    } else {
        max as f64 / min as f64
    }
}

pub fn run(ctx: &EvalCtx) -> Result<Vec<Table>> {
    let mut tables = Vec::new();
    let (requests, devices) = scale(ctx);
    let ds = ctx.datasets.first().cloned().unwrap_or_else(|| "synthetic".into());

    // 1) headline: the full fleet through 4 least-loaded servers
    let head = run_fleet(ctx, &ds, requests, devices, 4, Placement::LeastLoaded)?;
    let mut t = Table::new(
        format!(
            "Fleet [{ds}]: {requests} requests x {devices} devices, 4 servers \
             (least-loaded, sim engine) — {:.1}s host, {:.0} req/s host, \
             sojourn p95 {} ms / p99 {} ms",
            head.host_s,
            requests as f64 / head.host_s.max(1e-9),
            ms(head.rep.p95_latency_s),
            ms(head.rep.p99_latency_s),
        ),
        &["server", "requests", "batches", "mean_batch", "queue_mean_ms", "queue_p95_ms"],
    );
    for s in &head.rep.shards {
        t.row(vec![
            s.server.to_string(),
            s.requests.to_string(),
            s.batches.to_string(),
            format!("{:.2}", s.mean_batch_size),
            ms(s.mean_queue_s),
            ms(s.p95_queue_s),
        ]);
    }
    // totals row: the queue columns are per-shard quantities and do not
    // aggregate into one number, so they stay blank here (sojourn latency
    // lives in the title)
    t.row(vec![
        "all".into(),
        head.rep.requests.to_string(),
        head.rep.batches.to_string(),
        format!("{:.2}", head.rep.mean_batch_size),
        "-".into(),
        "-".into(),
    ]);
    tables.push(t);

    // 2) placement comparison at reduced scale
    let (n2, d2) = ((requests / 5).max(1000), (devices / 10).max(8));
    let mut t2 = Table::new(
        format!("Fleet [{ds}]: placement policies ({n2} requests x {d2} devices, 4 servers)"),
        &["placement", "throughput_rps", "p95_ms", "p99_ms", "shard_imbalance", "accuracy"],
    );
    for placement in [Placement::Static, Placement::RoundRobin, Placement::LeastLoaded] {
        let run = run_fleet(ctx, &ds, n2, d2, 4, placement)?;
        t2.row(vec![
            placement.name().into(),
            format!("{:.1}", run.rep.throughput_rps),
            ms(run.rep.p95_latency_s),
            ms(run.rep.p99_latency_s),
            format!("{:.2}", imbalance(&run.rep)),
            pct(run.rep.accuracy),
        ]);
    }
    tables.push(t2);

    // 3) server scaling under the same offered load
    let mut t3 = Table::new(
        format!("Fleet [{ds}]: server scaling ({n2} requests x {d2} devices, least-loaded)"),
        &["servers", "p95_ms", "p99_ms", "queue_mean_ms", "batches", "mean_batch"],
    );
    for servers in [1usize, 2, 4, 8] {
        let run = run_fleet(ctx, &ds, n2, d2, servers, Placement::LeastLoaded)?;
        let queue_mean = if run.rep.shards.is_empty() {
            0.0
        } else {
            run.rep.shards.iter().map(|s| s.mean_queue_s).sum::<f64>()
                / run.rep.shards.len() as f64
        };
        t3.row(vec![
            servers.to_string(),
            ms(run.rep.p95_latency_s),
            ms(run.rep.p99_latency_s),
            ms(queue_mean),
            run.rep.batches.to_string(),
            format!("{:.2}", run.rep.mean_batch_size),
        ]);
    }
    tables.push(t3);
    Ok(tables)
}
