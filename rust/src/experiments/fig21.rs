//! Fig 21: effectiveness of skewness manipulation under different
//! requirements (k, rho) — achieved skewness, accuracy, transmission latency.
//!
//! The full sweep needs 9 trained variants (`make fig21-train`, writes
//! artifacts/fig21/k{K}_rho{R}/meta.json). When the sweep artifacts are
//! absent, we report the main trained point from each dataset's meta.json so
//! the bench always produces the figure's series shape.

use super::common::EvalCtx;
use crate::report::{pct, Table};
use crate::simulator::{NetworkProfile, NetworkSim};
use anyhow::Result;

/// Slim meta for sweep variants (written by compile/experiments/fig21_variants.py).
#[derive(Debug)]
struct VariantMeta {
    k: usize,
    rho: f64,
    accuracy: f64,
    achieved_skewness: f64,
    mean_tx_payload_bytes: f64,
}

impl VariantMeta {
    fn parse(text: &str) -> Result<Self> {
        let v = crate::json::Value::parse(text)?;
        Ok(Self {
            k: v.usize_at("k")?,
            rho: v.f64_at("rho")?,
            accuracy: v.f64_at("accuracy")?,
            achieved_skewness: v.f64_at("achieved_skewness")?,
            mean_tx_payload_bytes: v.f64_at("mean_tx_payload_bytes")?,
        })
    }
}

pub fn run(ctx: &EvalCtx) -> Result<Vec<Table>> {
    let sweep_dir = ctx.artifacts_dir.join("fig21");
    let net = NetworkSim::new(NetworkProfile::wifi_6mbps());
    let mut t = Table::new(
        "Fig 21: skewness manipulation effectiveness",
        &["source", "k", "rho_target", "achieved_skew", "accuracy", "tx_latency_ms"],
    );
    let mut found_sweep = false;
    if sweep_dir.is_dir() {
        let mut entries: Vec<_> = std::fs::read_dir(&sweep_dir)?
            .filter_map(|e| e.ok())
            .filter(|e| e.path().join("meta.json").exists())
            .collect();
        entries.sort_by_key(|e| e.file_name());
        for e in entries {
            let text = std::fs::read_to_string(e.path().join("meta.json"))?;
            let v = VariantMeta::parse(&text)?;
            t.row(vec![
                format!("sweep/{}", e.file_name().to_string_lossy()),
                v.k.to_string(),
                format!("{:.2}", v.rho),
                pct(v.achieved_skewness),
                pct(v.accuracy),
                format!("{:.2}", net.transfer_s(v.mean_tx_payload_bytes as usize) * 1e3),
            ]);
            found_sweep = true;
        }
    }
    if !found_sweep {
        // fall back to the trained point of every dataset
        for ds in &ctx.datasets {
            let meta = ctx.meta(ds)?;
            let eval = super::common::eval_scheme(
                ctx,
                &ctx.run_config(ds, crate::config::Scheme::Agile),
                super::common::eval_n(),
            )?;
            t.row(vec![
                ds.clone(),
                meta.k.to_string(),
                format!("{:.2}", meta.rho),
                pct(meta.importance.achieved_skewness_mean),
                pct(eval.accuracy),
                format!("{:.2}", net.transfer_s(eval.mean_tx_bytes as usize) * 1e3),
            ]);
        }
        t.title.push_str("  [run `make fig21-train` for the full (k,rho) sweep]");
    }
    Ok(vec![t])
}
