//! Adaptive split/rate policy (`bench --figure adaptive`): static
//! operating points vs the per-request [`crate::serve::policy`] on the
//! loss sweep (PR 2) and the diurnal fleet trace (PR 9).
//!
//! Three legs:
//!
//! 1. loss sweep — AgileNN accuracy and p99 link latency vs packet-loss
//!    rate for two static widths (4-bit and 1-bit, both ARQ) and the
//!    adaptive policy starting at 4 bits over the [1, 2, 4] ladder. The
//!    policy should track the 4-bit column on a clean channel and move
//!    toward the 1-bit column's latency as loss grows — matching or
//!    dominating the static points at ≥ 30% loss;
//! 2. what the policy actually did per loss point — switches, the mean
//!    chosen width, and the chosen-width histogram;
//! 3. diurnal trace — the PR-9 day/night arrival cycle over a priced
//!    fleet with a lossy channel, static vs adaptive, where the server's
//!    advertised queue depth (not just link stats) drives the ladder.
//!
//! All runs share channel seeds, so every comparison is paired.

use super::common::{eval_n, serve_scheme, EvalCtx};
use super::netsweep::LOSS_SWEEP;
use crate::config::{BackendKind, RunConfig, Scheme};
use crate::net::GilbertElliott;
use crate::report::{ms, pct, Table};
use crate::serve::{
    ClockKind, Placement, PipelineReport, PolicyConfig, Service, ServiceModel,
};
use crate::workload::Arrival;
use anyhow::Result;

/// Anytime packet payload cap, matching the netsweep figure: small enough
/// that a 4-bit AgileNN frame spans ~a dozen packets, so per-packet loss
/// (and the policy's delivered-rate signal) is well exercised.
const PAYLOAD_CAP: usize = 64;

/// Unconteded per-device arrival rate for the loss sweep (free under the
/// sim clock).
const SWEEP_RATE_HZ: f64 = 30.0;
const SWEEP_DEVICES: usize = 4;

/// Diurnal leg: the PR-9 day/night cycle (0.4 → 4 Hz per device over 20
/// virtual seconds) on a priced fleet, plus a bursty 30%-loss channel.
const DIURNAL: Arrival =
    Arrival::Diurnal { period_s: 20.0, base_hz: 0.4, peak_hz: 4.0, seed: 16 };
const DIURNAL_LOSS: f64 = 0.3;
const SERVICE: (f64, f64) = (0.5e-3, 0.1e-3);
const SLO_P99_S: f64 = 50e-3;

fn env_usize(key: &str, default: usize) -> usize {
    std::env::var(key).ok().and_then(|s| s.parse().ok()).unwrap_or(default)
}

/// (requests, devices) for the diurnal leg (env-overridable like the
/// autoscale figure; the CI smoke runs a reduced trace).
fn diurnal_scale(ctx: &EvalCtx) -> (usize, usize) {
    let (n, d) = match ctx.backend_kind {
        BackendKind::Reference => (50_000, 200),
        BackendKind::Pjrt => (2_000, 16),
    };
    (env_usize("AGILENN_FLEET_N", n), env_usize("AGILENN_FLEET_DEVICES", d))
}

/// The figure's policy: the default [1, 2, 4] ladder with the anytime
/// rung armed, no local-only fallback (every request keeps the remote
/// path, so accuracy columns compare like for like).
fn figure_policy() -> PolicyConfig {
    PolicyConfig::default()
}

fn base_config(ctx: &EvalCtx, ds: &str, loss_rate: f64, bits: u32) -> RunConfig {
    let mut cfg = ctx.run_config(ds, Scheme::Agile);
    cfg.batch.max_batch = 1; // b1 executable everywhere: bitwise-stable logits
    cfg.bits = bits;
    cfg.net.loss = if loss_rate > 0.0 {
        GilbertElliott::bursty(loss_rate, 4.0)
    } else {
        GilbertElliott::lossless()
    };
    cfg.net.packet_payload = Some(PAYLOAD_CAP);
    cfg.net.seed = 42; // shared across rows: paired loss patterns
    cfg
}

fn run_sweep_point(
    ctx: &EvalCtx,
    ds: &str,
    loss_rate: f64,
    bits: u32,
    adaptive: bool,
    n: usize,
) -> Result<PipelineReport> {
    let mut cfg = base_config(ctx, ds, loss_rate, bits);
    if adaptive {
        cfg.policy = Some(figure_policy());
    }
    serve_scheme(
        ctx,
        &cfg,
        SWEEP_DEVICES,
        n,
        Arrival::Periodic { hz: SWEEP_RATE_HZ },
        ClockKind::Sim,
    )
}

fn run_diurnal(
    ctx: &EvalCtx,
    ds: &str,
    requests: usize,
    devices: usize,
    adaptive: bool,
) -> Result<PipelineReport> {
    let mut cfg = base_config(ctx, ds, DIURNAL_LOSS, 4);
    cfg.batch.max_batch = 8;
    if adaptive {
        cfg.policy = Some(figure_policy());
    }
    let meta = ctx.meta(ds)?;
    let testset = ctx.testset(ds)?;
    Service::from_parts(cfg, meta, testset, devices, requests, DIURNAL)?
        .with_clock(ClockKind::Sim)
        .with_servers(2, Placement::WeightedLeastLoaded)
        .with_service_model(ServiceModel {
            base_s: SERVICE.0,
            per_sample_s: SERVICE.1,
            capacities: Vec::new(),
        })
        .with_slo_p99(SLO_P99_S)
        .run()
}

struct SweepRow {
    label: &'static str,
    bits: u32,
    adaptive: bool,
}

const SWEEP_ROWS: [SweepRow; 3] = [
    SweepRow { label: "static/4-bit arq", bits: 4, adaptive: false },
    SweepRow { label: "static/1-bit arq", bits: 1, adaptive: false },
    SweepRow { label: "adaptive", bits: 4, adaptive: true },
];

fn policy_cells(rep: &PipelineReport) -> (String, String, String) {
    match &rep.policy {
        None => ("-".into(), "-".into(), "-".into()),
        Some(p) => {
            let widths: Vec<String> =
                p.widths.iter().map(|(w, n)| format!("{w}b x{n}")).collect();
            (p.switches.to_string(), format!("{:.2}", p.mean_bits), widths.join(" "))
        }
    }
}

pub fn run(ctx: &EvalCtx) -> Result<Vec<Table>> {
    let mut tables = Vec::new();
    let Some(ds) = ctx.datasets.first().cloned() else {
        return Ok(tables);
    };
    let n = eval_n();
    let headers = ["config", "0%", "10%", "30%", "50%"];
    let mut acc = Table::new(
        format!("Adaptive [{ds}]: AgileNN accuracy vs packet loss ({n} reqs)"),
        &headers,
    );
    let mut lat = Table::new(
        format!("Adaptive [{ds}]: p99 simulated link latency (ms)"),
        &headers,
    );
    let mut ops = Table::new(
        format!("Adaptive [{ds}]: what the policy did per loss point"),
        &["loss", "switches", "mean_bits", "chosen widths"],
    );
    let mut adaptive_reps: Vec<(f64, PipelineReport)> = Vec::new();
    for row in &SWEEP_ROWS {
        let mut acc_cells = vec![row.label.to_string()];
        let mut lat_cells = vec![row.label.to_string()];
        for loss_rate in LOSS_SWEEP {
            let rep = run_sweep_point(ctx, &ds, loss_rate, row.bits, row.adaptive, n)?;
            acc_cells.push(pct(rep.accuracy));
            lat_cells.push(ms(rep.p99_net_s));
            if row.adaptive {
                adaptive_reps.push((loss_rate, rep));
            }
        }
        acc.row(acc_cells);
        lat.row(lat_cells);
    }
    for (loss_rate, rep) in &adaptive_reps {
        let (switches, mean_bits, widths) = policy_cells(rep);
        ops.row(vec![pct(*loss_rate), switches, mean_bits, widths]);
    }
    tables.push(acc);
    tables.push(lat);
    tables.push(ops);

    // diurnal leg: queue-depth pressure, not just link stats
    let (requests, devices) = diurnal_scale(ctx);
    let mut t = Table::new(
        format!(
            "Adaptive [{ds}]: diurnal trace, {requests} requests x {devices} devices \
             (0.4-4 Hz/device over 20 s virtual, {}% bursty loss, p99 SLO {} ms)",
            (DIURNAL_LOSS * 100.0) as u32,
            ms(SLO_P99_S)
        ),
        &[
            "config",
            "accuracy",
            "p99_ms",
            "slo_attained",
            "switches",
            "mean_bits",
            "chosen widths",
        ],
    );
    for (label, adaptive) in [("static/4-bit arq", false), ("adaptive", true)] {
        let rep = run_diurnal(ctx, &ds, requests, devices, adaptive)?;
        let (switches, mean_bits, widths) = policy_cells(&rep);
        t.row(vec![
            label.into(),
            pct(rep.accuracy),
            ms(rep.p99_latency_s),
            pct(rep.slo_attainment),
            switches,
            mean_bits,
            widths,
        ]);
    }
    tables.push(t);
    Ok(tables)
}
