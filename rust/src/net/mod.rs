//! `agilenn::net` — the lossy, trace-driven channel subsystem.
//!
//! The original link model (`simulator::NetworkSim`) was a closed-form
//! transfer-time formula: no loss, no time variation, no notion of *which*
//! bytes matter. This subsystem replaces the wire underneath serving with:
//!
//! * [`Channel`] — a seeded, deterministic link: Gilbert–Elliott bursty
//!   packet loss ([`GilbertElliott`]), time-varying bandwidth replayed
//!   from a [`BandwidthTrace`], and per-packet delivery timestamps. The
//!   zero-loss constant-bandwidth special case ([`Channel::ideal`])
//!   reproduces the old `NetworkSim` exactly — which is now implemented on
//!   top of it, so the two models cannot drift.
//! * [`Packetizer`] — uplink frames split into payload-capped packets
//!   *ordered by XAI importance rank* ([`importance_order`]), each
//!   independently decodable via a small header (frame id, order-space
//!   feature range, seq), so the server can reconstruct from any subset.
//! * [`DeliveryPolicy`] — ARQ (retransmit until complete; latency pays)
//!   vs. deadline-bounded anytime (the server decodes whatever arrived by
//!   the deadline, imputing missing features; accuracy degrades
//!   gracefully — and *most* gracefully when the most important features
//!   were sent first). Selected via `ServeBuilder::delivery`.
//! * [`wire`] — the versioned, length-prefixed envelope the cross-process
//!   transports (the TCP serving daemon and device client,
//!   [`crate::serve::daemon`]) speak. Frame and packet headers carry a
//!   protocol magic + version byte; mismatched peers are rejected with a
//!   typed [`WireError`] instead of garbage-decoded.
//!
//! All stochastic behavior is seed-deterministic: the same
//! [`NetConfig::seed`] yields the same loss pattern, byte for byte.

pub mod channel;
pub mod delivery;
pub mod packetizer;
pub mod wire;

pub use channel::{BandwidthTrace, Channel, GilbertElliott, PacketTx};
pub use delivery::{
    transmit_frame, transmit_frame_traced, transmit_packets, transmit_packets_traced,
    DeliveryPolicy, LinkOutcome, NetStats, MAX_ARQ_ROUNDS,
};
pub use packetizer::{
    importance_order, reassemble_symbols, Packet, PacketOrder, Packetizer, PACKET_HEADER_BYTES,
};
pub use wire::{Hello, WireError, WireMsg, WIRE_MAGIC, WIRE_VERSION};

/// Channel-facing knobs of one serving run (lives in `RunConfig.net`; the
/// defaults are the ideal link, making the pre-channel behavior the
/// zero-loss special case).
#[derive(Debug, Clone, PartialEq)]
pub struct NetConfig {
    /// packet-loss process (default: lossless)
    pub loss: GilbertElliott,
    /// replayable bandwidth trace (default: constant profile bandwidth)
    pub trace: Option<BandwidthTrace>,
    /// uplink delivery policy (default: ARQ)
    pub delivery: DeliveryPolicy,
    /// packet ordering under the anytime policy (default: importance)
    pub order: PacketOrder,
    /// max application bytes per anytime packet, header included
    /// (default: link MTU)
    pub packet_payload: Option<usize>,
    /// seed for the loss process (per-device streams are derived from it)
    pub seed: u64,
}

impl Default for NetConfig {
    fn default() -> Self {
        Self {
            loss: GilbertElliott::lossless(),
            trace: None,
            delivery: DeliveryPolicy::Arq,
            order: PacketOrder::Importance,
            packet_payload: None,
            seed: 42,
        }
    }
}

impl NetConfig {
    /// True when the channel is behaviorally identical to the pre-channel
    /// closed-form link model (no loss, no bandwidth variation).
    pub fn is_ideal(&self) -> bool {
        self.loss.is_lossless() && self.trace.is_none()
    }

    /// Resolved per-packet payload cap for a link MTU.
    pub fn payload_cap(&self, mtu: usize) -> usize {
        self.packet_payload.unwrap_or(mtu).min(mtu).max(PACKET_HEADER_BYTES + 1)
    }

    /// Per-device channel seed: decorrelates device loss streams while
    /// keeping the whole run reproducible from one seed (shared derivation
    /// with the per-device arrival streams).
    pub fn device_seed(&self, device_index: usize) -> u64 {
        crate::workload::derive_device_seed(self.seed, device_index)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_config_is_ideal() {
        let c = NetConfig::default();
        assert!(c.is_ideal());
        assert_eq!(c.payload_cap(1400), 1400);
        assert_eq!(c.payload_cap(8), PACKET_HEADER_BYTES + 1);
    }

    #[test]
    fn lossy_or_traced_config_is_not_ideal() {
        let c = NetConfig { loss: GilbertElliott::uniform(0.1), ..NetConfig::default() };
        assert!(!c.is_ideal());
        let c = NetConfig { trace: Some(BandwidthTrace::constant(1e6)), ..NetConfig::default() };
        assert!(!c.is_ideal());
    }

    #[test]
    fn device_seeds_differ_but_are_stable() {
        let c = NetConfig::default();
        assert_ne!(c.device_seed(0), c.device_seed(1));
        assert_eq!(c.device_seed(3), c.device_seed(3));
    }
}
