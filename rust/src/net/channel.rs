//! Lossy, trace-driven wireless channel: Gilbert–Elliott bursty packet
//! loss + time-varying bandwidth from a replayable trace, with per-packet
//! delivery timestamps.
//!
//! The zero-loss, constant-bandwidth special case ([`Channel::ideal`])
//! reproduces the closed-form timing of the original `NetworkSim` exactly
//! — `simulator::network` is reimplemented on top of this type so the two
//! link models cannot drift. All randomness comes from a seeded xorshift64*
//! generator: the same seed always yields the same loss pattern.

use crate::simulator::NetworkProfile;
use anyhow::{ensure, Context, Result};
use std::path::Path;

/// Deterministic xorshift64* PRNG (same family as `workload::Arrival`).
#[derive(Debug, Clone)]
struct Rng(u64);

impl Rng {
    fn new(seed: u64) -> Self {
        Rng(seed.max(1))
    }

    fn next(&mut self) -> u64 {
        let mut x = self.0;
        x ^= x >> 12;
        x ^= x << 25;
        x ^= x >> 27;
        self.0 = x;
        x.wrapping_mul(0x2545_F491_4F6C_DD1D)
    }

    /// Uniform in [0, 1).
    fn f64(&mut self) -> f64 {
        (self.next() >> 11) as f64 / (1u64 << 53) as f64
    }
}

/// Two-state Gilbert–Elliott packet-loss model: a Good state with low loss
/// and a Bad (burst) state with high loss, with per-packet state
/// transitions. Captures the bursty losses of real wireless links that a
/// single Bernoulli rate cannot.
#[derive(Debug, Clone, PartialEq)]
pub struct GilbertElliott {
    /// P(Good -> Bad) after each packet
    pub p_good_to_bad: f64,
    /// P(Bad -> Good) after each packet
    pub p_bad_to_good: f64,
    /// per-packet loss probability while Good
    pub loss_good: f64,
    /// per-packet loss probability while Bad
    pub loss_bad: f64,
}

impl GilbertElliott {
    /// No loss at all (the ideal-link special case).
    pub fn lossless() -> Self {
        Self { p_good_to_bad: 0.0, p_bad_to_good: 1.0, loss_good: 0.0, loss_bad: 0.0 }
    }

    /// Independent (Bernoulli) loss at `rate` — no burstiness.
    pub fn uniform(rate: f64) -> Self {
        let rate = rate.clamp(0.0, 1.0);
        Self { p_good_to_bad: 0.0, p_bad_to_good: 1.0, loss_good: rate, loss_bad: rate }
    }

    /// Bursty loss with stationary loss `rate` (clamped to 0.95) and mean
    /// burst length `mean_burst` packets: the Bad state drops everything
    /// and lasts `mean_burst` packets on average; `p_good_to_bad` is
    /// solved so the stationary Bad-state probability equals `rate`. When
    /// the requested burst length cannot reach `rate` (the solved
    /// transition probability would exceed 1), the burst is stretched
    /// instead, so the stationary loss rate is always honoured.
    pub fn bursty(rate: f64, mean_burst: f64) -> Self {
        let rate = rate.clamp(0.0, 0.95);
        let mean_burst = mean_burst.max(1.0);
        let mut p_bad_to_good = 1.0 / mean_burst;
        let mut p_good_to_bad =
            if rate <= 0.0 { 0.0 } else { rate * p_bad_to_good / (1.0 - rate) };
        if p_good_to_bad > 1.0 {
            p_good_to_bad = 1.0;
            p_bad_to_good = (1.0 - rate) / rate;
        }
        Self { p_good_to_bad, p_bad_to_good, loss_good: 0.0, loss_bad: 1.0 }
    }

    /// True when this model can never drop a packet.
    pub fn is_lossless(&self) -> bool {
        self.loss_good <= 0.0 && (self.loss_bad <= 0.0 || self.p_good_to_bad <= 0.0)
    }

    /// Stationary expected packet-loss rate.
    pub fn expected_loss_rate(&self) -> f64 {
        let denom = self.p_good_to_bad + self.p_bad_to_good;
        if denom <= 0.0 {
            return self.loss_good;
        }
        let pi_bad = self.p_good_to_bad / denom;
        (1.0 - pi_bad) * self.loss_good + pi_bad * self.loss_bad
    }
}

/// Piecewise-constant bandwidth over time, replayed in a loop — e.g. a
/// measured walk-through-a-building trace. Timestamps are seconds from the
/// start of the run; the trace wraps at its total duration.
#[derive(Debug, Clone, PartialEq)]
pub struct BandwidthTrace {
    /// (duration_s, bandwidth_bps) segments, in order
    segments: Vec<(f64, f64)>,
    period_s: f64,
}

impl BandwidthTrace {
    pub fn new(segments: Vec<(f64, f64)>) -> Result<Self> {
        ensure!(!segments.is_empty(), "empty bandwidth trace");
        for &(dur, bps) in &segments {
            ensure!(dur > 0.0 && dur.is_finite(), "trace segment duration must be positive");
            ensure!(bps > 0.0 && bps.is_finite(), "trace segment bandwidth must be positive");
        }
        let period_s = segments.iter().map(|s| s.0).sum();
        Ok(Self { segments, period_s })
    }

    /// Single-segment constant-bandwidth trace.
    pub fn constant(bps: f64) -> Self {
        Self { segments: vec![(f64::INFINITY, bps)], period_s: f64::INFINITY }
    }

    /// Parse the trace text format: one `<duration_s> <bandwidth_bps>` pair
    /// per line; blank lines and `#` comments are ignored.
    pub fn parse(text: &str) -> Result<Self> {
        let mut segments = Vec::new();
        for (lineno, line) in text.lines().enumerate() {
            let line = line.split('#').next().unwrap_or("").trim();
            if line.is_empty() {
                continue;
            }
            let mut it = line.split_whitespace();
            let dur: f64 = it
                .next()
                .with_context(|| format!("trace line {}: missing duration", lineno + 1))?
                .parse()
                .with_context(|| format!("trace line {}: bad duration", lineno + 1))?;
            let bps: f64 = it
                .next()
                .with_context(|| format!("trace line {}: missing bandwidth", lineno + 1))?
                .parse()
                .with_context(|| format!("trace line {}: bad bandwidth", lineno + 1))?;
            ensure!(it.next().is_none(), "trace line {}: trailing tokens", lineno + 1);
            segments.push((dur, bps));
        }
        Self::new(segments)
    }

    pub fn from_file(path: &Path) -> Result<Self> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading bandwidth trace {}", path.display()))?;
        Self::parse(&text).with_context(|| format!("parsing {}", path.display()))
    }

    /// Bandwidth in effect at absolute time `t` (trace wraps).
    pub fn bandwidth_at(&self, t: f64) -> f64 {
        let mut phase = if self.period_s.is_finite() { t.rem_euclid(self.period_s) } else { t };
        for &(dur, bps) in &self.segments {
            if phase < dur {
                return bps;
            }
            phase -= dur;
        }
        self.segments.last().expect("non-empty trace").1
    }

    /// Time to serialize `bits` starting at absolute time `t0`, integrating
    /// the piecewise-constant rate across segment boundaries and wraps.
    pub fn transmit_s(&self, t0: f64, bits: f64) -> f64 {
        if bits <= 0.0 {
            return 0.0;
        }
        if !self.period_s.is_finite() {
            return bits / self.segments[0].1; // constant-bandwidth trace
        }
        // locate the segment containing t0's phase
        let mut seg = 0usize;
        let mut off = t0.rem_euclid(self.period_s);
        while seg < self.segments.len() && off >= self.segments[seg].0 {
            off -= self.segments[seg].0;
            seg += 1;
        }
        if seg == self.segments.len() {
            // fp edge: phase rounded up to the period; wrap to the start
            seg = 0;
            off = 0.0;
        }
        let mut remaining = bits;
        let mut elapsed = 0.0;
        loop {
            let (dur, bps) = self.segments[seg];
            let seg_left = dur - off;
            let can_send = bps * seg_left;
            if can_send >= remaining {
                return elapsed + remaining / bps;
            }
            remaining -= can_send;
            elapsed += seg_left;
            seg = (seg + 1) % self.segments.len();
            off = 0.0;
        }
    }
}

/// Outcome of pushing one packet into the channel.
#[derive(Debug, Clone, Copy)]
pub struct PacketTx {
    /// absolute time serialization finished (the radio frees up)
    pub t_end: f64,
    /// absolute arrival time at the receiver, `None` if the packet was lost
    pub arrival_s: Option<f64>,
}

/// A seeded, deterministic lossy link: packetized serialization over a
/// bandwidth trace, Gilbert–Elliott loss, and a fixed one-way latency.
///
/// Pure-timing queries (`transfer_s`, `airtime_s`) take `&self` and never
/// touch the RNG; only `send_packet` advances the loss process.
#[derive(Debug, Clone)]
pub struct Channel {
    mtu: usize,
    per_packet_overhead: usize,
    one_way_latency_s: f64,
    loss: GilbertElliott,
    trace: BandwidthTrace,
    rng: Rng,
    in_bad: bool,
    /// lifetime counters (packets offered / lost / wire bytes serialized)
    pub packets_offered: u64,
    pub packets_dropped: u64,
    pub wire_bytes_sent: u64,
}

impl Channel {
    /// Channel with explicit loss model and optional bandwidth trace
    /// (`None` = constant bandwidth from the profile).
    pub fn new(
        profile: &NetworkProfile,
        loss: GilbertElliott,
        trace: Option<BandwidthTrace>,
        seed: u64,
    ) -> Self {
        Self {
            mtu: profile.mtu,
            per_packet_overhead: profile.per_packet_overhead,
            one_way_latency_s: profile.one_way_latency_s,
            loss,
            trace: trace.unwrap_or_else(|| BandwidthTrace::constant(profile.bandwidth_bps)),
            rng: Rng::new(seed),
            in_bad: false,
            packets_offered: 0,
            packets_dropped: 0,
            wire_bytes_sent: 0,
        }
    }

    /// The zero-loss, constant-bandwidth special case: behaviorally
    /// identical to the closed-form `NetworkSim` this subsystem replaces.
    pub fn ideal(profile: &NetworkProfile) -> Self {
        Self::new(profile, GilbertElliott::lossless(), None, 1)
    }

    pub fn mtu(&self) -> usize {
        self.mtu
    }

    /// Number of packets for `bytes` of application payload.
    pub fn packets(&self, bytes: usize) -> usize {
        if bytes == 0 {
            0
        } else {
            bytes.div_ceil(self.mtu)
        }
    }

    /// On-air bytes including per-packet overhead.
    pub fn wire_bytes(&self, bytes: usize) -> usize {
        bytes + self.packets(bytes) * self.per_packet_overhead
    }

    /// One-way transfer time for `bytes` of application payload starting at
    /// absolute time `t0`, seconds. Pure timing — loss does not apply.
    pub fn transfer_s(&self, t0: f64, bytes: usize) -> f64 {
        if bytes == 0 {
            return 0.0;
        }
        self.trace.transmit_s(t0, self.wire_bytes(bytes) as f64 * 8.0) + self.one_way_latency_s
    }

    /// Radio-active airtime (serialization only, for the energy model).
    pub fn airtime_s(&self, t0: f64, bytes: usize) -> f64 {
        self.trace.transmit_s(t0, self.wire_bytes(bytes) as f64 * 8.0)
    }

    /// Round-trip time (feedback delay for ARQ retransmission rounds).
    pub fn rtt_s(&self) -> f64 {
        2.0 * self.one_way_latency_s
    }

    /// Serialize one packet of `app_bytes` application payload starting at
    /// absolute time `t`: returns when the radio frees up and whether/when
    /// the packet arrives. Advances the Gilbert–Elliott chain.
    pub fn send_packet(&mut self, t: f64, app_bytes: usize) -> PacketTx {
        let wire = app_bytes + self.per_packet_overhead;
        let t_end = t + self.trace.transmit_s(t, wire as f64 * 8.0);
        let loss_p = if self.in_bad { self.loss.loss_bad } else { self.loss.loss_good };
        let delivered = loss_p <= 0.0 || self.rng.f64() >= loss_p;
        let flip_p = if self.in_bad { self.loss.p_bad_to_good } else { self.loss.p_good_to_bad };
        if flip_p > 0.0 && self.rng.f64() < flip_p {
            self.in_bad = !self.in_bad;
        }
        self.packets_offered += 1;
        self.wire_bytes_sent += wire as u64;
        if !delivered {
            self.packets_dropped += 1;
        }
        PacketTx { t_end, arrival_s: delivered.then_some(t_end + self.one_way_latency_s) }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ideal_channel_matches_closed_form() {
        let p = NetworkProfile::wifi_6mbps();
        let ch = Channel::ideal(&p);
        for bytes in [0usize, 1, 244, 1400, 1401, 10_000] {
            let wire = if bytes == 0 {
                0
            } else {
                bytes + bytes.div_ceil(p.mtu) * p.per_packet_overhead
            };
            let expect = if bytes == 0 {
                0.0
            } else {
                wire as f64 * 8.0 / p.bandwidth_bps + p.one_way_latency_s
            };
            assert!((ch.transfer_s(0.0, bytes) - expect).abs() < 1e-12, "{bytes} bytes");
            // constant trace: start time does not matter
            assert!((ch.transfer_s(123.4, bytes) - expect).abs() < 1e-12);
        }
    }

    #[test]
    fn lossless_channel_never_drops() {
        let mut ch = Channel::ideal(&NetworkProfile::ble_270kbps());
        let mut t = 0.0;
        for _ in 0..500 {
            let tx = ch.send_packet(t, 100);
            assert!(tx.arrival_s.is_some());
            t = tx.t_end;
        }
        assert_eq!(ch.packets_dropped, 0);
        assert_eq!(ch.packets_offered, 500);
    }

    #[test]
    fn uniform_loss_rate_close_to_nominal_and_seed_deterministic() {
        let p = NetworkProfile::wifi_6mbps();
        let run = |seed| {
            let mut ch = Channel::new(&p, GilbertElliott::uniform(0.3), None, seed);
            let mut t = 0.0;
            let mut pattern = Vec::new();
            for _ in 0..2000 {
                let tx = ch.send_packet(t, 500);
                pattern.push(tx.arrival_s.is_some());
                t = tx.t_end;
            }
            (pattern, ch.packets_dropped)
        };
        let (a, dropped) = run(7);
        let (b, _) = run(7);
        assert_eq!(a, b, "same seed must reproduce the same loss pattern");
        let rate = dropped as f64 / 2000.0;
        assert!((rate - 0.3).abs() < 0.05, "observed loss {rate}");
        let (c, _) = run(8);
        assert_ne!(a, c, "different seeds should differ");
    }

    #[test]
    fn bursty_model_hits_stationary_rate() {
        let ge = GilbertElliott::bursty(0.3, 4.0);
        assert!((ge.expected_loss_rate() - 0.3).abs() < 1e-9);
        let mut ch = Channel::new(&NetworkProfile::wifi_6mbps(), ge, None, 11);
        let (mut t, mut lost) = (0.0, 0usize);
        for _ in 0..20_000 {
            let tx = ch.send_packet(t, 500);
            lost += tx.arrival_s.is_none() as usize;
            t = tx.t_end;
        }
        let rate = lost as f64 / 20_000.0;
        assert!((rate - 0.3).abs() < 0.05, "observed bursty loss {rate}");
    }

    #[test]
    fn trace_varies_bandwidth_over_time() {
        // 1 s at 1 Mbps, then 1 s at 125 kbps, looping
        let trace = BandwidthTrace::new(vec![(1.0, 1e6), (1.0, 125e3)]).unwrap();
        assert_eq!(trace.bandwidth_at(0.5), 1e6);
        assert_eq!(trace.bandwidth_at(1.5), 125e3);
        assert_eq!(trace.bandwidth_at(2.5), 1e6); // wraps
        // 1 Mbit starting at t=0 fits exactly in the first segment
        assert!((trace.transmit_s(0.0, 1e6) - 1.0).abs() < 1e-9);
        // starting in the slow segment takes longer than in the fast one
        assert!(trace.transmit_s(1.0, 1e5) > trace.transmit_s(0.0, 1e5));
        // spans segments and wraps: 0.5 s fast (500 kbit) + 1 s slow
        // (125 kbit) + 125 kbit more in the next fast segment
        let t = trace.transmit_s(0.5, 750e3);
        assert!((t - (0.5 + 1.0 + 125e3 / 1e6)).abs() < 1e-9, "got {t}");
    }

    #[test]
    fn trace_parses_text_format() {
        let text = "# walk trace\n1.0 6e6\n\n0.5 270e3 # doorway\n";
        let trace = BandwidthTrace::parse(text).unwrap();
        assert_eq!(trace.bandwidth_at(0.0), 6e6);
        assert_eq!(trace.bandwidth_at(1.2), 270e3);
        assert!(BandwidthTrace::parse("1.0\n").is_err());
        assert!(BandwidthTrace::parse("1.0 -5\n").is_err());
        assert!(BandwidthTrace::parse("").is_err());
    }
}
