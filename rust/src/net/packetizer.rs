//! Importance-ordered packetization of uplink feature frames.
//!
//! The anytime delivery policy needs every packet to be *independently*
//! decodable, so instead of one whole-frame LZW stream the quantized
//! symbol stream is split into bit-packed chunks, each carried in a packet
//! whose header names the range of the (shared) transmit-order permutation
//! it covers. The server can then rebuild a valid feature tensor from any
//! subset of packets, imputing the missing symbols — and when packets are
//! sent most-important-features-first, whatever arrives by the deadline is
//! the best possible subset. This trades the whole-stream LZW entropy win
//! for independent decodability, which is exactly the trade-off a lossy
//! link forces.

use crate::compression::quantizer::{bitpack, bitunpack};
use crate::config::{Meta, Scheme};
use crate::net::wire::{WireError, WIRE_MAGIC, WIRE_VERSION};
use anyhow::{ensure, Result};
use std::sync::Arc;

/// Packet header, a real serialized layout since the wire protocol landed
/// (see [`crate::net::wire`]): magic (u8) + version (u8) + frame id (u64)
/// + seq/total (u16 each) + order-space range start/len (u32 each) = 22
/// bytes. [`Packet::encode_wire`] emits exactly these bytes, and the
/// simulated channel prices the same header the TCP transport carries.
pub const PACKET_HEADER_BYTES: usize = 22;

/// How uplink packets are ordered on the wire.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PacketOrder {
    /// XAI importance rank: most important feature channels first
    /// (AgileNN; schemes without importance info fall back to index order).
    Importance,
    /// naive flat index order (the ablation baseline)
    Index,
}

impl std::str::FromStr for PacketOrder {
    type Err = anyhow::Error;

    fn from_str(s: &str) -> Result<Self> {
        match s.to_ascii_lowercase().as_str() {
            "importance" | "xai" => Ok(PacketOrder::Importance),
            "index" | "naive" => Ok(PacketOrder::Index),
            other => anyhow::bail!("unknown packet order {other:?} (importance|index)"),
        }
    }
}

/// One uplink packet: an independently decodable bit-packed chunk of the
/// quantized symbol stream, covering `range_start..range_start+range_len`
/// of the transmit-order permutation.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Packet {
    pub frame_id: u64,
    pub seq: u16,
    pub total: u16,
    pub range_start: u32,
    pub range_len: u32,
    /// bit-packed symbols for this range (no entropy coding — packets must
    /// decode independently)
    pub payload: Vec<u8>,
}

impl Packet {
    /// Application-layer bytes this packet puts on the wire.
    pub fn app_bytes(&self) -> usize {
        self.payload.len() + PACKET_HEADER_BYTES
    }

    /// Serialize header + payload ([`PACKET_HEADER_BYTES`] +
    /// `payload.len()` = [`Packet::app_bytes`] bytes, little-endian).
    pub fn encode_wire(&self, buf: &mut Vec<u8>) {
        buf.push(WIRE_MAGIC);
        buf.push(WIRE_VERSION);
        buf.extend_from_slice(&self.frame_id.to_le_bytes());
        buf.extend_from_slice(&self.seq.to_le_bytes());
        buf.extend_from_slice(&self.total.to_le_bytes());
        buf.extend_from_slice(&self.range_start.to_le_bytes());
        buf.extend_from_slice(&self.range_len.to_le_bytes());
        buf.extend_from_slice(&self.payload);
    }

    /// Decode one packet blob (everything after the header is payload).
    /// Wrong magic or version is a typed [`WireError`], so a cross-process
    /// peer speaking another encoding is rejected, never garbage-decoded.
    pub fn decode_wire(buf: &[u8]) -> Result<Packet, WireError> {
        if buf.len() < PACKET_HEADER_BYTES {
            return Err(WireError::Truncated { context: "packet header" });
        }
        if buf[0] != WIRE_MAGIC {
            return Err(WireError::BadMagic { found: buf[0] });
        }
        if buf[1] != WIRE_VERSION {
            return Err(WireError::VersionMismatch { found: buf[1] });
        }
        Ok(Packet {
            frame_id: u64::from_le_bytes(buf[2..10].try_into().expect("8-byte slice")),
            seq: u16::from_le_bytes([buf[10], buf[11]]),
            total: u16::from_le_bytes([buf[12], buf[13]]),
            range_start: u32::from_le_bytes(buf[14..18].try_into().expect("4-byte slice")),
            range_len: u32::from_le_bytes(buf[18..22].try_into().expect("4-byte slice")),
            payload: buf[PACKET_HEADER_BYTES..].to_vec(),
        })
    }
}

/// Splits a quantized symbol stream into packets along a transmit-order
/// permutation (importance rank), sized to a payload cap.
#[derive(Debug, Clone)]
pub struct Packetizer {
    /// max application bytes per packet, header included
    payload_cap: usize,
    /// permutation of symbol indices in transmit-priority order
    /// (`None` = identity / index order); shared with the receiver
    order: Option<Arc<Vec<u32>>>,
}

impl Packetizer {
    pub fn new(payload_cap: usize, order: Option<Vec<u32>>) -> Self {
        Self {
            payload_cap: payload_cap.max(PACKET_HEADER_BYTES + 1),
            order: order.map(Arc::new),
        }
    }

    pub fn order(&self) -> Option<&[u32]> {
        self.order.as_deref().map(|v| v.as_slice())
    }

    /// Symbols carried per packet at `bits` per symbol.
    pub fn symbols_per_packet(&self, bits: u32) -> usize {
        (((self.payload_cap - PACKET_HEADER_BYTES) * 8) / bits.clamp(1, 8) as usize).max(1)
    }

    /// Split `symbols` into independently decodable packets in transmit
    /// order. The permutation, when present, must cover `symbols` exactly.
    pub fn packetize(&self, frame_id: u64, symbols: &[u8], bits: u32) -> Result<Vec<Packet>> {
        if let Some(order) = self.order.as_deref() {
            ensure!(
                order.len() == symbols.len(),
                "tx order covers {} symbols, frame has {}",
                order.len(),
                symbols.len()
            );
        }
        let per = self.symbols_per_packet(bits);
        let total = symbols.len().div_ceil(per).max(1);
        ensure!(total <= u16::MAX as usize, "frame needs {total} packets (> u16 seq space)");
        let mut packets = Vec::with_capacity(total);
        let mut chunk = Vec::with_capacity(per);
        for (seq, start) in (0..symbols.len()).step_by(per).enumerate() {
            let len = per.min(symbols.len() - start);
            chunk.clear();
            match self.order.as_deref() {
                Some(order) => {
                    chunk.extend(order[start..start + len].iter().map(|&i| symbols[i as usize]))
                }
                None => chunk.extend_from_slice(&symbols[start..start + len]),
            }
            packets.push(Packet {
                frame_id,
                seq: seq as u16,
                total: total as u16,
                range_start: start as u32,
                range_len: len as u32,
                payload: bitpack(&chunk, bits),
            });
        }
        if packets.is_empty() {
            // zero-symbol frame still announces itself with an empty packet
            packets.push(Packet {
                frame_id,
                seq: 0,
                total: 1,
                range_start: 0,
                range_len: 0,
                payload: Vec::new(),
            });
        }
        Ok(packets)
    }
}

/// Rebuild the symbol stream from any subset of packets: delivered ranges
/// are unpacked into place (through the shared permutation), everything
/// else is imputed with `fill`. Returns the symbols and how many were
/// actually delivered.
pub fn reassemble_symbols(
    packets: &[Packet],
    count: usize,
    bits: u32,
    fill: u8,
    order: Option<&[u32]>,
) -> Result<(Vec<u8>, usize)> {
    if let Some(order) = order {
        ensure!(order.len() == count, "tx order covers {} symbols, frame has {count}", order.len());
    }
    let mut symbols = vec![fill; count];
    let mut delivered = 0usize;
    for p in packets {
        let (start, len) = (p.range_start as usize, p.range_len as usize);
        ensure!(
            start + len <= count,
            "packet {} covers {}..{} of a {count}-symbol frame",
            p.seq,
            start,
            start + len
        );
        let chunk = bitunpack(&p.payload, bits, len);
        for (k, &sym) in chunk.iter().enumerate() {
            let idx = match order {
                Some(order) => order[start + k] as usize,
                None => start + k,
            };
            symbols[idx] = sym;
        }
        delivered += len;
    }
    Ok((symbols, delivered))
}

/// XAI-importance transmit order for a scheme's uplink feature stream:
/// feature elements ranked by their channel's mean Integrated-Gradients
/// importance, most important first (spatial order preserved within a
/// channel). Only AgileNN exports per-channel importance for the remote
/// (non-top-k) features; other schemes get `None` (index order).
pub fn importance_order(meta: &Meta, scheme: Scheme) -> Option<Vec<u32>> {
    if scheme != Scheme::Agile {
        return None;
    }
    let [h, w, c_all] = meta.feature;
    let imp = &meta.importance.mean_importance_per_channel;
    if imp.len() != c_all {
        return None;
    }
    let selected: std::collections::HashSet<usize> =
        meta.selected_channels.iter().copied().collect();
    // remote channels keep their original ascending order in the feature
    // tensor (the artifact splits the top-k out positionally)
    let remote: Vec<usize> = (0..c_all).filter(|c| !selected.contains(c)).collect();
    let c_rem = remote.len();
    if c_rem == 0 || meta.tx_elements.agile != h * w * c_rem {
        return None;
    }
    let mut rank: Vec<usize> = (0..c_rem).collect();
    rank.sort_by(|&a, &b| {
        imp[remote[b]].partial_cmp(&imp[remote[a]]).unwrap_or(std::cmp::Ordering::Equal)
    });
    // layout (h, w, c_rem) row-major: element (spatial s, channel c) = s*c_rem + c
    let mut order = Vec::with_capacity(h * w * c_rem);
    for &c in &rank {
        for s in 0..h * w {
            order.push((s * c_rem + c) as u32);
        }
    }
    Some(order)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_identity_order() {
        let pz = Packetizer::new(16 + PACKET_HEADER_BYTES, None); // 16 payload bytes
        let symbols: Vec<u8> = (0..100u8).map(|i| i % 16).collect();
        let packets = pz.packetize(7, &symbols, 4).unwrap();
        assert!(packets.len() > 1);
        assert!(packets.iter().all(|p| p.app_bytes() <= 16 + PACKET_HEADER_BYTES));
        let (back, delivered) = reassemble_symbols(&packets, 100, 4, 0, None).unwrap();
        assert_eq!(back, symbols);
        assert_eq!(delivered, 100);
    }

    #[test]
    fn roundtrip_with_permutation() {
        let n = 60usize;
        let order: Vec<u32> = (0..n as u32).rev().collect();
        let pz = Packetizer::new(8 + PACKET_HEADER_BYTES, Some(order.clone()));
        let symbols: Vec<u8> = (0..n as u8).map(|i| i % 8).collect();
        let packets = pz.packetize(1, &symbols, 3).unwrap();
        let (back, _) = reassemble_symbols(&packets, n, 3, 0, Some(&order)).unwrap();
        assert_eq!(back, symbols);
    }

    #[test]
    fn partial_subset_imputes_fill() {
        let pz = Packetizer::new(8 + PACKET_HEADER_BYTES, None);
        let symbols: Vec<u8> = (0..64u8).map(|i| 1 + i % 3).collect();
        let packets = pz.packetize(2, &symbols, 2).unwrap();
        let kept: Vec<Packet> = packets.into_iter().skip(1).collect(); // drop the first
        let (back, delivered) = reassemble_symbols(&kept, 64, 2, 0, None).unwrap();
        assert!(delivered < 64);
        let first_len = 64 - delivered;
        assert!(back[..first_len].iter().all(|&s| s == 0), "missing range imputed");
        assert_eq!(&back[first_len..], &symbols[first_len..]);
    }

    #[test]
    fn wire_codec_round_trips_and_rejects_foreign_bytes() {
        let pz = Packetizer::new(16 + PACKET_HEADER_BYTES, None);
        let symbols: Vec<u8> = (0..50u8).map(|i| i % 16).collect();
        for p in pz.packetize(0xDEAD_BEEF, &symbols, 4).unwrap() {
            let mut buf = Vec::new();
            p.encode_wire(&mut buf);
            assert_eq!(buf.len(), p.app_bytes(), "header constant matches the real layout");
            assert_eq!(Packet::decode_wire(&buf).unwrap(), p);
            let mut bad = buf.clone();
            bad[0] ^= 0xFF;
            assert!(matches!(Packet::decode_wire(&bad), Err(WireError::BadMagic { .. })));
            let mut bad = buf.clone();
            bad[1] = WIRE_VERSION + 9;
            assert!(matches!(
                Packet::decode_wire(&bad),
                Err(WireError::VersionMismatch { found }) if found == WIRE_VERSION + 9
            ));
        }
        assert!(matches!(
            Packet::decode_wire(&[WIRE_MAGIC, WIRE_VERSION, 0]),
            Err(WireError::Truncated { .. })
        ));
    }

    #[test]
    fn rejects_out_of_range_packet() {
        let p = Packet {
            frame_id: 0,
            seq: 0,
            total: 1,
            range_start: 10,
            range_len: 10,
            payload: vec![0; 10],
        };
        assert!(reassemble_symbols(&[p], 15, 8, 0, None).is_err());
    }

    #[test]
    fn importance_order_is_a_permutation_grouped_by_channel_rank() {
        use crate::json::Value;
        let mut meta =
            Meta::from_json(&Value::parse(crate::config::tests::MINIMAL_META).unwrap()).unwrap();
        // 24 feature channels, top-5 selected, 19 remote => 8*8*19 = 1216
        meta.importance.mean_importance_per_channel =
            (0..24).map(|c| 1.0 / (1.0 + c as f64)).collect();
        let order = importance_order(&meta, Scheme::Agile).expect("agile order");
        assert_eq!(order.len(), 1216);
        let mut seen = vec![false; 1216];
        for &i in &order {
            assert!(!seen[i as usize], "duplicate index {i}");
            seen[i as usize] = true;
        }
        // channels 1..5 are selected; channel 0 is the most important remote
        // channel, so the first 64 entries are its spatial positions
        let c_rem = 19;
        assert!(order[..64].iter().enumerate().all(|(s, &i)| i as usize == s * c_rem));
        assert!(importance_order(&meta, Scheme::Deepcod).is_none());
    }
}
