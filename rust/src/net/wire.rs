//! The versioned wire protocol shared by every cross-process transport.
//!
//! In-process serving hands `UplinkBody` values over an `mpsc` channel and
//! never serializes anything; the TCP daemon ([`crate::serve::daemon`])
//! and device client speak *this* format instead: a length-prefixed
//! envelope (magic, version, message type, payload length) around the
//! existing packetized frame format. Frames and packets carry their own
//! magic + version bytes too ([`FRAME_HEADER_BYTES`],
//! [`PACKET_HEADER_BYTES`]), so a peer speaking a different encoding is
//! rejected with a typed [`WireError`] instead of garbage-decoding — and
//! the simulated channel prices exactly the header bytes the real wire
//! carries.
//!
//! Everything is little-endian and deliberately dependency-free (no serde
//! in the build environment): each message is a hand-rolled codec with a
//! round-trip unit test, and `perfgate` times the encode/decode loop
//! (`wire_codec`) so the codecs stay off the serving hot path's budget.
//!
//! [`PACKET_HEADER_BYTES`]: crate::net::PACKET_HEADER_BYTES

use crate::compression::{Frame, FRAME_HEADER_BYTES};
use crate::net::packetizer::Packet;
use anyhow::Result;
use std::io::{Read, Write};

/// First byte of every envelope, frame header, and packet header.
pub const WIRE_MAGIC: u8 = 0xA6;
/// Protocol version; peers reject anything else with
/// [`WireError::VersionMismatch`].
///
/// **v2** — the `Reply` queue-depth advertisement is now stamped by the
/// server loop at the instant it sends each reply (it was previously
/// re-read by the forwarding thread, so clients could act on the queue
/// state of a different moment). The byte layout of every message is
/// unchanged — only the semantics of `Reply.queue_depth` tightened — but
/// v1 and v2 peers make different freshness assumptions, so the version
/// byte fences them apart. Golden wire captures need no re-bless: header
/// byte *counts* are unchanged and goldens don't pin the version byte's
/// value (see `tests/golden/README.md`).
pub const WIRE_VERSION: u8 = 2;
/// Envelope header: magic + version + message type + reserved + payload
/// length (u32).
pub const ENVELOPE_HEADER_BYTES: usize = 8;
/// Hard cap on one envelope payload — far above any real frame, small
/// enough that a corrupt length prefix cannot allocate the host away.
pub const MAX_PAYLOAD_BYTES: u32 = 64 << 20;

const MSG_HELLO: u8 = 1;
const MSG_HELLO_ACK: u8 = 2;
const MSG_REJECT: u8 = 3;
const MSG_OFFLOAD_FRAME: u8 = 4;
const MSG_OFFLOAD_PACKETS: u8 = 5;
const MSG_REPLY: u8 = 6;
const MSG_SHUTDOWN: u8 = 7;

/// A protocol violation on the wire: the bytes parsed, but not as this
/// protocol (wrong magic), not as this version, or not as a well-formed
/// message. Typed (and downcastable through `anyhow`) so cross-process
/// peers can tell an incompatible peer from an I/O failure.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum WireError {
    /// the first header byte was not [`WIRE_MAGIC`]
    BadMagic { found: u8 },
    /// magic matched but the version byte was not [`WIRE_VERSION`]
    VersionMismatch { found: u8 },
    /// the message-type byte names no known message
    BadMessageType { found: u8 },
    /// the stream ended inside a header or declared payload
    Truncated { context: &'static str },
    /// the payload length prefix exceeds [`MAX_PAYLOAD_BYTES`]
    Oversized { len: u32 },
    /// the payload decoded structurally but violates an invariant
    Malformed { context: &'static str },
    /// the peer stopped sending mid-conversation and the socket's
    /// configured read/write timeout expired — a stalled or half-open
    /// connection, disconnected instead of pinning its handler forever
    TimedOut { context: &'static str },
}

impl std::fmt::Display for WireError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            WireError::BadMagic { found } => {
                write!(f, "bad wire magic {found:#04x} (expected {WIRE_MAGIC:#04x}) — peer is not speaking the agilenn protocol")
            }
            WireError::VersionMismatch { found } => {
                write!(f, "wire protocol version {found} (this build speaks version {WIRE_VERSION})")
            }
            WireError::BadMessageType { found } => write!(f, "unknown wire message type {found}"),
            WireError::Truncated { context } => write!(f, "truncated wire data in {context}"),
            WireError::Oversized { len } => {
                write!(f, "wire payload of {len} bytes exceeds the {MAX_PAYLOAD_BYTES}-byte cap")
            }
            WireError::Malformed { context } => write!(f, "malformed wire payload: {context}"),
            WireError::TimedOut { context } => {
                write!(f, "peer stalled (socket timeout) in {context}")
            }
        }
    }
}

impl std::error::Error for WireError {}

/// The device↔daemon handshake: the client declares the world it was
/// built against; the daemon rejects any mismatch before serving.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Hello {
    pub dataset: String,
    pub scheme: String,
    pub bits: u32,
}

/// Every message the TCP transport exchanges. One request–reply pair per
/// in-flight offload, strictly ordered per connection (each simulated
/// device is half-duplex, so its transport never pipelines).
#[derive(Debug, Clone, PartialEq)]
pub enum WireMsg {
    /// connection opener (client → daemon)
    Hello(Hello),
    /// handshake accepted; carries the server world's class count
    HelloAck { num_classes: u32 },
    /// handshake or request rejected with a reason (daemon → client)
    Reject { reason: String },
    /// an intact LZW frame offload (the ARQ transport)
    OffloadFrame { id: u64, frame: Frame },
    /// whatever packets survived the simulated channel (anytime transport)
    OffloadPackets { id: u64, count: u32, bits: u32, packets: Vec<Packet> },
    /// remote logits (or the remote failure) plus the server's current
    /// batch-queue depth — the advertisement adaptive-split policies key on
    Reply { id: u64, queue_depth: u32, result: Result<Vec<f32>, String> },
    /// stop the daemon once in-flight connections drain
    Shutdown,
}

impl WireMsg {
    fn msg_type(&self) -> u8 {
        match self {
            WireMsg::Hello(_) => MSG_HELLO,
            WireMsg::HelloAck { .. } => MSG_HELLO_ACK,
            WireMsg::Reject { .. } => MSG_REJECT,
            WireMsg::OffloadFrame { .. } => MSG_OFFLOAD_FRAME,
            WireMsg::OffloadPackets { .. } => MSG_OFFLOAD_PACKETS,
            WireMsg::Reply { .. } => MSG_REPLY,
            WireMsg::Shutdown => MSG_SHUTDOWN,
        }
    }

    fn encode_payload(&self, buf: &mut Vec<u8>) {
        match self {
            WireMsg::Hello(h) => {
                put_str(buf, &h.dataset);
                put_str(buf, &h.scheme);
                buf.extend_from_slice(&h.bits.to_le_bytes());
            }
            WireMsg::HelloAck { num_classes } => buf.extend_from_slice(&num_classes.to_le_bytes()),
            WireMsg::Reject { reason } => buf.extend_from_slice(reason.as_bytes()),
            WireMsg::OffloadFrame { id, frame } => {
                buf.extend_from_slice(&id.to_le_bytes());
                encode_frame(frame, buf);
            }
            WireMsg::OffloadPackets { id, count, bits, packets } => {
                buf.extend_from_slice(&id.to_le_bytes());
                buf.extend_from_slice(&count.to_le_bytes());
                buf.extend_from_slice(&bits.to_le_bytes());
                buf.extend_from_slice(&(packets.len() as u16).to_le_bytes());
                for p in packets {
                    buf.extend_from_slice(&(p.app_bytes() as u32).to_le_bytes());
                    p.encode_wire(buf);
                }
            }
            WireMsg::Reply { id, queue_depth, result } => {
                buf.extend_from_slice(&id.to_le_bytes());
                buf.extend_from_slice(&queue_depth.to_le_bytes());
                match result {
                    Ok(row) => {
                        buf.push(0);
                        for v in row {
                            buf.extend_from_slice(&v.to_le_bytes());
                        }
                    }
                    Err(e) => {
                        buf.push(1);
                        buf.extend_from_slice(e.as_bytes());
                    }
                }
            }
            WireMsg::Shutdown => {}
        }
    }

    /// Serialize the full envelope (header + payload) into a fresh buffer.
    pub fn encode(&self) -> Vec<u8> {
        let mut payload = Vec::new();
        self.encode_payload(&mut payload);
        let mut buf = Vec::with_capacity(ENVELOPE_HEADER_BYTES + payload.len());
        buf.push(WIRE_MAGIC);
        buf.push(WIRE_VERSION);
        buf.push(self.msg_type());
        buf.push(0); // reserved
        buf.extend_from_slice(&(payload.len() as u32).to_le_bytes());
        buf.extend_from_slice(&payload);
        buf
    }

    /// Write the full envelope to a stream (one `write_all`; callers flush).
    pub fn write_to(&self, w: &mut impl Write) -> std::io::Result<()> {
        w.write_all(&self.encode())
    }

    /// Read one envelope off a stream. `Ok(None)` is a clean end-of-stream
    /// (the peer closed between messages); EOF *inside* a message is
    /// [`WireError::Truncated`]. Protocol violations come back as typed
    /// [`WireError`]s (downcastable), I/O failures as `std::io::Error`.
    pub fn read_from(r: &mut impl Read) -> Result<Option<WireMsg>> {
        let mut header = [0u8; ENVELOPE_HEADER_BYTES];
        let mut got = 0usize;
        while got < header.len() {
            let n = r.read(&mut header[got..])?;
            if n == 0 {
                if got == 0 {
                    return Ok(None);
                }
                return Err(WireError::Truncated { context: "envelope header" }.into());
            }
            got += n;
        }
        if header[0] != WIRE_MAGIC {
            return Err(WireError::BadMagic { found: header[0] }.into());
        }
        if header[1] != WIRE_VERSION {
            return Err(WireError::VersionMismatch { found: header[1] }.into());
        }
        let msg_type = header[2];
        let len = u32::from_le_bytes([header[4], header[5], header[6], header[7]]);
        if len > MAX_PAYLOAD_BYTES {
            return Err(WireError::Oversized { len }.into());
        }
        let mut payload = vec![0u8; len as usize];
        r.read_exact(&mut payload)
            .map_err(|_| WireError::Truncated { context: "envelope payload" })?;
        Ok(Some(decode_payload(msg_type, &payload)?))
    }

    /// Decode one full envelope from a byte slice (the streaming form is
    /// [`WireMsg::read_from`]).
    pub fn decode(buf: &[u8]) -> Result<WireMsg> {
        let mut r = buf;
        WireMsg::read_from(&mut r)?
            .ok_or_else(|| WireError::Truncated { context: "envelope header" }.into())
    }
}

fn decode_payload(msg_type: u8, payload: &[u8]) -> Result<WireMsg, WireError> {
    let mut r = Reader { buf: payload, pos: 0 };
    let msg = match msg_type {
        MSG_HELLO => {
            let dataset = r.take_str("hello dataset")?;
            let scheme = r.take_str("hello scheme")?;
            let bits = r.take_u32("hello bits")?;
            WireMsg::Hello(Hello { dataset, scheme, bits })
        }
        MSG_HELLO_ACK => WireMsg::HelloAck { num_classes: r.take_u32("hello-ack")? },
        MSG_REJECT => WireMsg::Reject { reason: r.take_rest_str("reject reason")? },
        MSG_OFFLOAD_FRAME => {
            let id = r.take_u64("frame offload id")?;
            let frame = decode_frame(r.rest())?;
            r.pos = r.buf.len();
            WireMsg::OffloadFrame { id, frame }
        }
        MSG_OFFLOAD_PACKETS => {
            let id = r.take_u64("packet offload id")?;
            let count = r.take_u32("packet offload count")?;
            let bits = r.take_u32("packet offload bits")?;
            if !(1..=8).contains(&bits) {
                return Err(WireError::Malformed { context: "offload bits outside 1..=8" });
            }
            let n = r.take_u16("packet offload packet count")? as usize;
            let mut packets = Vec::with_capacity(n);
            for _ in 0..n {
                let blob_len = r.take_u32("packet blob length")? as usize;
                let blob = r.take_bytes(blob_len, "packet blob")?;
                let p = Packet::decode_wire(blob)?;
                let expect = (p.range_len as usize * bits as usize).div_ceil(8);
                if p.payload.len() != expect {
                    return Err(WireError::Malformed {
                        context: "packet payload length does not match its symbol range",
                    });
                }
                packets.push(p);
            }
            WireMsg::OffloadPackets { id, count, bits, packets }
        }
        MSG_REPLY => {
            let id = r.take_u64("reply id")?;
            let queue_depth = r.take_u32("reply queue depth")?;
            let status = r.take_u8("reply status")?;
            let rest = r.rest();
            r.pos = r.buf.len();
            let result = match status {
                0 => {
                    if rest.len() % 4 != 0 {
                        return Err(WireError::Malformed {
                            context: "reply logits are not a whole number of f32s",
                        });
                    }
                    Ok(rest
                        .chunks_exact(4)
                        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                        .collect())
                }
                1 => Err(String::from_utf8_lossy(rest).into_owned()),
                _ => return Err(WireError::Malformed { context: "reply status byte" }),
            };
            WireMsg::Reply { id, queue_depth, result }
        }
        MSG_SHUTDOWN => WireMsg::Shutdown,
        other => return Err(WireError::BadMessageType { found: other }),
    };
    if r.pos != r.buf.len() {
        return Err(WireError::Malformed { context: "trailing bytes after message payload" });
    }
    Ok(msg)
}

/// Serialize a [`Frame`] blob: the [`FRAME_HEADER_BYTES`]-byte header
/// (magic, version, bits, reserved, count) followed by the LZW payload —
/// exactly the bytes [`Frame::wire_bytes`] prices on the simulated link.
pub fn encode_frame(frame: &Frame, buf: &mut Vec<u8>) {
    buf.push(WIRE_MAGIC);
    buf.push(WIRE_VERSION);
    buf.push(frame.bits.min(u8::MAX as u32) as u8);
    buf.push(0); // reserved
    buf.extend_from_slice(&(frame.count as u32).to_le_bytes());
    buf.extend_from_slice(&frame.payload);
}

/// Decode a [`Frame`] blob (everything after the header is payload).
pub fn decode_frame(buf: &[u8]) -> Result<Frame, WireError> {
    if buf.len() < FRAME_HEADER_BYTES {
        return Err(WireError::Truncated { context: "frame header" });
    }
    if buf[0] != WIRE_MAGIC {
        return Err(WireError::BadMagic { found: buf[0] });
    }
    if buf[1] != WIRE_VERSION {
        return Err(WireError::VersionMismatch { found: buf[1] });
    }
    let bits = buf[2] as u32;
    if !(1..=8).contains(&bits) {
        return Err(WireError::Malformed { context: "frame bits outside 1..=8" });
    }
    let count = u32::from_le_bytes([buf[4], buf[5], buf[6], buf[7]]) as usize;
    Ok(Frame { payload: buf[FRAME_HEADER_BYTES..].to_vec(), count, bits })
}

fn put_str(buf: &mut Vec<u8>, s: &str) {
    let bytes = s.as_bytes();
    buf.extend_from_slice(&(bytes.len().min(u16::MAX as usize) as u16).to_le_bytes());
    buf.extend_from_slice(&bytes[..bytes.len().min(u16::MAX as usize)]);
}

/// Bounds-checked little-endian reader over one payload slice; every
/// overrun is a typed [`WireError::Truncated`] naming the field.
struct Reader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Reader<'a> {
    fn take_bytes(&mut self, n: usize, context: &'static str) -> Result<&'a [u8], WireError> {
        if self.pos + n > self.buf.len() {
            return Err(WireError::Truncated { context });
        }
        let out = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(out)
    }

    fn take_u8(&mut self, context: &'static str) -> Result<u8, WireError> {
        Ok(self.take_bytes(1, context)?[0])
    }

    fn take_u16(&mut self, context: &'static str) -> Result<u16, WireError> {
        let b = self.take_bytes(2, context)?;
        Ok(u16::from_le_bytes([b[0], b[1]]))
    }

    fn take_u32(&mut self, context: &'static str) -> Result<u32, WireError> {
        let b = self.take_bytes(4, context)?;
        Ok(u32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }

    fn take_u64(&mut self, context: &'static str) -> Result<u64, WireError> {
        let b = self.take_bytes(8, context)?;
        Ok(u64::from_le_bytes(b.try_into().expect("8-byte slice")))
    }

    fn take_str(&mut self, context: &'static str) -> Result<String, WireError> {
        let len = self.take_u16(context)? as usize;
        let b = self.take_bytes(len, context)?;
        Ok(String::from_utf8_lossy(b).into_owned())
    }

    fn take_rest_str(&mut self, _context: &'static str) -> Result<String, WireError> {
        let rest = &self.buf[self.pos..];
        self.pos = self.buf.len();
        Ok(String::from_utf8_lossy(rest).into_owned())
    }

    fn rest(&self) -> &'a [u8] {
        &self.buf[self.pos..]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::packetizer::Packetizer;
    use crate::net::PACKET_HEADER_BYTES;

    fn roundtrip(msg: WireMsg) {
        let bytes = msg.encode();
        let back = WireMsg::decode(&bytes).unwrap();
        assert_eq!(back, msg);
        // streaming form agrees with the slice form
        let mut r = &bytes[..];
        assert_eq!(WireMsg::read_from(&mut r).unwrap(), Some(msg));
        assert_eq!(WireMsg::read_from(&mut r).unwrap(), None, "clean EOF after one message");
    }

    #[test]
    fn every_message_round_trips() {
        roundtrip(WireMsg::Hello(Hello {
            dataset: "synthetic".into(),
            scheme: "agile".into(),
            bits: 4,
        }));
        roundtrip(WireMsg::HelloAck { num_classes: 10 });
        roundtrip(WireMsg::Reject { reason: "scheme mismatch".into() });
        roundtrip(WireMsg::OffloadFrame {
            id: 7,
            frame: Frame { payload: vec![1, 2, 3, 4, 5], count: 1216, bits: 4 },
        });
        let pz = Packetizer::new(16 + PACKET_HEADER_BYTES, None);
        let symbols: Vec<u8> = (0..100u8).map(|i| i % 16).collect();
        let packets = pz.packetize(9, &symbols, 4).unwrap();
        roundtrip(WireMsg::OffloadPackets { id: 9, count: 100, bits: 4, packets });
        roundtrip(WireMsg::Reply {
            id: 3,
            queue_depth: 5,
            result: Ok(vec![0.25, -1.5, f32::MIN_POSITIVE]),
        });
        roundtrip(WireMsg::Reply { id: 4, queue_depth: 0, result: Err("remote failed".into()) });
        roundtrip(WireMsg::Shutdown);
    }

    #[test]
    fn frame_blob_length_is_wire_bytes() {
        let frame = Frame { payload: vec![9; 37], count: 120, bits: 2 };
        let mut buf = Vec::new();
        encode_frame(&frame, &mut buf);
        assert_eq!(buf.len(), frame.wire_bytes());
        assert_eq!(decode_frame(&buf).unwrap(), frame);
    }

    #[test]
    fn bad_magic_is_typed() {
        let mut bytes = WireMsg::Shutdown.encode();
        bytes[0] = 0x00;
        let err = WireMsg::decode(&bytes).unwrap_err();
        assert_eq!(
            err.downcast_ref::<WireError>(),
            Some(&WireError::BadMagic { found: 0x00 })
        );
    }

    #[test]
    fn version_mismatch_is_typed() {
        let mut bytes = WireMsg::HelloAck { num_classes: 10 }.encode();
        bytes[1] = WIRE_VERSION + 1;
        let err = WireMsg::decode(&bytes).unwrap_err();
        assert_eq!(
            err.downcast_ref::<WireError>(),
            Some(&WireError::VersionMismatch { found: WIRE_VERSION + 1 })
        );
        // ...and on the embedded frame header too
        let msg = WireMsg::OffloadFrame {
            id: 1,
            frame: Frame { payload: vec![1], count: 2, bits: 4 },
        };
        let mut bytes = msg.encode();
        bytes[ENVELOPE_HEADER_BYTES + 8 + 1] = WIRE_VERSION + 1; // frame header version byte
        let err = WireMsg::decode(&bytes).unwrap_err();
        assert_eq!(
            err.downcast_ref::<WireError>(),
            Some(&WireError::VersionMismatch { found: WIRE_VERSION + 1 })
        );
    }

    #[test]
    fn truncation_and_unknown_types_are_typed() {
        let bytes = WireMsg::Reply { id: 1, queue_depth: 0, result: Ok(vec![1.0]) }.encode();
        let err = WireMsg::decode(&bytes[..bytes.len() - 2]).unwrap_err();
        assert_eq!(
            err.downcast_ref::<WireError>(),
            Some(&WireError::Truncated { context: "envelope payload" })
        );
        let mut bytes = WireMsg::Shutdown.encode();
        bytes[2] = 200;
        let err = WireMsg::decode(&bytes).unwrap_err();
        assert_eq!(
            err.downcast_ref::<WireError>(),
            Some(&WireError::BadMessageType { found: 200 })
        );
    }

    #[test]
    fn packet_payload_must_match_its_range() {
        let pz = Packetizer::new(16 + PACKET_HEADER_BYTES, None);
        let symbols: Vec<u8> = (0..32u8).map(|i| i % 16).collect();
        let mut packets = pz.packetize(1, &symbols, 4).unwrap();
        packets[0].payload.push(0xFF); // one byte too many for its range
        let bytes = WireMsg::OffloadPackets { id: 1, count: 32, bits: 4, packets }.encode();
        let err = WireMsg::decode(&bytes).unwrap_err();
        assert!(matches!(
            err.downcast_ref::<WireError>(),
            Some(WireError::Malformed { .. })
        ));
    }

    #[test]
    fn oversized_length_prefix_is_rejected_without_allocating() {
        let mut bytes = WireMsg::Shutdown.encode();
        bytes[4..8].copy_from_slice(&(MAX_PAYLOAD_BYTES + 1).to_le_bytes());
        let err = WireMsg::decode(&bytes).unwrap_err();
        assert_eq!(
            err.downcast_ref::<WireError>(),
            Some(&WireError::Oversized { len: MAX_PAYLOAD_BYTES + 1 })
        );
    }
}
