//! Delivery policies over the lossy channel: ARQ (retransmit until
//! complete — latency pays) and deadline-bounded anytime (the server
//! decodes whatever arrived by the deadline — accuracy pays, gracefully,
//! when packets are importance-ordered).

use super::channel::Channel;
use super::packetizer::Packet;
use crate::obs::{EventKind, Lane, Tracer};

/// Retransmission-round cap: with any loss rate below ~50% the residual
/// probability of an undelivered packet after this many rounds is
/// negligible; the cap only guards runaway simulation time.
pub const MAX_ARQ_ROUNDS: usize = 32;

/// How uplink frames are delivered across the lossy link.
#[derive(Debug, Clone, Default, PartialEq)]
pub enum DeliveryPolicy {
    /// Retransmit lost packets (one RTT feedback delay per round) until the
    /// frame is complete. Latency grows with loss; accuracy does not.
    #[default]
    Arq,
    /// Send importance-ordered packets until `deadline_s` after transmit
    /// start (retransmitting lost ones while time remains); the server
    /// decodes whatever arrived, imputing missing features. Latency is
    /// bounded; accuracy degrades gracefully.
    Anytime { deadline_s: f64 },
}

impl DeliveryPolicy {
    pub fn name(&self) -> &'static str {
        match self {
            DeliveryPolicy::Arq => "arq",
            DeliveryPolicy::Anytime { .. } => "anytime",
        }
    }
}

/// Per-request transport accounting.
#[derive(Debug, Clone, Copy, Default)]
pub struct NetStats {
    /// packets pushed into the channel, retransmissions included
    pub packets_sent: usize,
    /// packets the channel dropped
    pub packets_lost: usize,
    /// retransmission rounds beyond the first pass
    pub retransmit_rounds: usize,
    /// feature elements in the uplink frame (0 for whole-frame transport)
    pub features_total: usize,
    /// feature elements that reached the server in time
    pub features_delivered: usize,
    /// application-layer bytes offered on the first pass
    pub app_bytes_offered: usize,
    /// application-layer bytes that arrived in time
    pub app_bytes_delivered: usize,
    /// the server decoded the full frame
    pub complete: bool,
    /// time the uplink waited for the device's (half-duplex) radio to
    /// finish the previous request's exchange before serialization could
    /// begin — simulated queueing under load, seconds. Filled in by the
    /// device loop; the transmit functions themselves start at `t0`.
    pub radio_wait_s: f64,
    /// transmit start -> frame usable at the server, seconds
    pub uplink_s: f64,
    /// radio-on serialization time, retransmissions included, seconds
    pub airtime_s: f64,
}

/// What the device loop hands to outcome assembly when a request crossed
/// the simulated channel.
#[derive(Debug, Clone, Copy)]
pub struct LinkOutcome {
    /// uplink + downlink time on the simulated link, seconds
    pub network_s: f64,
    /// total radio-on time (uplink incl. retransmissions + downlink)
    pub airtime_s: f64,
    pub stats: NetStats,
}

/// Transmit independently decodable packets under `policy`, starting at
/// absolute time `t0`. Returns the packets that arrived in time (in send
/// order) and the transport accounting.
pub fn transmit_packets(
    channel: &mut Channel,
    policy: &DeliveryPolicy,
    packets: &[Packet],
    t0: f64,
) -> (Vec<Packet>, NetStats) {
    transmit_packets_traced(channel, policy, packets, t0, &Tracer::off(), Lane::Device(0), 0)
}

/// [`transmit_packets`] with per-packet trace emission: a `Packet` span
/// per serialization (value = app bytes), a `PacketLost` instant at the
/// would-be arrival of each dropped packet, and a `RetransmitRound`
/// instant (value = round number) when a NACK round begins. `lane`/`id`
/// stamp the emitting device and request.
pub fn transmit_packets_traced(
    channel: &mut Channel,
    policy: &DeliveryPolicy,
    packets: &[Packet],
    t0: f64,
    tracer: &Tracer,
    lane: Lane,
    id: u64,
) -> (Vec<Packet>, NetStats) {
    let deadline = match policy {
        DeliveryPolicy::Arq => f64::INFINITY,
        DeliveryPolicy::Anytime { deadline_s } => t0 + deadline_s.max(0.0),
    };
    let mut stats = NetStats {
        features_total: packets.iter().map(|p| p.range_len as usize).sum(),
        app_bytes_offered: packets.iter().map(Packet::app_bytes).sum(),
        ..NetStats::default()
    };
    let mut delivered_idx: Vec<usize> = Vec::with_capacity(packets.len());
    let mut pending: Vec<usize> = (0..packets.len()).collect();
    let mut t = t0;
    let mut last_arrival = t0;
    let mut rounds = 0usize;
    while !pending.is_empty() && rounds < MAX_ARQ_ROUNDS && t < deadline {
        if rounds > 0 {
            // NACK feedback before the retransmission round; pointless
            // (and uncounted) when the RTT alone crosses the deadline
            if t + channel.rtt_s() >= deadline {
                break;
            }
            t += channel.rtt_s();
            stats.retransmit_rounds += 1;
            tracer.instant(lane, EventKind::RetransmitRound, id, t, rounds as f64);
        }
        let mut still = Vec::new();
        for &i in &pending {
            if t >= deadline {
                still.push(i);
                continue;
            }
            let t_tx = t;
            let tx = channel.send_packet(t, packets[i].app_bytes());
            stats.packets_sent += 1;
            stats.airtime_s += tx.t_end - t;
            t = tx.t_end;
            tracer.span(lane, EventKind::Packet, id, t_tx, t, packets[i].app_bytes() as f64);
            match tx.arrival_s {
                Some(a) if a <= deadline => {
                    last_arrival = last_arrival.max(a);
                    stats.app_bytes_delivered += packets[i].app_bytes();
                    stats.features_delivered += packets[i].range_len as usize;
                    delivered_idx.push(i);
                }
                Some(_) => still.push(i), // arrived too late to decode
                None => {
                    stats.packets_lost += 1;
                    let bytes = packets[i].app_bytes() as f64;
                    tracer.instant(lane, EventKind::PacketLost, id, t, bytes);
                    still.push(i);
                }
            }
        }
        pending = still;
        rounds += 1;
    }
    stats.complete = pending.is_empty();
    stats.uplink_s = if stats.complete {
        last_arrival - t0
    } else if deadline.is_finite() {
        deadline - t0
    } else {
        t - t0
    };
    delivered_idx.sort_unstable();
    let delivered = delivered_idx.into_iter().map(|i| packets[i].clone()).collect();
    (delivered, stats)
}

/// Time a whole LZW frame (the ARQ fast path: the frame only decodes when
/// complete, so lost packets are always retransmitted) of `app_bytes`
/// across the channel, MTU chunk by MTU chunk. On a lossless channel this
/// reproduces the closed-form `transfer_s` exactly: one round, same wire
/// bytes, same serialization.
pub fn transmit_frame(channel: &mut Channel, app_bytes: usize, t0: f64) -> NetStats {
    transmit_frame_traced(channel, app_bytes, t0, &Tracer::off(), Lane::Device(0), 0)
}

/// [`transmit_frame`] with the same per-packet trace emission as
/// [`transmit_packets_traced`].
pub fn transmit_frame_traced(
    channel: &mut Channel,
    app_bytes: usize,
    t0: f64,
    tracer: &Tracer,
    lane: Lane,
    id: u64,
) -> NetStats {
    let mtu = channel.mtu();
    let mut chunks: Vec<usize> = Vec::with_capacity(channel.packets(app_bytes));
    let mut left = app_bytes;
    while left > 0 {
        let c = left.min(mtu);
        chunks.push(c);
        left -= c;
    }
    let mut stats = NetStats {
        app_bytes_offered: app_bytes,
        complete: true,
        ..NetStats::default()
    };
    if chunks.is_empty() {
        return stats;
    }
    let mut pending: Vec<usize> = (0..chunks.len()).collect();
    let mut t = t0;
    let mut last_arrival = t0;
    let mut rounds = 0usize;
    while !pending.is_empty() && rounds < MAX_ARQ_ROUNDS {
        if rounds > 0 {
            t += channel.rtt_s();
            stats.retransmit_rounds += 1;
            tracer.instant(lane, EventKind::RetransmitRound, id, t, rounds as f64);
        }
        let mut still = Vec::new();
        for &i in &pending {
            let t_tx = t;
            let tx = channel.send_packet(t, chunks[i]);
            stats.packets_sent += 1;
            stats.airtime_s += tx.t_end - t;
            t = tx.t_end;
            tracer.span(lane, EventKind::Packet, id, t_tx, t, chunks[i] as f64);
            match tx.arrival_s {
                Some(a) => {
                    last_arrival = last_arrival.max(a);
                    stats.app_bytes_delivered += chunks[i];
                }
                None => {
                    stats.packets_lost += 1;
                    tracer.instant(lane, EventKind::PacketLost, id, t, chunks[i] as f64);
                    still.push(i);
                }
            }
        }
        pending = still;
        rounds += 1;
    }
    // the cap only bounds simulation time: ARQ semantics guarantee the
    // frame eventually ships, so residual chunks (possible only under
    // near-total loss) are force-delivered on one final round — the server
    // always decodes a complete frame, and the accounting says so
    if !pending.is_empty() {
        stats.retransmit_rounds += 1;
        tracer.instant(lane, EventKind::RetransmitRound, id, t, MAX_ARQ_ROUNDS as f64);
        for &i in &pending {
            let ser = channel.airtime_s(t, chunks[i]);
            stats.packets_sent += 1;
            stats.airtime_s += ser;
            let t_tx = t;
            t += ser;
            tracer.span(lane, EventKind::Packet, id, t_tx, t, chunks[i] as f64);
            stats.app_bytes_delivered += chunks[i];
            last_arrival = last_arrival.max(t + channel.rtt_s() / 2.0);
        }
    }
    stats.complete = true;
    stats.uplink_s = last_arrival.max(t) - t0;
    stats
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::channel::GilbertElliott;
    use crate::net::packetizer::Packetizer;
    use crate::simulator::NetworkProfile;

    fn packets(n_symbols: usize, payload: usize) -> Vec<Packet> {
        let pz = Packetizer::new(payload, None);
        let symbols: Vec<u8> = (0..n_symbols).map(|i| (i % 16) as u8).collect();
        pz.packetize(0, &symbols, 4).unwrap()
    }

    #[test]
    fn lossless_frame_matches_closed_form_transfer() {
        let p = NetworkProfile::wifi_6mbps();
        let mut ch = Channel::ideal(&p);
        for bytes in [100usize, 1400, 1401, 5000] {
            let stats = transmit_frame(&mut ch, bytes, 0.0);
            let expect = Channel::ideal(&p).transfer_s(0.0, bytes);
            assert!((stats.uplink_s - expect).abs() < 1e-12, "{bytes} bytes");
            assert!(stats.complete);
            assert_eq!(stats.packets_lost, 0);
            assert_eq!(stats.retransmit_rounds, 0);
        }
    }

    #[test]
    fn arq_retransmits_until_complete_under_loss() {
        let p = NetworkProfile::wifi_6mbps();
        let mut ch = Channel::new(&p, GilbertElliott::uniform(0.4), None, 3);
        let pkts = packets(2000, 64);
        let (delivered, stats) = transmit_packets(&mut ch, &DeliveryPolicy::Arq, &pkts, 0.0);
        assert!(stats.complete);
        assert_eq!(delivered.len(), pkts.len());
        assert_eq!(stats.features_delivered, stats.features_total);
        assert!(stats.retransmit_rounds >= 1);
        assert!(stats.packets_sent > pkts.len());
        // retransmission latency exceeds the lossless send
        let mut ideal = Channel::ideal(&p);
        let (_, clean) = transmit_packets(&mut ideal, &DeliveryPolicy::Arq, &pkts, 0.0);
        assert!(stats.uplink_s > clean.uplink_s);
    }

    #[test]
    fn anytime_bounds_latency_and_delivers_a_prefix_under_loss() {
        let p = NetworkProfile::ble_270kbps();
        // deadline ~ half the clean serialization: only a prefix fits
        let pkts = packets(4000, 128);
        let total: usize = pkts.iter().map(Packet::app_bytes).sum();
        let clean = Channel::ideal(&p).airtime_s(0.0, total);
        let policy = DeliveryPolicy::Anytime { deadline_s: clean * 0.5 };
        let mut ch = Channel::new(&p, GilbertElliott::uniform(0.2), None, 5);
        let (delivered, stats) = transmit_packets(&mut ch, &policy, &pkts, 0.0);
        assert!(!stats.complete);
        assert!(!delivered.is_empty());
        assert!(delivered.len() < pkts.len());
        assert!((stats.uplink_s - clean * 0.5).abs() < 1e-9, "deadline bounds uplink");
        // delivered packets are a loss-thinned prefix of the send order
        assert!(delivered.windows(2).all(|w| w[0].seq < w[1].seq));
    }

    #[test]
    fn anytime_with_slack_completes_on_lossless_channel() {
        let p = NetworkProfile::wifi_6mbps();
        let pkts = packets(500, 100);
        let mut ch = Channel::ideal(&p);
        let policy = DeliveryPolicy::Anytime { deadline_s: 10.0 };
        let (delivered, stats) = transmit_packets(&mut ch, &policy, &pkts, 0.0);
        assert!(stats.complete);
        assert_eq!(delivered.len(), pkts.len());
        assert!(stats.uplink_s < 10.0);
    }

    #[test]
    fn transport_is_seed_deterministic() {
        let p = NetworkProfile::wifi_6mbps();
        let pkts = packets(3000, 80);
        let run = |seed| {
            let mut ch = Channel::new(&p, GilbertElliott::bursty(0.3, 4.0), None, seed);
            let (d, s) = transmit_packets(&mut ch, &DeliveryPolicy::Arq, &pkts, 0.0);
            (d.iter().map(|p| p.seq).collect::<Vec<_>>(), s.packets_sent, s.uplink_s)
        };
        assert_eq!(run(9), run(9));
    }
}
