//! Randomized property tests (hand-rolled proptest substitute — the build
//! environment vendors no proptest). A deterministic xorshift PRNG drives
//! hundreds of cases per invariant; failures print the seed for replay.

use agilenn::compression::quantizer::{bitpack, bitunpack, Codebook};
use agilenn::compression::{lzw, RxDecoder, TxEncoder};
use agilenn::coordinator::batcher::{pad_batch_size, BatchQueue, REMOTE_BATCH_SIZES};
use agilenn::config::{BackendKind, Scheme};
use agilenn::net::{
    reassemble_symbols, BandwidthTrace, Channel, DeliveryPolicy, GilbertElliott, NetStats,
    PacketOrder, Packetizer, PACKET_HEADER_BYTES,
};
use agilenn::serve::{DevicePolicy, PolicyConfig, ServeBuilder};
use agilenn::obs::{chrome_trace_json, EventKind, Lane, TraceEvent};
use agilenn::simulator::{DeviceProfile, NetworkProfile, NetworkSim};
use agilenn::tensor::{argmax, softmax, Tensor};
use agilenn::tune::{ranking, Objectives};
use agilenn::xai;

/// xorshift64* — deterministic, seedable.
struct Rng(u64);

impl Rng {
    fn new(seed: u64) -> Self {
        Rng(seed.max(1))
    }

    fn next(&mut self) -> u64 {
        let mut x = self.0;
        x ^= x >> 12;
        x ^= x << 25;
        x ^= x >> 27;
        self.0 = x;
        x.wrapping_mul(0x2545_F491_4F6C_DD1D)
    }

    fn usize(&mut self, bound: usize) -> usize {
        (self.next() % bound.max(1) as u64) as usize
    }

    fn f32(&mut self) -> f32 {
        (self.next() >> 40) as f32 / (1u64 << 24) as f32
    }

    fn bytes(&mut self, n: usize) -> Vec<u8> {
        (0..n).map(|_| (self.next() >> 56) as u8).collect()
    }

    /// zero-heavy byte stream like quantized post-ReLU features
    fn sparse_bytes(&mut self, n: usize, zero_pct: u64) -> Vec<u8> {
        (0..n)
            .map(|_| if self.next() % 100 < zero_pct { 0 } else { (self.next() % 16) as u8 })
            .collect()
    }
}

// ---------------------------------------------------------------------------
// LZW: roundtrip is identity for arbitrary byte streams
// ---------------------------------------------------------------------------

#[test]
fn prop_lzw_roundtrip_random_streams() {
    for seed in 1..=200u64 {
        let mut rng = Rng::new(seed);
        let n = rng.usize(5000);
        let data = rng.bytes(n);
        let back = lzw::decompress(&lzw::compress(&data)).unwrap();
        assert_eq!(back, data, "seed {seed} len {n}");
    }
}

#[test]
fn prop_lzw_roundtrip_sparse_streams_and_compresses() {
    for seed in 1..=100u64 {
        let mut rng = Rng::new(seed);
        let n = 500 + rng.usize(4000);
        let data = rng.sparse_bytes(n, 85);
        let c = lzw::compress(&data);
        assert_eq!(lzw::decompress(&c).unwrap(), data, "seed {seed}");
        assert!(c.len() < data.len(), "seed {seed}: sparse stream must shrink");
    }
}

#[test]
fn prop_lzw_handles_long_runs_and_dictionary_resets() {
    for seed in 1..=20u64 {
        let mut rng = Rng::new(seed);
        // long run + noise tail forces dictionary growth and resets
        let mut data = vec![(seed % 251) as u8; 30_000 + rng.usize(30_000)];
        data.extend(rng.bytes(30_000));
        assert_eq!(lzw::decompress(&lzw::compress(&data)).unwrap(), data, "seed {seed}");
    }
}

// ---------------------------------------------------------------------------
// bitpack: roundtrip for every width
// ---------------------------------------------------------------------------

#[test]
fn prop_bitpack_roundtrip() {
    for seed in 1..=100u64 {
        let mut rng = Rng::new(seed);
        let bits = 1 + (rng.usize(8)) as u32;
        let n = rng.usize(2000);
        let idx: Vec<u8> = (0..n).map(|_| (rng.next() % (1u64 << bits)) as u8).collect();
        let back = bitunpack(&bitpack(&idx, bits), bits, n);
        assert_eq!(back, idx, "seed {seed} bits {bits} n {n}");
    }
}

// ---------------------------------------------------------------------------
// quantizer: dequantized value is always the nearest codeword
// ---------------------------------------------------------------------------

#[test]
fn prop_quantizer_nearest_codeword() {
    for seed in 1..=60u64 {
        let mut rng = Rng::new(seed);
        let nlevels = 2 + rng.usize(63);
        let levels: Vec<f32> = (0..nlevels).map(|_| rng.f32() * 4.0).collect();
        let cb = match Codebook::new(levels) {
            Ok(cb) => cb,
            Err(_) => continue, // duplicate levels are fine to skip
        };
        for _ in 0..200 {
            let v = rng.f32() * 5.0 - 0.5;
            let q = cb.levels()[cb.index_of(v) as usize];
            let best = cb
                .levels()
                .iter()
                .cloned()
                .min_by(|a, b| (a - v).abs().partial_cmp(&(b - v).abs()).unwrap())
                .unwrap();
            assert!(
                (q - v).abs() <= (best - v).abs() + 1e-6,
                "seed {seed}: {v} -> {q}, nearest {best}"
            );
        }
    }
}

#[test]
fn prop_tx_rx_roundtrip_through_wire_format() {
    for seed in 1..=40u64 {
        let mut rng = Rng::new(seed);
        let levels: Vec<f32> = (0..16).map(|i| i as f32 * 0.13).collect();
        let cb = Codebook::new(levels).unwrap();
        let mut tx = TxEncoder::new(cb.clone());
        let rx = RxDecoder::new(cb.clone());
        let n = 1 + rng.usize(3000);
        let vals: Vec<f32> =
            (0..n).map(|_| if rng.next() % 4 == 0 { rng.f32() * 2.0 } else { 0.0 }).collect();
        let frame = tx.encode(&vals);
        let back = rx.decode(&frame).unwrap();
        assert_eq!(back.len(), vals.len(), "seed {seed}");
        for (v, b) in vals.iter().zip(&back) {
            assert_eq!(*b, cb.levels()[cb.index_of(*v) as usize], "seed {seed}");
        }
    }
}

// ---------------------------------------------------------------------------
// batcher: conservation — every pushed request is dispatched exactly once
// ---------------------------------------------------------------------------

#[test]
fn prop_batcher_conserves_requests() {
    for seed in 1..=60u64 {
        let mut rng = Rng::new(seed);
        let max_batch = REMOTE_BATCH_SIZES[rng.usize(REMOTE_BATCH_SIZES.len())];
        let mut q = BatchQueue::new(max_batch, 0.005);
        let n = 1 + rng.usize(200);
        let mut dispatched = Vec::new();
        for id in 0..n as u64 {
            if let Some(batch) = q.push(id, (), 0.0) {
                assert!(batch.len() <= max_batch);
                dispatched.extend(batch.into_iter().map(|p| p.id));
            }
            // random deadline polls
            if rng.next() % 3 == 0 {
                if let Some(batch) = q.poll_deadline(0.006) {
                    dispatched.extend(batch.into_iter().map(|p| p.id));
                }
            }
        }
        dispatched.extend(q.flush().into_iter().map(|p| p.id));
        dispatched.sort_unstable();
        let expect: Vec<u64> = (0..n as u64).collect();
        assert_eq!(dispatched, expect, "seed {seed} max_batch {max_batch}");
    }
}

#[test]
fn prop_pad_batch_size_is_minimal_exported_cover() {
    for n in 1..=8usize {
        let p = pad_batch_size(n);
        assert!(REMOTE_BATCH_SIZES.contains(&p));
        assert!(p >= n);
        // minimality: no smaller exported size covers n
        for &b in REMOTE_BATCH_SIZES.iter() {
            if b >= n {
                assert!(p <= b);
            }
        }
    }
}

// ---------------------------------------------------------------------------
// tensor ops
// ---------------------------------------------------------------------------

#[test]
fn prop_stack_padded_preserves_rows() {
    for seed in 1..=60u64 {
        let mut rng = Rng::new(seed);
        let w = 1 + rng.usize(30);
        let n = 1 + rng.usize(8);
        let pad = pad_batch_size(n);
        let items: Vec<Tensor> = (0..n)
            .map(|_| Tensor::new(vec![1, w], (0..w).map(|_| rng.f32()).collect()).unwrap())
            .collect();
        let stacked = Tensor::stack_padded(&items, pad).unwrap();
        assert_eq!(stacked.shape(), &[pad, w]);
        for (i, item) in items.iter().enumerate() {
            assert_eq!(stacked.row(i).unwrap(), item.data(), "seed {seed} row {i}");
        }
        // padding rows replicate the last real row
        for i in n..pad {
            assert_eq!(stacked.row(i).unwrap(), items[n - 1].data(), "seed {seed} pad {i}");
        }
    }
}

#[test]
fn prop_softmax_is_distribution_and_argmax_stable() {
    for seed in 1..=100u64 {
        let mut rng = Rng::new(seed);
        let n = 2 + rng.usize(200);
        let logits: Vec<f32> = (0..n).map(|_| rng.f32() * 20.0 - 10.0).collect();
        let p = softmax(&logits);
        let sum: f32 = p.iter().sum();
        assert!((sum - 1.0).abs() < 1e-4, "seed {seed} sum {sum}");
        assert!(p.iter().all(|&x| (0.0..=1.0).contains(&x)));
        assert_eq!(argmax(&logits), argmax(&p), "softmax must preserve argmax");
    }
}

// ---------------------------------------------------------------------------
// net: packetizer round-trip and partial decode
// ---------------------------------------------------------------------------

/// Random permutation of 0..n via Fisher–Yates over the test PRNG.
fn random_order(rng: &mut Rng, n: usize) -> Vec<u32> {
    let mut order: Vec<u32> = (0..n as u32).collect();
    for i in (1..n).rev() {
        order.swap(i, rng.usize(i + 1));
    }
    order
}

#[test]
fn prop_packetizer_lossless_roundtrip_is_bit_exact() {
    for seed in 1..=60u64 {
        let mut rng = Rng::new(seed);
        let n = 1 + rng.usize(3000);
        let bits = 1 + rng.usize(8) as u32;
        let symbols: Vec<u8> = (0..n).map(|_| (rng.next() % (1u64 << bits)) as u8).collect();
        let cap = PACKET_HEADER_BYTES + 1 + rng.usize(200);
        let order = if rng.next() % 2 == 0 { Some(random_order(&mut rng, n)) } else { None };
        let pz = Packetizer::new(cap, order.clone());
        let packets = pz.packetize(seed, &symbols, bits).unwrap();
        // every packet respects the payload cap
        assert!(packets.iter().all(|p| p.app_bytes() <= cap), "seed {seed}");
        let (back, delivered) =
            reassemble_symbols(&packets, n, bits, 0xFF, order.as_deref()).unwrap();
        assert_eq!(back, symbols, "seed {seed} n {n} bits {bits} cap {cap}");
        assert_eq!(delivered, n, "seed {seed}");
    }
}

#[test]
fn prop_packetizer_any_subset_decodes_with_correct_feature_indices() {
    for seed in 1..=60u64 {
        let mut rng = Rng::new(seed);
        let n = 50 + rng.usize(2000);
        let bits = 1 + rng.usize(8) as u32;
        // fill-distinguishable symbols: never equal to the fill value below
        let fill = ((1u64 << bits) - 1) as u8;
        let symbols: Vec<u8> =
            (0..n).map(|_| (rng.next() % ((1u64 << bits) - 1)) as u8).collect();
        let order = if rng.next() % 2 == 0 { Some(random_order(&mut rng, n)) } else { None };
        let pz = Packetizer::new(PACKET_HEADER_BYTES + 1 + rng.usize(64), order.clone());
        let packets = pz.packetize(0, &symbols, bits).unwrap();
        // keep a random subset of packets
        let kept: Vec<_> = packets.into_iter().filter(|_| rng.next() % 2 == 0).collect();
        let (back, delivered) =
            reassemble_symbols(&kept, n, bits, fill, order.as_deref()).unwrap();
        assert_eq!(delivered, kept.iter().map(|p| p.range_len as usize).sum::<usize>());
        // delivered order-space ranges land on the right original indices
        let mut covered = vec![false; n];
        for p in &kept {
            for k in 0..p.range_len as usize {
                let pos = p.range_start as usize + k;
                let idx = order.as_ref().map_or(pos, |o| o[pos] as usize);
                covered[idx] = true;
            }
        }
        for i in 0..n {
            if covered[i] {
                assert_eq!(back[i], symbols[i], "seed {seed} idx {i}");
            } else {
                assert_eq!(back[i], fill, "seed {seed} idx {i} must be imputed");
            }
        }
    }
}

// ---------------------------------------------------------------------------
// net: channel determinism and the zero-loss special case
// ---------------------------------------------------------------------------

#[test]
fn prop_channel_same_seed_same_loss_pattern() {
    for seed in 1..=30u64 {
        let profile = NetworkProfile::wifi_6mbps();
        let run = |s: u64| {
            let mut ch = Channel::new(&profile, GilbertElliott::bursty(0.25, 3.0), None, s);
            let mut t = 0.0;
            let mut pattern = Vec::new();
            for k in 0..400usize {
                let tx = ch.send_packet(t, 100 + (k % 7) * 50);
                pattern.push((tx.arrival_s.is_some(), tx.t_end.to_bits()));
                t = tx.t_end;
            }
            pattern
        };
        assert_eq!(run(seed), run(seed), "seed {seed} must replay identically");
    }
}

#[test]
fn prop_zero_loss_channel_matches_network_sim_closed_form() {
    for seed in 1..=40u64 {
        let mut rng = Rng::new(seed);
        let profile = if rng.next() % 2 == 0 {
            NetworkProfile::wifi_6mbps()
        } else {
            NetworkProfile::ble_270kbps()
        };
        let sim = NetworkSim::new(profile.clone());
        let ch = Channel::ideal(&profile);
        for _ in 0..50 {
            let bytes = rng.usize(20_000);
            let t0 = rng.f32() as f64 * 100.0;
            let wire = if bytes == 0 {
                0
            } else {
                bytes + bytes.div_ceil(profile.mtu) * profile.per_packet_overhead
            };
            let closed_form = if bytes == 0 {
                0.0
            } else {
                wire as f64 * 8.0 / profile.bandwidth_bps + profile.one_way_latency_s
            };
            assert!((sim.transfer_s(bytes) - closed_form).abs() < 1e-12, "seed {seed}");
            assert!((ch.transfer_s(t0, bytes) - closed_form).abs() < 1e-12, "seed {seed}");
            assert_eq!(sim.wire_bytes(bytes), wire, "seed {seed}");
        }
    }
}

// ---------------------------------------------------------------------------
// xai metrics
// ---------------------------------------------------------------------------

#[test]
fn prop_natural_skewness_bounds_achieved() {
    for seed in 1..=100u64 {
        let mut rng = Rng::new(seed);
        let c = 4 + rng.usize(28);
        let k = 1 + rng.usize(c - 1);
        let imp: Vec<f64> = (0..c).map(|_| rng.f32() as f64).collect();
        let nat = xai::natural_skewness(&imp, k);
        let ach = xai::achieved_skewness(&imp, k);
        assert!(nat >= ach - 1e-9, "seed {seed}: natural {nat} < achieved {ach}");
        assert!((0.0..=1.0 + 1e-9).contains(&nat));
        // equality iff not disordered
        if !xai::is_disordered(&imp, k) {
            assert!((nat - ach).abs() < 1e-9, "seed {seed}");
        }
    }
}

// ---------------------------------------------------------------------------
// tune: Pareto front invariants
// ---------------------------------------------------------------------------

/// Objective vectors drawn from small discrete grids, so exact ties and
/// duplicate points occur constantly — the hard cases for front stability.
fn rand_objectives(rng: &mut Rng, n: usize) -> Vec<Objectives> {
    (0..n)
        .map(|_| Objectives {
            accuracy: rng.usize(4) as f64 * 0.25,
            p99_latency_s: rng.usize(3) as f64 * 0.01,
            goodput_bps: rng.usize(3) as f64 * 1e5,
            server_seconds: rng.usize(3) as f64,
        })
        .collect()
}

#[test]
fn prop_pareto_front_members_are_mutually_non_dominated() {
    for seed in 1..=200u64 {
        let mut rng = Rng::new(seed);
        let n = 1 + rng.usize(40);
        let objs = rand_objectives(&mut rng, n);
        let front = ranking::pareto_front(&objs);
        assert!(!front.is_empty(), "seed {seed}: a non-empty set has a front");
        for (k, &i) in front.iter().enumerate() {
            for &j in front.iter().skip(k + 1) {
                assert!(
                    !ranking::dominates(&objs[i], &objs[j])
                        && !ranking::dominates(&objs[j], &objs[i]),
                    "seed {seed}: front members {i} and {j} dominate each other"
                );
            }
        }
    }
}

#[test]
fn prop_pareto_excluded_points_are_dominated_by_a_front_member() {
    for seed in 1..=200u64 {
        let mut rng = Rng::new(seed);
        let n = 1 + rng.usize(40);
        let objs = rand_objectives(&mut rng, n);
        let front = ranking::pareto_front(&objs);
        for (i, o) in objs.iter().enumerate() {
            if front.contains(&i) {
                continue;
            }
            // dominance is transitive, so some front member witnesses
            // every exclusion
            assert!(
                front.iter().any(|&f| ranking::dominates(&objs[f], o)),
                "seed {seed}: excluded point {i} has no dominating front member"
            );
        }
    }
}

#[test]
fn prop_pareto_front_is_stable_under_permutation() {
    for seed in 1..=200u64 {
        let mut rng = Rng::new(seed);
        let n = 2 + rng.usize(30);
        let objs = rand_objectives(&mut rng, n);
        let mut perm: Vec<usize> = (0..n).collect();
        for i in (1..n).rev() {
            perm.swap(i, rng.usize(i + 1));
        }
        let shuffled: Vec<Objectives> = perm.iter().map(|&i| objs[i]).collect();
        // compare fronts as ordered value sequences (compare() totally
        // orders distinct vectors; ties are bit-identical duplicates)
        let values = |set: &[Objectives], front: &[usize]| -> Vec<String> {
            front.iter().map(|&i| set[i].to_ordered_json()).collect()
        };
        let a = values(&objs, &ranking::pareto_front(&objs));
        let b = values(&shuffled, &ranking::pareto_front(&shuffled));
        assert_eq!(a, b, "seed {seed}: the front must not depend on evaluation order");
    }
}

// ---------------------------------------------------------------------------
// observability: the Chrome trace export is a pure function of the event SET
// ---------------------------------------------------------------------------

fn rand_events(rng: &mut Rng, n: usize) -> Vec<TraceEvent> {
    use EventKind::*;
    const SPANS: [EventKind; 5] = [Encode, RadioWait, Uplink, ServerQueue, Remote];
    const INSTANTS: [EventKind; 4] = [Arrival, Done, BatchDispatch, PacketLost];
    (0..n)
        .map(|_| {
            let lane = match rng.usize(3) {
                0 => Lane::Device(rng.usize(4) as u32),
                1 => Lane::Server(rng.usize(2) as u32),
                _ => Lane::Tuner,
            };
            let id = rng.usize(16) as u64;
            let t = rng.f32() as f64;
            if rng.usize(2) == 0 {
                let kind = SPANS[rng.usize(SPANS.len())];
                TraceEvent::span(lane, kind, id, t, t + rng.f32() as f64, rng.f32() as f64)
            } else {
                let kind = INSTANTS[rng.usize(INSTANTS.len())];
                TraceEvent::instant(lane, kind, id, t, rng.f32() as f64)
            }
        })
        .collect()
}

#[test]
fn prop_chrome_trace_export_is_recording_order_invariant() {
    // the exporter sorts by the total (time, lane, kind, ...) order, so any
    // permutation of the same events serializes byte-identically — the
    // property behind the golden trace's bitwise reproducibility
    for seed in 1..=200u64 {
        let mut rng = Rng::new(seed);
        let n = 1 + rng.usize(60);
        let evs = rand_events(&mut rng, n);
        let mut shuffled = evs.clone();
        for i in (1..n).rev() {
            shuffled.swap(i, rng.usize(i + 1));
        }
        let (a, b) = (chrome_trace_json(&evs), chrome_trace_json(&shuffled));
        assert_eq!(a, b, "seed {seed}: export must not depend on recording order");
        let v = agilenn::json::Value::parse(&a).expect("export must be valid JSON");
        assert!(v.as_arr().unwrap().len() >= n, "metadata + one entry per event");
    }
}

// ---------------------------------------------------------------------------
// serving config: from_config ⇄ to_config is lossless
// ---------------------------------------------------------------------------

/// A random valid [`PolicyConfig`]: an ascending width subset of 1..=8
/// plus randomized bands that keep `validate()`'s invariants
/// (rate_low < rate_high, depth_low < depth_high, sustain >= 1).
fn rand_policy(rng: &mut Rng) -> PolicyConfig {
    let mut widths: Vec<u32> = (1..=8).filter(|_| rng.usize(3) == 0).collect();
    if widths.is_empty() {
        widths = vec![1 + rng.usize(8) as u32];
    }
    let rate_low = 0.4 + 0.3 * rng.f32() as f64;
    PolicyConfig {
        widths,
        ewma_alpha: 0.05 + 0.9 * rng.f32() as f64,
        rate_low,
        rate_high: (rate_low + 0.05 + 0.2 * rng.f32() as f64).min(1.0),
        rounds_high: 0.5 + 2.0 * rng.f32() as f64,
        goodput_low_bps: if rng.usize(2) == 0 { 0.0 } else { 1e5 },
        depth_high: 5 + rng.usize(8),
        depth_low: rng.usize(4),
        sustain: 1 + rng.usize(3) as u32,
        cooldown: rng.usize(9) as u32,
        anytime_deadline_s: if rng.usize(4) == 0 { 0.0 } else { 0.01 + 0.05 * rng.f32() as f64 },
        local_fallback: rng.usize(2) == 0,
        probe_every: 1 + rng.usize(16) as u32,
    }
}

/// A builder with every `RunConfig`-backed knob randomized through the
/// grouped sub-config surface.
fn rand_serve_builder(rng: &mut Rng) -> ServeBuilder {
    const SCHEMES: [Scheme; 5] =
        [Scheme::Agile, Scheme::Deepcod, Scheme::Spinn, Scheme::Mcunet, Scheme::EdgeOnly];
    let loss = if rng.usize(2) == 0 {
        GilbertElliott::uniform(rng.f32() as f64 * 0.5)
    } else {
        GilbertElliott::bursty(rng.f32() as f64 * 0.5, 1.0 + rng.f32() as f64 * 7.0)
    };
    let delivery = if rng.usize(2) == 0 {
        DeliveryPolicy::Arq
    } else {
        DeliveryPolicy::Anytime { deadline_s: 0.005 + rng.f32() as f64 * 0.05 }
    };
    let order = if rng.usize(2) == 0 { PacketOrder::Importance } else { PacketOrder::Index };
    let payload = if rng.usize(2) == 0 { None } else { Some(32 + rng.usize(512)) };
    let trace =
        if rng.usize(2) == 0 { None } else { Some(BandwidthTrace::constant(1e5 + rng.f32() as f64 * 1e7)) };
    let seed = rng.next();
    let mut b = ServeBuilder::new(["svhns", "cifar"][rng.usize(2)])
        .artifacts_dir(["/nonexistent/a", "/nonexistent/b"][rng.usize(2)])
        .scheme(SCHEMES[rng.usize(SCHEMES.len())])
        .backend(if rng.usize(2) == 0 { BackendKind::Reference } else { BackendKind::Pjrt })
        .bits(1 + rng.usize(6) as u32);
    // draw outside the closures: capturing `rng` would borrow it twice
    let (max_batch, deadline_us) = (1 << rng.usize(4), rng.next() % 5_000);
    b = b.batch(move |bt| {
        bt.max_batch = max_batch;
        bt.deadline_us = deadline_us;
    });
    b = b.net(move |n| {
        n.loss = loss;
        n.delivery = delivery;
        n.order = order;
        n.packet_payload = payload;
        n.trace = trace;
        n.seed = seed;
    });
    if rng.usize(2) == 0 {
        b = b.alpha(rng.f32() as f64);
    }
    if rng.usize(2) == 0 {
        b = b.policy(rand_policy(rng));
    }
    if rng.usize(2) == 0 {
        b = b.device_profile(if rng.usize(2) == 0 {
            DeviceProfile::stm32f746()
        } else {
            DeviceProfile::stm32h743()
        });
    }
    if rng.usize(2) == 0 {
        b = b.network_profile(if rng.usize(2) == 0 {
            NetworkProfile::wifi_6mbps()
        } else {
            NetworkProfile::ble_270kbps()
        });
    }
    b
}

#[test]
fn prop_serve_builder_config_round_trip_is_lossless() {
    // from_config is the exact inverse of to_config on the RunConfig
    // surface: rebuilding a builder from its resolved config and
    // resolving again must reproduce the config field for field —
    // including the grouped batch/net sub-configs and the optional
    // policy ladder
    for seed in 1..=300u64 {
        let mut rng = Rng::new(seed);
        let cfg = rand_serve_builder(&mut rng).to_config();
        let back = ServeBuilder::from_config(cfg.clone()).to_config();
        assert_eq!(back, cfg, "seed {seed}: from_config ⇄ to_config must be lossless");
    }
}

// ---------------------------------------------------------------------------
// adaptive policy: hysteresis converges on a constant channel
// ---------------------------------------------------------------------------

/// A random constant channel observation: one `NetStats` + advertised
/// depth fed back verbatim after every offloaded decision.
fn rand_observation(rng: &mut Rng) -> (NetStats, usize) {
    let delivered = rng.usize(101);
    let stats = NetStats {
        packets_sent: 5,
        packets_lost: rng.usize(3),
        retransmit_rounds: rng.usize(4),
        features_total: 100,
        features_delivered: delivered,
        app_bytes_offered: 400,
        app_bytes_delivered: 4 * delivered,
        complete: delivered == 100,
        radio_wait_s: 0.0,
        uplink_s: 0.005 + rng.f32() as f64 * 0.05,
        airtime_s: 0.004,
    };
    (stats, rng.usize(14))
}

#[test]
fn prop_policy_hysteresis_converges_and_never_flaps_on_a_constant_channel() {
    // the good/bad signal bands are disjoint, so a constant observation
    // stream classifies one way forever: the ladder walks monotonically
    // to its resting rung — at most one step per rung — and then freezes.
    // Decisions are pure state-machine arithmetic, so a second identical
    // run reproduces the sequence exactly.
    for seed in 1..=150u64 {
        let mut rng = Rng::new(seed);
        let cfg = rand_policy(&mut rng);
        cfg.validate().expect("rand_policy must generate valid configs");
        let (stats, depth) = rand_observation(&mut rng);
        let run = || {
            let mut pol = DevicePolicy::new(cfg.clone());
            let mut decisions = Vec::with_capacity(600);
            let mut steps_at_burn_in = 0;
            for i in 0..600 {
                let d = pol.decide();
                if !d.local_only {
                    pol.observe(&stats, depth); // local-only skips the uplink
                }
                decisions.push(d);
                if i == 399 {
                    steps_at_burn_in = pol.steps();
                }
            }
            (decisions, steps_at_burn_in, pol.steps())
        };
        let (decisions, steps_at_burn_in, steps) = run();
        // monotone descent (or none): one transition per rung at most —
        // widths.len()-1 width steps, plus anytime, plus local-only
        let max_steps = (cfg.widths.len() + 1) as u64;
        assert!(steps <= max_steps, "seed {seed}: {steps} ladder steps > bound {max_steps}");
        // converged: 400 observations cover any descent (each step needs
        // at most sustain + cooldown <= 11 of them, over at most 9 rungs),
        // so the ladder must be frozen across the tail...
        assert_eq!(steps, steps_at_burn_in, "seed {seed}: ladder stepped after burn-in");
        // ...and the decision stream's width constant
        let tail = &decisions[400..];
        assert!(
            tail.windows(2).all(|w| w[0].bits == w[1].bits),
            "seed {seed}: width still moving after burn-in"
        );
        // bitwise double-run determinism of the decision sequence
        let (again, _, steps2) = run();
        assert_eq!(decisions, again, "seed {seed}: decisions must reproduce exactly");
        assert_eq!(steps, steps2);
    }
}
