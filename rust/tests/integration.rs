//! Integration tests over the serving stack.
//!
//! The suite runs **unconditionally** on the pure-Rust reference backend
//! (`BackendKind::Reference` + the synthetic world in `agilenn::fixtures`):
//! no artifacts directory, no PJRT, no skips — the whole
//! device→channel→batcher→fuser pipeline executes on every `cargo test`.
//!
//! The PJRT twin of the suite (real AOT artifacts, real numerics) lives in
//! [`pjrt_artifact_tests`] at the bottom: it compiles only with the `pjrt`
//! cargo feature and still skips gracefully when `make artifacts` hasn't
//! been run.

use agilenn::baselines::{make_runner, AgileRunner, SchemeRunner};
use agilenn::config::{BackendKind, Meta, RunConfig, Scheme};
use agilenn::coordinator::{DeviceRuntime, RemoteServer};
use agilenn::fixtures::{SyntheticSpec, SYNTHETIC_DATASET};
use agilenn::net::{DeliveryPolicy, GilbertElliott};
use agilenn::obs::{
    chrome_trace_json, EventKind, Lane, NoopSink, RecordingSink, TraceEvent, Tracer,
};
use agilenn::runtime::{make_backend, ReferenceBackend};
use agilenn::serve::{
    AutoscaleConfig, ClockKind, ConfigError, Daemon, Placement, PipelineReport, PolicyConfig,
    ServeBuilder, Service, SimEngine,
};
use agilenn::tune::{self, ranking, EvalSpec, SearchSpace, StrategyKind, TuneConfig};
use agilenn::workload::{Arrival, TestSet};
use std::path::PathBuf;
use std::sync::Arc;

/// A path no artifacts tree will ever live at: every reference-backend
/// test below proves the pipeline runs with *no* artifacts directory.
const NO_ARTIFACTS: &str = "/nonexistent/agilenn-artifacts";

struct RefCtx {
    backend: ReferenceBackend,
    cfg: RunConfig,
    meta: Meta,
    testset: TestSet,
}

fn ref_ctx(scheme: Scheme) -> RefCtx {
    let spec = SyntheticSpec::new(SYNTHETIC_DATASET);
    let meta = spec.meta();
    let mut cfg = RunConfig::new(NO_ARTIFACTS, SYNTHETIC_DATASET, scheme);
    cfg.backend = BackendKind::Reference;
    RefCtx {
        backend: ReferenceBackend::from_meta(&meta),
        cfg,
        meta,
        testset: spec.testset(64).unwrap(),
    }
}

/// A `ServeBuilder` pinned to the reference backend and the synthetic
/// dataset, pointing at a nonexistent artifacts tree on purpose.
fn reference_builder(scheme: Scheme) -> ServeBuilder {
    ServeBuilder::new(SYNTHETIC_DATASET)
        .artifacts_dir(NO_ARTIFACTS)
        .backend(BackendKind::Reference)
        .scheme(scheme)
}

// ---------------------------------------------------------------------------
// device/server halves on the reference backend
// ---------------------------------------------------------------------------

#[test]
fn reference_device_module_shapes_match_meta() {
    let c = ref_ctx(Scheme::Agile);
    let mut device = DeviceRuntime::new(&c.backend, &c.cfg, &c.meta).unwrap();
    let out = device.process(&c.testset.image(0).unwrap()).unwrap();
    assert_eq!(out.local_logits.len(), c.meta.num_classes);
    let [h, w, ch] = c.meta.feature;
    assert_eq!(out.remote_shape, vec![1, h, w, ch - c.meta.k]);
    assert_eq!(out.frame.count, c.meta.tx_elements(Scheme::Agile));
    assert!(out.timings.total_s() > 0.0);
}

#[test]
fn reference_remote_batch_padding_is_row_consistent() {
    // the same features must yield identical logits whether run at batch
    // size 1 or padded into a batch of 8 — on the reference family the
    // rows are computed independently, so the match is bitwise
    let c = ref_ctx(Scheme::Agile);
    let mut device = DeviceRuntime::new(&c.backend, &c.cfg, &c.meta).unwrap();
    let mut server = RemoteServer::new(&c.backend, &c.cfg, &c.meta).unwrap();
    let feats: Vec<_> = (0..5)
        .map(|i| {
            let out = device.process(&c.testset.image(i).unwrap()).unwrap();
            server.decode(&out.frame).unwrap()
        })
        .collect();
    let single: Vec<Vec<f32>> = feats
        .iter()
        .map(|f| server.infer(std::slice::from_ref(f)).unwrap().remove(0))
        .collect();
    let batched = server.infer(&feats).unwrap(); // pads 5 -> 8
    for (s, b) in single.iter().zip(&batched) {
        assert_eq!(s, b, "batch padding changed reference logits");
    }
}

#[test]
fn reference_accuracy_survives_the_quantized_tx_path() {
    // end-to-end through quantize -> LZW -> decode -> remote head ->
    // alpha fusion: the reference family recovers every synthetic label
    let c = ref_ctx(Scheme::Agile);
    let mut runner = AgileRunner::new(&c.backend, &c.cfg, &c.meta).unwrap();
    let n = c.testset.len();
    let mut correct = 0;
    for i in 0..n {
        let out =
            SchemeRunner::process(&mut runner, &c.testset.image(i).unwrap(), c.testset.labels[i])
                .unwrap();
        correct += out.correct as usize;
    }
    let acc = correct as f64 / n as f64;
    let nominal = c.meta.accuracy.agile_quant4;
    assert!(acc >= 0.95, "clean-link reference accuracy {acc} must be ~1.0");
    assert!((acc - nominal).abs() < 0.08, "accuracy {acc} vs nominal {nominal}");
}

#[test]
fn reference_all_schemes_produce_outcomes() {
    let c = ref_ctx(Scheme::Agile);
    let img = c.testset.image(0).unwrap();
    for scheme in Scheme::all() {
        let mut cfg = c.cfg.clone();
        cfg.scheme = scheme;
        let mut runner = make_runner(&c.backend, &cfg, &c.meta).unwrap();
        let out = runner.process(&img, c.testset.labels[0]).unwrap();
        assert!(out.predicted < c.meta.num_classes, "{}", scheme.name());
        assert!(out.correct, "{} must recover the synthetic label", scheme.name());
        assert!(out.breakdown.total_s() > 0.0, "{}", scheme.name());
        assert!(out.energy.total_j() > 0.0, "{}", scheme.name());
        let mem = runner.memory_report();
        assert!(mem.fits(), "{} must fit the STM32F746 budgets", scheme.name());
        match scheme {
            Scheme::Mcunet => assert_eq!(out.tx_bytes, 0),
            Scheme::Agile | Scheme::Deepcod | Scheme::EdgeOnly => assert!(out.tx_bytes > 0),
            Scheme::Spinn => {} // tx depends on the early exit
        }
    }
}

#[test]
fn reference_tx_stream_is_compressible() {
    // the family's skewed (half-zero) features must make the quantized +
    // LZW'd uplink far smaller than shipping raw f32 features
    let c = ref_ctx(Scheme::Agile);
    let mut runner = make_runner(&c.backend, &c.cfg, &c.meta).unwrap();
    let n = 16;
    let mut tx = 0usize;
    for i in 0..n {
        tx += runner.process(&c.testset.image(i).unwrap(), c.testset.labels[i]).unwrap().tx_bytes;
    }
    let raw = n * c.meta.tx_elements(Scheme::Agile) * 4;
    assert!(tx * 2 < raw, "compressed {tx} vs raw {raw}: expected >2x saving");
}

#[test]
fn reference_alpha_override_changes_behavior_at_extremes() {
    let c = ref_ctx(Scheme::Agile);
    let mut runner = AgileRunner::new(&c.backend, &c.cfg, &c.meta).unwrap();
    let n = 48.min(c.testset.len());
    let mut acc_at = |alpha: f64, runner: &mut AgileRunner| {
        runner.set_alpha(alpha).unwrap();
        let mut correct = 0;
        for i in 0..n {
            let out = SchemeRunner::process(
                runner,
                &c.testset.image(i).unwrap(),
                c.testset.labels[i],
            )
            .unwrap();
            correct += out.correct as usize;
        }
        correct as f64 / n as f64
    };
    let trained = acc_at(c.meta.alpha, &mut runner);
    let local_only = acc_at(1.0, &mut runner);
    let remote_only = acc_at(0.0, &mut runner);
    // the reference family classifies from either head alone, so every
    // mix must work — and the trained combination never loses to an
    // extreme (Fig 18's shape)
    assert!(trained >= local_only - 1e-9, "trained {trained} < local-only {local_only}");
    assert!(remote_only > 0.9, "remote head alone must classify: {remote_only}");
}

#[test]
fn reference_offline_fallback_runs_without_network() {
    let c = ref_ctx(Scheme::Agile);
    let mut runner = AgileRunner::new(&c.backend, &c.cfg, &c.meta).unwrap();
    let out = runner.process_offline(&c.testset.image(0).unwrap(), c.testset.labels[0]).unwrap();
    assert_eq!(out.tx_bytes, 0);
    assert_eq!(out.breakdown.network_s, 0.0);
    assert!(out.exited_early);
    assert!(out.correct, "local top-k head alone must recover the label");
}

#[test]
fn reference_spinn_exit_rate_matches_the_exported_meta() {
    // fixture samples alternate strong/weak amplitudes, so the exit head
    // resolves exactly the strong half on device
    let c = ref_ctx(Scheme::Spinn);
    let mut runner = make_runner(&c.backend, &c.cfg, &c.meta).unwrap();
    let n = 32;
    let mut exits = 0usize;
    for i in 0..n {
        let out = runner.process(&c.testset.image(i).unwrap(), c.testset.labels[i]).unwrap();
        assert!(out.correct, "sample {i}");
        exits += out.exited_early as usize;
    }
    let rate = exits as f64 / n as f64;
    assert!(
        (rate - c.meta.spinn_exit.rate).abs() < 0.1,
        "exit rate {rate} vs exported {}",
        c.meta.spinn_exit.rate
    );
}

// ---------------------------------------------------------------------------
// the batched multi-device pipeline, artifact-free
// ---------------------------------------------------------------------------

#[test]
fn reference_pipeline_serves_all_requests() {
    let c = ref_ctx(Scheme::Agile);
    let spec = SyntheticSpec::new(SYNTHETIC_DATASET);
    let rep = Service::from_parts(
        c.cfg.clone(),
        c.meta.clone(),
        Arc::new(spec.testset(64).unwrap()),
        3,
        24,
        Arrival::Poisson { hz: 200.0, seed: 7 },
    )
    .unwrap()
    .with_clock(ClockKind::Sim)
    .run()
    .unwrap();
    assert_eq!(rep.requests, 24);
    assert!(rep.throughput_rps > 0.0);
    assert!(rep.mean_batch_size >= 1.0);
    assert!(rep.batches >= 3); // at least one per device's first send
}

#[test]
fn reference_serve_runs_all_five_schemes_through_the_batched_pipeline() {
    // the acceptance bar: with no artifacts directory at all, every
    // scheme completes N requests through the multi-device batched
    // Service on the reference backend
    let n = 12;
    for scheme in Scheme::all() {
        let rep = reference_builder(scheme)
            .fleet(|f| f.devices = 2)
            .fleet(|f| f.requests = n)
            .rate_hz(500.0)
            .build()
            .unwrap()
            .run()
            .unwrap();
        assert_eq!(rep.requests, n, "{}", scheme.name());
        assert!(rep.throughput_rps > 0.0, "{}", scheme.name());
        assert!(rep.accuracy > 0.0, "{}", scheme.name());
        match scheme {
            // local-only requests never touch the batcher
            Scheme::Mcunet => assert_eq!(rep.batches, 0, "{}", scheme.name()),
            // offloading schemes must have batched something
            Scheme::Agile | Scheme::Deepcod | Scheme::EdgeOnly => {
                assert!(rep.batches > 0, "{}", scheme.name())
            }
            Scheme::Spinn => {} // batches depend on the early-exit rate
        }
    }
}

#[test]
fn reference_streaming_outcomes_are_observable_per_request() {
    let n = 16;
    let mut stream =
        reference_builder(Scheme::Agile).fleet(|f| f.devices = 2).fleet(|f| f.requests = n).build().unwrap().stream().unwrap();
    let mut ids = std::collections::HashSet::new();
    let mut count = 0;
    for out in stream.by_ref() {
        assert!(ids.insert(out.id), "duplicate outcome id {}", out.id);
        assert!(out.device < 2);
        assert!(out.wall_s > 0.0);
        assert!(out.outcome.tx_bytes > 0); // agile always uplinks
        assert!(out.outcome.predicted < 10);
        count += 1;
    }
    assert_eq!(count, n);
    let rep = stream.finish().unwrap();
    assert_eq!(rep.requests, n);
}

#[test]
fn serve_builder_reference_needs_no_artifacts_directory() {
    // Meta::load on the same config must fail — and the builder must not
    // care, because the synthetic world replaces the artifacts tree
    let cfg = reference_builder(Scheme::Agile).to_config();
    assert!(Meta::load(&cfg.dataset_dir()).is_err(), "test must point at no artifacts");
    assert!(TestSet::load(&cfg.dataset_dir().join("test.bin")).is_err());
    let rep = reference_builder(Scheme::Agile).fleet(|f| f.requests = 4).build().unwrap().run().unwrap();
    assert_eq!(rep.requests, 4);
    // and make_backend resolves without touching the filesystem
    let backend = make_backend(&cfg, &SyntheticSpec::new(SYNTHETIC_DATASET).meta()).unwrap();
    assert_eq!(backend.name(), "reference");
}

// ---------------------------------------------------------------------------
// lossy channel + serving clock, artifact-free
// ---------------------------------------------------------------------------

#[test]
fn reference_lossy_serve_is_seed_deterministic() {
    // two runs with the same ServeBuilder seeds produce the same accuracy
    // and transport counters (wall-clock fields excepted)
    let run = || {
        reference_builder(Scheme::Agile)
            .fleet(|f| f.devices = 2)
            .fleet(|f| f.requests = 24)
            .batch(|b| b.max_batch = 1)
            .net(|n| n.loss = GilbertElliott::bursty(0.3, 4.0))
            .net(|n| n.delivery = DeliveryPolicy::Anytime { deadline_s: 0.01 })
            .net(|n| n.packet_payload = Some(64))
            .net(|n| n.seed = 9)
            .build()
            .unwrap()
            .run()
            .unwrap()
    };
    let (a, b) = (run(), run());
    assert_eq!(a.accuracy, b.accuracy);
    assert_eq!(a.packets_sent, b.packets_sent);
    assert_eq!(a.packets_lost, b.packets_lost);
    assert_eq!(a.retransmit_rounds, b.retransmit_rounds);
    assert_eq!(a.incomplete_frames, b.incomplete_frames);
    assert_eq!(a.delivered_feature_rate, b.delivered_feature_rate);
    // the mean is deterministic up to f64 summation order (outcomes can
    // arrive in a different interleaving run to run)
    assert!((a.mean_net_s - b.mean_net_s).abs() < 1e-9);
    assert!(a.packets_lost > 0, "30% loss over 24 uplinks must drop something");
}

#[test]
fn reference_anytime_transport_decodes_partial_frames_under_heavy_loss() {
    // paced arrivals on the sim clock: the radio is uncontended, so
    // p99_net_s measures the transport alone — and the pacing costs no
    // wall time
    let rep = reference_builder(Scheme::Agile)
        .fleet(|f| f.devices = 1)
        .fleet(|f| f.requests = 16)
        .batch(|b| b.max_batch = 1)
        .arrival(Arrival::Periodic { hz: 30.0 })
        .clock(ClockKind::Sim)
        .net(|n| n.loss = GilbertElliott::uniform(0.5))
        // tight deadline: one pass, no time for full recovery
        .net(|n| n.delivery = DeliveryPolicy::Anytime { deadline_s: 0.004 })
        .net(|n| n.packet_payload = Some(64))
        .net(|n| n.seed = 3)
        .build()
        .unwrap()
        .run()
        .unwrap();
    assert_eq!(rep.requests, 16);
    assert!(rep.incomplete_frames > 0, "50% loss must leave partial frames");
    assert!(rep.delivered_feature_rate < 1.0);
    assert!(rep.delivered_feature_rate > 0.0);
    // every request still produced a prediction (graceful degradation);
    // the imputed reference symbols keep most of them correct
    assert!(rep.accuracy > 0.5, "accuracy {}", rep.accuracy);
    // the deadline bounds the simulated link time
    assert!(rep.p99_net_s <= 0.004 + 0.01, "p99 net {}", rep.p99_net_s);
}

#[test]
fn reference_zero_loss_channel_reproduces_the_ideal_link_numbers() {
    // at 0% loss the default (ARQ, whole-frame) path is behaviorally
    // identical to the pre-channel NetworkSim pricing
    use agilenn::simulator::NetworkSim;
    let mut stream = reference_builder(Scheme::Agile)
        .fleet(|f| f.devices = 1)
        .fleet(|f| f.requests = 8)
        .batch(|b| b.max_batch = 1)
        .arrival(Arrival::Periodic { hz: 30.0 })
        .clock(ClockKind::Sim)
        .build()
        .unwrap()
        .stream()
        .unwrap();
    let cfg = reference_builder(Scheme::Agile).to_config();
    let net = NetworkSim::new(cfg.network.clone());
    let reply = agilenn::serve::reply_bytes(10);
    for out in stream.by_ref() {
        let expect = net.transfer_s(out.outcome.tx_bytes) + net.transfer_s(reply);
        let got = out.outcome.breakdown.network_s;
        assert!((got - expect).abs() < 1e-9, "network_s {got} != closed form {expect}");
        assert!(out.outcome.net.complete);
        assert_eq!(out.outcome.net.packets_lost, 0);
        assert_eq!(out.outcome.net.radio_wait_s, 0.0, "paced run must not queue the radio");
    }
    stream.finish().unwrap();
}

#[test]
fn reference_sim_clock_serve_is_bit_reproducible_and_never_sleeps() {
    // two identical-seed sim-clock runs produce bit-identical accuracy,
    // latency quantiles and net counters
    let run = || -> PipelineReport {
        reference_builder(Scheme::Agile)
            .fleet(|f| f.devices = 8)
            .fleet(|f| f.requests = 512)
            .rate_hz(200.0)
            .arrival_seed(11)
            .batch(|b| b.max_batch = 1)
            .net(|n| n.loss = GilbertElliott::bursty(0.2, 4.0))
            .net(|n| n.seed = 5)
            .clock(ClockKind::Sim)
            .build()
            .unwrap()
            .run()
            .unwrap()
    };
    let (a, b) = (run(), run());
    assert_eq!(a.clock, ClockKind::Sim);
    assert_eq!(a.requests, 512);
    assert_eq!(a.accuracy, b.accuracy);
    assert_eq!(a.p95_latency_s, b.p95_latency_s, "latency quantiles must be virtual-time exact");
    assert_eq!(a.p99_net_s, b.p99_net_s);
    assert_eq!(a.packets_sent, b.packets_sent);
    assert_eq!(a.packets_lost, b.packets_lost);
    assert_eq!(a.retransmit_rounds, b.retransmit_rounds);
    assert_eq!(a.incomplete_frames, b.incomplete_frames);
    assert_eq!(a.delivered_feature_rate, b.delivered_feature_rate);
    assert!((a.wall_s - b.wall_s).abs() < 1e-9, "virtual makespan must reproduce");
    assert!((a.mean_latency_s - b.mean_latency_s).abs() < 1e-9);
    // the virtual makespan covers the arrival schedule (~64 reqs/device
    // at 200 Hz ≈ 0.32 s), not the microseconds an unpaced run would show
    assert!(a.wall_s > 0.1, "virtual time {} must reflect the pacing", a.wall_s);
    assert!(a.packets_lost > 0, "20% bursty loss must drop something");
}

#[test]
fn reference_wall_and_sim_clocks_agree_on_the_seed_deterministic_fields() {
    // the simulated timeline is schedule-anchored, so switching clocks
    // must not move any deterministic field
    let run = |clock: ClockKind| -> PipelineReport {
        reference_builder(Scheme::Agile)
            .fleet(|f| f.devices = 2)
            .fleet(|f| f.requests = 16)
            .rate_hz(120.0)
            .arrival_seed(3)
            .batch(|b| b.max_batch = 1)
            .net(|n| n.loss = GilbertElliott::uniform(0.1))
            .net(|n| n.seed = 4)
            .clock(clock)
            .build()
            .unwrap()
            .run()
            .unwrap()
    };
    let (w, s) = (run(ClockKind::Wall), run(ClockKind::Sim));
    assert_eq!(w.clock, ClockKind::Wall);
    assert_eq!(s.clock, ClockKind::Sim);
    assert_eq!(w.accuracy, s.accuracy);
    assert_eq!(w.packets_sent, s.packets_sent);
    assert_eq!(w.packets_lost, s.packets_lost);
    assert_eq!(w.retransmit_rounds, s.retransmit_rounds);
    assert_eq!(w.incomplete_frames, s.incomplete_frames);
    assert_eq!(w.delivered_feature_rate, s.delivered_feature_rate);
    assert_eq!(w.p99_net_s, s.p99_net_s, "link quantiles derive from the same multiset");
    assert!((w.mean_net_s - s.mean_net_s).abs() < 1e-9);
    assert!((w.mean_radio_wait_s - s.mean_radio_wait_s).abs() < 1e-12);
}

// ---------------------------------------------------------------------------
// the real-socket serving daemon: loopback runs verify against the simulator
// ---------------------------------------------------------------------------

/// Spawn a loopback daemon hosting the agile scheme, returning its
/// address and the running thread.
fn spawn_loopback_daemon() -> (String, std::thread::JoinHandle<agilenn::serve::DaemonSummary>) {
    let daemon = Daemon::bind("127.0.0.1:0", reference_builder(Scheme::Agile)).unwrap();
    let addr = daemon.local_addr().unwrap().to_string();
    (addr, std::thread::spawn(move || daemon.run().unwrap()))
}

#[test]
fn reference_loopback_daemon_matches_the_event_engine_bitwise() {
    // THE verification contract of the socket path (docs/daemon.md): the
    // same workload run (a) in-process on the sim clock's event engine and
    // (b) on the wall clock against a real TCP daemon over loopback must
    // agree bit for bit on every seed-deterministic report field. The
    // simulated channel stays on the device client, so swapping the mpsc
    // fabric for a socket may not move a single schedule-anchored bit.
    // Both delivery policies, so both wire bodies (whole frame / packet
    // subset) cross the real socket.
    for delivery in [DeliveryPolicy::Arq, DeliveryPolicy::Anytime { deadline_s: 0.004 }] {
        let configure = |b: ServeBuilder| {
            b.fleet(|f| f.devices = 3)
                .fleet(|f| f.requests = 24)
                .arrival(Arrival::Periodic { hz: 1e9 }) // unpaced: wall run is instant
                .batch(|b| b.max_batch = 4)
                .net(|n| n.loss = GilbertElliott::bursty(0.25, 4.0))
                .net(|n| n.delivery = delivery.clone())
                .net(|n| n.seed = 5)
        };
        let label = delivery.name();
        let mut engine_stream = configure(reference_builder(Scheme::Agile))
            .clock(ClockKind::Sim)
            .build()
            .unwrap()
            .stream()
            .unwrap();
        engine_stream.by_ref().for_each(drop);
        let (engine, mut engine_reg) = engine_stream.finish_full().unwrap();

        let (addr, daemon) = spawn_loopback_daemon();
        let mut loop_stream = configure(reference_builder(Scheme::Agile))
            .connect(&addr)
            .build()
            .unwrap()
            .stream()
            .unwrap();
        loop_stream.by_ref().for_each(drop);
        let (loopback, mut loop_reg) = loop_stream.finish_full().unwrap();
        agilenn::serve::send_shutdown(&addr).unwrap();
        let summary = daemon.join().unwrap();

        assert_eq!(loopback.accuracy.to_bits(), engine.accuracy.to_bits(), "{label}: accuracy");
        assert_eq!(loopback.packets_sent, engine.packets_sent, "{label}: packets sent");
        assert_eq!(loopback.packets_lost, engine.packets_lost, "{label}: packets lost");
        assert_eq!(loopback.retransmit_rounds, engine.retransmit_rounds, "{label}: retx");
        assert_eq!(loopback.incomplete_frames, engine.incomplete_frames, "{label}: partial");
        assert_eq!(
            loopback.delivered_feature_rate.to_bits(),
            engine.delivered_feature_rate.to_bits(),
            "{label}: delivered rate"
        );
        assert_eq!(
            loopback.p99_net_s.to_bits(),
            engine.p99_net_s.to_bits(),
            "{label}: link p99 derives from the same schedule-anchored multiset"
        );
        // the registries behind the reports agree on every wire counter
        for c in ["uplinks", "bytes_delivered", "features_total", "features_delivered"] {
            assert_eq!(loop_reg.counter(c), engine_reg.counter(c), "{label}: counter {c}");
        }
        // latency histograms match in shape: same request population
        assert_eq!(
            loop_reg.hist_mut("latency_s").count(),
            engine_reg.hist_mut("latency_s").count(),
            "{label}: latency sample count"
        );
        // every offload the client sent was batched by the daemon's loop
        assert_eq!(summary.shard.requests, 24, "{label}: daemon batched count");
    }
}

#[test]
fn reference_remote_client_requires_wall_clock_and_one_server() {
    let err = reference_builder(Scheme::Agile)
        .connect("127.0.0.1:1")
        .clock(ClockKind::Sim)
        .build()
        .unwrap()
        .run()
        .unwrap_err();
    assert!(err.to_string().contains("requires the wall clock"), "{err:#}");
    let err = reference_builder(Scheme::Agile)
        .connect("127.0.0.1:1")
        .fleet(|f| f.servers = 2)
        .clock(ClockKind::Sim) // servers>1 needs sim; the remote check must still win
        .build()
        .unwrap()
        .run()
        .unwrap_err();
    let msg = format!("{err:#}");
    assert!(
        msg.contains("requires the wall clock") || msg.contains("conflict"),
        "{msg}"
    );
}

#[test]
fn reference_daemon_handshake_rejects_a_mismatched_client() {
    // client built with bits=2 against a daemon serving bits=4: the
    // handshake must fail with the daemon's reason, before any request
    let (addr, daemon) = spawn_loopback_daemon();
    let err = reference_builder(Scheme::Agile)
        .bits(2)
        .fleet(|f| f.devices = 1)
        .fleet(|f| f.requests = 2)
        .connect(&addr)
        .build()
        .unwrap()
        .run()
        .unwrap_err();
    let msg = format!("{err:#}");
    assert!(msg.contains("rejected the handshake"), "{msg}");
    assert!(msg.contains("2 bits"), "{msg}");
    agilenn::serve::send_shutdown(&addr).unwrap();
    daemon.join().unwrap();
}

#[test]
fn wall_pacing_anchor_holds_on_both_transports() {
    // Periodic pacing is per device: 4 requests per device at 100 Hz puts
    // the last scheduled arrival at 30 ms, so a wall-clock run can never
    // finish earlier — whether offloads ride the in-process channel
    // transport or a real loopback socket.
    let schedule_end = 3.0 / 100.0;
    let paced =
        |b: ServeBuilder| b.fleet(|f| f.devices = 2).fleet(|f| f.requests = 8).arrival(Arrival::Periodic { hz: 100.0 });
    let in_process =
        paced(reference_builder(Scheme::Agile)).build().unwrap().run().unwrap();
    assert!(
        in_process.wall_s >= schedule_end,
        "channel transport finished before the schedule: {} < {schedule_end}",
        in_process.wall_s
    );
    let (addr, daemon) = spawn_loopback_daemon();
    let remote = paced(reference_builder(Scheme::Agile))
        .connect(&addr)
        .build()
        .unwrap()
        .run()
        .unwrap();
    assert!(
        remote.wall_s >= schedule_end,
        "tcp transport finished before the schedule: {} < {schedule_end}",
        remote.wall_s
    );
    agilenn::serve::send_shutdown(&addr).unwrap();
    daemon.join().unwrap();
}

#[test]
fn dropping_the_stream_shuts_down_both_transports_cleanly() {
    // a consumer that walks away mid-run must not wedge either fabric:
    // device loops notice the closed outcome channel and stop producing,
    // worker threads unwind, and (for the socket path) the daemon survives
    // the abandoned connections and still honors a later shutdown
    let slow = |b: ServeBuilder| b.fleet(|f| f.devices = 2).fleet(|f| f.requests = 200).rate_hz(50.0);
    let mut stream =
        slow(reference_builder(Scheme::Agile)).build().unwrap().stream().unwrap();
    assert!(stream.by_ref().take(2).count() == 2);
    drop(stream); // joins nothing; threads exit on the dead channel

    let (addr, daemon) = spawn_loopback_daemon();
    let mut stream = slow(reference_builder(Scheme::Agile))
        .connect(&addr)
        .build()
        .unwrap()
        .stream()
        .unwrap();
    assert!(stream.by_ref().take(2).count() == 2);
    drop(stream);
    agilenn::serve::send_shutdown(&addr).unwrap();
    daemon.join().unwrap();
}

#[test]
fn reference_radio_contention_grows_with_offered_rate_never_shrinks() {
    let run = |hz: f64| -> PipelineReport {
        reference_builder(Scheme::Agile)
            .fleet(|f| f.devices = 1)
            .fleet(|f| f.requests = 48)
            .batch(|b| b.max_batch = 1)
            .arrival(Arrival::Periodic { hz })
            .clock(ClockKind::Sim)
            .build()
            .unwrap()
            .run()
            .unwrap()
    };
    let relaxed = run(5.0); // 200 ms gaps: the radio always drains
    let saturated = run(2000.0); // 0.5 ms gaps: far beyond link capacity
    assert_eq!(relaxed.mean_radio_wait_s, 0.0, "uncontended link must not queue");
    assert!(saturated.mean_radio_wait_s > 0.0, "saturated link must surface radio queueing");
    assert!(
        saturated.p99_net_s >= relaxed.p99_net_s,
        "higher rate cannot lower simulated link latency: {} vs {}",
        saturated.p99_net_s,
        relaxed.p99_net_s
    );
}

// ---------------------------------------------------------------------------
// scheme × clock × delivery matrix smoke
// ---------------------------------------------------------------------------

#[test]
fn reference_scheme_clock_delivery_matrix_smoke() {
    // 5 schemes × {wall, sim} × {ARQ, anytime}: every combination serves
    // its requests and produces predictions on the reference backend,
    // under a mildly lossy link so both transports do real work
    let n = 10;
    for scheme in Scheme::all() {
        for clock in [ClockKind::Wall, ClockKind::Sim] {
            for delivery in
                [DeliveryPolicy::Arq, DeliveryPolicy::Anytime { deadline_s: 0.004 }]
            {
                let label =
                    format!("{} / {} / {}", scheme.name(), clock.name(), delivery.name());
                let rep = reference_builder(scheme)
                    .fleet(|f| f.devices = 2)
                    .fleet(|f| f.requests = n)
                    .rate_hz(500.0)
                    .clock(clock)
                    .net(|n| n.loss = GilbertElliott::uniform(0.1))
                    .net(|n| n.delivery = delivery)
                    .net(|n| n.seed = 1)
                    .build()
                    .unwrap()
                    .run()
                    .unwrap();
                assert_eq!(rep.requests, n, "{label}");
                assert!(rep.accuracy > 0.0, "{label}: accuracy {}", rep.accuracy);
                if scheme == Scheme::Mcunet {
                    assert_eq!(rep.batches, 0, "{label}");
                    assert_eq!(rep.packets_sent, 0, "{label}");
                }
            }
        }
    }
}

// ---------------------------------------------------------------------------
// the event engine: bitwise equivalence with the threaded sim fabric
// ---------------------------------------------------------------------------

/// Assert that two sim-clock reports agree on every deterministic field —
/// bitwise — and on the summation-order-sensitive means up to reordering.
/// This is the fleet engine's contract with the threaded fabric.
fn assert_sim_reports_equivalent(a: &PipelineReport, b: &PipelineReport, label: &str) {
    assert_eq!(a.requests, b.requests, "{label}: requests");
    assert_eq!(a.clock, b.clock, "{label}: clock");
    assert_eq!(a.accuracy, b.accuracy, "{label}: accuracy");
    assert_eq!(a.wall_s, b.wall_s, "{label}: virtual makespan must be bit-equal");
    assert_eq!(a.p95_latency_s, b.p95_latency_s, "{label}: p95 latency");
    assert_eq!(a.p99_latency_s, b.p99_latency_s, "{label}: p99 latency");
    assert_eq!(a.batches, b.batches, "{label}: batch count");
    assert_eq!(a.mean_batch_size, b.mean_batch_size, "{label}: mean batch size");
    assert_eq!(a.packets_sent, b.packets_sent, "{label}: packets sent");
    assert_eq!(a.packets_lost, b.packets_lost, "{label}: packets lost");
    assert_eq!(a.retransmit_rounds, b.retransmit_rounds, "{label}: retransmit rounds");
    assert_eq!(a.incomplete_frames, b.incomplete_frames, "{label}: incomplete frames");
    assert_eq!(a.delivered_feature_rate, b.delivered_feature_rate, "{label}: delivered rate");
    assert_eq!(a.p99_net_s, b.p99_net_s, "{label}: p99 net");
    assert_eq!(a.shards.len(), b.shards.len(), "{label}: shard count");
    for (x, y) in a.shards.iter().zip(&b.shards) {
        assert_eq!(x.requests, y.requests, "{label}: shard {} load", x.server);
        assert_eq!(x.batches, y.batches, "{label}: shard {} batches", x.server);
        // both paths record queue waits in dispatch order, so even the
        // mean is bit-equal, not just the sort-based quantile
        assert_eq!(x.mean_queue_s, y.mean_queue_s, "{label}: shard {} queue mean", x.server);
        assert_eq!(x.p95_queue_s, y.p95_queue_s, "{label}: shard {} queue p95", x.server);
    }
    // outcome-stream accumulation order differs between the paths (thread
    // scheduling vs event order), so f64 sums agree only up to reordering
    assert!(
        (a.mean_latency_s - b.mean_latency_s).abs() < 1e-9,
        "{label}: mean latency {} vs {}",
        a.mean_latency_s,
        b.mean_latency_s
    );
    assert!((a.mean_net_s - b.mean_net_s).abs() < 1e-9, "{label}: mean net");
    assert!((a.mean_radio_wait_s - b.mean_radio_wait_s).abs() < 1e-12, "{label}: radio wait");
    let gp_scale = a.goodput_bps.abs().max(1.0);
    assert!(
        (a.goodput_bps - b.goodput_bps).abs() / gp_scale < 1e-9,
        "{label}: goodput {} vs {}",
        a.goodput_bps,
        b.goodput_bps
    );
}

#[test]
fn reference_event_engine_matches_threaded_sim_across_the_scheme_delivery_matrix() {
    // 5 schemes x {ARQ, anytime} under a lossy link: the engine must
    // reproduce the threaded sim fabric bit for bit on every deterministic
    // report field.
    //
    // The configs are deliberately NON-saturating (periodic 50 Hz, 20 ms
    // gaps far above the per-request latency): every offload send is then
    // anchored on `arrival + compute + uplink`, which the per-device
    // periodic phases keep tie-free, so the threaded fabric's event order
    // is fully determined and the comparison is exact. Saturated fleets
    // can produce bit-equal send instants (same-batch devices resume
    // together), where the threaded fabric's order is OS-scheduling
    // dependent — the engine resolves those races deterministically, so
    // demanding bit-equality there would be demanding equality with a
    // race (see the serve::engine module docs).
    for scheme in Scheme::all() {
        for delivery in [DeliveryPolicy::Arq, DeliveryPolicy::Anytime { deadline_s: 0.004 }] {
            let run = |engine: SimEngine| -> PipelineReport {
                reference_builder(scheme)
                    .fleet(|f| f.devices = 3)
                    .fleet(|f| f.requests = 30)
                    .arrival(Arrival::Periodic { hz: 50.0 })
                    .clock(ClockKind::Sim)
                    .sim_engine(engine)
                    .net(|n| n.loss = GilbertElliott::uniform(0.1))
                    .net(|n| n.delivery = delivery.clone())
                    .net(|n| n.seed = 1)
                    .build()
                    .unwrap()
                    .run()
                    .unwrap()
            };
            let label = format!("{} / {}", scheme.name(), delivery.name());
            let threads = run(SimEngine::Threads);
            let engine = run(SimEngine::Event);
            assert_sim_reports_equivalent(&engine, &threads, &label);
        }
    }
}

#[test]
fn reference_event_engine_matches_threaded_sim_with_golden_style_lossy_anytime() {
    // the golden snapshot's ingredients — 8 devices, max_batch 4, bursty
    // 20% loss, anytime delivery, multi-rider batches — at a
    // non-saturating periodic rate, so the threaded fabric is tie-free
    // and the comparison is exact (the golden config itself runs 200 Hz
    // Poisson into saturation, where threaded ordering is OS-racy; its
    // reproducibility is pinned by the engine-run snapshot instead)
    let run = |engine: SimEngine| -> PipelineReport {
        reference_builder(Scheme::Agile)
            .fleet(|f| f.devices = 8)
            .fleet(|f| f.requests = 128)
            .arrival(Arrival::Periodic { hz: 25.0 })
            .batch(|b| b.max_batch = 4)
            .net(|n| n.loss = GilbertElliott::bursty(0.2, 4.0))
            .net(|n| n.delivery = DeliveryPolicy::Anytime { deadline_s: 0.02 })
            .net(|n| n.packet_payload = Some(128))
            .net(|n| n.seed = 5)
            .clock(ClockKind::Sim)
            .sim_engine(engine)
            .build()
            .unwrap()
            .run()
            .unwrap()
    };
    let threads = run(SimEngine::Threads);
    let engine = run(SimEngine::Event);
    assert_sim_reports_equivalent(&engine, &threads, "golden-style lossy anytime");
    assert!(engine.packets_lost > 0, "20% bursty loss must drop something");
    assert!(engine.mean_batch_size > 1.5, "periodic lockstep must form multi-rider batches");
}

#[test]
fn reference_event_engine_is_bit_reproducible_including_means() {
    // the engine emits outcomes in deterministic event order, so even the
    // f64 sums — nondeterministic on the threaded paths — reproduce
    // bitwise, and so does the serialized report
    let run = || -> PipelineReport {
        reference_builder(Scheme::Agile)
            .fleet(|f| f.devices = 16)
            .fleet(|f| f.requests = 512)
            .rate_hz(150.0)
            .arrival_seed(3)
            .fleet(|f| f.servers = 4)
            .fleet(|f| f.placement = Placement::LeastLoaded)
            .clock(ClockKind::Sim)
            .net(|n| n.loss = GilbertElliott::bursty(0.2, 4.0))
            .net(|n| n.seed = 5)
            .build()
            .unwrap()
            .run()
            .unwrap()
    };
    let (a, b) = (run(), run());
    assert_eq!(a.mean_latency_s, b.mean_latency_s, "engine means must be bit-stable");
    assert_eq!(a.mean_net_s, b.mean_net_s);
    assert_eq!(a.goodput_bps, b.goodput_bps);
    assert_eq!(a.mean_radio_wait_s, b.mean_radio_wait_s);
    assert_eq!(a.to_ordered_json(), b.to_ordered_json(), "serialized reports must match");
}

// ---------------------------------------------------------------------------
// multi-server sharding + placement policies
// ---------------------------------------------------------------------------

fn fleet_builder(devices: usize, requests: usize) -> ServeBuilder {
    reference_builder(Scheme::Agile)
        .fleet(|f| f.devices = devices)
        .fleet(|f| f.requests = requests)
        .rate_hz(200.0)
        .arrival_seed(7)
        .clock(ClockKind::Sim)
}

#[test]
fn reference_multi_server_run_reports_per_shard_accounting() {
    let rep = fleet_builder(8, 160)
        .fleet(|f| f.servers = 4)
        .fleet(|f| f.placement = Placement::LeastLoaded)
        .build()
        .unwrap()
        .run()
        .unwrap();
    assert_eq!(rep.requests, 160);
    assert_eq!(rep.shards.len(), 4, "one report entry per server");
    // agile offloads every request: the shard loads partition the run
    let shard_total: usize = rep.shards.iter().map(|s| s.requests).sum();
    assert_eq!(shard_total, 160);
    let batch_total: usize = rep.shards.iter().map(|s| s.batches).sum();
    assert_eq!(batch_total, rep.batches);
    for s in &rep.shards {
        assert!(s.requests > 0, "server {} never saw a request", s.server);
        assert!(s.mean_batch_size >= 1.0, "server {}", s.server);
    }
}

#[test]
fn reference_least_loaded_balances_better_than_static_on_a_skewed_fleet() {
    // 6 devices onto 4 servers: static pins two shards to double load
    // (devices 0&4 -> 0, 1&5 -> 1) — exactly 2.0x imbalance. Least-loaded
    // must spread the same offered load near-evenly: the rotating
    // tie-break makes flat-queue decisions round-robin (a lowest-index
    // tie-break measurably does WORSE than static here — closed-loop
    // queues drain to empty between bursts and every tie would pile onto
    // server 0).
    let run = |placement: Placement| {
        fleet_builder(6, 240).fleet(|f| f.servers = 4).fleet(|f| f.placement = placement).build().unwrap().run().unwrap()
    };
    let imbalance = |rep: &PipelineReport| {
        let max = rep.shards.iter().map(|s| s.requests).max().unwrap();
        let min = rep.shards.iter().map(|s| s.requests).min().unwrap().max(1);
        max as f64 / min as f64
    };
    let least = run(Placement::LeastLoaded);
    let statics = run(Placement::Static);
    assert_eq!(least.requests, 240);
    // static's shard loads follow the device pinning exactly: 2x load on
    // the shards owning two devices
    assert!(
        (imbalance(&statics) - 2.0).abs() < 1e-9,
        "static imbalance {:.2} should be exactly 2.0 here",
        imbalance(&statics)
    );
    let mean = 240.0 / 4.0;
    for s in &least.shards {
        assert!(s.requests > 0, "least-loaded left server {} idle", s.server);
        assert!(
            (s.requests as f64 - mean).abs() <= mean * 0.35,
            "server {} load {} strays from the {} mean",
            s.server,
            s.requests,
            mean
        );
    }
    assert!(
        imbalance(&least) < 1.5 && imbalance(&least) < imbalance(&statics),
        "least-loaded ({:.2}) must balance tighter than static ({:.2})",
        imbalance(&least),
        imbalance(&statics)
    );
}

#[test]
fn reference_round_robin_spreads_offloads_within_one_request() {
    let rep = fleet_builder(5, 200)
        .fleet(|f| f.servers = 4)
        .fleet(|f| f.placement = Placement::RoundRobin)
        .build()
        .unwrap()
        .run()
        .unwrap();
    let loads: Vec<usize> = rep.shards.iter().map(|s| s.requests).collect();
    assert_eq!(loads.iter().sum::<usize>(), 200);
    let (max, min) = (*loads.iter().max().unwrap(), *loads.iter().min().unwrap());
    assert!(max - min <= 1, "round-robin shard loads {loads:?} must differ by at most 1");
}

#[test]
fn reference_static_placement_is_deterministic_under_device_renumbering() {
    // static shard load is a pure function of the request->device->shard
    // arithmetic: recompute it from the schedule and demand equality, and
    // demand two runs agree bitwise
    let (devices, requests, servers) = (6usize, 120usize, 4usize);
    let run = || {
        fleet_builder(devices, requests)
            .fleet(|f| f.servers = servers)
            .fleet(|f| f.placement = Placement::Static)
            .build()
            .unwrap()
            .run()
            .unwrap()
    };
    let (a, b) = (run(), run());
    assert_eq!(a.to_ordered_json(), b.to_ordered_json());
    let mut expected = vec![0usize; servers];
    for i in 0..requests {
        expected[(i % devices) % servers] += 1; // request -> device -> shard
    }
    let got: Vec<usize> = a.shards.iter().map(|s| s.requests).collect();
    assert_eq!(got, expected, "static shard loads must follow device % servers exactly");
}

#[test]
fn reference_multi_server_requires_the_event_engine() {
    // wall clock: no engine -> reject
    let err = fleet_builder(4, 16)
        .clock(ClockKind::Wall)
        .fleet(|f| f.servers = 2)
        .build()
        .unwrap()
        .run()
        .unwrap_err();
    assert!(err.to_string().contains("event engine"), "{err}");
    // sim clock forced onto the threaded fabric: also reject
    let err = fleet_builder(4, 16)
        .fleet(|f| f.servers = 2)
        .sim_engine(SimEngine::Threads)
        .build()
        .unwrap()
        .run()
        .unwrap_err();
    assert!(err.to_string().contains("event engine"), "{err}");
}

#[test]
fn reference_fleet_scale_smoke() {
    // a deliberately chunky engine run (50k requests x 2k devices x 4
    // servers) — the 1M x 10k sweep lives in CI's `bench --figure fleet`
    // and the perfgate; this keeps `cargo test` honest about scale without
    // slowing it down
    let rep = fleet_builder(2_000, 50_000)
        .fleet(|f| f.servers = 4)
        .fleet(|f| f.placement = Placement::LeastLoaded)
        .build()
        .unwrap()
        .run()
        .unwrap();
    assert_eq!(rep.requests, 50_000);
    assert_eq!(rep.shards.len(), 4);
    assert!(rep.accuracy > 0.9, "accuracy {}", rep.accuracy);
    assert!(rep.wall_s > 0.0 && rep.throughput_rps > 0.0);
}

// ---------------------------------------------------------------------------
// the autoscale control plane: determinism, bit-identity, drain-before-retire
// ---------------------------------------------------------------------------

/// Controller knobs tightened for test scale: act on a single breached
/// tick (sustain 1), 1 s cooldown, and a 10 ms queue-p95 SLO with the
/// scale-in watermark at 60% of it.
fn autoscale_cfg() -> AutoscaleConfig {
    let mut cfg = AutoscaleConfig::new(1, 4);
    cfg.slo_queue_p95_s = 10e-3;
    cfg.low_watermark = 0.6;
    cfg.window_s = 2.0;
    cfg.interval_s = 0.5;
    cfg.cooldown_s = 1.0;
    cfg.sustain = 1;
    cfg
}

/// A diurnal fleet sized so the controller must act both ways: the
/// raised cosine starts at a near-idle trough (0.2 Hz/device — queue
/// waits pinned to the 0.5 ms batch deadline, far under the scale-in
/// watermark), so the 2-server initial fleet drains to 1; the priced
/// service model (1 ms + 3 ms/sample, ~320 req/s per server at batch 8)
/// then saturates that lone server well before the 60 Hz/device peak,
/// and the sustained queue-p95 breach forces a scale-out.
fn autoscaled_builder() -> ServeBuilder {
    reference_builder(Scheme::Agile)
        .fleet(|f| f.devices = 32)
        .fleet(|f| f.requests = 6400)
        .arrival(Arrival::Diurnal { period_s: 16.0, base_hz: 0.2, peak_hz: 60.0, seed: 7 })
        .clock(ClockKind::Sim)
        .fleet(|f| f.servers = 2)
        .fleet(|f| f.placement = Placement::WeightedLeastLoaded)
        .batch(|b| b.deadline_us = 500)
        .fleet(|f| {
            f.service.base_s = 1e-3;
            f.service.per_sample_s = 3e-3;
        })
        .fleet(|f| f.autoscale = Some(autoscale_cfg()))
        .fleet(|f| f.slo_p99_s = 200e-3)
}

#[test]
fn reference_autoscaler_scales_both_ways_and_is_bitwise_deterministic() {
    let run = || {
        let sink = Arc::new(RecordingSink::new());
        let rep =
            autoscaled_builder().trace_sink(sink.clone()).build().unwrap().run().unwrap();
        (rep, sink.take())
    };
    let (a, evs_a) = run();
    assert_eq!(a.requests, 6400);
    assert!(a.scale_ins >= 1, "the opening trough must drain the fleet ({} scale-ins)", a.scale_ins);
    assert!(a.scale_outs >= 1, "the diurnal peak must grow the fleet ({} scale-outs)", a.scale_outs);
    assert!(a.server_seconds > 0.0 && a.slo_attainment > 0.0);
    // the whole report reproduces byte for byte across runs...
    let (b, evs_b) = run();
    assert_eq!(a.to_ordered_json(), b.to_ordered_json(), "autoscaled report must be bitwise stable");
    // ...and so does the applied scale-action sequence: every
    // ScaleOut/ScaleIn trace instant's (kind, shard, time, fleet-size)
    // tuple, times compared bitwise
    let scales = |evs: &[TraceEvent]| -> Vec<(EventKind, u64, u64, u64)> {
        evs.iter()
            .filter(|e| matches!(e.kind, EventKind::ScaleOut | EventKind::ScaleIn))
            .map(|e| (e.kind, e.id, e.t_s.to_bits(), e.value.to_bits()))
            .collect()
    };
    let (sa, sb) = (scales(&evs_a), scales(&evs_b));
    assert_eq!(sa, sb, "scale-event sequences must be bitwise identical");
    assert_eq!(sa.len(), a.scale_outs + a.scale_ins, "every applied action leaves one instant");
}

#[test]
fn reference_controller_off_runs_the_fixed_fleet_code_path_bit_identically() {
    // no autoscale, no service model: the engine executes the
    // pre-autoscale fixed-fleet path — reproducible byte for byte, with
    // the new report fields pinned to their fixed-fleet values
    let run = |p: Placement| {
        fleet_builder(8, 400).fleet(|f| f.servers = 2).fleet(|f| f.placement = p).build().unwrap().run().unwrap()
    };
    let (a, b) = (run(Placement::LeastLoaded), run(Placement::LeastLoaded));
    assert_eq!(a.to_ordered_json(), b.to_ordered_json());
    assert_eq!((a.scale_outs, a.scale_ins), (0, 0), "controller off must apply no scale actions");
    // fixed fleets bill every shard for the whole makespan: the
    // integrated accounting degenerates to the old shards x wall formula
    assert_eq!(a.server_seconds.to_bits(), (a.shards.len() as f64 * a.wall_s).to_bits());
    for s in &a.shards {
        assert_eq!(s.active_s.to_bits(), a.wall_s.to_bits(), "shard {} active lifetime", s.server);
    }
    // weighted placement with the default uniform capacities is the same
    // decision procedure as least-loaded: the whole report matches
    let w = run(Placement::WeightedLeastLoaded);
    assert_eq!(w.to_ordered_json(), a.to_ordered_json(), "uniform weighted == least-loaded");
}

#[test]
fn reference_autoscaler_drains_before_retiring() {
    // a retiring shard stops accepting placements but serves out its
    // queue and in-service batches: every request completes (a dropped
    // reply would fail the run with a RemoteFailure surfaced from
    // `finish`), and the retired shard's active lifetime — and with it
    // the integrated fleet cost — stays strictly below the makespan
    let rep = autoscaled_builder().build().unwrap().run().unwrap();
    assert_eq!(rep.requests, 6400, "drain-before-retire must not drop requests");
    let offloaded: usize = rep.shards.iter().map(|s| s.requests).sum();
    assert_eq!(offloaded, 6400, "every offload lands on exactly one shard");
    assert!(rep.scale_ins >= 1);
    assert!(
        rep.shards.iter().any(|s| s.active_s < rep.wall_s),
        "a retired shard must bill less than the makespan"
    );
    assert!(
        rep.server_seconds < rep.shards.len() as f64 * rep.wall_s,
        "integrated cost {} must undercut the old shards x makespan formula {}",
        rep.server_seconds,
        rep.shards.len() as f64 * rep.wall_s
    );
    for s in &rep.shards {
        assert!(s.active_s >= 0.0 && s.active_s <= rep.wall_s + 1e-9, "shard {} active_s", s.server);
    }
}

// ---------------------------------------------------------------------------
// the autotuner: fronts, resume, determinism, typed config errors
// ---------------------------------------------------------------------------

/// A small 8-point grid (2 deadlines x 2 bit widths x 2 server counts).
fn tune_space() -> SearchSpace {
    SearchSpace {
        batch_deadline_us: vec![500, 2000],
        packet_payload: vec![None],
        bits: vec![2, 4],
        delivery: vec![DeliveryPolicy::Arq],
        placement: vec![Placement::Static],
        servers: vec![1, 2],
        autoscale: vec![false],
        policy: vec![false],
    }
}

/// A cheap evaluation world: 4 devices x 64 requests on the sim clock.
fn tune_eval() -> EvalSpec {
    EvalSpec {
        artifacts_dir: Some(NO_ARTIFACTS.into()),
        devices: 4,
        requests: 64,
        rate_hz: 200.0,
        ..EvalSpec::default()
    }
}

fn tune_cfg(state: Option<PathBuf>, stop_after: Option<usize>) -> TuneConfig {
    TuneConfig {
        space: tune_space(),
        eval: tune_eval(),
        strategy: StrategyKind::Exhaustive,
        state,
        out: None,
        stop_after,
        trace: Tracer::off(),
    }
}

#[test]
fn reference_tune_exhaustive_emits_a_front() {
    let out = tune::run(&tune_cfg(None, None), |_| {}).unwrap();
    assert!(out.completed);
    assert_eq!(out.evaluated, 8);
    assert_eq!(out.cached, 0);
    assert_eq!(out.infeasible, 0);
    assert!(!out.front.is_empty() && out.front.len() <= 8, "front size {}", out.front.len());
    // front members are mutually non-dominated
    for (i, (_, a)) in out.front.iter().enumerate() {
        for (_, b) in out.front.iter().skip(i + 1) {
            assert!(!ranking::dominates(a, b) && !ranking::dominates(b, a));
        }
    }
    // the artifact is valid ordered JSON naming every front point
    let v = agilenn::json::Value::parse(&out.front_json).unwrap();
    assert_eq!(v.str_at("schema").unwrap(), "agilenn-tune-v1");
    assert_eq!(v.usize_at("evaluations").unwrap(), 8);
    assert_eq!(v.get("front").unwrap().as_arr().unwrap().len(), out.front.len());
}

#[test]
fn reference_tune_resume_round_trip_bitwise() {
    let dir = std::env::temp_dir().join(format!("agilenn_tune_it_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let state = dir.join("resume.state");
    let _ = std::fs::remove_file(&state);
    let _ = std::fs::remove_file(tune::state::log_path(&state));
    // interrupt after 3 evaluations
    let first = tune::run(&tune_cfg(Some(state.clone()), Some(3)), |_| {}).unwrap();
    assert!(!first.completed);
    assert_eq!(first.evaluated, 3);
    // resume with the same state: the 3 logged points replay from cache
    let resumed = tune::run(&tune_cfg(Some(state.clone()), None), |_| {}).unwrap();
    assert!(resumed.completed);
    assert_eq!(resumed.cached, 3);
    assert_eq!(resumed.evaluated, 5);
    // ...and the artifact is byte-identical to an uninterrupted run
    let oneshot = tune::run(&tune_cfg(None, None), |_| {}).unwrap();
    assert_eq!(resumed.front_json, oneshot.front_json, "resume must be bitwise transparent");
    let _ = std::fs::remove_file(&state);
    let _ = std::fs::remove_file(tune::state::log_path(&state));
}

#[test]
fn reference_tune_genetic_same_seed_is_deterministic() {
    let mk = || TuneConfig {
        strategy: StrategyKind::Genetic { seed: 9, population: 4, budget: 6 },
        ..tune_cfg(None, None)
    };
    let a = tune::run(&mk(), |_| {}).unwrap();
    let b = tune::run(&mk(), |_| {}).unwrap();
    assert!(a.completed);
    assert!(a.evaluated > 0 && !a.front.is_empty());
    assert_eq!(a.evaluated, b.evaluated);
    assert_eq!(a.front_json, b.front_json, "same seed must reproduce the artifact bitwise");
}

#[test]
fn reference_tune_skips_infeasible_points_gracefully() {
    // servers > 1 on the threaded sim fabric is a typed ConfigError: the
    // tuner records those points infeasible and keeps searching
    let sink = Arc::new(RecordingSink::new());
    let cfg = TuneConfig {
        eval: EvalSpec { sim_engine: SimEngine::Threads, ..tune_eval() },
        trace: Tracer::new(sink.clone()),
        ..tune_cfg(None, None)
    };
    let out = tune::run(&cfg, |_| {}).unwrap();
    assert!(out.completed);
    assert_eq!(out.evaluated, 8);
    assert_eq!(out.infeasible, 4, "the four servers=2 points are infeasible");
    assert!(!out.front.is_empty());
    assert!(out.front.iter().all(|(p, _)| p.servers == 1), "front must hold feasible points only");
    // the tuner lane mirrors the outcome split: infeasible points are
    // instants, evaluated points are unit-duration spans in visit order
    let evs = sink.take();
    assert_eq!(evs.len(), 8);
    assert!(evs.iter().all(|e| e.lane == Lane::Tuner));
    assert_eq!(evs.iter().filter(|e| e.kind == EventKind::TuneInfeasible).count(), 4);
    assert_eq!(evs.iter().filter(|e| e.kind == EventKind::TuneEval).count(), 4);
    for (i, e) in evs.iter().enumerate() {
        assert_eq!(e.id, i as u64, "tuner events carry the visit sequence");
        assert_eq!(e.t_s, i as f64, "the tuner lane runs in visit-index virtual time");
    }
}

#[test]
fn reference_config_error_is_typed_and_downcastable() {
    // unsupported batch size: caught at stream() time with a typed error
    let err = reference_builder(Scheme::Agile)
        .fleet(|f| f.devices = 2)
        .fleet(|f| f.requests = 8)
        .batch(|b| b.max_batch = 3)
        .build()
        .unwrap()
        .stream()
        .unwrap_err();
    match err.downcast_ref::<ConfigError>() {
        Some(ConfigError::UnsupportedMaxBatch { max_batch: 3 }) => {}
        other => panic!("expected UnsupportedMaxBatch, got {other:?}"),
    }
    // multi-server off the event engine: same typed surface
    let err =
        fleet_builder(4, 16).clock(ClockKind::Wall).fleet(|f| f.servers = 2).build().unwrap().run().unwrap_err();
    match err.downcast_ref::<ConfigError>() {
        Some(ConfigError::MultiServerNeedsEventEngine { servers: 2, .. }) => {}
        other => panic!("expected MultiServerNeedsEventEngine, got {other:?}"),
    }
}

#[test]
fn pipeline_report_ordered_json_is_stable_and_parseable() {
    let rep = fleet_builder(4, 40).fleet(|f| f.servers = 2).build().unwrap().run().unwrap();
    let text = rep.to_ordered_json();
    assert_eq!(text, rep.to_ordered_json(), "same report must serialize byte-identically");
    let v = agilenn::json::Value::parse(&text).expect("report JSON must parse");
    assert_eq!(v.usize_at("requests").unwrap(), 40);
    assert_eq!(v.str_at("clock").unwrap(), "sim");
    assert_eq!(v.get("shards").unwrap().as_arr().unwrap().len(), 2);
    assert_eq!(v.f64_at("accuracy").unwrap().to_bits(), rep.accuracy.to_bits());
}

// ---------------------------------------------------------------------------
// per-request adaptive policy (serve::policy)
// ---------------------------------------------------------------------------

/// A lossy saturating fleet with the default adaptive ladder on: 30%
/// bursty loss inflates the EWMA retransmit rounds past `rounds_high`,
/// so the ladder actually walks during the run.
fn adaptive_builder() -> ServeBuilder {
    reference_builder(Scheme::Agile)
        .fleet(|f| {
            f.devices = 8;
            f.requests = 256;
        })
        .rate_hz(200.0)
        .arrival_seed(11)
        .batch(|b| b.max_batch = 4)
        .net(|n| {
            n.loss = GilbertElliott::bursty(0.3, 4.0);
            n.packet_payload = Some(64);
            n.seed = 5;
        })
        .clock(ClockKind::Sim)
        .policy(PolicyConfig::default())
}

#[test]
fn adaptive_policy_decisions_are_bit_reproducible() {
    // the policy is pure state-machine arithmetic over the seeded channel's
    // NetStats, so two consecutive runs — decisions, switches, widths, and
    // every report field downstream of them — must agree bitwise
    let run = || adaptive_builder().build().unwrap().run().unwrap();
    let (a, b) = (run(), run());
    assert_eq!(
        a.to_ordered_json(),
        b.to_ordered_json(),
        "adaptive runs must be bit-stable across consecutive runs"
    );
    let pol = a.policy.as_ref().expect("a policy-on run must carry a policy report");
    assert!(pol.switches >= 1, "30% bursty loss must force at least one ladder move");
    assert_eq!(pol.local_only, 0, "local fallback is off in this config");
    assert!(
        pol.mean_bits >= 1.0 && pol.mean_bits <= 4.0,
        "mean width must stay inside the [1,2,4] ladder, got {}",
        pol.mean_bits
    );
    let offloaded: usize = pol.widths.iter().map(|&(_, n)| n).sum();
    assert!(offloaded > 0 && offloaded <= a.requests, "width histogram covers offloaded uplinks");
    assert!(pol.widths.iter().all(|&(w, _)| [1, 2, 4].contains(&w)), "only ladder widths appear");
}

#[test]
fn policy_off_report_has_no_policy_fields() {
    // the policy-off ≡ PR-9 contract, surface half: without `.policy(..)`
    // the report must not grow a policy section and the serialized JSON
    // must be byte-identical to the pre-policy schema (the committed
    // golden snapshot in `golden_sim_pipeline_report_is_bit_stable` pins
    // the field *values* across commits; this pins the field *set*)
    let rep = golden_run();
    assert!(rep.policy.is_none(), "policy-off runs must not synthesize a policy report");
    let text = rep.to_ordered_json();
    assert!(
        !text.contains("policy"),
        "policy-off JSON must carry no policy keys, got: {text}"
    );
    // and a policy-on run does grow exactly those fields
    let on = adaptive_builder().build().unwrap().run().unwrap().to_ordered_json();
    for key in ["policy_switches", "policy_local_only", "policy_mean_bits", "policy_widths"] {
        assert!(on.contains(key), "policy-on JSON must carry {key}");
    }
}

#[test]
fn policy_misconfiguration_is_a_typed_error() {
    // a ladder width with no exported codebook — the synthetic world
    // ships 1..=6 — is caught against the manifest before serving starts
    let err = reference_builder(Scheme::Agile)
        .fleet(|f| {
            f.devices = 2;
            f.requests = 8;
        })
        .clock(ClockKind::Sim)
        .policy(PolicyConfig { widths: vec![2, 7], ..PolicyConfig::default() })
        .build()
        .unwrap()
        .stream()
        .unwrap_err();
    match err.downcast_ref::<ConfigError>() {
        Some(ConfigError::UnsupportedBits { bits: 7, scheme: Scheme::Agile, available }) => {
            assert_eq!(available, &[1, 2, 3, 4, 5, 6]);
        }
        other => panic!("expected UnsupportedBits, got {other:?}"),
    }
    // a scheme that never quantizes features has no width actuator
    let err = reference_builder(Scheme::Mcunet)
        .fleet(|f| {
            f.devices = 2;
            f.requests = 8;
        })
        .clock(ClockKind::Sim)
        .policy(PolicyConfig::default())
        .build()
        .unwrap()
        .stream()
        .unwrap_err();
    assert!(
        matches!(err.downcast_ref::<ConfigError>(), Some(ConfigError::InvalidPolicy { .. })),
        "expected InvalidPolicy for a non-quantizing scheme, got {err:?}"
    );
    // the local-only rung needs an on-device head: DeepCOD has none
    let err = reference_builder(Scheme::Deepcod)
        .fleet(|f| {
            f.devices = 2;
            f.requests = 8;
        })
        .clock(ClockKind::Sim)
        .policy(PolicyConfig { local_fallback: true, ..PolicyConfig::default() })
        .build()
        .unwrap()
        .stream()
        .unwrap_err();
    match err.downcast_ref::<ConfigError>() {
        Some(ConfigError::InvalidPolicy { reason }) => {
            assert!(reason.contains("local_fallback"), "reason names the knob: {reason}")
        }
        other => panic!("expected InvalidPolicy, got {other:?}"),
    }
}

// ---------------------------------------------------------------------------
// golden snapshot: PR 3's reproducibility contract
// ---------------------------------------------------------------------------

fn golden_builder() -> ServeBuilder {
    reference_builder(Scheme::Agile)
        .fleet(|f| f.devices = 8)
        .fleet(|f| f.requests = 256)
        .rate_hz(200.0)
        .arrival_seed(11)
        .batch(|b| b.max_batch = 4)
        .net(|n| n.loss = GilbertElliott::bursty(0.2, 4.0))
        .net(|n| n.delivery = DeliveryPolicy::Anytime { deadline_s: 0.02 })
        .net(|n| n.packet_payload = Some(128))
        .net(|n| n.seed = 5)
        .clock(ClockKind::Sim)
}

fn golden_run() -> PipelineReport {
    golden_builder().build().unwrap().run().unwrap()
}

/// Canonical text form of the report's deterministic fields. Floats use
/// Rust's shortest-roundtrip `{:?}` formatting, so string equality is
/// bit equality.
fn golden_snapshot(r: &PipelineReport) -> String {
    format!(
        "requests={}\nclock={}\naccuracy={:?}\nwall_s={:?}\np95_latency_s={:?}\n\
         batches={}\npackets_sent={}\npackets_lost={}\nretransmit_rounds={}\n\
         incomplete_frames={}\ndelivered_feature_rate={:?}\np99_net_s={:?}\n",
        r.requests,
        r.clock.name(),
        r.accuracy,
        r.wall_s,
        r.p95_latency_s,
        r.batches,
        r.packets_sent,
        r.packets_lost,
        r.retransmit_rounds,
        r.incomplete_frames,
        r.delivered_feature_rate,
        r.p99_net_s,
    )
}

#[test]
fn golden_sim_pipeline_report_is_bit_stable() {
    // (1) two consecutive runs must agree bitwise on every deterministic
    // field — the sim clock's reproducibility contract from PR 3
    let (a, b) = (golden_run(), golden_run());
    let (sa, sb) = (golden_snapshot(&a), golden_snapshot(&b));
    assert_eq!(sa, sb, "sim-clock report must be bit-stable across consecutive runs");

    // (2) and they must match the committed snapshot, guarding the
    // contract across commits. Bless (create/update) the file with
    // AGILENN_BLESS=1, then commit it.
    let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .join("tests/golden/serve_sim_reference.snap");
    if path.exists() && std::env::var_os("AGILENN_BLESS").is_none() {
        let want = std::fs::read_to_string(&path).unwrap();
        assert_eq!(
            sa,
            want,
            "deterministic PipelineReport fields drifted from the committed golden \
             snapshot at {}; if the change is intentional, re-bless with \
             `AGILENN_BLESS=1 cargo test golden` and commit the file",
            path.display()
        );
    } else {
        std::fs::create_dir_all(path.parent().unwrap()).unwrap();
        std::fs::write(&path, &sa).unwrap();
        eprintln!("blessed golden snapshot at {} — commit this file", path.display());
    }
}

// ---------------------------------------------------------------------------
// observability: request-lifecycle traces + the unified metrics registry
// ---------------------------------------------------------------------------

/// The golden serving config with a recording sink attached; returns the
/// report and the recorded events (in recording order).
fn golden_traced_run() -> (PipelineReport, Vec<TraceEvent>) {
    let sink = Arc::new(RecordingSink::new());
    let rep = golden_builder().trace_sink(sink.clone()).build().unwrap().run().unwrap();
    (rep, sink.take())
}

#[test]
fn golden_sim_trace_is_bit_stable() {
    // (1) the exported Chrome trace of the golden sim run must be
    // byte-identical across consecutive runs — tracing inherits the sim
    // clock's reproducibility contract
    let (_, ea) = golden_traced_run();
    let (_, eb) = golden_traced_run();
    let (ja, jb) = (chrome_trace_json(&ea), chrome_trace_json(&eb));
    assert_eq!(ja, jb, "sim-clock trace must be bit-stable across consecutive runs");

    // (2) and match the committed snapshot, like the report golden above.
    // Bless (create/update) with AGILENN_BLESS=1, then commit.
    let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .join("tests/golden/serve_sim_reference_trace.json");
    if path.exists() && std::env::var_os("AGILENN_BLESS").is_none() {
        let want = std::fs::read_to_string(&path).unwrap();
        assert_eq!(
            ja,
            want.trim_end_matches('\n'),
            "golden sim trace drifted from the committed snapshot at {}; if the \
             change is intentional, re-bless with `AGILENN_BLESS=1 cargo test golden` \
             and commit the file",
            path.display()
        );
    } else {
        std::fs::create_dir_all(path.parent().unwrap()).unwrap();
        std::fs::write(&path, format!("{ja}\n")).unwrap();
        eprintln!("blessed golden trace at {} — commit this file", path.display());
    }
}

#[test]
fn reference_noop_sink_leaves_the_report_bit_identical() {
    // attaching the disabled sink exercises the full emission path but
    // must not perturb a single reported bit
    let plain = golden_run();
    let noop =
        golden_builder().trace_sink(Arc::new(NoopSink)).build().unwrap().run().unwrap();
    assert_eq!(plain.to_ordered_json(), noop.to_ordered_json());
}

#[test]
fn reference_golden_trace_spans_are_well_formed() {
    let (rep, evs) = golden_traced_run();
    assert!(!evs.is_empty());
    // every request produces exactly one Arrival and one Done instant
    let count = |k: EventKind| evs.iter().filter(|e| e.kind == k).count();
    assert_eq!(count(EventKind::Arrival), rep.requests);
    assert_eq!(count(EventKind::Done), rep.requests);
    assert_eq!(count(EventKind::BatchDispatch), rep.batches);

    for e in &evs {
        assert!(e.t_s.is_finite() && e.t_s >= 0.0, "bad timestamp in {e:?}");
        assert!(e.dur_s.is_finite() && e.dur_s >= 0.0, "negative duration in {e:?}");
        if !e.kind.is_span() {
            assert_eq!(e.dur_s, 0.0, "instant kinds must have zero duration: {e:?}");
        }
    }

    // per-request lifecycle nesting on each device lane: arrival opens the
    // encode span, and each priced phase begins no earlier than the
    // previous one ended (radio wait and server-side queueing are the only
    // gaps the pricing model allows)
    // `end_s()` recomputes t0 + (t1 - t0), so butt-joined phases may differ
    // from the next phase's stored start by a rounding ulp
    const EPS: f64 = 1e-9;
    let arrivals: Vec<&TraceEvent> =
        evs.iter().filter(|e| e.kind == EventKind::Arrival).collect();
    for a in arrivals {
        let chain: Vec<&TraceEvent> =
            evs.iter().filter(|e| e.lane == a.lane && e.id == a.id).collect();
        let find = |k: EventKind| chain.iter().find(|e| e.kind == k);
        let encode = find(EventKind::Encode).expect("every request encodes");
        assert_eq!(encode.t_s, a.t_s, "encode starts at arrival");
        let done = find(EventKind::Done).expect("every request finishes");
        assert!(done.t_s >= encode.end_s() - EPS);
        if let Some(up) = find(EventKind::Uplink) {
            assert!(up.t_s >= encode.end_s() - EPS, "uplink after encode in {chain:?}");
            if let Some(w) = find(EventKind::RadioWait) {
                assert!((w.t_s - encode.end_s()).abs() < EPS);
                assert!(
                    (w.end_s() - up.t_s).abs() < EPS,
                    "radio wait fills the encode→uplink gap in {chain:?}"
                );
            }
            if let Some(remote) = find(EventKind::Remote) {
                assert!(remote.t_s >= up.end_s() - EPS, "remote after uplink in {chain:?}");
                let down = find(EventKind::Downlink).expect("remote implies downlink");
                assert!((down.t_s - remote.end_s()).abs() < EPS);
                assert!(
                    (done.t_s - down.end_s()).abs() < EPS,
                    "done stamps the downlink end in {chain:?}"
                );
            }
        }
    }

    // the half-duplex radio serializes each device's uplinks
    let lanes: std::collections::BTreeSet<Lane> = evs.iter().map(|e| e.lane).collect();
    for lane in &lanes {
        let mut ups: Vec<&TraceEvent> =
            evs.iter().filter(|e| e.lane == *lane && e.kind == EventKind::Uplink).collect();
        ups.sort_by(|a, b| a.t_s.total_cmp(&b.t_s));
        for w in ups.windows(2) {
            assert!(w[1].t_s >= w[0].end_s() - EPS, "overlapping uplinks on {lane:?}");
        }
        // a device serves its requests serially: Done instants are
        // monotone in recording order
        let dones: Vec<&TraceEvent> =
            evs.iter().filter(|e| e.lane == *lane && e.kind == EventKind::Done).collect();
        for w in dones.windows(2) {
            assert!(w[1].t_s >= w[0].t_s, "device Done times must be monotone");
        }
        // batch dispatches on a server lane carry an increasing sequence
        let fires: Vec<&TraceEvent> = evs
            .iter()
            .filter(|e| e.lane == *lane && e.kind == EventKind::BatchDispatch)
            .collect();
        for w in fires.windows(2) {
            assert!(w[1].id == w[0].id + 1 && w[1].t_s >= w[0].t_s);
        }
    }
}

#[test]
fn reference_report_fields_match_the_metrics_registry() {
    // finish_full exposes the registry the report is a view over: every
    // shared field must match bitwise
    let mut stream = golden_builder().build().unwrap().stream().unwrap();
    for _ in stream.by_ref() {}
    let (rep, mut m) = stream.finish_full().unwrap();
    assert_eq!(rep.requests, m.counter("requests_total") as usize);
    assert_eq!(rep.batches, m.counter("batches") as usize);
    assert_eq!(rep.packets_sent, m.counter("packets_sent"));
    assert_eq!(rep.packets_lost, m.counter("packets_lost"));
    assert_eq!(rep.retransmit_rounds, m.counter("retransmit_rounds"));
    assert_eq!(rep.incomplete_frames, m.counter("incomplete_frames") as usize);
    let acc = m.counter("requests_correct") as f64 / m.counter("requests_total") as f64;
    assert_eq!(rep.accuracy.to_bits(), acc.to_bits());
    assert_eq!(rep.mean_latency_s.to_bits(), m.hist_mut("latency_s").mean_s().to_bits());
    assert_eq!(rep.p95_latency_s.to_bits(), m.hist_mut("latency_s").p95().to_bits());
    assert_eq!(rep.p99_net_s.to_bits(), m.hist_mut("net_s").p99().to_bits());
    // ...and the registry serializes deterministically, with the per-phase
    // histograms the breakdown figure reads
    let json = m.to_ordered_json();
    assert_eq!(json, m.to_ordered_json());
    let v = agilenn::json::Value::parse(&json).unwrap();
    assert_eq!(v.str_at("schema").unwrap(), "agilenn-metrics-v1");
    for name in ["latency_s", "net_s", "phase_network_s", "phase_remote_s"] {
        let h = v.get("histograms").unwrap().get(name).unwrap();
        assert!(h.f64_at("p95_s").is_ok(), "histogram {name} must export quantiles");
    }
}

#[test]
fn reference_threaded_sim_fabric_emits_traces_too() {
    // the legacy thread-per-device fabric routes through the same sink
    let sink = Arc::new(RecordingSink::new());
    let rep = reference_builder(Scheme::Agile)
        .fleet(|f| f.devices = 4)
        .fleet(|f| f.requests = 64)
        .rate_hz(200.0)
        .clock(ClockKind::Sim)
        .sim_engine(SimEngine::Threads)
        .trace_sink(sink.clone())
        .build()
        .unwrap()
        .run()
        .unwrap();
    let evs = sink.take();
    assert_eq!(evs.iter().filter(|e| e.kind == EventKind::Done).count(), rep.requests);
    assert!(evs.iter().any(|e| e.kind == EventKind::ServerQueue));
    let v = agilenn::json::Value::parse(&chrome_trace_json(&evs)).unwrap();
    assert!(!v.as_arr().unwrap().is_empty());
}

#[test]
fn reference_tune_trace_replays_cached_points_as_instants() {
    let dir = std::env::temp_dir().join(format!("agilenn_tune_trace_it_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let state = dir.join("resume.state");
    let _ = std::fs::remove_file(&state);
    let _ = std::fs::remove_file(tune::state::log_path(&state));
    // interrupt after 3 evaluations: 3 TuneEval spans, nothing cached
    let sink = Arc::new(RecordingSink::new());
    let cfg = TuneConfig {
        trace: Tracer::new(sink.clone()),
        ..tune_cfg(Some(state.clone()), Some(3))
    };
    assert_eq!(tune::run(&cfg, |_| {}).unwrap().evaluated, 3);
    let evs = sink.take();
    assert_eq!(evs.iter().filter(|e| e.kind == EventKind::TuneEval).count(), 3);
    assert!(evs.iter().all(|e| e.kind != EventKind::TuneCached));
    // the resumed run replays those 3 points as TuneCached instants — no
    // re-evaluation spans — then finishes the remaining 5 fresh
    let cfg = TuneConfig {
        trace: Tracer::new(sink.clone()),
        ..tune_cfg(Some(state.clone()), None)
    };
    let out = tune::run(&cfg, |_| {}).unwrap();
    assert!(out.completed);
    let evs = sink.take();
    assert_eq!(evs.iter().filter(|e| e.kind == EventKind::TuneCached).count(), 3);
    assert_eq!(evs.iter().filter(|e| e.kind == EventKind::TuneEval).count(), 5);
    // visit-index virtual time covers cached and fresh visits alike, so a
    // resumed trace lines up with an uninterrupted one
    for (i, e) in evs.iter().enumerate() {
        assert_eq!(e.id, i as u64);
    }
    let _ = std::fs::remove_file(&state);
    let _ = std::fs::remove_file(tune::state::log_path(&state));
}

// ---------------------------------------------------------------------------
// PJRT twin: the same suite over real AOT artifacts (feature `pjrt` +
// `make artifacts`; skips gracefully when no artifacts are present)
// ---------------------------------------------------------------------------

#[cfg(feature = "pjrt")]
mod pjrt_artifact_tests {
    use super::*;
    use agilenn::config::{default_artifacts_dir, Manifest};
    use agilenn::runtime::Engine;

    struct Ctx {
        engine: Engine,
        cfg: RunConfig,
        meta: Meta,
        testset: TestSet,
    }

    fn ctx() -> Option<Ctx> {
        let dir = default_artifacts_dir();
        let manifest = Manifest::load(&dir).ok()?;
        let ds = manifest.datasets.first()?.clone();
        let cfg = RunConfig::new(dir, &ds, Scheme::Agile);
        let meta = Meta::load(&cfg.dataset_dir()).ok()?;
        let testset = TestSet::load(&cfg.dataset_dir().join("test.bin")).ok()?;
        Some(Ctx { engine: Engine::cpu().ok()?, cfg, meta, testset })
    }

    macro_rules! require_artifacts {
        () => {
            match ctx() {
                Some(c) => c,
                None => {
                    eprintln!("skipping: no artifacts (run `make artifacts`)");
                    return;
                }
            }
        };
    }

    #[test]
    fn device_artifact_shapes_match_meta() {
        let c = require_artifacts!();
        let backend = agilenn::runtime::PjrtBackend::cpu().unwrap();
        let mut device = DeviceRuntime::new(&backend, &c.cfg, &c.meta).unwrap();
        let out = device.process(&c.testset.image(0).unwrap()).unwrap();
        assert_eq!(out.local_logits.len(), c.meta.num_classes);
        let [h, w, ch] = c.meta.feature;
        assert_eq!(out.remote_shape, vec![1, h, w, ch - c.meta.k]);
        assert_eq!(out.frame.count, c.meta.tx_elements(Scheme::Agile));
        assert!(out.timings.total_s() > 0.0);
    }

    #[test]
    fn remote_batch_padding_is_row_consistent() {
        let c = require_artifacts!();
        let backend = agilenn::runtime::PjrtBackend::cpu().unwrap();
        let mut device = DeviceRuntime::new(&backend, &c.cfg, &c.meta).unwrap();
        let mut server = RemoteServer::new(&backend, &c.cfg, &c.meta).unwrap();
        let feats: Vec<_> = (0..5)
            .map(|i| {
                let out = device.process(&c.testset.image(i).unwrap()).unwrap();
                server.decode(&out.frame).unwrap()
            })
            .collect();
        let single: Vec<Vec<f32>> = feats
            .iter()
            .map(|f| server.infer(std::slice::from_ref(f)).unwrap().remove(0))
            .collect();
        let batched = server.infer(&feats).unwrap(); // pads 5 -> 8
        for (s, b) in single.iter().zip(&batched) {
            for (x, y) in s.iter().zip(b) {
                assert!((x - y).abs() < 1e-4, "batch padding changed logits: {x} vs {y}");
            }
        }
    }

    #[test]
    fn rust_accuracy_tracks_python_measurement() {
        let c = require_artifacts!();
        let backend = agilenn::runtime::PjrtBackend::cpu().unwrap();
        let mut runner = AgileRunner::new(&backend, &c.cfg, &c.meta).unwrap();
        let n = 128.min(c.testset.len());
        let mut correct = 0;
        for i in 0..n {
            let out = SchemeRunner::process(
                &mut runner,
                &c.testset.image(i).unwrap(),
                c.testset.labels[i],
            )
            .unwrap();
            correct += out.correct as usize;
        }
        let acc = correct as f64 / n as f64;
        let py = c.meta.accuracy.agile_quant4;
        assert!((acc - py).abs() < 0.08, "rust accuracy {acc:.3} vs python {py:.3} (n={n})");
    }

    #[test]
    fn all_schemes_produce_outcomes() {
        let c = require_artifacts!();
        let backend = agilenn::runtime::PjrtBackend::cpu().unwrap();
        let img = c.testset.image(0).unwrap();
        for scheme in Scheme::all() {
            let cfg = RunConfig::new(c.cfg.artifacts_dir.clone(), &c.cfg.dataset, scheme);
            let mut runner = make_runner(&backend, &cfg, &c.meta).unwrap();
            let out = runner.process(&img, c.testset.labels[0]).unwrap();
            assert!(out.predicted < c.meta.num_classes, "{}", scheme.name());
            assert!(out.breakdown.total_s() > 0.0, "{}", scheme.name());
            assert!(out.energy.total_j() > 0.0, "{}", scheme.name());
            let mem = runner.memory_report();
            assert!(mem.fits(), "{} must fit the STM32F746 budgets", scheme.name());
            match scheme {
                Scheme::Mcunet => assert_eq!(out.tx_bytes, 0),
                Scheme::Agile | Scheme::Deepcod | Scheme::EdgeOnly => assert!(out.tx_bytes > 0),
                Scheme::Spinn => {} // tx depends on the early exit
            }
        }
    }

    #[test]
    fn agile_features_compress_harder_than_deepcod_code() {
        let c = require_artifacts!();
        let backend = agilenn::runtime::PjrtBackend::cpu().unwrap();
        let mut agile = make_runner(&backend, &c.cfg, &c.meta).unwrap();
        let cfg_d = RunConfig::new(c.cfg.artifacts_dir.clone(), &c.cfg.dataset, Scheme::Deepcod);
        let mut deepcod = make_runner(&backend, &cfg_d, &c.meta).unwrap();
        let n = 32.min(c.testset.len());
        let (mut a_bytes, mut d_bytes) = (0usize, 0usize);
        for i in 0..n {
            let img = c.testset.image(i).unwrap();
            a_bytes += agile.process(&img, c.testset.labels[i]).unwrap().tx_bytes;
            d_bytes += deepcod.process(&img, c.testset.labels[i]).unwrap().tx_bytes;
        }
        let a_per_elem = a_bytes as f64 / c.meta.tx_elements(Scheme::Agile) as f64;
        let d_per_elem = d_bytes as f64 / c.meta.tx_elements(Scheme::Deepcod) as f64;
        assert!(
            a_per_elem < d_per_elem * 1.05,
            "agile {a_per_elem:.4} B/elem must not exceed deepcod {d_per_elem:.4} B/elem (n={n})"
        );
    }

    #[test]
    fn alpha_override_changes_behavior_at_extremes() {
        let c = require_artifacts!();
        let backend = agilenn::runtime::PjrtBackend::cpu().unwrap();
        let mut runner = AgileRunner::new(&backend, &c.cfg, &c.meta).unwrap();
        let n = 48.min(c.testset.len());
        let mut acc_at = |alpha: f64, runner: &mut AgileRunner| {
            runner.set_alpha(alpha).unwrap();
            let mut correct = 0;
            for i in 0..n {
                let out = SchemeRunner::process(
                    runner,
                    &c.testset.image(i).unwrap(),
                    c.testset.labels[i],
                )
                .unwrap();
                correct += out.correct as usize;
            }
            correct as f64 / n as f64
        };
        let trained = acc_at(c.meta.alpha, &mut runner);
        let local_only = acc_at(1.0, &mut runner);
        assert!(trained >= local_only - 1e-9, "trained {trained} < local-only {local_only}");
    }

    #[test]
    fn offline_fallback_runs_without_network() {
        let c = require_artifacts!();
        let backend = agilenn::runtime::PjrtBackend::cpu().unwrap();
        let mut runner = AgileRunner::new(&backend, &c.cfg, &c.meta).unwrap();
        let out =
            runner.process_offline(&c.testset.image(0).unwrap(), c.testset.labels[0]).unwrap();
        assert_eq!(out.tx_bytes, 0);
        assert_eq!(out.breakdown.network_s, 0.0);
        assert!(out.exited_early);
    }

    #[test]
    fn pipeline_serves_all_requests() {
        let c = require_artifacts!();
        let rep = Service::from_parts(
            c.cfg.clone(),
            c.meta.clone(),
            Arc::new(TestSet::load(&c.cfg.dataset_dir().join("test.bin")).unwrap()),
            3,
            24,
            Arrival::Poisson { hz: 200.0, seed: 7 },
        )
        .unwrap()
        .run()
        .unwrap();
        assert_eq!(rep.requests, 24);
        assert!(rep.throughput_rps > 0.0);
        assert!(rep.mean_batch_size >= 1.0);
        assert!(rep.batches >= 3);
    }

    #[test]
    fn serve_runs_all_five_schemes_through_the_batched_pipeline() {
        let c = require_artifacts!();
        let n = 12;
        for scheme in Scheme::all() {
            let rep = ServeBuilder::new(&c.cfg.dataset)
                .artifacts_dir(c.cfg.artifacts_dir.clone())
                .scheme(scheme)
                .fleet(|f| f.devices = 2)
                .fleet(|f| f.requests = n)
                .rate_hz(500.0)
                .build()
                .unwrap()
                .run()
                .unwrap();
            assert_eq!(rep.requests, n, "{}", scheme.name());
            assert!(rep.throughput_rps > 0.0, "{}", scheme.name());
            assert!(rep.accuracy > 0.0, "{}", scheme.name());
            match scheme {
                Scheme::Mcunet => assert_eq!(rep.batches, 0, "{}", scheme.name()),
                Scheme::Agile | Scheme::Deepcod | Scheme::EdgeOnly => {
                    assert!(rep.batches > 0, "{}", scheme.name())
                }
                Scheme::Spinn => {}
            }
        }
    }

    #[test]
    fn streaming_outcomes_are_observable_per_request() {
        let c = require_artifacts!();
        let n = 16;
        let mut stream = ServeBuilder::new(&c.cfg.dataset)
            .artifacts_dir(c.cfg.artifacts_dir.clone())
            .scheme(Scheme::Agile)
            .fleet(|f| f.devices = 2)
            .fleet(|f| f.requests = n)
            .build()
            .unwrap()
            .stream()
            .unwrap();
        let mut ids = std::collections::HashSet::new();
        let mut count = 0;
        for out in stream.by_ref() {
            assert!(ids.insert(out.id), "duplicate outcome id {}", out.id);
            assert!(out.device < 2);
            assert!(out.wall_s > 0.0);
            assert!(out.outcome.tx_bytes > 0);
            assert!(out.outcome.predicted < c.meta.num_classes);
            count += 1;
        }
        assert_eq!(count, n);
        let rep = stream.finish().unwrap();
        assert_eq!(rep.requests, n);
    }

    #[test]
    fn engine_caches_executables() {
        let c = require_artifacts!();
        let dir = c.cfg.dataset_dir();
        let before = c.engine.cached_count();
        let _a = c.engine.load_artifact(&dir, "agile_device_b1").unwrap();
        let _b = c.engine.load_artifact(&dir, "agile_device_b1").unwrap();
        assert_eq!(c.engine.cached_count(), before + 1, "second load must hit the cache");
    }

    #[test]
    fn engine_concurrent_first_loads_compile_once() {
        // regression for the duplicate-compilation race: N threads race
        // the first load of one artifact; the single-flight cache must
        // end up with exactly one entry (and everyone gets the same exe)
        let c = require_artifacts!();
        let engine = Arc::new(c.engine);
        let dir = c.cfg.dataset_dir();
        let before = engine.cached_count();
        let handles: Vec<_> = (0..4)
            .map(|_| {
                let engine = engine.clone();
                let dir = dir.clone();
                std::thread::spawn(move || {
                    engine.load_artifact(&dir, "agile_remote_b2").unwrap();
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(engine.cached_count(), before + 1);
    }

    #[test]
    fn lossy_serve_is_seed_deterministic() {
        let c = require_artifacts!();
        let run = || {
            ServeBuilder::new(&c.cfg.dataset)
                .artifacts_dir(c.cfg.artifacts_dir.clone())
                .scheme(Scheme::Agile)
                .fleet(|f| f.devices = 2)
                .fleet(|f| f.requests = 24)
                .batch(|b| b.max_batch = 1) // b1 executable everywhere: bitwise-stable logits
                .net(|n| n.loss = GilbertElliott::bursty(0.3, 4.0))
                .net(|n| n.delivery = DeliveryPolicy::Anytime { deadline_s: 0.01 })
                .net(|n| n.packet_payload = Some(64))
                .net(|n| n.seed = 9)
                .build()
                .unwrap()
                .run()
                .unwrap()
        };
        let (a, b) = (run(), run());
        assert_eq!(a.accuracy, b.accuracy);
        assert_eq!(a.packets_sent, b.packets_sent);
        assert_eq!(a.packets_lost, b.packets_lost);
        assert_eq!(a.retransmit_rounds, b.retransmit_rounds);
        assert_eq!(a.incomplete_frames, b.incomplete_frames);
        assert_eq!(a.delivered_feature_rate, b.delivered_feature_rate);
        assert!((a.mean_net_s - b.mean_net_s).abs() < 1e-9);
        assert!(a.packets_lost > 0, "30% loss over 24 uplinks must drop something");
    }

    #[test]
    fn anytime_transport_decodes_partial_frames_under_heavy_loss() {
        let c = require_artifacts!();
        let rep = ServeBuilder::new(&c.cfg.dataset)
            .artifacts_dir(c.cfg.artifacts_dir.clone())
            .scheme(Scheme::Agile)
            .fleet(|f| f.devices = 1)
            .fleet(|f| f.requests = 16)
            .batch(|b| b.max_batch = 1)
            .arrival(Arrival::Periodic { hz: 30.0 })
            .clock(ClockKind::Sim)
            .net(|n| n.loss = GilbertElliott::uniform(0.5))
            .net(|n| n.delivery = DeliveryPolicy::Anytime { deadline_s: 0.004 })
            .net(|n| n.packet_payload = Some(64))
            .net(|n| n.seed = 3)
            .build()
            .unwrap()
            .run()
            .unwrap();
        assert_eq!(rep.requests, 16);
        assert!(rep.incomplete_frames > 0, "50% loss must leave partial frames");
        assert!(rep.delivered_feature_rate < 1.0);
        assert!(rep.delivered_feature_rate > 0.0);
        assert!(rep.accuracy > 0.0);
        assert!(rep.p99_net_s <= 0.004 + 0.01, "p99 net {}", rep.p99_net_s);
    }

    #[test]
    fn zero_loss_channel_reproduces_the_ideal_link_numbers() {
        let c = require_artifacts!();
        use agilenn::simulator::NetworkSim;
        let mut stream = ServeBuilder::new(&c.cfg.dataset)
            .artifacts_dir(c.cfg.artifacts_dir.clone())
            .scheme(Scheme::Agile)
            .fleet(|f| f.devices = 1)
            .fleet(|f| f.requests = 8)
            .batch(|b| b.max_batch = 1)
            .arrival(Arrival::Periodic { hz: 30.0 })
            .clock(ClockKind::Sim)
            .build()
            .unwrap()
            .stream()
            .unwrap();
        let net = NetworkSim::new(c.cfg.network.clone());
        let reply = agilenn::serve::reply_bytes(c.meta.num_classes);
        for out in stream.by_ref() {
            let expect = net.transfer_s(out.outcome.tx_bytes) + net.transfer_s(reply);
            let got = out.outcome.breakdown.network_s;
            assert!((got - expect).abs() < 1e-9, "network_s {got} != closed form {expect}");
            assert!(out.outcome.net.complete);
            assert_eq!(out.outcome.net.packets_lost, 0);
            assert_eq!(out.outcome.net.radio_wait_s, 0.0);
        }
        stream.finish().unwrap();
    }

    #[test]
    fn sim_clock_serve_is_bit_reproducible_and_never_sleeps() {
        let c = require_artifacts!();
        let run = || -> PipelineReport {
            ServeBuilder::new(&c.cfg.dataset)
                .artifacts_dir(c.cfg.artifacts_dir.clone())
                .scheme(Scheme::Agile)
                .fleet(|f| f.devices = 8)
                .fleet(|f| f.requests = 512)
                .rate_hz(200.0)
                .arrival_seed(11)
                .batch(|b| b.max_batch = 1)
                .net(|n| n.loss = GilbertElliott::bursty(0.2, 4.0))
                .net(|n| n.seed = 5)
                .clock(ClockKind::Sim)
                .build()
                .unwrap()
                .run()
                .unwrap()
        };
        let (a, b) = (run(), run());
        assert_eq!(a.clock, ClockKind::Sim);
        assert_eq!(a.requests, 512);
        assert_eq!(a.accuracy, b.accuracy);
        assert_eq!(a.p95_latency_s, b.p95_latency_s);
        assert_eq!(a.p99_net_s, b.p99_net_s);
        assert_eq!(a.packets_sent, b.packets_sent);
        assert_eq!(a.packets_lost, b.packets_lost);
        assert_eq!(a.retransmit_rounds, b.retransmit_rounds);
        assert_eq!(a.incomplete_frames, b.incomplete_frames);
        assert_eq!(a.delivered_feature_rate, b.delivered_feature_rate);
        assert!((a.wall_s - b.wall_s).abs() < 1e-9);
        assert!((a.mean_latency_s - b.mean_latency_s).abs() < 1e-9);
        assert!(a.wall_s > 0.1, "virtual time {} must reflect the pacing", a.wall_s);
        assert!(a.packets_lost > 0, "20% bursty loss must drop something");
    }

    #[test]
    fn wall_and_sim_clocks_agree_on_the_seed_deterministic_fields() {
        let c = require_artifacts!();
        let run = |clock: ClockKind| -> PipelineReport {
            ServeBuilder::new(&c.cfg.dataset)
                .artifacts_dir(c.cfg.artifacts_dir.clone())
                .scheme(Scheme::Agile)
                .fleet(|f| f.devices = 2)
                .fleet(|f| f.requests = 16)
                .rate_hz(120.0)
                .arrival_seed(3)
                .batch(|b| b.max_batch = 1)
                .net(|n| n.loss = GilbertElliott::uniform(0.1))
                .net(|n| n.seed = 4)
                .clock(clock)
                .build()
                .unwrap()
                .run()
                .unwrap()
        };
        let (w, s) = (run(ClockKind::Wall), run(ClockKind::Sim));
        assert_eq!(w.clock, ClockKind::Wall);
        assert_eq!(s.clock, ClockKind::Sim);
        assert_eq!(w.accuracy, s.accuracy);
        assert_eq!(w.packets_sent, s.packets_sent);
        assert_eq!(w.packets_lost, s.packets_lost);
        assert_eq!(w.retransmit_rounds, s.retransmit_rounds);
        assert_eq!(w.incomplete_frames, s.incomplete_frames);
        assert_eq!(w.delivered_feature_rate, s.delivered_feature_rate);
        assert_eq!(w.p99_net_s, s.p99_net_s);
        assert!((w.mean_net_s - s.mean_net_s).abs() < 1e-9);
        assert!((w.mean_radio_wait_s - s.mean_radio_wait_s).abs() < 1e-12);
    }

    #[test]
    fn radio_contention_grows_with_offered_rate_never_shrinks() {
        let c = require_artifacts!();
        let run = |hz: f64| -> PipelineReport {
            ServeBuilder::new(&c.cfg.dataset)
                .artifacts_dir(c.cfg.artifacts_dir.clone())
                .scheme(Scheme::Agile)
                .fleet(|f| f.devices = 1)
                .fleet(|f| f.requests = 48)
                .batch(|b| b.max_batch = 1)
                .arrival(Arrival::Periodic { hz })
                .clock(ClockKind::Sim)
                .build()
                .unwrap()
                .run()
                .unwrap()
        };
        let relaxed = run(5.0);
        let saturated = run(2000.0);
        assert_eq!(relaxed.mean_radio_wait_s, 0.0, "uncontended link must not queue");
        assert!(saturated.mean_radio_wait_s > 0.0, "saturated link must queue");
        assert!(
            saturated.p99_net_s >= relaxed.p99_net_s,
            "higher rate cannot lower simulated link latency: {} vs {}",
            saturated.p99_net_s,
            relaxed.p99_net_s
        );
    }
}
