//! Integration tests over the real AOT artifacts + PJRT runtime.
//!
//! These need `make artifacts` (or AGILENN_ARTIFACTS pointing at a built
//! tree). When no artifacts are present they skip, so `cargo test` stays
//! green on a fresh checkout.

use agilenn::baselines::{make_runner, AgileRunner, SchemeRunner};
use agilenn::config::{default_artifacts_dir, Manifest, Meta, RunConfig, Scheme};
use agilenn::coordinator::{DeviceRuntime, RemoteServer};
use agilenn::runtime::Engine;
use agilenn::serve::{ClockKind, PipelineReport, ServeBuilder, Service};
use agilenn::workload::{Arrival, TestSet};
use std::sync::Arc;

struct Ctx {
    engine: Engine,
    cfg: RunConfig,
    meta: Meta,
    testset: TestSet,
}

fn ctx() -> Option<Ctx> {
    let dir = default_artifacts_dir();
    let manifest = Manifest::load(&dir).ok()?;
    let ds = manifest.datasets.first()?.clone();
    let cfg = RunConfig::new(dir, &ds, Scheme::Agile);
    let meta = Meta::load(&cfg.dataset_dir()).ok()?;
    let testset = TestSet::load(&cfg.dataset_dir().join("test.bin")).ok()?;
    Some(Ctx { engine: Engine::cpu().ok()?, cfg, meta, testset })
}

macro_rules! require_artifacts {
    () => {
        match ctx() {
            Some(c) => c,
            None => {
                eprintln!("skipping: no artifacts (run `make artifacts`)");
                return;
            }
        }
    };
}

#[test]
fn device_artifact_shapes_match_meta() {
    let c = require_artifacts!();
    let mut device = DeviceRuntime::new(&c.engine, &c.cfg, &c.meta).unwrap();
    let out = device.process(&c.testset.image(0).unwrap()).unwrap();
    assert_eq!(out.local_logits.len(), c.meta.num_classes);
    let [h, w, ch] = c.meta.feature;
    assert_eq!(out.remote_shape, vec![1, h, w, ch - c.meta.k]);
    assert_eq!(out.frame.count, c.meta.tx_elements(Scheme::Agile));
    assert!(out.timings.total_s() > 0.0);
}

#[test]
fn remote_batch_padding_is_row_consistent() {
    // the same features must yield (near-)identical logits whether run at
    // batch size 1 or padded into a batch of 8
    let c = require_artifacts!();
    let mut device = DeviceRuntime::new(&c.engine, &c.cfg, &c.meta).unwrap();
    let mut server = RemoteServer::new(&c.engine, &c.cfg, &c.meta).unwrap();
    let feats: Vec<_> = (0..5)
        .map(|i| {
            let out = device.process(&c.testset.image(i).unwrap()).unwrap();
            server.decode(&out.frame).unwrap()
        })
        .collect();
    let single: Vec<Vec<f32>> = feats
        .iter()
        .map(|f| server.infer(std::slice::from_ref(f)).unwrap().remove(0))
        .collect();
    let batched = server.infer(&feats).unwrap(); // pads 5 -> 8
    for (s, b) in single.iter().zip(&batched) {
        for (x, y) in s.iter().zip(b) {
            assert!((x - y).abs() < 1e-4, "batch padding changed logits: {x} vs {y}");
        }
    }
}

#[test]
fn rust_accuracy_tracks_python_measurement() {
    // end-to-end accuracy through the Rust serving path (quantized tx)
    // should be within a few points of python's agile_quant4 measurement.
    let c = require_artifacts!();
    let mut runner = AgileRunner::new(&c.engine, &c.cfg, &c.meta).unwrap();
    let n = 128.min(c.testset.len());
    let mut correct = 0;
    for i in 0..n {
        let out =
            SchemeRunner::process(&mut runner, &c.testset.image(i).unwrap(), c.testset.labels[i])
                .unwrap();
        correct += out.correct as usize;
    }
    let acc = correct as f64 / n as f64;
    let py = c.meta.accuracy.agile_quant4;
    assert!(
        (acc - py).abs() < 0.08,
        "rust accuracy {acc:.3} vs python {py:.3} diverged (n={n})"
    );
}

#[test]
fn all_schemes_produce_outcomes() {
    let c = require_artifacts!();
    let img = c.testset.image(0).unwrap();
    for scheme in Scheme::all() {
        let cfg = RunConfig::new(c.cfg.artifacts_dir.clone(), &c.cfg.dataset, scheme);
        let mut runner = make_runner(&c.engine, &cfg, &c.meta).unwrap();
        let out = runner.process(&img, c.testset.labels[0]).unwrap();
        assert!(out.predicted < c.meta.num_classes, "{}", scheme.name());
        assert!(out.breakdown.total_s() > 0.0, "{}", scheme.name());
        assert!(out.energy.total_j() > 0.0, "{}", scheme.name());
        let mem = runner.memory_report();
        assert!(mem.fits(), "{} must fit the STM32F746 budgets", scheme.name());
        match scheme {
            Scheme::Mcunet => assert_eq!(out.tx_bytes, 0),
            Scheme::Agile | Scheme::Deepcod | Scheme::EdgeOnly => assert!(out.tx_bytes > 0),
            Scheme::Spinn => {} // tx depends on the early exit
        }
    }
}

#[test]
fn agile_features_compress_harder_than_deepcod_code() {
    // Table 2's mechanism: skewness manipulation leaves the transmitted
    // features sparser than DeepCOD's learned code, so AgileNN spends fewer
    // wire bits *per transmitted element* at the same quantizer width.
    // (Absolute byte totals are reported by `bench --figure t2`.)
    let c = require_artifacts!();
    let mut agile = make_runner(&c.engine, &c.cfg, &c.meta).unwrap();
    let cfg_d = RunConfig::new(c.cfg.artifacts_dir.clone(), &c.cfg.dataset, Scheme::Deepcod);
    let mut deepcod = make_runner(&c.engine, &cfg_d, &c.meta).unwrap();
    let n = 32.min(c.testset.len());
    let (mut a_bytes, mut d_bytes) = (0usize, 0usize);
    for i in 0..n {
        let img = c.testset.image(i).unwrap();
        a_bytes += agile.process(&img, c.testset.labels[i]).unwrap().tx_bytes;
        d_bytes += deepcod.process(&img, c.testset.labels[i]).unwrap().tx_bytes;
    }
    let a_per_elem = a_bytes as f64 / c.meta.tx_elements(Scheme::Agile) as f64;
    let d_per_elem = d_bytes as f64 / c.meta.tx_elements(Scheme::Deepcod) as f64;
    assert!(
        a_per_elem < d_per_elem * 1.05,
        "agile {a_per_elem:.4} B/elem must not exceed deepcod {d_per_elem:.4} B/elem (n={n})"
    );
}

#[test]
fn alpha_override_changes_behavior_at_extremes() {
    let c = require_artifacts!();
    let mut runner = AgileRunner::new(&c.engine, &c.cfg, &c.meta).unwrap();
    let n = 48.min(c.testset.len());
    let mut acc_at = |alpha: f64, runner: &mut AgileRunner| {
        runner.set_alpha(alpha).unwrap();
        let mut correct = 0;
        for i in 0..n {
            let out = SchemeRunner::process(
                runner,
                &c.testset.image(i).unwrap(),
                c.testset.labels[i],
            )
            .unwrap();
            correct += out.correct as usize;
        }
        correct as f64 / n as f64
    };
    let trained = acc_at(c.meta.alpha, &mut runner);
    let local_only = acc_at(1.0, &mut runner);
    // the trained combination must not be worse than the local-only extreme
    // (Fig 18's shape: accuracy collapses toward alpha = 1)
    assert!(trained >= local_only - 1e-9, "trained {trained} < local-only {local_only}");
}

#[test]
fn offline_fallback_runs_without_network() {
    let c = require_artifacts!();
    let mut runner = AgileRunner::new(&c.engine, &c.cfg, &c.meta).unwrap();
    let out = runner.process_offline(&c.testset.image(0).unwrap(), c.testset.labels[0]).unwrap();
    assert_eq!(out.tx_bytes, 0);
    assert_eq!(out.breakdown.network_s, 0.0);
    assert!(out.exited_early);
}

#[test]
fn pipeline_serves_all_requests() {
    let c = require_artifacts!();
    let rep = Service::from_parts(
        c.cfg.clone(),
        c.meta.clone(),
        Arc::new(TestSet::load(&c.cfg.dataset_dir().join("test.bin")).unwrap()),
        3,
        24,
        Arrival::Poisson { hz: 200.0, seed: 7 },
    )
    .unwrap()
    .run()
    .unwrap();
    assert_eq!(rep.requests, 24);
    assert!(rep.throughput_rps > 0.0);
    assert!(rep.mean_batch_size >= 1.0);
    assert!(rep.batches >= 3); // at least one per device's first send
}

#[test]
fn serve_runs_all_five_schemes_through_the_batched_pipeline() {
    // the redesign's acceptance bar: every scheme (not just agile)
    // completes N requests through the multi-device batched Service
    let c = require_artifacts!();
    let n = 12;
    for scheme in Scheme::all() {
        let rep = ServeBuilder::new(&c.cfg.dataset)
            .artifacts_dir(c.cfg.artifacts_dir.clone())
            .scheme(scheme)
            .devices(2)
            .requests(n)
            .rate_hz(500.0)
            .build()
            .unwrap()
            .run()
            .unwrap();
        assert_eq!(rep.requests, n, "{}", scheme.name());
        assert!(rep.throughput_rps > 0.0, "{}", scheme.name());
        assert!(rep.accuracy > 0.0, "{}", scheme.name());
        match scheme {
            // local-only requests never touch the batcher
            Scheme::Mcunet => assert_eq!(rep.batches, 0, "{}", scheme.name()),
            // offloading schemes must have batched something
            Scheme::Agile | Scheme::Deepcod | Scheme::EdgeOnly => {
                assert!(rep.batches > 0, "{}", scheme.name())
            }
            Scheme::Spinn => {} // batches depend on the early-exit rate
        }
    }
}

#[test]
fn streaming_outcomes_are_observable_per_request() {
    let c = require_artifacts!();
    let n = 16;
    let mut stream = ServeBuilder::new(&c.cfg.dataset)
        .artifacts_dir(c.cfg.artifacts_dir.clone())
        .scheme(Scheme::Agile)
        .devices(2)
        .requests(n)
        .build()
        .unwrap()
        .stream()
        .unwrap();
    let mut ids = std::collections::HashSet::new();
    let mut count = 0;
    for out in stream.by_ref() {
        assert!(ids.insert(out.id), "duplicate outcome id {}", out.id);
        assert!(out.device < 2);
        assert!(out.wall_s > 0.0);
        assert!(out.outcome.tx_bytes > 0); // agile always uplinks
        assert!(out.outcome.predicted < c.meta.num_classes);
        count += 1;
    }
    assert_eq!(count, n);
    let rep = stream.finish().unwrap();
    assert_eq!(rep.requests, n);
}

#[test]
#[allow(deprecated)]
fn deprecated_run_pipeline_shim_still_serves() {
    let c = require_artifacts!();
    let rep = agilenn::coordinator::run_pipeline(
        &c.cfg,
        &c.meta,
        Arc::new(TestSet::load(&c.cfg.dataset_dir().join("test.bin")).unwrap()),
        2,
        8,
        Arrival::Periodic { hz: 1e9 },
    )
    .unwrap();
    assert_eq!(rep.requests, 8);
}

#[test]
fn engine_caches_executables() {
    let c = require_artifacts!();
    let dir = c.cfg.dataset_dir();
    let before = c.engine.cached_count();
    let _a = c.engine.load_artifact(&dir, "agile_device_b1").unwrap();
    let _b = c.engine.load_artifact(&dir, "agile_device_b1").unwrap();
    assert_eq!(c.engine.cached_count(), before + 1, "second load must hit the cache");
}

#[test]
fn lossy_serve_is_seed_deterministic() {
    // acceptance: two runs with the same ServeBuilder seed produce the same
    // accuracy and transport counters (wall-clock fields excepted)
    let c = require_artifacts!();
    let run = || {
        use agilenn::net::DeliveryPolicy;
        ServeBuilder::new(&c.cfg.dataset)
            .artifacts_dir(c.cfg.artifacts_dir.clone())
            .scheme(Scheme::Agile)
            .devices(2)
            .requests(24)
            .max_batch(1) // b1 executable everywhere: bitwise-stable logits
            .loss(agilenn::net::GilbertElliott::bursty(0.3, 4.0))
            .delivery(DeliveryPolicy::Anytime { deadline_s: 0.01 })
            .packet_payload(64)
            .net_seed(9)
            .build()
            .unwrap()
            .run()
            .unwrap()
    };
    let (a, b) = (run(), run());
    assert_eq!(a.accuracy, b.accuracy);
    assert_eq!(a.packets_sent, b.packets_sent);
    assert_eq!(a.packets_lost, b.packets_lost);
    assert_eq!(a.retransmit_rounds, b.retransmit_rounds);
    assert_eq!(a.incomplete_frames, b.incomplete_frames);
    assert_eq!(a.delivered_feature_rate, b.delivered_feature_rate);
    // the mean is deterministic up to f64 summation order (outcomes can
    // arrive in a different interleaving run to run)
    assert!((a.mean_net_s - b.mean_net_s).abs() < 1e-9);
    assert!(a.packets_lost > 0, "30% loss over 24 uplinks must drop something");
}

#[test]
fn anytime_transport_decodes_partial_frames_under_heavy_loss() {
    let c = require_artifacts!();
    use agilenn::net::{DeliveryPolicy, GilbertElliott};
    // paced arrivals on the sim clock: the radio is uncontended (33 ms
    // gaps vs a 4 ms deadline-bounded exchange), so p99_net_s measures
    // the transport alone — and the pacing costs no wall time
    let rep = ServeBuilder::new(&c.cfg.dataset)
        .artifacts_dir(c.cfg.artifacts_dir.clone())
        .scheme(Scheme::Agile)
        .devices(1)
        .requests(16)
        .max_batch(1)
        .arrival(Arrival::Periodic { hz: 30.0 })
        .clock(ClockKind::Sim)
        .loss(GilbertElliott::uniform(0.5))
        // tight deadline: one pass, no time for full recovery
        .delivery(DeliveryPolicy::Anytime { deadline_s: 0.004 })
        .packet_payload(64)
        .net_seed(3)
        .build()
        .unwrap()
        .run()
        .unwrap();
    assert_eq!(rep.requests, 16);
    assert!(rep.incomplete_frames > 0, "50% loss must leave partial frames");
    assert!(rep.delivered_feature_rate < 1.0);
    assert!(rep.delivered_feature_rate > 0.0);
    // every request still produced a prediction (graceful degradation)
    assert!(rep.accuracy > 0.0);
    // the deadline bounds the simulated link time
    assert!(rep.p99_net_s <= 0.004 + 0.01, "p99 net {}", rep.p99_net_s);
}

#[test]
fn zero_loss_channel_reproduces_the_ideal_link_numbers() {
    // acceptance: at 0% loss the default (ARQ, whole-frame) path is
    // behaviorally identical to the pre-channel NetworkSim pricing. Paced
    // arrivals keep the radio idle between requests (no queueing term);
    // the sim clock makes the pacing free.
    let c = require_artifacts!();
    use agilenn::simulator::NetworkSim;
    let mut stream = ServeBuilder::new(&c.cfg.dataset)
        .artifacts_dir(c.cfg.artifacts_dir.clone())
        .scheme(Scheme::Agile)
        .devices(1)
        .requests(8)
        .max_batch(1)
        .arrival(Arrival::Periodic { hz: 30.0 })
        .clock(ClockKind::Sim)
        .build()
        .unwrap()
        .stream()
        .unwrap();
    let net = NetworkSim::new(c.cfg.network.clone());
    let reply = agilenn::serve::reply_bytes(c.meta.num_classes);
    for out in stream.by_ref() {
        let expect = net.transfer_s(out.outcome.tx_bytes) + net.transfer_s(reply);
        let got = out.outcome.breakdown.network_s;
        assert!((got - expect).abs() < 1e-9, "network_s {got} != closed form {expect}");
        assert!(out.outcome.net.complete);
        assert_eq!(out.outcome.net.packets_lost, 0);
        assert_eq!(out.outcome.net.radio_wait_s, 0.0, "paced run must not queue the radio");
    }
    stream.finish().unwrap();
}

// ---------------------------------------------------------------------------
// virtual-time serving clock
// ---------------------------------------------------------------------------

#[test]
fn sim_clock_serve_is_bit_reproducible_and_never_sleeps() {
    // acceptance: two identical-seed sim-clock runs produce bit-identical
    // accuracy, latency quantiles and net counters — and the paced run
    // costs no wall time (512 requests at 200 Hz would be ~0.32 s of
    // sleeping per device on the wall clock; here only the compute pays)
    let c = require_artifacts!();
    use agilenn::net::GilbertElliott;
    let run = || -> PipelineReport {
        ServeBuilder::new(&c.cfg.dataset)
            .artifacts_dir(c.cfg.artifacts_dir.clone())
            .scheme(Scheme::Agile)
            .devices(8)
            .requests(512)
            .rate_hz(200.0)
            .arrival_seed(11)
            .max_batch(1) // b1 executable everywhere: bitwise-stable logits
            .loss(GilbertElliott::bursty(0.2, 4.0))
            .net_seed(5)
            .clock(ClockKind::Sim)
            .build()
            .unwrap()
            .run()
            .unwrap()
    };
    let (a, b) = (run(), run());
    assert_eq!(a.clock, ClockKind::Sim);
    assert_eq!(a.requests, 512);
    assert_eq!(a.accuracy, b.accuracy);
    assert_eq!(a.p95_latency_s, b.p95_latency_s, "latency quantiles must be virtual-time exact");
    assert_eq!(a.p99_net_s, b.p99_net_s);
    assert_eq!(a.packets_sent, b.packets_sent);
    assert_eq!(a.packets_lost, b.packets_lost);
    assert_eq!(a.retransmit_rounds, b.retransmit_rounds);
    assert_eq!(a.incomplete_frames, b.incomplete_frames);
    assert_eq!(a.delivered_feature_rate, b.delivered_feature_rate);
    assert!((a.wall_s - b.wall_s).abs() < 1e-9, "virtual makespan must reproduce");
    assert!((a.mean_latency_s - b.mean_latency_s).abs() < 1e-9);
    // the virtual makespan covers the arrival schedule (~64 reqs/device
    // at 200 Hz ≈ 0.32 s), not the microseconds an unpaced run would show
    assert!(a.wall_s > 0.1, "virtual time {} must reflect the pacing", a.wall_s);
    assert!(a.packets_lost > 0, "20% bursty loss must drop something");
}

#[test]
fn wall_and_sim_clocks_agree_on_the_seed_deterministic_fields() {
    // the simulated timeline (channel timestamps, loss pattern, radio
    // queueing) is schedule-anchored, so switching clocks must not move
    // any deterministic field — only the live wall measurements change
    let c = require_artifacts!();
    use agilenn::net::GilbertElliott;
    let run = |clock: ClockKind| -> PipelineReport {
        ServeBuilder::new(&c.cfg.dataset)
            .artifacts_dir(c.cfg.artifacts_dir.clone())
            .scheme(Scheme::Agile)
            .devices(2)
            .requests(16)
            .rate_hz(120.0)
            .arrival_seed(3)
            .max_batch(1)
            .loss(GilbertElliott::uniform(0.1))
            .net_seed(4)
            .clock(clock)
            .build()
            .unwrap()
            .run()
            .unwrap()
    };
    let (w, s) = (run(ClockKind::Wall), run(ClockKind::Sim));
    assert_eq!(w.clock, ClockKind::Wall);
    assert_eq!(s.clock, ClockKind::Sim);
    assert_eq!(w.accuracy, s.accuracy);
    assert_eq!(w.packets_sent, s.packets_sent);
    assert_eq!(w.packets_lost, s.packets_lost);
    assert_eq!(w.retransmit_rounds, s.retransmit_rounds);
    assert_eq!(w.incomplete_frames, s.incomplete_frames);
    assert_eq!(w.delivered_feature_rate, s.delivered_feature_rate);
    assert_eq!(w.p99_net_s, s.p99_net_s, "link quantiles derive from the same multiset");
    assert!((w.mean_net_s - s.mean_net_s).abs() < 1e-9);
    assert!((w.mean_radio_wait_s - s.mean_radio_wait_s).abs() < 1e-12);
}

#[test]
fn radio_contention_grows_with_offered_rate_never_shrinks() {
    // regression: uplinks used to start at arrival + compute with no
    // memory of the previous transmission, so a saturated device's
    // simulated transfers overlapped and link latency was underestimated
    let c = require_artifacts!();
    let run = |hz: f64| -> PipelineReport {
        ServeBuilder::new(&c.cfg.dataset)
            .artifacts_dir(c.cfg.artifacts_dir.clone())
            .scheme(Scheme::Agile)
            .devices(1)
            .requests(48)
            .max_batch(1)
            .arrival(Arrival::Periodic { hz })
            .clock(ClockKind::Sim)
            .build()
            .unwrap()
            .run()
            .unwrap()
    };
    let relaxed = run(5.0); // 200 ms gaps: the radio always drains
    let saturated = run(2000.0); // 0.5 ms gaps: far beyond link capacity
    assert_eq!(relaxed.mean_radio_wait_s, 0.0, "uncontended link must not queue");
    assert!(
        saturated.mean_radio_wait_s > 0.0,
        "saturated link must surface radio queueing"
    );
    assert!(
        saturated.p99_net_s >= relaxed.p99_net_s,
        "higher rate cannot lower simulated link latency: {} vs {}",
        saturated.p99_net_s,
        relaxed.p99_net_s
    );
}
