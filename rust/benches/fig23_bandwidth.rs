//! Fig 23 bench: latency vs wireless bandwidth.

use agilenn::bench::Bench;
use agilenn::experiments::{run_figure, EvalCtx};
use agilenn::simulator::{NetworkProfile, NetworkSim};

fn main() {
    let ctx = EvalCtx::from_env().expect("run `make artifacts` first");
    for t in run_figure(&ctx, "23").expect("fig23") {
        t.print();
        println!();
    }
    let net = NetworkSim::new(NetworkProfile::ble_270kbps());
    Bench::new().run("fig23_link_model", || net.transfer_s(420));
}
