//! Table 2 bench: transmitted-bytes reduction vs DeepCOD; times the
//! device-side transmit encoder (quantize + bitpack + LZW).

use agilenn::bench::Bench;
use agilenn::compression::{quantizer::Codebook, TxEncoder};
use agilenn::config::Scheme;
use agilenn::experiments::{run_figure, EvalCtx};

fn main() {
    let ctx = EvalCtx::from_env().expect("run `make artifacts` first");
    for t in run_figure(&ctx, "t2").expect("tab02") {
        t.print();
        println!();
    }
    let ds = ctx.datasets[0].clone();
    let meta = ctx.meta(&ds).unwrap();
    let cb = Codebook::new(meta.codebook(Scheme::Agile, 4).unwrap()).unwrap();
    let mut tx = TxEncoder::new(cb);
    // representative zero-skewed feature frame
    let feats: Vec<f32> = (0..meta.tx_elements(Scheme::Agile))
        .map(|i| if i % 6 == 0 { (i % 13) as f32 * 0.11 } else { 0.0 })
        .collect();
    Bench::new().run("tab02_tx_encode", || tx.encode(&feats));
}
