//! Fig 17 bench: accuracy vs compression rate (AgileNN vs DeepCOD);
//! times codebook quantization across bit widths.

use agilenn::bench::Bench;
use agilenn::compression::quantizer::{bitpack, Codebook};
use agilenn::config::Scheme;
use agilenn::experiments::{run_figure, EvalCtx};

fn main() {
    let ctx = EvalCtx::from_env().expect("run `make artifacts` first");
    for t in run_figure(&ctx, "17").expect("fig17") {
        t.print();
        println!();
    }
    let ds = ctx.datasets[0].clone();
    let meta = ctx.meta(&ds).unwrap();
    let vals: Vec<f32> = (0..1216).map(|i| if i % 5 == 0 { 0.4 } else { 0.0 }).collect();
    let b = Bench::new();
    for bits in [1u32, 4] {
        let cb = Codebook::new(meta.codebook(Scheme::Agile, bits).unwrap()).unwrap();
        let mut idx = Vec::new();
        b.run(&format!("fig17_quantize/{bits}bit"), || {
            cb.quantize(&vals, &mut idx);
            bitpack(&idx, bits)
        });
    }
}
