//! Fig 24 bench: IG vs Gradient-Saliency XAI comparison table.

use agilenn::bench::Bench;
use agilenn::experiments::{run_figure, EvalCtx};
use agilenn::xai;

fn main() {
    let ctx = EvalCtx::from_env().expect("run `make artifacts` first");
    for t in run_figure(&ctx, "24").expect("fig24") {
        t.print();
        println!();
    }
    let imp: Vec<f64> = (0..24).map(|i| ((i * 7919) % 101) as f64).collect();
    Bench::new().run("fig24_normalize", || xai::normalize(&imp));
}
