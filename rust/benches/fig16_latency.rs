//! Fig 16 bench: regenerates the end-to-end latency/accuracy table and
//! times the AgileNN request hot path against DeepCOD's.

use agilenn::baselines::make_runner;
use agilenn::bench::Bench;
use agilenn::config::Scheme;
use agilenn::experiments::{run_figure, EvalCtx};

fn main() {
    let ctx = EvalCtx::from_env().expect("run `make artifacts` first");
    for t in run_figure(&ctx, "16").expect("fig16") {
        t.print();
        println!();
    }
    let ds = ctx.datasets[0].clone();
    let meta = ctx.meta(&ds).unwrap();
    let testset = ctx.testset(&ds).unwrap();
    let img = testset.image(0).unwrap();
    let b = Bench::new();
    for scheme in [Scheme::Agile, Scheme::Deepcod] {
        let cfg = ctx.run_config(&ds, scheme);
        let mut runner = make_runner(ctx.backend.as_ref(), &cfg, &meta).unwrap();
        b.run(&format!("fig16_request_path/{}", scheme.name()), || {
            runner.process(&img, testset.labels[0]).unwrap()
        });
    }
}
