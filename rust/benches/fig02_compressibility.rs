//! Fig 2 bench: data-compressibility motivation; times the DCT codec at two
//! quality levels.

use agilenn::bench::Bench;
use agilenn::compression::dct;
use agilenn::experiments::{run_figure, EvalCtx};

fn main() {
    let ctx = EvalCtx::from_env().expect("run `make artifacts` first");
    for t in run_figure(&ctx, "2").expect("fig02") {
        t.print();
        println!();
    }
    let img: Vec<f32> = (0..32 * 32 * 3).map(|i| ((i % 97) as f32) / 97.0).collect();
    let b = Bench::new();
    for q in [10.0f32, 90.0] {
        b.run(&format!("fig02_dct_encode/q{}", q as u32), || {
            dct::encode(&img, 32, 32, 3, q).unwrap()
        });
    }
}
