//! Fig 19 bench: device energy per inference across schemes/datasets.

use agilenn::bench::Bench;
use agilenn::experiments::{run_figure, EvalCtx};
use agilenn::simulator::{DeviceProfile, DeviceSim};

fn main() {
    let ctx = EvalCtx::from_env().expect("run `make artifacts` first");
    for t in run_figure(&ctx, "19").expect("fig19") {
        t.print();
        println!();
    }
    let sim = DeviceSim::new(DeviceProfile::stm32f746());
    Bench::new().run("fig19_energy_model", || {
        let t = sim.nn_latency_s(332_146) + sim.quantize_latency_s(1216);
        sim.compute_energy_j(t) + sim.radio_energy_j(0.001)
    });
}
