//! Fig 18 bench: accuracy vs prediction re-weighting alpha; times the
//! combiner (which must be negligible — §3.3's argument for weighted
//! summation over an extra NN layer).

use agilenn::bench::Bench;
use agilenn::coordinator::Combiner;
use agilenn::experiments::{run_figure, EvalCtx};

fn main() {
    let ctx = EvalCtx::from_env().expect("run `make artifacts` first");
    for t in run_figure(&ctx, "18").expect("fig18") {
        t.print();
        println!();
    }
    let combiner = Combiner::new(0.3).unwrap();
    let local: Vec<f32> = (0..200).map(|i| (i as f32 * 0.37).sin()).collect();
    let remote: Vec<f32> = (0..200).map(|i| (i as f32 * 0.11).cos()).collect();
    Bench::new().run("fig18_combine_200class", || combiner.predict(&local, &remote).unwrap());
}
