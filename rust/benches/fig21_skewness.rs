//! Fig 21 bench: skewness-manipulation effectiveness; times the runtime
//! skewness metrics over importance vectors.

use agilenn::bench::Bench;
use agilenn::experiments::{run_figure, EvalCtx};
use agilenn::xai;

fn main() {
    let ctx = EvalCtx::from_env().expect("run `make artifacts` first");
    for t in run_figure(&ctx, "21").expect("fig21") {
        t.print();
        println!();
    }
    let imp: Vec<f64> = (0..24).map(|i| 1.0 / (1.0 + i as f64)).collect();
    Bench::new().run("fig21_skewness_metrics", || {
        (
            xai::natural_skewness(&imp, 5),
            xai::achieved_skewness(&imp, 5),
            xai::is_disordered(&imp, 5),
        )
    });
}
