//! Fleet-engine timed harness: events/sec of the discrete-event serving
//! engine on the reference backend, at a size small enough for the
//! microbench loop (the full 1M-request gate lives in `agilenn perfgate`;
//! this bench tracks per-iteration cost during development).

use agilenn::bench::Bench;
use agilenn::config::{BackendKind, Scheme};
use agilenn::fixtures::SYNTHETIC_DATASET;
use agilenn::serve::{ClockKind, Placement, ServeBuilder, SimEngine};

fn run(requests: usize, devices: usize, servers: usize) -> usize {
    ServeBuilder::new(SYNTHETIC_DATASET)
        .backend(BackendKind::Reference)
        .scheme(Scheme::Agile)
        .clock(ClockKind::Sim)
        .fleet(|f| f.devices = devices)
        .fleet(|f| f.requests = requests)
        .rate_hz(20.0)
        .arrival_seed(11)
        .fleet(|f| f.servers = servers)
        .fleet(|f| f.placement = Placement::LeastLoaded)
        .build()
        .unwrap()
        .run()
        .unwrap()
        .requests
}

fn main() {
    let b = Bench::new();
    b.run("fleet_engine/10k_reqs_256_dev_1srv", || run(10_000, 256, 1));
    b.run("fleet_engine/10k_reqs_256_dev_4srv", || run(10_000, 256, 4));

    // the threaded fabric at the largest size it comfortably runs, for
    // the engine-vs-threads speedup headline
    let threaded = ServeBuilder::new(SYNTHETIC_DATASET)
        .backend(BackendKind::Reference)
        .scheme(Scheme::Agile)
        .clock(ClockKind::Sim)
        .sim_engine(SimEngine::Threads)
        .fleet(|f| f.devices = 8)
        .fleet(|f| f.requests = 2_000)
        .rate_hz(20.0)
        .arrival_seed(11);
    b.run("fleet_threads/2k_reqs_8_dev", || {
        threaded.clone().build().unwrap().run().unwrap().requests
    });
}
