//! Fig 20 bench: SRAM/flash usage per scheme (static accounting).

use agilenn::bench::Bench;
use agilenn::experiments::{run_figure, EvalCtx};
use agilenn::simulator::{DeviceProfile, MemoryReport};

fn main() {
    let ctx = EvalCtx::from_env().expect("run `make artifacts` first");
    for t in run_figure(&ctx, "20").expect("fig20") {
        t.print();
        println!();
    }
    let profile = DeviceProfile::stm32f746();
    Bench::new().run("fig20_memory_report", || {
        MemoryReport::new(&profile, 64 * 1024, 100 * 1024).fits()
    });
}
