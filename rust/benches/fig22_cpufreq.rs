//! Fig 22 bench: latency vs device CPU frequency.

use agilenn::bench::Bench;
use agilenn::experiments::{run_figure, EvalCtx};
use agilenn::simulator::{DeviceProfile, DeviceSim};

fn main() {
    let ctx = EvalCtx::from_env().expect("run `make artifacts` first");
    for t in run_figure(&ctx, "22").expect("fig22") {
        t.print();
        println!();
    }
    Bench::new().run("fig22_cost_model_sweep", || {
        [216e6, 160e6, 108e6, 64e6]
            .iter()
            .map(|&f| {
                DeviceSim::new(DeviceProfile::stm32f746().with_freq(f)).nn_latency_s(332_146)
            })
            .sum::<f64>()
    });
}
