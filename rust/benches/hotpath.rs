//! Hot-path microbenches for the perf pass (EXPERIMENTS.md §Perf):
//! device PJRT call, remote PJRT call per batch size, LZW, quantize,
//! end-to-end request.

use agilenn::baselines::make_runner;
use agilenn::bench::Bench;
use agilenn::compression::{lzw, quantizer::Codebook, TxEncoder};
use agilenn::config::Scheme;
use agilenn::coordinator::{DeviceRuntime, RemoteServer};
use agilenn::experiments::EvalCtx;
use agilenn::tensor::Tensor;

fn main() {
    let ctx = EvalCtx::from_env().expect("run `make artifacts` first");
    let ds = ctx.datasets[0].clone();
    let meta = ctx.meta(&ds).unwrap();
    let testset = ctx.testset(&ds).unwrap();
    let img = testset.image(0).unwrap();
    let cfg = ctx.run_config(&ds, Scheme::Agile);
    let b = Bench::new();

    // device phase (PJRT extractor+local + quantize + LZW)
    let mut device = DeviceRuntime::new(ctx.backend.as_ref(), &cfg, &meta).unwrap();
    b.run("hot_device_phase", || device.process(&img).unwrap());

    // remote phase per batch size
    let mut server = RemoteServer::new(ctx.backend.as_ref(), &cfg, &meta).unwrap();
    let out = device.process(&img).unwrap();
    let feat = server.decode(&out.frame).unwrap();
    for bsz in [1usize, 4, 8] {
        let feats: Vec<Tensor> = (0..bsz).map(|_| feat.clone()).collect();
        b.run(&format!("hot_remote_batch/{bsz}"), || server.infer(&feats).unwrap());
    }

    // compression kernels
    let vals: Vec<f32> = (0..meta.tx_elements(Scheme::Agile))
        .map(|i| if i % 6 == 0 { 0.4 } else { 0.0 })
        .collect();
    let cb = Codebook::new(meta.codebook(Scheme::Agile, 4).unwrap()).unwrap();
    let mut tx = TxEncoder::new(cb);
    b.run("hot_tx_encode", || tx.encode(&vals));
    let frame = tx.encode(&vals);
    b.run("hot_lzw_decompress", || lzw::decompress(&frame.payload).unwrap());

    // end-to-end request
    let mut runner = make_runner(ctx.backend.as_ref(), &cfg, &meta).unwrap();
    b.run("hot_e2e_agile_request", || runner.process(&img, testset.labels[0]).unwrap());
}
